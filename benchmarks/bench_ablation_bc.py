"""Ablation: reduced phase-b/c chain length (the paper's §4 future work —
"reduce the number of samples for sub-blocks in phase (b) and (c)").

Sweeps the phase-b/c sample count at fixed phase-a length and reports the
RMSE / modeled-16-worker-wall trade-off.
"""
from __future__ import annotations

import argparse

import jax

from repro.core import bmf as BMF
from repro.core import pp as PP
from repro.core.partition import partition, suggest_grid
from repro.data import synthetic as SYN
from repro.data.sparse import train_test_split

from benchmarks.common import emit


def run(dataset: str = "movielens", n_samples: int = 40):
    coo, p = SYN.generate(dataset, seed=61)
    train, test = train_test_split(coo, 0.1, seed=62)
    K = min(p.K, 16)
    I, J = suggest_grid(train.n_rows, train.n_cols, 4)
    part = partition(train, I, J)

    base = BMF.BMFConfig(K=K, n_samples=n_samples, burnin=n_samples // 3)
    # warm the executables
    PP.run_pp(jax.random.key(9), part, base._replace(n_samples=2, burnin=0),
              test)

    for frac, bc in [("1.00", None), ("0.50", n_samples // 2),
                     ("0.25", n_samples // 4)]:
        cfg = base._replace(phase_bc_samples=bc)
        res = PP.run_pp(jax.random.key(0), part, cfg, test)
        t16 = res.modeled_parallel_s(16)
        emit(f"ablation_bc/{dataset}/bc_frac={frac}", t16,
             f"rmse={res.rmse:.4f}")
    return True


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="movielens")
    args = ap.parse_args()
    run(args.dataset)


if __name__ == "__main__":
    main()
