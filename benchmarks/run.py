"""Benchmark entrypoint: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Budget-friendly defaults; pass
--full for the larger presets.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="larger datasets / paper K (slow on CPU)")
    ap.add_argument("--only", nargs="*", default=None)
    args, _ = ap.parse_known_args()

    from benchmarks import (bench_ablation_bc, bench_blocksize, bench_rmse,
                            bench_roofline, bench_scaling, bench_throughput,
                            bench_walltime)

    suites = {
        "table2_rmse": lambda: bench_rmse.run(
            "movielens" if not args.full else "netflix"),
        "table3_walltime": lambda: bench_walltime.run("movielens"),
        "fig3_blocksize": lambda: bench_blocksize.run(
            "netflix" if args.full else "movielens"),
        "fig45_scaling": lambda: bench_scaling.run("movielens"),
        "table1_throughput": lambda: bench_throughput.run("movielens"),
        "ablation_bc": lambda: bench_ablation_bc.run("movielens"),
        "roofline": lambda: bench_roofline.run(mesh="single"),
    }
    failed = []
    for name, fn in suites.items():
        if args.only and name not in args.only:
            continue
        print(f"# --- {name} ---")
        try:
            fn()
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"# FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
