"""Paper Table 3: wall-clock of BMF+PP vs full BMF (single node).

The paper's claim: PP cuts single-system wall-clock ~2× on movielens,
~2.3× netflix, ~5.6× yahoo, ~3× amazon versus full BMF at the same
per-block sample count (fewer data per Gibbs sweep, same #sweeps).
derived = speedup (bmf / bmf_pp).
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.core import bmf as BMF
from repro.core import pp as PP
from repro.core.partition import partition, suggest_grid
from repro.data import synthetic as SYN
from repro.data.sparse import train_test_split

from benchmarks.common import emit


def run(dataset: str, n_blocks: int = 4, n_samples: int = 30):
    coo, p = SYN.generate(dataset, seed=21)
    train, test = train_test_split(coo, 0.1, seed=22)
    K = min(p.K, 16)
    cfg = BMF.BMFConfig(K=K, n_samples=n_samples, burnin=n_samples // 3)

    I, J = suggest_grid(train.n_rows, train.n_cols, n_blocks)
    part = partition(train, I, J)

    # warm-up pass: populate the jit caches (compile time is amortized in a
    # production deployment; steady-state sweeps are what Table 3 compares)
    warm = cfg._replace(n_samples=2, burnin=0)
    PP.run_full_bmf(jax.random.key(9), train, test, warm)
    PP.run_pp(jax.random.key(9), part, warm, test)

    rmse_full, t_full, _ = PP.run_full_bmf(jax.random.key(0), train, test, cfg)
    res = PP.run_pp(jax.random.key(1), part, cfg, test)

    speedup = t_full / max(res.wall_time_s, 1e-9)
    emit(f"table3_walltime/{dataset}/bmf", t_full, f"rmse={rmse_full:.4f}")
    emit(f"table3_walltime/{dataset}/bmf_pp_{I}x{J}", res.wall_time_s,
         f"rmse={res.rmse:.4f};speedup={speedup:.2f}")
    # the paper's Table-3 deployment runs blocks of a phase concurrently on
    # the node's cores; model that with the measured per-block times
    t16 = res.modeled_parallel_s(16)
    emit(f"table3_walltime/{dataset}/bmf_pp_{I}x{J}_16workers", t16,
         f"rmse={res.rmse:.4f};speedup={t_full / max(t16, 1e-9):.2f}")

    # beyond-paper: reduced phase-b/c chains (paper §4 future work)
    cfg_red = cfg._replace(phase_bc_samples=max(8, n_samples // 2))
    res_red = PP.run_pp(jax.random.key(1), part, cfg_red, test)
    t16r = res_red.modeled_parallel_s(16)
    emit(f"table3_walltime/{dataset}/bmf_pp_{I}x{J}_reduced_bc", t16r,
         f"rmse={res_red.rmse:.4f};speedup={t_full / max(t16r, 1e-9):.2f}")
    return t_full, res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--datasets", nargs="+", default=["movielens"])
    args = ap.parse_args()
    for d in args.datasets:
        run(d)


if __name__ == "__main__":
    main()
