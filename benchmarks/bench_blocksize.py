"""Paper Figure 3: block-size exploration (RMSE vs wall-clock vs I×J).

The paper explores Netflix (27:1 row/col aspect) and finds squarer blocks
(e.g. 20×3) give the best trade-off. We sweep grids on the netflix-like
preset and emit rmse+time per grid; squareness = |log(rows-per-block /
cols-per-block)|.
"""
from __future__ import annotations

import argparse
import math

import jax

from repro.core import bmf as BMF
from repro.core import pp as PP
from repro.core.partition import partition
from repro.data import synthetic as SYN
from repro.data.sparse import train_test_split

from benchmarks.common import emit

GRIDS = [(1, 1), (2, 2), (4, 1), (1, 4), (4, 2), (8, 1), (2, 4), (8, 2)]


def run(dataset: str = "netflix", n_samples: int = 25):
    coo, p = SYN.generate(dataset, seed=31)
    train, test = train_test_split(coo, 0.1, seed=32)
    cfg = BMF.BMFConfig(K=min(p.K, 16), n_samples=n_samples,
                        burnin=n_samples // 3)
    out = []
    for (I, J) in GRIDS:
        part = partition(train, I, J)
        res = PP.run_pp(jax.random.key(0), part, cfg, test)
        sq = abs(math.log((train.n_rows / I) / max(train.n_cols / J, 1)))
        emit(f"fig3_blocksize/{dataset}/{I}x{J}", res.wall_time_s,
             f"rmse={res.rmse:.4f};squareness={sq:.2f}")
        out.append(((I, J), res.rmse, res.wall_time_s, sq))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="netflix")
    args = ap.parse_args()
    run(args.dataset)


if __name__ == "__main__":
    main()
