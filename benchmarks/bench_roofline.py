"""Roofline tables.

Default mode reads the LLM dry-run artifact JSON and emits one row per
(arch × shape × mesh) with the three roofline terms + dominant.

``--bmf`` mode rooflines the BMF Gibbs hot path instead: it traces
``core.bmf.sufficient_stats`` for the fused zero-materialization path and
the XLA-gather baseline (``--use-kernel both``, the default, does both in
one run), reporting jaxpr FLOPs, HBM byte estimate, the LARGEST live
buffer (the (N, M, K) gathered tensor shows up only in the baseline), and
the measured wall-clock per call on this host."""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from benchmarks.common import emit, timed

DEFAULT = Path(__file__).resolve().parent / "dryrun_results.json"


def run(path=DEFAULT, mesh: str = "single"):
    recs = json.loads(Path(path).read_text())
    rows = []
    for r in recs:
        if r.get("tag"):           # hillclimb variants reported in §Perf
            continue
        if r["status"] != "ok" or r["mesh"] != mesh:
            continue
        rf = r["roofline"]
        step = rf["compute_s"] + rf["memory_s"] + rf["collective_s"]
        emit(f"roofline/{r['arch']}/{r['shape']}/{mesh}", step,
             f"dom={rf['dominant']};c={rf['compute_s']:.3e};"
             f"m={rf['memory_s']:.3e};coll={rf['collective_s']:.3e};"
             f"useful={r['useful_flops_ratio']:.2f}")
        rows.append(r)
    return rows


def run_bmf(datasets, use_kernel: str = "both"):
    import jax
    import jax.numpy as jnp

    from repro.core import bmf as BMF
    from repro.data import synthetic as SYN
    from repro.data.sparse import PaddedCSR, coo_to_padded_csr, \
        train_test_split
    from repro.roofline.jaxpr_cost import jaxpr_cost, peak_buffer_bytes

    from benchmarks.bench_throughput import KERNEL_PATHS, path_name
    rows = []
    for d in datasets:
        coo, p = SYN.generate(d, seed=51)
        train, _ = train_test_split(coo, 0.1, seed=52)
        csr = coo_to_padded_csr(train)
        K = min(p.K, 16)
        other = jnp.zeros((train.n_cols, K), jnp.float32)
        for uk in KERNEL_PATHS[use_kernel]:
            def stats(idx, val, mask, o, _uk=uk):
                return BMF.sufficient_stats(
                    PaddedCSR(idx, val, mask, train.n_cols), o, 2.0, _uk)

            jaxpr = jax.make_jaxpr(stats)(csr.idx, csr.val, csr.mask, other)
            cost = jaxpr_cost(jaxpr)
            peak = peak_buffer_bytes(jaxpr)
            fn = jax.jit(stats)
            jax.block_until_ready(
                fn(csr.idx, csr.val, csr.mask, other))   # compile + sync
            _, secs = timed(fn, csr.idx, csr.val, csr.mask, other, repeats=3)
            name = path_name(uk)
            emit(f"bmf_roofline/{d}/{name}", secs,
                 f"flops={cost['flops']:.3e};bytes={cost['bytes']:.3e};"
                 f"peak_buffer_mb={peak / 2**20:.1f};K={K}")
            rows.append({"dataset": d, "path": name, "sec_per_call": secs,
                         "flops": cost["flops"], "bytes": cost["bytes"],
                         "peak_buffer_bytes": peak})
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--path", default=str(DEFAULT))
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--bmf", action="store_true",
                    help="roofline the BMF sufficient-stats hot path")
    ap.add_argument("--datasets", nargs="+", default=["movielens"])
    ap.add_argument("--use-kernel", choices=["on", "off", "both"],
                    default="both")
    args = ap.parse_args()
    if args.bmf:
        run_bmf(args.datasets, args.use_kernel)
        return
    if not Path(args.path).exists():
        print("# no dryrun_results.json - run python -m repro.launch.dryrun --all first")
        return
    run(args.path, args.mesh)


if __name__ == "__main__":
    main()
