"""Assignment roofline table: reads the dry-run artifact JSON and emits one
row per (arch × shape × mesh) with the three roofline terms + dominant."""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from benchmarks.common import emit

DEFAULT = Path(__file__).resolve().parent / "dryrun_results.json"


def run(path=DEFAULT, mesh: str = "single"):
    recs = json.loads(Path(path).read_text())
    rows = []
    for r in recs:
        if r.get("tag"):           # hillclimb variants reported in §Perf
            continue
        if r["status"] != "ok" or r["mesh"] != mesh:
            continue
        rf = r["roofline"]
        step = rf["compute_s"] + rf["memory_s"] + rf["collective_s"]
        emit(f"roofline/{r['arch']}/{r['shape']}/{mesh}", step,
             f"dom={rf['dominant']};c={rf['compute_s']:.3e};"
             f"m={rf['memory_s']:.3e};coll={rf['collective_s']:.3e};"
             f"useful={r['useful_flops_ratio']:.2f}")
        rows.append(r)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--path", default=str(DEFAULT))
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    args = ap.parse_args()
    if not Path(args.path).exists():
        print("# no dryrun_results.json - run python -m repro.launch.dryrun --all first")
        return
    run(args.path, args.mesh)


if __name__ == "__main__":
    main()
