"""Roofline tables.

Default mode reads the LLM dry-run artifact JSON and emits one row per
(arch × shape × mesh) with the three roofline terms + dominant.

``--bmf`` mode rooflines the BMF Gibbs hot path instead: it traces
``core.bmf.sufficient_stats`` for the fused zero-materialization path and
the XLA-gather baseline (``--use-kernel both``, the default, does both in
one run), reporting jaxpr FLOPs, HBM byte estimate, the LARGEST live
buffer (the (N, M, K) gathered tensor shows up only in the baseline), and
the measured wall-clock per call on this host.

``--gibbs-peak`` measures the PEAK LIVE device-buffer footprint of a full
PP run under the stacked, async, and streaming executors, donation off vs
on (streaming's peak is bounded by its --window, flat in grid size): every
``run_gibbs``/``run_gibbs_stacked`` dispatch samples
``sum(nbytes over jax.live_arrays())``, and each run's phase-c chain
executable is additionally lowered both ways to record XLA's own buffer
assignment (argument+temp+output−alias = the effective per-dispatch peak;
donation turns U0/V0 into in-place aliases of the U/V outputs). The async
executor's per-block dispatch also holds ~1/B of the stacked bucket's
input planes at a time, which is the larger live-footprint lever."""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from benchmarks.common import emit, timed

DEFAULT = Path(__file__).resolve().parent / "dryrun_results.json"


def run(path=DEFAULT, mesh: str = "single"):
    recs = json.loads(Path(path).read_text())
    rows = []
    for r in recs:
        if r.get("tag"):           # hillclimb variants reported in §Perf
            continue
        if r["status"] != "ok" or r["mesh"] != mesh:
            continue
        rf = r["roofline"]
        step = rf["compute_s"] + rf["memory_s"] + rf["collective_s"]
        emit(f"roofline/{r['arch']}/{r['shape']}/{mesh}", step,
             f"dom={rf['dominant']};c={rf['compute_s']:.3e};"
             f"m={rf['memory_s']:.3e};coll={rf['collective_s']:.3e};"
             f"useful={r['useful_flops_ratio']:.2f}")
        rows.append(r)
    return rows


def run_bmf(datasets, use_kernel: str = "both"):
    import jax
    import jax.numpy as jnp

    from repro.core import bmf as BMF
    from repro.data import synthetic as SYN
    from repro.data.sparse import PaddedCSR, coo_to_padded_csr, \
        train_test_split
    from repro.roofline.jaxpr_cost import jaxpr_cost, peak_buffer_bytes

    from benchmarks.bench_throughput import KERNEL_PATHS, path_name
    rows = []
    for d in datasets:
        coo, p = SYN.generate(d, seed=51)
        train, _ = train_test_split(coo, 0.1, seed=52)
        csr = coo_to_padded_csr(train)
        K = min(p.K, 16)
        other = jnp.zeros((train.n_cols, K), jnp.float32)
        for uk in KERNEL_PATHS[use_kernel]:
            def stats(idx, val, mask, o, _uk=uk):
                return BMF.sufficient_stats(
                    PaddedCSR(idx, val, mask, train.n_cols), o, 2.0, _uk)

            jaxpr = jax.make_jaxpr(stats)(csr.idx, csr.val, csr.mask, other)
            cost = jaxpr_cost(jaxpr)
            peak = peak_buffer_bytes(jaxpr)
            fn = jax.jit(stats)
            jax.block_until_ready(
                fn(csr.idx, csr.val, csr.mask, other))   # compile + sync
            _, secs = timed(fn, csr.idx, csr.val, csr.mask, other, repeats=3)
            name = path_name(uk)
            emit(f"bmf_roofline/{d}/{name}", secs,
                 f"flops={cost['flops']:.3e};bytes={cost['bytes']:.3e};"
                 f"peak_buffer_mb={peak / 2**20:.1f};K={K}")
            rows.append({"dataset": d, "path": name, "sec_per_call": secs,
                         "flops": cost["flops"], "bytes": cost["bytes"],
                         "peak_buffer_bytes": peak})
    return rows


def _xla_chain_peak(shapes, n_blocks: int, cfg, stacked: bool, donate: bool,
                    has_priors: bool, prior_flags: bool = False):
    """Lower the engine's chain executable at one bucket's shapes and read
    XLA's buffer assignment: effective peak = arg + temp + out − alias
    (aliased donations are written in place, not double-counted).
    ``prior_flags`` lowers the per-block prior_use variant — the executable
    the STREAMING executor actually dispatches per window chunk."""
    import warnings

    import jax
    import jax.numpy as jnp

    from repro.core import gibbs as GIBBS
    from repro.core.posterior import RowGaussians

    K = cfg.K
    S = jax.ShapeDtypeStruct
    lead = (n_blocks,) if stacked else ()
    N, D, M, Mc, T = (shapes.n_rows, shapes.n_cols, shapes.m_rows,
                      shapes.m_cols, shapes.n_test)
    csr_r = (S(lead + (N, M), jnp.int32), S(lead + (N, M), jnp.float32),
             S(lead + (N, M), jnp.float32))
    csr_c = (S(lead + (D, Mc), jnp.int32), S(lead + (D, Mc), jnp.float32),
             S(lead + (D, Mc), jnp.float32))
    tst = S(lead + (T,), jnp.int32)
    prior_u = prior_v = None
    if has_priors or prior_flags:
        prior_u = RowGaussians(eta=S(lead + (N, K), jnp.float32),
                               Lambda=S(lead + (N, K, K), jnp.float32))
        prior_v = RowGaussians(eta=S(lead + (D, K), jnp.float32),
                               Lambda=S(lead + (D, K, K), jnp.float32))
    u0, v0 = S(lead + (N, K), jnp.float32), S(lead + (D, K), jnp.float32)
    sc = S((), jnp.int32)
    use = S((n_blocks,), jnp.float32) if prior_flags else None
    cfg_key = cfg._replace(n_samples=0, burnin=0, phase_bc_samples=None)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        if stacked:
            fn = (GIBBS._run_gibbs_stacked_jit_donated if donate
                  else GIBBS._run_gibbs_stacked_jit)
            traced = fn.trace(S((n_blocks, 2), jnp.uint32), csr_r, csr_c,
                              tst, tst, cfg_key, D, N, sc, sc,
                              prior_u, prior_v, u0, v0, use, use, mesh=None)
        else:
            fn = (GIBBS._run_gibbs_jit_donated if donate
                  else GIBBS._run_gibbs_jit)
            traced = fn.trace(jax.eval_shape(lambda: jax.random.key(0)),
                              csr_r, csr_c, tst, tst, cfg_key, D, N,
                              sc, sc, prior_u, prior_v, u0, v0)
        ma = traced.lower().compile().memory_analysis()
    eff = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
           + ma.output_size_in_bytes - ma.alias_size_in_bytes)
    return {"argument_mb": ma.argument_size_in_bytes / 2**20,
            "temp_mb": ma.temp_size_in_bytes / 2**20,
            "output_mb": ma.output_size_in_bytes / 2**20,
            "alias_mb": ma.alias_size_in_bytes / 2**20,
            "effective_peak_mb": eff / 2**20}


def run_gibbs_peak(datasets, samples: int = 10, blocks: int = 4,
                   window: int = 2, json_out=None):
    """Peak live-buffer bytes of a PP run: stacked/async/streaming ×
    donate off/on. The streaming executor's peak is bounded by its window
    (W blocks in flight + W prefetched), flat in the grid size — the
    number that lets oversized grids run at all."""
    import jax

    from repro.core import bmf as BMF
    from repro.core import engine as ENG
    from repro.core import pp as PP
    from repro.core.partition import partition, suggest_grid
    from repro.data import synthetic as SYN
    from repro.data.sparse import apply_permutation, train_test_split

    from benchmarks.common import gibbs_live_peak

    rows = []
    for d in datasets:
        coo, p = SYN.generate(d, seed=51)
        train, test = train_test_split(coo, 0.1, seed=52)
        K = min(p.K, 16)
        cfg = BMF.BMFConfig(K=K, n_samples=samples, burnin=samples // 3)
        I, J = suggest_grid(train.n_rows, train.n_cols, blocks)
        part = partition(train, I, J)

        # XLA buffer assignment for the busiest bucket's chain executable
        test_p = apply_permutation(test, part.row_perm, part.col_perm)
        buckets = PP.BlockShapes.per_phase(part, test_p)
        tag = "c" if "c" in buckets else max(
            buckets, key=lambda t: sum(1 for b in part.all_blocks()
                                       if b.phase == t))
        n_tag = sum(1 for b in part.all_blocks() if b.phase == tag)
        # streaming_window lowers the flagged prior_use variant — the
        # executable StreamingExecutor actually dispatches per chunk
        for kind, stacked, nb, flags in (
                ("stacked_bucket", True, n_tag, False),
                ("streaming_window", True, window, True),
                ("async_block", False, n_tag, False)):
            for donate in (False, True):
                ma = _xla_chain_peak(buckets[tag], nb, cfg,
                                     stacked=stacked, donate=donate,
                                     has_priors=(tag != "a"),
                                     prior_flags=flags)
                rec = {"dataset": d, "kind": kind, "bucket": tag,
                       "n_blocks": nb, "donate": donate, **ma}
                rows.append(rec)
                emit(f"gibbs_xla_peak/{d}/{kind}/donate={int(donate)}",
                     0.0,
                     f"effective_peak_mb={ma['effective_peak_mb']:.2f};"
                     f"alias_mb={ma['alias_mb']:.2f};"
                     f"temp_mb={ma['temp_mb']:.2f}")
                print(f"  {d} {kind:14s} donate={int(donate)} "
                      f"xla effective peak={ma['effective_peak_mb']:.2f}MB "
                      f"(alias {ma['alias_mb']:.2f}MB)")

        # the one-kernel sweep lowered into the SAME per-block chain the
        # async executor dispatches: the (B, K, K)/(B, K) sufficient-stats
        # round-trip disappears from XLA's temp assignment (the Λ/η
        # accumulators live only inside the striped map body / VMEM)
        for dt in ("fp32", "bf16"):
            cfg_f = cfg._replace(sweep_fused=True, sweep_dtype=dt)
            for donate in (False, True):
                ma = _xla_chain_peak(buckets[tag], n_tag, cfg_f,
                                     stacked=False, donate=donate,
                                     has_priors=(tag != "a"))
                kind = f"fused_sweep_{dt}"
                rec = {"dataset": d, "kind": kind, "bucket": tag,
                       "n_blocks": n_tag, "donate": donate,
                       "sweep_dtype": dt, **ma}
                rows.append(rec)
                emit(f"gibbs_xla_peak/{d}/{kind}/donate={int(donate)}",
                     0.0,
                     f"effective_peak_mb={ma['effective_peak_mb']:.2f};"
                     f"alias_mb={ma['alias_mb']:.2f};"
                     f"temp_mb={ma['temp_mb']:.2f}")
                print(f"  {d} {kind:14s} donate={int(donate)} "
                      f"xla effective peak={ma['effective_peak_mb']:.2f}MB "
                      f"(temp {ma['temp_mb']:.2f}MB)")

        for ex_name, make in (
                ("stacked", ENG.StackedExecutor),
                ("async", ENG.AsyncExecutor),
                ("streaming",
                 lambda donate: ENG.StreamingExecutor(window=window,
                                                      donate=donate))):
            for donate in (False, True):
                with gibbs_live_peak() as peak:
                    res = PP.run_pp(jax.random.key(7), part, cfg, test,
                                    executor=make(donate=donate))
                    jax.block_until_ready((res.U_agg, res.V_agg))
                rec = {"dataset": d, "executor": ex_name, "donate": donate,
                       "rmse": res.rmse,
                       "baseline_mb": peak["baseline"] / 2**20,
                       "peak_live_mb": peak["peak"] / 2**20,
                       "delta_mb": (peak["peak"] - peak["baseline"]) / 2**20}
                if ex_name == "streaming":
                    rec["window"] = window
                del res
                rows.append(rec)
                emit(f"gibbs_peak/{d}/{ex_name}/donate={int(donate)}",
                     0.0,
                     f"peak_live_mb={rec['peak_live_mb']:.1f};"
                     f"delta_mb={rec['delta_mb']:.1f};"
                     f"rmse={rec['rmse']:.4f}")
                print(f"  {d} {ex_name:8s} donate={int(donate)} "
                      f"peak_live={rec['peak_live_mb']:.1f}MB "
                      f"(+{rec['delta_mb']:.1f}MB over baseline)")
    if json_out:
        Path(json_out).write_text(json.dumps(
            {"benchmark": "gibbs_peak", "samples": samples,
             "blocks": blocks, "window": window, "records": rows},
            indent=2))
        print("->", json_out)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--path", default=str(DEFAULT))
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--bmf", action="store_true",
                    help="roofline the BMF sufficient-stats hot path")
    ap.add_argument("--gibbs-peak", action="store_true",
                    help="peak live-buffer bytes of a PP run, "
                         "stacked/async x donation off/on")
    ap.add_argument("--samples", type=int, default=10)
    ap.add_argument("--blocks", type=int, default=4)
    ap.add_argument("--window", type=int, default=2,
                    help="streaming executor window for --gibbs-peak")
    ap.add_argument("--json-out", default=None)
    ap.add_argument("--datasets", nargs="+", default=["movielens"])
    ap.add_argument("--use-kernel", choices=["on", "off", "both"],
                    default="both")
    args = ap.parse_args()
    if args.gibbs_peak:
        run_gibbs_peak(args.datasets, samples=args.samples,
                       blocks=args.blocks, window=args.window,
                       json_out=args.json_out)
        return
    if args.bmf:
        run_bmf(args.datasets, args.use_kernel)
        return
    if not Path(args.path).exists():
        print("# no dryrun_results.json - run python -m repro.launch.dryrun --all first")
        return
    run(args.path, args.mesh)


if __name__ == "__main__":
    main()
