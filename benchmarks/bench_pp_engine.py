"""Phase-graph engine benchmark: serial vs stacked (vs sharded vs async)
execution of the SAME Posterior Propagation run.

The serial executor is the paper-reference loop — one jitted Gibbs call and
one host sync per block. The stacked executor runs each phase shape bucket
as ONE vmapped call behind a hard phase barrier; with >1 local device, the
sharded executor spreads the bucket batch over a 'block' mesh. The async
executor replaces the barrier with dependency counters: each block
dispatches the moment its propagated priors resolve, phase b and c overlap,
input buffers are donated, and only tiny per-block scalars ever cross to
the host. Chains are identical across executors (same keys, same padding),
so RMSE parity is asserted here and the numbers isolate pure orchestration
cost.

``--skew S`` (S > 1) replaces the preset's balanced partition with an
occupancy-SKEWED synthetic grid: expected block density falls off as
S^-(i+j), and the partition keeps identity permutations (balance="none") so
the skew survives. This is the worst case for barrier executors — every
bucket is padded to its densest block and phase c waits on the slowest
phase-b straggler — and the case the async executor is built for.

Each executor gets one warmup run (compile) and ``--repeats`` timed runs;
reported phase times are the per-phase minima over repeats. With
``--json-out`` the run record is APPENDED to the file's "runs" list (one
file accumulates the plain + skewed grids).

  PYTHONPATH=src:. python benchmarks/bench_pp_engine.py \
      --dataset movielens --blocks 8 --samples 20 \
      --executors serial stacked async --skew 4 \
      --json-out BENCH_pp_engine.json
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import jax
import numpy as np

from repro.core import bmf as BMF
from repro.core import pp as PP
from repro.core.partition import partition, suggest_grid
from repro.data import synthetic as SYN
from repro.data.sparse import COO, train_test_split

from benchmarks.common import emit


def make_skewed(p: SYN.DatasetPreset, I: int, J: int, skew: float,
                seed: int) -> COO:
    """Occupancy-skewed grid: row stripe i draws nnz mass ∝ skew^-i (same
    for col stripes), uniform within a stripe, so block (i,j) has expected
    density ∝ skew^-(i+j) — block (0,0) is the dense corner, the far
    interior is nearly empty. Values are low-rank + noise like the preset
    generator (same scale clipping)."""
    rng = np.random.default_rng(seed)
    nnz = int(p.n_rows * p.ratings_per_row)
    row_splits = np.linspace(0, p.n_rows, I + 1).astype(np.int64)
    col_splits = np.linspace(0, p.n_cols, J + 1).astype(np.int64)

    def stripe_draw(splits, n_strata, total):
        w = skew ** -np.arange(n_strata, dtype=np.float64)
        w /= w.sum()
        stripe = rng.choice(n_strata, size=total, p=w)
        lo, hi = splits[stripe], splits[stripe + 1]
        return (lo + rng.random(total) * (hi - lo)).astype(np.int32)

    rows = stripe_draw(row_splits, I, int(nnz * 1.6))
    cols = stripe_draw(col_splits, J, int(nnz * 1.6))
    key = rows.astype(np.int64) * p.n_cols + cols
    _, uniq = np.unique(key, return_index=True)
    uniq = uniq[:nnz]
    rows, cols = rows[uniq], cols[uniq]

    r = p.true_rank
    scale_mid = 0.5 * (p.scale_lo + p.scale_hi)
    spread = 0.5 * (p.scale_hi - p.scale_lo)
    U = rng.normal(0, 1, (p.n_rows, r))
    V = rng.normal(0, 1, (p.n_cols, r))
    raw = np.einsum("ek,ek->e", U[rows], V[cols]) / np.sqrt(r)
    vals = scale_mid + spread * 0.5 * raw + 0.35 * spread * rng.normal(
        size=len(rows))
    vals = np.clip(vals, p.scale_lo, p.scale_hi).astype(np.float32)
    return COO(row=rows, col=cols, val=vals,
               n_rows=p.n_rows, n_cols=p.n_cols)


def run_one(executor: str, key, part, cfg, test, repeats: int):
    runs = []
    for _ in range(1 + repeats):           # first run compiles; dropped
        runs.append(PP.run_pp(key, part, cfg, test, executor=executor))
    timed = runs[1:]
    phases = {ph: min(r.phase_times_s[ph] for r in timed)
              for ph in timed[0].phase_times_s}
    rec = {
        "executor": executor,
        "rmse": timed[0].rmse,
        "wall_s": min(r.wall_time_s for r in timed),
        "phase_s": phases,
        "phase_bc_s": phases.get("b", 0.0) + phases.get("c", 0.0),
    }
    if timed[0].block_spans_s:
        best = min(timed, key=lambda r: r.wall_time_s)
        rec["critical_path_s"] = best.critical_path_s()
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="movielens",
                    choices=list(SYN.PRESETS))
    ap.add_argument("--blocks", type=int, default=8)
    ap.add_argument("--samples", type=int, default=20)
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--skew", type=float, default=0.0,
                    help=">1: occupancy-skewed grid (block density "
                         "∝ skew^-(i+j), identity permutations)")
    ap.add_argument("--executors", nargs="+",
                    default=["serial", "stacked"],
                    choices=["serial", "stacked", "sharded", "async"])
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()

    p = SYN.PRESETS[args.dataset]
    K = min(p.K, 16)
    cfg = BMF.BMFConfig(K=K, n_samples=args.samples,
                        burnin=args.samples // 3)
    if args.skew and args.skew > 1:
        I, J = suggest_grid(p.n_rows, p.n_cols, args.blocks)
        coo = make_skewed(p, I, J, args.skew, seed=51)
        train, test = train_test_split(coo, 0.1, seed=52)
        part = partition(train, I, J, balance="none")
        grid_kind = f"skew{args.skew:g}"
    else:
        coo, p = SYN.generate(args.dataset, seed=51)
        train, test = train_test_split(coo, 0.1, seed=52)
        I, J = suggest_grid(train.n_rows, train.n_cols, args.blocks)
        part = partition(train, I, J)
        grid_kind = "balanced"
    nnz_blocks = np.array([[b.coo.nnz for b in row] for row in part.blocks])
    print(f"dataset={args.dataset} grid={I}x{J} K={K} kind={grid_kind} "
          f"samples={args.samples} devices={len(jax.devices())}")
    print(f"block nnz: max={nnz_blocks.max()} min={nnz_blocks.min()} "
          f"imbalance={nnz_blocks.max() / max(nnz_blocks.mean(), 1):.2f}x")

    key = jax.random.key(7)
    recs = []
    for ex in args.executors:
        rec = run_one(ex, key, part, cfg, test, args.repeats)
        recs.append(rec)
        emit(f"pp_engine/{args.dataset}/{grid_kind}/{ex}", rec["wall_s"],
             f"rmse={rec['rmse']:.4f};phase_bc_s={rec['phase_bc_s']:.3f}")
        print(f"  {ex:8s} wall={rec['wall_s']:.2f}s "
              f"phases={ {k: round(v, 3) for k, v in rec['phase_s'].items()} } "
              f"rmse={rec['rmse']:.4f}")

    # executors must be RMSE-identical under a fixed key
    for rec in recs[1:]:
        np.testing.assert_allclose(rec["rmse"], recs[0]["rmse"], atol=1e-4)
    base = next((r for r in recs if r["executor"] == "serial"), None)
    for rec in recs:
        if base is None or rec is base:
            continue
        rec["speedup_vs_serial"] = base["wall_s"] / rec["wall_s"]
        rec["phase_bc_speedup_vs_serial"] = (base["phase_bc_s"]
                                             / rec["phase_bc_s"])
        print(f"  {rec['executor']} vs serial: wall x{rec['speedup_vs_serial']:.2f}, "
              f"phases b+c x{rec['phase_bc_speedup_vs_serial']:.2f}")
    stk = next((r for r in recs if r["executor"] == "stacked"), None)
    asy = next((r for r in recs if r["executor"] == "async"), None)
    if stk and asy:
        asy["speedup_vs_stacked"] = stk["wall_s"] / asy["wall_s"]
        print(f"  async vs stacked: wall x{asy['speedup_vs_stacked']:.2f} "
              f"(barrier stalls removed)")

    if args.json_out:
        run_rec = {"backend": jax.default_backend(),
                   "n_devices": len(jax.devices()),
                   "dataset": args.dataset, "grid": [I, J], "K": K,
                   "grid_kind": grid_kind, "skew": args.skew or None,
                   "nnz_imbalance":
                       float(nnz_blocks.max() / max(nnz_blocks.mean(), 1)),
                   "samples": args.samples, "records": recs}
        out = Path(args.json_out)
        doc = {"benchmark": "pp_engine", "runs": []}
        if out.exists():
            prev = json.loads(out.read_text())
            # migrate the PR-2 single-run layout into the runs list
            runs = prev.get("runs",
                            [prev] if prev.get("records") else [])
            doc["runs"] = [{k: v for k, v in r.items() if k != "benchmark"}
                           for r in runs]
        doc["runs"] = [r for r in doc["runs"]
                       if not (r.get("dataset") == args.dataset
                               and r.get("grid_kind",
                                         "balanced") == grid_kind)]
        doc["runs"].append(run_rec)
        out.write_text(json.dumps(doc, indent=2))
        print("->", out)


if __name__ == "__main__":
    main()
