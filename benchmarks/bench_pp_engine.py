"""Phase-graph engine benchmark: serial vs stacked (vs sharded) execution
of the SAME Posterior Propagation run.

The serial executor is the paper-reference loop — one jitted Gibbs call and
one host sync per block. The stacked executor runs each phase shape bucket
as ONE vmapped call; with >1 local device, the sharded executor spreads the
bucket batch over a 'block' mesh. Chains are identical across executors
(same keys, same padding), so RMSE parity is asserted here and the numbers
isolate pure orchestration cost.

Each executor gets one warmup run (compile) and ``--repeats`` timed runs;
reported phase times are the per-phase minima over repeats.

  PYTHONPATH=src:. python benchmarks/bench_pp_engine.py \
      --dataset movielens --blocks 8 --samples 20 \
      --json-out BENCH_pp_engine.json
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.core import bmf as BMF
from repro.core import pp as PP
from repro.core.partition import partition, suggest_grid
from repro.data import synthetic as SYN
from repro.data.sparse import train_test_split

from benchmarks.common import emit


def run_one(executor: str, key, part, cfg, test, repeats: int):
    runs = []
    for _ in range(1 + repeats):           # first run compiles; dropped
        runs.append(PP.run_pp(key, part, cfg, test, executor=executor))
    timed = runs[1:]
    phases = {ph: min(r.phase_times_s[ph] for r in timed)
              for ph in timed[0].phase_times_s}
    return {
        "executor": executor,
        "rmse": timed[0].rmse,
        "wall_s": min(r.wall_time_s for r in timed),
        "phase_s": phases,
        "phase_bc_s": phases.get("b", 0.0) + phases.get("c", 0.0),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="movielens",
                    choices=list(SYN.PRESETS))
    ap.add_argument("--blocks", type=int, default=8)
    ap.add_argument("--samples", type=int, default=20)
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--executors", nargs="+",
                    default=["serial", "stacked"],
                    choices=["serial", "stacked", "sharded"])
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()

    coo, p = SYN.generate(args.dataset, seed=51)
    train, test = train_test_split(coo, 0.1, seed=52)
    K = min(p.K, 16)
    cfg = BMF.BMFConfig(K=K, n_samples=args.samples,
                        burnin=args.samples // 3)
    I, J = suggest_grid(train.n_rows, train.n_cols, args.blocks)
    part = partition(train, I, J)
    print(f"dataset={args.dataset} grid={I}x{J} K={K} "
          f"samples={args.samples} devices={len(jax.devices())}")

    key = jax.random.key(7)
    recs = []
    for ex in args.executors:
        rec = run_one(ex, key, part, cfg, test, args.repeats)
        recs.append(rec)
        emit(f"pp_engine/{args.dataset}/{ex}", rec["wall_s"],
             f"rmse={rec['rmse']:.4f};phase_bc_s={rec['phase_bc_s']:.3f}")
        print(f"  {ex:8s} wall={rec['wall_s']:.2f}s "
              f"phases={ {k: round(v, 3) for k, v in rec['phase_s'].items()} } "
              f"rmse={rec['rmse']:.4f}")

    # executors must be RMSE-identical under a fixed key
    for rec in recs[1:]:
        np.testing.assert_allclose(rec["rmse"], recs[0]["rmse"], atol=1e-4)
    base = next((r for r in recs if r["executor"] == "serial"), None)
    for rec in recs:
        if base is None or rec is base:
            continue
        rec["speedup_vs_serial"] = base["wall_s"] / rec["wall_s"]
        rec["phase_bc_speedup_vs_serial"] = (base["phase_bc_s"]
                                             / rec["phase_bc_s"])
        print(f"  {rec['executor']} vs serial: wall x{rec['speedup_vs_serial']:.2f}, "
              f"phases b+c x{rec['phase_bc_speedup_vs_serial']:.2f}")

    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump({"benchmark": "pp_engine",
                       "backend": jax.default_backend(),
                       "n_devices": len(jax.devices()),
                       "dataset": args.dataset, "grid": [I, J], "K": K,
                       "samples": args.samples, "records": recs}, f, indent=2)
        print("->", args.json_out)


if __name__ == "__main__":
    main()
