"""Phase-graph engine benchmark: serial vs stacked (vs sharded vs async)
execution of the SAME Posterior Propagation run.

The serial executor is the paper-reference loop — one jitted Gibbs call and
one host sync per block. The stacked executor runs each phase shape bucket
as ONE vmapped call behind a hard phase barrier; with >1 local device, the
sharded executor spreads the bucket batch over a 'block' mesh. The async
executor replaces the barrier with dependency counters: each block
dispatches the moment its propagated priors resolve, phase b and c overlap,
input buffers are donated, and only tiny per-block scalars ever cross to
the host. Chains are identical across executors (same keys, same padding),
so RMSE parity is asserted here and the numbers isolate pure orchestration
cost.

``--skew S`` (S > 1) replaces the preset's balanced partition with an
occupancy-SKEWED synthetic grid: expected block density falls off as
S^-(i+j), and the partition keeps identity permutations (balance="none") so
the skew survives. This is the worst case for barrier executors — every
bucket is padded to its densest block and phase c waits on the slowest
phase-b straggler — and the case the async executor is built for.

``--topology B D`` places the sharded/async/streaming executors on the
unified 2-D ('block','data') mesh (core.topology): B device groups run
blocks concurrently, each block's chain sharded over D devices — the
paper's combined system. Records gain a ``topology`` field (part of the
run identity) and streaming records a ``window_streams`` count
(one W-window per group).

``--grid I J`` pins the grid explicitly; combined with ``--oversized`` it
builds the streaming executor's target case: a grid (e.g. 32×8) whose
stacked phase buckets exceed ``--mem-cap-mb`` of device memory. Executors
whose estimated footprint breaks the cap are SKIPPED with a printed
reason; the streaming executor's live peak stays bounded by
``--window × (depth+1)`` blocks and is measured (``peak_live_mb``,
benchmarks.common.gibbs_live_peak) and recorded.

``--faults off|nan|hang`` exercises the fault-tolerant engine under load:
'off' (default) measures the chain-health guard's zero-fault overhead
(the guard rides every run now — compare wall_s against the pre-guard
records), 'nan' poisons one block's chain so the guard trips and one
retry heals it, 'hang' suppresses one dispatch's completion so the
async/streaming watchdog re-dispatches it. Records gain
``n_fault_events``/``n_retries``; the ``faults`` mode is part of the run
identity (old fault-free rows are replaced by ``--faults off`` reruns).

Each executor gets one warmup run (compile) and ``--repeats`` timed runs;
reported phase times are the per-phase minima over repeats. With
``--json-out`` the run record is merge-appended into the ``{runs: [...]}``
schema idempotently: re-running a config (same dataset/grid_kind/grid/K/
samples) REPLACES its record instead of duplicating it (``merge_runs``).

  PYTHONPATH=src:. python benchmarks/bench_pp_engine.py \
      --dataset movielens --blocks 8 --samples 20 \
      --executors serial stacked async --skew 4 \
      --json-out BENCH_pp_engine.json

  PYTHONPATH=src:. python benchmarks/bench_pp_engine.py \
      --dataset movielens --grid 32 8 --oversized --samples 10 \
      --executors serial streaming --window 4 --mem-cap-mb 64 \
      --json-out BENCH_pp_engine.json
"""
from __future__ import annotations

import argparse
import jax
import numpy as np

from repro.core import bmf as BMF
from repro.core import pp as PP
from repro.core.partition import partition, suggest_grid
from repro.data import synthetic as SYN
from repro.data.sparse import COO, apply_permutation, train_test_split

from benchmarks import common as COMMON
from benchmarks.common import emit, gibbs_live_peak

# a run record's config identity: re-running the same config replaces its
# record in the {runs: [...]} file instead of appending a duplicate
RUN_KEY = ("dataset", "grid_kind", "grid", "K", "samples", "topology",
           "faults")


def _run_key(rec: dict) -> tuple:
    vals = []
    for f in RUN_KEY:
        v = rec.get(f)
        if f == "faults":
            # records written before the fault-injection mode existed have
            # no "faults" field — normalize so --faults off REPLACES them
            v = v or "off"
        vals.append(tuple(v) if isinstance(v, list) else v)
    return tuple(vals)


def merge_runs(doc, run_rec: dict) -> dict:
    """This bench's binding of ``benchmarks.common.merge_runs`` (kept as a
    public name — tests and tooling import it from here)."""
    return COMMON.merge_runs(doc, run_rec, _run_key, "pp_engine")


def merge_json_out(path, run_rec: dict) -> dict:
    return COMMON.merge_json_out(path, run_rec, _run_key, "pp_engine")


def make_skewed(p: SYN.DatasetPreset, I: int, J: int, skew: float,
                seed: int) -> COO:
    """Occupancy-skewed grid: row stripe i draws nnz mass ∝ skew^-i (same
    for col stripes), uniform within a stripe, so block (i,j) has expected
    density ∝ skew^-(i+j) — block (0,0) is the dense corner, the far
    interior is nearly empty. Values are low-rank + noise like the preset
    generator (same scale clipping)."""
    rng = np.random.default_rng(seed)
    nnz = int(p.n_rows * p.ratings_per_row)
    row_splits = np.linspace(0, p.n_rows, I + 1).astype(np.int64)
    col_splits = np.linspace(0, p.n_cols, J + 1).astype(np.int64)

    def stripe_draw(splits, n_strata, total):
        w = skew ** -np.arange(n_strata, dtype=np.float64)
        w /= w.sum()
        stripe = rng.choice(n_strata, size=total, p=w)
        lo, hi = splits[stripe], splits[stripe + 1]
        return (lo + rng.random(total) * (hi - lo)).astype(np.int32)

    rows = stripe_draw(row_splits, I, int(nnz * 1.6))
    cols = stripe_draw(col_splits, J, int(nnz * 1.6))
    key = rows.astype(np.int64) * p.n_cols + cols
    _, uniq = np.unique(key, return_index=True)
    # shuffle BEFORE truncating: np.unique returns indices sorted by
    # row-major key, so uniq[:nnz] alone would keep only the smallest row
    # ids and cut the tail stripes off entirely instead of thinning them
    # by the documented S^-(i+j) profile
    uniq = rng.permutation(uniq)[:nnz]
    rows, cols = rows[uniq], cols[uniq]

    r = p.true_rank
    scale_mid = 0.5 * (p.scale_lo + p.scale_hi)
    spread = 0.5 * (p.scale_hi - p.scale_lo)
    U = rng.normal(0, 1, (p.n_rows, r))
    V = rng.normal(0, 1, (p.n_cols, r))
    raw = np.einsum("ek,ek->e", U[rows], V[cols]) / np.sqrt(r)
    vals = scale_mid + spread * 0.5 * raw + 0.35 * spread * rng.normal(
        size=len(rows))
    vals = np.clip(vals, p.scale_lo, p.scale_hi).astype(np.float32)
    return COO(row=rows, col=cols, val=vals,
               n_rows=p.n_rows, n_cols=p.n_cols)


def fault_setup(mode: str, part, topology=None):
    """(fault_plan, fault_policy) for one --faults mode. Deterministic by
    construction (engine.FaultPlan is a pure function of coord/attempt or
    group/ordinal), so faulted timings are reproducible run to run."""
    from repro.core import engine as ENG
    if mode == "off":
        return None, None
    c = (min(1, part.I - 1), min(1, part.J - 1))
    if mode == "nan":
        # one NaN-poisoned chain: health guard trips, one retry heals it
        return ENG.FaultPlan(nan_at={c: 1}), None
    if mode in ("group-dead", "group-slow"):
        # group-level injection targets the LAST device group; needs >= 2
        # groups to rebalance/speculate onto (inert otherwise — barrier
        # executors and 1-group runs report zero group events)
        G = topology.block if topology is not None else 1
        if G < 2:
            return None, None
        g = G - 1
        if mode == "group-dead":
            # the group's first dispatch stays healthy (compile +
            # calibration), then the group dies: after quarantine_after
            # consecutive expiries it is drained and its share rebalances
            return (ENG.FaultPlan(group_dead_at={g: 1}),
                    ENG.FaultPolicy(timeout_floor_s=8.0, timeout_slack=10.0,
                                    quarantine_after=2, max_retries=8))
        # the group lags 4x the watchdog floor: no expiry (generous
        # floor), but the per-group rate model flags the stragglers and
        # speculative twins on the healthy groups win resolution
        return (ENG.FaultPlan(group_slow_at={g: (1, 4.0)}),
                ENG.FaultPolicy(timeout_floor_s=60.0, timeout_slack=0.0,
                                speculate_at=2.0))
    # one hung dispatch: the watchdog re-dispatches after its deadline.
    # Only the async/streaming poll loops can hang — barrier executors
    # report zero fault events here, which the record makes visible.
    return (ENG.FaultPlan(hang_at={c: 1}),
            ENG.FaultPolicy(timeout_floor_s=2.0, timeout_slack=10.0))


def run_one(executor: str, key, part, cfg, test, repeats: int,
            window=None, measure_peak: bool = False, topology=None,
            faults: str = "off"):
    # the serial/stacked references are placement-free; topology composes
    # with the sharded/async/streaming executors
    topo = topology if executor in ("sharded", "async", "streaming") else None
    plan, policy = fault_setup(faults, part, topo)
    kw = dict(executor=executor, window=window, topology=topo,
              fault_plan=plan, fault_policy=policy)
    runs = []
    peak = None
    for i in range(1 + repeats):           # first run compiles; dropped
        if i == 0 and measure_peak:
            # live peak sampled on the (untimed) warmup run so the
            # per-dispatch live_arrays() walk never pollutes the timings
            with gibbs_live_peak() as pk:
                runs.append(PP.run_pp(key, part, cfg, test, **kw))
            peak = pk
        else:
            runs.append(PP.run_pp(key, part, cfg, test, **kw))
    timed = runs[1:]
    phases = {ph: min(r.phase_times_s[ph] for r in timed)
              for ph in timed[0].phase_times_s}
    rec = {
        "executor": executor,
        "rmse": timed[0].rmse,
        "wall_s": min(r.wall_time_s for r in timed),
        "phase_s": phases,
        "phase_bc_s": phases.get("b", 0.0) + phases.get("c", 0.0),
    }
    if faults != "off":
        rec["faults"] = faults
        rec["n_fault_events"] = len(timed[0].faults)
        rec["n_retries"] = timed[0].n_retries
        if faults.startswith("group"):
            # quarantine/steal/speculate/cancel counters from the elastic
            # scheduler (PPResult.group_stats)
            rec["group_stats"] = timed[0].group_stats
    if executor == "streaming":
        rec["window"] = window
        if topo is not None:
            # number of concurrent window STREAMS (one W-window per group)
            rec["window_streams"] = topo.block
    if topo is not None:
        rec["topology"] = [topo.block, topo.data]
    if peak is not None:
        rec["peak_live_mb"] = peak["peak"] / 2**20
        rec["baseline_live_mb"] = peak["baseline"] / 2**20
    if timed[0].block_spans_s:
        best = min(timed, key=lambda r: r.wall_time_s)
        rec["critical_path_s"] = best.critical_path_s()
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="movielens",
                    choices=list(SYN.PRESETS))
    ap.add_argument("--blocks", type=int, default=8)
    ap.add_argument("--samples", type=int, default=20)
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--skew", type=float, default=0.0,
                    help=">1: occupancy-skewed grid (block density "
                         "∝ skew^-(i+j), identity permutations)")
    ap.add_argument("--grid", type=int, nargs=2, default=None,
                    metavar=("I", "J"),
                    help="explicit block grid (overrides --blocks)")
    ap.add_argument("--oversized", action="store_true",
                    help="oversized-grid mode: label the run, measure "
                         "per-executor live peaks, honor --mem-cap-mb")
    ap.add_argument("--window", type=int, default=0,
                    help="streaming executor window W (0 = default)")
    ap.add_argument("--mem-cap-mb", type=float, default=0.0,
                    help="skip executors whose estimated live input "
                         "footprint exceeds this many MB (stacked/sharded "
                         "hold whole phase buckets; streaming is bounded "
                         "by its window)")
    ap.add_argument("--topology", type=int, nargs=2, default=None,
                    metavar=("BLOCK", "DATA"),
                    help="2-D ('block','data') placement for the sharded/"
                         "async/streaming executors: BLOCK device groups "
                         "x DATA devices per group (core.topology)")
    ap.add_argument("--executors", nargs="+",
                    default=["serial", "stacked"],
                    choices=["serial", "stacked", "sharded", "async",
                             "streaming"])
    ap.add_argument("--faults", default="off",
                    choices=["off", "nan", "hang", "group-dead",
                             "group-slow"],
                    help="deterministic fault injection: 'nan' poisons one "
                         "block's chain (health guard + retry), 'hang' "
                         "suppresses one dispatch's completion (watchdog "
                         "re-dispatch; async/streaming only), 'group-dead' "
                         "kills the last device group after its first "
                         "dispatch (quarantine + rebalance; needs "
                         "--topology B D with B >= 2), 'group-slow' lags "
                         "it 4x (speculative re-dispatch). 'off' runs "
                         "clean and measures the guard's zero-fault "
                         "overhead")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()

    p = SYN.PRESETS[args.dataset]
    K = min(p.K, 16)
    cfg = BMF.BMFConfig(K=K, n_samples=args.samples,
                        burnin=args.samples // 3)
    if args.grid:
        I, J = args.grid
    else:
        I, J = suggest_grid(p.n_rows, p.n_cols, args.blocks)
    if args.skew and args.skew > 1:
        coo = make_skewed(p, I, J, args.skew, seed=51)
        train, test = train_test_split(coo, 0.1, seed=52)
        part = partition(train, I, J, balance="none")
        grid_kind = f"skew{args.skew:g}"
    else:
        coo, p = SYN.generate(args.dataset, seed=51)
        train, test = train_test_split(coo, 0.1, seed=52)
        if not args.grid:
            I, J = suggest_grid(train.n_rows, train.n_cols, args.blocks)
        part = partition(train, I, J)
        grid_kind = "balanced"
    if args.oversized:
        grid_kind = f"oversized{I}x{J}-{grid_kind}"
    nnz_blocks = np.array([[b.coo.nnz for b in row] for row in part.blocks])
    print(f"dataset={args.dataset} grid={I}x{J} K={K} kind={grid_kind} "
          f"samples={args.samples} devices={len(jax.devices())}")
    print(f"block nnz: max={nnz_blocks.max()} min={nnz_blocks.min()} "
          f"imbalance={nnz_blocks.max() / max(nnz_blocks.mean(), 1):.2f}x")

    # estimated live INPUT footprints (pp.BlockShapes.block_bytes): the
    # stacked executor holds its largest phase bucket whole, the streaming
    # executor at most window x (depth+1) blocks of the largest bucket —
    # W and depth read from a probe instance so the estimate, the skip
    # decision, and the recorded config track the executor's defaults
    from repro.core import engine as ENG
    probe = ENG.make_executor("streaming", window=args.window or None)
    W = probe.window
    test_p = apply_permutation(test, part.row_perm, part.col_perm)
    buckets = PP.BlockShapes.per_phase(part, test_p)
    per_tag = {tag: sum(1 for b in part.all_blocks() if b.phase == tag)
               * s.block_bytes(K) for tag, s in buckets.items()}
    stacked_mb = max(per_tag.values()) / 2**20
    window_mb = W * (probe.depth + 1) * max(
        s.block_bytes(K) for s in buckets.values()) / 2**20
    print(f"est. live inputs: stacked bucket {stacked_mb:.1f}MB, "
          f"streaming window (W={W}) {window_mb:.1f}MB"
          + (f", cap {args.mem_cap_mb:.1f}MB" if args.mem_cap_mb else ""))

    topology = None
    if args.topology:
        from repro.core.topology import Topology
        topology = Topology(block=args.topology[0], data=args.topology[1])
        print(topology.describe())

    key = jax.random.key(7)
    recs, skipped = [], []
    for ex in args.executors:
        est_mb = {"stacked": stacked_mb, "sharded": stacked_mb,
                  "streaming": window_mb}.get(ex)
        if args.mem_cap_mb and est_mb is not None and est_mb > args.mem_cap_mb:
            print(f"  {ex:9s} SKIPPED: est. {est_mb:.1f}MB live inputs "
                  f"> cap {args.mem_cap_mb:.1f}MB")
            skipped.append({"executor": ex, "est_mb": est_mb,
                            "cap_mb": args.mem_cap_mb})
            continue
        rec = run_one(ex, key, part, cfg, test, args.repeats,
                      window=W, measure_peak=args.oversized,
                      topology=topology, faults=args.faults)
        recs.append(rec)
        emit(f"pp_engine/{args.dataset}/{grid_kind}/{ex}", rec["wall_s"],
             f"rmse={rec['rmse']:.4f};phase_bc_s={rec['phase_bc_s']:.3f}")
        print(f"  {ex:8s} wall={rec['wall_s']:.2f}s "
              f"phases={ {k: round(v, 3) for k, v in rec['phase_s'].items()} } "
              f"rmse={rec['rmse']:.4f}"
              + (f" peak_live={rec['peak_live_mb']:.1f}MB"
                 if "peak_live_mb" in rec else "")
              + (f" faults={rec['n_fault_events']} "
                 f"retries={rec['n_retries']}"
                 if "n_fault_events" in rec else ""))

    # executors must be RMSE-identical under a fixed key
    for rec in recs[1:]:
        np.testing.assert_allclose(rec["rmse"], recs[0]["rmse"], atol=1e-4)
    base = next((r for r in recs if r["executor"] == "serial"), None)
    for rec in recs:
        if base is None or rec is base:
            continue
        rec["speedup_vs_serial"] = base["wall_s"] / rec["wall_s"]
        rec["phase_bc_speedup_vs_serial"] = (base["phase_bc_s"]
                                             / rec["phase_bc_s"])
        print(f"  {rec['executor']} vs serial: wall x{rec['speedup_vs_serial']:.2f}, "
              f"phases b+c x{rec['phase_bc_speedup_vs_serial']:.2f}")
    stk = next((r for r in recs if r["executor"] == "stacked"), None)
    asy = next((r for r in recs if r["executor"] == "async"), None)
    if stk and asy:
        asy["speedup_vs_stacked"] = stk["wall_s"] / asy["wall_s"]
        print(f"  async vs stacked: wall x{asy['speedup_vs_stacked']:.2f} "
              f"(barrier stalls removed)")

    if args.json_out:
        run_rec = {"backend": jax.default_backend(),
                   "n_devices": len(jax.devices()),
                   "dataset": args.dataset, "grid": [I, J], "K": K,
                   "grid_kind": grid_kind, "skew": args.skew or None,
                   "nnz_imbalance":
                       float(nnz_blocks.max() / max(nnz_blocks.mean(), 1)),
                   "samples": args.samples,
                   "est_stacked_bucket_mb": stacked_mb,
                   "est_streaming_window_mb": window_mb,
                   "mem_cap_mb": args.mem_cap_mb or None,
                   "topology": (list(args.topology) if args.topology
                                else None),
                   "faults": args.faults,
                   "skipped": skipped, "records": recs}
        merge_json_out(args.json_out, run_rec)
        print("->", args.json_out)


if __name__ == "__main__":
    main()
