"""Shared helpers for the benchmark harness.

Output contract: every benchmark prints ``name,us_per_call,derived`` CSV
rows (one per paper-table cell) where ``derived`` carries the table's
metric (RMSE, speedup, bytes, ...).
"""
from __future__ import annotations

import time
from typing import Callable


def timed(fn: Callable, *args, repeats: int = 1):
    """Run fn, return (result, seconds). jax results are block_until_ready'd."""
    import jax
    t0 = time.time()
    out = None
    for _ in range(repeats):
        out = fn(*args)
        jax.block_until_ready(out) if hasattr(out, "block_until_ready") or \
            isinstance(out, (tuple, list, dict)) else None
    return out, (time.time() - t0) / repeats


def emit(name: str, seconds: float, derived):
    print(f"{name},{seconds * 1e6:.1f},{derived}")
