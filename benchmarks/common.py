"""Shared helpers for the benchmark harness.

Output contract: every benchmark prints ``name,us_per_call,derived`` CSV
rows (one per paper-table cell) where ``derived`` carries the table's
metric (RMSE, speedup, bytes, ...).
"""
from __future__ import annotations

import contextlib
import json
import time
from pathlib import Path
from typing import Callable


def merge_runs(doc, run_rec: dict, key_fn: Callable[[dict], tuple],
               benchmark: str) -> dict:
    """Idempotently merge one run record into the ``{runs: [...]}`` schema:
    an existing record with the same config key (``key_fn``) is REPLACED,
    any other record is kept, and the legacy single-run layout (top-level
    ``records``) migrates transparently. Pure function of (previous doc or
    None, new record) — each bench wraps it with its own key/benchmark
    name (``bench_pp_engine.merge_runs``, ``bench_serving.merge_runs``)
    and the wrappers are unit-tested over temp files in
    tests/test_bench_json.py."""
    runs = []
    if doc:
        runs = doc.get("runs", [doc] if doc.get("records") else [])
        runs = [{k: v for k, v in r.items() if k != "benchmark"}
                for r in runs]
    runs = [r for r in runs if key_fn(r) != key_fn(run_rec)]
    runs.append(run_rec)
    return {"benchmark": benchmark, "runs": runs}


def merge_json_out(path, run_rec: dict, key_fn: Callable[[dict], tuple],
                   benchmark: str) -> dict:
    out = Path(path)
    doc = json.loads(out.read_text()) if out.exists() else None
    merged = merge_runs(doc, run_rec, key_fn, benchmark)
    out.write_text(json.dumps(merged, indent=2))
    return merged


def timed(fn: Callable, *args, repeats: int = 1):
    """Run fn, return (result, seconds). jax results are block_until_ready'd."""
    import jax
    t0 = time.time()
    out = None
    for _ in range(repeats):
        out = fn(*args)
        jax.block_until_ready(out) if hasattr(out, "block_until_ready") or \
            isinstance(out, (tuple, list, dict)) else None
    return out, (time.time() - t0) / repeats


def emit(name: str, seconds: float, derived):
    print(f"{name},{seconds * 1e6:.1f},{derived}")


@contextlib.contextmanager
def gibbs_live_peak():
    """Sample the peak live device-buffer bytes at every
    ``run_gibbs``/``run_gibbs_stacked`` dispatch inside the block: yields a
    dict whose ``peak``/``baseline`` fields are filled in (bytes). Shared
    by bench_roofline --gibbs-peak and bench_pp_engine's oversized-grid
    mode so both report the same live-footprint metric."""
    import gc

    import jax

    from repro.core import gibbs as GIBBS

    def live_bytes():
        return sum(a.nbytes for a in jax.live_arrays()
                   if not a.is_deleted())

    rec = {"peak": 0, "baseline": 0}

    def sample():
        rec["peak"] = max(rec["peak"], live_bytes())

    orig_g, orig_s = GIBBS.run_gibbs, GIBBS.run_gibbs_stacked

    def g(*a, **k):
        r = orig_g(*a, **k)
        sample()        # post-dispatch: donated inputs already invalidated
        return r

    def s(*a, **k):
        r = orig_s(*a, **k)
        sample()
        return r

    GIBBS.run_gibbs, GIBBS.run_gibbs_stacked = g, s
    try:
        gc.collect()
        rec["baseline"] = live_bytes()
        yield rec
    finally:
        GIBBS.run_gibbs, GIBBS.run_gibbs_stacked = orig_g, orig_s
