"""Shared helpers for the benchmark harness.

Output contract: every benchmark prints ``name,us_per_call,derived`` CSV
rows (one per paper-table cell) where ``derived`` carries the table's
metric (RMSE, speedup, bytes, ...).
"""
from __future__ import annotations

import contextlib
import time
from typing import Callable


def timed(fn: Callable, *args, repeats: int = 1):
    """Run fn, return (result, seconds). jax results are block_until_ready'd."""
    import jax
    t0 = time.time()
    out = None
    for _ in range(repeats):
        out = fn(*args)
        jax.block_until_ready(out) if hasattr(out, "block_until_ready") or \
            isinstance(out, (tuple, list, dict)) else None
    return out, (time.time() - t0) / repeats


def emit(name: str, seconds: float, derived):
    print(f"{name},{seconds * 1e6:.1f},{derived}")


@contextlib.contextmanager
def gibbs_live_peak():
    """Sample the peak live device-buffer bytes at every
    ``run_gibbs``/``run_gibbs_stacked`` dispatch inside the block: yields a
    dict whose ``peak``/``baseline`` fields are filled in (bytes). Shared
    by bench_roofline --gibbs-peak and bench_pp_engine's oversized-grid
    mode so both report the same live-footprint metric."""
    import gc

    import jax

    from repro.core import gibbs as GIBBS

    def live_bytes():
        return sum(a.nbytes for a in jax.live_arrays()
                   if not a.is_deleted())

    rec = {"peak": 0, "baseline": 0}

    def sample():
        rec["peak"] = max(rec["peak"], live_bytes())

    orig_g, orig_s = GIBBS.run_gibbs, GIBBS.run_gibbs_stacked

    def g(*a, **k):
        r = orig_g(*a, **k)
        sample()        # post-dispatch: donated inputs already invalidated
        return r

    def s(*a, **k):
        r = orig_s(*a, **k)
        sample()
        return r

    GIBBS.run_gibbs, GIBBS.run_gibbs_stacked = g, s
    try:
        gc.collect()
        rec["baseline"] = live_bytes()
        yield rec
    finally:
        GIBBS.run_gibbs, GIBBS.run_gibbs_stacked = orig_g, orig_s
