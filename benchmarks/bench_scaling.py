"""Paper Figures 4-5: strong scaling of D-BMF+PP.

The container has one CPU core, so multi-node wall-clock cannot be
measured directly. Methodology (documented in EXPERIMENTS §Scaling):

  1. MEASURE the per-block Gibbs sweep time for each block of an I×J
     partition on this host (real compute, real XLA).
  2. MEASURE the within-block distributed-BMF communication volume
     analytically (core.distributed.sweep_comm_bytes — it is exact) and
     convert to seconds with the v5e ICI model (50 GB/s × 2 links).
  3. MODEL the PP schedule exactly as the paper describes: phase a is
     serial; phase b runs its I+J-2 blocks on min(nodes, I+J-2) groups;
     phase c its (I-1)(J-1) blocks on min(nodes, ...) groups; within a
     block, distributed BMF divides compute by the group size with the
     comm term added per sweep.

  T(nodes) = T_a(g) + ceil(n_b/G) · max_b T_b(g) + ceil(n_c/G) · max_c T_c(g)
  where G = node groups, g = nodes per group.

This reproduces the paper's qualitative findings: more blocks => more total
compute but more parallelism; node counts aligned with I+J / I·J show
step-downs; K=100-style compute-heavy blocks scale further than K=10.
"""
from __future__ import annotations

import argparse
import math
import time

import jax
import numpy as np

from repro.core import bmf as BMF
from repro.core import distributed as DIST
from repro.core import gibbs as GIBBS
from repro.core.partition import partition
from repro.data import synthetic as SYN
from repro.data.sparse import coo_to_padded_csr, train_test_split

from benchmarks.common import emit

ICI_BYTES_PER_S = 100e9     # 50 GB/s x 2 links


def _block_sweep_seconds(blk, cfg, n_probe=6):
    csr_r = coo_to_padded_csr(blk.coo)
    csr_c = coo_to_padded_csr(blk.coo.transpose())
    t = jax.random.key(0)
    dummy = np.zeros(1, np.int32)
    t0 = time.time()
    GIBBS.run_gibbs(t, csr_r, csr_c, dummy, dummy,
                    BMF.BMFConfig(K=cfg.K, n_samples=n_probe, burnin=0))
    per_sweep = (time.time() - t0) / n_probe
    return per_sweep, csr_r.n_cols


def model_strong_scaling(part, cfg, nodes_list, n_samples):
    """Returns {nodes: seconds} for the PP schedule model."""
    I, J = part.I, part.J
    # measure per-block sweep time (serial, this host)
    t_a, D_a = _block_sweep_seconds(part.block(0, 0), cfg)
    b_blocks = ([part.block(i, 0) for i in range(1, I)] +
                [part.block(0, j) for j in range(1, J)])
    c_blocks = [part.block(i, j) for i in range(1, I) for j in range(1, J)]
    t_b = [_block_sweep_seconds(b, cfg) for b in b_blocks[:2]]
    t_c = [_block_sweep_seconds(b, cfg) for b in c_blocks[:2]] if c_blocks else []
    # use max of sampled blocks as the critical path block
    tb_max = max((t for t, _ in t_b), default=0.0)
    tc_max = max((t for t, _ in t_c), default=0.0)
    Db = max((d for _, d in t_b), default=1)
    Dc = max((d for _, d in t_c), default=1)

    out = {}
    for nodes in nodes_list:
        def block_time(t_serial, D, g):
            """distributed BMF inside a block on g nodes."""
            comm = DIST.sweep_comm_bytes(D, cfg.K) / ICI_BYTES_PER_S
            return n_samples * (t_serial / g + comm)

        # phase a: all nodes on the single block
        T = block_time(t_a, D_a, nodes)
        # phase b: split nodes into G groups over n_b blocks
        n_b = len(b_blocks)
        if n_b:
            G = min(nodes, n_b)
            g = max(nodes // G, 1)
            T += math.ceil(n_b / G) * block_time(tb_max, Db, g)
        n_c = len(c_blocks)
        if n_c:
            G = min(nodes, n_c)
            g = max(nodes // G, 1)
            T += math.ceil(n_c / G) * block_time(tc_max, Dc, g)
        out[nodes] = T
    return out


def run(dataset: str, grids=((1, 1), (4, 4), (8, 8)),
        nodes=(1, 2, 4, 8, 16, 32, 64, 128, 256),
        n_samples: int = 30):
    coo, p = SYN.generate(dataset, seed=41)
    train, _ = train_test_split(coo, 0.1, seed=42)
    cfg = BMF.BMFConfig(K=min(p.K, 16), n_samples=n_samples,
                        burnin=n_samples // 3)
    for (I, J) in grids:
        part = partition(train, I, J)
        curve = model_strong_scaling(part, cfg, list(nodes), n_samples)
        t1 = curve[nodes[0]]
        for n, t in curve.items():
            emit(f"fig45_scaling/{dataset}/{I}x{J}/nodes={n}", t,
                 f"speedup={t1 / max(t, 1e-12):.2f}")
    return True


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="movielens")
    args = ap.parse_args()
    run(args.dataset)


if __name__ == "__main__":
    main()
