"""Paper Table 1 (bottom rows): rows/sec and ratings/sec of the Gibbs
sampler per dataset — measured on this host, derived = both metrics.

``--use-kernel both`` (default) runs the XLA-gather baseline AND the
zero-materialization fused path (Pallas on TPU, N-striped symmetric
matmul elsewhere) back to back so the two hot paths are directly comparable in
one run; ``--json-out`` additionally writes the records as JSON (the CI
smoke check uploads them as the BENCH_throughput.json artifact)."""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.core import bmf as BMF
from repro.core import gibbs as GIBBS
from repro.data import synthetic as SYN
from repro.data.sparse import coo_to_padded_csr, train_test_split

from benchmarks.common import emit

# --use-kernel flag value -> list of use_kernel settings to run (shared
# with bench_roofline so the two benchmarks can't drift)
KERNEL_PATHS = {"on": [True], "off": [False], "both": [False, True]}


def path_name(use_kernel: bool) -> str:
    """Label records by the implementation actually measured: off TPU,
    use_kernel=True dispatches to the N-striped XLA fallback, not the
    Pallas kernel."""
    if not use_kernel:
        return "xla_gather"
    return "fused_pallas" if jax.default_backend() == "tpu" else "striped_xla"


def run(dataset: str, n_probe: int = 8, use_kernel: bool = False):
    coo, p = SYN.generate(dataset, seed=51)
    train, _ = train_test_split(coo, 0.1, seed=52)
    csr_r = coo_to_padded_csr(train)
    csr_c = coo_to_padded_csr(train.transpose())
    K = min(p.K, 16)
    cfg = BMF.BMFConfig(K=K, n_samples=n_probe, burnin=0,
                        use_kernel=use_kernel)
    dummy = np.zeros(1, np.int32)
    # warmup + compile (synced so no warmup tail leaks into the timed region)
    jax.block_until_ready(
        GIBBS.run_gibbs(jax.random.key(0), csr_r, csr_c, dummy, dummy,
                        BMF.BMFConfig(K=K, n_samples=1, burnin=0,
                                      use_kernel=use_kernel)))
    t0 = time.time()
    jax.block_until_ready(
        GIBBS.run_gibbs(jax.random.key(0), csr_r, csr_c, dummy, dummy, cfg).U)
    dt = (time.time() - t0) / n_probe
    rows_per_s = (train.n_rows + train.n_cols) / dt
    ratings_per_s = 2 * train.nnz / dt   # each rating visited in both factors
    path = path_name(use_kernel)
    emit(f"table1_throughput/{dataset}/{path}", dt,
         f"rows_per_s={rows_per_s:.0f};ratings_per_s={ratings_per_s:.0f};K={K}")
    return {"dataset": dataset, "path": path, "use_kernel": use_kernel,
            "sec_per_sweep": dt, "rows_per_s": rows_per_s,
            "ratings_per_s": ratings_per_s, "K": K, "nnz": train.nnz,
            "n_rows": train.n_rows, "n_cols": train.n_cols,
            "max_nnz_row": csr_r.max_nnz, "backend": jax.default_backend()}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--datasets", nargs="+", default=["movielens", "amazon"])
    ap.add_argument("--use-kernel", choices=["on", "off", "both"],
                    default="both",
                    help="fused zero-materialization path, XLA-gather "
                         "baseline, or both for a side-by-side")
    ap.add_argument("--n-probe", type=int, default=8)
    ap.add_argument("--json-out", default=None,
                    help="also write records to this JSON file")
    args = ap.parse_args()
    recs = []
    for d in args.datasets:
        for uk in KERNEL_PATHS[args.use_kernel]:
            recs.append(run(d, n_probe=args.n_probe, use_kernel=uk))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump({"benchmark": "table1_throughput",
                       "backend": jax.default_backend(),
                       "records": recs}, f, indent=2)


if __name__ == "__main__":
    main()
