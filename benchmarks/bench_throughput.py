"""Paper Table 1 (bottom rows): rows/sec and ratings/sec of the Gibbs
sampler per dataset — measured on this host, derived = both metrics.

``--use-kernel both`` (default) runs the XLA-gather baseline AND the
zero-materialization fused path (Pallas on TPU, N-striped symmetric
matmul elsewhere) back to back so the two hot paths are directly comparable in
one run; ``--json-out`` additionally writes the records as JSON (the CI
smoke check uploads them as the BENCH_throughput.json artifact).

``--distributed`` additionally measures the shard_map'd within-block sweep
(core.distributed.run_gibbs_distributed) in its paper-faithful psum and
beyond-paper scatter-V variants, crossed with the kernel paths — the
scatter-V × fused-kernel interaction the ROADMAP flagged unbenchmarked.
Fakes a 4-device CPU mesh via XLA_FLAGS when no multi-device platform is
present (must happen before the first jax backend touch)."""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# --distributed wants >1 device; the flag only takes effect before the
# backend initializes, hence the pre-import peek at argv
if "--distributed" in sys.argv and "xla_force_host_platform_device_count" \
        not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=4")

import jax
import numpy as np

from repro.core import bmf as BMF
from repro.core import gibbs as GIBBS
from repro.data import synthetic as SYN
from repro.data.sparse import coo_to_padded_csr, train_test_split

from benchmarks.common import emit

# --use-kernel flag value -> list of use_kernel settings to run (shared
# with bench_roofline so the two benchmarks can't drift)
KERNEL_PATHS = {"on": [True], "off": [False], "both": [False, True]}


def path_name(use_kernel: bool) -> str:
    """Label records by the implementation actually measured: off TPU,
    use_kernel=True dispatches to the N-striped XLA fallback, not the
    Pallas kernel."""
    if not use_kernel:
        return "xla_gather"
    return "fused_pallas" if jax.default_backend() == "tpu" else "striped_xla"


def run(dataset: str, n_probe: int = 8, use_kernel: bool = False,
        sweep_fused: bool = False, sweep_dtype: str = "fp32"):
    coo, p = SYN.generate(dataset, seed=51)
    train, _ = train_test_split(coo, 0.1, seed=52)
    csr_r = coo_to_padded_csr(train)
    csr_c = coo_to_padded_csr(train.transpose())
    K = min(p.K, 16)
    cfg = BMF.BMFConfig(K=K, n_samples=n_probe, burnin=0,
                        use_kernel=use_kernel, sweep_fused=sweep_fused,
                        sweep_dtype=sweep_dtype)
    dummy = np.zeros(1, np.int32)
    # warmup + compile (synced so no warmup tail leaks into the timed region)
    jax.block_until_ready(
        GIBBS.run_gibbs(jax.random.key(0), csr_r, csr_c, dummy, dummy,
                        cfg._replace(n_samples=1)))
    t0 = time.time()
    jax.block_until_ready(
        GIBBS.run_gibbs(jax.random.key(0), csr_r, csr_c, dummy, dummy, cfg).U)
    dt = (time.time() - t0) / n_probe
    rows_per_s = (train.n_rows + train.n_cols) / dt
    ratings_per_s = 2 * train.nnz / dt   # each rating visited in both factors
    path = "fused_sweep" if sweep_fused else path_name(use_kernel)
    tag = f"{path}/{sweep_dtype}" if sweep_fused else path
    emit(f"table1_throughput/{dataset}/{tag}", dt,
         f"rows_per_s={rows_per_s:.0f};ratings_per_s={ratings_per_s:.0f};K={K}")
    rec = {"dataset": dataset, "path": path, "use_kernel": use_kernel,
           "sec_per_sweep": dt, "rows_per_s": rows_per_s,
           "ratings_per_s": ratings_per_s, "K": K, "nnz": train.nnz,
           "n_rows": train.n_rows, "n_cols": train.n_cols,
           "max_nnz_row": csr_r.max_nnz, "backend": jax.default_backend()}
    if sweep_fused:
        rec["sweep_dtype"] = sweep_dtype
    return rec


def run_distributed(dataset: str, n_probe: int, use_kernel: bool,
                    scatter_v: bool):
    """Within-block shard_map sweep throughput: scatter-V × kernel paths."""
    from repro.core import distributed as DIST
    coo, p = SYN.generate(dataset, seed=51)
    train, _ = train_test_split(coo, 0.1, seed=52)
    csr_r = coo_to_padded_csr(train)
    csr_c = coo_to_padded_csr(train.transpose())
    K = min(p.K, 16)
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("data",))
    dummy = np.zeros(1, np.int32)

    def chain_secs(n):
        cfg = BMF.BMFConfig(K=K, n_samples=n, burnin=0,
                            use_kernel=use_kernel)
        t0 = time.time()
        jax.block_until_ready(DIST.run_gibbs_distributed(
            jax.random.key(0), csr_r, csr_c, dummy, dummy, cfg, mesh,
            scatter_v=scatter_v).U)
        return time.time() - t0

    # run_gibbs_distributed re-jits its shard_map sweep and redoes the
    # host-side shard-CSR prep on EVERY call (no cross-call cache), so a
    # warmup call can't amortize compile. Instead both a 1-sweep and an
    # (n_probe+1)-sweep call pay the identical trace+compile+prep cost and
    # the difference isolates n_probe steady-state sweeps.
    chain_secs(1)                                  # backend/alloc warmup
    t_one = chain_secs(1)
    t_many = chain_secs(n_probe + 1)
    dt = max(t_many - t_one, 1e-9) / n_probe
    variant = "dist_scatter_v" if scatter_v else "dist_psum"
    path = f"{variant}/{path_name(use_kernel)}"
    ratings_per_s = 2 * train.nnz / dt
    emit(f"table1_throughput/{dataset}/{path}", dt,
         f"ratings_per_s={ratings_per_s:.0f};K={K};devices={n_dev}")
    return {"dataset": dataset, "path": path, "use_kernel": use_kernel,
            "scatter_v": scatter_v, "n_devices": n_dev,
            "sec_per_sweep": dt, "ratings_per_s": ratings_per_s,
            "rows_per_s": (train.n_rows + train.n_cols) / dt, "K": K,
            "nnz": train.nnz, "n_rows": train.n_rows,
            "n_cols": train.n_cols, "max_nnz_row": csr_r.max_nnz,
            "backend": jax.default_backend(),
            "comm_bytes_per_sweep": (
                DIST.sweep_comm_bytes_scatter(train.n_cols, K) if scatter_v
                else DIST.sweep_comm_bytes(train.n_cols, K))}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--datasets", nargs="+", default=["movielens", "amazon"])
    ap.add_argument("--use-kernel", choices=["on", "off", "both", "fused"],
                    default="both",
                    help="fused zero-materialization path, XLA-gather "
                         "baseline, or both for a side-by-side; 'fused' "
                         "measures ONLY the one-kernel Gibbs sweep "
                         "(kernels/bmf_sweep, fp32 + bf16 rows)")
    ap.add_argument("--distributed", action="store_true",
                    help="also measure the shard_map'd sweep, psum and "
                         "scatter-V variants crossed with the kernel paths")
    ap.add_argument("--n-probe", type=int, default=8)
    ap.add_argument("--json-out", default=None,
                    help="also write records to this JSON file")
    args = ap.parse_args()
    recs = []
    for d in args.datasets:
        for uk in KERNEL_PATHS.get(args.use_kernel, []):
            recs.append(run(d, n_probe=args.n_probe, use_kernel=uk))
            if args.distributed:
                for sv in (False, True):
                    recs.append(run_distributed(d, n_probe=args.n_probe,
                                                use_kernel=uk, scatter_v=sv))
        # the one-kernel sweep rides along with 'both' (artifact
        # regeneration keeps every hot path side by side) and is the sole
        # subject of 'fused' (the CI smoke): fp32 and bf16 rows each
        if args.use_kernel in ("both", "fused"):
            for dt in ("fp32", "bf16"):
                recs.append(run(d, n_probe=args.n_probe,
                                sweep_fused=True, sweep_dtype=dt))
    if args.json_out:
        payload = {"benchmark": "table1_throughput",
                   "backend": jax.default_backend(),
                   "records": recs}
        if args.distributed:
            payload["note"] = (
                "this run faked a multi-device CPU mesh via XLA_FLAGS "
                f"host_platform_device_count ({len(jax.devices())} devices); "
                "dist_* records measure the shard_map'd sweep there, and the "
                "plain-path records of the same process share that env")
        with open(args.json_out, "w") as f:
            json.dump(payload, f, indent=2)


if __name__ == "__main__":
    main()
