"""Paper Table 1 (bottom rows): rows/sec and ratings/sec of the Gibbs
sampler per dataset — measured on this host, derived = both metrics."""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core import bmf as BMF
from repro.core import gibbs as GIBBS
from repro.data import synthetic as SYN
from repro.data.sparse import coo_to_padded_csr, train_test_split

from benchmarks.common import emit


def run(dataset: str, n_probe: int = 8):
    coo, p = SYN.generate(dataset, seed=51)
    train, _ = train_test_split(coo, 0.1, seed=52)
    csr_r = coo_to_padded_csr(train)
    csr_c = coo_to_padded_csr(train.transpose())
    K = min(p.K, 16)
    cfg = BMF.BMFConfig(K=K, n_samples=n_probe, burnin=0)
    dummy = np.zeros(1, np.int32)
    # warmup + compile
    GIBBS.run_gibbs(jax.random.key(0), csr_r, csr_c, dummy, dummy,
                    BMF.BMFConfig(K=K, n_samples=1, burnin=0))
    t0 = time.time()
    GIBBS.run_gibbs(jax.random.key(0), csr_r, csr_c, dummy, dummy, cfg)
    dt = (time.time() - t0) / n_probe
    rows_per_s = (train.n_rows + train.n_cols) / dt
    ratings_per_s = 2 * train.nnz / dt   # each rating visited in both factors
    emit(f"table1_throughput/{dataset}", dt,
         f"rows_per_s={rows_per_s:.0f};ratings_per_s={ratings_per_s:.0f};K={K}")
    return rows_per_s, ratings_per_s


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--datasets", nargs="+", default=["movielens", "amazon"])
    args = ap.parse_args()
    for d in args.datasets:
        run(d)


if __name__ == "__main__":
    main()
