"""Serving-path benchmark: batched top-K latency/QPS over a live store.

Trains one PP run, builds the device-resident ``PosteriorStore``, then
drives the ``MicroBatchRouter`` closed-loop at a sweep of batch sizes for
BOTH scoring modes (exact posterior-mean ranking and per-request Thompson
draws): each config submits ``--iters`` full batches and reports
per-request p50/p99 latency (inclusive of the scoring dispatch — the
router stamps tickets after the device result is host-visible) and QPS.
The batch executable is warmed before timing, so the numbers isolate
serving, not compilation.

With ``--json-out`` each (mode, batch) config merge-appends one run
record into the ``{runs: [...]}`` schema idempotently (re-running a
config REPLACES its record — ``benchmarks.common.merge_runs``, covered
in tests/test_bench_json.py).

  PYTHONPATH=src:. python benchmarks/bench_serving.py \
      --dataset movielens --blocks 4 --samples 20 \
      --batches 1 8 32 --json-out BENCH_serving.json
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core import bmf as BMF
from repro.core import pp as PP
from repro.core.partition import partition, suggest_grid
from repro.data import synthetic as SYN
from repro.data.sparse import train_test_split
from repro.launch.bmf_serve import build_requests
from repro.serving import MicroBatchRouter, PosteriorStore
from repro.serving.scoring import MODES

from benchmarks import common as COMMON
from benchmarks.common import emit

# a run record's config identity (one record per mode x batch size)
RUN_KEY = ("dataset", "grid", "K", "samples", "slots", "mode", "batch")


def _run_key(rec: dict) -> tuple:
    return tuple(tuple(v) if isinstance(v, list) else v
                 for v in (rec.get(f) for f in RUN_KEY))


def merge_runs(doc, run_rec: dict) -> dict:
    """This bench's binding of ``benchmarks.common.merge_runs`` (public
    name — tests and tooling import it from here)."""
    return COMMON.merge_runs(doc, run_rec, _run_key, "serving")


def merge_json_out(path, run_rec: dict) -> dict:
    return COMMON.merge_json_out(path, run_rec, _run_key, "serving")


def bench_config(store, reqs, mode: str, batch: int, k: int, max_seen: int,
                 iters: int, seed: int) -> dict:
    """Closed-loop: submit ``batch`` requests back to back (the router
    auto-dispatches at the full batch), ``iters`` times."""
    router = MicroBatchRouter(store, k=k, mode=mode, latency_budget_s=0.0,
                              max_batch=batch, max_seen=max_seen, seed=seed)
    # warm the batch executable
    for r in reqs[:batch]:
        router.submit(r)
    router.flush()
    router.latencies_s.clear()
    router.dispatches.clear()

    t0 = time.time()
    for it in range(iters):
        lo = (it * batch) % max(1, len(reqs) - batch)
        for r in reqs[lo:lo + batch]:
            router.submit(r)
        router.flush()           # tail (short final slice) dispatches too
    wall = time.time() - t0

    lat = np.asarray(router.latencies_s)
    return {
        "mode": mode, "batch": batch,
        "n_requests": int(len(lat)),
        "n_dispatches": len(router.dispatches),
        "wall_s": round(wall, 4),
        "qps": round(len(lat) / max(wall, 1e-9), 1),
        "p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 4),
        "p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 4),
        "plan": [list(s) for s in router.plan_signatures],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="movielens",
                    choices=list(SYN.PRESETS))
    ap.add_argument("--blocks", type=int, default=4)
    ap.add_argument("--samples", type=int, default=20)
    ap.add_argument("--k", type=int, default=0, help="0 = preset K (cap 16)")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--topk", type=int, default=10)
    ap.add_argument("--batches", type=int, nargs="+", default=[1, 8, 32])
    ap.add_argument("--modes", nargs="+", default=list(MODES),
                    choices=list(MODES))
    ap.add_argument("--iters", type=int, default=30,
                    help="timed batches per (mode, batch) config")
    ap.add_argument("--max-seen", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()

    coo, p = SYN.generate(args.dataset, seed=args.seed)
    train, test = train_test_split(coo, 0.1, seed=args.seed + 1)
    K = args.k or min(p.K, 16)
    cfg = BMF.BMFConfig(K=K, n_samples=args.samples,
                        burnin=args.samples // 3)
    I, J = suggest_grid(train.n_rows, train.n_cols, args.blocks)
    part = partition(train, I, J)
    print(f"dataset={args.dataset} N={train.n_rows} M={train.n_cols} "
          f"grid={I}x{J} K={K}")

    res = PP.run_pp(jax.random.key(args.seed), part, cfg, test,
                    executor="stacked")
    store = PosteriorStore.from_pp_result(
        res, jax.random.key(args.seed + 2), n_slots=args.slots)
    jax.block_until_ready(store)
    print(f"trained RMSE={res.rmse:.4f}; store {store.n_users}x"
          f"{store.n_items} K={store.K} slots={store.n_slots}")

    n_reqs = max(args.batches) * 4
    reqs = build_requests(train, n_reqs, args.max_seen, args.seed + 4)

    base = {"dataset": args.dataset, "grid": [I, J], "K": K,
            "samples": args.samples, "slots": args.slots,
            "topk": args.topk, "rmse": round(res.rmse, 4)}
    for mode in args.modes:
        for batch in args.batches:
            rec = dict(base)
            rec.update(bench_config(store, reqs, mode, batch, args.topk,
                                    args.max_seen, args.iters,
                                    args.seed + 5))
            emit(f"serving/{mode}/b{batch}", rec["p50_ms"] / 1e3,
                 f"qps={rec['qps']}")
            print(f"  {mode:9s} batch={batch:3d}  "
                  f"p50={rec['p50_ms']:.2f}ms p99={rec['p99_ms']:.2f}ms "
                  f"QPS={rec['qps']:.0f} ({rec['n_dispatches']} dispatches)")
            if args.json_out:
                merge_json_out(args.json_out, rec)
    if args.json_out:
        print("->", args.json_out)


if __name__ == "__main__":
    main()
