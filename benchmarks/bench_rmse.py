"""Paper Table 2: RMSE of BMF+PP vs BMF vs ALS / blocked-SGD / CCD++.

Datasets are the Table-1-matched synthetic analogues (offline container;
see repro.data.synthetic). K follows Table 1 for movielens/amazon (K=10);
for the K=100 presets (netflix, yahoo) the benchmark default uses K=16 to
stay within the CPU container budget — pass --full-k to use the paper's K.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.baselines.als import ALSConfig, run_als
from repro.baselines.ccd import CCDConfig, run_ccd
from repro.baselines.sgd import SGDConfig, run_sgd
from repro.core import bmf as BMF
from repro.core import pp as PP
from repro.core.partition import partition, suggest_grid
from repro.data import synthetic as SYN
from repro.data.sparse import coo_to_padded_csr, train_test_split

from benchmarks.common import emit


def run(dataset: str = "movielens", n_blocks: int = 4, full_k: bool = False,
        n_samples: int = 40):
    coo, p = SYN.generate(dataset, seed=11)
    train, test = train_test_split(coo, 0.1, seed=12)
    K = p.K if (full_k or p.K <= 16) else 16
    tr = np.asarray(test.row)
    tc = np.asarray(test.col)

    def rmse(pred):
        return float(np.sqrt(np.mean((np.asarray(pred) - test.val) ** 2)))

    results = {}

    # BMF+PP
    I, J = suggest_grid(train.n_rows, train.n_cols, n_blocks)
    part = partition(train, I, J)
    cfg = BMF.BMFConfig(K=K, n_samples=n_samples, burnin=n_samples // 3)
    t0 = time.time()
    res = PP.run_pp(jax.random.key(0), part, cfg, test)
    results["bmf_pp"] = (res.rmse, time.time() - t0)

    # full BMF
    t0 = time.time()
    r_full, secs, _ = PP.run_full_bmf(jax.random.key(0), train, test, cfg)
    results["bmf"] = (r_full, secs)

    csr_r = coo_to_padded_csr(train)
    csr_c = coo_to_padded_csr(train.transpose())

    t0 = time.time()
    _, _, pred = run_als(jax.random.key(0), csr_r, csr_c, tr, tc,
                         ALSConfig(K=K, n_iters=20))
    results["als"] = (rmse(pred), time.time() - t0)

    t0 = time.time()
    _, _, pred = run_sgd(jax.random.key(0), train, tr, tc,
                         SGDConfig(K=K, n_epochs=30))
    results["fpsgd"] = (rmse(pred), time.time() - t0)

    t0 = time.time()
    _, _, pred = run_ccd(jax.random.key(0), csr_r, csr_c, tr, tc,
                         CCDConfig(K=K, n_iters=10))
    results["ccd"] = (rmse(pred), time.time() - t0)

    for method, (r, secs) in results.items():
        emit(f"table2_rmse/{dataset}/{method}", secs, f"rmse={r:.4f}")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--datasets", nargs="+",
                    default=["movielens", "netflix", "amazon"])
    ap.add_argument("--full-k", action="store_true")
    args = ap.parse_args()
    for d in args.datasets:
        run(d, full_k=args.full_k)


if __name__ == "__main__":
    main()
