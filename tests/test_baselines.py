"""Baseline MF methods (ALS / blocked SGD / CCD++) must all beat the mean
predictor on synthetic low-rank data — they are the paper's Table 2/3
competitor columns."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.baselines.als import ALSConfig, run_als
from repro.baselines.ccd import CCDConfig, run_ccd
from repro.baselines.sgd import SGDConfig, run_sgd
from repro.data import synthetic as SYN
from repro.data.sparse import coo_to_padded_csr, train_test_split


@pytest.fixture(scope="module")
def data():
    coo, p = SYN.generate("mini", seed=7)
    train, test = train_test_split(coo, 0.15, seed=8)
    csr_r = coo_to_padded_csr(train)
    csr_c = coo_to_padded_csr(train.transpose())
    tr = jnp.asarray(test.row)
    tc = jnp.asarray(test.col)
    base = float(np.sqrt(np.mean((test.val - train.val.mean()) ** 2)))
    return train, test, csr_r, csr_c, tr, tc, base, p


def _rmse(pred, test):
    return float(np.sqrt(np.mean((np.asarray(pred) - test.val) ** 2)))


def test_als(data):
    train, test, csr_r, csr_c, tr, tc, base, p = data
    _, _, pred = run_als(jax.random.key(0), csr_r, csr_c, tr, tc,
                         ALSConfig(K=p.K, n_iters=15))
    assert _rmse(pred, test) < 0.9 * base


def test_sgd(data):
    train, test, csr_r, csr_c, tr, tc, base, p = data
    _, _, pred = run_sgd(jax.random.key(0), train, tr, tc,
                         SGDConfig(K=p.K, n_epochs=40))
    assert _rmse(pred, test) < 0.9 * base


def test_ccd(data):
    train, test, csr_r, csr_c, tr, tc, base, p = data
    _, _, pred = run_ccd(jax.random.key(0), csr_r, csr_c, tr, tc,
                         CCDConfig(K=p.K, n_iters=12))
    assert _rmse(pred, test) < 0.9 * base
