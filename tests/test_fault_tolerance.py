"""Chaos battery for the fault-tolerant PP engine.

Drives every registered executor through the deterministic injection seam
(``engine.FaultPlan``): NaN-poisoned chains, hung dispatches, failed
dispatches — and asserts the three recovery contracts:

  * heal:    a retried block re-runs through the shared single-block
             runner, so the healed run's numbers match the serial
             executor's healed run (executor-independent retries);
  * degrade: an unrecoverable block falls back to its propagated prior,
             which cancels exactly in the divide-away aggregation — the
             result stays finite and the fault is in the ledger;
  * resume:  a run killed mid-graph restarts from its block checkpoints
             and finishes bitwise-identical to an uninterrupted one.

Mirrors tests/test_executor_conformance.py: new executors registered in
``engine.EXECUTORS`` auto-enroll here too.
"""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import bmf as BMF
from repro.core import engine as ENG
from repro.core import pp as PP
from repro.core.partition import partition
from repro.core.posterior import RowGaussians
from repro.data import synthetic as SYN
from repro.data.sparse import apply_permutation, train_test_split

EXECUTOR_NAMES = sorted(ENG.EXECUTORS)
# executors with a poll loop (completion-detection seam) — the only ones a
# hang can affect, and the ones the watchdog polices
OVERLAPPED = [n for n in EXECUTOR_NAMES
              if hasattr(ENG.EXECUTORS[n], "_is_resolved")]

# same atol the conformance battery uses for cross-executor parity: the
# stacked/sharded paths batch the fp reductions
PARITY_ATOL = 5e-5


def _make(name, **kw):
    if name == "sharded":
        from repro.core.topology import Topology
        return ENG.ShardedExecutor(Topology(block=1, data=1), **kw)
    if name == "streaming":
        return ENG.StreamingExecutor(window=2, **kw)
    return ENG.EXECUTORS[name](**kw)


@pytest.fixture(scope="module")
def conf_run():
    coo, p = SYN.generate("mini", seed=13)
    train, test = train_test_split(coo, 0.15, seed=14)
    cfg = BMF.BMFConfig(K=p.K, n_samples=5, burnin=1)
    part = partition(train, 3, 3)          # covers all four phase tags
    key = jax.random.key(5)
    ref = PP.run_pp(key, part, cfg, test, executor="serial")
    return part, cfg, test, key, ref


@pytest.fixture(scope="module")
def serial_healed(conf_run):
    """The serial executor's healed run under the canonical NaN plan — the
    parity reference every other executor's healed run must match."""
    part, cfg, test, key, _ = conf_run
    plan = ENG.FaultPlan(nan_at={(1, 1): 1})
    return PP.run_pp(key, part, cfg, test, executor="serial",
                     fault_plan=plan)


def _assert_trace_dep_safe(trace, part):
    graph = {t.coord: t for _, ts in ENG.build_phase_graph(part) for t in ts}
    dispatched, resolved = set(), set()
    for ev, c in trace:
        if ev == "dispatch":
            assert set(graph[c].deps) <= resolved, \
                f"{c} dispatched before deps {graph[c].deps} resolved"
            assert c not in dispatched, f"{c} dispatched twice"
            dispatched.add(c)
        else:
            assert ev == "resolve" and c in dispatched
            resolved.add(c)
    assert resolved == set(graph)
    assert len(trace) == 2 * len(graph)


# ---------------------------------------------------------------------------
# NaN-poisoned chains: retry heals, degrade stays finite, raise raises
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", EXECUTOR_NAMES)
def test_nan_injection_retry_heals_with_serial_parity(conf_run,
                                                      serial_healed, name):
    """A NaN'd chain is caught by the health guard and retried through the
    shared runner — so the healed run matches serial's healed run to the
    usual batched-fp tolerance, whatever executor hit the fault."""
    part, cfg, test, key, _ = conf_run
    plan = ENG.FaultPlan(nan_at={(1, 1): 1})
    ex = _make(name, record_trace=True)
    res = PP.run_pp(key, part, cfg, test, executor=ex, fault_plan=plan)
    assert res.n_retries == 1
    assert [(f.kind, f.action) for f in res.faults] == \
        [("nonfinite", "retried")]
    assert np.isfinite(res.rmse)
    assert abs(res.rmse - serial_healed.rmse) < PARITY_ATOL
    # retries run through ONE shared single-block runner, so the healed
    # block's chain matches serial's healed chain up to the batched-fp
    # differences its PRIORS inherit from the executor's upstream blocks
    assert abs(res.per_block_rmse[1, 1]
               - serial_healed.per_block_rmse[1, 1]) < PARITY_ATOL
    # trace contract survives the retry: one dispatch + one resolve per
    # block, dependency-safe order
    _assert_trace_dep_safe(ex.trace, part)


@pytest.mark.parametrize("name", EXECUTOR_NAMES)
def test_nan_degrade_yields_finite_result(conf_run, name):
    """With the retry budget exhausted, 'degrade' swaps the propagated
    prior in for the poisoned posterior BEFORE it reaches any successor or
    the aggregation — everything downstream stays finite and the fault is
    on the ledger."""
    part, cfg, test, key, ref = conf_run
    plan = ENG.FaultPlan(nan_at={(1, 1): 99})   # poison survives retries
    res = PP.run_pp(key, part, cfg, test, executor=_make(name),
                    fault_plan=plan, on_fault="degrade", max_retries=1)
    assert np.isfinite(res.rmse)
    assert np.isfinite(np.asarray(res.U_agg.eta)).all()
    assert np.isfinite(np.asarray(res.U_agg.Lambda)).all()
    assert np.isfinite(np.asarray(res.V_agg.eta)).all()
    assert np.isfinite(np.asarray(res.V_agg.Lambda)).all()
    assert [f.action for f in res.faults] == ["retried", "degraded"]
    assert all(f.coord == (1, 1) for f in res.faults)
    # the degraded block's test entries leave the RMSE, they don't poison it
    assert res.n_test < ref.n_test
    assert res.per_block_rmse[1, 1] == 0.0


def test_nan_on_fault_raise_raises(conf_run):
    part, cfg, test, key, _ = conf_run
    plan = ENG.FaultPlan(nan_at={(1, 1): 99})
    with pytest.raises(ENG.BlockFaultError, match=r"\(1, 1\).*nonfinite"):
        PP.run_pp(key, part, cfg, test, executor="serial", fault_plan=plan,
                  on_fault="raise", max_retries=1)


def test_nan_phase_a_degrades_to_hyperprior(conf_run):
    """Phase (0,0) has no propagated prior — degrade substitutes the
    neutral N(0, I) rows and every downstream block still runs."""
    part, cfg, test, key, _ = conf_run
    plan = ENG.FaultPlan(nan_at={(0, 0): 99})
    res = PP.run_pp(key, part, cfg, test, executor="serial",
                    fault_plan=plan, on_fault="degrade", max_retries=0)
    assert np.isfinite(res.rmse)
    assert np.isfinite(np.asarray(res.U_agg.eta)).all()


# ---------------------------------------------------------------------------
# dispatch failures: healed at every executor's dispatch site
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", EXECUTOR_NAMES)
def test_dispatch_failure_heals(conf_run, name):
    part, cfg, test, key, _ = conf_run
    plan = ENG.FaultPlan(fail_dispatch_at={(0, 1): 1, (2, 2): 2})
    ref = PP.run_pp(key, part, cfg, test, executor="serial",
                    fault_plan=plan)
    ex = _make(name, record_trace=True)
    res = PP.run_pp(key, part, cfg, test, executor=ex, fault_plan=plan)
    assert res.n_retries == 3            # 1 for (0,1) + 2 for (2,2)
    assert {f.kind for f in res.faults} == {"dispatch"}
    assert abs(res.rmse - ref.rmse) < PARITY_ATOL
    _assert_trace_dep_safe(ex.trace, part)


def test_dispatch_failure_exhausted_raises(conf_run):
    part, cfg, test, key, _ = conf_run
    plan = ENG.FaultPlan(fail_dispatch_at={(1, 0): 99})
    with pytest.raises(ENG.BlockFaultError, match=r"\(1, 0\).*dispatch"):
        PP.run_pp(key, part, cfg, test, executor="serial", fault_plan=plan,
                  max_retries=1)


# ---------------------------------------------------------------------------
# hangs: the watchdog recovers within its deadline (satellite: the legacy
# block-on-oldest fallback would spin forever here)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(OVERLAPPED))
def test_hang_recovered_by_watchdog(conf_run, name):
    """A dispatch whose completion never fires is re-dispatched after its
    deadline — with the same key, so the recovered run is bitwise-equal to
    a clean run of the same executor."""
    part, cfg, test, key, _ = conf_run
    clean = PP.run_pp(key, part, cfg, test, executor=_make(name))
    pol = ENG.FaultPolicy(timeout_floor_s=0.5, timeout_slack=0.0)
    res = PP.run_pp(key, part, cfg, test, executor=_make(name),
                    fault_plan=ENG.FaultPlan(hang_at={(1, 1): 1}),
                    fault_policy=pol)
    # streaming's timeout domain is the chunk, so chunk-mates of the hung
    # block may carry redispatch records too — but nothing else happens
    assert {(f.kind, f.action) for f in res.faults} == \
        {("timeout", "redispatched")}
    assert (1, 1) in {f.coord for f in res.faults}
    assert res.rmse == clean.rmse
    np.testing.assert_array_equal(np.asarray(res.U_agg.eta),
                                  np.asarray(clean.U_agg.eta))


@pytest.mark.parametrize("name", sorted(OVERLAPPED))
def test_hang_budget_exhaustion_degrades(conf_run, name):
    part, cfg, test, key, _ = conf_run
    pol = ENG.FaultPolicy(timeout_floor_s=0.3, timeout_slack=0.0,
                          on_fault="degrade", max_retries=1)
    res = PP.run_pp(key, part, cfg, test, executor=_make(name),
                    fault_plan=ENG.FaultPlan(hang_at={(1, 1): 99}),
                    fault_policy=pol)
    assert np.isfinite(res.rmse)
    assert res.faults[-1].action == "degraded"
    assert any(f.kind == "timeout" for f in res.faults)


# ---------------------------------------------------------------------------
# checkpoint / resume
# ---------------------------------------------------------------------------


RESUME_EXECUTORS = ["serial", "async", "streaming"]


def _interrupt(part, cfg, test, key, name, ckpt_dir, **ckpt_kw):
    """Run with checkpointing and an unrecoverable mid-graph dispatch
    failure — the stand-in for a kill: the raise unwinds through the
    engine's flush, leaving a valid resumable directory."""
    with pytest.raises(ENG.BlockFaultError):
        PP.run_pp(key, part, cfg, test, executor=_make(name),
                  checkpoint_dir=ckpt_dir,
                  fault_plan=ENG.FaultPlan(fail_dispatch_at={(1, 2): 99}),
                  max_retries=0, on_fault="raise", **ckpt_kw)


@pytest.mark.parametrize("name", RESUME_EXECUTORS)
def test_kill_and_resume_bitwise_identical(conf_run, tmp_path, name):
    part, cfg, test, key, _ = conf_run
    ref = PP.run_pp(key, part, cfg, test, executor=_make(name))
    d = tmp_path / "ckpt"
    _interrupt(part, cfg, test, key, name, d)
    n_saved = len(list(d.glob("block_*.npz")))
    assert 0 < n_saved < part.I * part.J    # genuinely mid-graph
    res = PP.run_pp(key, part, cfg, test, executor=_make(name),
                    resume_from=d)
    assert res.resumed_blocks == n_saved
    assert res.rmse == ref.rmse
    assert res.n_test == ref.n_test
    for got, want in ((res.U_agg, ref.U_agg), (res.V_agg, ref.V_agg)):
        np.testing.assert_array_equal(np.asarray(got.eta),
                                      np.asarray(want.eta))
        np.testing.assert_array_equal(np.asarray(got.Lambda),
                                      np.asarray(want.Lambda))


def test_resume_skips_restored_blocks(conf_run, tmp_path):
    part, cfg, test, key, _ = conf_run
    d = tmp_path / "ckpt"
    _interrupt(part, cfg, test, key, "serial", d)
    restored = {tuple(int(x) for x in p.stem.split("_")[1:])
                for p in d.glob("block_*.npz")}
    ex = _make("serial", record_trace=True)
    PP.run_pp(key, part, cfg, test, executor=ex, resume_from=d)
    ran = {c for ev, c in ex.trace if ev == "dispatch"}
    assert not (ran & restored)             # restored blocks never re-run
    assert ran | restored == {t.coord for _, ts in
                              ENG.build_phase_graph(part) for t in ts}


def test_resume_continues_checkpointing(conf_run, tmp_path):
    """resume_from == checkpoint_dir: the continued run tops the directory
    up to a complete set, usable for yet another (full) resume."""
    part, cfg, test, key, ref = conf_run
    d = tmp_path / "ckpt"
    _interrupt(part, cfg, test, key, "serial", d)
    PP.run_pp(key, part, cfg, test, executor="serial",
              resume_from=d, checkpoint_dir=d)
    assert len(list(d.glob("block_*.npz"))) == part.I * part.J
    res = PP.run_pp(key, part, cfg, test, executor="serial", resume_from=d)
    assert res.resumed_blocks == part.I * part.J
    assert res.rmse == ref.rmse


def test_ckpt_every_batches_writes(conf_run, tmp_path):
    part, cfg, test, key, _ = conf_run
    every = tmp_path / "every"
    one = tmp_path / "one"
    _interrupt(part, cfg, test, key, "serial", one)
    _interrupt(part, cfg, test, key, "serial", every, ckpt_every=4)
    # batching persists no MORE than per-resolve flushing at the kill, and
    # the engine's final flush still lands the buffered remainder
    assert len(list(every.glob("block_*.npz"))) \
        <= len(list(one.glob("block_*.npz")))
    res = PP.run_pp(key, part, cfg, test, executor="serial",
                    resume_from=every)
    ref = PP.run_pp(key, part, cfg, test, executor="serial")
    assert res.rmse == ref.rmse


def test_resume_mismatch_rejected(conf_run, tmp_path):
    part, cfg, test, key, _ = conf_run
    d = tmp_path / "ckpt"
    _interrupt(part, cfg, test, key, "serial", d)
    with pytest.raises(ValueError, match="resume_from"):
        PP.run_pp(jax.random.key(99), part, cfg, test, executor="serial",
                  resume_from=d)                       # different PRNG key
    with pytest.raises(ValueError, match="resume_from"):
        PP.run_pp(key, part, cfg._replace(n_samples=7), test,
                  executor="serial", resume_from=d)    # different chain
    coo2, _ = SYN.generate("mini", seed=13)
    train2, _ = train_test_split(coo2, 0.15, seed=14)
    with pytest.raises(ValueError, match="resume_from"):
        PP.run_pp(key, partition(train2, 2, 2), cfg, test,
                  executor="serial", resume_from=d)    # different grid


# ---------------------------------------------------------------------------
# aggregation under non-finite posteriors: why the guard sits BEFORE it
# ---------------------------------------------------------------------------


def test_aggregate_axis_propagates_nonfinite(conf_run):
    """``pp._aggregate_axis`` is a plain linear reduction: one NaN'd block
    posterior poisons the whole factor. That is exactly why the engine's
    health guard runs at block resolution, before the store — this test
    pins the division of labor."""
    part, cfg, _, _, _ = conf_run
    K = cfg.K
    posts = [[RowGaussians(
        eta=jnp.zeros((len(part.block(i, j).row_ids), K)),
        Lambda=jnp.broadcast_to(jnp.eye(K),
                                (len(part.block(i, j).row_ids), K, K)))
        for j in range(part.J)] for i in range(part.I)]
    clean = PP._aggregate_axis(part, posts, axis="row")
    assert np.isfinite(np.asarray(clean.eta)).all()
    posts[1][1] = RowGaussians(eta=posts[1][1].eta.at[0, 0].set(jnp.nan),
                               Lambda=posts[1][1].Lambda)
    dirty = PP._aggregate_axis(part, posts, axis="row")
    assert not np.isfinite(np.asarray(dirty.eta)).all()


def test_rmse_aggregation_guarded_from_nonfinite(conf_run):
    """End to end: a poisoned block under 'degrade' reaches neither the
    RMSE sum nor the factor aggregation — both stay finite while the raw
    injected chain demonstrably goes non-finite (health=False)."""
    part, cfg, test, key, _ = conf_run
    plan = ENG.FaultPlan(nan_at={(1, 1): 99})
    # the injected chain really is non-finite at the gibbs level
    task = [t for _, ts in ENG.build_phase_graph(part) for t in ts
            if t.coord == (1, 1)][0]
    test_p = apply_permutation(test, part.row_perm, part.col_perm)
    keys = jax.random.split(key, part.I * part.J).reshape(part.I, part.J)
    ctx = ENG.PhaseContext(part=part, cfg=cfg, test_p=test_p, keys=keys,
                           shapes=PP.BlockShapes.per_phase(part, test_p),
                           fault_plan=plan)
    ctx.U_posts[(1, 0)] = _uniform_prior(part, 1, 0, cfg.K, rows=True)
    ctx.V_posts[(0, 1)] = _uniform_prior(part, 0, 1, cfg.K, rows=False)
    raw = ENG._run_block_attempt(ctx, task, attempt=0)
    assert not bool(np.asarray(raw.health))
    assert ENG._fault_kind(ctx, task, raw) == "nonfinite"
    # ...and the guarded run never lets it out
    res = PP.run_pp(key, part, cfg, test, executor="serial",
                    fault_plan=plan, on_fault="degrade", max_retries=0)
    assert np.isfinite(res.rmse)
    assert np.isfinite(np.asarray(res.U_agg.eta)).all()


def _uniform_prior(part, i, j, K, rows):
    blk = part.block(i, j)
    n = len(blk.row_ids) if rows else len(blk.col_ids)
    return RowGaussians(eta=jnp.zeros((n, K)),
                        Lambda=jnp.broadcast_to(jnp.eye(K), (n, K, K)))


def test_rmse_divergence_threshold_trips(conf_run):
    """rmse_max treats a finite-but-diverged block as faulty."""
    part, cfg, test, key, _ = conf_run
    pol = ENG.FaultPolicy(rmse_max=1e-6, on_fault="degrade", max_retries=0)
    res = PP.run_pp(key, part, cfg, test, executor="serial",
                    fault_policy=pol)
    assert res.faults
    assert all(f.kind == "rmse" for f in res.faults)
    assert np.isfinite(res.rmse)


# ---------------------------------------------------------------------------
# input validation: actionable errors naming the offending argument
# ---------------------------------------------------------------------------


def test_validation_errors(conf_run, tmp_path):
    part, cfg, test, key, _ = conf_run
    with pytest.raises(ValueError, match="window"):
        ENG.make_executor("streaming", window=0)
    with pytest.raises(ValueError, match="window"):
        ENG.StreamingExecutor(window=-3)
    with pytest.raises(ValueError, match="depth"):
        ENG.StreamingExecutor(depth=0)
    with pytest.raises(ValueError, match="max_retries"):
        PP.run_pp(key, part, cfg, test, max_retries=-1)
    with pytest.raises(ValueError, match="on_fault"):
        PP.run_pp(key, part, cfg, test, on_fault="panic")
    with pytest.raises(ValueError, match="ckpt_every"):
        PP.run_pp(key, part, cfg, test, ckpt_every=0)
    with pytest.raises(ValueError, match="max_retries"):
        ENG.FaultPolicy(max_retries=-2)
    with pytest.raises(ValueError, match="on_fault"):
        ENG.FaultPolicy(on_fault="ignore")
    from repro.checkpoint.ckpt import PPCheckpoint
    with pytest.raises(ValueError, match="ckpt_every"):
        PPCheckpoint(tmp_path / "x", every=0)
    from repro.core.topology import Topology
    with pytest.raises(ValueError, match="axes"):
        Topology(block=0, data=1)
    with pytest.raises(ValueError, match="devices"):
        Topology(block=2, data=2, devices=tuple(jax.devices()[:1]))


def test_fault_plan_is_deterministic():
    plan = ENG.FaultPlan(nan_at={(1, 1): 2}, hang_at={(0, 2): 1})
    assert plan.nan((1, 1), 0) and plan.nan((1, 1), 1)
    assert not plan.nan((1, 1), 2)
    assert not plan.nan((2, 2), 0)
    assert plan.hang((0, 2), 0) and not plan.hang((0, 2), 1)
    assert not plan.fail((1, 1), 0)
