"""Chaos battery for the fault-tolerant PP engine.

Drives every registered executor through the deterministic injection seam
(``engine.FaultPlan``): NaN-poisoned chains, hung dispatches, failed
dispatches — and asserts the three recovery contracts:

  * heal:    a retried block re-runs through the shared single-block
             runner, so the healed run's numbers match the serial
             executor's healed run (executor-independent retries);
  * degrade: an unrecoverable block falls back to its propagated prior,
             which cancels exactly in the divide-away aggregation — the
             result stays finite and the fault is in the ledger;
  * resume:  a run killed mid-graph restarts from its block checkpoints
             and finishes bitwise-identical to an uninterrupted one.

Mirrors tests/test_executor_conformance.py: new executors registered in
``engine.EXECUTORS`` auto-enroll here too.
"""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import bmf as BMF
from repro.core import engine as ENG
from repro.core import pp as PP
from repro.core.partition import partition
from repro.core.posterior import RowGaussians
from repro.data import synthetic as SYN
from repro.data.sparse import apply_permutation, train_test_split

EXECUTOR_NAMES = sorted(ENG.EXECUTORS)
# executors with a poll loop (completion-detection seam) — the only ones a
# hang can affect, and the ones the watchdog polices
OVERLAPPED = [n for n in EXECUTOR_NAMES
              if hasattr(ENG.EXECUTORS[n], "_is_resolved")]

# same atol the conformance battery uses for cross-executor parity: the
# stacked/sharded paths batch the fp reductions
PARITY_ATOL = 5e-5


def _make(name, **kw):
    if name == "sharded":
        from repro.core.topology import Topology
        return ENG.ShardedExecutor(Topology(block=1, data=1), **kw)
    if name == "streaming":
        return ENG.StreamingExecutor(window=2, **kw)
    return ENG.EXECUTORS[name](**kw)


@pytest.fixture(scope="module")
def conf_run():
    coo, p = SYN.generate("mini", seed=13)
    train, test = train_test_split(coo, 0.15, seed=14)
    cfg = BMF.BMFConfig(K=p.K, n_samples=5, burnin=1)
    part = partition(train, 3, 3)          # covers all four phase tags
    key = jax.random.key(5)
    ref = PP.run_pp(key, part, cfg, test, executor="serial")
    return part, cfg, test, key, ref


@pytest.fixture(scope="module")
def serial_healed(conf_run):
    """The serial executor's healed run under the canonical NaN plan — the
    parity reference every other executor's healed run must match."""
    part, cfg, test, key, _ = conf_run
    plan = ENG.FaultPlan(nan_at={(1, 1): 1})
    return PP.run_pp(key, part, cfg, test, executor="serial",
                     fault_plan=plan)


def _assert_trace_dep_safe(trace, part):
    graph = {t.coord: t for _, ts in ENG.build_phase_graph(part) for t in ts}
    dispatched, resolved = set(), set()
    for ev, c, *_ in trace:
        if ev == "dispatch":
            assert set(graph[c].deps) <= resolved, \
                f"{c} dispatched before deps {graph[c].deps} resolved"
            assert c not in dispatched, f"{c} dispatched twice"
            dispatched.add(c)
        else:
            assert ev == "resolve" and c in dispatched
            resolved.add(c)
    assert resolved == set(graph)
    assert len(trace) == 2 * len(graph)


# ---------------------------------------------------------------------------
# NaN-poisoned chains: retry heals, degrade stays finite, raise raises
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", EXECUTOR_NAMES)
def test_nan_injection_retry_heals_with_serial_parity(conf_run,
                                                      serial_healed, name):
    """A NaN'd chain is caught by the health guard and retried through the
    shared runner — so the healed run matches serial's healed run to the
    usual batched-fp tolerance, whatever executor hit the fault."""
    part, cfg, test, key, _ = conf_run
    plan = ENG.FaultPlan(nan_at={(1, 1): 1})
    ex = _make(name, record_trace=True)
    res = PP.run_pp(key, part, cfg, test, executor=ex, fault_plan=plan)
    assert res.n_retries == 1
    assert [(f.kind, f.action) for f in res.faults] == \
        [("nonfinite", "retried")]
    assert np.isfinite(res.rmse)
    assert abs(res.rmse - serial_healed.rmse) < PARITY_ATOL
    # retries run through ONE shared single-block runner, so the healed
    # block's chain matches serial's healed chain up to the batched-fp
    # differences its PRIORS inherit from the executor's upstream blocks
    assert abs(res.per_block_rmse[1, 1]
               - serial_healed.per_block_rmse[1, 1]) < PARITY_ATOL
    # trace contract survives the retry: one dispatch + one resolve per
    # block, dependency-safe order
    _assert_trace_dep_safe(ex.trace, part)


@pytest.mark.parametrize("name", EXECUTOR_NAMES)
def test_nan_degrade_yields_finite_result(conf_run, name):
    """With the retry budget exhausted, 'degrade' swaps the propagated
    prior in for the poisoned posterior BEFORE it reaches any successor or
    the aggregation — everything downstream stays finite and the fault is
    on the ledger."""
    part, cfg, test, key, ref = conf_run
    plan = ENG.FaultPlan(nan_at={(1, 1): 99})   # poison survives retries
    res = PP.run_pp(key, part, cfg, test, executor=_make(name),
                    fault_plan=plan, on_fault="degrade", max_retries=1)
    assert np.isfinite(res.rmse)
    assert np.isfinite(np.asarray(res.U_agg.eta)).all()
    assert np.isfinite(np.asarray(res.U_agg.Lambda)).all()
    assert np.isfinite(np.asarray(res.V_agg.eta)).all()
    assert np.isfinite(np.asarray(res.V_agg.Lambda)).all()
    assert [f.action for f in res.faults] == ["retried", "degraded"]
    assert all(f.coord == (1, 1) for f in res.faults)
    # the degraded block's test entries leave the RMSE, they don't poison it
    assert res.n_test < ref.n_test
    assert res.per_block_rmse[1, 1] == 0.0


def test_nan_on_fault_raise_raises(conf_run):
    part, cfg, test, key, _ = conf_run
    plan = ENG.FaultPlan(nan_at={(1, 1): 99})
    with pytest.raises(ENG.BlockFaultError, match=r"\(1, 1\).*nonfinite"):
        PP.run_pp(key, part, cfg, test, executor="serial", fault_plan=plan,
                  on_fault="raise", max_retries=1)


def test_nan_phase_a_degrades_to_hyperprior(conf_run):
    """Phase (0,0) has no propagated prior — degrade substitutes the
    neutral N(0, I) rows and every downstream block still runs."""
    part, cfg, test, key, _ = conf_run
    plan = ENG.FaultPlan(nan_at={(0, 0): 99})
    res = PP.run_pp(key, part, cfg, test, executor="serial",
                    fault_plan=plan, on_fault="degrade", max_retries=0)
    assert np.isfinite(res.rmse)
    assert np.isfinite(np.asarray(res.U_agg.eta)).all()


# ---------------------------------------------------------------------------
# dispatch failures: healed at every executor's dispatch site
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", EXECUTOR_NAMES)
def test_dispatch_failure_heals(conf_run, name):
    part, cfg, test, key, _ = conf_run
    plan = ENG.FaultPlan(fail_dispatch_at={(0, 1): 1, (2, 2): 2})
    ref = PP.run_pp(key, part, cfg, test, executor="serial",
                    fault_plan=plan)
    ex = _make(name, record_trace=True)
    res = PP.run_pp(key, part, cfg, test, executor=ex, fault_plan=plan)
    assert res.n_retries == 3            # 1 for (0,1) + 2 for (2,2)
    assert {f.kind for f in res.faults} == {"dispatch"}
    assert abs(res.rmse - ref.rmse) < PARITY_ATOL
    _assert_trace_dep_safe(ex.trace, part)


def test_dispatch_failure_exhausted_raises(conf_run):
    part, cfg, test, key, _ = conf_run
    plan = ENG.FaultPlan(fail_dispatch_at={(1, 0): 99})
    with pytest.raises(ENG.BlockFaultError, match=r"\(1, 0\).*dispatch"):
        PP.run_pp(key, part, cfg, test, executor="serial", fault_plan=plan,
                  max_retries=1)


# ---------------------------------------------------------------------------
# hangs: the watchdog recovers within its deadline (satellite: the legacy
# block-on-oldest fallback would spin forever here)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(OVERLAPPED))
def test_hang_recovered_by_watchdog(conf_run, name):
    """A dispatch whose completion never fires is re-dispatched after its
    deadline — with the same key, so the recovered run is bitwise-equal to
    a clean run of the same executor."""
    part, cfg, test, key, _ = conf_run
    clean = PP.run_pp(key, part, cfg, test, executor=_make(name))
    pol = ENG.FaultPolicy(timeout_floor_s=0.5, timeout_slack=0.0)
    res = PP.run_pp(key, part, cfg, test, executor=_make(name),
                    fault_plan=ENG.FaultPlan(hang_at={(1, 1): 1}),
                    fault_policy=pol)
    # streaming's timeout domain is the chunk, so chunk-mates of the hung
    # block may carry redispatch records too — but nothing else happens
    assert {(f.kind, f.action) for f in res.faults} == \
        {("timeout", "redispatched")}
    assert (1, 1) in {f.coord for f in res.faults}
    assert res.rmse == clean.rmse
    np.testing.assert_array_equal(np.asarray(res.U_agg.eta),
                                  np.asarray(clean.U_agg.eta))


@pytest.mark.parametrize("name", sorted(OVERLAPPED))
def test_hang_budget_exhaustion_degrades(conf_run, name):
    part, cfg, test, key, _ = conf_run
    pol = ENG.FaultPolicy(timeout_floor_s=0.3, timeout_slack=0.0,
                          on_fault="degrade", max_retries=1)
    res = PP.run_pp(key, part, cfg, test, executor=_make(name),
                    fault_plan=ENG.FaultPlan(hang_at={(1, 1): 99}),
                    fault_policy=pol)
    assert np.isfinite(res.rmse)
    assert res.faults[-1].action == "degraded"
    assert any(f.kind == "timeout" for f in res.faults)


# ---------------------------------------------------------------------------
# checkpoint / resume
# ---------------------------------------------------------------------------


RESUME_EXECUTORS = ["serial", "async", "streaming"]


def _interrupt(part, cfg, test, key, name, ckpt_dir, **ckpt_kw):
    """Run with checkpointing and an unrecoverable mid-graph dispatch
    failure — the stand-in for a kill: the raise unwinds through the
    engine's flush, leaving a valid resumable directory."""
    with pytest.raises(ENG.BlockFaultError):
        PP.run_pp(key, part, cfg, test, executor=_make(name),
                  checkpoint_dir=ckpt_dir,
                  fault_plan=ENG.FaultPlan(fail_dispatch_at={(1, 2): 99}),
                  max_retries=0, on_fault="raise", **ckpt_kw)


@pytest.mark.parametrize("name", RESUME_EXECUTORS)
def test_kill_and_resume_bitwise_identical(conf_run, tmp_path, name):
    part, cfg, test, key, _ = conf_run
    ref = PP.run_pp(key, part, cfg, test, executor=_make(name))
    d = tmp_path / "ckpt"
    _interrupt(part, cfg, test, key, name, d)
    n_saved = len(list(d.glob("block_*.npz")))
    assert 0 < n_saved < part.I * part.J    # genuinely mid-graph
    res = PP.run_pp(key, part, cfg, test, executor=_make(name),
                    resume_from=d)
    assert res.resumed_blocks == n_saved
    assert res.rmse == ref.rmse
    assert res.n_test == ref.n_test
    for got, want in ((res.U_agg, ref.U_agg), (res.V_agg, ref.V_agg)):
        np.testing.assert_array_equal(np.asarray(got.eta),
                                      np.asarray(want.eta))
        np.testing.assert_array_equal(np.asarray(got.Lambda),
                                      np.asarray(want.Lambda))


def test_resume_skips_restored_blocks(conf_run, tmp_path):
    part, cfg, test, key, _ = conf_run
    d = tmp_path / "ckpt"
    _interrupt(part, cfg, test, key, "serial", d)
    restored = {tuple(int(x) for x in p.stem.split("_")[1:])
                for p in d.glob("block_*.npz")}
    ex = _make("serial", record_trace=True)
    PP.run_pp(key, part, cfg, test, executor=ex, resume_from=d)
    ran = {c for ev, c, *_ in ex.trace if ev == "dispatch"}
    assert not (ran & restored)             # restored blocks never re-run
    assert ran | restored == {t.coord for _, ts in
                              ENG.build_phase_graph(part) for t in ts}


def test_resume_continues_checkpointing(conf_run, tmp_path):
    """resume_from == checkpoint_dir: the continued run tops the directory
    up to a complete set, usable for yet another (full) resume."""
    part, cfg, test, key, ref = conf_run
    d = tmp_path / "ckpt"
    _interrupt(part, cfg, test, key, "serial", d)
    PP.run_pp(key, part, cfg, test, executor="serial",
              resume_from=d, checkpoint_dir=d)
    assert len(list(d.glob("block_*.npz"))) == part.I * part.J
    res = PP.run_pp(key, part, cfg, test, executor="serial", resume_from=d)
    assert res.resumed_blocks == part.I * part.J
    assert res.rmse == ref.rmse


def test_ckpt_every_batches_writes(conf_run, tmp_path):
    part, cfg, test, key, _ = conf_run
    every = tmp_path / "every"
    one = tmp_path / "one"
    _interrupt(part, cfg, test, key, "serial", one)
    _interrupt(part, cfg, test, key, "serial", every, ckpt_every=4)
    # batching persists no MORE than per-resolve flushing at the kill, and
    # the engine's final flush still lands the buffered remainder
    assert len(list(every.glob("block_*.npz"))) \
        <= len(list(one.glob("block_*.npz")))
    res = PP.run_pp(key, part, cfg, test, executor="serial",
                    resume_from=every)
    ref = PP.run_pp(key, part, cfg, test, executor="serial")
    assert res.rmse == ref.rmse


def test_resume_mismatch_rejected(conf_run, tmp_path):
    part, cfg, test, key, _ = conf_run
    d = tmp_path / "ckpt"
    _interrupt(part, cfg, test, key, "serial", d)
    with pytest.raises(ValueError, match="resume_from"):
        PP.run_pp(jax.random.key(99), part, cfg, test, executor="serial",
                  resume_from=d)                       # different PRNG key
    with pytest.raises(ValueError, match="resume_from"):
        PP.run_pp(key, part, cfg._replace(n_samples=7), test,
                  executor="serial", resume_from=d)    # different chain
    coo2, _ = SYN.generate("mini", seed=13)
    train2, _ = train_test_split(coo2, 0.15, seed=14)
    with pytest.raises(ValueError, match="resume_from"):
        PP.run_pp(key, partition(train2, 2, 2), cfg, test,
                  executor="serial", resume_from=d)    # different grid


# ---------------------------------------------------------------------------
# elastic group fault domain: quarantine, work stealing, speculation,
# graceful degradation (faked multi-device mesh; see the chaos CI job)
# ---------------------------------------------------------------------------


GROUP_EXECUTORS = ["async", "streaming"]

needs_two = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="group fault domain needs >= 2 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")


def _grouped(name, permute=False, **kw):
    """A 2-group (block=2, data=1) executor on the first two devices;
    ``permute=True`` reverses the physical device order — logical group
    indices (and the canonical-winner rule) are unchanged, so results
    must stay bitwise identical."""
    from repro.core.topology import Topology
    devs = tuple(jax.devices()[:2])
    if permute:
        devs = devs[::-1]
    topo = Topology(block=2, data=1, devices=devs)
    if name == "streaming":
        kw.setdefault("window", 2)
        return ENG.StreamingExecutor(topology=topo, **kw)
    return ENG.AsyncExecutor(topology=topo, **kw)


@pytest.fixture(scope="module")
def grouped_clean(conf_run):
    """Fault-free 2-group reference runs. Also warms every executable on
    BOTH physical devices (both permutations): executables cache per
    device, and the rate estimator drops only each group's FIRST resolve
    — a mid-run compile on the permuted topology would otherwise inflate
    a group's EWMA rate, stalling the speculation threshold and blowing
    tight watchdog floors."""
    if len(jax.devices()) < 2:
        pytest.skip("group fault domain needs >= 2 devices")
    part, cfg, test, key, _ = conf_run
    out = {}
    for name in GROUP_EXECUTORS:
        out[name] = PP.run_pp(key, part, cfg, test, executor=_grouped(name))
        PP.run_pp(key, part, cfg, test,
                  executor=_grouped(name, permute=True))
    return out


def _assert_group_trace_clean(ex, part, label):
    """The extended happens-before pass over the real trace: no dispatch
    to a quarantined group, twins collapse via cancel, steals hit staged
    blocks only."""
    from repro.analysis import registry as REG
    deps = {t.coord: list(t.deps)
            for _, ts in ENG.build_phase_graph(part) for t in ts}
    vs = REG.analyze(REG.TraceArtifact(label=label, trace=ex.trace,
                                       deps=deps))
    assert not vs, [str(v) for v in vs]


def test_group_health_rate_estimator():
    """Satellite: per-group EWMA rates replace PR 6's single global
    fastest-rate; each group drops its first (compile-span) resolve and
    cold groups inherit the fastest calibrated rate."""
    h = ENG._GroupHealth(3, quarantine_after=2)
    assert h.rate(0) == 0.0                  # nothing calibrated yet
    h.observe(0, 5.0)                        # first resolve: compile span
    assert h.rate(0) == 0.0
    h.observe(0, 1.0)
    assert h.rate(0) == 1.0
    h.observe(0, 2.0)                        # EWMA, alpha 0.4
    assert abs(h.rate(0) - (0.6 * 1.0 + 0.4 * 2.0)) < 1e-12
    # a cold group inherits the fastest calibrated rate, not zero —
    # and keeps its OWN rate once calibrated, however slow
    h.observe(1, 9.9)                        # dropped (group 1's compile)
    assert h.rate(1) == h.rate(0)
    h.observe(1, 3.0)
    assert h.rate(1) == 3.0
    assert h.rate(2) == h.global_rate == h.rate(0)
    # consecutive-expiry counter: any resolve resets it; a drained
    # group never re-trips
    assert not h.note_expiry(0)
    h.note_resolve(0)
    assert not h.note_expiry(0)
    assert h.note_expiry(0)
    h.quarantine(0)
    assert h.healthy() == [1, 2]
    assert not h.note_expiry(0)


def test_group_fault_policy_validation():
    with pytest.raises(ValueError, match="on_group_fault"):
        ENG.FaultPolicy(on_group_fault="shrug")
    with pytest.raises(ValueError, match="quarantine_after"):
        ENG.FaultPolicy(quarantine_after=0)
    with pytest.raises(ValueError, match="min_groups"):
        ENG.FaultPolicy(min_groups=0)
    with pytest.raises(ValueError, match="speculate_at"):
        ENG.FaultPolicy(speculate_at=-1.0)
    with pytest.raises(ValueError, match="depth"):
        ENG.AsyncExecutor(depth=0)
    plan = ENG.FaultPlan(group_dead_at={1: 2},
                         group_slow_at={0: (1, 2.5)})
    assert not plan.group_dead(1, 1) and plan.group_dead(1, 2)
    assert not plan.group_dead(0, 0)
    assert plan.group_slow_s(0, 0) == 0.0
    assert plan.group_slow_s(0, 1) == 2.5
    assert plan.group_slow_s(1, 5) == 0.0


def test_topology_without_groups():
    """Survivor sub-topology construction (the resume path after
    ``TopologyDegradedError``)."""
    from repro.core.topology import Topology
    devs = tuple(range(8))        # device identity is opaque to the math
    t = Topology(block=4, data=2, devices=devs)
    s = t.without_groups((1, 3))
    assert (s.block, s.data) == (2, 2)
    assert s.devices == t.group(0) + t.group(2)
    assert t.without_groups(()) == t
    with pytest.raises(ValueError, match="unknown group"):
        t.without_groups((4,))
    with pytest.raises(ValueError, match="every device group"):
        t.without_groups((0, 1, 2, 3))


@needs_two
@pytest.mark.parametrize("name", GROUP_EXECUTORS)
def test_group_dead_quarantine_heals_bitwise(conf_run, grouped_clean, name):
    """A group that dies mid-run expires ``quarantine_after`` consecutive
    times and is quarantined; its staged share and in-flight blocks
    rebalance onto the survivor under the same keys, so the healed run
    is bitwise identical to the fault-free 2-group run."""
    part, cfg, test, key, _ = conf_run
    clean = grouped_clean[name]
    pol = ENG.FaultPolicy(timeout_floor_s=1.0, timeout_slack=0.0,
                          quarantine_after=2, max_retries=5)
    ex = _grouped(name, record_trace=True)
    res = PP.run_pp(key, part, cfg, test, executor=ex,
                    fault_plan=ENG.FaultPlan(group_dead_at={1: 0}),
                    fault_policy=pol)
    assert res.group_stats["n_quarantined"] == 1
    assert ("group", "quarantined") in {(f.kind, f.action)
                                        for f in res.faults}
    assert res.rmse == clean.rmse
    np.testing.assert_array_equal(np.asarray(res.U_agg.eta),
                                  np.asarray(clean.U_agg.eta))
    np.testing.assert_array_equal(np.asarray(res.V_agg.Lambda),
                                  np.asarray(clean.V_agg.Lambda))
    _assert_group_trace_clean(ex, part, f"{name}-group-dead")


@needs_two
@pytest.mark.parametrize("name", GROUP_EXECUTORS)
def test_group_dead_min_groups_breach_raises(conf_run, grouped_clean, name,
                                             tmp_path):
    """Quarantine below ``min_groups`` flushes a checkpoint and raises
    ``TopologyDegradedError`` naming the dead groups — and the flushed
    directory resumes cleanly on a healthy topology."""
    part, cfg, test, key, _ = conf_run
    pol = ENG.FaultPolicy(timeout_floor_s=1.0, timeout_slack=0.0,
                          quarantine_after=1, min_groups=2, max_retries=5)
    d = tmp_path / "ckpt"
    with pytest.raises(ENG.TopologyDegradedError, match="group"):
        PP.run_pp(key, part, cfg, test, executor=_grouped(name),
                  fault_plan=ENG.FaultPlan(group_dead_at={1: 0}),
                  fault_policy=pol, checkpoint_dir=d)
    dead = None
    try:
        PP.run_pp(key, part, cfg, test, executor=_grouped(name),
                  fault_plan=ENG.FaultPlan(group_dead_at={1: 0}),
                  fault_policy=pol)
    except ENG.TopologyDegradedError as e:
        dead = e.dead_groups
    assert dead == (1,)
    assert (d / "meta.json").exists()
    # resume on the survivor sub-topology named by the error
    from repro.core.topology import Topology
    survivor = Topology(block=2, data=1,
                        devices=tuple(jax.devices()[:2])).without_groups(dead)
    assert survivor.block == 1 and survivor.devices[0] == jax.devices()[0]
    ex2 = (ENG.StreamingExecutor(window=2, topology=survivor)
           if name == "streaming" else ENG.AsyncExecutor(topology=survivor))
    res = PP.run_pp(key, part, cfg, test, executor=ex2, resume_from=d)
    assert res.rmse == grouped_clean[name].rmse


@needs_two
@pytest.mark.parametrize("name", GROUP_EXECUTORS)
def test_group_dead_continue_on_survivors(conf_run, grouped_clean, name):
    """``on_group_fault='continue'`` keeps the run alive below
    ``min_groups``: the survivors finish the graph bitwise-identically."""
    part, cfg, test, key, _ = conf_run
    pol = ENG.FaultPolicy(timeout_floor_s=1.0, timeout_slack=0.0,
                          quarantine_after=1, min_groups=2,
                          on_group_fault="continue", max_retries=5)
    res = PP.run_pp(key, part, cfg, test, executor=_grouped(name),
                    fault_plan=ENG.FaultPlan(group_dead_at={1: 0}),
                    fault_policy=pol)
    assert res.group_stats["n_quarantined"] == 1
    assert res.rmse == grouped_clean[name].rmse


@needs_two
@pytest.mark.parametrize("name", GROUP_EXECUTORS)
def test_group_slow_speculative_winner_deterministic(conf_run,
                                                     grouped_clean, name):
    """A straggling group's dispatches are twinned on the idle group
    with the same attempt-0 key; resolution commits the canonical-group
    winner, so rerunning with the PHYSICAL device order permuted (same
    logical groups) commits bitwise-identical numbers."""
    part, cfg, test, key, _ = conf_run
    clean = grouped_clean[name]
    pol = ENG.FaultPolicy(timeout_floor_s=60.0, timeout_slack=0.0,
                          speculate_at=2.0)
    plan = ENG.FaultPlan(group_slow_at={1: (0, 1.5)})
    for permute in (False, True):
        ex = _grouped(name, permute=permute, record_trace=True)
        res = PP.run_pp(key, part, cfg, test, executor=ex,
                        fault_plan=plan, fault_policy=pol)
        assert res.group_stats["n_speculations"] >= 1, res.group_stats
        assert res.group_stats["n_cancels"] >= 1, res.group_stats
        assert res.rmse == clean.rmse
        np.testing.assert_array_equal(np.asarray(res.U_agg.eta),
                                      np.asarray(clean.U_agg.eta))
        np.testing.assert_array_equal(np.asarray(res.V_agg.Lambda),
                                      np.asarray(clean.V_agg.Lambda))
        _assert_group_trace_clean(ex, part,
                                  f"{name}-speculate-permute{permute}")


@needs_two
@pytest.mark.parametrize("name", GROUP_EXECUTORS)
def test_group_steal_resolves_exactly_once(conf_run, grouped_clean, name):
    """With ``depth=1`` (and window=1 for streaming — single-block
    chunks, so the straggler's prefetch slot holds stealable work) the
    groups hold staged shares; an idle group steals from the most-loaded
    one. Every block still resolves exactly once and the numbers stay
    bitwise."""
    import collections
    part, cfg, test, key, _ = conf_run
    kw = {"window": 1} if name == "streaming" else {}
    # like-for-like fault-free reference (window=1 chunks recompile, so
    # this also warms them before the faulted run)
    clean = (PP.run_pp(key, part, cfg, test, executor=_grouped(name, **kw))
             if kw else grouped_clean[name])
    pol = ENG.FaultPolicy(timeout_floor_s=60.0, timeout_slack=0.0)
    ex = _grouped(name, record_trace=True, depth=1, **kw)
    res = PP.run_pp(key, part, cfg, test, executor=ex,
                    fault_plan=ENG.FaultPlan(group_slow_at={1: (0, 1.0)}),
                    fault_policy=pol)
    assert res.group_stats["n_steals"] >= 1, res.group_stats
    resolves = collections.Counter(c for ev, c, *_ in ex.trace
                                   if ev == "resolve")
    graph = {t.coord for _, ts in ENG.build_phase_graph(part) for t in ts}
    assert set(resolves) == graph
    assert set(resolves.values()) == {1}     # exactly once, stolen or not
    assert res.rmse == clean.rmse
    np.testing.assert_array_equal(np.asarray(res.U_agg.eta),
                                  np.asarray(clean.U_agg.eta))
    _assert_group_trace_clean(ex, part, f"{name}-steal")


@pytest.mark.skipif(len(jax.devices()) < 4,
                    reason="topology switch needs 4 devices")
def test_resume_across_topology_switch(conf_run, tmp_path):
    """Checkpoint meta records run IDENTITY only, not placement: a run
    checkpointed under a 4x1 topology resumes under 2x1 bitwise (same
    per-block math), and a complete 4x1 directory restores wholesale
    under 2x2 data-sharded groups."""
    from repro.core.topology import Topology
    part, cfg, test, key, _ = conf_run
    clean21 = PP.run_pp(key, part, cfg, test,
                        executor=ENG.AsyncExecutor(topology=Topology(2, 1)))
    d = tmp_path / "ckpt41"
    with pytest.raises(ENG.BlockFaultError):
        PP.run_pp(key, part, cfg, test,
                  executor=ENG.AsyncExecutor(topology=Topology(4, 1)),
                  checkpoint_dir=d,
                  fault_plan=ENG.FaultPlan(fail_dispatch_at={(1, 2): 99}),
                  max_retries=0, on_fault="raise")
    n_saved = len(list(d.glob("block_*.npz")))
    assert 0 < n_saved < part.I * part.J     # genuinely mid-graph
    res = PP.run_pp(key, part, cfg, test,
                    executor=ENG.AsyncExecutor(topology=Topology(2, 1)),
                    resume_from=d)
    assert res.resumed_blocks == n_saved
    assert res.rmse == clean21.rmse          # bitwise across the switch
    np.testing.assert_array_equal(np.asarray(res.U_agg.eta),
                                  np.asarray(clean21.U_agg.eta))
    full = tmp_path / "full41"
    ref41 = PP.run_pp(key, part, cfg, test,
                      executor=ENG.AsyncExecutor(topology=Topology(4, 1)),
                      checkpoint_dir=full)
    res22 = PP.run_pp(key, part, cfg, test,
                      executor=ENG.AsyncExecutor(topology=Topology(2, 2)),
                      resume_from=full)
    assert res22.resumed_blocks == part.I * part.J
    assert res22.rmse == ref41.rmse          # nothing recomputed


# ---------------------------------------------------------------------------
# aggregation under non-finite posteriors: why the guard sits BEFORE it
# ---------------------------------------------------------------------------


def test_aggregate_axis_propagates_nonfinite(conf_run):
    """``pp._aggregate_axis`` is a plain linear reduction: one NaN'd block
    posterior poisons the whole factor. That is exactly why the engine's
    health guard runs at block resolution, before the store — this test
    pins the division of labor."""
    part, cfg, _, _, _ = conf_run
    K = cfg.K
    posts = [[RowGaussians(
        eta=jnp.zeros((len(part.block(i, j).row_ids), K)),
        Lambda=jnp.broadcast_to(jnp.eye(K),
                                (len(part.block(i, j).row_ids), K, K)))
        for j in range(part.J)] for i in range(part.I)]
    clean = PP._aggregate_axis(part, posts, axis="row")
    assert np.isfinite(np.asarray(clean.eta)).all()
    posts[1][1] = RowGaussians(eta=posts[1][1].eta.at[0, 0].set(jnp.nan),
                               Lambda=posts[1][1].Lambda)
    dirty = PP._aggregate_axis(part, posts, axis="row")
    assert not np.isfinite(np.asarray(dirty.eta)).all()


def test_rmse_aggregation_guarded_from_nonfinite(conf_run):
    """End to end: a poisoned block under 'degrade' reaches neither the
    RMSE sum nor the factor aggregation — both stay finite while the raw
    injected chain demonstrably goes non-finite (health=False)."""
    part, cfg, test, key, _ = conf_run
    plan = ENG.FaultPlan(nan_at={(1, 1): 99})
    # the injected chain really is non-finite at the gibbs level
    task = [t for _, ts in ENG.build_phase_graph(part) for t in ts
            if t.coord == (1, 1)][0]
    test_p = apply_permutation(test, part.row_perm, part.col_perm)
    keys = jax.random.split(key, part.I * part.J).reshape(part.I, part.J)
    ctx = ENG.PhaseContext(part=part, cfg=cfg, test_p=test_p, keys=keys,
                           shapes=PP.BlockShapes.per_phase(part, test_p),
                           fault_plan=plan)
    ctx.U_posts[(1, 0)] = _uniform_prior(part, 1, 0, cfg.K, rows=True)
    ctx.V_posts[(0, 1)] = _uniform_prior(part, 0, 1, cfg.K, rows=False)
    raw = ENG._run_block_attempt(ctx, task, attempt=0)
    assert not bool(np.asarray(raw.health))
    assert ENG._fault_kind(ctx, task, raw) == "nonfinite"
    # ...and the guarded run never lets it out
    res = PP.run_pp(key, part, cfg, test, executor="serial",
                    fault_plan=plan, on_fault="degrade", max_retries=0)
    assert np.isfinite(res.rmse)
    assert np.isfinite(np.asarray(res.U_agg.eta)).all()


def _uniform_prior(part, i, j, K, rows):
    blk = part.block(i, j)
    n = len(blk.row_ids) if rows else len(blk.col_ids)
    return RowGaussians(eta=jnp.zeros((n, K)),
                        Lambda=jnp.broadcast_to(jnp.eye(K), (n, K, K)))


def test_rmse_divergence_threshold_trips(conf_run):
    """rmse_max treats a finite-but-diverged block as faulty."""
    part, cfg, test, key, _ = conf_run
    pol = ENG.FaultPolicy(rmse_max=1e-6, on_fault="degrade", max_retries=0)
    res = PP.run_pp(key, part, cfg, test, executor="serial",
                    fault_policy=pol)
    assert res.faults
    assert all(f.kind == "rmse" for f in res.faults)
    assert np.isfinite(res.rmse)


# ---------------------------------------------------------------------------
# input validation: actionable errors naming the offending argument
# ---------------------------------------------------------------------------


def test_validation_errors(conf_run, tmp_path):
    part, cfg, test, key, _ = conf_run
    with pytest.raises(ValueError, match="window"):
        ENG.make_executor("streaming", window=0)
    with pytest.raises(ValueError, match="window"):
        ENG.StreamingExecutor(window=-3)
    with pytest.raises(ValueError, match="depth"):
        ENG.StreamingExecutor(depth=0)
    with pytest.raises(ValueError, match="max_retries"):
        PP.run_pp(key, part, cfg, test, max_retries=-1)
    with pytest.raises(ValueError, match="on_fault"):
        PP.run_pp(key, part, cfg, test, on_fault="panic")
    with pytest.raises(ValueError, match="ckpt_every"):
        PP.run_pp(key, part, cfg, test, ckpt_every=0)
    with pytest.raises(ValueError, match="max_retries"):
        ENG.FaultPolicy(max_retries=-2)
    with pytest.raises(ValueError, match="on_fault"):
        ENG.FaultPolicy(on_fault="ignore")
    from repro.checkpoint.ckpt import PPCheckpoint
    with pytest.raises(ValueError, match="ckpt_every"):
        PPCheckpoint(tmp_path / "x", every=0)
    from repro.core.topology import Topology
    with pytest.raises(ValueError, match="axes"):
        Topology(block=0, data=1)
    with pytest.raises(ValueError, match="devices"):
        Topology(block=2, data=2, devices=tuple(jax.devices()[:1]))


def test_fault_plan_is_deterministic():
    plan = ENG.FaultPlan(nan_at={(1, 1): 2}, hang_at={(0, 2): 1})
    assert plan.nan((1, 1), 0) and plan.nan((1, 1), 1)
    assert not plan.nan((1, 1), 2)
    assert not plan.nan((2, 2), 0)
    assert plan.hang((0, 2), 0) and not plan.hang((0, 2), 1)
    assert not plan.fail((1, 1), 0)
