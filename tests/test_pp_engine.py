"""Phase-graph PP engine: graph structure, executor parity, aggregation
algebra, verbose reporting, and the occupancy-sorted partition wiring."""
import os
import subprocess
import sys
import textwrap
import types
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bmf as BMF
from repro.core import engine as ENG
from repro.core import posterior as POST
from repro.core import pp as PP
from repro.core.partition import partition
from repro.data import synthetic as SYN
from repro.data.sparse import train_test_split

ROOT = Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# graph structure
# ---------------------------------------------------------------------------


def _graph_for(I, J):
    part = types.SimpleNamespace(I=I, J=J)
    return ENG.build_phase_graph(part)


def test_phase_graph_covers_grid_once():
    for I, J in ((1, 1), (2, 2), (3, 2), (4, 4)):
        graph = _graph_for(I, J)
        coords = [t.coord for _, tasks in graph for t in tasks]
        assert len(coords) == I * J
        assert len(set(coords)) == I * J


def test_phase_graph_deps_precede():
    """Every task's deps must be scheduled in a strictly earlier phase —
    the invariant that makes within-phase execution embarrassingly
    parallel."""
    graph = _graph_for(3, 4)
    done = set()
    for _, tasks in graph:
        for t in tasks:
            assert set(t.deps) <= done, (t, done)
        done |= {t.coord for t in tasks}


def test_phase_graph_prior_sources():
    graph = dict(_graph_for(3, 3))
    (a,) = graph["a"]
    assert a.deps == ()
    for t in graph["b"]:
        assert t.deps == ((0, 0),)
        # first block-column propagates V, first block-row propagates U
        if t.j == 0:
            assert t.u_prior_from is None and t.v_prior_from == (0, 0)
        else:
            assert t.u_prior_from == (0, 0) and t.v_prior_from is None
    for t in graph["c"]:
        assert t.u_prior_from == (t.i, 0)
        assert t.v_prior_from == (0, t.j)


# ---------------------------------------------------------------------------
# aggregation algebra (satellite: divide-away exactness)
# ---------------------------------------------------------------------------


def _int_gaussians(rng, n, k, lo=-8, hi=8):
    """Integer-valued natural params: float32 adds/subtracts on small
    integers are exact, so the divide-away identity can be checked with
    zero tolerance."""
    return POST.RowGaussians(
        eta=jnp.asarray(rng.integers(lo, hi, (n, k)).astype(np.float32)),
        Lambda=jnp.asarray(rng.integers(lo, hi, (n, k, k)).astype(np.float32)))


@pytest.mark.parametrize("seed", range(5))
def test_aggregate_divides_away_priors_exactly(seed):
    """Qin et al. 2019 eq. 5: posts[i][j>=1] = prior_i * likelihood_ij in
    natural params; aggregation must return prior_i * prod_j likelihood_ij
    EXACTLY — the (J-1) multiply-counted prior copies are divided away."""
    rng = np.random.default_rng(seed)
    I, J, n, k = int(rng.integers(1, 4)), int(rng.integers(1, 4)), 5, 3
    part = types.SimpleNamespace(I=I, J=J)

    priors = [_int_gaussians(rng, n, k) for _ in range(I)]
    liks = [[_int_gaussians(rng, n, k) for _ in range(J)] for _ in range(I)]
    posts = [[priors[i] if j == 0 else POST.product(priors[i], liks[i][j])
              for j in range(J)] for i in range(I)]

    agg = PP._aggregate_axis(part, posts, axis="row")
    for i in range(I):
        expect = priors[i]
        for j in range(1, J):
            expect = POST.product(expect, liks[i][j])
        np.testing.assert_array_equal(
            np.asarray(agg.eta[i * n:(i + 1) * n]), np.asarray(expect.eta))
        np.testing.assert_array_equal(
            np.asarray(agg.Lambda[i * n:(i + 1) * n]),
            np.asarray(expect.Lambda))


def test_aggregate_col_axis_symmetry():
    rng = np.random.default_rng(11)
    I, J, n, k = 3, 2, 4, 2
    part = types.SimpleNamespace(I=I, J=J)
    priors = [_int_gaussians(rng, n, k) for _ in range(J)]
    liks = [[_int_gaussians(rng, n, k) for _ in range(J)] for _ in range(I)]
    posts = [[priors[j] if i == 0 else POST.product(priors[j], liks[i][j])
              for j in range(J)] for i in range(I)]
    agg = PP._aggregate_axis(part, posts, axis="col")
    for j in range(J):
        expect = priors[j]
        for i in range(1, I):
            expect = POST.product(expect, liks[i][j])
        np.testing.assert_array_equal(
            np.asarray(agg.eta[j * n:(j + 1) * n]), np.asarray(expect.eta))


# ---------------------------------------------------------------------------
# executor parity + verbose (satellite: serial == stacked under a fixed key)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def mini_run():
    coo, p = SYN.generate("mini", seed=3)
    train, test = train_test_split(coo, 0.15, seed=4)
    cfg = BMF.BMFConfig(K=p.K, n_samples=10, burnin=3)
    part = partition(train, 2, 2)
    return part, cfg, test


def test_serial_stacked_identical_rmse(mini_run):
    part, cfg, test = mini_run
    key = jax.random.key(1)
    r_ser = PP.run_pp(key, part, cfg, test, executor="serial")
    r_stk = PP.run_pp(key, part, cfg, test, executor="stacked")
    assert r_ser.executor == "serial" and r_stk.executor == "stacked"
    # identical keys + identical padding -> identical chains (up to batched
    # fp scheduling)
    assert abs(r_ser.rmse - r_stk.rmse) < 1e-5, (r_ser.rmse, r_stk.rmse)
    np.testing.assert_allclose(r_ser.per_block_rmse, r_stk.per_block_rmse,
                               atol=1e-4)
    # natural params are ill-conditioned (ridge-scale covariance inverses);
    # the aggregated posterior MEANS are the well-conditioned comparison
    np.testing.assert_allclose(np.asarray(r_ser.U_agg.mean),
                               np.asarray(r_stk.U_agg.mean),
                               atol=5e-3)
    assert r_ser.n_test == r_stk.n_test > 0
    assert set(r_ser.phase_times_s) == set(r_stk.phase_times_s) == {"a", "b", "c"}


def test_run_pp_verbose_reports_phases(mini_run, capsys):
    part, cfg, test = mini_run
    fast = cfg._replace(n_samples=2, burnin=0)
    PP.run_pp(jax.random.key(0), part, fast, test, executor="stacked",
              verbose=True)
    out = capsys.readouterr().out
    for phase in ("phase a", "phase b", "phase c"):
        assert phase in out, out
    assert "block(s)" in out and "[pp:stacked]" in out
    # shape buckets are reported
    assert "m=" in out


def test_executor_instance_and_unknown(mini_run):
    part, cfg, test = mini_run
    fast = cfg._replace(n_samples=2, burnin=0)
    res = PP.run_pp(jax.random.key(0), part, fast, test,
                    executor=ENG.StackedExecutor())
    assert res.executor == "stacked"
    with pytest.raises(ValueError):
        PP.run_pp(jax.random.key(0), part, fast, test, executor="warp")


def test_distributed_mesh_forces_serial():
    ex = ENG.make_executor("stacked", distributed_mesh=object())
    assert isinstance(ex, ENG.SerialExecutor)


# ---------------------------------------------------------------------------
# sharded executor (subprocess: needs a faked multi-device mesh)
# ---------------------------------------------------------------------------

SHARDED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax
    from repro.core import bmf as BMF, pp as PP
    from repro.core.partition import partition
    from repro.data import synthetic as SYN
    from repro.data.sparse import train_test_split

    coo, p = SYN.generate("mini", seed=3)
    train, test = train_test_split(coo, 0.15, seed=4)
    cfg = BMF.BMFConfig(K=p.K, n_samples=8, burnin=2)
    part = partition(train, 3, 2)
    key = jax.random.key(1)
    r_stk = PP.run_pp(key, part, cfg, test, executor="stacked")
    r_shd = PP.run_pp(key, part, cfg, test, executor="sharded")
    print(json.dumps({"stacked": r_stk.rmse, "sharded": r_shd.rmse,
                      "n_devices": len(jax.devices())}))
""")


@pytest.mark.slow
def test_sharded_matches_stacked():
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
    out = subprocess.run([sys.executable, "-c", SHARDED_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=500)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = __import__("json").loads(out.stdout.strip().splitlines()[-1])
    assert rec["n_devices"] == 4
    # same chains, sharded placement: parity (the 3x2 grid exercises both
    # uneven bucket padding — phase b has 3 blocks over 4 devices — and
    # multi-block-per-device batches)
    assert abs(rec["stacked"] - rec["sharded"]) < 1e-4, rec


# ---------------------------------------------------------------------------
# occupancy-sorted partition (satellite: data.sparse wiring)
# ---------------------------------------------------------------------------


def test_partition_occupancy_sorts_within_stripes():
    coo, _ = SYN.generate("mini", seed=5)
    part = partition(coo, 2, 2, occupancy_sort=True)
    from repro.data.sparse import apply_permutation
    pc = apply_permutation(coo, part.row_perm, part.col_perm)
    counts = np.bincount(pc.row, minlength=coo.n_rows)
    for lo, hi in zip(part.row_splits[:-1], part.row_splits[1:]):
        stripe = counts[lo:hi]
        assert (np.diff(stripe) <= 0).all(), stripe[:10]
    ccounts = np.bincount(pc.col, minlength=coo.n_cols)
    for lo, hi in zip(part.col_splits[:-1], part.col_splits[1:]):
        assert (np.diff(ccounts[lo:hi]) <= 0).all()


def test_partition_occupancy_preserves_balance_and_nnz():
    coo, _ = SYN.generate("mini", seed=6)
    from repro.core.partition import nnz_balance_stats
    p_sorted = partition(coo, 2, 2, occupancy_sort=True)
    p_plain = partition(coo, 2, 2, occupancy_sort=False)
    # stripe membership untouched -> identical per-block nnz balance
    assert nnz_balance_stats(p_sorted) == nnz_balance_stats(p_plain)
    assert sum(b.coo.nnz for b in p_sorted.all_blocks()) == coo.nnz
