"""Phase-graph PP engine: graph structure, executor parity, aggregation
algebra, verbose reporting, and the occupancy-sorted partition wiring."""
import os
import subprocess
import sys
import textwrap
import types
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import guards as GUARDS
from repro.core import bmf as BMF
from repro.core import engine as ENG
from repro.core import gibbs as GIBBS
from repro.core import posterior as POST
from repro.core import pp as PP
from repro.core.partition import partition
from repro.data import synthetic as SYN
from repro.data.sparse import train_test_split

ROOT = Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# graph structure
# ---------------------------------------------------------------------------


def _graph_for(I, J):
    part = types.SimpleNamespace(I=I, J=J)
    return ENG.build_phase_graph(part)


def test_phase_graph_covers_grid_once():
    for I, J in ((1, 1), (2, 2), (3, 2), (4, 4)):
        graph = _graph_for(I, J)
        coords = [t.coord for _, tasks in graph for t in tasks]
        assert len(coords) == I * J
        assert len(set(coords)) == I * J


def test_phase_graph_deps_precede():
    """Every task's deps must be scheduled in a strictly earlier phase —
    the invariant that makes within-phase execution embarrassingly
    parallel."""
    graph = _graph_for(3, 4)
    done = set()
    for _, tasks in graph:
        for t in tasks:
            assert set(t.deps) <= done, (t, done)
        done |= {t.coord for t in tasks}


def test_phase_graph_prior_sources():
    graph = dict(_graph_for(3, 3))
    (a,) = graph["a"]
    assert a.deps == ()
    for t in graph["b"]:
        assert t.deps == ((0, 0),)
        # first block-column propagates V, first block-row propagates U
        if t.j == 0:
            assert t.u_prior_from is None and t.v_prior_from == (0, 0)
        else:
            assert t.u_prior_from == (0, 0) and t.v_prior_from is None
    for t in graph["c"]:
        assert t.u_prior_from == (t.i, 0)
        assert t.v_prior_from == (0, t.j)


# ---------------------------------------------------------------------------
# aggregation algebra (satellite: divide-away exactness)
# ---------------------------------------------------------------------------


def _int_gaussians(rng, n, k, lo=-8, hi=8):
    """Integer-valued natural params: float32 adds/subtracts on small
    integers are exact, so the divide-away identity can be checked with
    zero tolerance."""
    return POST.RowGaussians(
        eta=jnp.asarray(rng.integers(lo, hi, (n, k)).astype(np.float32)),
        Lambda=jnp.asarray(rng.integers(lo, hi, (n, k, k)).astype(np.float32)))


@pytest.mark.parametrize("seed", range(5))
def test_aggregate_divides_away_priors_exactly(seed):
    """Qin et al. 2019 eq. 5: posts[i][j>=1] = prior_i * likelihood_ij in
    natural params; aggregation must return prior_i * prod_j likelihood_ij
    EXACTLY — the (J-1) multiply-counted prior copies are divided away."""
    rng = np.random.default_rng(seed)
    I, J, n, k = int(rng.integers(1, 4)), int(rng.integers(1, 4)), 5, 3
    part = types.SimpleNamespace(I=I, J=J)

    priors = [_int_gaussians(rng, n, k) for _ in range(I)]
    liks = [[_int_gaussians(rng, n, k) for _ in range(J)] for _ in range(I)]
    posts = [[priors[i] if j == 0 else POST.product(priors[i], liks[i][j])
              for j in range(J)] for i in range(I)]

    agg = PP._aggregate_axis(part, posts, axis="row")
    for i in range(I):
        expect = priors[i]
        for j in range(1, J):
            expect = POST.product(expect, liks[i][j])
        np.testing.assert_array_equal(
            np.asarray(agg.eta[i * n:(i + 1) * n]), np.asarray(expect.eta))
        np.testing.assert_array_equal(
            np.asarray(agg.Lambda[i * n:(i + 1) * n]),
            np.asarray(expect.Lambda))


def test_aggregate_col_axis_symmetry():
    rng = np.random.default_rng(11)
    I, J, n, k = 3, 2, 4, 2
    part = types.SimpleNamespace(I=I, J=J)
    priors = [_int_gaussians(rng, n, k) for _ in range(J)]
    liks = [[_int_gaussians(rng, n, k) for _ in range(J)] for _ in range(I)]
    posts = [[priors[j] if i == 0 else POST.product(priors[j], liks[i][j])
              for j in range(J)] for i in range(I)]
    agg = PP._aggregate_axis(part, posts, axis="col")
    for j in range(J):
        expect = priors[j]
        for i in range(1, I):
            expect = POST.product(expect, liks[i][j])
        np.testing.assert_array_equal(
            np.asarray(agg.eta[j * n:(j + 1) * n]), np.asarray(expect.eta))


# ---------------------------------------------------------------------------
# executor parity + verbose (satellite: serial == stacked under a fixed key)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def mini_run():
    coo, p = SYN.generate("mini", seed=3)
    train, test = train_test_split(coo, 0.15, seed=4)
    cfg = BMF.BMFConfig(K=p.K, n_samples=10, burnin=3)
    part = partition(train, 2, 2)
    return part, cfg, test


def test_serial_stacked_identical_rmse(mini_run):
    part, cfg, test = mini_run
    key = jax.random.key(1)
    r_ser = PP.run_pp(key, part, cfg, test, executor="serial")
    r_stk = PP.run_pp(key, part, cfg, test, executor="stacked")
    assert r_ser.executor == "serial" and r_stk.executor == "stacked"
    # identical keys + identical padding -> identical chains (up to batched
    # fp scheduling)
    assert abs(r_ser.rmse - r_stk.rmse) < 1e-5, (r_ser.rmse, r_stk.rmse)
    np.testing.assert_allclose(r_ser.per_block_rmse, r_stk.per_block_rmse,
                               atol=1e-4)
    # natural params are ill-conditioned (ridge-scale covariance inverses);
    # the aggregated posterior MEANS are the well-conditioned comparison
    np.testing.assert_allclose(np.asarray(r_ser.U_agg.mean),
                               np.asarray(r_stk.U_agg.mean),
                               atol=5e-3)
    assert r_ser.n_test == r_stk.n_test > 0
    assert set(r_ser.phase_times_s) == set(r_stk.phase_times_s) == {"a", "b", "c"}


def test_run_pp_verbose_reports_phases(mini_run, capsys):
    part, cfg, test = mini_run
    fast = cfg._replace(n_samples=2, burnin=0)
    PP.run_pp(jax.random.key(0), part, fast, test, executor="stacked",
              verbose=True)
    out = capsys.readouterr().out
    for phase in ("phase a", "phase b", "phase c"):
        assert phase in out, out
    assert "block(s)" in out and "[pp:stacked]" in out
    # shape buckets are reported
    assert "m=" in out


def test_executor_instance_and_unknown(mini_run):
    part, cfg, test = mini_run
    fast = cfg._replace(n_samples=2, burnin=0)
    res = PP.run_pp(jax.random.key(0), part, fast, test,
                    executor=ENG.StackedExecutor())
    assert res.executor == "stacked"
    with pytest.raises(ValueError):
        PP.run_pp(jax.random.key(0), part, fast, test, executor="warp")


def test_distributed_mesh_forces_serial():
    ex = ENG.make_executor("stacked", distributed_mesh=object())
    assert isinstance(ex, ENG.SerialExecutor)


def test_window_with_instance_rejected():
    """window= only configures the named streaming executor; silently
    dropping it on a pre-built instance would hand the user a different
    window than they asked for."""
    assert ENG.make_executor("streaming", window=7).window == 7
    with pytest.raises(ValueError):
        ENG.make_executor(ENG.StreamingExecutor(), window=7)


def test_executor_instances_reusable_across_runs(mini_run):
    """run_graph resets per-run state: reusing one instance (warmup +
    timed runs) must not accumulate traces or peak counters."""
    part, cfg, test = mini_run
    fast = cfg._replace(n_samples=2, burnin=0)
    key = jax.random.key(3)
    ex = ENG.AsyncExecutor(record_trace=True)
    PP.run_pp(key, part, fast, test, executor=ex)
    n_events = len(ex.trace)
    PP.run_pp(key, part, fast, test, executor=ex)
    assert len(ex.trace) == n_events == 2 * part.I * part.J
    st = ENG.StreamingExecutor(window=2, record_trace=True)
    PP.run_pp(key, part, fast, test, executor=st)
    assert len(st.trace) == 2 * part.I * part.J
    first_peak = st.peak_window_blocks
    PP.run_pp(key, part, fast, test, executor=st)
    assert st.peak_window_blocks == first_peak
    assert len(st.trace) == 2 * part.I * part.J


def test_grouped_ready_queue_chunks_by_group():
    groups = {(0, 0): "a", (1, 0): "a", (2, 0): "a", (0, 1): "b",
              (1, 1): "b"}
    prio = {(0, 0): 1.0, (1, 0): 3.0, (2, 0): 2.0, (0, 1): 9.0,
            (1, 1): 8.0}
    q = ENG._GroupedReadyQueue(prio, groups.__getitem__)
    for c in groups:
        q.push(c)
    # lead = highest priority overall; chunk filled from ITS group only,
    # in priority order — other groups untouched
    assert q.pop_chunk(3) == [(0, 1), (1, 1)]
    assert len(q) == 3
    assert q.pop_chunk(2) == [(1, 0), (2, 0)]
    assert q.pop_chunk(2) == [(0, 0)]
    assert not q


# ---------------------------------------------------------------------------
# async executor (tentpole: dependency-driven overlap of phases b/c)
# ---------------------------------------------------------------------------


def test_serial_async_identical_rmse(mini_run):
    part, cfg, test = mini_run
    key = jax.random.key(1)
    r_ser = PP.run_pp(key, part, cfg, test, executor="serial")
    r_asy = PP.run_pp(key, part, cfg, test, executor="async")
    assert r_asy.executor == "async"
    assert abs(r_ser.rmse - r_asy.rmse) < 1e-5, (r_ser.rmse, r_asy.rmse)
    np.testing.assert_allclose(r_ser.per_block_rmse, r_asy.per_block_rmse,
                               atol=1e-4)
    # same bucketed per-block executables, same keys -> the device-resident
    # aggregation is BIT-identical to the serial reference
    np.testing.assert_array_equal(np.asarray(r_ser.U_agg.eta),
                                  np.asarray(r_asy.U_agg.eta))
    np.testing.assert_array_equal(np.asarray(r_ser.V_agg.Lambda),
                                  np.asarray(r_asy.V_agg.Lambda))
    # aggregated posteriors never left the device
    assert isinstance(r_asy.U_agg.eta, jax.Array)
    # overlapped run records dispatch→resolve spans for every block
    coords = {(i, j) for i in range(part.I) for j in range(part.J)}
    assert set(r_asy.block_spans_s) == coords
    for td, tr in r_asy.block_spans_s.values():
        assert 0.0 <= td <= tr
    assert set(r_asy.phase_times_s) == {"a", "b", "c"}


class _ShuffledAsync(ENG.AsyncExecutor):
    """Fake-delay executor: each completion poll flips a seeded coin per
    in-flight block, deferring its OBSERVED resolution even when the device
    finished long ago — randomizing the completion order the scheduler
    reacts to (the fallback path force-resolves the oldest in-flight block,
    so progress is always made)."""

    def __init__(self, seed, **kw):
        super().__init__(record_trace=True, **kw)
        self._rng = np.random.default_rng(seed)

    def _is_resolved(self, coord, signal):
        return bool(self._rng.random() < 0.4) and signal.is_ready()


@pytest.fixture(scope="module")
def mini_3x3():
    coo, p = SYN.generate("mini", seed=3)
    train, test = train_test_split(coo, 0.15, seed=4)
    cfg = BMF.BMFConfig(K=p.K, n_samples=4, burnin=1)
    part = partition(train, 3, 3)
    key = jax.random.key(2)
    r_ser = PP.run_pp(key, part, cfg, test, executor="serial")
    return part, cfg, test, key, r_ser


@pytest.mark.parametrize("seed", range(3))
def test_async_completion_order_stress(mini_3x3, seed):
    """Randomized completion order must never let a block dispatch before
    both its prior sources resolved, and the divide-away aggregation must
    stay bit-identical to the serial reference regardless of order."""
    part, cfg, test, key, r_ser = mini_3x3
    ex = _ShuffledAsync(seed)
    r_asy = PP.run_pp(key, part, cfg, test, executor=ex)

    graph = {t.coord: t for _, ts in ENG.build_phase_graph(part) for t in ts}
    resolved = set()
    dispatched = set()
    for ev, c, *_ in ex.trace:
        if ev == "dispatch":
            assert set(graph[c].deps) <= resolved, \
                f"{c} dispatched before deps {graph[c].deps} resolved"
            dispatched.add(c)
        else:
            assert c in dispatched
            resolved.add(c)
    assert resolved == set(graph)          # every block ran exactly once
    assert len(ex.trace) == 2 * len(graph)

    np.testing.assert_array_equal(np.asarray(r_ser.U_agg.eta),
                                  np.asarray(r_asy.U_agg.eta))
    np.testing.assert_array_equal(np.asarray(r_ser.V_agg.eta),
                                  np.asarray(r_asy.V_agg.eta))
    assert abs(r_ser.rmse - r_asy.rmse) < 1e-5


# ---------------------------------------------------------------------------
# streaming executor (tentpole: bounded window for out-of-memory grids)
# ---------------------------------------------------------------------------


def test_streaming_window_bounds_live_blocks(mini_3x3):
    """The streaming executor's realized live-buffer bound: at no point do
    more than window x (depth + 1) blocks' worth of input buffers exist
    (in-flight chunks + the prefetched one) — the property that lets
    grids with num_blocks x block_bytes >> HBM run at a flat peak."""
    part, cfg, test, key, r_ser = mini_3x3
    ex = ENG.StreamingExecutor(window=2, depth=2)
    r_str = PP.run_pp(key, part, cfg, test, executor=ex)
    assert 0 < ex.peak_window_blocks <= 2 * (2 + 1)
    assert abs(r_str.rmse - r_ser.rmse) < 1e-5
    # 9 blocks through windows of 2: at least 5 chunks => the bound binds
    assert ex.peak_window_blocks < part.I * part.J


def test_streaming_chunks_record_spans_and_phases(mini_3x3):
    part, cfg, test, key, _ = mini_3x3
    r = PP.run_pp(key, part, cfg, test, executor="streaming", window=3)
    coords = {(i, j) for i in range(part.I) for j in range(part.J)}
    assert set(r.block_spans_s) == coords
    for td, tr in r.block_spans_s.values():
        assert 0.0 <= td <= tr
    assert set(r.phase_times_s) == {"a", "b", "c"}
    assert r.executor == "streaming"


def test_streaming_coalesced_buckets_still_sample(mini_3x3):
    """max_waste > 1 merges phase buckets into fewer window shapes (the
    one-window-shape-serves-many-blocks lever). Padding then differs from
    the reference buckets, so chains are DIFFERENT (the NW hyper-resample
    sees the padded rows) but must remain a valid sampler: RMSE stays in
    the same range, and every phase tag maps to a coalesced shape that
    dominates its own bucket."""
    part, cfg, test, key, r_ser = mini_3x3
    ex = ENG.StreamingExecutor(window=2, max_waste=4.0)
    r = PP.run_pp(key, part, cfg, test, executor=ex)
    shapes = PP.BlockShapes.per_phase(
        part, None)  # row/col/m dims don't depend on the test split
    n_groups = len({id(s) for s in ex.window_shapes.values()})
    assert n_groups < len(ex.window_shapes)       # something coalesced
    for tag, merged in ex.window_shapes.items():
        assert merged.n_rows >= shapes[tag].n_rows
        assert merged.n_cols >= shapes[tag].n_cols
        assert merged.m_rows >= shapes[tag].m_rows
    assert abs(r.rmse - r_ser.rmse) < 0.15        # same model, other draws


def test_stacked_prior_use_flags_bit_match_dedicated():
    """gibbs.run_gibbs_stacked(prior_use=...): a flagged chunk mixing
    with-prior and without-prior blocks must reproduce the DEDICATED
    stacked executables (fixed-prior pytree / no-prior pytree) bit-exactly
    per block — the invariant that lets one streaming window executable
    serve every phase tag. (Comparison is stacked-vs-stacked: the single-
    block executable differs in benign vmap fp scheduling.)"""
    from repro.core.posterior import RowGaussians
    from repro.data.sparse import PaddedCSR, coo_to_padded_csr

    coo, p = SYN.generate("mini", seed=21)
    csr_r = coo_to_padded_csr(coo)
    csr_c = coo_to_padded_csr(coo.transpose())
    cfg = BMF.BMFConfig(K=4, n_samples=3, burnin=1)
    keys = jax.random.split(jax.random.key(9), 2)
    rng = np.random.default_rng(5)
    prior_u = RowGaussians(
        eta=jnp.asarray(rng.normal(size=(coo.n_rows, 4)).astype(np.float32)),
        Lambda=jnp.broadcast_to(2.0 * jnp.eye(4), (coo.n_rows, 4, 4)))
    prior_v = RowGaussians(
        eta=jnp.asarray(rng.normal(size=(coo.n_cols, 4)).astype(np.float32)),
        Lambda=jnp.broadcast_to(3.0 * jnp.eye(4), (coo.n_cols, 4, 4)))

    def stack2(csr):
        return PaddedCSR(idx=jnp.stack([csr.idx] * 2),
                         val=jnp.stack([csr.val] * 2),
                         mask=jnp.stack([csr.mask] * 2), n_cols=csr.n_cols)

    tr2 = jnp.zeros((2, 6), jnp.int32)
    both = jax.tree.map(lambda x: jnp.stack([x] * 2), (prior_u, prior_v))
    ded_with = GIBBS.run_gibbs_stacked(keys, stack2(csr_r), stack2(csr_c),
                                       tr2, tr2, cfg, U_prior=both[0],
                                       V_prior=both[1])
    ded_wo = GIBBS.run_gibbs_stacked(keys, stack2(csr_r), stack2(csr_c),
                                     tr2, tr2, cfg)

    # flagged mixed chunk: block 0 fixed priors, block 1 NW hyperprior
    # (dummy zero rows where the flag is off)
    mixed = jax.tree.map(lambda x: jnp.stack([x, jnp.zeros_like(x)]),
                         (prior_u, prior_v))
    res = GIBBS.run_gibbs_stacked(
        keys, stack2(csr_r), stack2(csr_c), tr2, tr2, cfg,
        U_prior=mixed[0], V_prior=mixed[1],
        prior_use=(jnp.asarray([1.0, 0.0]), jnp.asarray([1.0, 0.0])))
    np.testing.assert_array_equal(np.asarray(res.U[0]),
                                  np.asarray(ded_with.U[0]))
    np.testing.assert_array_equal(np.asarray(res.U_post.eta[0]),
                                  np.asarray(ded_with.U_post.eta[0]))
    np.testing.assert_array_equal(np.asarray(res.U[1]),
                                  np.asarray(ded_wo.U[1]))
    np.testing.assert_array_equal(np.asarray(res.V_post.eta[1]),
                                  np.asarray(ded_wo.V_post.eta[1]))


# ---------------------------------------------------------------------------
# critical-path-first priority dispatch (tentpole: ready-queue ordering)
# ---------------------------------------------------------------------------


def test_critical_path_priority_bottom_levels():
    graph = {t.coord: t for _, ts in _graph_for(3, 3) for t in ts}
    est = {c: 1.0 for c in graph}
    est[(1, 0)] = 5.0                      # heavy phase-b row source
    prio = ENG.critical_path_priority(graph, est)
    # bottom levels: interior = own cost; b blocks add their successors'
    # longest chain; (0,0) tops everything
    assert prio[(1, 1)] == pytest.approx(1.0)
    assert prio[(1, 0)] == pytest.approx(6.0)    # 5 + deepest c successor
    assert prio[(0, 1)] == pytest.approx(2.0)
    assert prio[(0, 0)] == pytest.approx(1.0 + 6.0)
    # heavy source outranks every other phase-b block
    assert prio[(1, 0)] > max(prio[c] for c in ((2, 0), (0, 1), (0, 2)))


def test_ready_queue_orders_by_priority_fifo_ties():
    q = ENG._ReadyQueue({(0, 0): 1.0, (1, 0): 5.0, (0, 1): 5.0,
                         (1, 1): 0.0})
    for c in ((0, 0), (1, 0), (0, 1), (1, 1)):
        q.push(c)
    # descending priority, FIFO among ties
    assert [q.pop() for _ in range(len(q))] == \
        [(1, 0), (0, 1), (0, 0), (1, 1)]
    # and without priorities it degenerates to pure FIFO
    q2 = ENG._ReadyQueue(None)
    for c in ((2, 2), (0, 0), (1, 1)):
        q2.push(c)
    assert [q2.pop() for _ in range(3)] == [(2, 2), (0, 0), (1, 1)]


def test_async_priority_dispatch_order(mini_3x3):
    """With priorities on, the async scheduler drains the phase-b ready
    set critical-path-first: dispatch order of phase-b blocks follows
    descending bottom-level (nnz-weighted)."""
    part, cfg, test, key, r_ser = mini_3x3
    ex = ENG.AsyncExecutor(record_trace=True, priority=True)
    r = PP.run_pp(key, part, cfg, test, executor=ex)
    assert abs(r.rmse - r_ser.rmse) < 1e-5
    graph = {t.coord: t for _, ts in ENG.build_phase_graph(part) for t in ts}
    est = {c: float(part.block(*c).coo.nnz + 1) for c in graph}
    prio = ENG.critical_path_priority(graph, est)
    b_coords = [c for c in graph if graph[c].phase in ("b_row", "b_col")]
    order = [c for ev, c, *_ in ex.trace
             if ev == "dispatch" and c in b_coords]
    # phase b becomes ready all at once (single dep on (0,0)), so its
    # dispatch order is exactly the priority order
    assert order == sorted(b_coords, key=lambda c: -prio[c])


# ---------------------------------------------------------------------------
# device-resident aggregation (satellite: no host transfers mid-run)
# ---------------------------------------------------------------------------


def _device_posts(rng, I, J, n, k):
    return [[POST.RowGaussians(
        eta=jnp.asarray(rng.normal(size=(n, k)).astype(np.float32)),
        Lambda=jnp.asarray(rng.normal(size=(n, k, k)).astype(np.float32)))
        for _ in range(J)] for _ in range(I)]


def test_aggregate_axis_no_host_transfers():
    """_aggregate_axis is ONE jitted reduction over device-resident
    posteriors: running it under analysis.guards.no_host_transfers() proves no
    host round-trip happens mid-run (any implicit device↔host copy would
    raise)."""
    rng = np.random.default_rng(7)
    I, J, n, k = 2, 3, 4, 3
    part = types.SimpleNamespace(I=I, J=J)
    posts = _device_posts(rng, I, J, n, k)
    jax.block_until_ready(PP._aggregate_axis(part, posts, axis="row"))  # warm
    with GUARDS.no_host_transfers():
        agg = PP._aggregate_axis(part, posts, axis="row")
    jax.block_until_ready(agg)
    assert isinstance(agg.eta, jax.Array)


def test_aggregate_axis_jaxpr_no_blowup():
    """PR-1 idiom (roofline.jaxpr_cost.iter_avals): the jitted divide-away
    reduction may not materialize anything beyond the stacked input — its
    largest aval is exactly the (J, n, K, K) per-group Lambda stack."""
    from repro.roofline.jaxpr_cost import iter_avals, jaxpr_cost

    rng = np.random.default_rng(8)
    I, J, n, k = 3, 4, 5, 3
    posts = tuple(tuple(row) for row in _device_posts(rng, I, J, n, k))
    jaxpr = jax.make_jaxpr(
        lambda p: PP._aggregate_axis_jit(p, "row"))(posts)
    cap = J * n * k * k           # one row-group's stacked Lambda leaves
    assert max(int(np.prod(a.shape)) for a in iter_avals(jaxpr)
               if a.shape) <= cap
    # and it is pure arithmetic: FLOPs bounded by a few passes over inputs
    cost = jaxpr_cost(jaxpr)
    assert cost["flops"] <= 16 * I * J * n * k * k


# ---------------------------------------------------------------------------
# donation (satellite: padded input buffers are donated to XLA)
# ---------------------------------------------------------------------------


def test_run_gibbs_donation_matches_and_aliases():
    """donate=True must not change the chain (same executable semantics)
    and must alias U0/V0 onto the U/V outputs — the donated initializations
    are invalidated at dispatch."""
    from repro.data.sparse import coo_to_padded_csr

    coo, p = SYN.generate("mini", seed=9)
    csr_r = coo_to_padded_csr(coo)
    csr_c = coo_to_padded_csr(coo.transpose())
    cfg = BMF.BMFConfig(K=4, n_samples=3, burnin=1)
    tr = jnp.zeros((5,), jnp.int32)
    tc = jnp.zeros((5,), jnp.int32)
    from repro.core import bmf as BMFmod
    key = jax.random.key(3)
    U0, V0 = BMFmod.init_factors(jax.random.key(4), csr_r.n_rows,
                                 csr_c.n_rows, cfg.K)
    ref = GIBBS.run_gibbs(key, csr_r, csr_c, tr, tc, cfg,
                          U0=U0, V0=V0, donate=False)

    U0d, V0d = BMFmod.init_factors(jax.random.key(4), csr_r.n_rows,
                                   csr_c.n_rows, cfg.K)
    don = GIBBS.run_gibbs(key, csr_r, csr_c, tr, tc, cfg,
                          U0=U0d, V0=V0d, donate=True)
    assert U0d.is_deleted() and V0d.is_deleted()   # aliased in place
    np.testing.assert_array_equal(np.asarray(ref.U), np.asarray(don.U))
    np.testing.assert_array_equal(np.asarray(ref.U_post.eta),
                                  np.asarray(don.U_post.eta))


# ---------------------------------------------------------------------------
# timing semantics (satellite: critical path, not even bucket splits)
# ---------------------------------------------------------------------------


def test_modeled_parallel_is_dependency_aware():
    res = PP.PPResult(
        rmse=0.0, U_agg=None, V_agg=None, per_block_rmse=np.zeros((2, 2)),
        wall_time_s=0.0, phase_times_s={}, n_test=0,
        block_times_s={(0, 0): 1.0, (1, 0): 2.0, (0, 1): 3.0, (1, 1): 1.0})
    # longest chain: (0,0) -> (0,1) -> (1,1) = 1 + 3 + 1
    assert res.critical_path_s() == pytest.approx(5.0)
    # enough workers: b blocks overlap, c starts when BOTH its sources are
    # done (not at a phase barrier) -> equals the critical path
    assert res.modeled_parallel_s(16) == pytest.approx(5.0)
    # one worker degenerates to the serial sum
    assert res.modeled_parallel_s(1) == pytest.approx(7.0)


# ---------------------------------------------------------------------------
# sharded executor (subprocess: needs a faked multi-device mesh)
# ---------------------------------------------------------------------------

SHARDED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax
    from repro.core import bmf as BMF, pp as PP
    from repro.core.partition import partition
    from repro.data import synthetic as SYN
    from repro.data.sparse import train_test_split

    coo, p = SYN.generate("mini", seed=3)
    train, test = train_test_split(coo, 0.15, seed=4)
    cfg = BMF.BMFConfig(K=p.K, n_samples=8, burnin=2)
    part = partition(train, 3, 2)
    key = jax.random.key(1)
    r_stk = PP.run_pp(key, part, cfg, test, executor="stacked")
    r_shd = PP.run_pp(key, part, cfg, test, executor="sharded")
    print(json.dumps({"stacked": r_stk.rmse, "sharded": r_shd.rmse,
                      "n_devices": len(jax.devices())}))
""")


@pytest.mark.slow
def test_sharded_matches_stacked():
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
    out = subprocess.run([sys.executable, "-c", SHARDED_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=500)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = __import__("json").loads(out.stdout.strip().splitlines()[-1])
    assert rec["n_devices"] == 4
    # same chains, sharded placement: parity (the 3x2 grid exercises both
    # uneven bucket padding — phase b has 3 blocks over 4 devices — and
    # multi-block-per-device batches)
    assert abs(rec["stacked"] - rec["sharded"]) < 1e-4, rec


ASYNC_STREAMS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax
    from repro.core import bmf as BMF, pp as PP
    from repro.core.partition import partition
    from repro.data import synthetic as SYN
    from repro.data.sparse import train_test_split

    coo, p = SYN.generate("mini", seed=3)
    train, test = train_test_split(coo, 0.15, seed=4)
    cfg = BMF.BMFConfig(K=p.K, n_samples=6, burnin=2)
    part = partition(train, 3, 2)
    key = jax.random.key(1)
    r_ser = PP.run_pp(key, part, cfg, test, executor="serial")
    r_asy = PP.run_pp(key, part, cfg, test, executor="async")
    print(json.dumps({"serial": r_ser.rmse, "async": r_asy.rmse,
                      "n_devices": len(jax.devices())}))
""")


@pytest.mark.slow
def test_async_streams_on_faked_mesh():
    """Per-device streams: with 4 faked devices the async executor places
    each dispatch round-robin and device_puts propagated priors across
    streams — RMSE parity with serial must survive the placement."""
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
    out = subprocess.run([sys.executable, "-c", ASYNC_STREAMS_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=500)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = __import__("json").loads(out.stdout.strip().splitlines()[-1])
    assert rec["n_devices"] == 4
    assert abs(rec["serial"] - rec["async"]) < 1e-4, rec


# ---------------------------------------------------------------------------
# occupancy-sorted partition (satellite: data.sparse wiring)
# ---------------------------------------------------------------------------


def test_partition_occupancy_sorts_within_stripes():
    coo, _ = SYN.generate("mini", seed=5)
    part = partition(coo, 2, 2, occupancy_sort=True)
    from repro.data.sparse import apply_permutation
    pc = apply_permutation(coo, part.row_perm, part.col_perm)
    counts = np.bincount(pc.row, minlength=coo.n_rows)
    for lo, hi in zip(part.row_splits[:-1], part.row_splits[1:]):
        stripe = counts[lo:hi]
        assert (np.diff(stripe) <= 0).all(), stripe[:10]
    ccounts = np.bincount(pc.col, minlength=coo.n_cols)
    for lo, hi in zip(part.col_splits[:-1], part.col_splits[1:]):
        assert (np.diff(ccounts[lo:hi]) <= 0).all()


def test_partition_occupancy_preserves_balance_and_nnz():
    coo, _ = SYN.generate("mini", seed=6)
    from repro.core.partition import nnz_balance_stats
    p_sorted = partition(coo, 2, 2, occupancy_sort=True)
    p_plain = partition(coo, 2, 2, occupancy_sort=False)
    # stripe membership untouched -> identical per-block nnz balance
    assert nnz_balance_stats(p_sorted) == nnz_balance_stats(p_plain)
    assert sum(b.coo.nnz for b in p_sorted.all_blocks()) == coo.nnz
