"""Executor conformance suite — the registration battery for the
phase-graph engine.

``engine.EXECUTORS`` is the registry: every name in it is parametrized
through the SAME battery, so registering a new executor auto-enrolls it.
The battery asserts the contract every executor must honor:

  * fixed-key RMSE parity with the serial reference (identical per-block
    keys + identical bucket padding => identical chains up to batched-fp
    scheduling);
  * bitwise-deterministic results across repeated runs — completion-timing
    races (async polling, streaming chunk regrouping) may NOT leak into
    the numbers;
  * dependency-safe dispatch: the executor's event trace
    (``record_trace=True``) never shows a block dispatching before both
    its prior sources resolved, including under randomized fake completion
    orders for executors with a completion-detection seam;
  * transfer-guard cleanliness: the final divide-away aggregation runs
    under ``jax.transfer_guard("disallow")`` — executors must leave
    posterior summaries device-resident.
"""
import json
import os
import subprocess
import sys
import textwrap
import types
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import guards as GUARDS
from repro.core import bmf as BMF
from repro.core import engine as ENG
from repro.core import pp as PP
from repro.core.partition import partition
from repro.data import synthetic as SYN
from repro.data.sparse import train_test_split

ROOT = Path(__file__).resolve().parents[1]

EXECUTOR_NAMES = sorted(ENG.EXECUTORS)
# executors with a completion-detection seam (_is_resolved) that the
# fake-delay stress can scramble
OVERLAPPED = [n for n in EXECUTOR_NAMES
              if hasattr(ENG.EXECUTORS[n], "_is_resolved")]


def _make(name, **kw):
    """Fresh executor instance for the battery. The sharded executor gets
    an explicit 1-device topology so the battery runs on any host."""
    if name == "sharded":
        from repro.core.topology import Topology
        return ENG.ShardedExecutor(Topology(block=1, data=1), **kw)
    if name == "streaming":
        # a window smaller than the phase-b/c buckets exercises chunking
        return ENG.StreamingExecutor(window=2, **kw)
    return ENG.EXECUTORS[name](**kw)


def _fake_delay(ex, seed):
    """Scramble the completion order the scheduler OBSERVES: each poll
    flips a seeded coin per in-flight unit (the fallback path force-
    resolves the oldest, so progress is always made)."""
    rng = np.random.default_rng(seed)
    orig = ex._is_resolved

    def shuffled(coord, signal):
        return bool(rng.random() < 0.4) and orig(coord, signal)

    ex._is_resolved = shuffled
    return ex


@pytest.fixture(scope="module")
def conf_run():
    coo, p = SYN.generate("mini", seed=13)
    train, test = train_test_split(coo, 0.15, seed=14)
    cfg = BMF.BMFConfig(K=p.K, n_samples=5, burnin=1)
    part = partition(train, 3, 3)          # covers all four phase tags
    key = jax.random.key(5)
    ref = PP.run_pp(key, part, cfg, test, executor="serial")
    return part, cfg, test, key, ref


@pytest.fixture(scope="module")
def results(conf_run):
    """One traced run per executor, shared across the battery's asserts."""
    part, cfg, test, key, _ = conf_run
    cache = {}

    def get(name):
        if name not in cache:
            ex = _make(name, record_trace=True)
            cache[name] = (ex, PP.run_pp(key, part, cfg, test, executor=ex))
        return cache[name]

    return get


def test_registry_names_resolve():
    for name in EXECUTOR_NAMES:
        assert ENG.make_executor(name).name == name
    with pytest.raises(ValueError):
        ENG.make_executor("warp")
    # the battery covers the WHOLE registry — a new executor that isn't
    # parametrized here means this module is stale
    assert set(EXECUTOR_NAMES) == set(ENG.EXECUTORS)
    # the fake-delay stress knows about every overlapped executor
    assert set(OVERLAPPED) >= {"async", "streaming"}


@pytest.mark.parametrize("name", EXECUTOR_NAMES)
def test_fixed_key_rmse_parity(conf_run, results, name):
    part, cfg, test, key, ref = conf_run
    _, res = results(name)
    assert res.executor == name
    assert abs(res.rmse - ref.rmse) < 1e-5, (name, res.rmse, ref.rmse)
    np.testing.assert_allclose(res.per_block_rmse, ref.per_block_rmse,
                               atol=1e-4)
    assert res.n_test == ref.n_test > 0
    assert set(res.phase_times_s) == set(ref.phase_times_s)
    # (aggregated natural params are deliberately NOT compared across
    # executors here: with short conformance chains the moment covariances
    # are near-singular and Λ⁻¹ amplifies benign batched-fp scheduling
    # noise unboundedly. Cross-run bitwise identity is asserted in
    # test_bitwise_deterministic_aggregation instead.)


@pytest.mark.parametrize("name", EXECUTOR_NAMES)
def test_bitwise_deterministic_aggregation(conf_run, results, name):
    """Same key, fresh executor => BIT-identical final aggregation.
    Completion-timing races must never reach the numbers."""
    part, cfg, test, key, _ = conf_run
    _, res1 = results(name)
    res2 = PP.run_pp(key, part, cfg, test, executor=_make(name))
    assert res1.rmse == res2.rmse
    np.testing.assert_array_equal(np.asarray(res1.U_agg.eta),
                                  np.asarray(res2.U_agg.eta))
    np.testing.assert_array_equal(np.asarray(res1.V_agg.Lambda),
                                  np.asarray(res2.V_agg.Lambda))


def _assert_trace_dep_safe(trace, part):
    graph = {t.coord: t for _, ts in ENG.build_phase_graph(part) for t in ts}
    dispatched, resolved = set(), set()
    for ev, c, *_ in trace:
        if ev == "dispatch":
            assert set(graph[c].deps) <= resolved, \
                f"{c} dispatched before deps {graph[c].deps} resolved"
            assert c not in dispatched, f"{c} dispatched twice"
            dispatched.add(c)
        else:
            assert ev == "resolve" and c in dispatched
            resolved.add(c)
    assert resolved == set(graph)          # every block ran exactly once
    assert len(trace) == 2 * len(graph)


@pytest.mark.parametrize("name", EXECUTOR_NAMES)
def test_no_dispatch_before_deps_resolve(conf_run, results, name):
    part, _, _, _, _ = conf_run
    ex, _ = results(name)
    _assert_trace_dep_safe(ex.trace, part)


@pytest.mark.parametrize("name", sorted(OVERLAPPED))
@pytest.mark.parametrize("seed", range(2))
def test_fake_delay_completion_order(conf_run, results, name, seed):
    """Randomized observed-completion order: dispatch stays dependency-
    safe and the aggregation stays bit-identical to the undelayed run."""
    part, cfg, test, key, _ = conf_run
    _, res_ref = results(name)
    ex = _fake_delay(_make(name, record_trace=True), seed)
    res = PP.run_pp(key, part, cfg, test, executor=ex)
    _assert_trace_dep_safe(ex.trace, part)
    np.testing.assert_array_equal(np.asarray(res_ref.U_agg.eta),
                                  np.asarray(res.U_agg.eta))
    np.testing.assert_array_equal(np.asarray(res_ref.V_agg.eta),
                                  np.asarray(res.V_agg.eta))
    assert res.rmse == res_ref.rmse


@pytest.mark.parametrize("name", EXECUTOR_NAMES)
def test_aggregation_transfer_guard_clean(conf_run, results, name,
                                          monkeypatch):
    """The divide-away aggregation must see device-resident posteriors:
    run it under guards.no_host_transfers() (the executable is warm from
    the cached run, so any failure is a genuine host round-trip)."""
    part, cfg, test, key, _ = conf_run
    results(name)                              # warm the executables
    orig = PP._aggregate_axis

    def guarded(p, posts, axis):
        with GUARDS.no_host_transfers():
            return orig(p, posts, axis)

    monkeypatch.setattr(PP, "_aggregate_axis", guarded)
    res = PP.run_pp(key, part, cfg, test, executor=_make(name))
    assert isinstance(res.U_agg.eta, jax.Array)
    jax.block_until_ready((res.U_agg, res.V_agg))


# ---------------------------------------------------------------------------
# mixed-precision fused sweep — the bf16 RMSE-parity gate
# ---------------------------------------------------------------------------

# |RMSE(bf16 fused, executor) - RMSE(fp32 fused, serial)| gate. Measured
# drift on this fixture is ~1e-4 across every executor; the gate leaves
# two orders of headroom while still catching a half-precision leak into
# the factor/solve path (which blows drift past 0.1 immediately).
BF16_RMSE_GATE = 1e-2


@pytest.fixture(scope="module")
def mixed_precision_ref():
    """movielens 8x2 with the fused sweep on — big enough that per-row
    conditionals are data-dominated (the regime where bf16 accumulation
    error would actually surface), short chains to keep it tier-1."""
    coo, p = SYN.generate("movielens", seed=13)
    train, test = train_test_split(coo, 0.15, seed=14)
    part = partition(train, 8, 2)
    cfg = BMF.BMFConfig(K=min(p.K, 16), n_samples=5, burnin=1,
                        sweep_fused=True, sweep_dtype="fp32")
    key = jax.random.key(5)
    ref = PP.run_pp(key, part, cfg, test, executor="serial")
    return part, cfg, test, key, ref


@pytest.mark.parametrize("name", EXECUTOR_NAMES)
def test_bf16_fused_rmse_parity(mixed_precision_ref, name):
    """Every registered executor must hold the bf16 fused sweep inside
    the RMSE-parity gate against the fp32 serial reference — the
    conformance-side proof that mixed precision stays confined to the
    gather/accumulate half of the kernel."""
    part, cfg, test, key, ref = mixed_precision_ref
    res = PP.run_pp(key, part, cfg._replace(sweep_dtype="bf16"), test,
                    executor=_make(name))
    assert res.executor == name
    assert res.n_test == ref.n_test > 0
    assert abs(res.rmse - ref.rmse) < BF16_RMSE_GATE, \
        (name, res.rmse, ref.rmse)


# ---------------------------------------------------------------------------
# composed (2-D topology) executor variants — faked 4-device mesh
# ---------------------------------------------------------------------------

COMPOSED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax
    import numpy as np
    from repro.analysis import guards as GUARDS
    from repro.core import bmf as BMF, engine as ENG, pp as PP
    from repro.core.partition import partition
    from repro.core.topology import Topology
    from repro.data import synthetic as SYN
    from repro.data.sparse import train_test_split

    coo, p = SYN.generate("mini", seed=13)
    train, test = train_test_split(coo, 0.15, seed=14)
    cfg = BMF.BMFConfig(K=p.K, n_samples=5, burnin=1)
    part = partition(train, 3, 3)          # covers all four phase tags
    key = jax.random.key(5)
    ref = PP.run_pp(key, part, cfg, test, executor="serial")
    topo = Topology(block=2, data=2)

    orig_agg = PP._aggregate_axis
    def guarded(p_, posts, axis):
        with GUARDS.no_host_transfers():
            return orig_agg(p_, posts, axis)

    execs = {
        "sharded": ENG.ShardedExecutor(topo, record_trace=True),
        "sharded_psum": ENG.ShardedExecutor(topo, comm="psum",
                                            record_trace=True),
        "async": ENG.AsyncExecutor(topology=topo, record_trace=True),
        "streaming": ENG.StreamingExecutor(window=2, topology=topo,
                                           record_trace=True),
    }
    out = {"n_devices": len(jax.devices()), "serial": ref.rmse, "execs": {}}
    for name, ex in execs.items():
        res = PP.run_pp(key, part, cfg, test, executor=ex)   # warm compile
        PP._aggregate_axis = guarded       # aggregation must stay on device
        res2 = PP.run_pp(key, part, cfg, test, executor=ex)
        PP._aggregate_axis = orig_agg
        out["execs"][name] = {
            "rmse": res.rmse,
            "rmse_rerun": res2.rmse,
            "per_block_max_diff": float(np.abs(
                res.per_block_rmse - ref.per_block_rmse).max()),
            "trace": [[t[0], list(t[1])] + list(t[2:]) for t in ex.trace],
            "n_test": res.n_test,
        }
    print(json.dumps(out))
""")


@pytest.fixture(scope="module")
def composed_runs():
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
    out = subprocess.run([sys.executable, "-c", COMPOSED_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-4000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


COMPOSED = ["sharded", "sharded_psum", "async", "streaming"]


@pytest.mark.slow
@pytest.mark.parametrize("name", COMPOSED)
def test_composed_2d_rmse_parity(composed_runs, name):
    """The composed (block=2, data=2) variants keep fixed-key RMSE parity
    with the serial reference: the 'gather' intra-block exchange
    reproduces the reference chains (fp-level), 'psum' differs only in
    the item-stat reduction order."""
    rec = composed_runs
    assert rec["n_devices"] == 4
    r = rec["execs"][name]
    assert abs(r["rmse"] - rec["serial"]) < 1e-4, (name, r, rec["serial"])
    assert r["per_block_max_diff"] < 1e-3, (name, r)
    assert r["n_test"] > 0
    # deterministic across runs of the same executor (the rerun also
    # proves the aggregation stayed transfer-guard-clean on 4 devices)
    assert r["rmse_rerun"] == pytest.approx(r["rmse"], abs=1e-12)


@pytest.mark.slow
@pytest.mark.parametrize("name", COMPOSED)
def test_composed_2d_trace_dep_safe(composed_runs, conf_run, name):
    part, _, _, _, _ = conf_run
    trace = [(t[0], tuple(t[1]), *t[2:]) for t in
             composed_runs["execs"][name]["trace"]]
    _assert_trace_dep_safe(trace, part)
