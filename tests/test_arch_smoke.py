"""Per-architecture smoke tests (assignment requirement).

Each assigned architecture is instantiated as a REDUCED variant of the same
family (2 layers, d_model<=512, <=4 experts) and runs:
  - one forward pass        -> finite logits, right shape
  - one train step (AdamW)  -> finite loss, params updated
  - one decode step         -> finite logits, cache pos advanced
on CPU with a single real device.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, TrainConfig, get_config
from repro.models import model as MODEL
from repro.models import steps as STEPS
from repro.models.kvcache import serve_cache_init
from repro.optim import adamw

B, S = 2, 64


def _batch(cfg, key):
    kt, ki = jax.random.split(key)
    if cfg.family == "vlm":
        n_img = cfg.n_image_tokens
        return {
            "tokens": jax.random.randint(kt, (B, S - n_img), 0, cfg.vocab_size),
            "image_embeds": jax.random.normal(ki, (B, n_img, cfg.d_model),
                                              jnp.bfloat16),
        }
    if cfg.family == "audio":
        return {
            "tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab_size),
            "audio_embeds": jax.random.normal(
                ki, (B, cfg.n_audio_frames, cfg.d_model), jnp.bfloat16),
        }
    return {"tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab_size)}


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_setup(request):
    cfg = get_config(request.param).smoke_variant()
    key = jax.random.key(0)
    params = MODEL.init_params(key, cfg)
    batch = _batch(cfg, jax.random.key(1))
    return request.param, cfg, params, batch


def test_forward_shapes_and_finite(arch_setup):
    arch_id, cfg, params, batch = arch_setup
    logits, aux = MODEL.forward(params, cfg, batch, remat=False)
    S_total = S if cfg.family != "vlm" else S
    assert logits.shape == (B, S_total, cfg.vocab_size), logits.shape
    assert logits.dtype == jnp.float32
    assert bool(jnp.isfinite(logits).all()), f"{arch_id}: non-finite logits"


def test_train_step(arch_setup):
    arch_id, cfg, params, batch = arch_setup
    tcfg = TrainConfig(total_steps=10, warmup_steps=2, remat=True)
    step = jax.jit(STEPS.make_train_step(cfg, tcfg))
    opt = adamw.init(params)
    p2, opt2, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"])), f"{arch_id}: loss not finite"
    assert float(metrics["loss"]) > 0.0
    # params actually changed somewhere
    deltas = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                           b.astype(jnp.float32)))), params, p2)
    assert max(jax.tree.leaves(deltas)) > 0.0, f"{arch_id}: no param moved"
    # every param is still finite
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(p2))
    assert int(opt2.step) == 1


def test_decode_step(arch_setup):
    arch_id, cfg, params, batch = arch_setup
    cache = serve_cache_init(cfg, B, 128)
    step = jax.jit(STEPS.make_serve_step(cfg))
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, cache = step(params, cache, tok)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch_id}: decode logits not finite"
    assert int(cache["pos"]) == 1
    logits2, cache = step(params, cache, tok)
    assert int(cache["pos"]) == 2
    assert bool(jnp.isfinite(logits2).all())
