"""Correctness of the BMF core: conjugate math, Gibbs RMSE, PP parity.

These validate the paper's central claims at test scale:
  - the per-row Gibbs conditional matches the closed-form Gaussian posterior
    (linear-Gaussian conjugacy) when sampling noise is marginalized,
  - full BMF beats a mean predictor on synthetic low-rank data,
  - BMF+PP achieves RMSE close to full BMF (paper Table 2 claim),
  - natural-parameter algebra invariants (product/divide round-trip).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bmf as BMF
from repro.core import gibbs as GIBBS
from repro.core import posterior as POST
from repro.core import pp as PP
from repro.core.partition import partition, suggest_grid
from repro.data import synthetic as SYN
from repro.data.sparse import COO, coo_to_padded_csr, train_test_split


def test_sufficient_stats_match_dense():
    """Λ/η contributions equal the dense masked computation."""
    rng = np.random.default_rng(0)
    N, D, K, M = 7, 5, 3, 4
    idx = rng.integers(0, D, (N, M)).astype(np.int32)
    val = rng.normal(size=(N, M)).astype(np.float32)
    mask = (rng.random((N, M)) < 0.7).astype(np.float32)
    V = rng.normal(size=(D, K)).astype(np.float32)
    csr = __import__("repro.data.sparse", fromlist=["PaddedCSR"]).PaddedCSR(
        idx=jnp.asarray(idx), val=jnp.asarray(val), mask=jnp.asarray(mask),
        n_cols=D)
    tau = 1.7
    Lam, eta = BMF.sufficient_stats(csr, jnp.asarray(V), tau)
    for n in range(N):
        lam_ref = np.zeros((K, K))
        eta_ref = np.zeros(K)
        for m in range(M):
            if mask[n, m]:
                v = V[idx[n, m]]
                lam_ref += tau * np.outer(v, v)
                eta_ref += tau * val[n, m] * v
        np.testing.assert_allclose(np.asarray(Lam[n]), lam_ref, rtol=2e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(eta[n]), eta_ref, rtol=2e-4, atol=1e-4)


def test_gibbs_conditional_matches_closed_form():
    """With fixed V and fixed prior, the mean of many Gibbs draws of u_n
    approaches the closed-form posterior mean Λ⁻¹η."""
    rng = np.random.default_rng(1)
    D, K = 12, 3
    V = rng.normal(size=(D, K)).astype(np.float32)
    u_true = rng.normal(size=(K,)).astype(np.float32)
    tau = 4.0
    r = V @ u_true + rng.normal(0, 1 / np.sqrt(tau), D).astype(np.float32)

    from repro.data.sparse import PaddedCSR
    csr = PaddedCSR(idx=jnp.arange(D, dtype=jnp.int32)[None, :],
                    val=jnp.asarray(r)[None, :],
                    mask=jnp.ones((1, D), jnp.float32), n_cols=D)
    prior = POST.broadcast_prior(jnp.zeros(K), jnp.eye(K), 1)

    # closed form
    Lam = np.eye(K) + tau * V.T @ V
    eta = tau * V.T @ r
    mu_closed = np.linalg.solve(Lam, eta)
    cov_closed = np.linalg.inv(Lam)

    draws = []
    key = jax.random.key(0)
    for i in range(600):
        key, k = jax.random.split(key)
        draws.append(np.asarray(
            BMF.sample_factor(k, csr, jnp.asarray(V), tau, prior))[0])
    draws = np.stack(draws)
    np.testing.assert_allclose(draws.mean(0), mu_closed, atol=0.05)
    np.testing.assert_allclose(np.cov(draws.T), cov_closed, atol=0.05)


def test_posterior_algebra_roundtrip():
    rng = np.random.default_rng(2)
    K, N = 4, 6
    A = rng.normal(size=(N, K, K))
    LamA = jnp.asarray(A @ A.transpose(0, 2, 1) + 3 * np.eye(K))
    etaA = jnp.asarray(rng.normal(size=(N, K)))
    B = rng.normal(size=(N, K, K))
    LamB = jnp.asarray(B @ B.transpose(0, 2, 1) + 3 * np.eye(K))
    etaB = jnp.asarray(rng.normal(size=(N, K)))
    ga = POST.RowGaussians(etaA, LamA)
    gb = POST.RowGaussians(etaB, LamB)
    back = POST.divide(POST.product(ga, gb), gb)
    np.testing.assert_allclose(np.asarray(back.eta), np.asarray(ga.eta), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(back.Lambda), np.asarray(ga.Lambda), rtol=1e-5)


@pytest.fixture(scope="module")
def mini_data():
    coo, preset = SYN.generate("mini", seed=3)
    train, test = train_test_split(coo, 0.15, seed=4)
    return train, test, preset


def test_full_bmf_beats_mean(mini_data):
    train, test, p = mini_data
    cfg = BMF.BMFConfig(K=p.K, n_samples=40, burnin=15)
    rmse, secs, _ = PP.run_full_bmf(jax.random.key(0), train, test, cfg)
    base = float(np.sqrt(np.mean((test.val - train.val.mean()) ** 2)))
    assert rmse < 0.85 * base, (rmse, base)


def test_pp_rmse_close_to_full_bmf(mini_data):
    """Paper Table 2: BMF+PP ≈ BMF in RMSE."""
    train, test, p = mini_data
    cfg = BMF.BMFConfig(K=p.K, n_samples=40, burnin=15)
    rmse_full, _, _ = PP.run_full_bmf(jax.random.key(0), train, test, cfg)
    part = partition(train, 2, 2)
    res = PP.run_pp(jax.random.key(1), part, cfg, test)
    assert res.n_test > 0
    assert res.rmse < rmse_full * 1.15, (res.rmse, rmse_full)


def test_coo_to_padded_csr_vectorized_fill():
    """The numpy-scatter row fill must match a slot-by-slot loop, including
    truncation of rows beyond max_nnz and rows with zero ratings."""
    rng = np.random.default_rng(6)
    n_rows, n_cols, nnz = 23, 11, 150
    rows = rng.integers(0, n_rows - 2, nnz).astype(np.int32)  # last 2 empty
    coo = COO(row=rows, col=rng.integers(0, n_cols, nnz).astype(np.int32),
              val=rng.normal(size=nnz).astype(np.float32),
              n_rows=n_rows, n_cols=n_cols)
    for max_nnz in (None, 8):
        csr = coo_to_padded_csr(coo, max_nnz=max_nnz)
        M = csr.idx.shape[1]
        order = np.argsort(coo.row, kind="stable")
        r_s, c_s, v_s = coo.row[order], coo.col[order], coo.val[order]
        idx_ref = np.zeros((n_rows, M), np.int32)
        val_ref = np.zeros((n_rows, M), np.float32)
        mask_ref = np.zeros((n_rows, M), np.float32)
        fill = np.zeros(n_rows, np.int64)
        for r, c, v in zip(r_s, c_s, v_s):
            k = fill[r]
            if k < M:
                idx_ref[r, k], val_ref[r, k], mask_ref[r, k] = c, v, 1.0
            fill[r] += 1
        np.testing.assert_array_equal(np.asarray(csr.idx), idx_ref)
        np.testing.assert_array_equal(np.asarray(csr.val), val_ref)
        np.testing.assert_array_equal(np.asarray(csr.mask), mask_ref)


def test_occupancy_permutation_groups_heavy_rows():
    from repro.data.sparse import occupancy_permutation
    rng = np.random.default_rng(8)
    counts = np.array([5, 0, 9, 1, 9, 2])
    rows = np.repeat(np.arange(6), counts).astype(np.int32)
    coo = COO(row=rows, col=np.zeros(len(rows), np.int32),
              val=np.ones(len(rows), np.float32), n_rows=6, n_cols=1)
    perm = occupancy_permutation(coo, axis="row")
    # position of each row = its rank by descending count
    permuted_counts = np.empty(6, np.int64)
    permuted_counts[perm] = counts
    assert (np.diff(permuted_counts) <= 0).all(), permuted_counts


def test_sample_nw_moments_match_analytic():
    """Statistical correctness of the NW sampler + conjugate update (both
    rewritten onto Cholesky factor/solve in PR 1): empirical moments of
    ``sample_nw`` draws from ``nw_posterior(prior, X)`` must converge to
    the analytic Normal-Wishart values under a fixed seed —
      E[Λ] = ν·W,  E[μ] = μ0,  Cov(μ) = E[(βΛ)⁻¹] = W⁻¹ / (β(ν−K−1)).
    """
    K = 3
    prior = POST.NormalWishart(
        mu0=jnp.asarray([1.0, -2.0, 0.5]),
        beta0=jnp.asarray(2.0),
        W0=jnp.asarray([[1.0, 0.3, 0.0],
                        [0.3, 2.0, 0.2],
                        [0.0, 0.2, 0.5]]),
        nu0=jnp.asarray(float(K + 3)))      # ν−K−1 = 2 > 0: Cov(μ) finite
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(0.5, 1.2, (60, K)).astype(np.float32))
    post = POST.nw_posterior(prior, X)
    # conjugate bookkeeping is exact
    np.testing.assert_allclose(float(post.beta0), 2.0 + 60)
    np.testing.assert_allclose(float(post.nu0), K + 3 + 60)

    T = 4000
    keys = jax.random.split(jax.random.key(11), T)
    mus, lams = jax.vmap(lambda k: POST.sample_nw(k, post))(keys)
    mus, lams = np.asarray(mus), np.asarray(lams)

    E_lam = float(post.nu0) * np.asarray(post.W0)
    scale_lam = np.abs(E_lam).max()
    np.testing.assert_allclose(lams.mean(0), E_lam,
                               atol=0.02 * scale_lam)
    np.testing.assert_allclose(mus.mean(0), np.asarray(post.mu0), atol=0.02)
    Winv = np.linalg.inv(np.asarray(post.W0))
    cov_analytic = Winv / (float(post.beta0)
                           * (float(post.nu0) - K - 1))
    np.testing.assert_allclose(np.cov(mus.T), cov_analytic,
                               atol=0.15 * np.abs(cov_analytic).max())


def test_from_moments_cov_matches_inverse():
    """Cholesky factor/solve summarization == explicit-inverse natural
    params (the path it replaced)."""
    rng = np.random.default_rng(9)
    N, K = 6, 5
    A = rng.normal(size=(N, K, K)).astype(np.float32)
    cov = A @ A.transpose(0, 2, 1) + 2 * np.eye(K, dtype=np.float32)
    mu = rng.normal(size=(N, K)).astype(np.float32)
    g = POST.from_moments_cov(jnp.asarray(mu), jnp.asarray(cov))
    Lam_ref = np.linalg.inv(cov)
    eta_ref = np.einsum("nkl,nl->nk", Lam_ref, mu)
    np.testing.assert_allclose(np.asarray(g.Lambda), Lam_ref, rtol=2e-3,
                               atol=2e-3)
    np.testing.assert_allclose(np.asarray(g.eta), eta_ref, rtol=2e-3,
                               atol=2e-3)


def test_block_shapes_per_phase_tighter(mini_data):
    """Per-phase occupancy buckets must never exceed the global bucket and
    must cover every block of their phase."""
    train, test, p = mini_data
    part = partition(train, 2, 2)
    global_s = PP.BlockShapes.of(part, test)
    by_phase = PP.BlockShapes.per_phase(part, test)
    assert set(by_phase) == {b.phase for b in part.all_blocks()}
    for ph, s in by_phase.items():
        assert s.m_rows <= global_s.m_rows
        assert s.n_rows <= global_s.n_rows
        for b in part.all_blocks():
            if b.phase != ph or not b.coo.nnz:
                continue
            assert len(b.row_ids) <= s.n_rows
            m = int(np.bincount(b.coo.row, minlength=len(b.row_ids)).max())
            assert m <= s.m_rows


def test_suggest_grid_squareish():
    I, J = suggest_grid(480_000, 17_000, 64)
    # netflix-like 27:1 aspect -> more row blocks than col blocks
    assert I > J
    assert I * J == 64


def test_gibbs_with_pallas_kernel(mini_data):
    """cfg.use_kernel=True routes the precision accumulation through the
    Pallas kernel (interpret mode on CPU) — RMSE must match the jnp path."""
    train, test, p = mini_data
    cfg_ref = BMF.BMFConfig(K=p.K, n_samples=15, burnin=5, use_kernel=False)
    cfg_ker = BMF.BMFConfig(K=p.K, n_samples=15, burnin=5, use_kernel=True)
    r_ref, _, _ = PP.run_full_bmf(jax.random.key(5), train, test, cfg_ref)
    r_ker, _, _ = PP.run_full_bmf(jax.random.key(5), train, test, cfg_ker)
    # identical keys + near-identical math -> near-identical chains
    assert abs(r_ref - r_ker) < 0.05, (r_ref, r_ker)
