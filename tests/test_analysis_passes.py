"""The static invariant analyzer (repro.analysis): registry mechanics,
each pass firing on a seeded-violation fixture AND staying quiet on the
clean twin, the nested-jaxpr traversal it runs on, and the engine's
pre-dispatch graph-validation hook."""
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import analysis as LINT
from repro.analysis import registry as REG
from repro.analysis.hlo_passes import alias_param_ids, default_budget
from repro.analysis.jaxpr_passes import materialization_budget
from repro.analysis.trace_passes import check_graph
from repro.core import bmf as BMF
from repro.core import engine as ENG
from repro.core import gibbs as GIBBS
from repro.core import pp as PP
from repro.core.partition import partition
from repro.data import synthetic as SYN
from repro.data.sparse import train_test_split
from repro.roofline import jaxpr_cost as JCOST

S = jax.ShapeDtypeStruct
f32 = jnp.float32


def violations_of(art, pass_name):
    return [v for v in LINT.analyze(art) if v.pass_name == pass_name]


# ---------------------------------------------------------------------------
# registry mechanics
# ---------------------------------------------------------------------------


def test_registry_rejects_duplicates_and_bad_kinds():
    with pytest.raises(ValueError, match="duplicate"):
        REG.register(REG.Pass("materialization", "jaxpr", "", lambda a: []))
    with pytest.raises(ValueError, match="unknown artifact kind"):
        REG.register(REG.Pass("fresh-name", "mlir", "", lambda a: []))
    with pytest.raises(KeyError, match="unknown pass"):
        REG.get_pass("no-such-pass")


def test_registry_lists_every_shipped_pass():
    names = {p.name for p in LINT.passes()}
    assert {"materialization", "dtype-promotion", "host-callback",
            "collective-confinement", "donation-effectiveness",
            "recompilation-budget", "happens-before", "window-occupancy",
            "graph-validation"} <= names
    for p in LINT.passes():
        assert p.kind in REG.KINDS and p.doc


def test_analyze_runs_only_matching_kind():
    art = REG.PlanArtifact(label="p", signatures=["a"] * 3, cap=8)
    for v in LINT.analyze(art):
        assert LINT.get_pass(v.pass_name).kind == "plan"


def test_violation_roundtrip():
    v = REG.Violation("p", "a", "broken", "fix it")
    assert v.as_dict() == {"pass": "p", "artifact": "a",
                           "message": "broken", "fix_hint": "fix it"}
    assert "fix it" in str(v)


# ---------------------------------------------------------------------------
# jaxpr passes
# ---------------------------------------------------------------------------

# block dims where the dense (N, M, K) factor tensor clearly exceeds the
# budget while the fused padded-plane gather stays inside it
N, M, MP, K = 64, 64, 8, 8
BUDGET = materialization_budget(N, M, MP, MP, K)


def _naive_jaxpr():
    """The formulation the pass exists to catch: materializes the dense
    (N, M, K) gathered-factor tensor before reducing."""
    def f(U, V, R):
        G = U[:, None, :] * V[None, :, :]            # (N, M, K) — the bug
        return jnp.sum(G * R[:, :, None], axis=1)
    return jax.jit(f).trace(S((N, K), f32), S((M, K), f32),
                            S((N, M), f32)).jaxpr


def _fused_jaxpr():
    """The padded-CSR formulation: per-row gathers of width MP only."""
    def f(U, V, idx, vals):
        Vg = V[idx]                                   # (N, MP, K)
        return jnp.einsum("nmk,nm->nk", Vg, vals) + U
    return jax.jit(f).trace(S((N, K), f32), S((M, K), f32),
                            S((N, MP), jnp.int32), S((N, MP), f32)).jaxpr


def test_materialization_fires_on_dense_gather():
    art = REG.JaxprArtifact(label="naive", jaxpr=_naive_jaxpr(),
                            bytes_budget=BUDGET)
    vs = violations_of(art, "materialization")
    assert vs and f"[{N}, {M}, {K}]" in vs[0].message


def test_materialization_quiet_on_fused_gather():
    art = REG.JaxprArtifact(label="fused", jaxpr=_fused_jaxpr(),
                            bytes_budget=BUDGET)
    assert not violations_of(art, "materialization")


def test_materialization_sees_inside_scan_bodies():
    """A dense tensor hiding inside a scanned sweep body is still caught —
    the traversal recurses into the scan jaxpr."""
    def f(U, V, R):
        def sweep(carry, _):
            G = U[:, None, :] * V[None, :, :]        # (N, M, K) in the body
            return carry + jnp.sum(G * R[:, :, None], axis=1), None
        out, _ = jax.lax.scan(sweep, jnp.zeros((N, K), f32), None, length=3)
        return out
    jx = jax.jit(f).trace(S((N, K), f32), S((M, K), f32),
                          S((N, M), f32)).jaxpr
    art = REG.JaxprArtifact(label="scanned-naive", jaxpr=jx,
                            bytes_budget=BUDGET)
    assert violations_of(art, "materialization")


def test_materialization_skipped_without_budget():
    art = REG.JaxprArtifact(label="naive", jaxpr=_naive_jaxpr())
    assert not violations_of(art, "materialization")


def test_dtype_promotion_fires_on_f64():
    with jax.experimental.enable_x64():
        jx = jax.jit(lambda x: x * np.float64(2.0)).trace(
            S((4,), jnp.float64)).jaxpr
    art = REG.JaxprArtifact(label="x64", jaxpr=jx)
    assert violations_of(art, "dtype-promotion")
    assert not violations_of(
        REG.JaxprArtifact(label="x64-ok", jaxpr=jx, allow_f64=True),
        "dtype-promotion")


def test_dtype_promotion_fires_on_low_precision_cholesky():
    def f(A):
        L = jax.lax.linalg.cholesky(A)
        return jnp.sum(L)
    jx = jax.jit(f).trace(S((4, 4), jnp.bfloat16)).jaxpr
    vs = violations_of(REG.JaxprArtifact(label="bf16-chol", jaxpr=jx),
                       "dtype-promotion")
    assert vs and "cholesky" in vs[0].message
    jx32 = jax.jit(f).trace(S((4, 4), f32)).jaxpr
    assert not violations_of(REG.JaxprArtifact(label="f32-chol", jaxpr=jx32),
                             "dtype-promotion")


def test_host_callback_fires_inside_jit():
    def f(x):
        jax.debug.print("x={x}", x=x.sum())
        return x * 2
    jx = jax.jit(f).trace(S((4,), f32)).jaxpr
    vs = violations_of(REG.JaxprArtifact(label="cb", jaxpr=jx),
                       "host-callback")
    assert vs and "debug_callback" in vs[0].message
    jx_clean = jax.jit(lambda x: x * 2).trace(S((4,), f32)).jaxpr
    assert not violations_of(REG.JaxprArtifact(label="ok", jaxpr=jx_clean),
                             "host-callback")


# ---------------------------------------------------------------------------
# satellite: the nested-jaxpr traversal itself (roofline.jaxpr_cost)
# ---------------------------------------------------------------------------


def _shapes(jx):
    return {tuple(a.shape) for a in JCOST.iter_avals(jx)}


def test_iter_avals_recurses_into_scan_body():
    def f(x):
        def body(c, _):
            w = jnp.ones((17, 23), f32)                # (17,23) body-only
            return c + (c @ w @ w.T), None
        out, _ = jax.lax.scan(body, x, None, length=2)
        return out
    jx = jax.jit(f).trace(S((5, 17), f32)).jaxpr
    assert (17, 23) in _shapes(jx)


def test_iter_avals_recurses_into_while_body():
    def f(x):
        def cond(c):
            return c[0] < 3
        def body(c):
            i, v = c
            return i + 1, v + jnp.zeros((11, 13), f32).sum()
        return jax.lax.while_loop(cond, body, (0, x))
    jx = jax.jit(f).trace(S((), f32)).jaxpr
    assert (11, 13) in _shapes(jx)


def test_iter_avals_recurses_into_cond_branches():
    def f(p, x):
        return jax.lax.cond(p,
                            lambda v: jnp.zeros((7, 29), f32).sum() + v,
                            lambda v: v * 2.0, x)
    jx = jax.jit(f).trace(S((), jnp.bool_), S((), f32)).jaxpr
    assert (7, 29) in _shapes(jx)


def test_iter_avals_recurses_into_pjit_subjaxpr():
    @jax.jit
    def inner(x):
        return x @ jnp.ones((19, 31), f32)
    jx = jax.jit(lambda x: inner(x) + 1.0).trace(S((3, 19), f32)).jaxpr
    assert (19, 31) in _shapes(jx)


def test_iter_eqns_finds_primitive_inside_scan():
    def f(A):
        def body(c, _):
            return jax.lax.linalg.cholesky(c), None
        out, _ = jax.lax.scan(body, A, None, length=2)
        return out
    jx = jax.jit(f).trace(S((4, 4), f32)).jaxpr
    assert any(e.primitive.name == "cholesky" for e in JCOST.iter_eqns(jx))


# ---------------------------------------------------------------------------
# hlo passes
# ---------------------------------------------------------------------------

_HLO_TEMPLATE = """HloModule lint_fixture

ENTRY %main (p0: f32[4]) -> f32[8] {{
  %p0 = f32[4]{{0}} parameter(0)
{body}
}}
"""


def _hlo_with(lines):
    return _HLO_TEMPLATE.format(body="\n".join(f"  {ln}" for ln in lines))


def test_confinement_fires_on_block_axis_crossing():
    hlo = _hlo_with([
        "%ag = f32[8]{0} all-gather(f32[4]{0} %p0), "
        "replica_groups={{0,2},{1,3}}, dimensions={0}",
    ])
    art = REG.HLOArtifact(label="crossing", hlo_text=hlo, comm="gather",
                          allowed_groups=[[0, 1], [2, 3]])
    vs = violations_of(art, "collective-confinement")
    assert any("crosses the 'block' axis" in v.message for v in vs)


def test_confinement_fires_over_comm_budget():
    hlo = _hlo_with([
        "%ag1 = f32[8]{0} all-gather(f32[4]{0} %p0), "
        "replica_groups={{0,1},{2,3}}, dimensions={0}",
        "%ag2 = f32[8]{0} all-gather(f32[4]{0} %p0), "
        "replica_groups={{0,1},{2,3}}, dimensions={0}",
    ])
    art = REG.HLOArtifact(label="over-budget", hlo_text=hlo, comm="gather",
                          allowed_groups=[[0, 1], [2, 3]])
    vs = violations_of(art, "collective-confinement")
    assert any("budget" in v.message for v in vs)


def test_confinement_fires_on_any_collective_in_block_only_mode():
    hlo = _hlo_with([
        "%ar = f32[4]{0} all-reduce(f32[4]{0} %p0), "
        "replica_groups={{0,1}}, to_apply=%add",
    ])
    art = REG.HLOArtifact(label="block-only", hlo_text=hlo, comm=None)
    assert violations_of(art, "collective-confinement")


def test_confinement_quiet_within_groups_and_budget():
    hlo = _hlo_with([
        "%ag = f32[8]{0} all-gather(f32[4]{0} %p0), "
        "replica_groups={{0,1},{2,3}}, dimensions={0}",
    ])
    art = REG.HLOArtifact(label="confined", hlo_text=hlo, comm="gather",
                          allowed_groups=[[0, 1], [2, 3]])
    assert not violations_of(art, "collective-confinement")


def test_default_budget_rejects_unknown_comm():
    with pytest.raises(ValueError, match="unknown comm mode"):
        default_budget("broadcast")


def _compiled_hlo(fn, *avals, donate=None):
    jf = jax.jit(fn, donate_argnums=donate) if donate is not None \
        else jax.jit(fn)
    with GIBBS._quiet_donation():
        return jf.trace(*avals).lower().compile().as_text()


def test_donation_fires_when_nothing_aliases():
    # sum: f32[64] -> f32[] — the donated buffer cannot alias the output
    hlo = _compiled_hlo(lambda x: jnp.sum(x), S((64,), f32), donate=0)
    art = REG.HLOArtifact(label="dead-donation", hlo_text=hlo,
                          param_labels=["x"], donated=["x"],
                          must_alias=["x"])
    vs = violations_of(art, "donation-effectiveness")
    assert vs and "input_output_alias" in vs[0].message


def test_donation_quiet_on_real_alias():
    hlo = _compiled_hlo(lambda x: x * 2.0, S((64,), f32), donate=0)
    assert alias_param_ids(hlo) == [0]
    art = REG.HLOArtifact(label="live-donation", hlo_text=hlo,
                          param_labels=["x"], donated=["x"],
                          must_alias=["x"])
    assert not violations_of(art, "donation-effectiveness")


def test_donation_release_only_is_not_a_violation():
    # y is consumed but shape-mismatched with the output, so its donation
    # can only release the buffer, never alias it
    hlo = _compiled_hlo(lambda x, y: x * 2.0 + jnp.sum(y),
                        S((64,), f32), S((32,), f32), donate=(0, 1))
    art = REG.HLOArtifact(label="release", hlo_text=hlo,
                          param_labels=["x", "y"], donated=["x", "y"],
                          must_alias=["x"], release_only=["y"])
    assert not violations_of(art, "donation-effectiveness")
    # ... but an undocumented unusable donation fires
    art2 = REG.HLOArtifact(label="undocumented", hlo_text=hlo,
                           param_labels=["x", "y"], donated=["x", "y"],
                           must_alias=["x"])
    vs = violations_of(art2, "donation-effectiveness")
    assert vs and "unusable" in vs[0].message


def test_recompilation_budget():
    many = [("c", (i, 7, 3)) for i in range(12)]
    vs = violations_of(REG.PlanArtifact(label="explode", signatures=many,
                                        cap=8), "recompilation-budget")
    assert vs and "12 distinct" in vs[0].message
    few = [("c", (5, 7, 3)), ("a", (5, 7, 3))] * 10
    assert not violations_of(REG.PlanArtifact(label="ok", signatures=few,
                                              cap=8), "recompilation-budget")


# ---------------------------------------------------------------------------
# trace passes
# ---------------------------------------------------------------------------

A, B, C = (0, 0), (0, 1), (1, 1)
DEPS = {A: [], B: [A], C: [A, B]}


def test_happens_before_clean_trace():
    trace = [("dispatch", A), ("resolve", A), ("dispatch", B),
             ("resolve", B), ("dispatch", C), ("resolve", C)]
    art = REG.TraceArtifact(label="ok", trace=trace, deps=DEPS)
    assert not violations_of(art, "happens-before")


def test_happens_before_fires_on_dispatch_before_dep():
    trace = [("dispatch", A), ("dispatch", B), ("resolve", A),
             ("resolve", B), ("dispatch", C), ("resolve", C)]
    art = REG.TraceArtifact(label="early", trace=trace, deps=DEPS)
    vs = violations_of(art, "happens-before")
    assert vs and "before dep" in vs[0].message


def test_happens_before_watchdog_protocol():
    # expire -> redispatch -> resolve is the legal watchdog path
    ok = [("dispatch", A), ("expire", A), ("redispatch", A), ("resolve", A)]
    assert not violations_of(
        REG.TraceArtifact(label="wd", trace=ok, deps={A: []}),
        "happens-before")
    # expire -> terminal resolve (degraded path) is legal too
    degraded = [("dispatch", A), ("expire", A), ("resolve", A)]
    assert not violations_of(
        REG.TraceArtifact(label="deg", trace=degraded, deps={A: []}),
        "happens-before")
    # a second dispatch NOT ordered after an expire fires
    double = [("dispatch", A), ("dispatch", A), ("resolve", A)]
    vs = violations_of(
        REG.TraceArtifact(label="dbl", trace=double, deps={A: []}),
        "happens-before")
    assert any("twice" in v.message for v in vs)
    # redispatch with no expired attempt fires
    rogue = [("dispatch", A), ("redispatch", A), ("resolve", A)]
    vs = violations_of(
        REG.TraceArtifact(label="rogue", trace=rogue, deps={A: []}),
        "happens-before")
    assert any("without an expired attempt" in v.message for v in vs)


def test_happens_before_fires_on_unresolved_block():
    trace = [("dispatch", A), ("resolve", A), ("dispatch", B)]
    vs = violations_of(
        REG.TraceArtifact(label="lost", trace=trace, deps={A: [], B: [A]}),
        "happens-before")
    assert any("never resolved" in v.message for v in vs)


def test_happens_before_group_events_clean():
    """The full elastic vocabulary in legal order: a steal of a staged
    block, a speculate/cancel twin pair, and a quarantine after expiry —
    all on (event, coord, group) entries — stays quiet."""
    trace = [("dispatch", A, 0), ("resolve", A, 0),
             ("steal", B, 1), ("dispatch", B, 1),       # staged -> stolen
             ("speculate", B, 0),                       # straggler hedge
             ("cancel", B, 0),                          # loser side
             ("resolve", B, 1),
             ("dispatch", C, 1), ("expire", C, 1),
             ("quarantine", C, 1),                      # group 1 drained
             ("redispatch", C, 0), ("resolve", C, 0)]
    art = REG.TraceArtifact(label="elastic-ok", trace=trace, deps=DEPS)
    assert not violations_of(art, "happens-before")


def test_happens_before_fires_on_dispatch_to_quarantined_group():
    trace = [("dispatch", A, 0), ("expire", A, 0), ("quarantine", A, 0),
             ("redispatch", A, 1), ("resolve", A, 1),
             ("dispatch", B, 0),                        # group 0 is dead
             ("resolve", B, 0)]
    vs = violations_of(
        REG.TraceArtifact(label="necro", trace=trace, deps={A: [], B: [A]}),
        "happens-before")
    assert any("quarantined group 0" in v.message for v in vs)
    # ...and so does routing the watchdog redispatch back to it
    back = [("dispatch", A, 0), ("expire", A, 0), ("quarantine", A, 0),
            ("redispatch", A, 0), ("resolve", A, 0)]
    vs = violations_of(
        REG.TraceArtifact(label="necro2", trace=back, deps={A: []}),
        "happens-before")
    assert any("quarantined group 0" in v.message for v in vs)


def test_happens_before_speculative_twin_protocol():
    # a resolve with the twin pair still open fires
    open_twin = [("dispatch", A, 0), ("speculate", A, 1),
                 ("resolve", A, 0)]
    vs = violations_of(
        REG.TraceArtifact(label="twin-open", trace=open_twin, deps={A: []}),
        "happens-before")
    assert any("open speculative twin" in v.message for v in vs)
    # a cancel with no speculate behind it fires
    rogue_cancel = [("dispatch", A, 0), ("cancel", A, 0),
                    ("redispatch", A, 1), ("resolve", A, 1)]
    vs = violations_of(
        REG.TraceArtifact(label="rogue-cancel", trace=rogue_cancel,
                          deps={A: []}),
        "happens-before")
    assert any("without an open speculative twin" in v.message for v in vs)
    # speculating a block that is not in flight fires
    cold = [("dispatch", A, 0), ("resolve", A, 0), ("speculate", A, 1),
            ("cancel", A, 1)]
    vs = violations_of(
        REG.TraceArtifact(label="cold-spec", trace=cold, deps={A: []}),
        "happens-before")
    assert any("speculated while not in flight" in v.message for v in vs)
    # a run ending with both twins live fires
    dangling = [("dispatch", A, 0), ("speculate", A, 1)]
    vs = violations_of(
        REG.TraceArtifact(label="dangling", trace=dangling, deps={A: []}),
        "happens-before")
    assert any("uncollapsed speculative twin" in v.message for v in vs)


def test_happens_before_fires_on_steal_of_inflight_block():
    trace = [("dispatch", A, 0), ("steal", A, 1), ("resolve", A, 0)]
    vs = violations_of(
        REG.TraceArtifact(label="hot-steal", trace=trace, deps={A: []}),
        "happens-before")
    assert any("stolen while in flight" in v.message for v in vs)


def test_happens_before_fires_on_double_quarantine():
    trace = [("dispatch", A, 0), ("expire", A, 0), ("quarantine", A, 0),
             ("quarantine", A, 0), ("redispatch", A, 1), ("resolve", A, 1)]
    vs = violations_of(
        REG.TraceArtifact(label="dbl-q", trace=trace, deps={A: []}),
        "happens-before")
    assert any("quarantined twice" in v.message for v in vs)


def test_window_occupancy():
    over = [("dispatch", A), ("dispatch", B), ("dispatch", C),
            ("resolve", A), ("resolve", B), ("resolve", C)]
    art = REG.TraceArtifact(label="burst", trace=over,
                            deps={A: [], B: [], C: []}, window_bound=2)
    vs = violations_of(art, "window-occupancy")
    assert vs and "exceeds the window bound" in vs[0].message
    ok = [("dispatch", A), ("resolve", A), ("dispatch", B), ("resolve", B)]
    assert not violations_of(
        REG.TraceArtifact(label="paced", trace=ok, deps={A: [], B: []},
                          window_bound=2, reported_peak=1),
        "window-occupancy")
    # the executor's own counter over the bound fires even if the trace
    # looks paced
    assert violations_of(
        REG.TraceArtifact(label="counter", trace=ok, deps={A: [], B: []},
                          window_bound=2, reported_peak=5),
        "window-occupancy")


# ---------------------------------------------------------------------------
# graph validation (pass + the engine's pre-dispatch hook)
# ---------------------------------------------------------------------------


def test_graph_validation_detects_cycle_and_dangling():
    vs = check_graph({A: [B], B: [A]})
    assert any("cycle" in v.message for v in vs)
    vs = check_graph({A: [(9, 9)]})
    assert any("neither in the graph nor pre-resolved" in v.message
               for v in vs)
    # a pre-resolved dep (checkpoint resume) is satisfied
    assert not check_graph({A: [(9, 9)]}, resolved=[(9, 9)])
    assert not check_graph(DEPS)


def test_graph_pass_runs_via_registry():
    art = REG.GraphArtifact(label="cyclic", deps={A: [B], B: [A]})
    assert violations_of(art, "graph-validation")


def test_engine_refuses_invalid_phase_graph(monkeypatch):
    """run_phase_graph validates the (pruned) graph through the analyzer
    before any dispatch: a rewired prior_from that forms a cycle is
    refused up front instead of hanging the scheduler."""
    coo, p = SYN.generate("mini", seed=13)
    train, test = train_test_split(coo, 0.15, seed=14)
    cfg = BMF.BMFConfig(K=p.K, n_samples=2, burnin=1)
    part = partition(train, 2, 2)

    def cyclic_graph(part_):
        t00 = ENG.BlockTask(0, 0, "a", (1, 1), None)      # cycle: a <-> c
        t11 = ENG.BlockTask(1, 1, "c", (0, 0), (0, 0))
        return [("a", [t00]), ("c", [t11])]

    monkeypatch.setattr(ENG, "build_phase_graph", cyclic_graph)
    with pytest.raises(ValueError, match="invalid phase graph"):
        PP.run_pp(jax.random.key(0), part, cfg, test, executor="serial")


# ---------------------------------------------------------------------------
# integration: the real chain lowerings are clean
# ---------------------------------------------------------------------------


def test_real_chain_artifacts_are_clean():
    """The reference single-block chain, traced through the lowering hook,
    passes every jaxpr/hlo pass — the per-executor version of this runs in
    bmf_lint --all-executors (CI's lint-invariants gate)."""
    cfg = BMF.BMFConfig(K=8, n_samples=2, burnin=1)
    tc = GIBBS.trace_chain(cfg, 48, 32, 12, 16, 40, donate=True)
    budget = materialization_budget(48, 32, 12, 16, 8)
    jart = REG.JaxprArtifact(label="chain/jaxpr", jaxpr=tc.traced.jaxpr,
                             bytes_budget=budget)
    assert not LINT.analyze(jart)
    with GIBBS._quiet_donation():
        hlo = tc.traced.lower().compile().as_text()
    donated = tuple(tc.donated_labels)
    must = set(tc.must_alias)
    hart = REG.HLOArtifact(label="chain/hlo", hlo_text=hlo, comm=None,
                           param_labels=tc.param_labels, donated=donated,
                           must_alias=tc.must_alias,
                           release_only=tuple(lb for lb in donated
                                              if lb not in must))
    assert not LINT.analyze(hart)
