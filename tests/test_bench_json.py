"""bench_pp_engine --json-out merge semantics: idempotent merge-append
into the {runs: [...]} schema (re-running a config replaces its record),
including migration of the PR-2 single-run layout."""
import json
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT))

bench = pytest.importorskip("benchmarks.bench_pp_engine")


def _rec(dataset="movielens", grid_kind="balanced", grid=(8, 2), K=10,
         samples=20, wall=1.0):
    return {"dataset": dataset, "grid_kind": grid_kind,
            "grid": list(grid), "K": K, "samples": samples,
            "records": [{"executor": "serial", "wall_s": wall}]}


def test_merge_same_config_replaces(tmp_path):
    out = tmp_path / "bench.json"
    bench.merge_json_out(out, _rec(wall=1.0))
    bench.merge_json_out(out, _rec(wall=2.0))       # same config, re-run
    doc = json.loads(out.read_text())
    assert doc["benchmark"] == "pp_engine"
    assert len(doc["runs"]) == 1                    # replaced, not appended
    assert doc["runs"][0]["records"][0]["wall_s"] == 2.0


def test_merge_distinct_configs_coexist(tmp_path):
    out = tmp_path / "bench.json"
    bench.merge_json_out(out, _rec(samples=20))
    bench.merge_json_out(out, _rec(samples=40))          # different samples
    bench.merge_json_out(out, _rec(grid=(32, 8),
                                   grid_kind="oversized32x8-balanced"))
    bench.merge_json_out(out, _rec(dataset="amazon"))
    doc = json.loads(out.read_text())
    assert len(doc["runs"]) == 4
    # and re-running any one of them stays idempotent
    bench.merge_json_out(out, _rec(samples=40, wall=9.0))
    doc = json.loads(out.read_text())
    assert len(doc["runs"]) == 4
    hit = [r for r in doc["runs"] if r["samples"] == 40]
    assert len(hit) == 1 and hit[0]["records"][0]["wall_s"] == 9.0


def test_merge_migrates_legacy_single_run_layout(tmp_path):
    out = tmp_path / "bench.json"
    legacy = {"benchmark": "pp_engine", **_rec(dataset="netflix")}
    out.write_text(json.dumps(legacy))
    bench.merge_json_out(out, _rec(dataset="movielens"))
    doc = json.loads(out.read_text())
    assert len(doc["runs"]) == 2
    assert {r["dataset"] for r in doc["runs"]} == {"netflix", "movielens"}
    assert all("benchmark" not in r for r in doc["runs"])


def test_merge_runs_pure_function_roundtrip():
    doc = bench.merge_runs(None, _rec())
    doc2 = bench.merge_runs(doc, _rec(wall=3.0))
    assert len(doc2["runs"]) == 1
    assert doc2["runs"][0]["records"][0]["wall_s"] == 3.0
    assert bench._run_key(doc2["runs"][0]) == bench._run_key(_rec())
