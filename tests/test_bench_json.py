"""--json-out merge semantics: idempotent merge-append into the
{runs: [...]} schema (re-running a config replaces its record), including
migration of the PR-2 single-run layout. Both benches bind the shared
``benchmarks.common.merge_runs`` — bench_pp_engine keyed per training
config, bench_serving keyed per (mode, batch) serving config."""
import json
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT))

bench = pytest.importorskip("benchmarks.bench_pp_engine")
bench_srv = pytest.importorskip("benchmarks.bench_serving")


def _rec(dataset="movielens", grid_kind="balanced", grid=(8, 2), K=10,
         samples=20, wall=1.0):
    return {"dataset": dataset, "grid_kind": grid_kind,
            "grid": list(grid), "K": K, "samples": samples,
            "records": [{"executor": "serial", "wall_s": wall}]}


def test_merge_same_config_replaces(tmp_path):
    out = tmp_path / "bench.json"
    bench.merge_json_out(out, _rec(wall=1.0))
    bench.merge_json_out(out, _rec(wall=2.0))       # same config, re-run
    doc = json.loads(out.read_text())
    assert doc["benchmark"] == "pp_engine"
    assert len(doc["runs"]) == 1                    # replaced, not appended
    assert doc["runs"][0]["records"][0]["wall_s"] == 2.0


def test_merge_distinct_configs_coexist(tmp_path):
    out = tmp_path / "bench.json"
    bench.merge_json_out(out, _rec(samples=20))
    bench.merge_json_out(out, _rec(samples=40))          # different samples
    bench.merge_json_out(out, _rec(grid=(32, 8),
                                   grid_kind="oversized32x8-balanced"))
    bench.merge_json_out(out, _rec(dataset="amazon"))
    doc = json.loads(out.read_text())
    assert len(doc["runs"]) == 4
    # and re-running any one of them stays idempotent
    bench.merge_json_out(out, _rec(samples=40, wall=9.0))
    doc = json.loads(out.read_text())
    assert len(doc["runs"]) == 4
    hit = [r for r in doc["runs"] if r["samples"] == 40]
    assert len(hit) == 1 and hit[0]["records"][0]["wall_s"] == 9.0


def test_merge_migrates_legacy_single_run_layout(tmp_path):
    out = tmp_path / "bench.json"
    legacy = {"benchmark": "pp_engine", **_rec(dataset="netflix")}
    out.write_text(json.dumps(legacy))
    bench.merge_json_out(out, _rec(dataset="movielens"))
    doc = json.loads(out.read_text())
    assert len(doc["runs"]) == 2
    assert {r["dataset"] for r in doc["runs"]} == {"netflix", "movielens"}
    assert all("benchmark" not in r for r in doc["runs"])


def test_merge_runs_pure_function_roundtrip():
    doc = bench.merge_runs(None, _rec())
    doc2 = bench.merge_runs(doc, _rec(wall=3.0))
    assert len(doc2["runs"]) == 1
    assert doc2["runs"][0]["records"][0]["wall_s"] == 3.0
    assert bench._run_key(doc2["runs"][0]) == bench._run_key(_rec())


# ---------------------------------------------------------------------------
# bench_serving: same machinery, serving-config identity (mode x batch)
# ---------------------------------------------------------------------------


def _srec(dataset="movielens", grid=(4, 1), K=10, samples=20, slots=8,
          mode="mean", batch=8, p50=0.5, qps=1000.0):
    return {"dataset": dataset, "grid": list(grid), "K": K,
            "samples": samples, "slots": slots, "mode": mode,
            "batch": batch, "p50_ms": p50, "qps": qps}


def test_serving_merge_same_config_replaces(tmp_path):
    out = tmp_path / "bench.json"
    bench_srv.merge_json_out(out, _srec(p50=0.5))
    bench_srv.merge_json_out(out, _srec(p50=0.3))   # same config, re-run
    doc = json.loads(out.read_text())
    assert doc["benchmark"] == "serving"
    assert len(doc["runs"]) == 1
    assert doc["runs"][0]["p50_ms"] == 0.3


def test_serving_merge_mode_batch_sweep_coexists(tmp_path):
    out = tmp_path / "bench.json"
    for mode in ("mean", "thompson"):
        for batch in (1, 8, 32):
            bench_srv.merge_json_out(out, _srec(mode=mode, batch=batch))
    doc = json.loads(out.read_text())
    assert len(doc["runs"]) == 6
    bench_srv.merge_json_out(out, _srec(mode="thompson", batch=8, qps=77.0))
    doc = json.loads(out.read_text())
    assert len(doc["runs"]) == 6                    # replaced, not appended
    hit = [r for r in doc["runs"]
           if r["mode"] == "thompson" and r["batch"] == 8]
    assert len(hit) == 1 and hit[0]["qps"] == 77.0
    assert all("benchmark" not in r for r in doc["runs"])


def test_committed_serving_artifact_matches_merge_schema():
    """The checked-in BENCH_serving.json must be a fixpoint of the merge:
    re-merging any of its own records changes nothing."""
    path = ROOT / "BENCH_serving.json"
    doc = json.loads(path.read_text())
    assert doc["benchmark"] == "serving"
    keys = [bench_srv._run_key(r) for r in doc["runs"]]
    assert len(keys) == len(set(keys))              # config identity unique
    modes = {r["mode"] for r in doc["runs"]}
    batches = {r["batch"] for r in doc["runs"]}
    assert modes == {"mean", "thompson"} and len(batches) >= 3
    merged = doc
    for r in doc["runs"]:
        merged = bench_srv.merge_runs(merged, dict(r))
    assert merged == doc
