"""Integration tests: mini dry-run in a subprocess (8 fake devices), int8
KV-cache decode quality, checkpoint roundtrip, optimizer sanity."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

ROOT = Path(__file__).resolve().parents[1]

MINI_DRYRUN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.base import get_config, TrainConfig, InputShape
    from repro.models import steps as STEPS
    from repro.sharding import partitioning as PART
    from repro.roofline import jaxpr_cost as JC, analysis as ROOF

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = get_config("llama3_8b").smoke_variant()
    shape = InputShape("mini_train", 128, 8, "train")
    named = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                   is_leaf=lambda x: isinstance(x, P))
    params_s = STEPS.params_specs(cfg)
    p_sh = named(PART.param_specs(params_s, cfg, mesh))
    batch_s = STEPS.batch_specs(cfg, shape)
    opt_s = STEPS.opt_specs(cfg)
    b_sh = named(PART.batch_specs(batch_s, cfg, shape, mesh))
    o_sh = named(PART.opt_specs(opt_s, params_s, cfg, mesh))
    step = STEPS.make_train_step(cfg, TrainConfig(microbatches=2))
    import contextlib
    set_mesh = getattr(jax, "set_mesh", None)
    with (set_mesh(mesh) if set_mesh else contextlib.nullcontext()):
        tr = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, None),
                     donate_argnums=(0, 1)).trace(params_s, opt_s, batch_s)
        jc = JC.jaxpr_cost(tr.jaxpr)
        compiled = tr.lower().compile()
    terms = ROOF.terms_from(jc, compiled.as_text(), 8)
    print(json.dumps({"flops": terms.flops, "coll": terms.coll_bytes,
                      "dominant": terms.dominant}))
""")


@pytest.mark.slow
def test_mini_dryrun_subprocess():
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
    out = subprocess.run([sys.executable, "-c", MINI_DRYRUN], env=env,
                         capture_output=True, text=True, timeout=400)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["flops"] > 0
    assert rec["coll"] > 0          # TP attention/mlp must emit collectives


def test_int8_kv_decode_close_to_bf16():
    """Quantized-cache decode must track the full-precision logits."""
    import dataclasses
    from repro.configs.base import get_config
    from repro.models import model as MODEL
    from repro.models.kvcache import serve_cache_init

    cfg = dataclasses.replace(get_config("llama3_8b").smoke_variant(),
                              dtype="float32")
    params = MODEL.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (1, 10), 0, cfg.vocab_size)

    def run(quant):
        cache = serve_cache_init(cfg, 1, 64, dtype=jnp.float32,
                                 kv_quant=quant)
        logits = None
        for i in range(10):
            logits, cache = MODEL.decode_step(params, cfg, cache,
                                              toks[:, i:i + 1])
        return np.asarray(logits)

    full = run(False)
    quant = run(True)
    # int8 cache: small logit error, same argmax almost surely
    assert np.abs(full - quant).max() < 0.15, np.abs(full - quant).max()
    assert full.argmax() == quant.argmax()


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import ckpt
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    ckpt.save(tmp_path / "t", tree, step=7, extra={"note": "x"})
    like = jax.tree.map(jnp.zeros_like, tree)
    back = ckpt.restore(tmp_path / "t", like)
    np.testing.assert_allclose(np.asarray(back["a"]), np.asarray(tree["a"]))
    assert ckpt.manifest(tmp_path / "t")["step"] == 7


def test_adamw_converges_quadratic():
    from repro.configs.base import TrainConfig
    from repro.optim import adamw
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = adamw.init(params)
    tcfg = TrainConfig(learning_rate=0.1, weight_decay=0.0)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, opt = adamw.apply(params, g, opt, tcfg, 0.1)
    assert float(jnp.abs(params["w"]).max()) < 1e-2
