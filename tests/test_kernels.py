"""Per-kernel shape/dtype sweeps: Pallas (interpret=True on CPU) vs the
ref.py pure-jnp oracles, assert_allclose."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bmf_precision import ops as BMFK
from repro.kernels.decode_attention import ops as DECK
from repro.kernels.wkv6 import ops as WKVK
from repro.kernels.wkv6.ref import wkv_chunk_ref_batched

# ---------------------------------------------------------------------------
# bmf_precision
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("N,M,K", [(5, 17, 8), (16, 64, 10), (33, 100, 100),
                                   (8, 256, 16), (3, 512, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bmf_precision_sweep(N, M, K, dtype):
    rng = np.random.default_rng(42)
    D = 50
    idx = jnp.asarray(rng.integers(0, D, (N, M)), jnp.int32)
    val = jnp.asarray(rng.normal(size=(N, M)), jnp.float32)
    mask = jnp.asarray(rng.random((N, M)) < 0.8, jnp.float32)
    other = jnp.asarray(rng.normal(size=(D, K)), dtype)
    tau = 2.5

    Lam, eta = BMFK.precision_accum(idx, val, mask, other, tau)
    Lam_r, eta_r = BMFK.precision_accum_reference(idx, val, mask, other, tau)
    # f32 tol covers tile-accumulation-order roundoff vs the single-einsum
    # oracle (the fused/chunked paths sum per M-tile)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(Lam), np.asarray(Lam_r),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(eta), np.asarray(eta_r),
                               rtol=tol, atol=tol)


def _fused_case(rng, N, M, D, K, empty_rows=(), dtype=jnp.float32):
    idx = jnp.asarray(rng.integers(0, D, (N, M)), jnp.int32)
    val = jnp.asarray(rng.normal(size=(N, M)), jnp.float32)
    # contiguous-from-the-left CSR masks with ragged per-row occupancy
    nnz = rng.integers(0, M + 1, N)
    nnz[list(empty_rows)] = 0
    mask = jnp.asarray(np.arange(M)[None, :] < nnz[:, None], jnp.float32)
    other = jnp.asarray(rng.normal(size=(D, K)), dtype)
    return idx, val, mask, other


@pytest.mark.parametrize("N,M,K", [(5, 17, 8), (12, 40, 16), (9, 300, 128)])
def test_bmf_precision_fused_parity(N, M, K):
    """Fused-gather Pallas kernel (interpret mode) vs the dense oracle,
    with ragged occupancy and fully-empty rows (skipped M-tiles)."""
    rng = np.random.default_rng(7)
    idx, val, mask, other = _fused_case(rng, N, M, 37, K,
                                        empty_rows=(0, N - 1))
    Lam, eta = BMFK.precision_accum_fused(idx, val, mask, other, 1.3, tm=128)
    Lam_r, eta_r = BMFK.precision_accum_reference(idx, val, mask, other, 1.3)
    np.testing.assert_allclose(np.asarray(Lam), np.asarray(Lam_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(eta), np.asarray(eta_r),
                               rtol=1e-4, atol=1e-4)
    # empty rows must yield exactly-zero contributions
    assert float(jnp.abs(Lam[0]).max()) == 0.0
    assert float(jnp.abs(eta[-1]).max()) == 0.0


def test_bmf_precision_fused_n_striping():
    """A tiny SMEM budget forces the wrapper to stripe the N axis into
    several pallas_calls; parity with the oracle must hold across the
    stripe seams."""
    rng = np.random.default_rng(17)
    idx, val, mask, other = _fused_case(rng, 40, 50, 30, 8, empty_rows=(11,))
    # one TN-row stripe per call: 8 rows × Mp=128 slots × 4 B = 4 KB budget
    Lam, eta = BMFK.precision_accum_fused(idx, val, mask, other, 2.0,
                                          tm=128, smem_idx_budget=4096)
    Lam_r, eta_r = BMFK.precision_accum_reference(idx, val, mask, other, 2.0)
    np.testing.assert_allclose(np.asarray(Lam), np.asarray(Lam_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(eta), np.asarray(eta_r),
                               rtol=1e-4, atol=1e-4)


def test_bmf_precision_fused_bf16_and_truncated_rows():
    """bf16 factors + CSR built with truncating max_nnz bucketing."""
    from repro.data.sparse import COO, coo_to_padded_csr
    rng = np.random.default_rng(11)
    n_rows, n_cols, nnz = 19, 23, 400
    coo = COO(row=rng.integers(0, n_rows, nnz).astype(np.int32),
              col=rng.integers(0, n_cols, nnz).astype(np.int32),
              val=rng.normal(size=nnz).astype(np.float32),
              n_rows=n_rows, n_cols=n_cols)
    csr = coo_to_padded_csr(coo, max_nnz=16)      # truncates heavy rows
    other = jnp.asarray(rng.normal(size=(n_cols, 8)), jnp.bfloat16)
    Lam, eta = BMFK.precision_accum_fused(csr.idx, csr.val, csr.mask,
                                          other, 2.0, tm=128)
    Lam_r, eta_r = BMFK.precision_accum_reference(csr.idx, csr.val, csr.mask,
                                                  other, 2.0)
    np.testing.assert_allclose(np.asarray(Lam), np.asarray(Lam_r),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(eta), np.asarray(eta_r),
                               rtol=2e-2, atol=2e-2)


def test_bmf_precision_no_gather_materialization():
    """Regression: no path of ``precision_accum`` may have an (N, M, K)-sized
    live buffer — the fused kernel gathers inside, the XLA fallback chunks.
    The dense reference DOES materialize it (sanity check that the probe
    bites)."""
    from repro.roofline.jaxpr_cost import iter_avals
    rng = np.random.default_rng(13)
    N, M, D, K = 32, 8192, 64, 16   # N·M·K well above CHUNK_BUDGET_ELEMS
    idx, val, mask, other = _fused_case(rng, N, M, D, K)
    budget = N * M * K          # elements of the banned gathered tensor

    def peak(fn):
        jaxpr = jax.make_jaxpr(fn)(idx, val, mask, other)
        return max(int(np.prod(a.shape)) for a in iter_avals(jaxpr)
                   if a.shape)

    assert peak(lambda *a: BMFK.precision_accum(*a, tau=2.0)) < budget
    assert peak(lambda *a: BMFK.precision_accum_chunked(*a, 2.0)) < budget
    assert peak(lambda *a: BMFK.precision_accum_fused(*a, 2.0)) < budget
    assert peak(lambda *a: BMFK.precision_accum_reference(*a, 2.0)) >= budget


def test_tile_occupancy_counts():
    from repro.data.sparse import tile_occupancy
    mask = np.zeros((16, 512), np.float32)
    mask[0, :300] = 1.0      # row tile 0: occupancy 300 -> 2 tiles of 256
    mask[9, :1] = 1.0        # row tile 1: single slot -> 1 tile
    nt = np.asarray(tile_occupancy(jnp.asarray(mask), 8, 256))
    np.testing.assert_array_equal(nt, [2, 1])
    nt0 = np.asarray(tile_occupancy(jnp.zeros((8, 256)), 8, 256))
    np.testing.assert_array_equal(nt0, [0])


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,H,Hkv,hd,S", [
    (2, 8, 2, 64, 512), (1, 4, 4, 128, 1024), (2, 16, 8, 64, 700),
    (1, 32, 8, 128, 512),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, H, Hkv, hd, S, dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, H, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, hd)), dtype)
    q_pos = S - 100
    kv_pos = jnp.where(jnp.arange(S) <= q_pos, jnp.arange(S), -1)

    out = DECK.decode_attention(q, k, v, kv_pos, q_pos)
    ref = DECK.decode_attention_reference(q, k, v, kv_pos, q_pos)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=tol, atol=tol)


def test_decode_attention_sliding_window():
    rng = np.random.default_rng(1)
    B, H, Hkv, hd, S = 1, 4, 2, 64, 512
    q = jnp.asarray(rng.normal(size=(B, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, hd)), jnp.float32)
    q_pos = 400
    kv_pos = jnp.where(jnp.arange(S) <= q_pos, jnp.arange(S), -1)
    for window in (64, 128):
        out = DECK.decode_attention(q, k, v, kv_pos, q_pos, window=window)
        ref = DECK.decode_attention_reference(q, k, v, kv_pos, q_pos,
                                              window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


def test_decode_attention_ring_cache_positions():
    """Slots out of temporal order (ring buffer) must still mask correctly."""
    rng = np.random.default_rng(2)
    B, H, Hkv, hd, S = 1, 2, 1, 64, 512
    q = jnp.asarray(rng.normal(size=(B, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, hd)), jnp.float32)
    # ring layout: slot i holds position (1000 - S + i) for first half,
    # second half empty
    pos = np.full(S, -1, np.int32)
    pos[:256] = 700 + np.arange(256)
    kv_pos = jnp.asarray(np.roll(pos, 40))
    q_pos = 955
    out = DECK.decode_attention(q, k, v, kv_pos, q_pos, window=128)
    ref = DECK.decode_attention_reference(q, k, v, kv_pos, q_pos, window=128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# wkv6
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,S,H,N", [(1, 128, 2, 64), (2, 256, 1, 64),
                                     (1, 384, 4, 32)])
def test_wkv6_sweep(B, S, H, N):
    rng = np.random.default_rng(3)
    r = jnp.asarray(rng.normal(size=(B, S, H, N)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, N)), jnp.float32) * 0.5
    v = jnp.asarray(rng.normal(size=(B, S, H, N)), jnp.float32)
    logw = -jnp.exp(jnp.asarray(rng.normal(size=(B, S, H, N)), jnp.float32) - 2)
    u = jnp.asarray(rng.normal(size=(H, N)), jnp.float32) * 0.1
    s0 = jnp.asarray(rng.normal(size=(B, H, N, N)), jnp.float32) * 0.1

    y, st = WKVK.wkv6(r, k, v, logw, u, s0)
    y_ref, st_ref = WKVK.wkv6_reference(r, k, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# ssd_chunk (mamba2)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,S,H,P,N", [(1, 128, 2, 64, 64), (2, 256, 3, 32, 16),
                                       (1, 384, 1, 64, 64)])
def test_ssd_chunk_sweep(B, S, H, P, N):
    from repro.kernels.ssd_chunk import ops as SSDK
    rng = np.random.default_rng(5)
    xdt = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32) * 0.5
    a = -jnp.exp(jnp.asarray(rng.normal(size=(B, S, H)), jnp.float32) - 1)
    B_ = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32) * 0.5
    C_ = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32) * 0.5
    s0 = jnp.asarray(rng.normal(size=(B, H, P, N)), jnp.float32) * 0.1
    y, st = SSDK.ssd_scan(xdt, a, B_, C_, s0)
    y_ref, st_ref = SSDK.ssd_scan_reference(xdt, a, B_, C_, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               rtol=2e-4, atol=2e-4)
