"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")   # container images without hypothesis
from hypothesis import given, settings, strategies as st

from repro.core import posterior as POST
from repro.core.partition import (coalesce_shapes, nnz_balance_stats,
                                  partition, suggest_grid)
from repro.data.sparse import (COO, apply_permutation, balance_permutation,
                               coo_to_padded_csr)

jax.config.update("jax_platform_name", "cpu")

_settings = settings(max_examples=20, deadline=None)


# ---------------------------------------------------------------------------
# Gaussian natural-parameter algebra
# ---------------------------------------------------------------------------


@st.composite
def row_gaussians(draw, n=3, k=3):
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(n, k, k))
    Lam = A @ A.transpose(0, 2, 1) + (1 + draw(st.floats(0.1, 5.0))) * np.eye(k)
    eta = rng.normal(size=(n, k), scale=draw(st.floats(0.1, 3.0)))
    return POST.RowGaussians(jnp.asarray(eta, jnp.float32),
                             jnp.asarray(Lam, jnp.float32))


@_settings
@given(row_gaussians(), row_gaussians())
def test_product_commutes(a, b):
    ab = POST.product(a, b)
    ba = POST.product(b, a)
    np.testing.assert_allclose(np.asarray(ab.eta), np.asarray(ba.eta), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(ab.Lambda), np.asarray(ba.Lambda),
                               rtol=1e-6)


@_settings
@given(row_gaussians(), row_gaussians())
def test_divide_inverts_product(a, b):
    back = POST.divide(POST.product(a, b), b)
    np.testing.assert_allclose(np.asarray(back.eta), np.asarray(a.eta),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(back.Lambda), np.asarray(a.Lambda),
                               rtol=1e-4, atol=1e-4)


@_settings
@given(row_gaussians())
def test_mean_consistent_with_natural_params(g):
    mu = np.asarray(g.mean)
    eta = np.einsum("nij,nj->ni", np.asarray(g.Lambda), mu)
    np.testing.assert_allclose(eta, np.asarray(g.eta), rtol=1e-3, atol=1e-3)


@_settings
@given(st.integers(0, 1000), st.integers(2, 6))
def test_wishart_sample_psd(seed, k):
    W = POST.sample_wishart(jax.random.key(seed), jnp.eye(k), float(k + 2))
    evals = np.linalg.eigvalsh(np.asarray(W))
    assert (evals > -1e-4).all(), evals


# ---------------------------------------------------------------------------
# Partitioning
# ---------------------------------------------------------------------------


@st.composite
def random_coo(draw):
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    n = draw(st.integers(10, 80))
    d = draw(st.integers(8, 60))
    nnz = draw(st.integers(5, 200))
    rows = rng.integers(0, n, nnz)
    cols = rng.integers(0, d, nnz)
    key = rows * d + cols
    _, uniq = np.unique(key, return_index=True)
    return COO(row=rows[uniq].astype(np.int32), col=cols[uniq].astype(np.int32),
               val=rng.normal(size=len(uniq)).astype(np.float32),
               n_rows=n, n_cols=d)


@_settings
@given(random_coo(), st.integers(1, 4), st.integers(1, 4))
def test_partition_preserves_every_rating(coo, I, J):
    part = partition(coo, I, J)
    total = sum(b.coo.nnz for b in part.all_blocks())
    assert total == coo.nnz
    # values preserved as a multiset
    vals = np.sort(np.concatenate([b.coo.val for b in part.all_blocks()]))
    np.testing.assert_allclose(vals, np.sort(coo.val))


@_settings
@given(random_coo())
def test_balance_permutation_is_permutation(coo):
    perm = balance_permutation(coo, "row")
    assert sorted(perm.tolist()) == list(range(coo.n_rows))


@_settings
@given(random_coo())
def test_padded_csr_roundtrip(coo):
    csr = coo_to_padded_csr(coo)
    total = float(np.asarray(csr.mask).sum())
    assert total == coo.nnz
    # sum of values preserved
    np.testing.assert_allclose(float((np.asarray(csr.val) *
                                      np.asarray(csr.mask)).sum()),
                               float(coo.val.sum()), rtol=1e-4, atol=1e-3)


@_settings
@given(st.integers(100, 10**6), st.integers(100, 10**6),
       st.sampled_from([4, 16, 64]))
def test_suggest_grid_factors(n, d, blocks):
    I, J = suggest_grid(n, d, blocks)
    assert I * J == blocks
    assert I >= 1 and J >= 1


@_settings
@given(random_coo(), st.integers(1, 4), st.integers(1, 4),
       st.sampled_from([True, False, "none"]))
def test_occupancy_sorted_perms_are_permutations(coo, I, J, balance):
    """occupancy_sort composes a within-stripe refinement onto the global
    permutation — the result must remain a TRUE permutation for every
    balance mode (including the identity-permutation 'none' mode the
    skewed benchmarks rely on)."""
    part = partition(coo, I, J, balance=balance, occupancy_sort=True)
    assert sorted(part.row_perm.tolist()) == list(range(coo.n_rows))
    assert sorted(part.col_perm.tolist()) == list(range(coo.n_cols))


@_settings
@given(random_coo(), st.integers(1, 4), st.integers(1, 4),
       st.sampled_from([True, False, "none"]))
def test_occupancy_sort_preserves_stripes_and_balance(coo, I, J, balance):
    """occupancy_sort only reorders WITHIN stripes: stripe membership,
    per-block nnz balance, and total nnz are invariant — and within each
    stripe the rating counts end up non-increasing."""
    kw = dict(balance=balance, seed=3)
    p_sorted = partition(coo, I, J, occupancy_sort=True, **kw)
    p_plain = partition(coo, I, J, occupancy_sort=False, **kw)
    assert nnz_balance_stats(p_sorted) == nnz_balance_stats(p_plain)
    assert sum(b.coo.nnz for b in p_sorted.all_blocks()) == coo.nnz
    # stripe membership: the same original rows land in each stripe
    for perm_s, perm_p, splits in (
            (p_sorted.row_perm, p_plain.row_perm, p_sorted.row_splits),
            (p_sorted.col_perm, p_plain.col_perm, p_sorted.col_splits)):
        inv_s = np.argsort(perm_s)
        inv_p = np.argsort(perm_p)
        for lo, hi in zip(splits[:-1], splits[1:]):
            assert set(inv_s[lo:hi]) == set(inv_p[lo:hi])
    # within-stripe counts are non-increasing after the sort
    pc = apply_permutation(coo, p_sorted.row_perm, p_sorted.col_perm)
    counts = np.bincount(pc.row, minlength=coo.n_rows)
    for lo, hi in zip(p_sorted.row_splits[:-1], p_sorted.row_splits[1:]):
        assert (np.diff(counts[lo:hi]) <= 0).all()


# ---------------------------------------------------------------------------
# Bucket coalescing (streaming window shapes)
# ---------------------------------------------------------------------------


@st.composite
def shape_dicts(draw):
    n = draw(st.integers(1, 8))
    dims = draw(st.integers(1, 5))
    return {f"b{i}": tuple(draw(st.integers(1, 512)) for _ in range(dims))
            for i in range(n)}


def _footprint(t):
    return float(np.prod(t))


@_settings
@given(shape_dicts(), st.floats(1.0, 3.0))
def test_coalesce_never_merges_incompatible_shapes(shapes, max_waste):
    """The waste budget IS the compatibility rule: every bucket's merged
    shape must (a) dominate its own shape elementwise — merging never
    shrinks a buffer below what its blocks need — and (b) inflate its
    footprint by at most max_waste."""
    merged = coalesce_shapes(shapes, _footprint, max_waste=max_waste)
    assert set(merged) == set(shapes)
    for k, s in shapes.items():
        m = merged[k]
        assert all(a >= b for a, b in zip(m, s)), (k, m, s)
        assert _footprint(m) <= max_waste * _footprint(s) + 1e-9
    # group shapes are the elementwise max of their members
    groups = {}
    for k, m in merged.items():
        groups.setdefault(m, []).append(k)
    for m, members in groups.items():
        assert m == tuple(max(shapes[k][d] for k in members)
                          for d in range(len(m)))


@_settings
@given(shape_dicts())
def test_coalesce_exact_budget_only_merges_identical(shapes):
    """max_waste=1.0 (the streaming executor's default) merges ONLY
    bit-identical shapes — the setting under which streaming chains stay
    exactly parity with the serial reference."""
    merged = coalesce_shapes(shapes, _footprint, max_waste=1.0)
    for k, s in shapes.items():
        assert merged[k] == s


# ---------------------------------------------------------------------------
# MoE router
# ---------------------------------------------------------------------------


@_settings
@given(st.integers(0, 100))
def test_moe_router_weights_sum_to_one(seed):
    from repro.models.moe import _top_k_mask
    rng = np.random.default_rng(seed)
    probs = jax.nn.softmax(jnp.asarray(rng.normal(size=(4, 8, 16)),
                                       jnp.float32), -1)
    mask, w = _top_k_mask(probs, 4)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    assert int(mask.sum(-1).max()) == 4


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


@_settings
@given(st.integers(0, 100), st.sampled_from([0.5, 1.0]))
def test_rope_preserves_norm(seed, partial):
    from repro.models.layers import apply_rope, rope_frequencies
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(1, 6, 2, 64)), jnp.float32)
    inv, rot = rope_frequencies(64, partial, 10_000.0)
    pos = jnp.arange(6)[None, :]
    y = apply_rope(x, pos, inv, rot)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-4)
    # position 0 is identity
    np.testing.assert_allclose(np.asarray(y[:, 0]), np.asarray(x[:, 0]),
                               rtol=1e-5, atol=1e-6)
