"""Prefill flash-attention Pallas kernel vs jnp oracle — shape/dtype/mask
sweeps (interpret=True on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as FA


@pytest.mark.parametrize("B,Sq,H,Hkv,hd", [
    (1, 256, 4, 2, 64), (2, 512, 2, 2, 128), (1, 300, 8, 4, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal_sweep(B, Sq, H, Hkv, hd, dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, Sq, H, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, Sq, Hkv, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, Sq, Hkv, hd)), dtype)
    out = FA.flash_attention(q, k, v, causal=True)
    ref = FA.flash_attention_reference(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [128, 512])
def test_flash_attention_sliding_window(window):
    rng = np.random.default_rng(1)
    B, S, H, Hkv, hd = 1, 768, 4, 2, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, hd)), jnp.float32)
    out = FA.flash_attention(q, k, v, causal=True, window=window)
    ref = FA.flash_attention_reference(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_noncausal():
    """Encoder-style bidirectional attention (whisper encoder)."""
    rng = np.random.default_rng(2)
    B, S, H, hd = 1, 512, 2, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    out = FA.flash_attention(q, k, v, causal=False)
    ref = FA.flash_attention_reference(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("Hkv,window", [(2, 0), (4, 256), (1, 0)])
def test_flash_attention_vjp_matches_ref_grad(Hkv, window):
    """custom-VJP (two Pallas bwd kernels) vs jax.grad of the jnp oracle."""
    import jax
    rng = np.random.default_rng(7)
    B, S, H, hd = 1, 512, 4, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, hd)), jnp.float32)
    tgt = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)

    def loss_kernel(q, k, v):
        o = FA.flash_attention_trainable(q, k, v, True, window)
        return jnp.sum((o - tgt) ** 2)

    def loss_ref(q, k, v):
        o = FA.flash_attention_reference(q, k, v, causal=True, window=window)
        return jnp.sum((o - tgt) ** 2)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(gk, gr, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4,
                                   err_msg=f"d{name} mismatch")
