"""Prefill/decode consistency: decoding token-by-token from scratch must give
the same last-token logits as prefill over the whole prompt (same params,
same tokens) — for every family that supports prefill."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import model as MODEL
from repro.models.kvcache import serve_cache_init

B, S = 1, 12

FAMILIES = ["llama3_8b", "mixtral_8x7b", "rwkv6_7b", "zamba2_7b"]


@pytest.mark.parametrize("arch_id", FAMILIES)
def test_prefill_matches_stepwise_decode(arch_id):
    cfg = get_config(arch_id).smoke_variant()
    # float32 end-to-end for a tight comparison; capacity factor large enough
    # that MoE never drops tokens (capacity drops legitimately differ between
    # a 12-token prefill group and per-token decode groups)
    import dataclasses
    cfg = dataclasses.replace(cfg, dtype="float32", moe_capacity_factor=100.0)
    key = jax.random.key(0)
    params = MODEL.init_params(key, cfg)
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)

    # path A: prefill the whole prompt
    cache_a = serve_cache_init(cfg, B, 64, dtype=jnp.float32)
    logits_a, cache_a = MODEL.prefill(params, cfg, {"tokens": tokens}, cache_a)

    # path B: feed tokens one-by-one through decode_step
    cache_b = serve_cache_init(cfg, B, 64, dtype=jnp.float32)
    step = jax.jit(lambda c, t: MODEL.decode_step(params, cfg, c, t))
    for i in range(S):
        logits_b, cache_b = step(cache_b, tokens[:, i:i + 1])

    np.testing.assert_allclose(np.asarray(logits_a[:, 0]),
                               np.asarray(logits_b[:, 0]),
                               rtol=2e-3, atol=2e-3)
    # caches agree on position
    assert int(cache_a["pos"]) == int(cache_b["pos"]) == S
