"""Serving-layer battery: top-K parity, Thompson statistics, store
round-trip conformance over the executor registry, router semantics, and
the scoring-path lint.

Parity is asserted against a dense numpy brute-force reference whose
tie-break rule (stable: lowest index wins among equal scores) matches
``lax.top_k``, across the edge cases that break naive implementations:
k > n_unseen, every item seen, bitwise-duplicate scores, and empty-history
cold-start. The Thompson test mirrors the ``sample_nw`` moment-test style
in test_properties.py: selection frequencies over ~4000 per-request
posterior draws must match win probabilities computed analytically from
the stored covariances.

The store round-trip battery parametrizes over ``engine.EXECUTORS`` like
test_executor_conformance.py — registering a new executor auto-enrolls it
here, and the staleness assert fails if this module's list drifts.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import analysis as LINT
from repro import serving as SRV
from repro.core import bmf as BMF
from repro.core import engine as ENG
from repro.core import pp as PP
from repro.core.partition import partition
from repro.core.posterior import RowGaussians
from repro.data import synthetic as SYN
from repro.data.sparse import train_test_split
from repro.serving import scoring as SCORE
from repro.serving import store as STORE

pytestmark = pytest.mark.serving

EXECUTOR_NAMES = sorted(ENG.EXECUTORS)

# exact power of two: scale/rescale by PREC is bitwise-lossless in f32,
# so direct stores built from means reproduce those means exactly
PREC = float(2 ** 26)


def direct_store(U_mean, V_mean, n_slots=3, tau=2.0, V_samples=None,
                 U_Lambda=None):
    """Store with posteriors concentrated at the given means (Λ = PREC·I
    unless ``U_Lambda`` overrides the user side) and deterministic item
    sample slots (copies of V_mean unless ``V_samples`` is given)."""
    U_mean = jnp.asarray(U_mean, jnp.float32)
    V_mean = jnp.asarray(V_mean, jnp.float32)
    (N, K), M = U_mean.shape, V_mean.shape[0]
    eyeK = jnp.eye(K, dtype=jnp.float32)
    if U_Lambda is None:
        U = RowGaussians(eta=PREC * U_mean,
                         Lambda=jnp.broadcast_to(PREC * eyeK, (N, K, K)))
    else:
        U_Lambda = jnp.asarray(U_Lambda, jnp.float32)
        U = RowGaussians(
            eta=jnp.einsum("nkl,nl->nk", U_Lambda, U_mean), Lambda=U_Lambda)
    V = RowGaussians(eta=PREC * V_mean,
                     Lambda=jnp.broadcast_to(PREC * eyeK, (M, K, K)))
    if V_samples is None:
        V_samples = jnp.broadcast_to(V_mean, (n_slots, M, K))
    return SRV.PosteriorStore(U=U, V=V, U_mean=U_mean, V_mean=V_mean,
                              V_samples=jnp.asarray(V_samples, jnp.float32),
                              tau=jnp.asarray(tau, jnp.float32))


def make_batch(user_ids, M, seen=None, L=8, fold=None, F=2, seed=0):
    """Fixed-shape RequestBatch from ragged per-request seen/fold lists."""
    B = len(user_ids)
    seen = seen or [[] for _ in range(B)]
    fold = fold or [[] for _ in range(B)]
    s_idx = np.zeros((B, L), np.int32)
    s_msk = np.zeros((B, L), np.float32)
    f_idx = np.zeros((B, F), np.int32)
    f_val = np.zeros((B, F), np.float32)
    f_msk = np.zeros((B, F), np.float32)
    for i in range(B):
        ns = len(seen[i])
        s_idx[i, :ns] = seen[i]
        s_msk[i, :ns] = 1.0
        for j, (it, rt) in enumerate(fold[i]):
            f_idx[i, j], f_val[i, j], f_msk[i, j] = it, rt, 1.0
    kd = np.random.default_rng(seed).integers(0, 2 ** 32, (B, 2),
                                              dtype=np.uint32)
    return SRV.RequestBatch(
        user_ids=jnp.asarray(user_ids, jnp.int32),
        seen_idx=jnp.asarray(s_idx), seen_mask=jnp.asarray(s_msk),
        fold_idx=jnp.asarray(f_idx), fold_val=jnp.asarray(f_val),
        fold_mask=jnp.asarray(f_msk), key_data=jnp.asarray(kd))


def brute_topk(scores, seen, k):
    """Dense numpy reference: stable sort by (-score, index)."""
    s = np.array(scores, np.float32, copy=True)
    if len(seen):
        s[np.asarray(seen, int)] = -np.inf
    order = np.lexsort((np.arange(len(s)), -s))
    ids = order[:k].astype(np.int32)
    return ids, s[ids]


def raw_scores(store, user_id, batch_like):
    """Full unmasked score vector through the SAME executable shape (mask
    zeroed, k = M), so parity compares selection semantics bitwise."""
    b = batch_like._replace(
        user_ids=jnp.asarray([user_id], jnp.int32),
        seen_mask=jnp.zeros_like(batch_like.seen_mask))
    out = SRV.score_topk(store, b, k=store.n_items, mode="mean")
    full = np.empty(store.n_items, np.float32)
    full[np.asarray(out.ids[0])] = np.asarray(out.scores[0])
    return full


# ---------------------------------------------------------------------------
# top-K parity battery
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def parity_store():
    rng = np.random.default_rng(11)
    N, M, K = 6, 12, 3
    return direct_store(rng.normal(size=(N, K)), rng.normal(size=(M, K)))


def _assert_parity(store, user, seen, k, L=12):
    batch = make_batch([user], store.n_items, seen=[list(seen)], L=L)
    out = SRV.score_topk(store, batch, k=k, mode="mean")
    full = raw_scores(store, user, batch)
    ref_ids, ref_scores = brute_topk(full, seen, k)
    np.testing.assert_array_equal(np.asarray(out.ids[0]), ref_ids)
    np.testing.assert_array_equal(np.asarray(out.scores[0]), ref_scores)
    np.testing.assert_array_equal(np.asarray(out.valid[0]),
                                  ref_scores > -np.inf)


def test_parity_unmasked_and_random_seen(parity_store):
    _assert_parity(parity_store, user=0, seen=[], k=5)
    rng = np.random.default_rng(3)
    for case in range(10):
        seen = rng.choice(12, size=rng.integers(0, 9), replace=False)
        _assert_parity(parity_store, user=int(case % 6), seen=seen,
                       k=int(rng.integers(1, 12)))


def test_parity_k_exceeds_unseen(parity_store):
    # 10 of 12 items seen, k=5 > 2 scorable: exactly two valid slots, the
    # -inf tail ordered by index in BOTH implementations
    seen = list(range(10))
    batch = make_batch([1], 12, seen=[seen], L=12)
    out = SRV.score_topk(parity_store, batch, k=5, mode="mean")
    assert int(np.asarray(out.valid[0]).sum()) == 2
    _assert_parity(parity_store, user=1, seen=seen, k=5)


def test_parity_all_items_seen(parity_store):
    seen = list(range(12))
    batch = make_batch([2], 12, seen=[seen], L=12)
    out = SRV.score_topk(parity_store, batch, k=4, mode="mean")
    assert not np.asarray(out.valid).any()
    _assert_parity(parity_store, user=2, seen=seen, k=4)


def test_parity_duplicate_scores_tie_break():
    # items 0..3 are bitwise-identical factor rows => bitwise-equal
    # scores; the winner among ties must be the LOWEST index (stable),
    # matching the lexsort reference
    rng = np.random.default_rng(5)
    v = rng.normal(size=(1, 3))
    V = np.concatenate([np.repeat(v, 4, axis=0),
                        rng.normal(size=(4, 3))], axis=0)
    store = direct_store(rng.normal(size=(2, 3)), V)
    _assert_parity(store, user=0, seen=[], k=8, L=8)
    _assert_parity(store, user=1, seen=[0, 2], k=6, L=8)


def test_parity_cold_start_empty_history(parity_store):
    # user_id = -1, nothing seen, nothing folded: identity prior => zero
    # mean => all scores tie at 0.0 and the top-K is [0..k-1], all valid
    batch = make_batch([-1], 12, L=12)
    out = SRV.score_topk(parity_store, batch, k=5, mode="mean")
    np.testing.assert_array_equal(np.asarray(out.ids[0]), np.arange(5))
    np.testing.assert_array_equal(np.asarray(out.scores[0]), np.zeros(5))
    assert np.asarray(out.valid).all()
    _assert_parity(parity_store, user=-1, seen=[], k=5)


def test_cold_start_fold_in_personalizes():
    # folding feedback into a cold-start request must move its ranking
    # toward the liked item's neighborhood (here: exact duplicate items
    # rank together at the top)
    rng = np.random.default_rng(7)
    V = 0.1 * rng.normal(size=(6, 4)).astype(np.float32)
    V[0] = [2.0, 0.0, 0.0, 0.0]
    V[3] = V[0]                       # item 3 duplicates item 0
    store = direct_store(rng.normal(size=(2, 4)), V)
    batch = make_batch([-1], 6, seen=[[0]], L=4,
                       fold=[[(0, 5.0)]], F=2)
    out = SRV.score_topk(store, batch, k=2, mode="mean")
    assert int(np.asarray(out.ids[0])[0]) == 3   # the unseen duplicate wins
    assert np.asarray(out.valid[0]).all()


# ---------------------------------------------------------------------------
# Thompson statistics (mirrors test_properties.py's moment-test style)
# ---------------------------------------------------------------------------


def _phi(x):
    return np.exp(-0.5 * x * x) / math.sqrt(2 * math.pi)


_erf = np.vectorize(math.erf)


def _Phi(x):
    return 0.5 * (1.0 + _erf(x / math.sqrt(2.0)))


def _thompson_freqs(store, n_draws, chunk=1000, seed=0):
    rng = np.random.default_rng(seed)
    M = store.n_items
    counts = np.zeros(M)
    for lo in range(0, n_draws, chunk):
        B = min(chunk, n_draws - lo)
        kd = rng.integers(0, 2 ** 32, (B, 2), dtype=np.uint32)
        batch = make_batch([0] * B, M, L=2, F=1)._replace(
            key_data=jnp.asarray(kd))
        out = SRV.score_topk(store, batch, k=1, mode="thompson")
        counts += np.bincount(np.asarray(out.ids[:, 0]), minlength=M)
    return counts / n_draws


def test_thompson_frequencies_match_analytic_win_probs():
    """Orthogonal item axes => scores are INDEPENDENT normals with known
    means/sds from the stored posterior covariance; per-item top-1
    frequencies over 4000 per-request draws must match the win
    probabilities P(i) = ∫ φ_i(x) Π_{j≠i} Φ_j(x) dx."""
    K = 4
    c = np.array([1.0, 1.5, 0.8, 1.2], np.float32)
    V = (np.eye(K) * c[:, None]).astype(np.float32)      # v_i = c_i e_i
    mu = np.array([[0.5, 0.2, 0.9, 0.4]], np.float32)
    prec = np.array([4.0, 2.0, 6.0, 3.0], np.float32)
    store = direct_store(mu, V, U_Lambda=np.diag(prec)[None].astype(
        np.float32))
    means = c * mu[0]
    sds = c / np.sqrt(prec)

    x = np.linspace((means - 8 * sds).min(), (means + 8 * sds).max(), 20001)
    pdf = _phi((x[None] - means[:, None]) / sds[:, None]) / sds[:, None]
    cdf = _Phi((x[None] - means[:, None]) / sds[:, None])
    probs = np.empty(K)
    for i in range(K):
        others = np.prod(np.delete(cdf, i, axis=0), axis=0)
        probs[i] = np.trapezoid(pdf[i] * others, x)
    assert abs(probs.sum() - 1.0) < 1e-6

    freqs = _thompson_freqs(store, n_draws=4000, seed=21)
    np.testing.assert_allclose(freqs, probs, atol=0.03)


def test_thompson_frequencies_correlated_pair():
    """Two NON-orthogonal items: the score difference is 1-D Gaussian, so
    P(item 0 wins) = Φ((m0 - m1) / sd(s0 - s1)) exactly."""
    v0 = np.array([1.0, 0.6], np.float32)
    v1 = np.array([0.4, 1.1], np.float32)
    V = np.stack([v0, v1])
    mu = np.array([[0.3, 0.5]], np.float32)
    prec = np.array([3.0, 5.0], np.float32)
    store = direct_store(mu, V, U_Lambda=np.diag(prec)[None].astype(
        np.float32))
    d = v0 - v1
    m = float(d @ mu[0])
    sd = float(np.sqrt(d @ np.diag(1.0 / prec) @ d))
    p0 = float(_Phi(np.asarray(m / sd)))

    freqs = _thompson_freqs(store, n_draws=4000, seed=22)
    np.testing.assert_allclose(freqs[0], p0, atol=0.03)


def test_mean_mode_bitwise_deterministic():
    rng = np.random.default_rng(9)
    store = direct_store(rng.normal(size=(5, 4)), rng.normal(size=(9, 4)))
    batch = make_batch([0, 3, -1], 9, seen=[[1], [], [4, 5]], L=4, seed=1)
    out1 = SRV.score_topk(store, batch, k=4, mode="mean")
    jax.clear_caches()                       # force a fresh compilation
    out2 = SRV.score_topk(store, batch, k=4, mode="mean")
    # different keys must not matter either: mean mode consumes no RNG
    out3 = SRV.score_topk(
        store, batch._replace(key_data=jnp.zeros_like(batch.key_data)),
        k=4, mode="mean")
    for o in (out2, out3):
        np.testing.assert_array_equal(np.asarray(out1.ids),
                                      np.asarray(o.ids))
        np.testing.assert_array_equal(np.asarray(out1.scores),
                                      np.asarray(o.scores))


# ---------------------------------------------------------------------------
# store construction: round-trip conformance over the executor registry
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def conf_run():
    coo, p = SYN.generate("mini", seed=13)
    train, test = train_test_split(coo, 0.15, seed=14)
    cfg = BMF.BMFConfig(K=p.K, n_samples=5, burnin=1)
    part = partition(train, 3, 3)          # covers all four phase tags
    key = jax.random.key(5)
    return part, cfg, test, key


@pytest.fixture(scope="module")
def pp_results(conf_run):
    part, cfg, test, key = conf_run
    cache = {}

    def get(name):
        if name not in cache:
            kw = {}
            if name == "sharded":
                from repro.core.topology import Topology
                kw["topology"] = Topology(block=1, data=1)
            if name == "streaming":
                kw["window"] = 2
            ex = ENG.make_executor(name, **kw)
            cache[name] = PP.run_pp(key, part, cfg, test, executor=ex)
        return cache[name]

    return get


def test_registry_coverage():
    # the battery covers the WHOLE registry — a new executor that isn't
    # parametrized here means this module is stale
    assert set(EXECUTOR_NAMES) == set(ENG.EXECUTORS)


@pytest.mark.parametrize("name", EXECUTOR_NAMES)
def test_store_roundtrip_bitwise(pp_results, name):
    """``from_pp_result`` must equal the host-side reference gather,
    bitwise: build one store via the jitted device gather and one from
    posteriors gathered in numpy (identity perm), then compare every
    field AND the scores they serve."""
    res = pp_results(name)
    key = jax.random.key(17)
    store = SRV.PosteriorStore.from_pp_result(res, key, n_slots=2)

    # the device gather itself is bitwise (natural params are untouched
    # copies of the aggregated posteriors)
    np.testing.assert_array_equal(
        np.asarray(store.U.eta), np.asarray(res.U_agg.eta)[res.row_perm])
    np.testing.assert_array_equal(
        np.asarray(store.V.eta), np.asarray(res.V_agg.eta)[res.col_perm])

    U_h = RowGaussians(
        eta=jnp.asarray(np.asarray(res.U_agg.eta)[res.row_perm]),
        Lambda=jnp.asarray(np.asarray(res.U_agg.Lambda)[res.row_perm]))
    V_h = RowGaussians(
        eta=jnp.asarray(np.asarray(res.V_agg.eta)[res.col_perm]),
        Lambda=jnp.asarray(np.asarray(res.V_agg.Lambda)[res.col_perm]))
    ref = STORE._build_store(
        U_h, V_h, jnp.arange(store.n_users, dtype=jnp.int32),
        jnp.arange(store.n_items, dtype=jnp.int32),
        jnp.asarray(res.tau, jnp.float32), key, n_slots=2, jitter=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(store),
                    jax.tree_util.tree_leaves(ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    batch = make_batch([0, 7, -1], store.n_items,
                       seen=[[1, 2], [], [5]], L=4, seed=2)
    for mode in SCORE.MODES:
        out = SRV.score_topk(store, batch, k=5, mode=mode)
        out_ref = SRV.score_topk(ref, batch, k=5, mode=mode)
        np.testing.assert_array_equal(np.asarray(out.ids),
                                      np.asarray(out_ref.ids))
        np.testing.assert_array_equal(np.asarray(out.scores),
                                      np.asarray(out_ref.scores))
        assert np.isfinite(np.asarray(out.scores)[np.asarray(out.valid)]
                           ).all()


def test_store_sanitizes_indefinite_precisions():
    """Divide-away aggregation can leave indefinite per-row precisions
    (sample-covariance noise); the store build must project them PD so
    every serving Cholesky is finite."""
    rng = np.random.default_rng(31)
    K = 4
    Lam = np.stack([np.eye(K, dtype=np.float32) * 3.0,
                    np.diag([5.0, -2.0, 1.0, 0.5]).astype(np.float32),
                    rng.normal(size=(K, K)).astype(np.float32)])
    Lam[2] = (Lam[2] + Lam[2].T) / 2 - 2 * np.eye(K, dtype=np.float32)
    g = RowGaussians(eta=jnp.asarray(rng.normal(size=(3, K)), jnp.float32),
                     Lambda=jnp.asarray(Lam))
    st = STORE._build_store(g, g, jnp.arange(3), jnp.arange(3),
                            jnp.asarray(2.0, jnp.float32),
                            jax.random.key(0), n_slots=2, jitter=1e-6)
    for side in (st.U, st.V):
        ev = np.linalg.eigvalsh(np.asarray(side.Lambda))
        assert (ev > 0).all(), ev
    assert np.isfinite(np.asarray(st.U_mean)).all()
    assert np.isfinite(np.asarray(st.V_samples)).all()


def test_from_pp_result_rejects_pre_seam_results(pp_results):
    import dataclasses
    res = dataclasses.replace(pp_results("serial"), row_perm=None)
    with pytest.raises(ValueError, match="serving export seam"):
        SRV.PosteriorStore.from_pp_result(res)


# ---------------------------------------------------------------------------
# micro-batching router
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def router_store():
    rng = np.random.default_rng(23)
    return direct_store(rng.normal(size=(8, 4)), rng.normal(size=(20, 4)))


def test_router_latency_budget_rule(router_store):
    r = SRV.MicroBatchRouter(router_store, k=3, latency_budget_s=0.01,
                             max_batch=4, max_seen=8, max_fold=2)
    t1 = r.submit(SRV.Request(user_id=1, seen=[2]), now=0.0)
    t2 = r.submit(SRV.Request(user_id=2), now=0.004)
    assert not t1.done and r.poll(now=0.009) == 0      # budget not hit
    assert r.poll(now=0.010) == 2                      # oldest waited 10ms
    assert t1.done and t2.done
    assert t1.latency_s == pytest.approx(0.010)
    assert t2.latency_s == pytest.approx(0.006)
    assert len(r.dispatches) == 1 and r.dispatches[0][1] == 2


def test_router_full_batch_dispatches_immediately(router_store):
    r = SRV.MicroBatchRouter(router_store, k=3, latency_budget_s=10.0,
                             max_batch=3, max_seen=8, max_fold=2)
    ts = [r.submit(SRV.Request(user_id=i), now=0.0) for i in range(3)]
    assert all(t.done for t in ts)                     # no budget wait
    assert r.dispatches[0][1] == 3


def test_router_padding_is_invisible(router_store):
    """A partially-filled bucket (3 real requests padded to 4) must serve
    results bitwise-equal to a hand-built padded batch through the same
    executable."""
    reqs = [SRV.Request(user_id=0, seen=[1, 2]),
            SRV.Request(user_id=5),
            SRV.Request(user_id=-1, fold_items=[3], fold_ratings=[4.0])]
    r = SRV.MicroBatchRouter(router_store, k=4, mode="mean",
                             latency_budget_s=0.0, max_batch=4,
                             max_seen=8, max_fold=2)
    ts = [r.submit(q, now=0.0) for q in reqs]
    r.flush(now=0.0)
    shape = r.dispatches[0][0]
    batch = make_batch([0, 5, -1, -1], router_store.n_items,
                       seen=[[1, 2], [], [], []],
                       fold=[[], [], [(3, 4.0)], []],
                       L=shape[1], F=shape[2])
    ref = SRV.score_topk(router_store, batch, k=4, mode="mean")
    for i, t in enumerate(ts):
        np.testing.assert_array_equal(t.ids, np.asarray(ref.ids)[i])
        np.testing.assert_array_equal(t.scores, np.asarray(ref.scores)[i])


def test_router_thompson_end_to_end(router_store):
    r = SRV.MicroBatchRouter(router_store, k=3, mode="thompson",
                             latency_budget_s=0.0, max_batch=2,
                             max_seen=8, max_fold=2, seed=4)
    ts = [r.submit(SRV.Request(user_id=i, seen=[0]), now=0.0)
          for i in range(4)]
    r.flush(now=0.0)
    for t in ts:
        assert t.done and t.valid.all()
        assert 0 not in t.ids                      # seen item masked
        assert (t.ids < router_store.n_items).all()


def test_router_caps_and_plan():
    # at realistic serving dims the per-request (M, K) cost dominates the
    # seen/fold request-plane arrays, so the full default ladder coalesces
    # under the plan cap the lint pass enforces (PlanArtifact cap = 8);
    # the router never touches store values, so the abstract store works
    from repro.launch.bmf_lint import SERVE_DIMS as d
    store = SCORE.abstract_store(d["n_users"], d["n_items"], d["K"],
                                 d["n_slots"])
    r = SRV.MicroBatchRouter(store, max_batch=32, max_seen=64, max_fold=8)
    assert 1 <= len(r.plan_signatures) <= 8
    assert all(s in r.plan_signatures for s in r.bucket_table.values())
    # bucket_for is monotone in every dim and rejects over-cap requests
    b1 = r.bucket_for(1, 0, 0)
    b2 = r.bucket_for(32, 64, 8)
    assert all(a <= b for a, b in zip(b1, b2))
    with pytest.raises(ValueError, match="exceeds"):
        r.submit(SRV.Request(user_id=0, seen=list(range(65))))
    with pytest.raises(ValueError, match="mismatch"):
        r.submit(SRV.Request(user_id=0, fold_items=[1], fold_ratings=[]))
    with pytest.raises(ValueError, match="unknown scoring mode"):
        SRV.MicroBatchRouter(store, mode="greedy")


# ---------------------------------------------------------------------------
# scoring-path lint: no dense (N, M) score matrix, host-callback-free
# ---------------------------------------------------------------------------


def test_scoring_lint_zero_violations():
    """The shipped lint wiring (bmf_lint.serving_artifacts) must analyze
    clean: both mode jaxprs under scoring_budget plus the router plan."""
    from repro.launch import bmf_lint
    for art in bmf_lint.serving_artifacts():
        assert LINT.analyze(art) == [], art.label


def test_dense_all_users_scoring_trips_materialization_pass():
    """The banned formulation — score EVERY user against every item at
    once — materializes the (N, M) matrix and must trip the pass the
    serving lint runs."""
    from repro.launch.bmf_lint import SERVE_DIMS as d
    store = SCORE.abstract_store(d["n_users"], d["n_items"], d["K"],
                                 d["n_slots"])
    traced = jax.jit(lambda s: s.U_mean @ s.V_mean.T).trace(store)
    art = LINT.JaxprArtifact(
        label="serving/dense_all_users/jaxpr", jaxpr=traced.jaxpr,
        bytes_budget=SCORE.scoring_budget(d["n_users"], d["n_items"],
                                          d["K"], d["batch"], d["n_slots"]))
    vs = LINT.analyze(art)
    assert any(v.pass_name == "materialization" for v in vs), vs


def test_scoring_stays_device_resident(parity_store):
    """Runtime twin of the host-callback pass: a warm scoring executable
    must run under jax.transfer_guard('disallow')."""
    from repro.analysis import guards as GUARDS
    batch = make_batch([0, 1], 12, seen=[[3], []], L=4, seed=6)
    batch = jax.device_put(batch)
    store = jax.device_put(parity_store)
    SRV.score_topk(store, batch, k=3, mode="thompson")   # warm
    with GUARDS.no_host_transfers():
        out = SRV.score_topk(store, batch, k=3, mode="thompson")
    jax.block_until_ready(out)


def test_score_topk_rejects_unknown_mode(parity_store):
    batch = make_batch([0], 12, L=4)
    with pytest.raises(ValueError, match="unknown scoring mode"):
        SRV.score_topk(parity_store, batch, k=3, mode="map")
