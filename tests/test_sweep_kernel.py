"""One-kernel Gibbs sweep (kernels/bmf_sweep) conformance:

  * interpret-mode Pallas vs the striped-XLA fallback: both paths run the
    same tile helpers over the same padded operands in the same M-tile
    order, so in the single-stripe regime (eager dispatch on both sides)
    parity is BITWISE and asserted with assert_array_equal. Striped under
    ``lax.map`` the fallback compiles as one fused body and XLA CPU
    fast-math contraction shifts results a few ulps — same math, asserted
    at 1e-5 (see ref.py on the parity contract);
  * the in-register Cholesky/solve sampler is checked two ways: per-draw
    against ``posterior.sample_rows_noise`` (same z => same sample up to
    solver roundoff) and statistically (4000 draws reproduce the analytic
    Gibbs-conditional mean/covariance);
  * ``gibbs._summarize``'s relative ridge: the old ABSOLUTE 1e-4 ridge
    vanishes in f32 against rank-deficient moment estimates at 1e4 row
    scale (1e8-scale variances absorb the nudge), while the scaled ridge
    stays finite — and O(1)-scale rows remain bit-for-bit unchanged;
  * the dtype-promotion lint pass proves bf16 never reaches the
    factor/solve path of the traced fused step (and still fires on a
    planted bf16 sqrt).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bmf as BMF
from repro.core import gibbs as GIBBS
from repro.core import posterior as POST
from repro.kernels.bmf_sweep import ops as SWEEP


def _case(rng, N, M, D, K, empty_rows=(), scale=1.0):
    """Random padded-CSR factor-step inputs with ragged left-contiguous
    occupancy and per-row PD priors."""
    idx = jnp.asarray(rng.integers(0, D, (N, M)), jnp.int32)
    val = jnp.asarray(rng.normal(size=(N, M)) * scale, jnp.float32)
    nnz = rng.integers(0, M + 1, N)
    nnz[list(empty_rows)] = 0
    mask = jnp.asarray(np.arange(M)[None, :] < nnz[:, None], jnp.float32)
    other = jnp.asarray(rng.normal(size=(D, K)), jnp.float32)
    pe = jnp.asarray(rng.normal(size=(N, K)) * 0.3, jnp.float32)
    A = rng.normal(size=(N, K, K)) * 0.2
    pL = jnp.asarray(np.einsum("nij,nkj->nik", A, A)
                     + 1.5 * np.eye(K)[None], jnp.float32)
    z = jnp.asarray(rng.normal(size=(N, K)), jnp.float32)
    return idx, val, mask, pe, pL, z, other


# ---------------------------------------------------------------------------
# bitwise parity: interpret-mode Pallas vs striped-XLA fallback
# ---------------------------------------------------------------------------


# dims shaped like the engine's row buckets: ragged small and a
# TN-unaligned N, one M-tile each. n_stripe covers all rows => one eager
# dispatch per path => bitwise.
@pytest.mark.parametrize("N,M,D,K", [(5, 17, 23, 8), (19, 40, 31, 12)])
@pytest.mark.parametrize("dtype", ["fp32", "bf16"])
def test_fused_vs_ref_bitwise(N, M, D, K, dtype):
    rng = np.random.default_rng(3)
    idx, val, mask, pe, pL, z, other = _case(rng, N, M, D, K,
                                             empty_rows=(0, N - 1))
    kw = dict(dtype=dtype, tau=1.7, n_stripe=N)
    U_pal = SWEEP.fused_sweep(z, idx, val, mask, pe, pL, other,
                              force="pallas", interpret=True, **kw)
    U_ref = SWEEP.fused_sweep(z, idx, val, mask, pe, pL, other,
                              force="ref", **kw)
    assert U_pal.shape == (N, K)
    assert bool(jnp.all(jnp.isfinite(U_pal)))
    np.testing.assert_array_equal(np.asarray(U_pal), np.asarray(U_ref))


@pytest.mark.parametrize("dtype", ["fp32", "bf16"])
def test_fused_vs_ref_multi_m_tile(dtype):
    """M=300 pads to two tm=256 tiles: the kernel's scratch-accumulate
    revisits the row block across grid steps while the fallback loops in
    one trace — an extra fused-rounding context, so this leg is deep-ulp
    allclose rather than bitwise."""
    rng = np.random.default_rng(3)
    idx, val, mask, pe, pL, z, other = _case(rng, 16, 300, 48, 8,
                                             empty_rows=(0, 15))
    kw = dict(dtype=dtype, tau=1.7, n_stripe=16)
    U_pal = SWEEP.fused_sweep(z, idx, val, mask, pe, pL, other,
                              force="pallas", interpret=True, **kw)
    U_ref = SWEEP.fused_sweep(z, idx, val, mask, pe, pL, other,
                              force="ref", **kw)
    np.testing.assert_allclose(np.asarray(U_pal), np.asarray(U_ref),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("dtype", ["fp32", "bf16"])
def test_fused_vs_ref_forced_striping(dtype):
    """Tiny SMEM/tile budgets force BOTH paths to stripe the N axis into
    several dispatches; parity must hold across the stripe seams (and the
    dead M-tiles the kernel's occupancy counts skip must contribute exact
    zeros in the fallback, which processes them). The striped fallback
    body is XLA-fused (fast-math contraction), so this leg is ulp-level,
    not bitwise — 1e-5 against draws of O(1) magnitude."""
    rng = np.random.default_rng(11)
    idx, val, mask, pe, pL, z, other = _case(rng, 40, 50, 29, 8,
                                             empty_rows=(7, 21))
    kw = dict(dtype=dtype, tau=2.0, tm=128,
              smem_idx_budget=4096, tile_elems=4096)
    U_pal = SWEEP.fused_sweep(z, idx, val, mask, pe, pL, other,
                              force="pallas", interpret=True, **kw)
    U_ref = SWEEP.fused_sweep(z, idx, val, mask, pe, pL, other,
                              force="ref", **kw)
    np.testing.assert_allclose(np.asarray(U_pal), np.asarray(U_ref),
                               rtol=1e-5, atol=1e-5)
    # the striped and single-stripe fallbacks agree bitwise with each
    # other per row regardless of stripe seams (row-local math)
    U_one = SWEEP.fused_sweep(z, idx, val, mask, pe, pL, other,
                              force="ref", dtype=dtype, tau=2.0, tm=128,
                              n_stripe=40)
    np.testing.assert_allclose(np.asarray(U_one), np.asarray(U_ref),
                               rtol=1e-5, atol=1e-5)


def test_empty_rows_reduce_to_prior_sample():
    """A row with no observations must sample from its PRIOR conditional —
    the fused path's answer matches sample_rows_noise on the bare prior."""
    rng = np.random.default_rng(5)
    idx, val, mask, pe, pL, z, other = _case(rng, 6, 20, 13, 8,
                                             empty_rows=(2,))
    U = SWEEP.fused_sweep(z, idx, val, mask, pe, pL, other, 1.3,
                          force="ref")
    want = POST.sample_rows_noise(POST.RowGaussians(eta=pe, Lambda=pL), z)
    np.testing.assert_allclose(np.asarray(U[2]), np.asarray(want[2]),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# in-kernel sampler: per-draw + statistical agreement with posterior.py
# ---------------------------------------------------------------------------


def _conditional(idx, val, mask, pe, pL, other, tau):
    """Analytic Gibbs conditional per row: Λ = Λ0 + τ Σ v vᵀ, η = η0 + τ Σ r v."""
    V = np.asarray(other)[np.asarray(idx)]
    m = np.asarray(mask)
    Lam = np.asarray(pL) + tau * np.einsum("nm,nmk,nml->nkl", m, V, V)
    eta = np.asarray(pe) + tau * np.einsum("nm,nm,nmk->nk",
                                           m, np.asarray(val), V)
    return eta, Lam


def test_in_kernel_sampler_matches_sample_rows_noise():
    """Same conditional, same z: the masked-lane Cholesky/solve chain and
    LAPACK's agree to solver roundoff on every draw."""
    rng = np.random.default_rng(23)
    idx, val, mask, pe, pL, z, other = _case(rng, 12, 30, 17, 8)
    tau = 1.9
    U = SWEEP.fused_sweep(z, idx, val, mask, pe, pL, other, tau, force="ref")
    eta, Lam = _conditional(idx, val, mask, pe, pL, other, tau)
    want = POST.sample_rows_noise(
        POST.RowGaussians(eta=jnp.asarray(eta), Lambda=jnp.asarray(Lam)), z)
    np.testing.assert_allclose(np.asarray(U), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_in_kernel_sampler_moments():
    """4000 fused draws reproduce the analytic conditional moments: mean
    within standard-error bars, covariance within a Frobenius-relative
    tolerance of Λ⁻¹."""
    rng = np.random.default_rng(31)
    N, K, T = 6, 6, 4000
    idx, val, mask, pe, pL, _, other = _case(rng, N, 24, 15, K)
    tau = 2.2
    zs = jax.random.normal(jax.random.key(9), (T, N, K))

    draw = jax.jit(lambda zz: SWEEP.fused_sweep(
        zz, idx, val, mask, pe, pL, other, tau, force="ref"))
    samples = np.asarray(jax.lax.map(draw, zs, batch_size=500))   # (T, N, K)

    eta, Lam = _conditional(idx, val, mask, pe, pL, other, tau)
    Sig = np.linalg.inv(Lam + 1e-6 * np.eye(K))
    mu = np.einsum("nkl,nl->nk", Sig, eta)

    se = np.sqrt(np.diagonal(Sig, axis1=-2, axis2=-1) / T)
    assert np.all(np.abs(samples.mean(0) - mu) < 5 * se)
    c = samples - samples.mean(0)
    cov = np.einsum("tnk,tnl->nkl", c, c) / (T - 1)
    rel = (np.linalg.norm(cov - Sig, axis=(1, 2))
           / np.linalg.norm(Sig, axis=(1, 2)))
    assert np.all(rel < 0.15), rel


def test_sample_factor_fused_preserves_noise_stream():
    """Flipping the fused path on must not perturb the chain's random
    stream: same key => the legacy sample_factor and the fused step draw
    the SAME z and agree to solver roundoff."""
    rng = np.random.default_rng(41)
    idx, val, mask, pe, pL, _, other = _case(rng, 10, 25, 19, 8)
    from repro.data.sparse import PaddedCSR
    csr = PaddedCSR(idx=idx, val=val, mask=mask, n_cols=19)
    prior = POST.RowGaussians(eta=pe, Lambda=pL)
    key = jax.random.key(77)
    legacy = BMF.sample_factor(key, csr, other, 1.4, prior)
    fused = SWEEP.sample_factor_fused(key, csr, other, 1.4, prior)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(legacy),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# _summarize relative ridge
# ---------------------------------------------------------------------------


def _moments(samples):
    T = samples.shape[0]
    sum_ = samples.sum(0)
    outer = jnp.einsum("tnk,tnl->nkl", samples, samples)
    return sum_, outer, jnp.asarray(float(T))


def test_summarize_relative_ridge_ill_conditioned():
    """Rank-deficient draws (T-1 < K) at 1e4 row scale: variances sit at
    ~1e8, where the old absolute 1e-4 ridge is below f32 resolution
    (1e8 + 1e-4 == 1e8) — the Cholesky sees a singular matrix and the
    old path goes non-finite. The scaled ridge must stay finite and PD."""
    rng = np.random.default_rng(53)
    T, N, K = 4, 5, 8
    samples = jnp.asarray(rng.normal(size=(T, N, K)) * 1e4, jnp.float32)
    sum_, outer, cnt = _moments(samples)

    mean = sum_ / cnt
    cov = outer / cnt - jnp.einsum("nk,nl->nkl", mean, mean)
    old = POST.from_moments_cov(mean, cov, ridge=1e-4)       # pre-fix path
    assert not bool(jnp.all(jnp.isfinite(old.Lambda)))

    g = GIBBS._summarize(sum_, outer, cnt)
    assert bool(jnp.all(jnp.isfinite(g.Lambda)))
    assert bool(jnp.all(jnp.isfinite(g.eta)))
    ev = np.linalg.eigvalsh(np.asarray(g.Lambda))
    assert np.all(ev > 0), ev.min()


def test_summarize_relative_ridge_small_scale_bitwise_compat():
    """O(1)-scale rows (every existing chain): the floor pins the scaled
    ridge at exactly the old absolute 1e-4, so the summarization is
    bit-for-bit what from_moments_cov(ridge=1e-4) produced."""
    rng = np.random.default_rng(59)
    samples = jnp.asarray(rng.normal(size=(9, 7, 6)) * 0.3, jnp.float32)
    sum_, outer, cnt = _moments(samples)
    mean = sum_ / cnt
    cov = outer / cnt - jnp.einsum("nk,nl->nkl", mean, mean)
    assert float(jnp.abs(jnp.diagonal(cov, axis1=-2, axis2=-1)).max()) < 1.0

    old = POST.from_moments_cov(mean, cov, ridge=1e-4)
    new = GIBBS._summarize(sum_, outer, cnt)
    np.testing.assert_array_equal(np.asarray(new.eta), np.asarray(old.eta))
    np.testing.assert_array_equal(np.asarray(new.Lambda),
                                  np.asarray(old.Lambda))


# ---------------------------------------------------------------------------
# dtype-promotion pass over the fused lowering
# ---------------------------------------------------------------------------


def test_dtype_pass_proves_bf16_never_reaches_solver():
    """The traced bf16 fused step must carry NO low-precision operand into
    cholesky/triangular_solve/sqrt — the lint-side proof that mixed
    precision stays on the gather/accumulate side."""
    from repro.analysis.registry import JaxprArtifact, get_pass
    tc = SWEEP.trace_sweep(8, 16, 24, 48, dtype="bf16")
    art = JaxprArtifact(label="sweep[bf16]", jaxpr=tc.traced.jaxpr)
    assert get_pass("dtype-promotion").run(art) == []
    # the jaxpr really is the mixed-precision lowering, not an all-f32 one
    from repro.roofline import jaxpr_cost as JCOST
    assert any(str(getattr(a, "dtype", "")) == "bfloat16"
               for a in JCOST.iter_avals(tc.traced.jaxpr))


def test_dtype_pass_catches_bf16_sqrt():
    """Negative control: a planted bf16 sqrt (a half-precision in-register
    Cholesky diagonal) trips the pass."""
    from repro.analysis.registry import JaxprArtifact, get_pass
    bad = jax.make_jaxpr(
        lambda x: jnp.sqrt(x.astype(jnp.bfloat16)))(jnp.ones((4, 4)))
    art = JaxprArtifact(label="planted", jaxpr=bad)
    vs = get_pass("dtype-promotion").run(art)
    assert any("sqrt" in v.message for v in vs), vs
