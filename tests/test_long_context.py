"""Long-context variants: the SWA window override used for dense archs at
long_500k (DESIGN.md §4), ring-buffer wrap-around correctness, and constant
recurrent state for SSM/hybrid."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import model as MODEL
from repro.models.kvcache import serve_cache_init


def test_swa_ring_wraparound_matches_reference():
    """Decode with a ring cache of window W past position W must equal
    attention over exactly the last W tokens (computed with a big cache)."""
    cfg = dataclasses.replace(get_config("llama3_8b").smoke_variant(),
                              dtype="float32")
    W = 16
    S = 40  # > 2x window: the ring wraps twice
    params = MODEL.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (1, S), 0, cfg.vocab_size)

    # path A: ring cache of W slots, windowed decode
    cache_a = serve_cache_init(cfg, 1, S, dtype=jnp.float32,
                               window_override=W)
    for i in range(S):
        logits_a, cache_a = MODEL.decode_step(params, cfg, cache_a,
                                              toks[:, i:i + 1],
                                              window_override=W)

    # path B: full cache, same window mask (no ring)
    cache_b = serve_cache_init(cfg, 1, S + 8, dtype=jnp.float32)
    for i in range(S):
        logits_b, cache_b = MODEL.decode_step(params, cfg, cache_b,
                                              toks[:, i:i + 1],
                                              window_override=W)

    np.testing.assert_allclose(np.asarray(logits_a), np.asarray(logits_b),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch_id", ["rwkv6_7b", "zamba2_7b"])
def test_recurrent_state_constant_size(arch_id):
    """SSM/hybrid serving state must not grow with context length."""
    cfg = get_config(arch_id).smoke_variant()
    c1 = serve_cache_init(cfg, 2, 4096)
    c2 = serve_cache_init(cfg, 2, 1 << 19)   # 128x longer context
    flat1 = jax.tree_util.tree_flatten_with_path(c1)[0]
    flat2 = jax.tree_util.tree_flatten_with_path(c2)[0]
    for (p1, l1), (p2, l2) in zip(flat1, flat2):
        key = "/".join(str(getattr(k, "key", k)) for k in p1)
        if "attn" in key:
            # hybrid shared-attn ring is capped at its window (<= 4096)
            slot_dim = 2 if l2.ndim > 2 else 1
            assert l2.shape[slot_dim] <= 4096, (key, l2.shape)
        else:
            assert l1.shape == l2.shape, (key, l1.shape, l2.shape)


def test_dense_long_context_uses_window_cache():
    """serve_cache_init with window_override bounds the dense cache."""
    cfg = get_config("llama3_8b").smoke_variant()
    c = serve_cache_init(cfg, 1, 1 << 19, window_override=64)
    assert c["attn"]["k"].shape[2] == 64
