"""Distributed (shard_map) BMF must match the single-device sampler
statistically, and the limited-communication property must hold.

Runs in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the 512-device dry-run flag never leaks into the main test process.
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import bmf as BMF, gibbs as GIBBS, distributed as DIST
    from repro.data import synthetic as SYN
    from repro.data.sparse import train_test_split, coo_to_padded_csr

    mesh = jax.make_mesh((8,), ("data",))
    coo, p = SYN.generate("mini", seed=3)
    train, test = train_test_split(coo, 0.15, seed=4)
    csr_r = coo_to_padded_csr(train)
    csr_c = coo_to_padded_csr(train.transpose())
    cfg = BMF.BMFConfig(K=p.K, n_samples=40, burnin=15)

    res_d = DIST.run_gibbs_distributed(
        jax.random.key(0), csr_r, csr_c,
        jnp.asarray(test.row), jnp.asarray(test.col), cfg, mesh)
    rmse_d = float(GIBBS.rmse_from_acc(res_d.acc, jnp.asarray(test.val)))

    res_s = GIBBS.run_gibbs(jax.random.key(0), csr_r, csr_c,
                            jnp.asarray(test.row), jnp.asarray(test.col), cfg)
    rmse_s = float(GIBBS.rmse_from_acc(res_s.acc, jnp.asarray(test.val)))

    comm = DIST.sweep_comm_bytes(csr_r.n_cols, cfg.K)
    print(json.dumps({"rmse_dist": rmse_d, "rmse_single": rmse_s,
                      "comm_bytes_per_sweep": comm,
                      "U_shape": list(np.asarray(res_d.U).shape)}))
""")


@pytest.mark.slow
def test_distributed_matches_single():
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=500)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    # same data, same priors, different RNG partitioning -> statistically
    # equivalent results
    assert abs(rec["rmse_dist"] - rec["rmse_single"]) < 0.12, rec
    # limited communication: ~D*(K^2+K) floats per sweep, independent of nnz
    assert rec["comm_bytes_per_sweep"] < 200_000, rec


SCRIPT_SCATTER = SCRIPT.replace(
    "cfg, mesh)",
    "cfg, mesh, scatter_v=True)").replace(
    '"U_shape"', '"scatter_v_U_shape"')


@pytest.mark.slow
def test_scatter_v_matches_single():
    """Beyond-paper scatter-V variant (§Perf H6): statistical parity."""
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT_SCATTER], env=env,
                         capture_output=True, text=True, timeout=500)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert abs(rec["rmse_dist"] - rec["rmse_single"]) < 0.12, rec
