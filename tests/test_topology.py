"""Unified 2-D ('block', 'data') topology layer: Topology object
semantics, the composed stacked 2-D chain, group dispatch, and donation
through the distributed per-sweep loop.

Multi-device behavior runs in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count so faked meshes never
leak into the main test process (same pattern as test_distributed_bmf).
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.core import engine as ENG
from repro.core.distributed import make_block_mesh
from repro.core.topology import Topology

ROOT = Path(__file__).resolve().parents[1]


def _run(script: str, timeout: int = 500):
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-4000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


# ---------------------------------------------------------------------------
# Topology object (single-device: structure + validation only)
# ---------------------------------------------------------------------------


def test_topology_shape_validation():
    with pytest.raises(ValueError):
        Topology(block=0, data=1)
    with pytest.raises(ValueError):
        Topology(block=1, data=2)          # 1 local device: too few
    t = Topology(block=1, data=1)
    assert t.n_devices == 1
    assert t.groups() == (t.devices,)
    assert t.describe().startswith("topology 1x1")


def test_topology_from_spec_coercions():
    t = Topology.from_spec(None)
    assert t.block == len(jax.devices()) and t.data == 1
    assert Topology.from_spec(t) is t
    t2 = Topology.from_spec((1, 1))
    assert (t2.block, t2.data) == (1, 1)
    # legacy 1-D 'block' mesh
    t3 = Topology.from_spec(make_block_mesh(1))
    assert (t3.block, t3.data) == (1, 1)
    with pytest.raises(ValueError):
        Topology.from_spec(jax.make_mesh((1, 1), ("a", "b")))


def test_topology_meshes_unify_block_mesh():
    """distributed.make_block_mesh is the data==1 degenerate form of the
    topology mesh — same devices, same axis name."""
    t = Topology(block=1, data=1)
    bm = t.block_mesh()
    assert tuple(bm.axis_names) == ("block",)
    assert bm == make_block_mesh(1)
    assert tuple(t.mesh.axis_names) == ("block", "data")
    dm = t.data_mesh(0)
    assert tuple(dm.axis_names) == ("data",)
    g2 = t.group_mesh_2d(0)
    assert g2.devices.shape == (1, 1)
    assert tuple(g2.axis_names) == ("block", "data")


def test_topology_executor_wiring_errors():
    with pytest.raises(ValueError):
        ENG.make_executor("stacked", topology=Topology(1, 1))
    with pytest.raises(ValueError):
        ENG.make_executor(ENG.StackedExecutor(), topology=Topology(1, 1))
    with pytest.raises(ValueError):
        ENG.SerialExecutor(distributed_mesh=object(),
                           topology=Topology(1, 1))
    # serial with a block>1 topology is meaningless
    with pytest.raises(ValueError):
        ENG.make_executor("serial", topology=(2, 1))


def test_executors_consume_topology_single_device():
    """On one device every executor accepts the degenerate topology and
    keeps its legacy behavior."""
    t = Topology(block=1, data=1)
    assert ENG.make_executor("serial", topology=t).distributed_mesh is None
    sh = ENG.make_executor("sharded", topology=t)
    assert sh.topology is t and sh.block_mesh is not None
    asy = ENG.make_executor("async", topology=t)
    assert asy.topology is t and len(asy.devices) == 1
    st = ENG.make_executor("streaming", topology=t, window=3)
    assert st.topology is t and st.window == 3
    with pytest.raises(ValueError):
        ENG.StreamingExecutor(topology=Topology(1, 1), comm="psum")


# ---------------------------------------------------------------------------
# composed 2-D chain parity (subprocess, faked 4-device mesh)
# ---------------------------------------------------------------------------

CHAIN_2D_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import bmf as BMF, gibbs as GIBBS, distributed as DIST
    from repro.core.topology import Topology
    from repro.data import synthetic as SYN
    from repro.data.sparse import coo_to_padded_csr, PaddedCSR, \\
        train_test_split

    coo, p = SYN.generate("mini", seed=3)
    train, test = train_test_split(coo, 0.15, seed=4)
    csr_r = coo_to_padded_csr(train)
    csr_c = coo_to_padded_csr(train.transpose())
    cfg = BMF.BMFConfig(K=6, n_samples=8, burnin=3)
    keys = jax.random.split(jax.random.key(7), 2)
    tr = jnp.stack([jnp.asarray(test.row)] * 2)
    tc = jnp.stack([jnp.asarray(test.col)] * 2)
    tv = np.asarray(test.val)

    def stack2(c):
        return PaddedCSR(idx=jnp.stack([c.idx] * 2),
                         val=jnp.stack([c.val] * 2),
                         mask=jnp.stack([c.mask] * 2), n_cols=c.n_cols)

    def rmse(res):
        pred = np.asarray(res.acc.pred_sum[0]
                          / jnp.maximum(res.acc.pred_cnt[0], 1))
        return float(np.sqrt(np.mean((pred - tv) ** 2)))

    topo = Topology(block=2, data=2)
    S, N, D = topo.data, csr_r.n_rows, csr_c.n_rows
    N_pad = ((N + S - 1) // S) * S
    m_c = int(csr_c.idx.shape[1])
    ref = GIBBS.run_gibbs_stacked(keys, stack2(csr_r), stack2(csr_c),
                                  tr, tc, cfg)
    out = {"ref": rmse(ref)}
    res = DIST.run_gibbs_stacked_2d(keys, stack2(csr_r), stack2(csr_c),
                                    tr, tc, cfg, topo, comm="gather")
    out["gather"] = rmse(res)
    out["gather_U_diff"] = float(jnp.abs(ref.U - res.U).max())
    csrt1 = DIST.shard_transposed_planes(train.row, train.col, train.val,
                                         S, N_pad, D, m_c)
    csrt = tuple(np.stack([x] * 2) for x in csrt1)
    res = DIST.run_gibbs_stacked_2d(keys, stack2(csr_r), stack2(csr_c),
                                    tr, tc, cfg, topo, comm="psum",
                                    csrt=csrt)
    out["psum"] = rmse(res)
    D_pad = ((D + S - 1) // S) * S
    csrt1 = DIST.shard_transposed_planes(train.row, train.col, train.val,
                                         S, N_pad, D_pad, m_c)
    csrt = tuple(np.stack([x] * 2) for x in csrt1)
    res = DIST.run_gibbs_stacked_2d(keys, stack2(csr_r), stack2(csr_c),
                                    tr, tc, cfg, topo, comm="scatter",
                                    csrt=csrt)
    out["scatter"] = rmse(res)

    # single-block group dispatch == run_gibbs under the same key
    r1 = GIBBS.run_gibbs(jax.random.key(5), csr_r, csr_c,
                         jnp.asarray(test.row), jnp.asarray(test.col), cfg)
    r2 = DIST.run_gibbs_group(jax.random.key(5), csr_r, csr_c,
                              jnp.asarray(test.row),
                              jnp.asarray(test.col), cfg, topo, group=1)
    out["group_U_diff"] = float(jnp.abs(r1.U - r2.U).max())
    out["n_devices"] = len(jax.devices())
    print(json.dumps(out))
""")


@pytest.mark.slow
def test_stacked_2d_chain_parity_and_modes():
    """'gather' reproduces the single-level stacked chain (fp-level);
    'psum' matches statistically tightly (stats reassociation only);
    'scatter' stays a valid sampler; the B=1 group dispatch matches
    run_gibbs."""
    rec = _run(CHAIN_2D_SCRIPT)
    assert rec["n_devices"] == 4
    assert abs(rec["gather"] - rec["ref"]) < 1e-4, rec
    assert rec["gather_U_diff"] < 1e-3, rec
    assert abs(rec["psum"] - rec["ref"]) < 1e-3, rec
    assert abs(rec["scatter"] - rec["ref"]) < 0.15, rec
    assert rec["group_U_diff"] < 1e-3, rec


# ---------------------------------------------------------------------------
# donation through the distributed per-sweep loop (subprocess, 8 devices)
# ---------------------------------------------------------------------------

DONATE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import bmf as BMF, gibbs as GIBBS, distributed as DIST
    from repro.data import synthetic as SYN
    from repro.data.sparse import train_test_split, coo_to_padded_csr

    mesh = jax.make_mesh((8,), ("data",))
    coo, p = SYN.generate("mini", seed=3)
    train, test = train_test_split(coo, 0.15, seed=4)
    csr_r = coo_to_padded_csr(train)
    csr_c = coo_to_padded_csr(train.transpose())
    cfg = BMF.BMFConfig(K=p.K, n_samples=6, burnin=2)
    tr, tc = jnp.asarray(test.row), jnp.asarray(test.col)

    U0, V0 = BMF.init_factors(jax.random.key(4), csr_r.n_rows,
                              csr_c.n_rows, cfg.K)
    ref = DIST.run_gibbs_distributed(jax.random.key(0), csr_r, csr_c,
                                     tr, tc, cfg, mesh, U0=U0, V0=V0,
                                     donate=False)
    assert not U0.is_deleted()

    # pre-commit the carry to the sweep's shardings: a donated buffer jit
    # would have to reshard is consumed by the transfer, not aliased —
    # committed buffers are donated directly and the handles invalidate
    from jax.sharding import NamedSharding, PartitionSpec as P
    U0d, V0d = BMF.init_factors(jax.random.key(4), csr_r.n_rows,
                                csr_c.n_rows, cfg.K)
    U0d = jax.device_put(U0d, NamedSharding(mesh, P("data", None)))
    V0d = jax.device_put(V0d, NamedSharding(mesh, P(None, None)))
    don = DIST.run_gibbs_distributed(jax.random.key(0), csr_r, csr_c,
                                     tr, tc, cfg, mesh, U0=U0d, V0=V0d,
                                     donate=True)
    print(json.dumps({
        "n_devices": len(jax.devices()),
        "u0_deleted": bool(U0d.is_deleted()),
        "v0_deleted": bool(V0d.is_deleted()),
        "U_equal": bool(np.array_equal(np.asarray(ref.U),
                                       np.asarray(don.U))),
        "post_equal": bool(np.array_equal(np.asarray(ref.U_post.eta),
                                          np.asarray(don.U_post.eta))),
        "rmse_ref": float(GIBBS.rmse_from_acc(ref.acc,
                                              jnp.asarray(test.val))),
        "rmse_don": float(GIBBS.rmse_from_acc(don.acc,
                                              jnp.asarray(test.val))),
    }))
""")


@pytest.mark.slow
def test_distributed_sweep_donation_alias_and_invalidate():
    """Mirrors the PR-3 gibbs donation tests for the distributed per-sweep
    loop: donate=True must not change the chain, and the donated carry
    (U0/V0) must be invalidated at the first sweep — XLA recycles the
    factor buffers in place across iterations."""
    rec = _run(DONATE_SCRIPT)
    assert rec["n_devices"] == 8
    assert rec["u0_deleted"] and rec["v0_deleted"], rec
    assert rec["U_equal"] and rec["post_equal"], rec
    assert rec["rmse_ref"] == rec["rmse_don"], rec
