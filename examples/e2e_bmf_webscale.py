"""End-to-end driver: the paper's full pipeline at the largest
container-feasible scale — the yahoo-analogue dataset (10k x 6.2k, ~2.6M
ratings), balanced partitioning, three-phase Posterior Propagation,
posterior aggregation, RMSE evaluation and a checkpoint.

This is the training-system e2e the paper's kind dictates (a few hundred
Gibbs sweeps over every block). Takes a few minutes on the CPU container.

  PYTHONPATH=src python examples/e2e_bmf_webscale.py [--fast]
"""
import argparse
import time

import jax

from repro.checkpoint import ckpt
from repro.core import bmf as BMF
from repro.core import pp as PP
from repro.core.partition import nnz_balance_stats, partition, suggest_grid
from repro.data import synthetic as SYN
from repro.data.sparse import train_test_split


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller dataset + chains (CI-friendly)")
    ap.add_argument("--streaming", action="store_true",
                    help="oversized grid streamed through a bounded window "
                         "of donated block buffers (flat live peak — the "
                         "configuration for grids that exceed device "
                         "memory under the stacked executor)")
    args = ap.parse_args()

    dataset = "movielens" if args.fast else "yahoo"
    samples = 30 if args.fast else 120
    coo, preset = SYN.generate(dataset, seed=0)
    train, test = train_test_split(coo, 0.1, seed=1)
    print(f"[{dataset}] {train.n_rows} x {train.n_cols}, nnz={train.nnz}")

    K = min(preset.K, 16)
    cfg = BMF.BMFConfig(K=K, n_samples=samples, burnin=samples // 3)
    n_blocks = 32 if args.streaming else 4
    I, J = suggest_grid(train.n_rows, train.n_cols, n_blocks=n_blocks)
    part = partition(train, I, J)
    print(f"grid {I}x{J}, balance {nnz_balance_stats(part)}")

    t0 = time.time()
    # stacked executor: each PP phase bucket runs as ONE vmapped Gibbs
    # call; --streaming instead bounds the live footprint to a 4-block
    # window (prefetched, donated, critical-path-first)
    executor = "streaming" if args.streaming else "stacked"
    res = PP.run_pp(jax.random.key(0), part, cfg, test, executor=executor,
                    window=4 if args.streaming else None, verbose=True)
    print(f"BMF+PP[{res.executor}] RMSE={res.rmse:.4f} in "
          f"{time.time() - t0:.1f}s ({res.n_test} test ratings)")
    print(f"phase times: { {k: round(v,1) for k, v in res.phase_times_s.items()} }")
    print(f"modeled 16-worker wall: {res.modeled_parallel_s(16):.1f}s")

    ckpt.save("/tmp/repro_bmf_pp", {
        "U_eta": res.U_agg.eta, "U_Lam": res.U_agg.Lambda,
        "V_eta": res.V_agg.eta, "V_Lam": res.V_agg.Lambda},
        extra={"rmse": res.rmse, "dataset": dataset})
    print("aggregated posterior checkpointed -> /tmp/repro_bmf_pp.npz")


if __name__ == "__main__":
    main()
