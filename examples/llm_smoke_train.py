"""Train a small LLM from the architecture zoo on the synthetic token
pipeline and watch the loss decrease — exercises the same train_step /
AdamW / remat / data path that the production dry-run lowers at full scale.

  PYTHONPATH=src python examples/llm_smoke_train.py [--arch mixtral_8x7b]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.base import ARCH_IDS, TrainConfig, get_config
from repro.data.tokens import synthetic_token_batches
from repro.models import model as MODEL
from repro.models import steps as STEPS
from repro.optim import adamw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_8b", choices=list(ARCH_IDS))
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke_variant()
    tcfg = TrainConfig(learning_rate=1e-3, total_steps=args.steps,
                       warmup_steps=5)
    params = MODEL.init_params(jax.random.key(0), cfg)
    opt = adamw.init(params)
    step = jax.jit(STEPS.make_train_step(cfg, tcfg), donate_argnums=(0, 1))

    losses = []
    t0 = time.time()
    for i, batch in zip(range(args.steps),
                        synthetic_token_batches(cfg, batch=4, seq=128)):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
        if i % 10 == 0:
            print(f"step {i:3d}  loss {losses[-1]:.4f}")
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    print(f"loss {first:.3f} -> {last:.3f} in {time.time()-t0:.0f}s")
    assert last < first, "loss must decrease"
    print("OK")


if __name__ == "__main__":
    main()
