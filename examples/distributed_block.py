"""Distributed BMF inside one block (paper ref [16], Fig. 2 pattern):
rows of U sharded over 8 devices via shard_map, V replicated with psum'd
sufficient statistics — the 'limited communication' structure.

NOTE: must run as its own process (device count is fixed at first jax use).

  PYTHONPATH=src python examples/distributed_block.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import bmf as BMF  # noqa: E402
from repro.core import distributed as DIST  # noqa: E402
from repro.core import gibbs as GIBBS  # noqa: E402
from repro.data import synthetic as SYN  # noqa: E402
from repro.data.sparse import coo_to_padded_csr, train_test_split  # noqa: E402


def main():
    mesh = jax.make_mesh((8,), ("data",))
    coo, preset = SYN.generate("movielens", seed=0)
    train, test = train_test_split(coo, 0.1, seed=1)
    csr_r = coo_to_padded_csr(train)
    csr_c = coo_to_padded_csr(train.transpose())
    cfg = BMF.BMFConfig(K=preset.K, n_samples=40, burnin=15)

    res = DIST.run_gibbs_distributed(
        jax.random.key(0), csr_r, csr_c,
        jnp.asarray(test.row), jnp.asarray(test.col), cfg, mesh)
    rmse = float(GIBBS.rmse_from_acc(res.acc, jnp.asarray(test.val)))

    comm = DIST.sweep_comm_bytes(train.n_cols, cfg.K)
    print(f"8-way distributed Gibbs: RMSE={rmse:.4f}")
    print(f"communication per sweep: {comm/1e3:.1f} KB "
          f"(D*(K^2+K) floats — independent of the {train.nnz} ratings)")
    base = float(np.sqrt(np.mean((test.val - train.val.mean()) ** 2)))
    assert rmse < base
    print("OK")


if __name__ == "__main__":
    main()
