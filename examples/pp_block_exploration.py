"""Block-size exploration (paper Figure 3): how the I×J grid trades
wall-clock against RMSE, and why ~square blocks win.

  PYTHONPATH=src python examples/pp_block_exploration.py
"""
import math

import jax

from repro.core import bmf as BMF
from repro.core import pp as PP
from repro.core.partition import partition
from repro.data import synthetic as SYN
from repro.data.sparse import train_test_split


def main():
    coo, preset = SYN.generate("movielens", seed=0)
    train, test = train_test_split(coo, 0.1, seed=1)
    cfg = BMF.BMFConfig(K=preset.K, n_samples=30, burnin=10)

    print(f"{'grid':>6} {'rmse':>8} {'serial_s':>9} {'stacked_s':>9} "
          f"{'par16_s':>8} {'squareness':>10}")
    for (I, J) in [(1, 1), (2, 1), (2, 2), (4, 1), (4, 2), (8, 1)]:
        part = partition(train, I, J)
        res = PP.run_pp(jax.random.key(0), part, cfg, test)
        # same blocks through the phase-graph engine's stacked executor:
        # one vmapped Gibbs call per phase bucket instead of the per-block
        # loop (identical chains — same keys, same padding)
        res_stk = PP.run_pp(jax.random.key(0), part, cfg, test,
                            executor="stacked")
        sq = abs(math.log((train.n_rows / I) / (train.n_cols / J)))
        print(f"{I}x{J:<4} {res.rmse:8.4f} {res.wall_time_s:9.2f} "
              f"{res_stk.wall_time_s:9.2f} "
              f"{res.modeled_parallel_s(16):8.2f} {sq:10.2f}")
    print("\nlower squareness == closer to square blocks; the best "
          "time/RMSE points cluster there (paper §3.3). stacked_s is the "
          "phase-graph engine's batched execution of the same grid.")


if __name__ == "__main__":
    main()
