"""Quickstart: Bayesian Matrix Factorization with Posterior Propagation.

Runs BMF+PP on a small synthetic ratings matrix and compares RMSE against
full BMF and the mean predictor.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import bmf as BMF
from repro.core import pp as PP
from repro.core.partition import partition, suggest_grid
from repro.data import synthetic as SYN
from repro.data.sparse import train_test_split


def main():
    coo, preset = SYN.generate("mini", seed=0)
    train, test = train_test_split(coo, test_frac=0.15, seed=1)
    print(f"ratings matrix: {train.n_rows} x {train.n_cols}, "
          f"{train.nnz} train / {test.nnz} test ratings")

    cfg = BMF.BMFConfig(K=preset.K, n_samples=50, burnin=20)

    rmse_mean = float(np.sqrt(np.mean((test.val - train.val.mean()) ** 2)))
    rmse_bmf, secs, _ = PP.run_full_bmf(jax.random.key(0), train, test, cfg)

    I, J = suggest_grid(train.n_rows, train.n_cols, n_blocks=4)
    part = partition(train, I, J)
    # stacked executor: the phase-graph engine runs each PP phase's blocks
    # as one batched Gibbs call (executor="serial" is the reference loop)
    res = PP.run_pp(jax.random.key(1), part, cfg, test, executor="stacked")

    print(f"mean predictor RMSE : {rmse_mean:.4f}")
    print(f"full BMF RMSE       : {rmse_bmf:.4f}  ({secs:.1f}s)")
    print(f"BMF+PP {I}x{J} RMSE    : {res.rmse:.4f}  ({res.wall_time_s:.1f}s, "
          f"executor={res.executor})")
    assert res.rmse < rmse_mean, "PP must beat the mean predictor"
    print("OK")


if __name__ == "__main__":
    main()
