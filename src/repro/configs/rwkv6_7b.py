"""RWKV6-7B (Finch) — attention-free, data-dependent decay linear recurrence.
[arXiv:2404.05892]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    source="[arXiv:2404.05892]",
    n_layers=32,
    d_model=4096,
    n_heads=0,             # attention-free
    n_kv_heads=0,
    d_ff=14_336,
    vocab_size=65_536,
    wkv_head_dim=64,       # 64 wkv heads of size 64
    norm_eps=1e-5,
)
