"""InternVL2-1B — VLM: InternViT frontend (STUB per assignment carve-out;
``input_specs()`` provides patch embeddings (B, 256, d_model)) + Qwen2-0.5B
style LM backbone. [arXiv:2404.16821]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b",
    family="vlm",
    source="[arXiv:2404.16821]",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151_655,
    n_image_tokens=256,
    rope_theta=1_000_000.0,
    norm_eps=1e-6,
    tie_embeddings=True,
)
