"""Granite-3.0-1B-A400M — fine-grained MoE, 32 experts top-8, per-expert
d_ff=512. [hf:ibm-granite/granite-3.0-1b-a400m-base]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    source="[hf:ibm-granite/granite-3.0-1b-a400m-base]",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,              # per-expert
    vocab_size=49_155,
    n_experts=32,
    experts_per_token=8,
    rope_theta=10_000.0,
    norm_eps=1e-6,
    tie_embeddings=True,
)
