"""Zamba2-7B — hybrid: Mamba2 backbone + weight-shared attention blocks.
[arXiv:2411.15242]

81 Mamba2 layers; a single weight-tied (shared) full-attention transformer
block is interleaved every ``shared_attn_period`` Mamba2 layers (Zamba2 uses
shared blocks to add attention capacity at ~0 parameter cost).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    source="[arXiv:2411.15242]",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,         # shared block is MHA (kv=32)
    head_dim=112,          # 3584 / 32
    d_ff=14_336,
    vocab_size=32_000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    shared_attn_period=6,
    rope_theta=10_000.0,
    norm_eps=1e-5,
)
