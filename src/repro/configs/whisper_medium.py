"""Whisper-medium — encoder-decoder; mel+conv frontend is a STUB per the
assignment carve-out: ``input_specs()`` provides precomputed frame embeddings
(B, 1500, d_model). [arXiv:2212.04356]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    source="[arXiv:2212.04356]",
    n_layers=24,            # decoder layers
    n_encoder_layers=24,    # encoder layers (whisper-medium: 24+24)
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,          # MHA
    head_dim=64,
    d_ff=4096,
    vocab_size=51_865,
    n_audio_frames=1500,
    norm_eps=1e-5,
    tie_embeddings=True,
)
