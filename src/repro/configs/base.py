"""Config system: architecture + input-shape dataclasses and the registry.

Every assigned architecture lives in its own module (``src/repro/configs/
<id>.py``) exporting ``CONFIG``; ``get_config(arch_id)`` resolves it.
Reduced ("smoke") variants are derived mechanically so smoke tests always
exercise the same code path as the full config.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    """A transformer-family architecture, parameterized enough to cover
    dense / MoE / SSM / hybrid / enc-dec / VLM members of the zoo."""

    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    source: str                      # citation bracket from the assignment

    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: int = 0                # 0 -> d_model // n_heads

    # --- attention options -------------------------------------------------
    qk_norm: bool = False            # qwen3
    rope_theta: float = 10_000.0
    rope_partial: float = 1.0        # fraction of head_dim rotated (chatglm 0.5)
    sliding_window: int = 0          # 0 = full causal; >0 = SWA (mixtral 4096)
    attn_logit_softcap: float = 0.0

    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25

    # --- SSM / RWKV ---------------------------------------------------------
    ssm_state: int = 0               # mamba2 state size per head
    ssm_head_dim: int = 64           # mamba2 P (channels per head)
    ssm_expand: int = 2              # d_inner = expand * d_model
    ssm_conv_width: int = 4
    wkv_head_dim: int = 64           # rwkv6 head size

    # --- hybrid (zamba2): shared attention block every N ssm layers ---------
    shared_attn_period: int = 0      # 0 = no shared attention blocks

    # --- enc-dec (whisper) ---------------------------------------------------
    n_encoder_layers: int = 0        # >0 => encoder-decoder
    n_audio_frames: int = 1500       # stub conv frontend output length

    # --- vlm ------------------------------------------------------------------
    n_image_tokens: int = 0          # stub ViT frontend output length

    # --- norm / misc ----------------------------------------------------------
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    @property
    def padded_vocab_size(self) -> int:
        """Vocab rounded up to a multiple of 256 so the vocab dim shards
        evenly over the model axis (logical vocab padding; padded logits are
        masked to -inf in unembed)."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        assert self.n_heads, f"{self.name}: no heads and no head_dim"
        return self.d_model // self.n_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_encdec(self) -> bool:
        return self.n_encoder_layers > 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def subquadratic(self) -> bool:
        """True if long-context decode is natively sub-quadratic in memory."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    def param_count(self) -> int:
        """Analytic parameter count (embeddings included once)."""
        d, f, V = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim if (self.n_heads or self.head_dim) else 0
        n_q = self.n_heads * hd
        n_kv = self.n_kv_heads * hd

        def attn_params():
            return d * n_q + 2 * d * n_kv + n_q * d

        def mlp_params():
            return 3 * d * f  # SwiGLU: gate, up, down

        def moe_params():
            return self.n_experts * 3 * d * f + d * self.n_experts

        def mamba2_params():
            d_in = self.ssm_expand * d
            n = self.ssm_state
            nheads = d_in // self.ssm_head_dim
            zxbcdt = d * (2 * d_in + 2 * n + nheads)
            conv = self.ssm_conv_width * (d_in + 2 * n)
            return zxbcdt + conv + nheads * 2 + d_in * d + d_in

        def rwkv6_params():
            # r,k,v,g,w projections + output + time-mix lora + ffn(2 mats)
            att = 5 * d * d + d * d + 6 * d * 96
            ffn = d * int(3.5 * d) * 2 if not f else (d * f + f * d)
            return att + ffn

        total = V * d  # embedding
        if not self.tie_embeddings:
            total += V * d
        if self.family == "ssm":  # rwkv6
            total += self.n_layers * rwkv6_params()
        elif self.family == "hybrid":
            total += self.n_layers * mamba2_params()
            if self.shared_attn_period:
                total += attn_params() + mlp_params()  # one shared block
        elif self.is_moe:
            total += self.n_layers * (attn_params() + moe_params())
        elif self.is_encdec:
            total += self.n_encoder_layers * (attn_params() + 2 * d * f)
            total += self.n_layers * (2 * attn_params() + 2 * d * f)
        else:
            total += self.n_layers * (attn_params() + mlp_params())
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if not self.is_moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense_like = self.param_count() - self.n_layers * self.n_experts * 3 * d * f
        return dense_like + self.n_layers * self.experts_per_token * 3 * d * f

    # ------------------------------------------------------------------
    def smoke_variant(self) -> "ArchConfig":
        """Reduced config of the same family: 2 layers, d_model<=512,
        <=4 experts — used by CPU smoke tests."""
        d = min(self.d_model, 256)
        hd = 32
        n_heads = max(2, min(4, self.n_heads)) if self.n_heads else 0
        n_kv = max(1, min(n_heads, self.n_kv_heads)) if self.n_kv_heads else 0
        updates = dict(
            n_layers=2,
            d_model=d,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=hd if n_heads else 0,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
        )
        if self.is_moe:
            updates.update(n_experts=4, experts_per_token=min(2, self.experts_per_token))
        if self.family in ("ssm", "hybrid"):
            updates.update(ssm_state=min(self.ssm_state or 16, 16),
                           ssm_head_dim=32, wkv_head_dim=32)
        if self.shared_attn_period:
            updates.update(shared_attn_period=1)
        if self.is_encdec:
            updates.update(n_encoder_layers=2, n_audio_frames=16)
        if self.n_image_tokens:
            updates.update(n_image_tokens=8)
        if self.sliding_window:
            updates.update(sliding_window=64)
        return replace(self, **updates)


# ---------------------------------------------------------------------------
# Input shapes (assignment)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

INPUT_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


@dataclass(frozen=True)
class TrainConfig:
    """Hyper-parameters for the LLM training driver."""
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    warmup_steps: int = 100
    total_steps: int = 10_000
    grad_clip: float = 1.0
    seed: int = 0
    remat: bool = True                # activation checkpointing per layer
    remat_policy: str = "full"        # full | dots (save MXU outputs)
    microbatches: int = 1             # grad-accumulation steps per update


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = (
    "qwen3_4b",
    "minitron_8b",
    "zamba2_7b",
    "rwkv6_7b",
    "chatglm3_6b",
    "granite_moe_1b_a400m",
    "llama3_8b",
    "whisper_medium",
    "mixtral_8x7b",
    "internvl2_1b",
)

_ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}


def get_config(arch_id: str) -> ArchConfig:
    arch_id = _ALIASES.get(arch_id, arch_id)
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {list(ARCH_IDS)}")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def all_configs():
    return {i: get_config(i) for i in ARCH_IDS}


def shape_supported(cfg: ArchConfig, shape: InputShape) -> Tuple[bool, str]:
    """Whether (arch, shape) is in scope; returns (ok, note).

    long_500k requires sub-quadratic decode. Dense decoders run it under the
    sliding-window variant (handled by the model builder); whisper (enc-dec)
    skips it — see DESIGN.md §4.
    """
    if shape.name == "long_500k" and cfg.is_encdec:
        return False, "enc-dec decoder has no meaningful 524k autoregressive context (DESIGN.md §4)"
    return True, ""
