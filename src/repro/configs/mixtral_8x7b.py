"""Mixtral-8x7B — MoE 8 experts top-2, sliding-window attention (4096).
[arXiv:2401.04088]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    source="[arXiv:2401.04088]",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14_336,            # per-expert
    vocab_size=32_000,
    n_experts=8,
    experts_per_token=2,
    sliding_window=4096,
    rope_theta=1_000_000.0,
    norm_eps=1e-5,
)
