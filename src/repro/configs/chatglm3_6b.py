"""ChatGLM3-6B — dense, GQA kv=2, 2d/partial RoPE (rotary applied to half the
head dim). [arXiv:2406.12793]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b",
    family="dense",
    source="[arXiv:2406.12793]",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    head_dim=128,
    d_ff=13_696,
    vocab_size=65_024,
    rope_partial=0.5,      # ChatGLM rotates half of head_dim ("RoPE 2d")
    rope_theta=10_000.0,
    norm_eps=1e-5,
)
