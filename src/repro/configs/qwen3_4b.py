"""Qwen3-4B — dense, GQA, qk-norm. [hf:Qwen/Qwen3-8B]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-4b",
    family="dense",
    source="[hf:Qwen/Qwen3-8B]",
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab_size=151_936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    norm_eps=1e-6,
)
