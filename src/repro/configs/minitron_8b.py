"""Minitron-8B — width-pruned Nemotron-4. [arXiv:2407.14679]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minitron-8b",
    family="dense",
    source="[arXiv:2407.14679]",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16_384,
    vocab_size=256_000,
    rope_theta=10_000.0,
    norm_eps=1e-5,
)
