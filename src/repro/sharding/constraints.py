"""Ambient-mesh-aware sharding constraints.

Model code calls ``constrain(x, "data_batch", ...)`` style helpers; when no
mesh is ambient (CPU unit tests, single device) they are no-ops, so the same
model code runs everywhere.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import PartitionSpec as P


def _ambient_axes():
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        return ()
    if mesh is None or not mesh.axis_names:
        return ()
    return tuple(mesh.axis_names)


def constrain(x, *dim_axes):
    """with_sharding_constraint(x, P(*dim_axes)) filtered to ambient axes.

    dim_axes entries: None, an axis name, or a tuple of axis names. Axes not
    present in the ambient mesh are dropped; dims not divisible by the axis
    size are left unsharded.
    """
    names = _ambient_axes()
    if not names:
        return x
    mesh = jax.sharding.get_abstract_mesh()
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    spec = []
    for i, d in enumerate(dim_axes):
        if d is None:
            spec.append(None)
            continue
        cand = d if isinstance(d, tuple) else (d,)
        cand = tuple(a for a in cand if a in names)
        if not cand:
            spec.append(None)
            continue
        prod = 1
        for a in cand:
            prod *= sizes[a]
        if x.shape[i] % prod == 0:
            spec.append(cand if len(cand) > 1 else cand[0])
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, P(*spec))


def batch_axes():
    """('pod','data') subset present in the ambient mesh."""
    names = _ambient_axes()
    return tuple(a for a in ("pod", "data") if a in names) or None
