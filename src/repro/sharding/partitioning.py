"""Path-based PartitionSpec rules for params, optimizer state, batches and
serving caches.

Mesh axes: ``('data', 'model')`` single-pod, ``('pod', 'data', 'model')``
multi-pod. The ``pod`` axis extends data parallelism across pods (batch is
sharded over ``('pod', 'data')``); ``model`` is the tensor-parallel axis.

Rules are matched on the flattened param path (joined with '/'). All stacked
layer params carry a leading L axis which is never sharded (layers are
scanned, not pipelined — pipeline parallelism over 'pod' is a recorded
hillclimb candidate in EXPERIMENTS §Perf).
"""
from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def data_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _divides(n: int, d: int) -> bool:
    return d > 0 and n % d == 0


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------

# (regex on path suffix, spec WITHOUT the leading stacked-L axis)
# 'M' marks the model-sharded dim; None elsewhere.
_PARAM_RULES = [
    # embeddings: vocab over model => logits come out vocab-sharded with no
    # extra collective on the (B,S,V) tensor (see DESIGN §3 / EXPERIMENTS §Perf)
    (r"embed/table$", ("M", None)),
    (r"embed/unembed$", (None, "M")),
    # attention
    (r"(attn|self_attn|cross_attn)/wq$", (None, "M")),
    (r"(attn|self_attn|cross_attn)/wk$", (None, "M")),
    (r"(attn|self_attn|cross_attn)/wv$", (None, "M")),
    (r"(attn|self_attn|cross_attn)/wo$", ("M", None)),
    # dense mlp
    (r"mlp/w_gate$", (None, "M")),
    (r"mlp/w_up$", (None, "M")),
    (r"mlp/w_down$", ("M", None)),
    (r"mlp/w_in$", (None, "M")),
    (r"mlp/w_out$", ("M", None)),
    (r"mlp/b_in$", ("M",)),
    # moe (expert-parallel vs per-expert tensor-parallel decided dynamically)
    (r"mlp/router$", (None, None)),
    (r"mlp/(w_gate|w_up)$", (None, None, "M")),   # placeholder; see below
    # rwkv6
    (r"att/(wr|wk|wv|wg)$", (None, "M")),
    (r"att/wo$", ("M", None)),
    (r"att/(decay_A|decay_B|decay_w0|bonus_u|mix_base)$", None),
    (r"ffn/w_in$", (None, "M")),
    (r"ffn/w_out$", ("M", None)),
    # mamba2
    (r"mixer/(w_z|w_x)$", (None, "M")),
    (r"mixer/w_dt$", (None, "M")),
    (r"mixer/(w_B|w_C)$", (None, None)),
    (r"mixer/conv_x$", (None, "M")),
    (r"mixer/conv_bias_x$", ("M",)),
    (r"mixer/(conv_B|conv_C|conv_bias_B|conv_bias_C)$", None),
    (r"mixer/(A_log|D|dt_bias)$", ("M",)),
    (r"mixer/norm/scale$", ("M",)),
    (r"mixer/out_proj$", ("M", None)),
]


def _spec_for_path(path: str, shape: Tuple[int, ...], cfg: ArchConfig,
                   mesh: Mesh, stacked: bool) -> P:
    m_size = _axis_size(mesh, "model")

    # MoE expert weights: expert-parallel when E divides the model axis
    # evenly, else tensor-parallel on the per-expert ffn dim + FSDP over
    # 'data' on d_model (mixtral: 47B f32 params do not fit model-sharded
    # only — 2-D sharding is required, weights are all-gathered per layer).
    moe_w = re.search(r"mlp/(w_gate|w_up|w_down)$", path) and cfg.is_moe
    if moe_w:
        E = cfg.n_experts
        is_down = path.endswith("w_down")
        if _divides(E, m_size):
            spec = ("M", None, None)
            p = _materialize(spec, shape, cfg, mesh, stacked)
        else:
            spec = (None, "F", "M") if not is_down else (None, "M", "F")
            p = _materialize(spec, shape, cfg, mesh, stacked)
        return p

    for pat, spec in _PARAM_RULES:
        if re.search(pat, path):
            return _materialize(spec, shape, cfg, mesh, stacked)
    # norms, scalars, biases: replicate
    return P(*([None] * len(shape)))


def _materialize(spec, shape, cfg: ArchConfig, mesh: Mesh, stacked: bool) -> P:
    if spec is None:
        return P(*([None] * len(shape)))
    m_size = _axis_size(mesh, "model")
    d_size = _axis_size(mesh, "data")
    out = []
    base = len(shape) - len(spec)  # leading stacked axes (L) stay unsharded
    for i in range(base):
        out.append(None)
    for j, s in enumerate(spec):
        dim = shape[base + j]
        if s == "M" and _divides(dim, m_size):
            out.append("model")
        elif s == "F" and _divides(dim, d_size):
            out.append("data")   # FSDP-style weight shard over the data axis
        else:
            out.append(None)
    return P(*out)


def param_specs(params, cfg: ArchConfig, mesh: Mesh):
    """Pytree of PartitionSpec matching ``params``."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        path_str = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                            for k in path)
        stacked = False
        specs.append(_spec_for_path(path_str, leaf.shape, cfg, mesh, stacked))
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(params), specs)


def param_shardings(params, cfg: ArchConfig, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, cfg, mesh))


# ---------------------------------------------------------------------------
# Optimizer state
# ---------------------------------------------------------------------------


def opt_specs(opt_state, params, cfg: ArchConfig, mesh: Mesh):
    """AdamWState(step, mu, nu): ZeRO-1 — moments shard like the params PLUS
    'data' on the first still-unsharded divisible dim (optimizer update is
    elementwise, so this costs only the reduce-scatter/all-gather pair GSPMD
    already inserts for the grads)."""
    ps = param_specs(params, cfg, mesh)
    d_size = _axis_size(mesh, "data")

    def zero1(spec, leaf):
        names = list(spec) + [None] * (len(leaf.shape) - len(spec))
        if "data" in names:
            return P(*names)
        for i, (n, dim) in enumerate(zip(names, leaf.shape)):
            if n is None and _divides(dim, d_size) and dim >= d_size * 64:
                names[i] = "data"
                break
        return P(*names)

    moments = jax.tree.map(zero1, ps, params)
    return type(opt_state)(step=P(), mu=moments, nu=moments)


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------


def _batch_dim_axes(B: int, mesh: Mesh):
    """Largest prefix of (pod, data) whose product divides B."""
    axes = [a for a in data_axes(mesh)]
    total = 1
    used = []
    for a in axes:
        total *= _axis_size(mesh, a)
    if _divides(B, total):
        return tuple(axes)
    # try only 'data'
    if _divides(B, _axis_size(mesh, "data")):
        return ("data",)
    return None


def batch_specs(batch_tree, cfg: ArchConfig, shape: InputShape, mesh: Mesh):
    """tokens (B, S) / *_embeds (B, T, d) sharded over batch axes."""
    def spec(leaf):
        B = leaf.shape[0]
        ba = _batch_dim_axes(B, mesh)
        rest = [None] * (len(leaf.shape) - 1)
        return P(ba, *rest)
    return jax.tree.map(spec, batch_tree)


def cache_specs(cache_tree, cfg: ArchConfig, shape: InputShape, mesh: Mesh):
    """Serving cache sharding.

    - attention k/v (L, B, S, Hkv, hd): batch over data axes when divisible;
      'model' on the first of (Hkv, hd, S) it divides.
    - kv_pos (L, S): replicated.
    - ssm/wkv/conv states: batch over data; heads/d_inner over model.
    """
    m = _axis_size(mesh, "model")

    def spec(path, leaf):
        path_str = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                            for k in path)
        shp = leaf.shape
        if path_str.endswith("kv_pos") or path_str == "pos":
            return P(*([None] * len(shp)))
        if re.search(r"attn/(k_scale|v_scale)$", path_str):  # (L,B,S,Hkv)
            ba = _batch_dim_axes(shp[1], mesh)
            return P(None, ba, None, "model" if _divides(shp[3], m) else None)
        if re.search(r"attn/(k|v)$", path_str) or re.search(r"cross_(k|v)$", path_str):
            L_, B_, S_, H_, D_ = shp
            ba = _batch_dim_axes(B_, mesh)
            model_dim = None
            if _divides(H_, m):
                model_dim = 3
            elif _divides(D_, m):
                model_dim = 4
            elif _divides(S_, m):
                model_dim = 2
            out = [None, ba, None, None, None]
            if model_dim is not None:
                out[model_dim] = "model"
            return P(*out)
        if path_str.endswith("wkv"):                      # (L,B,H,N,N)
            L_, B_, H_, _, _ = shp
            ba = _batch_dim_axes(B_, mesh)
            return P(None, ba, "model" if _divides(H_, m) else None, None, None)
        if re.search(r"shift_(att|ffn)$", path_str):      # (L,B,d)
            ba = _batch_dim_axes(shp[1], mesh)
            return P(None, ba, "model" if _divides(shp[2], m) else None)
        if re.search(r"mamba/(conv_x|conv_B|conv_C)$", path_str):  # (L,B,W-1,C)
            ba = _batch_dim_axes(shp[1], mesh)
            return P(None, ba, None, "model" if _divides(shp[3], m) else None)
        if path_str.endswith("mamba/ssm"):                # (L,B,H,P,N)
            ba = _batch_dim_axes(shp[1], mesh)
            return P(None, ba, "model" if _divides(shp[2], m) else None, None, None)
        # decode-state conv/ssm without layer stack (smoke paths) and misc
        ba = _batch_dim_axes(shp[0], mesh) if len(shp) >= 1 and shp[0] > 1 else None
        return P(ba, *([None] * (len(shp) - 1))) if len(shp) else P()

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    specs = [spec(p, l) for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def logits_spec(mesh: Mesh, vocab: int):
    m = _axis_size(mesh, "model")
    return P(None, None, "model" if _divides(vocab, m) else None)


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
