"""Dispatch/resolve-trace passes and phase-graph validation.

The executor trace schema (``engine.Executor``). Barrier executors
record ``(event, coord)`` pairs; overlapped executors (async,
streaming) tag every event with the device group that observed it,
``(event, coord, group)``:

  ("dispatch", c[, g])    the block's chain was handed to the queue
  ("expire", c[, g])      the watchdog expired the in-flight attempt
  ("redispatch", c[, g])  the expired attempt was re-dispatched
  ("resolve", c[, g])     the block's outcome passed the commit guard
  ("quarantine", c, g)    group g drained after repeated expiries
                          (c is the triggering coord)
  ("steal", c, g)         idle group g re-staged the staged block c
                          from the most-loaded group
  ("speculate", c, g)     straggler hedge: c redundantly dispatched
                          to idle group g under the same attempt-0 key
  ("cancel", c, g)        one side of a speculative twin pair was
                          discarded (loser, expiry, or quarantine)

Happens-before contract per coord: dispatch first; every dep resolved
before it; expire only while in flight; redispatch only after an
expire; exactly one resolve, last. An expire followed directly by
resolve is the degraded/terminal-retire path and is legal. Group-level
contract: nothing dispatches to (or steals onto) a quarantined group;
a speculate must twin a block that is in flight, and the pair must be
collapsed by a cancel before the block may resolve; steal targets must
be staged, not in flight.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Set, Tuple

from repro.analysis.registry import (GraphArtifact, Pass, TraceArtifact,
                                     Violation, register)

Coord = Tuple[int, int]

_EVENTS = ("dispatch", "expire", "redispatch", "resolve",
           "quarantine", "steal", "speculate", "cancel")


def _entries(trace):
    """Normalize (ev, c) / (ev, c, g) entries to (ev, c, g-or-None)."""
    for entry in trace:
        ev, c = entry[0], entry[1]
        yield ev, c, (entry[2] if len(entry) > 2 else None)


def _happens_before(art: TraceArtifact) -> List[Violation]:
    out = []
    dispatched: Set[Coord] = set()
    resolved: Set[Coord] = set()
    expired: Set[Coord] = set()
    inflight: Dict[Coord, int] = {}
    twins: Dict[Coord, int] = {}        # open speculative pairs per coord
    quarantined: Set[int] = set()

    def bad(msg, hint):
        out.append(Violation("happens-before", art.label, msg, hint))

    def check_group(ev, c, g):
        if g is not None and g in quarantined:
            bad(f"{c} {ev} to quarantined group {g}",
                "a quarantined group is drained and must receive no "
                "further work — route dispatch/steal/speculation "
                "through health.healthy() only")

    for ev, c, g in _entries(art.trace):
        if ev == "dispatch":
            if c in dispatched:
                bad(f"{c} dispatched twice without an intervening expire",
                    "re-dispatch must go through the watchdog protocol: "
                    "record ('expire', c) before the second attempt "
                    "(a quarantine-released STAGED block was never "
                    "dispatched, so its later launch is a first "
                    "dispatch)")
            missing = [d for d in art.deps.get(c, ()) if d not in resolved]
            if missing:
                bad(f"{c} dispatched before dep(s) {missing} resolved",
                    "a block's propagated priors come from its deps — "
                    "gate dispatch on _dep_state readiness, never on "
                    "phase position alone")
            check_group(ev, c, g)
            dispatched.add(c)
            inflight[c] = inflight.get(c, 0) + 1
        elif ev == "expire":
            if not inflight.get(c) or c in resolved:
                bad(f"{c} expired while not in flight",
                    "the watchdog may only expire a dispatched, "
                    "unresolved attempt")
            inflight[c] = max(0, inflight.get(c, 0) - 1)
            expired.add(c)
        elif ev == "redispatch":
            if c not in expired:
                bad(f"{c} redispatched without an expired attempt",
                    "watchdog re-dispatch must be totally ordered with "
                    "the expiry it replaces: record ('expire', c) first")
            check_group(ev, c, g)
            expired.discard(c)
            inflight[c] = inflight.get(c, 0) + 1
        elif ev == "speculate":
            if not inflight.get(c):
                bad(f"{c} speculated while not in flight",
                    "speculative re-dispatch hedges a LIVE straggler — "
                    "twin only blocks with an unresolved in-flight "
                    "attempt")
            check_group(ev, c, g)
            inflight[c] = inflight.get(c, 0) + 1
            twins[c] = twins.get(c, 0) + 1
        elif ev == "cancel":
            if not twins.get(c):
                bad(f"{c} cancelled without an open speculative twin",
                    "cancel collapses a speculate pair — record "
                    "('speculate', c, g) before either side may cancel")
            twins[c] = max(0, twins.get(c, 0) - 1)
            inflight[c] = max(0, inflight.get(c, 0) - 1)
        elif ev == "steal":
            if inflight.get(c):
                bad(f"{c} stolen while in flight",
                    "steal targets must be STAGED blocks — an in-flight "
                    "block's handles live on the victim group and "
                    "cannot move; wait for expiry or speculation")
            if c in resolved:
                bad(f"{c} stolen after resolving",
                    "a resolved block has left the scheduler — the "
                    "steal scanned a stale staged slot")
            check_group(ev, c, g)
        elif ev == "quarantine":
            if g is None:
                bad(f"quarantine event for {c} carries no group",
                    "quarantine is a group-level event: record "
                    "('quarantine', trigger_coord, g)")
            elif g in quarantined:
                bad(f"group {g} quarantined twice",
                    "a quarantined group stays quarantined — "
                    "note_expiry must not re-trip on a drained group")
            else:
                quarantined.add(g)
        elif ev == "resolve":
            if c not in dispatched:
                bad(f"{c} resolved without a dispatch",
                    "every outcome must come from a recorded dispatch — "
                    "a resolve out of nowhere means the executor "
                    "committed a stale or foreign buffer")
            if c in resolved:
                bad(f"{c} resolved twice",
                    "double commit: the commit guard must run exactly "
                    "once per block")
            if twins.get(c):
                bad(f"{c} resolved with an open speculative twin",
                    "a speculative resolve must cancel its twin: record "
                    "('cancel', c, loser_group) for the losing side "
                    "before committing the deterministic winner")
            expired.discard(c)     # terminal retire of an expired attempt
            inflight[c] = max(0, inflight.get(c, 0) - 1)
            resolved.add(c)
        else:
            bad(f"unknown trace event {ev!r} for {c}",
                f"executor traces may only contain {_EVENTS}")
    for c in art.deps:
        if c not in resolved:
            bad(f"{c} never resolved",
                "the run ended with an unresolved block — the executor "
                "dropped an in-flight handle or lost a retire path")
    for c in sorted(expired):
        bad(f"{c} left with an expired attempt neither redispatched nor "
            f"retired",
            "an expiry must be followed by a redispatch or a terminal "
            "retire before the run ends")
    for c in sorted(k for k, n in twins.items() if n):
        bad(f"{c} left with an uncollapsed speculative twin",
            "every speculate pair must end in exactly one cancel — the "
            "run finished with both twins still live")
    return out


register(Pass(
    "happens-before", "trace",
    "every dep resolves before its dependent dispatches; watchdog "
    "re-dispatch is totally ordered with the expired attempt; every "
    "block resolves exactly once; no work reaches a quarantined group; "
    "speculative twins collapse via cancel; steal targets are staged",
    _happens_before))


def _window_occupancy(art: TraceArtifact) -> List[Violation]:
    if art.window_bound is None:
        return []
    out = []
    live: Dict[Coord, int] = {}
    peak = 0
    for ev, c, _ in _entries(art.trace):
        if ev in ("dispatch", "redispatch", "speculate"):
            live[c] = live.get(c, 0) + 1
        elif ev in ("resolve", "expire", "cancel"):
            live[c] = max(0, live.get(c, 0) - 1)
        peak = max(peak, sum(live.values()))
    if peak > art.window_bound:
        out.append(Violation(
            "window-occupancy", art.label,
            f"{peak} blocks in flight exceeds the window bound "
            f"{art.window_bound} (G*W*(depth+1))",
            "the streaming window must stay bounded for the flat-memory "
            "claim to hold — a chunk was dispatched without waiting for "
            "a window slot"))
    if art.reported_peak is not None and art.reported_peak > art.window_bound:
        out.append(Violation(
            "window-occupancy", art.label,
            f"executor-reported peak_window_blocks={art.reported_peak} "
            f"exceeds the bound {art.window_bound}",
            "staged + in-flight chunks together must fit "
            "G*W*(depth+1) blocks — the prefetch staged past its slot"))
    return out


register(Pass(
    "window-occupancy", "trace",
    "in-flight (and staged) blocks never exceed the streaming window "
    "bound G*W*(depth+1)",
    _window_occupancy))


def check_graph(deps: Dict[Coord, Sequence[Coord]],
                resolved: Sequence[Coord] = (),
                label: str = "phase-graph") -> List[Violation]:
    """Cycle / unreachable-block / dangling-dep detection on a dep map —
    the function behind the graph pass AND the engine's pre-dispatch
    hook (``run_phase_graph`` refuses to start on a graph that cannot
    drain)."""
    out = []
    done = set(resolved)
    dangling = {}
    for c, ds in deps.items():
        missing = [d for d in ds if d not in deps and d not in done]
        if missing:
            dangling[c] = missing
            out.append(Violation(
                "graph-validation", label,
                f"{c} depends on {missing} which are neither in the "
                f"graph nor pre-resolved",
                "a pruned/mistyped dep can never resolve — prune the "
                "dependent too (resume) or fix the prior_from coords"))
    # Kahn drain: whatever never becomes ready is cyclic or blocked
    pending = {c: [d for d in ds if d not in done]
               for c, ds in deps.items()}
    ready = [c for c, ds in pending.items() if not ds]
    order = []
    while ready:
        c = ready.pop()
        order.append(c)
        done.add(c)
        for s, ds in pending.items():
            if c in ds:
                ds.remove(c)
                if not ds and s not in done and s not in ready:
                    ready.append(s)
    stuck = sorted(c for c in deps if c not in done)
    stuck = [c for c in stuck if c not in dangling]
    if stuck:
        out.append(Violation(
            "graph-validation", label,
            f"blocks {stuck[:6]}{'...' if len(stuck) > 6 else ''} can "
            f"never become ready (dependency cycle)",
            "the PP phase DAG is acyclic by construction (deps point to "
            "strictly earlier phases) — a cycle means prior_from coords "
            "were rewired; break it or re-derive the graph from "
            "build_phase_graph"))
    return out


def _graph_validation(art: GraphArtifact) -> List[Violation]:
    return check_graph(art.deps, art.resolved, label=art.label)


register(Pass(
    "graph-validation", "graph",
    "the phase graph is acyclic, fully reachable, and every dep exists "
    "(in-graph or pre-resolved)",
    _graph_validation))
