"""Dispatch/resolve-trace passes and phase-graph validation.

The executor trace schema (``engine.Executor``):

  ("dispatch", c)    the block's chain was handed to the device queue
  ("expire", c)      the watchdog expired the in-flight attempt
  ("redispatch", c)  the expired attempt was re-dispatched (same keys)
  ("resolve", c)     the block's outcome passed the commit guard

Happens-before contract per coord: dispatch first; every dep resolved
before it; expire only while in flight; redispatch only after an expire;
exactly one resolve, last. An expire followed directly by resolve is the
degraded/terminal-retire path and is legal.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Set, Tuple

from repro.analysis.registry import (GraphArtifact, Pass, TraceArtifact,
                                     Violation, register)

Coord = Tuple[int, int]

_EVENTS = ("dispatch", "expire", "redispatch", "resolve")


def _happens_before(art: TraceArtifact) -> List[Violation]:
    out = []
    dispatched: Set[Coord] = set()
    resolved: Set[Coord] = set()
    expired: Set[Coord] = set()

    def bad(msg, hint):
        out.append(Violation("happens-before", art.label, msg, hint))

    for ev, c in art.trace:
        if ev == "dispatch":
            if c in dispatched:
                bad(f"{c} dispatched twice without an intervening expire",
                    "re-dispatch must go through the watchdog protocol: "
                    "record ('expire', c) before the second attempt")
            missing = [d for d in art.deps.get(c, ()) if d not in resolved]
            if missing:
                bad(f"{c} dispatched before dep(s) {missing} resolved",
                    "a block's propagated priors come from its deps — "
                    "gate dispatch on _dep_state readiness, never on "
                    "phase position alone")
            dispatched.add(c)
        elif ev == "expire":
            if c not in dispatched or c in resolved:
                bad(f"{c} expired while not in flight",
                    "the watchdog may only expire a dispatched, "
                    "unresolved attempt")
            expired.add(c)
        elif ev == "redispatch":
            if c not in expired:
                bad(f"{c} redispatched without an expired attempt",
                    "watchdog re-dispatch must be totally ordered with "
                    "the expiry it replaces: record ('expire', c) first")
            expired.discard(c)
        elif ev == "resolve":
            if c not in dispatched:
                bad(f"{c} resolved without a dispatch",
                    "every outcome must come from a recorded dispatch — "
                    "a resolve out of nowhere means the executor "
                    "committed a stale or foreign buffer")
            if c in resolved:
                bad(f"{c} resolved twice",
                    "double commit: the commit guard must run exactly "
                    "once per block")
            expired.discard(c)     # terminal retire of an expired attempt
            resolved.add(c)
        else:
            bad(f"unknown trace event {ev!r} for {c}",
                f"executor traces may only contain {_EVENTS}")
    for c in art.deps:
        if c not in resolved:
            bad(f"{c} never resolved",
                "the run ended with an unresolved block — the executor "
                "dropped an in-flight handle or lost a retire path")
    for c in sorted(expired):
        bad(f"{c} left with an expired attempt neither redispatched nor "
            f"retired",
            "an expiry must be followed by a redispatch or a terminal "
            "retire before the run ends")
    return out


register(Pass(
    "happens-before", "trace",
    "every dep resolves before its dependent dispatches; watchdog "
    "re-dispatch is totally ordered with the expired attempt; every "
    "block resolves exactly once",
    _happens_before))


def _window_occupancy(art: TraceArtifact) -> List[Violation]:
    if art.window_bound is None:
        return []
    out = []
    live: Set[Coord] = set()
    peak = 0
    for ev, c in art.trace:
        if ev in ("dispatch", "redispatch"):
            live.add(c)
        elif ev == "resolve":
            live.discard(c)
        peak = max(peak, len(live))
    if peak > art.window_bound:
        out.append(Violation(
            "window-occupancy", art.label,
            f"{peak} blocks in flight exceeds the window bound "
            f"{art.window_bound} (G*W*(depth+1))",
            "the streaming window must stay bounded for the flat-memory "
            "claim to hold — a chunk was dispatched without waiting for "
            "a window slot"))
    if art.reported_peak is not None and art.reported_peak > art.window_bound:
        out.append(Violation(
            "window-occupancy", art.label,
            f"executor-reported peak_window_blocks={art.reported_peak} "
            f"exceeds the bound {art.window_bound}",
            "staged + in-flight chunks together must fit "
            "G*W*(depth+1) blocks — the prefetch staged past its slot"))
    return out


register(Pass(
    "window-occupancy", "trace",
    "in-flight (and staged) blocks never exceed the streaming window "
    "bound G*W*(depth+1)",
    _window_occupancy))


def check_graph(deps: Dict[Coord, Sequence[Coord]],
                resolved: Sequence[Coord] = (),
                label: str = "phase-graph") -> List[Violation]:
    """Cycle / unreachable-block / dangling-dep detection on a dep map —
    the function behind the graph pass AND the engine's pre-dispatch
    hook (``run_phase_graph`` refuses to start on a graph that cannot
    drain)."""
    out = []
    done = set(resolved)
    dangling = {}
    for c, ds in deps.items():
        missing = [d for d in ds if d not in deps and d not in done]
        if missing:
            dangling[c] = missing
            out.append(Violation(
                "graph-validation", label,
                f"{c} depends on {missing} which are neither in the "
                f"graph nor pre-resolved",
                "a pruned/mistyped dep can never resolve — prune the "
                "dependent too (resume) or fix the prior_from coords"))
    # Kahn drain: whatever never becomes ready is cyclic or blocked
    pending = {c: [d for d in ds if d not in done]
               for c, ds in deps.items()}
    ready = [c for c, ds in pending.items() if not ds]
    order = []
    while ready:
        c = ready.pop()
        order.append(c)
        done.add(c)
        for s, ds in pending.items():
            if c in ds:
                ds.remove(c)
                if not ds and s not in done and s not in ready:
                    ready.append(s)
    stuck = sorted(c for c in deps if c not in done)
    stuck = [c for c in stuck if c not in dangling]
    if stuck:
        out.append(Violation(
            "graph-validation", label,
            f"blocks {stuck[:6]}{'...' if len(stuck) > 6 else ''} can "
            f"never become ready (dependency cycle)",
            "the PP phase DAG is acyclic by construction (deps point to "
            "strictly earlier phases) — a cycle means prior_from coords "
            "were rewired; break it or re-derive the graph from "
            "build_phase_graph"))
    return out


def _graph_validation(art: GraphArtifact) -> List[Violation]:
    return check_graph(art.deps, art.resolved, label=art.label)


register(Pass(
    "graph-validation", "graph",
    "the phase graph is acyclic, fully reachable, and every dep exists "
    "(in-graph or pre-resolved)",
    _graph_validation))
