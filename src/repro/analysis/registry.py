"""Pass registry + artifact containers for the static invariant analyzer.

A ``Pass`` couples a name with the artifact kind it understands and a
``run(artifact) -> [Violation]`` function. Passes register themselves at
import time (``repro.analysis`` imports every pass module), so
``analyze(artifact)`` always sees the full registry — the analyzer's
analogue of the executor registry's auto-enrollment.

Artifacts are plain dataclasses carrying exactly what the passes need;
none of them import engine/gibbs types, so the analyzer stays a leaf of
the dependency graph and ``core.engine`` can call into it (graph
validation before dispatch) without a cycle.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class Violation:
    """One invariant breach: which pass fired, on what artifact, what went
    wrong, and how to fix it (the fix hint is part of the contract — a
    violation the reader can't act on is noise)."""
    pass_name: str
    artifact: str
    message: str
    fix_hint: str

    def as_dict(self) -> Dict[str, str]:
        return {"pass": self.pass_name, "artifact": self.artifact,
                "message": self.message, "fix_hint": self.fix_hint}

    def __str__(self):
        return (f"[{self.pass_name}] {self.artifact}: {self.message}\n"
                f"    fix: {self.fix_hint}")


KINDS = ("jaxpr", "hlo", "trace", "graph", "plan")


@dataclass(frozen=True)
class Pass:
    """A named analysis over one artifact kind."""
    name: str
    kind: str                                   # one of KINDS
    doc: str
    run: Callable[[Any], List[Violation]]


_REGISTRY: Dict[str, Pass] = {}


def register(p: Pass) -> Pass:
    if p.kind not in KINDS:
        raise ValueError(f"pass {p.name!r}: unknown artifact kind {p.kind!r} "
                         f"(expected one of {KINDS})")
    if p.name in _REGISTRY:
        raise ValueError(f"duplicate pass name {p.name!r}")
    _REGISTRY[p.name] = p
    return p


def get_pass(name: str) -> Pass:
    if name not in _REGISTRY:
        raise KeyError(f"unknown pass {name!r} "
                       f"(registered: {sorted(_REGISTRY)})")
    return _REGISTRY[name]


def passes(kind: Optional[str] = None) -> List[Pass]:
    """All registered passes, optionally filtered to one artifact kind."""
    ps = sorted(_REGISTRY.values(), key=lambda p: p.name)
    return ps if kind is None else [p for p in ps if p.kind == kind]


def analyze(artifact) -> List[Violation]:
    """Run every registered pass of ``artifact.kind`` and concatenate the
    violations — the one-call enrollment point bmf_lint and the dry-run
    use."""
    return [v for p in passes(artifact.kind) for v in p.run(artifact)]


# ---------------------------------------------------------------------------
# Artifact containers
# ---------------------------------------------------------------------------


@dataclass
class JaxprArtifact:
    """A traced (unlowered) program. ``bytes_budget`` is the largest
    single buffer the program may legitimately materialize, derived from
    block dims (see ``jaxpr_passes.materialization_budget``); None skips
    the materialization pass."""
    label: str
    jaxpr: Any                                  # ClosedJaxpr or Jaxpr
    bytes_budget: Optional[int] = None
    allow_f64: bool = False
    kind: str = field(default="jaxpr", init=False)


@dataclass
class HLOArtifact:
    """A compiled module's HLO text plus what the passes need from the
    call site: the comm mode (keys ``hlo_passes.COLLECTIVE_BUDGETS``),
    the allowed replica groups ('data'-axis rows; None skips the
    confinement check on single-device modules), and the donation
    contract (flat param labels, donated labels, the subset that MUST
    alias an output, plus labels documented as release-only)."""
    label: str
    hlo_text: str
    comm: Optional[str] = None
    allowed_groups: Optional[Sequence[Sequence[int]]] = None
    collective_budget: Optional[Dict[str, int]] = None  # overrides comm's
    param_labels: Optional[Sequence[str]] = None
    donated: Sequence[str] = ()
    must_alias: Sequence[str] = ()
    release_only: Sequence[str] = ()
    alias_bytes: Optional[int] = None
    kind: str = field(default="hlo", init=False)


Coord = Tuple[int, int]


@dataclass
class TraceArtifact:
    """An executor's recorded event trace plus the dep map it ran
    against. Entries are ``(event, coord)`` or ``(event, coord, group)``
    — overlapped executors tag every event with the device group that
    observed it. Events: dispatch | expire | redispatch | resolve plus
    the elastic group-fault events quarantine | steal | speculate |
    cancel. ``window_bound`` is the streaming occupancy cap
    G*W*(depth+1); ``reported_peak`` the executor's own realized
    high-water mark (``peak_window_blocks``)."""
    label: str
    trace: Sequence[Tuple]
    deps: Dict[Coord, Sequence[Coord]]
    window_bound: Optional[int] = None
    reported_peak: Optional[int] = None
    kind: str = field(default="trace", init=False)


@dataclass
class GraphArtifact:
    """A phase graph as a plain dep map (coord -> dep coords), with any
    pre-resolved coords (checkpoint resume) counted as satisfied."""
    label: str
    deps: Dict[Coord, Sequence[Coord]]
    resolved: Sequence[Coord] = ()
    kind: str = field(default="graph", init=False)


@dataclass
class PlanArtifact:
    """The executable-shape plan a partition + coalesce_shapes choice
    implies: one hashable signature per distinct compilation, against a
    cap."""
    label: str
    signatures: Sequence[Any]
    cap: int = 8
    kind: str = field(default="plan", init=False)
