"""Runtime guards — the dynamic complements of the static passes."""
from __future__ import annotations

import contextlib

import jax


@contextlib.contextmanager
def no_host_transfers():
    """Fail loudly on ANY implicit host<->device transfer inside the
    block: the runtime twin of the 'host-callback' jaxpr pass. The PP
    engine's contract is that posterior summaries stay device-resident
    between dispatch and final aggregation — wrap the aggregation (or any
    phase-internal region) in this to prove it:

        with guards.no_host_transfers():
            U_agg = PP._aggregate_axis(part, posts, axis="row")

    Warm the executable first where compilation-time constant transfers
    would trip the guard."""
    with jax.transfer_guard("disallow"):
        yield
