"""Pass-based static invariant analyzer for the PP engine.

The paper's performance claims rest on structural invariants —
communication confined within blocks, no materialized (N, M, K)
intermediates, donated buffers actually recycled, dependency-safe
dispatch — that used to be checked by ad-hoc snippets scattered across
``bmf_dryrun``, the conformance suite and individual tests. This package
is the single enforcement layer: a registry of ``Pass`` objects, each
analyzing ONE artifact kind, that every executor and kernel auto-enrolls
in via ``launch/bmf_lint.py``.

Artifact kinds (see ``registry``):

  jaxpr  — traced-but-unlowered programs: materialization budget,
           dtype promotion, host callbacks (``jaxpr_passes``)
  hlo    — compiled modules + buffer assignment: collective confinement
           and per-comm-mode budgets, donation effectiveness
           (``hlo_passes``)
  trace  — executor dispatch/resolve event traces: happens-before,
           watchdog redispatch ordering, window occupancy
           (``trace_passes``)
  graph  — ``build_phase_graph`` output: cycles, unreachable blocks,
           dangling deps (``trace_passes``; the engine runs this pass
           before any dispatch)
  plan   — ``partition`` + ``coalesce_shapes`` plans: recompilation
           budget (``hlo_passes``)

``analyze(artifact)`` runs every registered pass of the artifact's kind
and returns the violations; ``guards`` holds the runtime complements
(``no_host_transfers``).
"""
from repro.analysis.registry import (  # noqa: F401
    Pass, Violation, analyze, get_pass, passes, register,
    GraphArtifact, HLOArtifact, JaxprArtifact, PlanArtifact, TraceArtifact,
)
from repro.analysis import jaxpr_passes  # noqa: F401  (registers passes)
from repro.analysis import hlo_passes    # noqa: F401
from repro.analysis import trace_passes  # noqa: F401
from repro.analysis import guards        # noqa: F401
