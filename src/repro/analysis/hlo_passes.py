"""HLO/buffer-assignment passes: collective confinement with
per-comm-mode budgets, donation effectiveness, recompilation budget.

These generalize the ad-hoc checks that used to live in
``launch/bmf_dryrun`` (replica-group confinement assert, alias-bytes
reporting) into registry passes every lowered executable enrolls in.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional

from repro.analysis.registry import (HLOArtifact, Pass, PlanArtifact,
                                     Violation, register)
from repro.roofline import analysis as ROOF

# Per-comm-mode collective budgets: flat instruction counts allowed in a
# lowered chain executable (the sweep body appears once in HLO text, so a
# flat count IS the per-sweep count). Kinds absent from a budget must not
# appear at all. The shapes follow distributed.py's comm modes, verified
# against the composed 2-D lowerings across every prior structure:
#   gather  — the U-step all-gathers the freshly sampled U row shards
#             (V replicated): exactly 1 all-gather per sweep.
#   psum    — the V-step psums its (Lambda, eta) partial stats — the
#             paper's single logical psum, lowered as 2 all-reduces —
#             plus the U-step's factor gather.
#   scatter — the V-step psum-scatters the partial stats (2
#             reduce-scatters) and all-gathers the sampled shard, plus
#             the U-step gather.
# comm=None (block-only sharding, single-block async chains, streaming
# windows) allows NO collectives: same-phase blocks never talk.
COLLECTIVE_BUDGETS: Dict[Optional[str], Dict[str, int]] = {
    None: {},
    "gather": {"all-gather": 1},
    "psum": {"all-gather": 1, "all-reduce": 2},
    "scatter": {"all-gather": 2, "reduce-scatter": 2},
}


def default_budget(comm: Optional[str]) -> Dict[str, int]:
    """The comm mode's per-sweep collective budget."""
    if comm not in COLLECTIVE_BUDGETS:
        raise ValueError(f"unknown comm mode {comm!r} "
                         f"(expected {sorted(COLLECTIVE_BUDGETS, key=str)})")
    return dict(COLLECTIVE_BUDGETS[comm])


def _flat_collective_counts(hlo_text: str) -> Dict[str, int]:
    return ROOF.collective_counts(hlo_text)


def _collective_confinement(art: HLOArtifact) -> List[Violation]:
    out = []
    # (1) zero 'block'-axis crossings: every replica group must lie
    # within one allowed 'data' row
    if art.allowed_groups is not None:
        chk = ROOF.collectives_confined_to_groups(art.hlo_text,
                                                  art.allowed_groups)
        for op, grp in chk["crossing"]:
            out.append(Violation(
                "collective-confinement", art.label,
                f"{op} replica group {grp} crosses the 'block' axis "
                f"(allowed 'data' rows: {[list(g) for g in art.allowed_groups]})",
                "blocks never talk during a phase — shard_map the batch "
                "with in_specs P('block') and keep every collective on "
                "the 'data' axis of the group submesh"))
    # (2) per-comm-mode budget: the mode dictates exactly which
    # collective kinds a sweep may contain, and how many
    budget = (art.collective_budget if art.collective_budget is not None
              else default_budget(art.comm))
    counts = _flat_collective_counts(art.hlo_text)
    for kind, n in sorted(counts.items()):
        cap = budget.get(kind, 0)
        if n > cap:
            out.append(Violation(
                "collective-confinement", art.label,
                f"{n} {kind} instruction(s) in a comm={art.comm!r} "
                f"executable (budget {cap})",
                f"comm={art.comm!r} allows only {budget or 'no collectives'}"
                f" per sweep — an extra collective means a factor update "
                f"is re-reducing stats it should keep shard-local "
                f"(see distributed.COMM_MODES)"))
    return out


register(Pass(
    "collective-confinement", "hlo",
    "every collective is confined to a 'data'-axis replica group and the "
    "comm mode's per-sweep collective budget holds",
    _collective_confinement))


def alias_param_ids(hlo_text: str) -> Optional[List[int]]:
    """Parameter numbers XLA aliased to outputs, parsed from the module
    header's ``input_output_alias={ {out}: (param, {index}, kind), ... }``.
    Returns None when the module declares no aliasing at all."""
    start = hlo_text.find("input_output_alias={")
    if start < 0:
        return None
    i = start + len("input_output_alias=")
    depth = 0
    for j in range(i, len(hlo_text)):
        if hlo_text[j] == "{":
            depth += 1
        elif hlo_text[j] == "}":
            depth -= 1
            if depth == 0:
                section = hlo_text[i:j + 1]
                return [int(m.group(1)) for m in
                        re.finditer(r":\s*\((\d+)", section)]
    return None


def _entry_param_count(hlo_text: str) -> Optional[int]:
    ids = {int(m.group(1))
           for m in re.finditer(r"=\s*(?:\([^)]*\)|\S+)\s+parameter\((\d+)\)",
                                hlo_text)}
    return (max(ids) + 1) if ids else None


def _donation_effectiveness(art: HLOArtifact) -> List[Violation]:
    if not art.donated:
        return []
    out = []
    aliased = alias_param_ids(art.hlo_text)
    if aliased is None:
        return [Violation(
            "donation-effectiveness", art.label,
            f"donate_argnums declared ({len(art.donated)} buffers) but the "
            f"compiled module has NO input_output_alias map",
            "XLA dropped every donation — check the donated leaves' "
            "shapes/dtypes still match an output (a shape drift silently "
            "turns aliasing off and doubles peak memory)")]
    if art.param_labels is None:
        return out
    label_to_id = {lb: i for i, lb in enumerate(art.param_labels)}
    n_hlo = _entry_param_count(art.hlo_text)
    if n_hlo is not None and n_hlo != len(art.param_labels):
        # compiled param numbering diverged from the flat arg order
        # (pruned unused args) — per-param attribution would misfire
        return [Violation(
            "donation-effectiveness", art.label,
            f"compiled module has {n_hlo} parameters but the call site "
            f"passes {len(art.param_labels)} leaves — donation aliases "
            f"cannot be attributed",
            "an argument was pruned as unused (keep_unused=False); drop "
            "it from the dispatch signature so donate_argnums and the "
            "buffer assignment describe the same parameter list")]
    aliased_set = set(aliased)
    release_ok = set(art.release_only)
    must = set(art.must_alias)
    for lb in art.donated:
        pid = label_to_id.get(lb)
        if pid is None:
            continue
        if pid in aliased_set:
            continue
        if lb in must:
            out.append(Violation(
                "donation-effectiveness", art.label,
                f"donated buffer {lb!r} (param {pid}) never aliases an "
                f"output in the buffer assignment",
                "this donation must be rewritten in place (U0/V0 alias "
                "the U/V outputs on every backend) — a dtype/shape "
                "mismatch or an output copy is blocking the alias"))
        elif lb not in release_ok:
            out.append(Violation(
                "donation-effectiveness", art.label,
                f"donated buffer {lb!r} (param {pid}) is unusable: no "
                f"output aliases it and it is not documented as "
                f"release-only",
                "either stop donating it or add it to the executable's "
                "release-only set (per-call buffers whose donation only "
                "returns them to the allocator at dispatch, see "
                "gibbs._quiet_donation)"))
    return out


register(Pass(
    "donation-effectiveness", "hlo",
    "every donate_argnums entry aliases an output, or is an explicitly "
    "documented release-only buffer — unusable donations are violations, "
    "not suppressed warnings",
    _donation_effectiveness))


def _recompilation_budget(art: PlanArtifact) -> List[Violation]:
    distinct = sorted({repr(s) for s in art.signatures})
    if len(distinct) <= art.cap:
        return []
    return [Violation(
        "recompilation-budget", art.label,
        f"plan implies {len(distinct)} distinct executable shapes "
        f"(cap {art.cap}): {distinct[:4]}{'...' if len(distinct) > 4 else ''}",
        "bucket blocks to shared shapes before dispatch — "
        "partition.coalesce_shapes merges near-size buckets under a "
        "max_waste bound, and BlockShapes.per_phase caps the grid at one "
        "shape per phase tag")]


register(Pass(
    "recompilation-budget", "plan",
    "a partition + coalesce_shapes plan implies at most `cap` distinct "
    "executable shapes",
    _recompilation_budget))
