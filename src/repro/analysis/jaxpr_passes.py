"""Jaxpr-level passes: materialization budget, dtype promotion, host
callbacks. All three walk the FULL nested jaxpr (scan/while/cond bodies,
pjit sub-jaxprs, pallas kernels) via ``roofline.jaxpr_cost`` traversal —
a materialized (N, M, K) tensor hiding inside a scanned sweep body is
exactly the bug class these exist to catch."""
from __future__ import annotations

from typing import List

import numpy as np

from repro.analysis.registry import JaxprArtifact, Pass, Violation, register
from repro.roofline import jaxpr_cost as JCOST


def materialization_budget(n_rows: int, n_cols: int, m_rows: int,
                           m_cols: int, K: int, batch: int = 1,
                           slack: float = 2.0) -> int:
    """Largest buffer a fused block chain legitimately holds, from block
    dims: the per-observation factor gathers on the padded CSR planes
    (B*n*m*K f32 — U[idx] per plane slot) and the per-row outer-product
    accumulators (B*n*K*K f32), whichever is bigger, times ``slack`` for
    layout/padding headroom. The naive sufficient-stats formulation
    materializes the DENSE (N_block, M_block, K) factor tensor instead —
    a factor M_block/m_pad over the plane gather (full column dim vs the
    padded per-row observation width), so it trips the pass whenever the
    block is meaningfully sparse."""
    plane = max(n_rows * m_rows, n_cols * m_cols) * K
    outer = max(n_rows, n_cols) * K * K
    return int(slack * 4 * batch * max(plane, outer))


def _materialization(art: JaxprArtifact) -> List[Violation]:
    if art.bytes_budget is None:
        return []
    seen = set()
    out = []
    for aval in JCOST.iter_avals(art.jaxpr):
        nb = JCOST._nbytes(aval)
        if nb <= art.bytes_budget:
            continue
        sig = (str(getattr(aval, "dtype", "?")), tuple(aval.shape))
        if sig in seen:
            continue
        seen.add(sig)
        out.append(Violation(
            "materialization", art.label,
            f"aval {sig[0]}{list(sig[1])} is {nb} bytes, over the "
            f"{art.bytes_budget}-byte block budget",
            "a gathered/broadcast intermediate is being materialized — "
            "route the sufficient-stats accumulation through the fused "
            "gather kernel (core.kernels) or chunk the contraction so no "
            "buffer exceeds the padded CSR plane"))
    return out


register(Pass(
    "materialization", "jaxpr",
    "no aval anywhere in the (nested) jaxpr exceeds the block-dim byte "
    "budget — the no-(N,M,K)-tensor invariant",
    _materialization))


# fp32-required linear-algebra primitives: the Cholesky factor/solve path
# of the posterior update loses PD-ness in half precision.  ``sqrt`` is
# the in-register Cholesky diagonal of the fused sweep kernel
# (kernels/bmf_sweep hand-rolls the factorization, so no cholesky
# primitive appears in its jaxpr — the diagonal sqrt is the operand the
# mixed-precision mode must keep f32)
_FP32_REQUIRED = ("cholesky", "triangular_solve", "sqrt")
_LOW_PRECISION = ("bfloat16", "float16")


def _dtype_promotion(art: JaxprArtifact) -> List[Violation]:
    out = []
    seen = set()
    if not art.allow_f64:
        for aval in JCOST.iter_avals(art.jaxpr):
            dt = str(getattr(aval, "dtype", ""))
            if dt != "float64":
                continue
            sig = tuple(aval.shape)
            if sig in seen:
                continue
            seen.add(sig)
            out.append(Violation(
                "dtype-promotion", art.label,
                f"silent f64 upcast: f64{list(sig)} appears in the jaxpr",
                "a host-side numpy float64 leaked into the traced program "
                "— cast inputs to float32 at the data layer (or mark the "
                "artifact allow_f64 if the upcast is deliberate)"))
    for eqn in JCOST.iter_eqns(art.jaxpr):
        if eqn.primitive.name not in _FP32_REQUIRED:
            continue
        for v in eqn.invars:
            dt = str(getattr(getattr(v, "aval", None), "dtype", ""))
            if dt in _LOW_PRECISION:
                out.append(Violation(
                    "dtype-promotion", art.label,
                    f"{eqn.primitive.name} sees {dt} operand "
                    f"{list(v.aval.shape)} — the posterior factor/solve "
                    f"path requires fp32",
                    "keep mixed precision on the gather/accumulate side "
                    "only: upcast the Lambda accumulator to float32 "
                    "before from_moments_cov"))
    return out


register(Pass(
    "dtype-promotion", "jaxpr",
    "no silent f64 upcast; Cholesky/triangular-solve/sqrt operands are "
    "never bf16/f16",
    _dtype_promotion))


# primitives that punch through to the host from inside a jitted body —
# any of these inside a phase chain serializes the dispatch pipeline
_HOST_PRIMS = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "outside_call", "host_callback_call", "infeed", "outfeed",
})


def _host_callback(art: JaxprArtifact) -> List[Violation]:
    out = []
    for eqn in JCOST.iter_eqns(art.jaxpr):
        if eqn.primitive.name in _HOST_PRIMS:
            out.append(Violation(
                "host-callback", art.label,
                f"host round-trip primitive {eqn.primitive.name!r} inside "
                f"a jitted phase body",
                "phase chains must stay device-resident end to end "
                "(guards.no_host_transfers is the runtime twin of this "
                "check) — move the callback outside the jitted chain or "
                "compute the quantity on device"))
    return out


register(Pass(
    "host-callback", "jaxpr",
    "no host-callback/transfer primitive inside a jitted phase body",
    _host_callback))
