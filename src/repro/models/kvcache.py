"""KV-cache / recurrent-state containers for serving.

Dense / MoE / enc-dec attention layers use a (possibly ring-buffered) KV
cache; SSM / hybrid layers carry recurrent state. The cache is a plain
pytree so it shards with NamedSharding like any other step input.

Ring buffer (sliding-window): ``max_len == window``; slot ``pos % window`` is
overwritten and per-slot absolute positions are tracked in ``kv_pos`` so the
flash-attention mask stays correct after wrap-around.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


def attn_cache_init(cfg: ArchConfig, n_layers: int, batch: int, max_len: int,
                    dtype=jnp.bfloat16, quant: bool = False):
    hd = cfg.resolved_head_dim
    if quant:
        # int8 cache with per (slot, head) scales — halves the decode
        # memory term, which dominates full-attention serving (§Perf H2)
        return {
            "k": jnp.zeros((n_layers, batch, max_len, cfg.n_kv_heads, hd),
                           jnp.int8),
            "v": jnp.zeros((n_layers, batch, max_len, cfg.n_kv_heads, hd),
                           jnp.int8),
            "k_scale": jnp.zeros((n_layers, batch, max_len, cfg.n_kv_heads),
                                 jnp.float32),
            "v_scale": jnp.zeros((n_layers, batch, max_len, cfg.n_kv_heads),
                                 jnp.float32),
            "kv_pos": jnp.full((n_layers, max_len), -1, jnp.int32),
        }
    return {
        "k": jnp.zeros((n_layers, batch, max_len, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((n_layers, batch, max_len, cfg.n_kv_heads, hd), dtype),
        # absolute position stored in each slot; -1 = empty
        "kv_pos": jnp.full((n_layers, max_len), -1, jnp.int32),
    }


def quantize_kv(x):
    """x: (B, 1, Hkv, hd) -> (int8 values, (B, 1, Hkv) f32 scales)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def attn_cache_update(cache_layer_k, cache_layer_v, kv_pos, k_new, v_new, pos,
                      ring: bool, k_scale=None, v_scale=None):
    """Write one token (k_new/v_new: (B, 1, Hkv, hd)) at absolute position
    ``pos``; returns updated (k, v, kv_pos[, k_scale, v_scale])."""
    max_len = cache_layer_k.shape[1]
    slot = jnp.where(ring, pos % max_len, pos)
    if cache_layer_k.dtype == jnp.int8:
        kq, ks = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        k = jax.lax.dynamic_update_slice_in_dim(cache_layer_k, kq, slot, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(cache_layer_v, vq, slot, axis=1)
        k_scale = jax.lax.dynamic_update_slice_in_dim(k_scale, ks, slot, axis=1)
        v_scale = jax.lax.dynamic_update_slice_in_dim(v_scale, vs, slot, axis=1)
        kv_pos = jax.lax.dynamic_update_slice_in_dim(
            kv_pos, jnp.full((1,), pos, jnp.int32), slot, axis=0)
        return k, v, kv_pos, k_scale, v_scale
    k = jax.lax.dynamic_update_slice_in_dim(cache_layer_k, k_new, slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache_layer_v, v_new, slot, axis=1)
    kv_pos = jax.lax.dynamic_update_slice_in_dim(
        kv_pos, jnp.full((1,), pos, jnp.int32), slot, axis=0)
    return k, v, kv_pos


def cache_view(cache, layer_idx):
    k = cache["k"][layer_idx]
    v = cache["v"][layer_idx]
    kv_pos = cache["kv_pos"][layer_idx]
    valid = kv_pos >= 0
    return k, v, kv_pos, valid


def serve_cache_init(cfg: ArchConfig, batch: int, seq_len: int,
                     dtype=jnp.bfloat16, window_override: Optional[int] = None,
                     kv_quant: bool = False):
    """Build the full serving state pytree for one architecture.

    ``seq_len`` is the context the cache must represent. For sliding-window
    attention the buffer is only ``window`` slots; for SSM/hybrid, constant
    state. ``pos`` is the number of tokens already consumed.
    """
    from repro.models.mamba2 import mamba2_state_init  # cycle-free local import

    window = window_override if window_override is not None else cfg.sliding_window
    state = {"pos": jnp.zeros((), jnp.int32)}

    if cfg.family == "ssm":  # rwkv6
        d = cfg.d_model
        H = d // cfg.wkv_head_dim
        N = cfg.wkv_head_dim
        state["wkv"] = jnp.zeros((cfg.n_layers, batch, H, N, N), jnp.float32)
        state["shift_att"] = jnp.zeros((cfg.n_layers, batch, d), dtype)
        state["shift_ffn"] = jnp.zeros((cfg.n_layers, batch, d), dtype)
        return state

    if cfg.family == "hybrid":  # zamba2
        per_layer = mamba2_state_init(cfg, batch, dtype)
        state["mamba"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (cfg.n_layers,) + x.shape).copy(),
            per_layer)
        n_attn = (cfg.n_layers + cfg.shared_attn_period - 1) // cfg.shared_attn_period
        eff_window = window if window > 0 else min(seq_len, 4096)
        # ring-buffer size doubles as the attention window (static shape)
        state["attn"] = attn_cache_init(cfg, n_attn, batch, eff_window, dtype)
        return state

    # dense / moe / vlm / enc-dec decoder
    max_len = window if window > 0 else seq_len
    state["attn"] = attn_cache_init(cfg, cfg.n_layers, batch, max_len, dtype,
                                    quant=kv_quant)
    if cfg.is_encdec:
        hd = cfg.resolved_head_dim
        state["cross_k"] = jnp.zeros(
            (cfg.n_layers, batch, cfg.n_audio_frames, cfg.n_kv_heads, hd), dtype)
        state["cross_v"] = jnp.zeros_like(state["cross_k"])
    return state
