"""RWKV6 (Finch) — attention-free time-mix with data-dependent decay.

Recurrence (per head, key-dim N_k = value-dim N_v = wkv_head_dim):

    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t^T S_{t-1} + (r_t ⊙ u ⊙ k_t) · v_t           (u = per-channel bonus)

with per-channel decay w_t ∈ (0,1) computed from the input via a small LoRA
(data-dependent decay — the core Finch novelty vs RWKV5).

Training/prefill uses a chunked formulation (lax.scan over chunks of length
``CHUNK``; intra-chunk via masked decayed attention einsum, inter-chunk via the
carried state) — O(S·C·N) memory instead of O(S²). The Pallas kernel in
``repro/kernels/wkv6`` implements a single chunk; this module is its jnp
reference path and the decode (single-step) path.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import _dense_init, rmsnorm, rmsnorm_init

CHUNK = 128
LORA_R = 64


def _use_pallas_wkv() -> bool:
    """Route the chunked recurrence through the Pallas wkv6 kernel
    (fwd-only paths: prefill/serve — no custom VJP yet)."""
    return os.environ.get("REPRO_PALLAS_WKV", "0") == "1"


def timemix_init(key, cfg: ArchConfig):
    d = cfg.d_model
    ks = jax.random.split(key, 10)
    H = d // cfg.wkv_head_dim
    return {
        "mix_base": jnp.full((5, d), 0.5, jnp.float32),  # r,k,v,g,w token-shift mixes
        "wr": _dense_init(ks[0], (d, d)),
        "wk": _dense_init(ks[1], (d, d)),
        "wv": _dense_init(ks[2], (d, d)),
        "wg": _dense_init(ks[3], (d, d)),
        "wo": _dense_init(ks[4], (d, d)),
        # data-dependent decay LoRA: w_t = exp(-exp(w0 + tanh(x A) B))
        "decay_w0": jnp.full((d,), -6.0, jnp.float32),
        "decay_A": _dense_init(ks[5], (d, LORA_R), scale=0.01),
        "decay_B": _dense_init(ks[6], (LORA_R, d), scale=0.01),
        "bonus_u": jnp.zeros((d,), jnp.float32),
        "ln_out": rmsnorm_init(d),
    }


def channelmix_init(key, cfg: ArchConfig):
    d, f = cfg.d_model, cfg.d_ff
    k1, k2 = jax.random.split(key)
    return {
        "mix_base": jnp.full((1, d), 0.5, jnp.float32),
        "w_in": _dense_init(k1, (d, f)),
        "w_out": _dense_init(k2, (f, d)),
    }


def _token_shift(x, x_prev):
    """x: (B,S,d); x_prev: (B,d) last token of previous segment."""
    shifted = jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)
    return shifted


def wkv_chunked(r, k, v, logw, u, state0):
    """Chunked WKV recurrence.

    r,k,v: (B, S, H, N); logw: (B, S, H, N) = log decay (negative);
    u: (H, N); state0: (B, H, N, N). Returns y (B,S,H,N), state (B,H,N,N).
    S must be a multiple of CHUNK (caller pads).
    """
    B, S, H, N = r.shape
    nc = S // CHUNK
    rc = r.reshape(B, nc, CHUNK, H, N)
    kc = k.reshape(B, nc, CHUNK, H, N)
    vc = v.reshape(B, nc, CHUNK, H, N)
    wc = logw.reshape(B, nc, CHUNK, H, N)

    def chunk_step(state, inp):
        rb, kb, vb, wb = inp  # (B, C, H, N)
        L = jnp.cumsum(wb, axis=1)                      # L_t = sum_{s<=t} log w_s
        Lm1 = L - wb                                    # L_{t-1} (with L_{-1}=0)
        # inter-chunk: y_t += (r_t * exp(L_{t-1})) @ state
        r_dec = rb * jnp.exp(Lm1)
        y_inter = jnp.einsum("bchn,bhnm->bchm", r_dec, state)
        # intra-chunk: A[t,j] = sum_n r_t,n exp(L_{t-1,n} - L_{j,n}) k_j,n, j<t
        # factorized as (r_t exp(L_{t-1} - c)) · (k_j exp(c - L_j)) with the
        # mid-chunk shift c = L_C/2 so neither factor overflows even under
        # strong decay (|exponent| <= |L_C|/2 instead of |L_C|).
        c = L[:, -1:] * 0.5
        r_dec2 = rb * jnp.exp(Lm1 - c)
        k_dec = kb * jnp.exp(c - L)
        A = jnp.einsum("bchn,bjhn->bhcj", r_dec2, k_dec)
        mask = jnp.tril(jnp.ones((CHUNK, CHUNK), bool), -1)
        A = jnp.where(mask[None, None], A, 0.0)
        y_intra = jnp.einsum("bhcj,bjhm->bchm", A, vb)
        # current-token bonus: y_t,m += (sum_n r_n u_n k_n) v_m
        y_diag = jnp.einsum("bchn,bchn->bch", rb * u, kb)[..., None] * vb
        y = y_inter + y_intra + y_diag
        # state update: S' = diag(exp(L_C)) S + sum_j diag(exp(L_C - L_j)) k_j v_j^T
        LC = L[:, -1]                                    # (B, H, N)
        k_tail = kb * jnp.exp(LC[:, None] - L)
        state_new = jnp.exp(LC)[..., None] * state + jnp.einsum(
            "bjhn,bjhm->bhnm", k_tail, vb)
        return state_new, y

    state, ys = jax.lax.scan(
        jax.checkpoint(chunk_step),  # don't save per-chunk intermediates
        state0,
        (jnp.moveaxis(rc, 1, 0), jnp.moveaxis(kc, 1, 0),
         jnp.moveaxis(vc, 1, 0), jnp.moveaxis(wc, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, N)
    return y, state


def wkv_step(r, k, v, logw, u, state):
    """Single decode step. r,k,v,logw: (B,H,N); state: (B,H,N,N)."""
    y = jnp.einsum("bhn,bhnm->bhm", r, state)
    y = y + jnp.einsum("bhn,bhn->bh", r * u, k)[..., None] * v
    state = jnp.exp(logw)[..., None] * state + k[..., None] * v[..., None, :]
    return y, state


def timemix_apply(params, cfg: ArchConfig, x, x_prev, state):
    """x: (B,S,d). x_prev: (B,d). state: (B,H,N,N). Returns y, x_last, state."""
    B, S, d = x.shape
    N = cfg.wkv_head_dim
    H = d // N
    shifted = _token_shift(x, x_prev)
    mix = params["mix_base"].astype(x.dtype)  # (5, d)
    xs = [x + mix[i] * (shifted - x) for i in range(5)]
    r = jnp.einsum("bsd,de->bse", xs[0], params["wr"].astype(x.dtype))
    k = jnp.einsum("bsd,de->bse", xs[1], params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,de->bse", xs[2], params["wv"].astype(x.dtype))
    g = jnp.einsum("bsd,de->bse", xs[3], params["wg"].astype(x.dtype))
    lora = jnp.tanh(xs[4].astype(jnp.float32) @ params["decay_A"]) @ params["decay_B"]
    logw = -jnp.exp(params["decay_w0"] + lora)          # (B,S,d), < 0
    u = params["bonus_u"].reshape(H, N)

    rf = r.astype(jnp.float32).reshape(B, S, H, N)
    kf = k.astype(jnp.float32).reshape(B, S, H, N)
    vf = v.astype(jnp.float32).reshape(B, S, H, N)
    wf = logw.reshape(B, S, H, N)

    pad = (-S) % CHUNK
    if S == 1:
        y, state = wkv_step(rf[:, 0], kf[:, 0], vf[:, 0], wf[:, 0], u, state)
        y = y[:, None]
    else:
        if pad:
            rf = jnp.pad(rf, ((0, 0), (0, pad), (0, 0), (0, 0)))
            kf = jnp.pad(kf, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vf = jnp.pad(vf, ((0, 0), (0, pad), (0, 0), (0, 0)))
            wf = jnp.pad(wf, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if _use_pallas_wkv():
            from repro.kernels.wkv6 import ops as WKVK
            y, state = WKVK.wkv6(rf, kf, vf, wf, u, state)
        else:
            y, state = wkv_chunked(rf, kf, vf, wf, u, state)
        y = y[:, :S]

    y = y.reshape(B, S, d)
    y = rmsnorm(params["ln_out"], y, cfg.norm_eps)
    y = y.astype(x.dtype) * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", y, params["wo"].astype(x.dtype))
    return out, x[:, -1, :], state


def channelmix_apply(params, cfg: ArchConfig, x, x_prev):
    shifted = _token_shift(x, x_prev)
    mix = params["mix_base"].astype(x.dtype)
    xk = x + mix[0] * (shifted - x)
    h = jnp.einsum("bsd,df->bsf", xk, params["w_in"].astype(x.dtype))
    h = jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(x.dtype)
    return jnp.einsum("bsf,fd->bsd", h, params["w_out"].astype(x.dtype)), x[:, -1, :]
