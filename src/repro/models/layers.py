"""Core transformer layers as pure init/apply functions.

Conventions
-----------
- Params are nested dicts of jnp arrays. Layer stacks store params with a
  leading ``(L, ...)`` axis and are applied with ``jax.lax.scan`` so the HLO
  (and compile time) stays O(1) in depth.
- Params are kept in float32 (master weights); activations/compute default to
  bfloat16 (``cfg.dtype``); logits and softmax statistics are float32.
- Attention is computed with a chunked online-softmax ("flash" style) scan
  over KV blocks so the S×S score matrix is never materialized — required
  for the 32k-prefill and 4k×256-batch train shapes to fit in VMEM/HBM.
"""
from __future__ import annotations

import math
import os
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.sharding.constraints import batch_axes, constrain

# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def _dense_init(key, shape, scale: Optional[float] = None):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale)


def _embed_init(key, shape):
    return jax.random.normal(key, shape, dtype=jnp.float32) * 0.02


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params, x, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"]).astype(x.dtype)


def layernorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(params, x, eps: float):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(x.dtype)


def make_norm(cfg: ArchConfig):
    """Whisper uses LayerNorm; the rest of the zoo uses RMSNorm."""
    if cfg.family == "audio":
        return layernorm_init, layernorm
    return rmsnorm_init, rmsnorm


# ---------------------------------------------------------------------------
# RoPE (incl. ChatGLM partial / "2d" variant via rope_partial < 1)
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, rope_partial: float, theta: float):
    rot_dim = int(head_dim * rope_partial)
    rot_dim -= rot_dim % 2
    inv_freq = 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim))
    return inv_freq, rot_dim


def apply_rope(x, positions, inv_freq, rot_dim: int):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    if rot_dim == 0:
        return x
    x_rot, x_pass = x[..., :rot_dim], x[..., rot_dim:]
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # (..., S, rot/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1)


def sinusoidal_positions(n_pos: int, d: int):
    pos = jnp.arange(n_pos, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10_000.0, dim / d)
    pe = jnp.zeros((n_pos, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(angle))
    pe = pe.at[:, 1::2].set(jnp.cos(angle))
    return pe


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def attention_init(key, cfg: ArchConfig):
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(kq, (d, cfg.n_heads * hd)),
        "wk": _dense_init(kk, (d, cfg.n_kv_heads * hd)),
        "wv": _dense_init(kv, (d, cfg.n_kv_heads * hd)),
        "wo": _dense_init(ko, (cfg.n_heads * hd, d)),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd)
        p["k_norm"] = rmsnorm_init(hd)
    return p


def _flash_attend(q, k, v, *, causal: bool, window: int, q_offset, kv_positions=None,
                  kv_valid=None, chunk: int = 1024, k_scale=None, v_scale=None):
    """Chunked online-softmax attention.

    q: (B, Sq, H, hd); k/v: (B, Skv, Hkv, hd). GQA by head repeat-grouping.
    window > 0 => sliding-window causal attention.
    kv_positions: (Skv,) absolute positions of kv slots (for ring caches);
    kv_valid: (Skv,) bool mask of filled slots. q positions are
    q_offset + arange(Sq).
    Returns (B, Sq, H, hd).
    """
    B, Sq, H, hd = q.shape
    _, Skv, Hkv, _ = k.shape
    group = H // Hkv
    scale = 1.0 / math.sqrt(hd)

    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, Hkv, group, hd)
    q_pos = q_offset + jnp.arange(Sq)

    if kv_positions is None:
        kv_positions = jnp.arange(Skv)
    if kv_valid is None:
        kv_valid = jnp.ones((Skv,), bool)

    n_chunks = max(1, (Skv + chunk - 1) // chunk)
    pad = n_chunks * chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, (0, pad))
        kv_valid = jnp.pad(kv_valid, (0, pad))
        if k_scale is not None:
            k_scale = jnp.pad(k_scale, ((0, 0), (0, pad), (0, 0)))
            v_scale = jnp.pad(v_scale, ((0, 0), (0, pad), (0, 0)))

    kc = k.reshape(B, n_chunks, chunk, Hkv, hd)
    vc = v.reshape(B, n_chunks, chunk, Hkv, hd)
    pc = kv_positions.reshape(n_chunks, chunk)
    mc = kv_valid.reshape(n_chunks, chunk)
    quant = k_scale is not None
    if quant:
        ksc = jnp.moveaxis(k_scale.reshape(B, n_chunks, chunk, Hkv), 1, 0)
        vsc = jnp.moveaxis(v_scale.reshape(B, n_chunks, chunk, Hkv), 1, 0)
    else:  # dummy streams keep the scan signature uniform
        ksc = vsc = jnp.zeros((n_chunks, 1, 1, 1), jnp.float32)

    def step(carry, inputs):
        m_run, l_run, acc = carry
        kb, vb, pb, vb_mask, ksb, vsb = inputs
        if quant:
            # dequantize int8 cache chunk-wise (fused, never materialized)
            kb = kb.astype(jnp.float32) * ksb[..., None]
            vb = vb.astype(jnp.float32) * vsb[..., None]
        # scores: (B, Sq, Hkv, group, chunk)
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qf, kb.astype(jnp.float32))
        mask = vb_mask[None, None, :]
        if causal:
            mask = mask & (pb[None, None, :] <= q_pos[None, :, None])
        if window > 0:
            mask = mask & (pb[None, None, :] > q_pos[None, :, None] - window)
        s = jnp.where(mask[:, :, None, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        # guard: rows with no valid key yet keep m=-inf; exp(-inf - -inf) nan
        m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[:, :, None, None, :], p, 0.0)
        corr = jnp.where(jnp.isinf(m_run), 0.0, jnp.exp(m_run - m_safe))
        l_new = l_run * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bqhgk,bkhd->bqhgd", p, vb.astype(jnp.float32))
        acc = acc * corr[..., None] + pv
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, Sq, Hkv, group), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, group), jnp.float32)
    a0 = jnp.zeros((B, Sq, Hkv, group, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), pc, mc, ksc, vsc))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def attention_apply(params, cfg: ArchConfig, x, *, positions, causal=True,
                    window=0, kv=None, kv_positions=None, kv_valid=None,
                    cross_kv=None, chunk=1024):
    """Self- or cross-attention.

    x: (B, S, d). positions: (S,) absolute positions of x tokens.
    cross_kv: optional (k, v) from an encoder — used instead of self kv.
    kv: optional externally provided (k, v, kv_positions, kv_valid) — the
    decode path passes the cache here (already rotated at write time).
    Returns (out, (k_new, v_new)) where k_new/v_new are this call's
    rotated K/V (for cache writes); None for cross-attention.
    """
    B, S, d = x.shape
    hd = cfg.resolved_head_dim
    inv_freq, rot_dim = rope_frequencies(hd, cfg.rope_partial, cfg.rope_theta)
    use_rope = cfg.family != "audio"  # whisper uses absolute sinusoidal pos

    q = jnp.einsum("bsd,dh->bsh", x, params["wq"].astype(x.dtype))
    q = q.reshape(B, S, cfg.n_heads, hd)
    # pin batch over data + heads over model (tensor-parallel attention)
    q = constrain(q, batch_axes(), None, "model", None)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
    if use_rope:
        q = apply_rope(q, jnp.broadcast_to(positions, (B, S)), inv_freq, rot_dim)

    if cross_kv is not None:
        # cross attention: no mask, q positions irrelevant
        k_x, v_x = cross_kv
        out = _flash_attend(q, k_x, v_x, causal=False, window=0, q_offset=0,
                            chunk=chunk)
        out = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, -1), params["wo"].astype(x.dtype))
        return out, None

    k = jnp.einsum("bsd,dh->bsh", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dh->bsh", x, params["wv"].astype(x.dtype))
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    k = constrain(k, batch_axes(), None, "model", None)
    v = constrain(v, batch_axes(), None, "model", None)
    if cfg.qk_norm:
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    if use_rope:
        k = apply_rope(k, jnp.broadcast_to(positions, (B, S)), inv_freq, rot_dim)

    # opt-in Pallas flash-attention for the self-attention (no-cache) path
    # (§Perf H7/H10). Tile-aligned shapes use the differentiable variant
    # (custom VJP backed by the two Pallas backward kernels) so training
    # goes through the kernel too; ragged shapes use the padded fwd-only
    # version (prefill/serve).
    if (kv is None and os.environ.get("REPRO_PALLAS_ATTN", "0") == "1"
            and S > 1):
        from repro.kernels.flash_attention import ops as FAK
        from repro.kernels.flash_attention.kernel import TK, TQ
        if S % TQ == 0 and S % TK == 0:
            o = FAK.flash_attention_trainable(q, k, v, causal, window)
        else:
            o = FAK.flash_attention(q, k, v, causal=causal, window=window)
        out = jnp.einsum("bsh,hd->bsd", o.astype(x.dtype).reshape(B, S, -1),
                         params["wo"].astype(x.dtype))
        return out, (k, v)

    if kv is not None:
        if len(kv) == 6:   # quantized cache: (k, v, pos, valid, k_scale, v_scale)
            k_all, v_all, kv_pos, kv_val, ks, vs = kv
        else:
            k_all, v_all, kv_pos, kv_val = kv
            ks = vs = None
        if (S == 1 and ks is None
                and os.environ.get("REPRO_PALLAS_DECODE_ATTN", "0") == "1"):
            # Pallas flash-decode kernel over the (ring) cache (§Perf)
            from repro.kernels.decode_attention import ops as DAK
            o = DAK.decode_attention(q[:, 0], k_all, v_all, kv_pos,
                                     positions[0], window=window)
            out = o[:, None].astype(x.dtype)
        else:
            out = _flash_attend(q, k_all, v_all, causal=causal, window=window,
                                q_offset=positions[0], kv_positions=kv_pos,
                                kv_valid=kv_val, chunk=chunk, k_scale=ks,
                                v_scale=vs)
    else:
        out = _flash_attend(q, k, v, causal=causal, window=window,
                            q_offset=0, chunk=chunk)
    out = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, -1), params["wo"].astype(x.dtype))
    return out, (k, v)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def swiglu_init(key, d: int, f: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": _dense_init(k1, (d, f)),
        "w_up": _dense_init(k2, (d, f)),
        "w_down": _dense_init(k3, (f, d)),
    }


def swiglu_apply(params, x):
    g = jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(x.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("bsf,fd->bsd", h, params["w_down"].astype(x.dtype))


def gelu_mlp_init(key, d: int, f: int):
    k1, k2 = jax.random.split(key, 2)
    return {
        "w_in": _dense_init(k1, (d, f)),
        "b_in": jnp.zeros((f,), jnp.float32),
        "w_out": _dense_init(k2, (f, d)),
        "b_out": jnp.zeros((d,), jnp.float32),
    }


def gelu_mlp_apply(params, x):
    h = jnp.einsum("bsd,df->bsf", x, params["w_in"].astype(x.dtype))
    h = h + params["b_in"].astype(x.dtype)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    o = jnp.einsum("bsf,fd->bsd", h, params["w_out"].astype(x.dtype))
    return o + params["b_out"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embedding_init(key, cfg: ArchConfig):
    """Tables use the *padded* vocab so the vocab dim shards evenly over the
    model axis; unembed masks the padding logits to a large negative."""
    ke, ko = jax.random.split(key)
    V = cfg.padded_vocab_size
    p = {"table": _embed_init(ke, (V, cfg.d_model))}
    if not cfg.tie_embeddings:
        p["unembed"] = _dense_init(ko, (cfg.d_model, V))
    return p


def embed(params, tokens, dtype):
    return params["table"].astype(dtype)[tokens]


def unembed(params, x, cfg: ArchConfig):
    if cfg.tie_embeddings:
        w = params["table"].astype(x.dtype).T
    else:
        w = params["unembed"].astype(x.dtype)
    logits = jnp.einsum("bsd,dv->bsv", x, w).astype(jnp.float32)
    V, Vp = cfg.vocab_size, cfg.padded_vocab_size
    if Vp != V:
        pad_mask = jax.lax.broadcasted_iota(jnp.int32, (Vp,), 0) >= V
        logits = jnp.where(pad_mask, -1e9, logits)
    return logits
