"""Training / serving step functions + ShapeDtypeStruct input specs.

These are the functions the launcher jits (and the dry-run lowers): they
close over the ArchConfig/TrainConfig so their only traced inputs are
params / optimizer state / batch / cache pytrees — all shardable.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape, TrainConfig
from repro.models import model as MODEL
from repro.models.kvcache import serve_cache_init
from repro.optim import adamw, schedules
from repro.sharding.constraints import batch_axes, constrain

# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def cross_entropy(logits, labels, mask):
    """logits: (B, S, V) f32; labels: (B, S) int32; mask: (B, S) f32.

    The gold logit is extracted with a one-hot contraction rather than
    take_along_axis: a gather along a vocab-sharded dim forces GSPMD to
    all-gather the full (B,S,V) tensor, while the contraction stays sharded
    and reduces with a psum.
    """
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.einsum("bsv,bsv->bs", logits, onehot)
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


def loss_fn(params, cfg: ArchConfig, batch, *, remat=True,
            remat_policy="full"):
    logits, aux = MODEL.forward(params, cfg, batch, remat=remat,
                                remat_policy=remat_policy)
    # keep the (B, S, V) tensor vocab-sharded over 'model' through the CE —
    # unsharded it is ~13 GB/device f32 at train_4k (see EXPERIMENTS §Perf)
    logits = constrain(logits, batch_axes(), None, "model")
    tokens = batch["tokens"]
    B, S_text = tokens.shape
    # next-token prediction over the text stream; for VLM the image prefix
    # positions produce no loss.
    text_logits = logits[:, -S_text:, :]
    labels = tokens[:, 1:]
    pred = text_logits[:, :-1, :]
    mask = jnp.ones_like(labels, jnp.float32)
    loss = cross_entropy(pred, labels, mask)
    if "moe_aux" in aux:
        loss = loss + 0.01 * aux["moe_aux"]
    metrics = {"loss": loss, **{k: v for k, v in aux.items()}}
    return loss, metrics


# ---------------------------------------------------------------------------
# Step factories
# ---------------------------------------------------------------------------


def make_train_step(cfg: ArchConfig, tcfg: TrainConfig):
    lr_fn = schedules.warmup_cosine(tcfg)

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, remat=tcfg.remat,
                              remat_policy=tcfg.remat_policy), has_aux=True
        )(params)

    def train_step(params, opt_state: adamw.AdamWState, batch):
        M = tcfg.microbatches
        if M > 1:
            # grad accumulation: scan over microbatches — divides the
            # activation footprint (remat stacks, logits) by M
            micro = jax.tree.map(
                lambda x: x.reshape((M, x.shape[0] // M) + x.shape[1:]), batch)

            def acc_step(carry, mb):
                g_acc, loss_acc, metr_acc = carry
                (loss, metrics), g = grads_of(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                metr_acc = jax.tree.map(lambda a, b: a + b, metr_acc, metrics)
                return (g_acc, loss_acc + loss, metr_acc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            metrics0 = jax.tree.map(
                lambda x: jnp.zeros(x.shape, x.dtype),
                jax.eval_shape(lambda: grads_of(
                    params, jax.tree.map(lambda x: x[0], micro))[0][1]))
            (grads, loss, metrics), _ = jax.lax.scan(
                acc_step, (g0, 0.0, metrics0),
                micro)
            grads = jax.tree.map(lambda g: g / M, grads)
            metrics = jax.tree.map(lambda m: m / M, metrics)
            loss = loss / M
        else:
            (loss, metrics), grads = grads_of(params, batch)
        grads, gnorm = adamw.clip_by_global_norm(grads, tcfg.grad_clip)
        lr = lr_fn(opt_state.step + 1)  # 1-based so warmup never yields lr=0
        params, opt_state = adamw.apply(params, grads, opt_state, tcfg, lr)
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, shape: InputShape,
                      window_override: Optional[int] = None):
    def prefill_step(params, batch):
        cache = serve_cache_init(cfg, batch["tokens"].shape[0], shape.seq_len,
                                 window_override=window_override)
        return MODEL.prefill(params, cfg, batch, cache)

    return prefill_step


def make_serve_step(cfg: ArchConfig, window_override: Optional[int] = None):
    def serve_step(params, cache, tokens):
        return MODEL.decode_step(params, cfg, cache, tokens,
                                 window_override=window_override)

    return serve_step


def cache_specs_quant(cfg: ArchConfig, shape: InputShape,
                      window_override: Optional[int] = None) -> Any:
    return jax.eval_shape(
        lambda: serve_cache_init(cfg, shape.global_batch, shape.seq_len,
                                 window_override=window_override,
                                 kv_quant=True))


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins — no allocation)
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ArchConfig, shape: InputShape) -> Dict[str, Any]:
    """Training / prefill batch spec for one (arch, shape)."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "vlm":
        n_img = cfg.n_image_tokens
        return {
            "tokens": _sds((B, S - n_img), jnp.int32),
            "image_embeds": _sds((B, n_img, cfg.d_model), jnp.bfloat16),
        }
    if cfg.family == "audio":
        return {
            "tokens": _sds((B, S), jnp.int32),
            "audio_embeds": _sds((B, cfg.n_audio_frames, cfg.d_model), jnp.bfloat16),
        }
    return {"tokens": _sds((B, S), jnp.int32)}


def cache_specs(cfg: ArchConfig, shape: InputShape,
                window_override: Optional[int] = None) -> Any:
    """ShapeDtypeStruct pytree matching serve_cache_init's output."""
    cache = jax.eval_shape(
        lambda: serve_cache_init(cfg, shape.global_batch, shape.seq_len,
                                 window_override=window_override))
    return cache


def decode_token_specs(shape: InputShape):
    return _sds((shape.global_batch, 1), jnp.int32)


def params_specs(cfg: ArchConfig):
    return jax.eval_shape(partial(MODEL.init_params, cfg=cfg),
                          jax.random.key(0))


def opt_specs(cfg: ArchConfig):
    p = params_specs(cfg)
    return jax.eval_shape(adamw.init, p)


def long_context_window(cfg: ArchConfig, shape: InputShape) -> Optional[int]:
    """Window override for full-attention archs on long_500k (DESIGN.md §4)."""
    if (shape.name == "long_500k" and cfg.family in ("dense", "vlm", "moe")
            and cfg.sliding_window == 0):
        return MODEL.LONG_CONTEXT_WINDOW
    return None
