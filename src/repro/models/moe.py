"""Mixture-of-Experts layer with GShard/Shazeer-style capacity-based one-hot
dispatch einsums.

Why capacity dispatch (and not dense all-expert compute): the dispatch/combine
einsums contract over a one-hot (group, token, expert, capacity) tensor, so the
expert FFN only processes ``E × C`` token slots — the compiled HLO FLOPs then
reflect *active* parameters (assignment: MODEL_FLOPS for MoE uses N_active),
and under pjit the (tokens over 'data') × (experts over 'model') sharding of
the dispatch einsum lowers to the canonical expert-parallel all-to-all.

Sharding strategy (see sharding/partitioning.py):
- ``E % model_axis == 0``  -> expert-parallel: experts sharded over 'model'.
- otherwise               -> tensor-parallel inside each expert: d_ff sharded.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import _dense_init
from repro.sharding.constraints import batch_axes, constrain


def moe_init(key, cfg: ArchConfig):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    kr, kg, ku, kd = jax.random.split(key, 4)
    return {
        "router": _dense_init(kr, (d, E), scale=0.02),
        "w_gate": jax.vmap(lambda k: _dense_init(k, (d, f)))(jax.random.split(kg, E)),
        "w_up": jax.vmap(lambda k: _dense_init(k, (d, f)))(jax.random.split(ku, E)),
        "w_down": jax.vmap(lambda k: _dense_init(k, (f, d)))(jax.random.split(kd, E)),
    }


def _top_k_mask(probs, k):
    """probs: (..., E) -> (mask, weights) keeping top-k entries."""
    top_vals, _ = jax.lax.top_k(probs, k)
    thresh = top_vals[..., -1:]
    mask = probs >= thresh
    w = jnp.where(mask, probs, 0.0)
    return mask, w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)


GROUP_SIZE = 512  # tokens per dispatch group (caps the one-hot tensor size)


def moe_apply(params, cfg: ArchConfig, x, *, capacity_factor=None,
              group_size=GROUP_SIZE):
    """x: (B, S, d) -> (B, S, d), plus aux losses dict.

    Tokens are dispatched in groups of ``group_size`` (sub-sequence chunks);
    per-group capacity C = ceil(group * top_k / E * cf). The (G, T, E, C)
    one-hot dispatch tensor is the GShard formulation — its size per device
    is tokens × E × C × 2B, so C (i.e. group size) bounds the working set.
    Tokens overflowing an expert's capacity within their group are dropped
    (residual connection passes them through) — standard GShard semantics.
    """
    B, S0, d = x.shape
    orig_shape = (B, S0, d)
    E, K = cfg.n_experts, cfg.experts_per_token
    cf = capacity_factor or cfg.moe_capacity_factor
    if S0 == 1:
        # decode: merge tokens into groups of gs and use DROPLESS capacity
        # C = gs. Per-token groups would need C >= K with all E experts
        # materializing C slots => E*K slots/token vs K needed (32x waste
        # for granite). Grouped: E*gs slots per gs tokens = E/K x waste,
        # which is fine because decode is memory-bound and this layout
        # reads each expert's weights exactly once per device. (§Perf H1)
        gs = 1
        for cand in (16, 8, 4, 2):
            if B % cand == 0:
                gs = cand
                break
        x = x.reshape(B // gs, gs, d)
        C = gs
    elif S0 % group_size == 0 and S0 > group_size:
        # train/prefill: sub-sequence groups bound the one-hot tensor size
        x = x.reshape(B * (S0 // group_size), group_size, d)
        C = max(1, int(group_size * K * cf / E + 0.5))
    else:
        C = max(1, int(S0 * K * cf / E + 0.5))
    B_, S = x.shape[0], x.shape[1]
    C = min(C, S * K)

    logits = jnp.einsum("gsd,de->gse", x, params["router"].astype(x.dtype))
    logits = logits.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    mask, weights = _top_k_mask(probs, K)  # (G, S, E)

    # position of each token in its expert's buffer (per group)
    pos_in_expert = jnp.cumsum(mask.astype(jnp.int32), axis=1) - 1  # (G,S,E)
    keep = mask & (pos_in_expert < C)
    # one-hot over capacity slots: (G, S, E, C)
    slot = jax.nn.one_hot(jnp.where(keep, pos_in_expert, -1), C, dtype=x.dtype)
    dispatch = slot * keep[..., None].astype(x.dtype)
    combine = dispatch * weights[..., None].astype(x.dtype)

    # dispatch: (G, S, E, C) x (G, S, d) -> (E, G, C, d)
    # Pinning E over 'model' (expert-parallel; dropped if E doesn't divide)
    # and G over data makes this einsum lower to the canonical all-to-all.
    expert_in = jnp.einsum("gsec,gsd->egcd", dispatch, x)
    expert_in = constrain(expert_in, "model", batch_axes(), None, None)
    h_g = jnp.einsum("egcd,edf->egcf", expert_in, params["w_gate"].astype(x.dtype))
    h_u = jnp.einsum("egcd,edf->egcf", expert_in, params["w_up"].astype(x.dtype))
    h = jax.nn.silu(h_g.astype(jnp.float32)).astype(x.dtype) * h_u
    expert_out = jnp.einsum("egcf,efd->egcd", h, params["w_down"].astype(x.dtype))
    y = jnp.einsum("gsec,egcd->gsd", combine, expert_out)

    # Switch-style load-balance aux loss
    frac_tokens = jnp.mean(mask.astype(jnp.float32), axis=(0, 1))     # (E,)
    frac_probs = jnp.mean(probs, axis=(0, 1))                          # (E,)
    aux = E * jnp.sum(frac_tokens * frac_probs)
    y = y.reshape(orig_shape)
    return y, {"moe_aux": aux, "moe_dropped": 1.0 - jnp.mean(keep.sum(-1) / K)}
