"""Model assembly: init / forward / prefill / decode for every family.

Families
--------
dense, vlm   : embed -> scan(attn + swiglu blocks) -> norm -> unembed
moe          : embed -> scan(attn + MoE blocks)    -> norm -> unembed
ssm (rwkv6)  : embed -> scan(timemix + channelmix) -> norm -> unembed
hybrid       : embed -> [6×mamba2 scan + shared attn block] × groups -> ...
audio        : stub-frontend encoder stack + autoregressive decoder stack

Layer stacks are stored with a leading (L, ...) axis and applied with
``jax.lax.scan`` so compile time is depth-independent. Activation
checkpointing (``remat=True``) wraps the per-layer body with
``jax.checkpoint`` — the standard memory/recompute trade for the train
shapes.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import mamba2 as M2
from repro.models import moe as MOE
from repro.models import rwkv6 as R6
from repro.models.kvcache import attn_cache_update
from repro.sharding.constraints import batch_axes, constrain

# Sliding-window used when a *full-attention* dense arch runs long_500k
# (the documented SWA variant, DESIGN.md §4).
LONG_CONTEXT_WINDOW = 8192

# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _stacked(init_fn, key, n):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def _cast_tree(tree, dtype):
    """Cast large float matmul weights to the compute dtype ONCE, before the
    layer scan — FSDP-sharded weights then all-gather in bf16 (half the ICI
    bytes and half the transient footprint vs gathering f32 and casting
    after). Small/1-D params (norm scales, decays, biases) stay f32."""
    def cast(a):
        if (hasattr(a, "dtype") and a.dtype == jnp.float32
                and a.ndim >= 2 and a.size > 16384):
            return a.astype(dtype)
        return a
    return jax.tree.map(cast, tree)


def _dense_block_init(key, cfg: ArchConfig, moe: bool):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    norm_init, _ = L.make_norm(cfg)
    return {
        "ln1": norm_init(cfg.d_model),
        "attn": L.attention_init(k1, cfg),
        "ln2": norm_init(cfg.d_model),
        "mlp": MOE.moe_init(k2, cfg) if moe else L.swiglu_init(k3, cfg.d_model, cfg.d_ff),
    }


def _rwkv_block_init(key, cfg: ArchConfig):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model),
        "att": R6.timemix_init(k1, cfg),
        "ln2": L.rmsnorm_init(cfg.d_model),
        "ffn": R6.channelmix_init(k2, cfg),
    }


def _mamba_block_init(key, cfg: ArchConfig):
    return {
        "ln": L.rmsnorm_init(cfg.d_model),
        "mixer": M2.mamba2_init(key, cfg),
    }


def _encoder_block_init(key, cfg: ArchConfig):
    k1, k2 = jax.random.split(key)
    norm_init, _ = L.make_norm(cfg)
    return {
        "ln1": norm_init(cfg.d_model),
        "attn": L.attention_init(k1, cfg),
        "ln2": norm_init(cfg.d_model),
        "mlp": L.gelu_mlp_init(k2, cfg.d_model, cfg.d_ff),
    }


def _decoder_block_init(key, cfg: ArchConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    norm_init, _ = L.make_norm(cfg)
    return {
        "ln1": norm_init(cfg.d_model),
        "self_attn": L.attention_init(k1, cfg),
        "ln_x": norm_init(cfg.d_model),
        "cross_attn": L.attention_init(k2, cfg),
        "ln2": norm_init(cfg.d_model),
        "mlp": L.gelu_mlp_init(k3, cfg.d_model, cfg.d_ff),
    }


def init_params(key, cfg: ArchConfig) -> Dict[str, Any]:
    ke, kb, ks, kf = jax.random.split(key, 4)
    norm_init, _ = L.make_norm(cfg)
    params: Dict[str, Any] = {
        "embed": L.embedding_init(ke, cfg),
        "final_norm": norm_init(cfg.d_model),
    }
    if cfg.family in ("dense", "vlm"):
        params["blocks"] = _stacked(
            lambda k: _dense_block_init(k, cfg, moe=False), kb, cfg.n_layers)
    elif cfg.family == "moe":
        params["blocks"] = _stacked(
            lambda k: _dense_block_init(k, cfg, moe=True), kb, cfg.n_layers)
    elif cfg.family == "ssm":
        params["blocks"] = _stacked(
            lambda k: _rwkv_block_init(k, cfg), kb, cfg.n_layers)
    elif cfg.family == "hybrid":
        params["blocks"] = _stacked(
            lambda k: _mamba_block_init(k, cfg), kb, cfg.n_layers)
        params["shared_attn"] = _dense_block_init(ks, cfg, moe=False)
    elif cfg.family == "audio":
        params["enc_blocks"] = _stacked(
            lambda k: _encoder_block_init(k, cfg), kb, cfg.n_encoder_layers)
        params["blocks"] = _stacked(
            lambda k: _decoder_block_init(k, cfg), ks, cfg.n_layers)
        params["enc_final_norm"] = norm_init(cfg.d_model)
    else:
        raise ValueError(cfg.family)
    return params


# ---------------------------------------------------------------------------
# Forward (train / full-sequence) paths
# ---------------------------------------------------------------------------


def _dense_block_apply(block, cfg: ArchConfig, x, positions, *, window, moe,
                       cross=None, causal=True):
    _, norm = L.make_norm(cfg)
    h = norm(block["ln1"], x, cfg.norm_eps)
    a, kv = L.attention_apply(
        block["attn"] if "attn" in block else block["self_attn"],
        cfg, h, positions=positions, causal=causal, window=window)
    x = x + a
    aux = {}
    if cross is not None:
        h = norm(block["ln_x"], x, cfg.norm_eps)
        c, _ = L.attention_apply(block["cross_attn"], cfg, h,
                                 positions=positions, cross_kv=cross)
        x = x + c
    h = norm(block["ln2"], x, cfg.norm_eps)
    if moe:
        m, aux = MOE.moe_apply(block["mlp"], cfg, h)
    elif cfg.family == "audio":
        m = L.gelu_mlp_apply(block["mlp"], h)
    else:
        m = L.swiglu_apply(block["mlp"], h)
    return x + m, aux, kv


def _remat_policy(name):
    if name in (None, "full"):
        return None
    if name == "dots":
        # save MXU (dot) outputs; recompute only cheap elementwise chains —
        # trades ~HBM for the remat third of the compute term (§Perf H5)
        return jax.checkpoint_policies.dots_saveable
    raise ValueError(name)


@jax.custom_vjp
def _carry_barrier(x):
    return jax.lax.optimization_barrier(x)


def _carry_barrier_fwd(x):
    return jax.lax.optimization_barrier(x), None


def _carry_barrier_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


# optimization_barrier has no differentiation rule (jax<=0.4.x); wrap it so
# the primal/cotangent each get a barrier and autodiff passes straight through
_carry_barrier.defvjp(_carry_barrier_fwd, _carry_barrier_bwd)


def _stack_scan(blocks, body, x, remat: bool, policy: str = "full"):
    """Scan ``body(x, block_params) -> (x, aux)`` over stacked blocks.

    The carry is re-constrained to batch-sharded at every block boundary:
    without this GSPMD can flip the activations to d_model-sharded /
    batch-replicated (propagated from the tensor-parallel weights), which
    replicates the remat-saved (L, B, S, d) stack on every device.
    """
    fn = jax.checkpoint(body, policy=_remat_policy(policy)) if remat else body

    def step(carry, block):
        carry = constrain(carry, batch_axes(), None, None)
        # barrier: stops XLA hoisting the body's first f32 upcast (rmsnorm)
        # out of the while loop — the LICM otherwise converts the whole
        # remat-saved bf16 (L,B,S,d) stack to f32, doubling its footprint
        carry = _carry_barrier(carry)
        y, aux = fn(carry, block)
        return y, aux

    x, auxs = jax.lax.scan(step, x, blocks)
    return constrain(x, batch_axes(), None, None), auxs


def forward(params, cfg: ArchConfig, batch: Dict[str, jnp.ndarray], *,
            remat: bool = True, remat_policy: str = "full",
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Full-sequence forward returning (logits_f32, aux).

    batch: {"tokens": (B, S)} plus family extras:
      vlm   -> {"image_embeds": (B, n_img, d)}
      audio -> {"audio_embeds": (B, n_frames, d)}
    """
    dtype = jnp.dtype(cfg.dtype)
    params = _cast_tree(params, dtype)
    tokens = batch["tokens"]
    B, S_text = tokens.shape
    x = L.embed(params["embed"], tokens, dtype)

    if cfg.family == "vlm":
        img = batch["image_embeds"].astype(dtype)
        x = jnp.concatenate([img, x], axis=1)
    S = x.shape[1]
    positions = jnp.arange(S)

    aux: Dict[str, jnp.ndarray] = {}

    if cfg.family in ("dense", "vlm", "moe"):
        moe = cfg.is_moe

        def body(h, block):
            h, a, _ = _dense_block_apply(block, cfg, h, positions,
                                         window=cfg.sliding_window, moe=moe)
            return h, a

        x, auxs = _stack_scan(params["blocks"], body, x, remat, remat_policy)
        if moe:
            aux["moe_aux"] = jnp.mean(auxs["moe_aux"])
            aux["moe_dropped"] = jnp.mean(auxs["moe_dropped"])

    elif cfg.family == "ssm":
        zero_prev = jnp.zeros((B, cfg.d_model), dtype)
        H = cfg.d_model // cfg.wkv_head_dim
        state0 = jnp.zeros((B, H, cfg.wkv_head_dim, cfg.wkv_head_dim), jnp.float32)

        def body(h, block):
            a, _, _ = R6.timemix_apply(block["att"],
                                       cfg,
                                       L.rmsnorm(block["ln1"], h, cfg.norm_eps),
                                       zero_prev, state0)
            h = h + a
            f, _ = R6.channelmix_apply(block["ffn"], cfg,
                                       L.rmsnorm(block["ln2"], h, cfg.norm_eps),
                                       zero_prev)
            return h + f, 0.0

        x, _ = _stack_scan(params["blocks"], body, x, remat, remat_policy)

    elif cfg.family == "hybrid":
        x = _hybrid_forward(params, cfg, x, positions, remat, remat_policy)

    elif cfg.family == "audio":
        enc = _encode_audio(params, cfg, batch["audio_embeds"].astype(dtype), remat)

        def body(h, block):
            cr = _cross_kv(block, cfg, enc)
            h, a, _ = _dense_block_apply(block, cfg, h, positions, window=0,
                                         moe=False, cross=cr)
            return h, a

        x = x + L.sinusoidal_positions(S, cfg.d_model)[None].astype(dtype)
        x, _ = _stack_scan(params["blocks"], body, x, remat, remat_policy)
    else:
        raise ValueError(cfg.family)

    _, norm = L.make_norm(cfg)
    x = norm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg)
    return logits, aux


def _encode_audio(params, cfg: ArchConfig, audio_embeds, remat):
    """Stub-frontend encoder: frame embeddings -> bidirectional stack.
    Returns (k_cross, v_cross) producer input = encoded states."""
    B, F, d = audio_embeds.shape
    x = audio_embeds + L.sinusoidal_positions(F, d)[None].astype(audio_embeds.dtype)
    positions = jnp.arange(F)

    def body(h, block):
        h, _, _ = _dense_block_apply(block, cfg, h, positions, window=0,
                                     moe=False, causal=False)
        return h, 0.0

    x, _ = _stack_scan(params["enc_blocks"], body, x, remat)
    _, norm = L.make_norm(cfg)
    x = norm(params["enc_final_norm"], x, cfg.norm_eps)
    return x


def _cross_kv(block, cfg: ArchConfig, enc_out):
    """Project encoder output to this decoder layer's cross K/V."""
    B, F, d = enc_out.shape
    hd = cfg.resolved_head_dim
    p = block["cross_attn"]
    k = jnp.einsum("bfd,dh->bfh", enc_out, p["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bfd,dh->bfh", enc_out, p["wv"].astype(enc_out.dtype))
    return (k.reshape(B, F, cfg.n_kv_heads, hd), v.reshape(B, F, cfg.n_kv_heads, hd))


def _hybrid_forward(params, cfg: ArchConfig, x, positions, remat, remat_policy="full"):
    """Zamba2: groups of ``shared_attn_period`` mamba2 layers, a weight-tied
    shared attention block after each full group."""
    B = x.shape[0]
    period = cfg.shared_attn_period
    n_layers = cfg.n_layers
    state = M2.mamba2_state_init(cfg, B, x.dtype)

    def mamba_body(h, block):
        a, _ = M2.mamba2_apply(block["mixer"], cfg,
                               L.rmsnorm(block["ln"], h, cfg.norm_eps), state)
        return h + a, 0.0

    def run_group(h, blocks_slice):
        return _stack_scan(blocks_slice, mamba_body, h, remat, remat_policy)[0]

    n_full = n_layers // period
    rem = n_layers - n_full * period
    blocks = params["blocks"]
    for g in range(n_full):
        sl = jax.tree.map(lambda a: a[g * period:(g + 1) * period], blocks)
        x = run_group(x, sl)
        x, _, _ = _dense_block_apply(params["shared_attn"], cfg, x, positions,
                                     window=cfg.sliding_window, moe=False)
    if rem:
        sl = jax.tree.map(lambda a: a[n_full * period:], blocks)
        x = run_group(x, sl)
    return x


# NOTE on the ssm/hybrid *training* paths: states start at zero and the
# full sequence is processed by the chunked scans inside the mixers, so the
# per-layer "state" passed above is only the zero initial state.

# ---------------------------------------------------------------------------
# Decode (single-token serve) paths
# ---------------------------------------------------------------------------


def decode_step(params, cfg: ArchConfig, cache, tokens,
                window_override: Optional[int] = None):
    """One autoregressive step.

    tokens: (B, 1) int32. cache: pytree from kvcache.serve_cache_init
    (pos already = number of consumed tokens). Returns (logits (B, 1, V) f32,
    new cache).
    """
    dtype = jnp.dtype(cfg.dtype)
    params = _cast_tree(params, dtype)
    pos = cache["pos"]
    x = L.embed(params["embed"], tokens, dtype)
    positions = pos + jnp.arange(1)
    _, norm = L.make_norm(cfg)
    window = window_override if window_override is not None else cfg.sliding_window

    new_cache = dict(cache)

    if cfg.family in ("dense", "vlm", "moe", "audio"):
        attn = cache["attn"]
        ring = window > 0 and attn["k"].shape[2] <= window
        is_encdec = cfg.is_encdec
        quant = attn["k"].dtype == jnp.int8

        def body(h, xs):
            if is_encdec:
                block, ck, cv, kv_pos, cross_k, cross_v = xs
            elif quant:
                block, ck, cv, kv_pos, ksc, vsc = xs
            else:
                block, ck, cv, kv_pos = xs
            hn = norm(block["ln1"], h, cfg.norm_eps)
            attn_p = block["self_attn"] if is_encdec else block["attn"]
            # project + rotate this token's k/v
            _, kv_new = L.attention_apply(
                attn_p, cfg, hn, positions=positions, causal=True,
                window=window, kv=None)
            k1, v1 = kv_new
            if quant:
                ck2, cv2, kvp2, ks2, vs2 = attn_cache_update(
                    ck, cv, kv_pos, k1, v1, pos, ring, ksc, vsc)
                kv_in = (ck2, cv2, kvp2, kvp2 >= 0, ks2, vs2)
            else:
                ck2, cv2, kvp2 = attn_cache_update(
                    ck, cv, kv_pos, k1.astype(ck.dtype),
                    v1.astype(cv.dtype), pos, ring)
                kv_in = (ck2, cv2, kvp2, kvp2 >= 0)
            a, _ = L.attention_apply(
                attn_p, cfg, hn, positions=positions, causal=True, window=window,
                kv=kv_in)
            h = h + a
            if is_encdec:
                hx = norm(block["ln_x"], h, cfg.norm_eps)
                c, _ = L.attention_apply(block["cross_attn"], cfg, hx,
                                         positions=positions,
                                         cross_kv=(cross_k, cross_v))
                h = h + c
            hn = norm(block["ln2"], h, cfg.norm_eps)
            if cfg.is_moe:
                m, _ = MOE.moe_apply(block["mlp"], cfg, hn)
            elif cfg.family == "audio":
                m = L.gelu_mlp_apply(block["mlp"], hn)
            else:
                m = L.swiglu_apply(block["mlp"], hn)
            if quant:
                return h + m, (ck2, cv2, kvp2, ks2, vs2)
            return h + m, (ck2, cv2, kvp2)

        xs = (params["blocks"], attn["k"], attn["v"], attn["kv_pos"])
        if is_encdec:
            xs = xs + (cache["cross_k"], cache["cross_v"])
        elif quant:
            xs = xs + (attn["k_scale"], attn["v_scale"])
        if quant:
            x, (k_new, v_new, kvp_new, ks_new, vs_new) = jax.lax.scan(body, x, xs)
            new_cache["attn"] = {"k": k_new, "v": v_new, "kv_pos": kvp_new,
                                 "k_scale": ks_new, "v_scale": vs_new}
        else:
            x, (k_new, v_new, kvp_new) = jax.lax.scan(body, x, xs)
            new_cache["attn"] = {"k": k_new, "v": v_new, "kv_pos": kvp_new}

    elif cfg.family == "ssm":
        def body(h, xs):
            block, wkv, sh_a, sh_f = xs
            a, sh_a2, wkv2 = R6.timemix_apply(
                block["att"], cfg, L.rmsnorm(block["ln1"], h, cfg.norm_eps),
                sh_a, wkv)
            h = h + a
            f, sh_f2 = R6.channelmix_apply(
                block["ffn"], cfg, L.rmsnorm(block["ln2"], h, cfg.norm_eps), sh_f)
            return h + f, (wkv2, sh_a2.astype(sh_a.dtype), sh_f2.astype(sh_f.dtype))

        xs = (params["blocks"], cache["wkv"], cache["shift_att"], cache["shift_ffn"])
        x, (wkv2, sa2, sf2) = jax.lax.scan(body, x, xs)
        new_cache.update(wkv=wkv2, shift_att=sa2, shift_ffn=sf2)

    elif cfg.family == "hybrid":
        x, new_cache = _hybrid_decode(params, cfg, cache, x, positions, pos)

    else:
        raise ValueError(cfg.family)

    x = norm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg)
    new_cache["pos"] = pos + 1
    return logits, new_cache


def _hybrid_decode(params, cfg: ArchConfig, cache, x, positions, pos):
    period = cfg.shared_attn_period
    n_layers = cfg.n_layers
    n_full = n_layers // period
    rem = n_layers - n_full * period
    # ring-buffer size == window (static shape, not a traced cache leaf)
    window = cache["attn"]["k"].shape[2]
    _, norm = L.make_norm(cfg)
    new_cache = dict(cache)

    def mamba_body(h, xs):
        block, st = xs
        a, st2 = M2.mamba2_apply(block["mixer"], cfg,
                                 L.rmsnorm(block["ln"], h, cfg.norm_eps), st)
        return h + a, st2

    mamba_states = cache["mamba"]
    attn = cache["attn"]
    new_states = []
    attn_k, attn_v, attn_pos = attn["k"], attn["v"], attn["kv_pos"]
    ks, vs, ps = [], [], []
    for g in range(n_full + (1 if rem else 0)):
        lo = g * period
        hi = min(lo + period, n_layers)
        blocks_sl = jax.tree.map(lambda a: a[lo:hi], params["blocks"])
        states_sl = jax.tree.map(lambda a: a[lo:hi], mamba_states)
        x, st2 = jax.lax.scan(mamba_body, x, (blocks_sl, states_sl))
        new_states.append(st2)
        if hi - lo == period:  # full group -> shared attention block
            hn = norm(params["shared_attn"]["ln1"], x, cfg.norm_eps)
            _, kv_new = L.attention_apply(params["shared_attn"]["attn"], cfg, hn,
                                          positions=positions, causal=True,
                                          window=window)
            k1, v1 = kv_new
            ck, cv, kvp = attn_k[g], attn_v[g], attn_pos[g]
            ck2, cv2, kvp2 = attn_cache_update(
                ck, cv, kvp, k1.astype(ck.dtype), v1.astype(cv.dtype), pos, True)
            a, _ = L.attention_apply(params["shared_attn"]["attn"], cfg, hn,
                                     positions=positions, causal=True,
                                     window=window,
                                     kv=(ck2, cv2, kvp2, kvp2 >= 0))
            x = x + a
            hn = norm(params["shared_attn"]["ln2"], x, cfg.norm_eps)
            x = x + L.swiglu_apply(params["shared_attn"]["mlp"], hn)
            ks.append(ck2); vs.append(cv2); ps.append(kvp2)

    new_cache["mamba"] = jax.tree.map(
        lambda *xs: jnp.concatenate(xs, axis=0), *new_states)
    new_cache["attn"] = {"k": jnp.stack(ks), "v": jnp.stack(vs),
                         "kv_pos": jnp.stack(ps)}
    return x, new_cache


def _hybrid_prefill(params, cfg: ArchConfig, cache, x, positions):
    """Full-prompt pass for zamba2: fills mamba states + shared-attn ring
    cache (last ``window`` positions)."""
    B, S, _ = x.shape
    period = cfg.shared_attn_period
    n_layers = cfg.n_layers
    n_full = n_layers // period
    rem = n_layers - n_full * period
    window = cache["attn"]["k"].shape[2]
    _, norm = L.make_norm(cfg)
    new_cache = dict(cache)

    def mamba_body(h, xs):
        block, st = xs
        a, st2 = M2.mamba2_apply(block["mixer"], cfg,
                                 L.rmsnorm(block["ln"], h, cfg.norm_eps), st)
        return h + a, st2

    mamba_states = cache["mamba"]
    new_states, ks, vs, ps = [], [], [], []
    for g in range(n_full + (1 if rem else 0)):
        lo, hi = g * period, min((g + 1) * period, n_layers)
        blocks_sl = jax.tree.map(lambda a: a[lo:hi], params["blocks"])
        states_sl = jax.tree.map(lambda a: a[lo:hi], mamba_states)
        x, st2 = jax.lax.scan(mamba_body, x, (blocks_sl, states_sl))
        new_states.append(st2)
        if hi - lo == period:
            sa = params["shared_attn"]
            hn = norm(sa["ln1"], x, cfg.norm_eps)
            a, kv = L.attention_apply(sa["attn"], cfg, hn, positions=positions,
                                      causal=True, window=window)
            x = x + a
            hn = norm(sa["ln2"], x, cfg.norm_eps)
            x = x + L.swiglu_apply(sa["mlp"], hn)
            k1, v1 = kv
            keep = min(S, window)
            ck = cache["attn"]["k"][g]
            # ring-aligned slots: slot = position % window, so decode-time
            # writes (pos % window) evict exactly the oldest entry
            pos_kept = jnp.arange(S - keep, S, dtype=jnp.int32)
            slots = pos_kept % window
            kk = jnp.zeros_like(ck).at[:, slots].set(
                k1[:, S - keep:S].astype(ck.dtype))
            vv = jnp.zeros_like(ck).at[:, slots].set(
                v1[:, S - keep:S].astype(ck.dtype))
            pp = jnp.full((ck.shape[1],), -1, jnp.int32).at[slots].set(pos_kept)
            ks.append(kk); vs.append(vv); ps.append(pp)
    new_cache["mamba"] = jax.tree.map(
        lambda *xs: jnp.concatenate(xs, axis=0), *new_states)
    new_cache["attn"] = {"k": jnp.stack(ks), "v": jnp.stack(vs),
                         "kv_pos": jnp.stack(ps)}
    return x, new_cache


# ---------------------------------------------------------------------------
# Prefill (build cache from a full prompt) — used by serve.py and tests
# ---------------------------------------------------------------------------


def prefill(params, cfg: ArchConfig, batch, cache, *, remat: bool = False):
    """Consume the full prompt, fill the cache, return last-token logits.

    For attention families this recomputes k/v per layer and writes them into
    the cache; for recurrent families it runs the chunked scans and stores
    final states. ``batch["tokens"]: (B, S)``.
    """
    dtype = jnp.dtype(cfg.dtype)
    params = _cast_tree(params, dtype)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = L.embed(params["embed"], tokens, dtype)
    if cfg.family == "vlm" and "image_embeds" in batch:
        x = jnp.concatenate([batch["image_embeds"].astype(dtype), x], axis=1)
        S = x.shape[1]
    positions = jnp.arange(S)
    _, norm = L.make_norm(cfg)
    new_cache = dict(cache)
    window = cfg.sliding_window

    if cfg.family in ("dense", "vlm", "moe", "audio"):
        cross = None
        if cfg.is_encdec:
            enc = _encode_audio(params, cfg, batch["audio_embeds"].astype(dtype),
                                remat)
            x = x + L.sinusoidal_positions(S, cfg.d_model)[None].astype(dtype)

        max_len = cache["attn"]["k"].shape[2]

        def body(h, xs):
            block = xs
            cr = _cross_kv(block, cfg, enc) if cfg.is_encdec else None
            h, _, kv = _dense_block_apply(block, cfg, h, positions,
                                          window=window, moe=cfg.is_moe, cross=cr)
            k1, v1 = kv
            if cfg.is_encdec:
                return h, (k1, v1, cr[0], cr[1])
            return h, (k1, v1)

        body_fn = jax.checkpoint(body) if remat else body
        x, outs = jax.lax.scan(body_fn, x, params["blocks"])
        k_all, v_all = outs[0], outs[1]          # (L, B, S, Hkv, hd)
        # keep the last max_len positions, at ring-aligned slots
        # (slot = position % max_len) so decode-time ring writes evict
        # exactly the oldest entry
        keep = min(S, max_len)
        pos_kept = jnp.arange(S - keep, S, dtype=jnp.int32)
        slots = pos_kept % max_len
        k_keep = k_all[:, :, S - keep:S]
        v_keep = v_all[:, :, S - keep:S]
        ck = cache["attn"]["k"]
        new_k = jnp.zeros_like(ck).at[:, :, slots].set(k_keep.astype(ck.dtype))
        new_v = jnp.zeros_like(ck).at[:, :, slots].set(v_keep.astype(ck.dtype))
        new_pos = jnp.full_like(cache["attn"]["kv_pos"], -1)
        new_pos = new_pos.at[:, slots].set(pos_kept[None])
        new_cache["attn"] = {"k": new_k, "v": new_v, "kv_pos": new_pos}
        if cfg.is_encdec:
            new_cache["cross_k"] = outs[2].astype(ck.dtype)
            new_cache["cross_v"] = outs[3].astype(ck.dtype)
    elif cfg.family == "ssm":
        H = cfg.d_model // cfg.wkv_head_dim
        zero_prev = jnp.zeros((B, cfg.d_model), dtype)

        def body(h, xs):
            block, wkv = xs
            a, sh_a, wkv2 = R6.timemix_apply(
                block["att"], cfg, L.rmsnorm(block["ln1"], h, cfg.norm_eps),
                zero_prev, wkv)
            h = h + a
            f, sh_f = R6.channelmix_apply(
                block["ffn"], cfg, L.rmsnorm(block["ln2"], h, cfg.norm_eps),
                zero_prev)
            return h + f, (wkv2, sh_a, sh_f)

        x, (wkv2, sa, sf) = jax.lax.scan(body, x, (params["blocks"], cache["wkv"]))
        new_cache.update(wkv=wkv2,
                         shift_att=sa.astype(cache["shift_att"].dtype),
                         shift_ffn=sf.astype(cache["shift_ffn"].dtype))
    elif cfg.family == "hybrid":
        x, new_cache = _hybrid_prefill(params, cfg, cache, x, positions)
    else:
        raise ValueError(cfg.family)

    x = norm(params["final_norm"], x, cfg.norm_eps)
    last = x[:, -1:, :]
    logits = L.unembed(params["embed"], last, cfg)
    new_cache["pos"] = jnp.asarray(S, jnp.int32)
    return logits, new_cache
