"""Mamba2 (SSD) block — selective state-space with scalar per-head decay.

Per head h (P = ssm_head_dim channels, N = ssm_state):

    h_t = exp(a_t) · h_{t-1} + dt_t · x_t ⊗ B_t        h ∈ R^{P×N}
    y_t = h_t C_t + D ⊙ x_t                            a_t = -exp(A_log)·dt_t

Same chunked-scan structure as rwkv6.wkv_chunked but with a *scalar* decay
per head per step (the SSD simplification), which is what makes Mamba2
matmul-friendly on MXU hardware.

TPU-sharding note: the reference implementation fuses [z|x|B|C|dt] into one
``in_proj``; we keep them as separate matrices so the d_inner/head dims can
be cleanly sharded over the 'model' mesh axis (the fused concat dim is not
divisible by 16 for the zamba2-7b config). Mathematically identical.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

import os

from repro.configs.base import ArchConfig
from repro.models.layers import _dense_init, rmsnorm, rmsnorm_init

CHUNK = 128


def _use_pallas_ssd() -> bool:
    """Route the chunked scan through the Pallas SSD kernel (fwd-only paths:
    prefill/serve — the kernel has no custom VJP yet). §Perf H3."""
    return os.environ.get("REPRO_PALLAS_SSD", "0") == "1"


def mamba2_dims(cfg: ArchConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads, cfg.ssm_state, cfg.ssm_head_dim


def mamba2_init(key, cfg: ArchConfig):
    d = cfg.d_model
    d_in, H, N, P = mamba2_dims(cfg)
    ks = jax.random.split(key, 9)
    W = cfg.ssm_conv_width
    return {
        "w_z": _dense_init(ks[0], (d, d_in)),
        "w_x": _dense_init(ks[1], (d, d_in)),
        "w_B": _dense_init(ks[2], (d, N)),
        "w_C": _dense_init(ks[3], (d, N)),
        "w_dt": _dense_init(ks[4], (d, H)),
        "conv_x": jax.random.normal(ks[5], (W, d_in), dtype=jnp.float32) * 0.2,
        "conv_B": jax.random.normal(ks[6], (W, N), dtype=jnp.float32) * 0.2,
        "conv_C": jax.random.normal(ks[7], (W, N), dtype=jnp.float32) * 0.2,
        "conv_bias_x": jnp.zeros((d_in,), jnp.float32),
        "conv_bias_B": jnp.zeros((N,), jnp.float32),
        "conv_bias_C": jnp.zeros((N,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),
        "norm": rmsnorm_init(d_in),
        "out_proj": _dense_init(ks[8], (d_in, d)),
    }


def _causal_conv(x, w, b, conv_state=None):
    """Depthwise causal conv + SiLU. x: (B,S,C); w: (W,C); conv_state:
    (B,W-1,C) history from the previous segment (decode) or None (zeros).
    Returns (y, new_conv_state)."""
    B, S, C = x.shape
    W = w.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((B, W - 1, C), x.dtype)
    xp = jnp.concatenate([conv_state, x], axis=1)           # (B, S+W-1, C)
    y = sum(xp[:, i:i + S, :] * w[i].astype(x.dtype) for i in range(W))
    y = y + b.astype(x.dtype)
    return jax.nn.silu(y.astype(jnp.float32)).astype(x.dtype), xp[:, -(W - 1):, :]


def ssd_chunked(x, dt, A_log, B_, C_, state0):
    """Chunked SSD scan.

    x: (B,S,H,P); dt: (B,S,H); B_,C_: (B,S,N); state0: (B,H,P,N).
    Returns y (B,S,H,P), state (B,H,P,N). S multiple of CHUNK.
    """
    Bb, S, H, P = x.shape
    N = B_.shape[-1]
    nc = S // CHUNK
    a = -jnp.exp(A_log)[None, None, :] * dt                 # (B,S,H) log-decay < 0
    xdt = x * dt[..., None]

    xc = xdt.reshape(Bb, nc, CHUNK, H, P)
    ac = a.reshape(Bb, nc, CHUNK, H)
    bc = B_.reshape(Bb, nc, CHUNK, N)
    cc = C_.reshape(Bb, nc, CHUNK, N)

    def chunk_step(state, inp):
        xb, ab, bb, cb = inp
        L = jnp.cumsum(ab, axis=1)                          # (B,C,H)
        # inter-chunk: y_t reads h_t (post-update) => carried state decayed
        # by exp(L_t) (decay steps 1..t applied).
        y_inter = jnp.exp(L)[..., None] * jnp.einsum(
            "bhpn,bcn->bchp", state, cb)
        # intra-chunk: h contribution of step j at step t (j<=t):
        # exp(L_t - L_j) dt_j x_j ⊗ B_j  (diagonal j=t enters undecayed)
        G = jnp.einsum("bcn,bjn->bcj", cb, bb)              # C_t · B_j
        D = L[:, :, None, :] - L[:, None, :, :]             # (B,C,J,H) = L_t - L_j
        mask = jnp.tril(jnp.ones((CHUNK, CHUNK), bool))[None, :, :, None]
        # mask the *exponent* (not the exponential): exp overflows at the
        # masked j>t positions and 0*inf => NaN in the VJP otherwise.
        Dexp = jnp.exp(jnp.where(mask, D, 0.0)) * mask
        y_intra = jnp.einsum("bcj,bcjh,bjhp->bchp", G, Dexp, xb)
        y = y_inter + y_intra
        # state update: state' = exp(L_C) state + sum_j exp(L_C - L_j) x_j ⊗ B_j
        LC = L[:, -1]                                       # (B,H)
        w_tail = jnp.exp(LC[:, None, :] - L)                # (B,C,H)
        state_new = jnp.exp(LC)[..., None, None] * state + jnp.einsum(
            "bjh,bjhp,bjn->bhpn", w_tail, xb, bb)
        return state_new, y

    state, ys = jax.lax.scan(
        jax.checkpoint(chunk_step),  # don't save per-chunk intermediates
        state0,
        (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(ac, 1, 0),
         jnp.moveaxis(bc, 1, 0), jnp.moveaxis(cc, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bb, S, H, P)
    return y, state


def ssd_step(x, dt, A_log, B_, C_, state):
    """Single decode step. x: (B,H,P); dt: (B,H); B_,C_: (B,N); state (B,H,P,N)."""
    a = jnp.exp(-jnp.exp(A_log)[None, :] * dt)              # (B,H)
    upd = jnp.einsum("bhp,bn->bhpn", x * dt[..., None], B_)
    state = a[..., None, None] * state + upd
    y = jnp.einsum("bhpn,bn->bhp", state, C_)
    return y, state


def mamba2_apply(params, cfg: ArchConfig, x, state):
    """x: (B,S,d); state: dict(conv_x/conv_B/conv_C histories, ssm=(B,H,P,N)).

    Returns (y, new_state)."""
    B, S, d = x.shape
    d_in, H, N, P = mamba2_dims(cfg)
    z = jnp.einsum("bsd,de->bse", x, params["w_z"].astype(x.dtype))
    xin = jnp.einsum("bsd,de->bse", x, params["w_x"].astype(x.dtype))
    B_ = jnp.einsum("bsd,dn->bsn", x, params["w_B"].astype(x.dtype))
    C_ = jnp.einsum("bsd,dn->bsn", x, params["w_C"].astype(x.dtype))
    dt = jnp.einsum("bsd,dh->bsh", x, params["w_dt"].astype(x.dtype))

    xin, st_x = _causal_conv(xin, params["conv_x"], params["conv_bias_x"],
                             state["conv_x"])
    B_, st_B = _causal_conv(B_, params["conv_B"], params["conv_bias_B"],
                            state["conv_B"])
    C_, st_C = _causal_conv(C_, params["conv_C"], params["conv_bias_C"],
                            state["conv_C"])

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    xh = xin.astype(jnp.float32).reshape(B, S, H, P)
    Bf = B_.astype(jnp.float32)
    Cf = C_.astype(jnp.float32)

    if S == 1:
        y, ssm_state = ssd_step(xh[:, 0], dt[:, 0], params["A_log"],
                                Bf[:, 0], Cf[:, 0], state["ssm"])
        y = y[:, None]
    else:
        pad = (-S) % CHUNK
        if pad:
            xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            Bf = jnp.pad(Bf, ((0, 0), (0, pad), (0, 0)))
            Cf = jnp.pad(Cf, ((0, 0), (0, pad), (0, 0)))
        if _use_pallas_ssd():
            from repro.kernels.ssd_chunk import ops as SSDK
            a = -jnp.exp(params["A_log"])[None, None, :] * dt
            y, ssm_state = SSDK.ssd_scan(xh * dt[..., None], a, Bf, Cf,
                                         state["ssm"])
        else:
            y, ssm_state = ssd_chunked(xh, dt, params["A_log"], Bf, Cf,
                                       state["ssm"])
        y = y[:, :S]

    y = y + params["D"][None, None, :, None] * xh[:, :S]
    y = y.reshape(B, S, d_in).astype(x.dtype)
    y = rmsnorm(params["norm"], y, cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(x.dtype))
    new_state = {"conv_x": st_x, "conv_B": st_B, "conv_C": st_C, "ssm": ssm_state}
    return out, new_state


def mamba2_state_init(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    d_in, H, N, P = mamba2_dims(cfg)
    W = cfg.ssm_conv_width
    return {
        "conv_x": jnp.zeros((batch, W - 1, d_in), dtype),
        "conv_B": jnp.zeros((batch, W - 1, N), dtype),
        "conv_C": jnp.zeros((batch, W - 1, N), dtype),
        "ssm": jnp.zeros((batch, H, P, N), jnp.float32),
    }
