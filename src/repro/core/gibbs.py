"""Gibbs sampler for BMF — single-block (jit, lax.fori_loop) version.

One sweep:
  1. (optional) resample NW hyperparameters for U and V given current factors
  2. sample all rows of U | V  (parallel across rows — batched einsums)
  3. sample all rows of V | U

Running accumulators (post-burn-in): predictive sums on the test entries
(for RMSE of the posterior-mean predictor), factor means and outer-product
sums (for Posterior Propagation summarization).
"""
from __future__ import annotations

import contextlib
import warnings
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import bmf as BMF
from repro.core import posterior as POST
from repro.core.posterior import NormalWishart, RowGaussians
from repro.data.sparse import PaddedCSR


class GibbsAccumulators(NamedTuple):
    pred_sum: jnp.ndarray      # (n_test,) sum over kept samples of u·v
    pred_cnt: jnp.ndarray      # scalar
    U_sum: jnp.ndarray         # (N, K)
    U_outer: jnp.ndarray       # (N, K, K)
    V_sum: jnp.ndarray         # (D, K)
    V_outer: jnp.ndarray       # (D, K, K)


class GibbsResult(NamedTuple):
    U: jnp.ndarray
    V: jnp.ndarray
    acc: GibbsAccumulators
    U_post: RowGaussians       # summarized per-row posteriors
    V_post: RowGaussians
    # chain-health scalar (bool; (B,) under the stacked paths): every
    # finiteness-relevant output — final factors, summarized posterior
    # natural params, and the predictive sums — reduced with jnp.all ∘
    # isfinite. One O(N·K²) reduction per CHAIN (vs n_samples sweeps of
    # O(nnz·K²) work), so the guard is ~free; a NaN'd Cholesky or a
    # diverged sweep anywhere in the chain flips it to False. None only on
    # legacy construction sites that predate the guard.
    health: Optional[jnp.ndarray] = None


def chain_health(*trees) -> jnp.ndarray:
    """All-finite reduction over arbitrary pytrees -> bool scalar (batched
    leaves reduce over their trailing axes only if the caller vmaps)."""
    ok = jnp.ones((), jnp.bool_)
    for leaf in jax.tree_util.tree_leaves(trees):
        ok = ok & jnp.all(jnp.isfinite(leaf))
    return ok


def _summarize(sum_, outer, cnt, ridge=1e-4):
    mean = sum_ / cnt
    cov = outer / cnt - jnp.einsum("nk,nl->nkl", mean, mean)
    K = mean.shape[-1]
    # The ridge keeps the moment estimate PD for the Cholesky below, but an
    # ABSOLUTE 1e-4 is meaningless against the row's scale: a near-singular
    # row whose variances sit at 1e4 gets a 1e-8-relative nudge (still
    # numerically indefinite), while a 1e-6-scale row gets drowned.  Scale
    # it by the row's largest diagonal — the same eigenvalue-magnitude
    # rationale as the serving store's PD projection — floored at the old
    # absolute value so O(1)-scale rows (every existing chain) are
    # bit-for-bit unchanged.
    mag = jnp.max(jnp.abs(jnp.diagonal(cov, axis1=-2, axis2=-1)),
                  axis=-1, keepdims=True)
    row_ridge = ridge * jnp.maximum(mag, 1.0)                    # (N, 1)
    cov = cov + row_ridge[..., None] * jnp.eye(K, dtype=cov.dtype)
    # Cholesky factor/solve: O(K³/3) per row + triangular solves, no
    # explicit inverse
    return POST.from_moments_cov(mean, cov, ridge=0.0)


def _run_gibbs_dispatch(key, csr_rows_arrs, csr_cols_arrs, test_rows,
                        test_cols, cfg, n_cols_r, n_cols_c, n_samples, burnin,
                        U_prior, V_prior, U0, V0):
    # n_samples/burnin are traced: one executable serves any chain length
    # (warm-up runs, reduced phase-b/c chains, ...)
    csr_rows = PaddedCSR(*csr_rows_arrs, n_cols=n_cols_r)
    csr_cols = PaddedCSR(*csr_cols_arrs, n_cols=n_cols_c)
    return _run_gibbs_impl(key, csr_rows, csr_cols, test_rows, test_cols,
                           cfg, n_samples, burnin, U_prior, V_prior, U0, V0)


_STATIC = ("cfg", "n_cols_r", "n_cols_c")
# Donated positions: the padded CSR planes, test indices, and the factor
# initializations — all per-call buffers the caller never reuses (U0/V0
# additionally alias the U/V outputs exactly). Priors are deliberately NOT
# donated: PP shares one propagated posterior across every block of a
# row/col group and reads it again at final aggregation, so donating it
# from one block's dispatch would invalidate the others' inputs.
_DONATE_SINGLE = (1, 2, 3, 4, 12, 13)

_run_gibbs_jit = jax.jit(_run_gibbs_dispatch, static_argnames=_STATIC)
_run_gibbs_jit_donated = jax.jit(_run_gibbs_dispatch, static_argnames=_STATIC,
                                 donate_argnums=_DONATE_SINGLE)


@contextlib.contextmanager
def _quiet_donation():
    """The CSR planes/test indices have no same-shape output to alias, so
    XLA notes them as 'not usable' — expected: on TPU/GPU their donation
    still invalidates the caller's handle at dispatch (allocator churn);
    the CPU runtime ignores unusable donations. U0/V0 alias the U/V
    outputs on every backend."""
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        yield


def run_gibbs(key,
              csr_rows: PaddedCSR,      # R rows:    users x items
              csr_cols: PaddedCSR,      # R^T rows:  items x users
              test_rows: jnp.ndarray,   # (n_test,) user ids
              test_cols: jnp.ndarray,   # (n_test,) item ids
              cfg: BMF.BMFConfig,
              U_prior: Optional[RowGaussians] = None,
              V_prior: Optional[RowGaussians] = None,
              U0: Optional[jnp.ndarray] = None,
              V0: Optional[jnp.ndarray] = None,
              donate: bool = False) -> GibbsResult:
    """Run cfg.n_samples sweeps (cfg.burnin of them discarded).

    U_prior / V_prior: propagated per-row priors (PP phases b/c). When None,
    the factor gets the NW hierarchical prior resampled each sweep.

    The whole chain is one cached jitted executable keyed on (shapes, cfg) —
    the PP scheduler buckets all blocks to common shapes precisely so every
    block reuses this compilation.

    donate=True donates the padded CSR planes, test indices, and U0/V0 to
    XLA: U0/V0 are rewritten in place as the U/V outputs (every backend),
    and where the runtime supports it (TPU/GPU) the remaining donated
    buffers are invalidated at dispatch instead of living until the Python
    refs drop — cutting peak HBM and allocator churn on the PP hot path.
    Callers that reuse any of those buffers across calls must keep the
    default. Propagated priors are never donated (shared across a PP
    row/col group and read again at final aggregation).
    """
    N, D, K = csr_rows.n_rows, csr_cols.n_rows, cfg.K
    k0, key = jax.random.split(key)
    if U0 is None or V0 is None:
        U0_, V0_ = BMF.init_factors(k0, N, D, K)
        U0 = U0 if U0 is not None else U0_
        V0 = V0 if V0 is not None else V0_
    cfg_key = cfg._replace(n_samples=0, burnin=0, phase_bc_samples=None)
    fn = _run_gibbs_jit_donated if donate else _run_gibbs_jit
    with (_quiet_donation() if donate else contextlib.nullcontext()):
        return fn(key,
                  (csr_rows.idx, csr_rows.val, csr_rows.mask),
                  (csr_cols.idx, csr_cols.val, csr_cols.mask),
                  test_rows, test_cols, cfg_key,
                  csr_rows.n_cols, csr_cols.n_cols,
                  jnp.asarray(cfg.n_samples, jnp.int32),
                  jnp.asarray(cfg.burnin, jnp.int32),
                  U_prior, V_prior, U0, V0)


def _run_gibbs_stacked_dispatch(key_data, csr_rows_arrs, csr_cols_arrs,
                                test_rows, test_cols, cfg, n_cols_r, n_cols_c,
                                n_samples, burnin, U_prior, V_prior, U0, V0,
                                u_use=None, v_use=None, mesh=None):
    """Batched (leading block axis) chain runner.

    Every array argument carries a leading axis B; ``mesh`` (hashable,
    static) optionally shard_maps that axis over a 1-D 'block' device mesh —
    same-phase PP blocks then run concurrently on separate devices with NO
    collectives inside the phase (communication stays at phase boundaries,
    which live on the host between calls).

    ``u_use`` / ``v_use`` are optional per-block {0,1} flags: when given
    (streaming window chunks), block b uses the fixed prior where its flag
    is 1 and the resampled NW hyperprior where it is 0 — one executable
    then serves blocks of EVERY phase tag (see ``_run_gibbs_impl``).

    Keys travel as raw uint32 key data so the leaves are plain arrays for
    vmap/shard_map; per-block semantics are EXACTLY ``_run_gibbs_impl``'s.
    """
    def batched(kd, rows_arrs, cols_arrs, tr, tc, ns, bi, up, vp, u0, v0,
                uu, vv):
        def one(kd1, ra, ca, tr1, tc1, up1, vp1, u01, v01, uu1, vv1):
            return _run_gibbs_impl(
                jax.random.wrap_key_data(kd1),
                PaddedCSR(*ra, n_cols=n_cols_r),
                PaddedCSR(*ca, n_cols=n_cols_c),
                tr1, tc1, cfg, ns, bi, up1, vp1, u01, v01, uu1, vv1)
        return jax.vmap(one)(kd, rows_arrs, cols_arrs, tr, tc, up, vp,
                             u0, v0, uu, vv)

    if mesh is None:
        return batched(key_data, csr_rows_arrs, csr_cols_arrs, test_rows,
                       test_cols, n_samples, burnin, U_prior, V_prior, U0, V0,
                       u_use, v_use)
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    blk = P("block")
    fsh = shard_map(batched, mesh=mesh,
                    in_specs=(blk, blk, blk, blk, blk, P(), P(),
                              blk, blk, blk, blk, blk, blk),
                    out_specs=blk, check_rep=False)
    return fsh(key_data, csr_rows_arrs, csr_cols_arrs, test_rows, test_cols,
               n_samples, burnin, U_prior, V_prior, U0, V0, u_use, v_use)


_STATIC_STACKED = ("cfg", "n_cols_r", "n_cols_c", "mesh")
# Stacked donation mirrors _DONATE_SINGLE: per-bucket stacked CSR planes,
# test indices, and vmapped U0/V0 (aliasing the stacked U/V outputs).
# Stacked priors are fresh jnp.stack copies at every call site, but stay
# un-donated for symmetry with the single-block contract.
_DONATE_STACKED = (1, 2, 3, 4, 12, 13)

_run_gibbs_stacked_jit = jax.jit(_run_gibbs_stacked_dispatch,
                                 static_argnames=_STATIC_STACKED)
_run_gibbs_stacked_jit_donated = jax.jit(_run_gibbs_stacked_dispatch,
                                         static_argnames=_STATIC_STACKED,
                                         donate_argnums=_DONATE_STACKED)


def run_gibbs_stacked(keys,
                      csr_rows: PaddedCSR,      # (B, N, M) leaves
                      csr_cols: PaddedCSR,      # (B, D, M_c) leaves
                      test_rows: jnp.ndarray,   # (B, n_test)
                      test_cols: jnp.ndarray,   # (B, n_test)
                      cfg: BMF.BMFConfig,
                      U_prior: Optional[RowGaussians] = None,  # (B, N, ...) or None
                      V_prior: Optional[RowGaussians] = None,
                      block_mesh=None, donate: bool = False,
                      prior_use: Optional[Tuple] = None) -> GibbsResult:
    """Batched analogue of ``run_gibbs``: one jitted vmapped executable runs
    B identically-shaped blocks' chains at once (the PP StackedExecutor's
    hot path — ``BlockShapes.per_phase`` guarantees the common shapes).

    ``keys`` is a (B,) typed PRNG key array; per-block key handling (split
    for init, then the chain) mirrors ``run_gibbs`` exactly, so block b of
    the stacked result reproduces ``run_gibbs(keys[b], ...)``.

    ``block_mesh``: optional 1-D Mesh with axis 'block'; B must be a
    multiple of the mesh size (callers pad the batch). The returned
    GibbsResult's leaves all carry the leading B axis.

    ``donate`` mirrors ``run_gibbs``: the stacked CSR planes, test indices,
    and U0/V0 are donated to XLA (same caller-must-not-reuse contract).

    ``prior_use``: optional ``(u_use, v_use)`` per-block {0,1} flag arrays
    (B,). With flags, ``U_prior``/``V_prior`` must be full (B, ...) arrays
    (dummy rows where a block has no propagated prior) and block b follows
    its flags: 1 = the fixed propagated prior, 0 = the hierarchical NW
    prior resampled each sweep — bit-identical per block to the dedicated
    with/without-prior executables, because the hyper-sampling keys are
    split unconditionally either way. This is the streaming executor's
    buffer-shape reuse lever: ONE window executable serves phase a, b and
    c blocks instead of one executable per prior structure.
    """
    N, D, K = csr_rows.idx.shape[1], csr_cols.idx.shape[1], cfg.K
    ks = jax.vmap(jax.random.split)(keys)                     # (B, 2)
    U0, V0 = jax.vmap(lambda k: BMF.init_factors(k, N, D, K))(ks[:, 0])
    cfg_key = cfg._replace(n_samples=0, burnin=0, phase_bc_samples=None)
    u_use, v_use = prior_use if prior_use is not None else (None, None)
    fn = _run_gibbs_stacked_jit_donated if donate else _run_gibbs_stacked_jit
    with (_quiet_donation() if donate else contextlib.nullcontext()):
        return fn(
            jax.random.key_data(ks[:, 1]),
            (csr_rows.idx, csr_rows.val, csr_rows.mask),
            (csr_cols.idx, csr_cols.val, csr_cols.mask),
            test_rows, test_cols, cfg_key, csr_rows.n_cols, csr_cols.n_cols,
            jnp.asarray(cfg.n_samples, jnp.int32),
            jnp.asarray(cfg.burnin, jnp.int32),
            U_prior, V_prior, U0, V0, u_use, v_use, mesh=block_mesh)


def _run_gibbs_impl(key, csr_rows, csr_cols, test_rows, test_cols, cfg,
                    n_samples, burnin, U_prior, V_prior, U0, V0,
                    u_use=None, v_use=None,
                    u_sampler=None, v_sampler=None,
                    n_rows=None, n_cols=None) -> GibbsResult:
    """Chain body shared by every executor path.

    ``u_sampler`` / ``v_sampler`` are the factor-step seams:
    ``sampler(key, csr, other, prior) -> factor``, defaulting to the
    single-device ``BMF.sample_factor``. The intra-block distributed
    sweep (core.distributed) swaps in 'data'-mesh-sharded samplers —
    everything else (key splitting, prior selection, accumulators,
    summaries) is THIS code, so the composed chains share the reference
    semantics by construction. ``n_rows`` / ``n_cols`` override the
    factor sizes when ``csr_rows`` / ``csr_cols`` hold only a device's
    local shard (the carry factors stay full-size and replicated)."""
    N = csr_rows.n_rows if n_rows is None else n_rows
    D = csr_cols.n_rows if n_cols is None else n_cols
    K = cfg.K
    nw = POST.default_nw(K)
    if cfg.sweep_fused:
        # one-kernel sweep: the whole factor step in a single pass (Pallas
        # on TPU, the bitwise-identical striped-XLA fallback elsewhere).
        # The noise stream matches sample_factor's draw exactly, so this is
        # a pure execution-strategy switch for every executor that leaves
        # these seams at their defaults.
        from repro.kernels.bmf_sweep import ops as SWEEP
        default_sampler = lambda k, csr, other, prior: \
            SWEEP.sample_factor_fused(k, csr, other, cfg.tau, prior,
                                      dtype=cfg.sweep_dtype)
    else:
        default_sampler = lambda k, csr, other, prior: BMF.sample_factor(
            k, csr, other, cfg.tau, prior, cfg.use_kernel)
    if u_sampler is None:
        u_sampler = default_sampler
    if v_sampler is None:
        v_sampler = default_sampler

    acc0 = GibbsAccumulators(
        pred_sum=jnp.zeros_like(test_rows, dtype=jnp.float32),
        pred_cnt=jnp.zeros((), jnp.float32),
        U_sum=jnp.zeros((N, K)), U_outer=jnp.zeros((N, K, K)),
        V_sum=jnp.zeros((D, K)), V_outer=jnp.zeros((D, K, K)))

    def pick_prior(fixed, use, kh, X, n):
        """Prior for one factor this sweep. ``use=None`` keeps the two
        dedicated structures (fixed prior XOR NW resample); a traced
        ``use`` flag selects per block between the fixed prior and the
        resampled hyperprior — both sides are elementwise identical to the
        dedicated paths (the hyper key was split unconditionally), so
        flagged executables are bit-compatible per block."""
        if fixed is not None and use is None:
            return fixed
        mu, Lam = BMF.sample_hyper(kh, X, nw)
        hier = POST.broadcast_prior(mu, Lam, n)
        if fixed is None:
            return hier
        return jax.tree.map(lambda f, h: jnp.where(use, f, h), fixed, hier)

    def sweep(i, carry):
        key, U, V, acc = carry
        key, kh1, kh2, ku, kv = jax.random.split(key, 5)

        u_prior = pick_prior(U_prior, u_use, kh1, U, N)
        v_prior = pick_prior(V_prior, v_use, kh2, V, D)

        U = u_sampler(ku, csr_rows, V, u_prior)
        V = v_sampler(kv, csr_cols, U, v_prior)

        keep = (i >= burnin).astype(jnp.float32)
        pred = BMF.predict(U, V, test_rows, test_cols)
        acc = GibbsAccumulators(
            pred_sum=acc.pred_sum + keep * pred,
            pred_cnt=acc.pred_cnt + keep,
            U_sum=acc.U_sum + keep * U,
            U_outer=acc.U_outer + keep * jnp.einsum("nk,nl->nkl", U, U),
            V_sum=acc.V_sum + keep * V,
            V_outer=acc.V_outer + keep * jnp.einsum("nk,nl->nkl", V, V))
        return (key, U, V, acc)

    key, U, V, acc = jax.lax.fori_loop(
        0, n_samples, sweep, (key, U0, V0, acc0))

    cnt = jnp.maximum(acc.pred_cnt, 1.0)
    U_post = _summarize(acc.U_sum, acc.U_outer, cnt)
    V_post = _summarize(acc.V_sum, acc.V_outer, cnt)
    health = chain_health(U, V, U_post, V_post, acc.pred_sum)
    return GibbsResult(U=U, V=V, acc=acc, U_post=U_post, V_post=V_post,
                       health=health)


def rmse_from_acc(acc: GibbsAccumulators, test_vals: jnp.ndarray) -> jnp.ndarray:
    pred = acc.pred_sum / jnp.maximum(acc.pred_cnt, 1.0)
    return jnp.sqrt(jnp.mean((pred - test_vals) ** 2))


class TracedChain(NamedTuple):
    """What the static analyzer needs from one lowering: the jax Traced
    object (``.jaxpr`` feeds the jaxpr passes, ``.lower().compile()`` the
    HLO passes), the flat XLA-parameter labels in order, the labels
    donate_argnums covers, and the subset that must alias an output."""
    traced: object
    param_labels: Tuple[str, ...]
    donated_labels: Tuple[str, ...]
    must_alias: Tuple[str, ...]


def _flat_param_labels(named_args) -> Tuple[str, ...]:
    """Flatten [(name, pytree-of-avals)] into per-XLA-parameter labels:
    the jit entry's parameter order IS the flattened order of its dynamic
    args, so label i names HLO parameter i."""
    labels = []
    for name, tree in named_args:
        leaves = jax.tree_util.tree_leaves(tree)
        if len(leaves) == 1:
            labels.append(name)
        else:
            labels.extend(f"{name}.{i}" for i in range(len(leaves)))
    return tuple(labels)


def _donated_labels(named_args, donate_argnums) -> Tuple[str, ...]:
    out = []
    for pos in donate_argnums:
        name, tree = named_args[pos]
        n = len(jax.tree_util.tree_leaves(tree))
        out.extend([name] if n == 1 else [f"{name}.{i}" for i in range(n)])
    return tuple(out)


def trace_chain(cfg: BMF.BMFConfig, n_rows: int, n_cols: int, m_rows: int,
                m_cols: int, n_test: int, *, batch: Optional[int] = None,
                donate: bool = False, u_prior: bool = True,
                v_prior: bool = True, prior_use: bool = False,
                mesh=None) -> TracedChain:
    """Lowering hook for the static analyzer (repro.analysis /
    launch.bmf_lint): trace the EXACT executable ``run_gibbs``
    (batch=None) or ``run_gibbs_stacked`` (batch=B) dispatches, at
    abstract shapes. ``prior_use`` adds the streaming executor's
    per-block prior-use flags (stacked only); ``mesh`` shard_maps the
    batch over a 1-D 'block' mesh (the sharded executor's data=1 path)."""
    S = jax.ShapeDtypeStruct
    f32, i32 = jnp.float32, jnp.int32
    K = cfg.K
    cfg_key = cfg._replace(n_samples=0, burnin=0, phase_bc_samples=None)

    def shp(*dims):
        return dims if batch is None else (batch,) + dims

    csr_r = (S(shp(n_rows, m_rows), i32), S(shp(n_rows, m_rows), f32),
             S(shp(n_rows, m_rows), f32))
    csr_c = (S(shp(n_cols, m_cols), i32), S(shp(n_cols, m_cols), f32),
             S(shp(n_cols, m_cols), f32))
    tr, tc = S(shp(n_test), i32), S(shp(n_test), i32)
    ns, bi = S((), i32), S((), i32)
    up = (RowGaussians(eta=S(shp(n_rows, K), f32),
                       Lambda=S(shp(n_rows, K, K), f32)) if u_prior else None)
    vp = (RowGaussians(eta=S(shp(n_cols, K), f32),
                       Lambda=S(shp(n_cols, K, K), f32)) if v_prior else None)
    U0, V0 = S(shp(n_rows, K), f32), S(shp(n_cols, K), f32)

    if batch is None:
        key = jax.eval_shape(lambda: jax.random.key(0))
        named = [("key", key), ("csr_rows", csr_r), ("csr_cols", csr_c),
                 ("test_rows", tr), ("test_cols", tc), ("n_samples", ns),
                 ("burnin", bi), ("U_prior", up), ("V_prior", vp),
                 ("U0", U0), ("V0", V0)]
        fn = _run_gibbs_jit_donated if donate else _run_gibbs_jit
        with (_quiet_donation() if donate else contextlib.nullcontext()):
            traced = fn.trace(key, csr_r, csr_c, tr, tc, cfg_key,
                              n_cols, n_rows, ns, bi, up, vp, U0, V0)
        # donate positions -> named entries: the dispatch signature
        # interleaves the static args (cfg, n_cols_r, n_cols_c) at 5-7
        dpos = (1, 2, 3, 4, 9, 10)
    else:
        kd = S((batch, 2), jnp.uint32)
        uu = S((batch,), f32) if prior_use else None
        named = [("key_data", kd), ("csr_rows", csr_r), ("csr_cols", csr_c),
                 ("test_rows", tr), ("test_cols", tc), ("n_samples", ns),
                 ("burnin", bi), ("U_prior", up), ("V_prior", vp),
                 ("U0", U0), ("V0", V0), ("u_use", uu), ("v_use", uu)]
        fn = _run_gibbs_stacked_jit_donated if donate \
            else _run_gibbs_stacked_jit
        with (_quiet_donation() if donate else contextlib.nullcontext()):
            traced = fn.trace(kd, csr_r, csr_c, tr, tc, cfg_key,
                              n_cols, n_rows, ns, bi, up, vp, U0, V0,
                              uu, uu, mesh=mesh)
        dpos = (1, 2, 3, 4, 9, 10)
    donated = _donated_labels(named, dpos) if donate else ()
    must = tuple(lb for lb in ("U0", "V0") if lb in donated)
    return TracedChain(traced=traced,
                       param_labels=_flat_param_labels(named),
                       donated_labels=donated, must_alias=must)
