"""Gaussian posterior algebra in natural parameters + Wishart sampling.

Posterior Propagation combines per-row Gaussian posteriors multiplicatively
and divides away multiply-counted priors. In natural parameters
(eta = Λ μ, Λ = precision) both operations are additions/subtractions:

    N(μ1,Λ1⁻¹)·N(μ2,Λ2⁻¹) ∝ N(Λ⁻¹η, Λ⁻¹),  Λ = Λ1+Λ2, η = η1+η2
    N1 / N2               ->  Λ = Λ1-Λ2, η = η1-η2   (valid if Λ ≻ 0)

All functions are batched over leading row axes: mu (N, K), Lambda (N, K, K).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class RowGaussians(NamedTuple):
    """Per-row Gaussian beliefs over factor rows. eta = Λ μ."""
    eta: jnp.ndarray      # (N, K)
    Lambda: jnp.ndarray   # (N, K, K)

    @property
    def mean(self):
        return jnp.linalg.solve(self.Lambda, self.eta[..., None])[..., 0]

    @property
    def cov(self):
        return _chol_inverse(jnp.linalg.cholesky(self.Lambda))


def _chol_inverse(L):
    """inv(L Lᵀ) via two batched triangular solves — O(K³/3) factor reuse,
    no LU / explicit ``jnp.linalg.inv``."""
    K = L.shape[-1]
    eye = jnp.broadcast_to(jnp.eye(K, dtype=L.dtype), L.shape)
    return jax.scipy.linalg.cho_solve((L, True), eye)


def from_moments(mu, Lambda) -> RowGaussians:
    eta = jnp.einsum("...ij,...j->...i", Lambda, mu)
    return RowGaussians(eta=eta, Lambda=Lambda)


def from_moments_cov(mu, cov, ridge: float = 0.0) -> RowGaussians:
    """Natural params from (mean, COVARIANCE) moments via one Cholesky
    factor + triangular solves: η = Σ⁻¹μ and Λ = Σ⁻¹ share the factor.
    This replaces the ``jnp.linalg.inv(cov)`` + matmul path in the Gibbs
    summarization hot loop."""
    K = mu.shape[-1]
    if ridge:
        cov = cov + ridge * jnp.eye(K, dtype=cov.dtype)
    L = jnp.linalg.cholesky(cov)
    eta = jax.scipy.linalg.cho_solve((L, True), mu[..., None])[..., 0]
    return RowGaussians(eta=eta, Lambda=_chol_inverse(L))


def broadcast_prior(mu, Lambda, n_rows: int) -> RowGaussians:
    """Shared prior (mu (K,), Lambda (K,K)) -> per-row natural params."""
    K = mu.shape[-1]
    eta = (Lambda @ mu)[None, :].repeat(n_rows, axis=0)
    Lam = jnp.broadcast_to(Lambda, (n_rows, K, K))
    return RowGaussians(eta=eta, Lambda=Lam)


def product(a: RowGaussians, b: RowGaussians) -> RowGaussians:
    return RowGaussians(eta=a.eta + b.eta, Lambda=a.Lambda + b.Lambda)


def divide(a: RowGaussians, b: RowGaussians) -> RowGaussians:
    return RowGaussians(eta=a.eta - b.eta, Lambda=a.Lambda - b.Lambda)


def scale(a: RowGaussians, c: float) -> RowGaussians:
    return RowGaussians(eta=c * a.eta, Lambda=c * a.Lambda)


def from_samples(samples, ridge: float = 1e-4) -> RowGaussians:
    """Summarize MCMC draws (T, N, K) as per-row Gaussians.

    Precision = inv(sample covariance + ridge·I); the ridge keeps the
    estimate PD for small T (as in Qin et al. 2019).
    """
    T, N, K = samples.shape
    mean = samples.mean(0)                                # (N, K)
    centered = samples - mean
    cov = jnp.einsum("tnk,tnl->nkl", centered, centered) / max(T - 1, 1)
    return from_moments_cov(mean, cov, ridge=ridge)


def sample_rows_noise(g: RowGaussians, z: jnp.ndarray,
                      jitter: float = 1e-6):
    """``sample_rows`` with the standard-normal draw ``z`` (N, K) supplied
    by the caller. Row-local math — the data-sharded intra-block sweep
    feeds each shard the SLICE of the full replicated draw so its local
    rows match the single-device sample bit-for-bit."""
    K = g.eta.shape[-1]
    Lam = g.Lambda + jitter * jnp.eye(K)
    chol = jnp.linalg.cholesky(Lam)
    mu = jax.scipy.linalg.cho_solve((chol, True), g.eta[..., None])[..., 0]
    # x = mu + L^-T z has covariance Λ⁻¹
    delta = jax.scipy.linalg.solve_triangular(
        jnp.swapaxes(chol, -1, -2), z[..., None], lower=False)[..., 0]
    return mu + delta


def sample_rows(key, g: RowGaussians, jitter: float = 1e-6):
    """Draw one row each: x_n ~ N(Λ_n⁻¹ η_n, Λ_n⁻¹), via Cholesky of Λ."""
    N, K = g.eta.shape
    z = jax.random.normal(key, (N, K), dtype=g.eta.dtype)
    return sample_rows_noise(g, z, jitter)


# ---------------------------------------------------------------------------
# Wishart / Normal-Wishart (BPMF hyperpriors)
# ---------------------------------------------------------------------------


class NormalWishart(NamedTuple):
    mu0: jnp.ndarray      # (K,)
    beta0: jnp.ndarray    # scalar
    W0: jnp.ndarray       # (K, K) scale matrix
    nu0: jnp.ndarray      # scalar degrees of freedom (> K-1)


def default_nw(K: int, dtype=jnp.float32) -> NormalWishart:
    return NormalWishart(
        mu0=jnp.zeros((K,), dtype),
        beta0=jnp.asarray(2.0, dtype),
        W0=jnp.eye(K, dtype=dtype),
        nu0=jnp.asarray(float(K), dtype),
    )


def sample_wishart(key, W: jnp.ndarray, nu, dtype=None):
    """Bartlett decomposition: X ~ W_K(W, nu)."""
    K = W.shape[-1]
    dtype = dtype or W.dtype
    kg, kn = jax.random.split(key)
    # diag: sqrt of chi2(nu - i) = 2*Gamma((nu-i)/2)
    i = jnp.arange(K, dtype=dtype)
    df = (nu - i) / 2.0
    chi2 = 2.0 * jax.random.gamma(kg, df, dtype=dtype)
    A = jnp.diag(jnp.sqrt(chi2))
    lower = jnp.tril(jax.random.normal(kn, (K, K), dtype=dtype), -1)
    A = A + lower
    L = jnp.linalg.cholesky(W + 1e-6 * jnp.eye(K, dtype=dtype))
    LA = L @ A
    return LA @ LA.T


def nw_posterior(prior: NormalWishart, X: jnp.ndarray) -> NormalWishart:
    """Conjugate NW update given rows X (N, K)."""
    N, K = X.shape
    xbar = X.mean(0)
    S = jnp.einsum("nk,nl->kl", X - xbar, X - xbar)      # N * sample cov
    beta_n = prior.beta0 + N
    nu_n = prior.nu0 + N
    mu_n = (prior.beta0 * prior.mu0 + N * xbar) / beta_n
    d = (xbar - prior.mu0)[:, None]
    W0_inv = _chol_inverse(jnp.linalg.cholesky(prior.W0))
    Wn_inv = W0_inv + S + (prior.beta0 * N / beta_n) * (d @ d.T)
    Wn = _chol_inverse(jnp.linalg.cholesky(Wn_inv))
    return NormalWishart(mu0=mu_n, beta0=beta_n, W0=Wn, nu0=nu_n)


def sample_nw(key, nw: NormalWishart):
    """Draw (mu, Lambda) ~ NW."""
    kw, km = jax.random.split(key)
    Lam = sample_wishart(kw, nw.W0, nw.nu0)
    K = Lam.shape[-1]
    # mu ~ N(mu0, (β Λ)⁻¹): with βΛ = L Lᵀ, x = L⁻ᵀ z has the right
    # covariance — one triangular solve, no inverse-then-Cholesky
    L = jnp.linalg.cholesky(nw.beta0 * Lam + 1e-6 * jnp.eye(K))
    z = jax.random.normal(km, (K,), dtype=Lam.dtype)
    mu = nw.mu0 + jax.scipy.linalg.solve_triangular(L.T, z, lower=False)
    return mu, Lam
