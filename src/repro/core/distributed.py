"""Distributed BMF within one block (ref [16], adapted MPI→shard_map).

The paper's Fig. 2 communication pattern: rows of U are sampled in parallel
on the workers that own them; the cross-factor dependency is resolved by
exchanging the freshly sampled factor. Our TPU adaptation:

  - the block's users (rows of U) and their ratings are sharded over the
    'data' mesh axis (padded CSR, rating-count-balanced by partition.py);
  - U-step: each device samples its local U rows against a REPLICATED V —
    zero communication;
  - V-step: each device computes partial per-item sufficient statistics
    (τ Σ u uᵀ, τ Σ r u) from its local ratings (COO segment-sum), a single
    psum reduces them, and every device samples the SAME V (same key) —
    communication is exactly 2·D·(K²+K)·4 bytes per sweep, independent of
    #ratings: the paper's "limited communication" property, made explicit.

Hyperparameter (NW) sampling similarly reduces O(K²) factor moments.
"""
from __future__ import annotations

import contextlib
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import bmf as BMF
from repro.core import gibbs as GIBBS
from repro.core import posterior as POST
from repro.core.posterior import NormalWishart, RowGaussians
from repro.core.topology import BLOCK_AXIS, DATA_AXIS
from repro.data.sparse import PaddedCSR


def make_block_mesh(n_devices: Optional[int] = None) -> Mesh:
    """1-D device mesh with axis 'block' for the PP phase-graph
    ShardedExecutor (core.engine): same-phase blocks are placed on separate
    devices and no collective runs inside a phase — posterior summaries
    cross phase boundaries through the host, which IS the paper's entire
    communication budget. The data==1 degenerate form of the unified 2-D
    ('block', 'data') placement (core.topology.Topology / the composed
    executables below, which add the intra-block 'data' axis)."""
    from repro.core.topology import Topology
    n = n_devices or len(jax.devices())
    return Topology(block=n, data=1).block_mesh()


def _pad_rows(arr, mult):
    n = arr.shape[0]
    pad = (-n) % mult
    if pad:
        arr = jnp.concatenate([arr, jnp.zeros((pad,) + arr.shape[1:],
                                              arr.dtype)], 0)
    return arr


def pad_csr_for_mesh(csr: PaddedCSR, n_shards: int) -> PaddedCSR:
    return PaddedCSR(idx=_pad_rows(csr.idx, n_shards),
                     val=_pad_rows(csr.val, n_shards),
                     mask=_pad_rows(csr.mask, n_shards),
                     n_cols=csr.n_cols)


def item_stats_local(U_loc, csr_t_loc: PaddedCSR, tau: float,
                     use_kernel: bool = False):
    """Per-item partial stats from this device's ratings.

    U_loc: (N_loc, K); csr_t_loc: this shard's TRANSPOSED padded CSR —
    rows = items, columns = *local* user slots (built host-side by
    run_gibbs_distributed). Returns (D, K, K), (D, K) partial sums
    (pre-reduction). Reuses bmf.sufficient_stats, i.e. the same
    fused-gather hot path (Pallas kernel / chunked scan) as the U-step —
    a segment_sum formulation would materialize an (nnz, K, K) outer
    product tensor (§Perf H6a).
    """
    return BMF.sufficient_stats(csr_t_loc, U_loc, tau, use_kernel)


def make_distributed_sweep(mesh: Mesh, cfg: BMF.BMFConfig, N: int, D: int,
                           n_shards: int,
                           has_u_prior: bool, has_v_prior: bool,
                           scatter_v: bool = False):
    """Build the shard_mapped one-sweep function.

    scatter_v=False — paper-faithful (ref [16] Fig. 2): psum the full
      (D, K, K) item stats, every device samples the same replicated V.
    scatter_v=True — beyond-paper (§Perf H6): psum_scatter the stats so
      each device reduces only its D/P item rows (half the ring bytes of a
      psum), samples ONLY those rows (V-step Cholesky parallelized too),
      then all_gathers the sampled V (D·K floats — 2/K² of the stats).
      Comm per sweep: D(K²+K)/2 + DK floats vs D(K²+K).
    """
    K = cfg.K
    nw = POST.default_nw(K)
    assert not (scatter_v and D % n_shards), (D, n_shards)

    def sweep(key, U, V, csr_idx, csr_val, csr_mask,
              csrt_idx, csrt_val, csrt_mask,
              u_prior_eta, u_prior_lam, v_prior_eta, v_prior_lam):
        # --- everything here runs per-device on local shards -------------
        csr_loc = PaddedCSR(idx=csr_idx, val=csr_val, mask=csr_mask, n_cols=D)
        # transposed shard: (1, D, M_c) with leading shard dim from shard_map
        csrt_loc = PaddedCSR(idx=csrt_idx[0], val=csrt_val[0],
                             mask=csrt_mask[0], n_cols=csr_idx.shape[0])
        key, kh1, kh2, ku, kv = jax.random.split(key, 5)

        # U hyperprior: needs global U moments -> psum of local moments
        if has_u_prior:
            u_prior = RowGaussians(eta=u_prior_eta, Lambda=u_prior_lam)
        else:
            s1 = jax.lax.psum(U.sum(0), "data")                  # (K,)
            s2 = jax.lax.psum(jnp.einsum("nk,nl->kl", U, U), "data")
            muU, LamU = _sample_nw_from_moments(kh1, s1, s2, N, nw)
            u_prior = POST.broadcast_prior(muU, LamU, U.shape[0])

        # --- U-step: local rows vs replicated V (no communication) -------
        # fold in the shard index: every device must draw DIFFERENT noise
        # for its own U rows (the V-step key below is deliberately shared so
        # all devices sample the identical replicated V).
        ku_dev = jax.random.fold_in(ku, jax.lax.axis_index("data"))
        U = BMF.sample_factor(ku_dev, csr_loc, V, cfg.tau, u_prior,
                              cfg.use_kernel)

        # --- V-step ---------------------------------------------------------
        Lam_part, eta_part = item_stats_local(U, csrt_loc, cfg.tau,
                                              cfg.use_kernel)
        if has_v_prior:
            v_prior = RowGaussians(eta=v_prior_eta, Lambda=v_prior_lam)
        else:
            s1v = V.sum(0)                                        # V replicated
            s2v = jnp.einsum("dk,dl->kl", V, V)
            muV, LamV = _sample_nw_from_moments(kh2, s1v, s2v, D, nw)
            v_prior = POST.broadcast_prior(muV, LamV, D)
        if scatter_v:
            # beyond-paper: reduce-scatter stats to D/P local item rows,
            # sample locally (different noise per shard), gather sampled V
            Lam_loc = jax.lax.psum_scatter(Lam_part, "data", scatter_dimension=0,
                                           tiled=True)   # (D/P, K, K)
            eta_loc = jax.lax.psum_scatter(eta_part, "data", scatter_dimension=0,
                                           tiled=True)   # (D/P, K)
            idx = jax.lax.axis_index("data")
            d_lo = idx * (D // n_shards)
            pr_eta = jax.lax.dynamic_slice_in_dim(v_prior.eta, d_lo,
                                                  D // n_shards, 0)
            pr_lam = jax.lax.dynamic_slice_in_dim(v_prior.Lambda, d_lo,
                                                  D // n_shards, 0)
            cond = RowGaussians(eta=pr_eta + eta_loc, Lambda=pr_lam + Lam_loc)
            kv_dev = jax.random.fold_in(kv, idx)
            V_loc = POST.sample_rows(kv_dev, cond)
            V = jax.lax.all_gather(V_loc, "data", tiled=True)     # (D, K)
        else:
            # paper-faithful: full psum, replicated sampling (same key)
            Lam_items = jax.lax.psum(Lam_part, "data")            # (D, K, K)
            eta_items = jax.lax.psum(eta_part, "data")            # (D, K)
            cond = RowGaussians(eta=v_prior.eta + eta_items,
                                Lambda=v_prior.Lambda + Lam_items)
            V = POST.sample_rows(kv, cond)  # same key everywhere -> same V
        return key, U, V

    in_specs = (P(), P("data", None), P(None, None),
                P("data", None), P("data", None), P("data", None),
                P("data", None, None), P("data", None, None),
                P("data", None, None),
                P("data", None) if has_u_prior else P(None),
                P("data", None, None) if has_u_prior else P(None),
                P(None, None) if has_v_prior else P(None),
                P(None, None, None) if has_v_prior else P(None))
    out_specs = (P(), P("data", None), P(None, None))
    return shard_map(sweep, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


def _sample_nw_from_moments(key, s1, s2, n, nw: NormalWishart):
    """NW posterior sample from psum'd moments (Σx, Σxxᵀ, n)."""
    xbar = s1 / n
    S = s2 - n * jnp.outer(xbar, xbar)
    beta_n = nw.beta0 + n
    nu_n = nw.nu0 + n
    mu_n = (nw.beta0 * nw.mu0 + n * xbar) / beta_n
    d = (xbar - nw.mu0)[:, None]
    Wn_inv = jnp.linalg.inv(nw.W0) + S + (nw.beta0 * n / beta_n) * (d @ d.T)
    Wn = jnp.linalg.inv(Wn_inv)
    return POST.sample_nw(key, NormalWishart(mu0=mu_n, beta0=beta_n,
                                             W0=Wn, nu0=nu_n))


def run_gibbs_distributed(key, csr_rows: PaddedCSR, csr_cols: PaddedCSR,
                          test_rows, test_cols, cfg: BMF.BMFConfig,
                          mesh: Mesh,
                          U_prior: Optional[RowGaussians] = None,
                          V_prior: Optional[RowGaussians] = None,
                          scatter_v: bool = False,
                          U0: Optional[jnp.ndarray] = None,
                          V0: Optional[jnp.ndarray] = None,
                          donate: bool = False) -> GIBBS.GibbsResult:
    """Distributed analogue of gibbs.run_gibbs for one (large) block.

    Note: csr_cols is unused in the distributed path (item stats come from
    the row-sharded COO via segment_sum) but kept for signature parity.

    ``donate=True`` donates the per-sweep CARRY (key, U, V) to the jitted
    sweep: each iteration's factor buffers are rewritten in place as the
    next iteration's outputs instead of allocating a fresh (N, K) + (D, K)
    pair per sweep — the distributed analogue of the PR-3 chain donation
    (the CSR planes and priors are reused every sweep and are never
    donated). ``U0`` / ``V0`` optionally seed the factors (same contract
    as ``run_gibbs``); with ``donate=True`` the caller's handles are
    invalidated by the first sweep.
    """
    n_shards = mesh.shape["data"]
    N, D, K = csr_rows.n_rows, csr_rows.n_cols, cfg.K
    D_orig = D
    csr = pad_csr_for_mesh(csr_rows, n_shards)
    N_pad = csr.idx.shape[0]
    if scatter_v and D % n_shards:
        # pad item dim so psum_scatter tiles evenly; padded item rows get
        # prior-only stats and their samples are never read back
        pad_d = (-D) % n_shards
        csr = PaddedCSR(idx=csr.idx, val=csr.val, mask=csr.mask,
                        n_cols=D + pad_d)
        if V_prior is not None:
            eye = jnp.broadcast_to(jnp.eye(K), (pad_d, K, K))
            V_prior = RowGaussians(
                eta=jnp.concatenate([V_prior.eta, jnp.zeros((pad_d, K))]),
                Lambda=jnp.concatenate([V_prior.Lambda, eye]))
        D = D + pad_d

    # host-side: per-shard TRANSPOSED padded CSR (items x local users) for
    # the V-step partial stats (§Perf H6a — avoids the (nnz,K,K) segment-sum
    # blow-up of the naive formulation)
    import numpy as np
    from repro.data.sparse import COO, coo_to_padded_csr
    N_loc = N_pad // n_shards
    idx_h = np.asarray(csr.idx)
    val_h = np.asarray(csr.val)
    mask_h = np.asarray(csr.mask)
    rows_h, slots_h = np.nonzero(mask_h > 0)
    cols_h = idx_h[rows_h, slots_h]
    vals_h = val_h[rows_h, slots_h]
    shard_of = rows_h // N_loc
    shard_csrts = []
    m_c = 1
    for s in range(n_shards):
        sel = shard_of == s
        coo_t = COO(row=cols_h[sel].astype(np.int32),
                    col=(rows_h[sel] - s * N_loc).astype(np.int32),
                    val=vals_h[sel].astype(np.float32),
                    n_rows=D, n_cols=N_loc)
        cnt = np.bincount(coo_t.row, minlength=D)
        m_c = max(m_c, int(cnt.max()) if cnt.size else 1)
        shard_csrts.append(coo_t)
    csrt_parts = [coo_to_padded_csr(c, max_nnz=m_c) for c in shard_csrts]
    csrt_idx = jnp.stack([c.idx for c in csrt_parts])     # (S, D, M_c)
    csrt_val = jnp.stack([c.val for c in csrt_parts])
    csrt_mask = jnp.stack([c.mask for c in csrt_parts])

    k0, key = jax.random.split(key)
    if U0 is None or V0 is None:
        U0_, V0_ = BMF.init_factors(k0, N_pad, D, K)
        U0 = U0 if U0 is not None else U0_
        V0 = V0 if V0 is not None else V0_
    U0 = _pad_rows(U0, n_shards)
    if U0.shape[0] != N_pad:
        raise ValueError(f"U0 rows {U0.shape[0]} != padded N {N_pad}")
    if V0.shape[0] != D:
        V0 = jnp.concatenate([V0, jnp.zeros((D - V0.shape[0], K))])

    has_u = U_prior is not None
    has_v = V_prior is not None
    if has_u:
        U_prior = RowGaussians(eta=_pad_rows(U_prior.eta, n_shards),
                               Lambda=_pad_rows(U_prior.Lambda, n_shards))
        # padded rows get identity precision (harmless, never read back)
        pad = N_pad - N
        if pad:
            U_prior = RowGaussians(
                eta=U_prior.eta,
                Lambda=U_prior.Lambda.at[N:].set(jnp.eye(K)))
    dummy_eta = jnp.zeros((1,), jnp.float32)

    sweep = make_distributed_sweep(mesh, cfg, N_pad, D, n_shards, has_u, has_v,
                                   scatter_v=scatter_v)
    # donate the carry: (key, U, V) of sweep t alias sweep t+1's outputs,
    # so the per-sweep loop recycles its factor buffers in place instead of
    # allocating a fresh pair every iteration (ROADMAP lever: donation for
    # the distributed per-sweep loop). The plane/prior args are reused
    # across sweeps and stay un-donated. The initial carry is device_put
    # to the sweep's exact shardings first — a donated buffer jit has to
    # reshard is consumed by the transfer, not aliased, and the caller's
    # U0/V0 handles would silently stay live.
    sweep = jax.jit(sweep, donate_argnums=(0, 1, 2) if donate else ())
    if donate:
        def commit(x, spec):
            sh = NamedSharding(mesh, spec)
            return x if getattr(x, "sharding", None) == sh \
                else jax.device_put(x, sh)
        key = commit(key, P())
        U0 = commit(U0, P("data", None))
        V0 = commit(V0, P(None, None))

    acc = GIBBS.GibbsAccumulators(
        pred_sum=jnp.zeros_like(test_rows, dtype=jnp.float32),
        pred_cnt=jnp.zeros((), jnp.float32),
        U_sum=jnp.zeros((N_pad, K)), U_outer=jnp.zeros((N_pad, K, K)),
        V_sum=jnp.zeros((D, K)), V_outer=jnp.zeros((D, K, K)))

    U, V = U0, V0
    predict_j = jax.jit(BMF.predict)
    for it in range(cfg.n_samples):
        key, U, V = sweep(
            key, U, V, csr.idx, csr.val, csr.mask,
            csrt_idx, csrt_val, csrt_mask,
            U_prior.eta if has_u else dummy_eta,
            U_prior.Lambda if has_u else dummy_eta,
            V_prior.eta if has_v else dummy_eta,
            V_prior.Lambda if has_v else dummy_eta)
        if it >= cfg.burnin:
            pred = predict_j(U, V, test_rows, test_cols)
            acc = GIBBS.GibbsAccumulators(
                pred_sum=acc.pred_sum + pred,
                pred_cnt=acc.pred_cnt + 1.0,
                U_sum=acc.U_sum + U,
                U_outer=acc.U_outer + jnp.einsum("nk,nl->nkl", U, U),
                V_sum=acc.V_sum + V,
                V_outer=acc.V_outer + jnp.einsum("dk,dl->dkl", V, V))

    cnt = jnp.maximum(acc.pred_cnt, 1.0)
    U_post = GIBBS._summarize(acc.U_sum[:N], acc.U_outer[:N], cnt)
    V_post = GIBBS._summarize(acc.V_sum[:D_orig], acc.V_outer[:D_orig], cnt)
    # trim padding
    acc = acc._replace(U_sum=acc.U_sum[:N], U_outer=acc.U_outer[:N],
                       V_sum=acc.V_sum[:D_orig], V_outer=acc.V_outer[:D_orig])
    health = jax.jit(GIBBS.chain_health)(
        U[:N], V[:D_orig], U_post, V_post, acc.pred_sum)
    return GIBBS.GibbsResult(U=U[:N], V=V[:D_orig], acc=acc, U_post=U_post,
                             V_post=V_post, health=health)


# ---------------------------------------------------------------------------
# Composed 2-D ('block', 'data') chains — block-parallel executors with the
# intra-block distributed sweep inside each block (the paper's combined
# system: PP block parallelism × ref [16]/[17] distributed BMF)
# ---------------------------------------------------------------------------

#: intra-block communication modes for the composed chains.
#:   'gather'  — exchange the freshly sampled factor: each 'data' shard
#:               samples its local U rows and all_gathers them (ref [17]'s
#:               asynchronous factor communication, made synchronous); the
#:               V-step then runs replicated on the full factor, so the
#:               chain is the single-device reference chain bit-for-bit
#:               (executor parity mode). Comm/sweep: N·K floats.
#:   'psum'    — paper-faithful ref [16]: per-shard partial item stats,
#:               one psum, every shard samples the same replicated V.
#:               Comm/sweep: D·(K²+K) floats (+ the N·K factor gather).
#:   'scatter' — beyond-paper §Perf H6: psum_scatter the stats, sample
#:               only local item rows, all_gather the sampled V.
COMM_MODES = ("gather", "psum", "scatter")


def _pad_rows_to(arr, n: int):
    pad = n - arr.shape[0]
    if pad <= 0:
        return arr
    return jnp.concatenate([arr, jnp.zeros((pad,) + arr.shape[1:],
                                           arr.dtype)], 0)


def shard_transposed_planes(rows, cols, vals, n_shards: int, n_rows_pad: int,
                            n_items: int, max_nnz: int):
    """Host-side per-shard TRANSPOSED padded-CSR planes for the composed
    V-step partial stats ('psum'/'scatter' modes): shard s holds
    items × its LOCAL users (rows [s·N_loc, (s+1)·N_loc) of the padded
    row space), so ``item_stats_local`` works on (n_items, max_nnz)
    planes whose column ids index the shard's local U rows.

    rows/cols/vals: COO triplets in BLOCK-local coordinates (numpy).
    Returns (idx, val, mask) numpy arrays of shape
    (n_shards, n_items, max_nnz) — the same per-shard layout
    ``run_gibbs_distributed`` assembles inline, factored out so the
    stacked 2-D executor path and the single-block path share it."""
    import numpy as np
    from repro.data.sparse import COO, coo_to_padded_csr

    N_loc = n_rows_pad // n_shards
    shard_of = rows // N_loc
    idxs, valss, masks = [], [], []
    for s in range(n_shards):
        sel = shard_of == s
        coo_t = COO(row=cols[sel].astype(np.int32),
                    col=(rows[sel] - s * N_loc).astype(np.int32),
                    val=vals[sel].astype(np.float32),
                    n_rows=n_items, n_cols=N_loc)
        csr = coo_to_padded_csr(coo_t, max_nnz=max_nnz,
                                n_rows_pad=n_items, n_cols_pad=N_loc,
                                as_numpy=True)
        idxs.append(csr.idx)
        valss.append(csr.val)
        masks.append(csr.mask)
    return (np.stack(idxs), np.stack(valss), np.stack(masks))


def _sharded_u_sampler(cfg: BMF.BMFConfig, N: int, N_pad: int,
                       n_shards: int):
    """U-step over the 'data' axis: local conditional stats from the
    shard's row planes, the SLICE of the full replicated noise draw, one
    all_gather of the freshly sampled rows. Because the noise is the
    single-device draw and the per-row math is row-local, the gathered
    factor reproduces the reference ``BMF.sample_factor`` rows exactly —
    this sampler is shared by every comm mode."""
    K = cfg.K
    N_loc = N_pad // n_shards

    def u_sampler(ku, csr_loc, V, u_prior):
        lo = jax.lax.axis_index(DATA_AXIS) * N_loc
        pr_eta = jax.lax.dynamic_slice_in_dim(
            _pad_rows_to(u_prior.eta, N_pad), lo, N_loc, 0)
        pr_lam = jax.lax.dynamic_slice_in_dim(
            _pad_rows_to(u_prior.Lambda, N_pad), lo, N_loc, 0)
        # the reference draw: sample_rows(ku, cond_full) pulls
        # normal(ku, (N, K)) — replicate it and slice this shard's rows
        # (padded rows get zero noise; their samples are never read)
        z = _pad_rows_to(jax.random.normal(ku, (N, K), jnp.float32), N_pad)
        z_loc = jax.lax.dynamic_slice_in_dim(z, lo, N_loc, 0)
        if cfg.sweep_fused:
            # one-kernel sweep on the local row shard: the per-row math is
            # row-local and the noise slice is the reference stream, so the
            # gathered factor matches the single-device fused step exactly
            from repro.kernels.bmf_sweep import ops as SWEEP
            U_loc = SWEEP.fused_sweep(
                z_loc, csr_loc.idx, csr_loc.val, csr_loc.mask,
                pr_eta, pr_lam, V, cfg.tau, dtype=cfg.sweep_dtype)
        else:
            Lam_c, eta_c = BMF.sufficient_stats(csr_loc, V, cfg.tau,
                                                cfg.use_kernel)
            cond = RowGaussians(eta=pr_eta + eta_c, Lambda=pr_lam + Lam_c)
            U_loc = POST.sample_rows_noise(cond, z_loc)
        U_full = jax.lax.all_gather(U_loc, DATA_AXIS, tiled=True)
        return U_full[:N]

    return u_sampler


def _sharded_v_sampler(cfg: BMF.BMFConfig, D: int, D_pad: int, N_pad: int,
                       n_shards: int, scatter: bool):
    """V-step over the 'data' axis from per-shard transposed planes:
    partial item stats reduced by psum ('psum' — ref [16] Fig. 2,
    replicated sampling under a shared key) or psum_scatter + local
    sampling + all_gather ('scatter' — §Perf H6 half-ring-bytes).

    This step stays UNFUSED under ``cfg.sweep_fused``: the psum/scatter
    collective splits the Λ/η accumulate from the sample across devices,
    which is exactly the fusion boundary the one-kernel sweep removes on
    a single device — there is no single pass to fuse here (documented in
    kernels/bmf_precision/README.md)."""
    K = cfg.K
    N_loc = N_pad // n_shards
    D_loc = D_pad // n_shards

    def v_sampler(kv, csrt_loc, U_full, v_prior):
        idx = jax.lax.axis_index(DATA_AXIS)
        U_loc = jax.lax.dynamic_slice_in_dim(
            _pad_rows_to(U_full, N_pad), idx * N_loc, N_loc, 0)
        Lam_part, eta_part = item_stats_local(U_loc, csrt_loc, cfg.tau,
                                              cfg.use_kernel)
        pr_eta = _pad_rows_to(v_prior.eta, D_pad)
        pr_lam = _pad_rows_to(v_prior.Lambda, D_pad)
        if scatter:
            Lam_loc = jax.lax.psum_scatter(Lam_part, DATA_AXIS,
                                           scatter_dimension=0, tiled=True)
            eta_loc = jax.lax.psum_scatter(eta_part, DATA_AXIS,
                                           scatter_dimension=0, tiled=True)
            d_lo = idx * D_loc
            cond = RowGaussians(
                eta=jax.lax.dynamic_slice_in_dim(pr_eta, d_lo, D_loc, 0)
                + eta_loc,
                Lambda=jax.lax.dynamic_slice_in_dim(pr_lam, d_lo, D_loc, 0)
                + Lam_loc)
            kv_dev = jax.random.fold_in(kv, idx)
            V_loc = POST.sample_rows(kv_dev, cond)
            V_full = jax.lax.all_gather(V_loc, DATA_AXIS, tiled=True)
            return V_full[:D]
        Lam_items = jax.lax.psum(Lam_part, DATA_AXIS)
        eta_items = jax.lax.psum(eta_part, DATA_AXIS)
        cond = RowGaussians(eta=pr_eta + eta_items,
                            Lambda=pr_lam + Lam_items)
        return POST.sample_rows(kv, cond)[:D]   # same key -> same V everywhere

    return v_sampler


def _run_gibbs_2d_dispatch(key_data, csr_rows_arrs, csr_cols_arrs,
                           csrt_arrs, test_rows, test_cols, cfg,
                           n_cols_r, n_cols_c, n_samples, burnin,
                           U_prior, V_prior, U0, V0, u_use, v_use,
                           mesh=None, comm="gather", n_rows=0, n_cols=0):
    """Composed chain runner: one executable shard_maps the stacked block
    batch over the 'block' axis while each block's chain runs the
    intra-block distributed sweep over the 'data' axis.

    Leaf layout (B = stacked blocks, padded to a multiple of the block
    axis; N_pad = bucket rows padded to a multiple of the data axis):

      csr_rows_arrs  (B, N_pad, M)        P('block', 'data')  row shards
      csr_cols_arrs  (B, D, M_c) | None   P('block')          'gather' only
      csrt_arrs      (B, S, D_pad, M_c) | None  P('block', 'data')
                                          'psum'/'scatter' partial-stat
                                          planes (items × local users)
      priors / U0 / V0 / tests            P('block')          replicated
                                          over 'data'

    Inside a shard the per-block chain is ``gibbs._run_gibbs_impl`` with
    the data-sharded factor samplers swapped in — key handling, prior
    selection, accumulators and summaries are literally the reference
    code, which is what makes the 'gather' mode chain-identical to the
    serial executor. Every intra-phase collective this executable contains
    runs on the 'data' axis; nothing ever reduces over 'block'
    (``bmf_dryrun --pp-engine`` asserts that from the compiled HLO).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    n_shards = mesh.shape[DATA_AXIS]
    N, D = n_rows, n_cols
    N_pad = csr_rows_arrs[0].shape[1]
    D_pad = (csrt_arrs[0].shape[2] if csrt_arrs is not None else D)
    u_sampler = _sharded_u_sampler(cfg, N, N_pad, n_shards)
    v_sampler = (None if comm == "gather" else
                 _sharded_v_sampler(cfg, D, D_pad, N_pad, n_shards,
                                    scatter=(comm == "scatter")))

    def per_shard(kd, ra, ca, ta, tr, tc, ns, bi, up, vp, u0, v0, uu, vv):
        def one(kd1, ra1, ca1, ta1, tr1, tc1, up1, vp1, u01, v01, uu1, vv1):
            csr_loc = PaddedCSR(*ra1, n_cols=n_cols_r)
            if comm == "gather":
                csr_v = PaddedCSR(*ca1, n_cols=n_cols_c)
            else:
                # (1, D_pad, M_c) leading local-shard dim from shard_map
                csr_v = PaddedCSR(ta1[0][0], ta1[1][0], ta1[2][0],
                                  n_cols=N_pad // n_shards)
            return GIBBS._run_gibbs_impl(
                jax.random.wrap_key_data(kd1), csr_loc, csr_v,
                tr1, tc1, cfg, ns, bi, up1, vp1, u01, v01, uu1, vv1,
                u_sampler=u_sampler, v_sampler=v_sampler,
                n_rows=N, n_cols=D)
        return jax.vmap(one)(kd, ra, ca, ta, tr, tc, up, vp, u0, v0, uu, vv)

    blk, blkdata = P(BLOCK_AXIS), P(BLOCK_AXIS, DATA_AXIS)
    in_specs = (blk, blkdata, blk, blkdata, blk, blk, P(), P(),
                blk, blk, blk, blk, blk, blk)
    fsh = shard_map(per_shard, mesh=mesh, in_specs=in_specs,
                    out_specs=blk, check_rep=False)
    return fsh(key_data, csr_rows_arrs, csr_cols_arrs, csrt_arrs,
               test_rows, test_cols, n_samples, burnin,
               U_prior, V_prior, U0, V0, u_use, v_use)


_STATIC_2D = ("cfg", "n_cols_r", "n_cols_c", "mesh", "comm", "n_rows",
              "n_cols")
# Mirrors gibbs._DONATE_STACKED: the stacked CSR/test planes plus U0/V0
# (U0/V0 alias the U/V outputs); priors stay un-donated (shared across a
# PP row/col group and read again at final aggregation).
_DONATE_2D = (1, 2, 3, 4, 5, 13, 14)

_run_gibbs_2d_jit = jax.jit(_run_gibbs_2d_dispatch,
                            static_argnames=_STATIC_2D)
_run_gibbs_2d_jit_donated = jax.jit(_run_gibbs_2d_dispatch,
                                    static_argnames=_STATIC_2D,
                                    donate_argnums=_DONATE_2D)


def run_gibbs_stacked_2d(keys,
                         csr_rows: PaddedCSR,      # (B, N, M) leaves
                         csr_cols: PaddedCSR,      # (B, D, M_c) leaves
                         test_rows, test_cols, cfg: BMF.BMFConfig,
                         topology,
                         U_prior: Optional[RowGaussians] = None,
                         V_prior: Optional[RowGaussians] = None,
                         donate: bool = False,
                         prior_use: Optional[tuple] = None,
                         comm: str = "gather",
                         csrt=None,
                         mesh: Optional[Mesh] = None) -> GIBBS.GibbsResult:
    """2-D analogue of ``gibbs.run_gibbs_stacked``: B identically-shaped
    blocks' chains run as ONE executable on ``topology``'s
    ('block', 'data') mesh — the batch splits over device groups, each
    block's sweep is data-sharded inside its group.

    B must be a multiple of ``topology.block`` (callers pad the batch,
    exactly like the 1-D sharded path). Row planes are padded here to a
    multiple of ``topology.data`` with empty rows — padding that never
    enters the chain semantics (zero-mask CSR rows, zero noise, results
    trimmed), so per-block chains in 'gather' mode reproduce
    ``run_gibbs_stacked`` / ``run_gibbs`` under the same keys.

    ``comm``: see ``COMM_MODES``. 'psum'/'scatter' need ``csrt`` — the
    (B, S, D_pad, M_c) per-shard transposed planes from
    ``shard_transposed_planes`` (host-assembled by the executor).
    ``mesh`` optionally overrides ``topology.mesh`` (the dry-run passes a
    pre-built faked mesh)."""
    if comm not in COMM_MODES:
        raise ValueError(f"comm={comm!r} not in {COMM_MODES}")
    mesh = topology.mesh if mesh is None else mesh
    n_shards = mesh.shape[DATA_AXIS]
    N, D, K = csr_rows.idx.shape[1], csr_cols.idx.shape[1], cfg.K
    N_pad = ((N + n_shards - 1) // n_shards) * n_shards

    def pad_plane(x):
        if x.shape[1] == N_pad:
            return x
        pad = jnp.zeros((x.shape[0], N_pad - x.shape[1]) + x.shape[2:],
                        x.dtype)
        return jnp.concatenate([x, pad], axis=1)

    rows_arrs = tuple(pad_plane(x) for x in
                      (csr_rows.idx, csr_rows.val, csr_rows.mask))
    if comm == "gather":
        cols_arrs = (csr_cols.idx, csr_cols.val, csr_cols.mask)
        csrt_arrs = None
    else:
        if csrt is None:
            raise ValueError(f"comm={comm!r} needs the per-shard transposed "
                             f"planes (shard_transposed_planes)")
        cols_arrs = None
        csrt_arrs = tuple(jnp.asarray(x) for x in csrt)
        if csrt_arrs[0].shape[1] != n_shards:
            raise ValueError(f"csrt shard dim {csrt_arrs[0].shape[1]} != "
                             f"data axis {n_shards}")
    ks = jax.vmap(jax.random.split)(keys)
    U0, V0 = jax.vmap(lambda k: BMF.init_factors(k, N, D, K))(ks[:, 0])
    cfg_key = cfg._replace(n_samples=0, burnin=0, phase_bc_samples=None)
    u_use, v_use = prior_use if prior_use is not None else (None, None)
    fn = _run_gibbs_2d_jit_donated if donate else _run_gibbs_2d_jit
    with (GIBBS._quiet_donation() if donate
          else contextlib.nullcontext()):
        return fn(jax.random.key_data(ks[:, 1]), rows_arrs, cols_arrs,
                  csrt_arrs, test_rows, test_cols, cfg_key,
                  csr_rows.n_cols, csr_cols.n_cols,
                  jnp.asarray(cfg.n_samples, jnp.int32),
                  jnp.asarray(cfg.burnin, jnp.int32),
                  U_prior, V_prior, U0, V0, u_use, v_use,
                  mesh=mesh, comm=comm, n_rows=N, n_cols=D)


def run_gibbs_group(key, csr_rows: PaddedCSR, csr_cols: PaddedCSR,
                    test_rows, test_cols, cfg: BMF.BMFConfig,
                    topology, group: int = 0,
                    U_prior: Optional[RowGaussians] = None,
                    V_prior: Optional[RowGaussians] = None,
                    donate: bool = False, comm: str = "gather",
                    csrt=None) -> GIBBS.GibbsResult:
    """One block's chain data-sharded over a single topology group — the
    AsyncExecutor's multi-device dispatch unit. Implemented as the B=1
    stacked 2-D executable on the group's (1, data) submesh, so every
    group shares one compilation per (bucket, group) and the chain
    matches ``run_gibbs`` under the same key (the stacked batched key
    handling is the single-block handling)."""
    stack = lambda x: jnp.expand_dims(x, 0) if x is not None else None
    stack_csr = lambda c: PaddedCSR(idx=stack(c.idx), val=stack(c.val),
                                    mask=stack(c.mask), n_cols=c.n_cols)
    pri = lambda p: (None if p is None else
                     RowGaussians(eta=stack(p.eta), Lambda=stack(p.Lambda)))
    res = run_gibbs_stacked_2d(
        jnp.expand_dims(key, 0), stack_csr(csr_rows), stack_csr(csr_cols),
        stack(jnp.asarray(test_rows)), stack(jnp.asarray(test_cols)), cfg,
        topology, U_prior=pri(U_prior), V_prior=pri(V_prior),
        donate=donate, comm=comm,
        csrt=None if csrt is None else tuple(x[None] for x in csrt),
        mesh=topology.group_mesh_2d(group))
    return jax.tree.map(lambda x: x[0], res)


def sweep_comm_bytes(D: int, K: int) -> int:
    """The paper's 'limited communication': bytes reduced per Gibbs sweep."""
    return 4 * (D * (K * K + K) + 2 * (K * K + K))


def sweep_comm_bytes_scatter(D: int, K: int) -> int:
    """Beyond-paper scatter-V variant (§Perf H6): a ring reduce-scatter
    moves half the bytes of a ring all-reduce, plus the tiny sampled-V
    gather."""
    return 4 * (D * (K * K + K) // 2 + D * K + 2 * (K * K + K))


def trace_chain_2d(cfg: BMF.BMFConfig, topology, n_rows: int, n_cols: int,
                   m_rows: int, m_cols: int, n_test: int, *,
                   batch: Optional[int] = None, comm: str = "gather",
                   donate: bool = False, u_prior: bool = True,
                   v_prior: bool = True,
                   prior_use: bool = False) -> "GIBBS.TracedChain":
    """Lowering hook for the static analyzer: trace the EXACT composed
    executable ``run_gibbs_stacked_2d`` dispatches — B blocks over the
    'block' axis, each chain data-sharded over the 'data' axis — at
    abstract shapes. Mirrors ``gibbs.trace_chain``'s contract (see
    ``TracedChain``); ``batch`` defaults to ``topology.block``."""
    if comm not in COMM_MODES:
        raise ValueError(f"comm={comm!r} not in {COMM_MODES}")
    S = jax.ShapeDtypeStruct
    f32, i32 = jnp.float32, jnp.int32
    B = topology.block if batch is None else batch
    n_shards = topology.data
    K = cfg.K
    cfg_key = cfg._replace(n_samples=0, burnin=0, phase_bc_samples=None)
    N_pad = ((n_rows + n_shards - 1) // n_shards) * n_shards
    D_pad = ((n_cols + n_shards - 1) // n_shards) * n_shards

    rows = (S((B, N_pad, m_rows), i32), S((B, N_pad, m_rows), f32),
            S((B, N_pad, m_rows), f32))
    if comm == "gather":
        cols = (S((B, n_cols, m_cols), i32), S((B, n_cols, m_cols), f32),
                S((B, n_cols, m_cols), f32))
        csrt = None
    else:
        cols = None
        csrt = (S((B, n_shards, D_pad, m_cols), i32),
                S((B, n_shards, D_pad, m_cols), f32),
                S((B, n_shards, D_pad, m_cols), f32))
    tr, tc = S((B, n_test), i32), S((B, n_test), i32)
    ns, bi = S((), i32), S((), i32)
    up = (RowGaussians(eta=S((B, n_rows, K), f32),
                       Lambda=S((B, n_rows, K, K), f32)) if u_prior else None)
    vp = (RowGaussians(eta=S((B, n_cols, K), f32),
                       Lambda=S((B, n_cols, K, K), f32)) if v_prior else None)
    U0, V0 = S((B, n_rows, K), f32), S((B, n_cols, K), f32)
    uu = S((B,), f32) if prior_use else None
    named = [("key_data", S((B, 2), jnp.uint32)),
             ("csr_rows", rows), ("csr_cols", cols), ("csrt", csrt),
             ("test_rows", tr), ("test_cols", tc), ("n_samples", ns),
             ("burnin", bi), ("U_prior", up), ("V_prior", vp),
             ("U0", U0), ("V0", V0), ("u_use", uu), ("v_use", uu)]
    fn = _run_gibbs_2d_jit_donated if donate else _run_gibbs_2d_jit
    with (GIBBS._quiet_donation() if donate else contextlib.nullcontext()):
        traced = fn.trace(named[0][1], rows, cols, csrt, tr, tc, cfg_key,
                          n_cols, n_rows, ns, bi, up, vp, U0, V0, uu, uu,
                          mesh=topology.mesh, comm=comm,
                          n_rows=n_rows, n_cols=n_cols)
    # _DONATE_2D positions -> named entries (statics interleave at 6-8)
    dpos = (1, 2, 3, 4, 5, 10, 11)
    donated = GIBBS._donated_labels(named, dpos) if donate else ()
    # U0 cannot alias in the composed lowering: every sweep rebuilds the
    # full U as an all_gather of the data-sharded sampled rows, and a
    # collective's output is a fresh buffer — donating U0 only releases
    # it. V0 (gather mode runs the reference V-step) aliases in place.
    must = tuple(lb for lb in ("V0",) if lb in donated)
    return GIBBS.TracedChain(traced=traced,
                             param_labels=GIBBS._flat_param_labels(named),
                             donated_labels=donated, must_alias=must)
