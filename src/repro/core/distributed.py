"""Distributed BMF within one block (ref [16], adapted MPI→shard_map).

The paper's Fig. 2 communication pattern: rows of U are sampled in parallel
on the workers that own them; the cross-factor dependency is resolved by
exchanging the freshly sampled factor. Our TPU adaptation:

  - the block's users (rows of U) and their ratings are sharded over the
    'data' mesh axis (padded CSR, rating-count-balanced by partition.py);
  - U-step: each device samples its local U rows against a REPLICATED V —
    zero communication;
  - V-step: each device computes partial per-item sufficient statistics
    (τ Σ u uᵀ, τ Σ r u) from its local ratings (COO segment-sum), a single
    psum reduces them, and every device samples the SAME V (same key) —
    communication is exactly 2·D·(K²+K)·4 bytes per sweep, independent of
    #ratings: the paper's "limited communication" property, made explicit.

Hyperparameter (NW) sampling similarly reduces O(K²) factor moments.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import bmf as BMF
from repro.core import gibbs as GIBBS
from repro.core import posterior as POST
from repro.core.posterior import NormalWishart, RowGaussians
from repro.data.sparse import PaddedCSR


def make_block_mesh(n_devices: Optional[int] = None) -> Mesh:
    """1-D device mesh with axis 'block' for the PP phase-graph
    ShardedExecutor (core.engine): same-phase blocks are placed on separate
    devices and no collective runs inside a phase — posterior summaries
    cross phase boundaries through the host, which IS the paper's entire
    communication budget. Distinct from the intra-block 'data' mesh built
    by callers of run_gibbs_distributed; the two don't compose (yet)."""
    n = n_devices or len(jax.devices())
    return jax.make_mesh((n,), ("block",))


def stream_devices(block_mesh=None):
    """Ordered device list for the AsyncExecutor's per-device streams.

    The async scheduler composes with the 'block' mesh differently from the
    sharded executor: instead of ONE shard_mapped bucket call spanning the
    mesh, each ready block is dispatched as its own executable onto the
    next device round-robin — every device runs an independent stream and
    the dependency counters (not a batch barrier) decide what lands where.
    Accepts a Mesh (any axis names; devices are taken flattened), an
    explicit device sequence, or None for all local devices."""
    if block_mesh is None:
        return tuple(jax.devices())
    if hasattr(block_mesh, "devices"):        # jax Mesh (devices: np.ndarray)
        return tuple(block_mesh.devices.flat)
    return tuple(block_mesh)


def _pad_rows(arr, mult):
    n = arr.shape[0]
    pad = (-n) % mult
    if pad:
        arr = jnp.concatenate([arr, jnp.zeros((pad,) + arr.shape[1:],
                                              arr.dtype)], 0)
    return arr


def pad_csr_for_mesh(csr: PaddedCSR, n_shards: int) -> PaddedCSR:
    return PaddedCSR(idx=_pad_rows(csr.idx, n_shards),
                     val=_pad_rows(csr.val, n_shards),
                     mask=_pad_rows(csr.mask, n_shards),
                     n_cols=csr.n_cols)


def item_stats_local(U_loc, csr_t_loc: PaddedCSR, tau: float,
                     use_kernel: bool = False):
    """Per-item partial stats from this device's ratings.

    U_loc: (N_loc, K); csr_t_loc: this shard's TRANSPOSED padded CSR —
    rows = items, columns = *local* user slots (built host-side by
    run_gibbs_distributed). Returns (D, K, K), (D, K) partial sums
    (pre-reduction). Reuses bmf.sufficient_stats, i.e. the same
    fused-gather hot path (Pallas kernel / chunked scan) as the U-step —
    a segment_sum formulation would materialize an (nnz, K, K) outer
    product tensor (§Perf H6a).
    """
    return BMF.sufficient_stats(csr_t_loc, U_loc, tau, use_kernel)


def make_distributed_sweep(mesh: Mesh, cfg: BMF.BMFConfig, N: int, D: int,
                           n_shards: int,
                           has_u_prior: bool, has_v_prior: bool,
                           scatter_v: bool = False):
    """Build the shard_mapped one-sweep function.

    scatter_v=False — paper-faithful (ref [16] Fig. 2): psum the full
      (D, K, K) item stats, every device samples the same replicated V.
    scatter_v=True — beyond-paper (§Perf H6): psum_scatter the stats so
      each device reduces only its D/P item rows (half the ring bytes of a
      psum), samples ONLY those rows (V-step Cholesky parallelized too),
      then all_gathers the sampled V (D·K floats — 2/K² of the stats).
      Comm per sweep: D(K²+K)/2 + DK floats vs D(K²+K).
    """
    K = cfg.K
    nw = POST.default_nw(K)
    assert not (scatter_v and D % n_shards), (D, n_shards)

    def sweep(key, U, V, csr_idx, csr_val, csr_mask,
              csrt_idx, csrt_val, csrt_mask,
              u_prior_eta, u_prior_lam, v_prior_eta, v_prior_lam):
        # --- everything here runs per-device on local shards -------------
        csr_loc = PaddedCSR(idx=csr_idx, val=csr_val, mask=csr_mask, n_cols=D)
        # transposed shard: (1, D, M_c) with leading shard dim from shard_map
        csrt_loc = PaddedCSR(idx=csrt_idx[0], val=csrt_val[0],
                             mask=csrt_mask[0], n_cols=csr_idx.shape[0])
        key, kh1, kh2, ku, kv = jax.random.split(key, 5)

        # U hyperprior: needs global U moments -> psum of local moments
        if has_u_prior:
            u_prior = RowGaussians(eta=u_prior_eta, Lambda=u_prior_lam)
        else:
            s1 = jax.lax.psum(U.sum(0), "data")                  # (K,)
            s2 = jax.lax.psum(jnp.einsum("nk,nl->kl", U, U), "data")
            muU, LamU = _sample_nw_from_moments(kh1, s1, s2, N, nw)
            u_prior = POST.broadcast_prior(muU, LamU, U.shape[0])

        # --- U-step: local rows vs replicated V (no communication) -------
        # fold in the shard index: every device must draw DIFFERENT noise
        # for its own U rows (the V-step key below is deliberately shared so
        # all devices sample the identical replicated V).
        ku_dev = jax.random.fold_in(ku, jax.lax.axis_index("data"))
        U = BMF.sample_factor(ku_dev, csr_loc, V, cfg.tau, u_prior,
                              cfg.use_kernel)

        # --- V-step ---------------------------------------------------------
        Lam_part, eta_part = item_stats_local(U, csrt_loc, cfg.tau,
                                              cfg.use_kernel)
        if has_v_prior:
            v_prior = RowGaussians(eta=v_prior_eta, Lambda=v_prior_lam)
        else:
            s1v = V.sum(0)                                        # V replicated
            s2v = jnp.einsum("dk,dl->kl", V, V)
            muV, LamV = _sample_nw_from_moments(kh2, s1v, s2v, D, nw)
            v_prior = POST.broadcast_prior(muV, LamV, D)
        if scatter_v:
            # beyond-paper: reduce-scatter stats to D/P local item rows,
            # sample locally (different noise per shard), gather sampled V
            Lam_loc = jax.lax.psum_scatter(Lam_part, "data", scatter_dimension=0,
                                           tiled=True)   # (D/P, K, K)
            eta_loc = jax.lax.psum_scatter(eta_part, "data", scatter_dimension=0,
                                           tiled=True)   # (D/P, K)
            idx = jax.lax.axis_index("data")
            d_lo = idx * (D // n_shards)
            pr_eta = jax.lax.dynamic_slice_in_dim(v_prior.eta, d_lo,
                                                  D // n_shards, 0)
            pr_lam = jax.lax.dynamic_slice_in_dim(v_prior.Lambda, d_lo,
                                                  D // n_shards, 0)
            cond = RowGaussians(eta=pr_eta + eta_loc, Lambda=pr_lam + Lam_loc)
            kv_dev = jax.random.fold_in(kv, idx)
            V_loc = POST.sample_rows(kv_dev, cond)
            V = jax.lax.all_gather(V_loc, "data", tiled=True)     # (D, K)
        else:
            # paper-faithful: full psum, replicated sampling (same key)
            Lam_items = jax.lax.psum(Lam_part, "data")            # (D, K, K)
            eta_items = jax.lax.psum(eta_part, "data")            # (D, K)
            cond = RowGaussians(eta=v_prior.eta + eta_items,
                                Lambda=v_prior.Lambda + Lam_items)
            V = POST.sample_rows(kv, cond)  # same key everywhere -> same V
        return key, U, V

    in_specs = (P(), P("data", None), P(None, None),
                P("data", None), P("data", None), P("data", None),
                P("data", None, None), P("data", None, None),
                P("data", None, None),
                P("data", None) if has_u_prior else P(None),
                P("data", None, None) if has_u_prior else P(None),
                P(None, None) if has_v_prior else P(None),
                P(None, None, None) if has_v_prior else P(None))
    out_specs = (P(), P("data", None), P(None, None))
    return shard_map(sweep, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


def _sample_nw_from_moments(key, s1, s2, n, nw: NormalWishart):
    """NW posterior sample from psum'd moments (Σx, Σxxᵀ, n)."""
    xbar = s1 / n
    S = s2 - n * jnp.outer(xbar, xbar)
    beta_n = nw.beta0 + n
    nu_n = nw.nu0 + n
    mu_n = (nw.beta0 * nw.mu0 + n * xbar) / beta_n
    d = (xbar - nw.mu0)[:, None]
    Wn_inv = jnp.linalg.inv(nw.W0) + S + (nw.beta0 * n / beta_n) * (d @ d.T)
    Wn = jnp.linalg.inv(Wn_inv)
    return POST.sample_nw(key, NormalWishart(mu0=mu_n, beta0=beta_n,
                                             W0=Wn, nu0=nu_n))


def run_gibbs_distributed(key, csr_rows: PaddedCSR, csr_cols: PaddedCSR,
                          test_rows, test_cols, cfg: BMF.BMFConfig,
                          mesh: Mesh,
                          U_prior: Optional[RowGaussians] = None,
                          V_prior: Optional[RowGaussians] = None,
                          scatter_v: bool = False) -> GIBBS.GibbsResult:
    """Distributed analogue of gibbs.run_gibbs for one (large) block.

    Note: csr_cols is unused in the distributed path (item stats come from
    the row-sharded COO via segment_sum) but kept for signature parity.
    """
    n_shards = mesh.shape["data"]
    N, D, K = csr_rows.n_rows, csr_rows.n_cols, cfg.K
    D_orig = D
    csr = pad_csr_for_mesh(csr_rows, n_shards)
    N_pad = csr.idx.shape[0]
    if scatter_v and D % n_shards:
        # pad item dim so psum_scatter tiles evenly; padded item rows get
        # prior-only stats and their samples are never read back
        pad_d = (-D) % n_shards
        csr = PaddedCSR(idx=csr.idx, val=csr.val, mask=csr.mask,
                        n_cols=D + pad_d)
        if V_prior is not None:
            eye = jnp.broadcast_to(jnp.eye(K), (pad_d, K, K))
            V_prior = RowGaussians(
                eta=jnp.concatenate([V_prior.eta, jnp.zeros((pad_d, K))]),
                Lambda=jnp.concatenate([V_prior.Lambda, eye]))
        D = D + pad_d

    # host-side: per-shard TRANSPOSED padded CSR (items x local users) for
    # the V-step partial stats (§Perf H6a — avoids the (nnz,K,K) segment-sum
    # blow-up of the naive formulation)
    import numpy as np
    from repro.data.sparse import COO, coo_to_padded_csr
    N_loc = N_pad // n_shards
    idx_h = np.asarray(csr.idx)
    val_h = np.asarray(csr.val)
    mask_h = np.asarray(csr.mask)
    rows_h, slots_h = np.nonzero(mask_h > 0)
    cols_h = idx_h[rows_h, slots_h]
    vals_h = val_h[rows_h, slots_h]
    shard_of = rows_h // N_loc
    shard_csrts = []
    m_c = 1
    for s in range(n_shards):
        sel = shard_of == s
        coo_t = COO(row=cols_h[sel].astype(np.int32),
                    col=(rows_h[sel] - s * N_loc).astype(np.int32),
                    val=vals_h[sel].astype(np.float32),
                    n_rows=D, n_cols=N_loc)
        cnt = np.bincount(coo_t.row, minlength=D)
        m_c = max(m_c, int(cnt.max()) if cnt.size else 1)
        shard_csrts.append(coo_t)
    csrt_parts = [coo_to_padded_csr(c, max_nnz=m_c) for c in shard_csrts]
    csrt_idx = jnp.stack([c.idx for c in csrt_parts])     # (S, D, M_c)
    csrt_val = jnp.stack([c.val for c in csrt_parts])
    csrt_mask = jnp.stack([c.mask for c in csrt_parts])

    k0, key = jax.random.split(key)
    U0, V0 = BMF.init_factors(k0, N_pad, D, K)

    has_u = U_prior is not None
    has_v = V_prior is not None
    if has_u:
        U_prior = RowGaussians(eta=_pad_rows(U_prior.eta, n_shards),
                               Lambda=_pad_rows(U_prior.Lambda, n_shards))
        # padded rows get identity precision (harmless, never read back)
        pad = N_pad - N
        if pad:
            U_prior = RowGaussians(
                eta=U_prior.eta,
                Lambda=U_prior.Lambda.at[N:].set(jnp.eye(K)))
    dummy_eta = jnp.zeros((1,), jnp.float32)

    sweep = make_distributed_sweep(mesh, cfg, N_pad, D, n_shards, has_u, has_v,
                                   scatter_v=scatter_v)
    sweep = jax.jit(sweep)

    acc = GIBBS.GibbsAccumulators(
        pred_sum=jnp.zeros_like(test_rows, dtype=jnp.float32),
        pred_cnt=jnp.zeros((), jnp.float32),
        U_sum=jnp.zeros((N_pad, K)), U_outer=jnp.zeros((N_pad, K, K)),
        V_sum=jnp.zeros((D, K)), V_outer=jnp.zeros((D, K, K)))

    U, V = U0, V0
    predict_j = jax.jit(BMF.predict)
    for it in range(cfg.n_samples):
        key, U, V = sweep(
            key, U, V, csr.idx, csr.val, csr.mask,
            csrt_idx, csrt_val, csrt_mask,
            U_prior.eta if has_u else dummy_eta,
            U_prior.Lambda if has_u else dummy_eta,
            V_prior.eta if has_v else dummy_eta,
            V_prior.Lambda if has_v else dummy_eta)
        if it >= cfg.burnin:
            pred = predict_j(U, V, test_rows, test_cols)
            acc = GIBBS.GibbsAccumulators(
                pred_sum=acc.pred_sum + pred,
                pred_cnt=acc.pred_cnt + 1.0,
                U_sum=acc.U_sum + U,
                U_outer=acc.U_outer + jnp.einsum("nk,nl->nkl", U, U),
                V_sum=acc.V_sum + V,
                V_outer=acc.V_outer + jnp.einsum("dk,dl->dkl", V, V))

    cnt = jnp.maximum(acc.pred_cnt, 1.0)
    U_post = GIBBS._summarize(acc.U_sum[:N], acc.U_outer[:N], cnt)
    V_post = GIBBS._summarize(acc.V_sum[:D_orig], acc.V_outer[:D_orig], cnt)
    # trim padding
    acc = acc._replace(U_sum=acc.U_sum[:N], U_outer=acc.U_outer[:N],
                       V_sum=acc.V_sum[:D_orig], V_outer=acc.V_outer[:D_orig])
    return GIBBS.GibbsResult(U=U[:N], V=V[:D_orig], acc=acc, U_post=U_post,
                             V_post=V_post)


def sweep_comm_bytes(D: int, K: int) -> int:
    """The paper's 'limited communication': bytes reduced per Gibbs sweep."""
    return 4 * (D * (K * K + K) + 2 * (K * K + K))


def sweep_comm_bytes_scatter(D: int, K: int) -> int:
    """Beyond-paper scatter-V variant (§Perf H6): a ring reduce-scatter
    moves half the bytes of a ring all-reduce, plus the tiny sampled-V
    gather."""
    return 4 * (D * (K * K + K) // 2 + D * K + 2 * (K * K + K))
