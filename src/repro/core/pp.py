"""Posterior Propagation (PP) for BMF — the paper's algorithmic contribution.

Three phases over an I×J block grid (paper §2.2, Fig. 1):
  (a)   block (0,0): vanilla BMF with NW hyperpriors.
  (b)   first block-column (i,0) and block-row (0,j), in parallel: the
        shared factor's prior is the phase-(a) posterior (per-row
        Gaussians); the new factor keeps the NW hyperprior.
  (c)   remaining blocks (i,j), in parallel: both factors receive
        propagated phase-(b) posteriors as priors.

Communication happens ONLY at the two phase boundaries: what moves between
blocks is O((N/I + D/J)·K²) posterior summaries — never ratings, never
samples. Within a phase, blocks are embarrassingly parallel. Orchestration
lives in core.engine (the phase-graph engine): ``run_pp`` is a thin wrapper
that picks an Executor — serial reference loop, stacked (one vmapped Gibbs
call per phase shape bucket), or sharded (same-phase blocks concurrently on
a 'block' device mesh). Each block's Gibbs loop can also be internally
sharded via core.distributed (serial executor only).

Aggregation (paper §2.2 last ¶, following Qin et al. 2019): per factor row,
the final posterior multiplies the per-block posteriors (natural-parameter
sums) and divides away the (J-1 or I-1) multiply-counted propagated priors.

Prediction: each test entry falls in exactly one block; the predictive mean
is that block's posterior-mean product (accumulated over its Gibbs samples).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bmf as BMF
from repro.core import gibbs as GIBBS
from repro.core.partition import Block, Partition
from repro.core.posterior import RowGaussians
from repro.data.sparse import COO, PaddedCSR, coo_to_padded_csr


@dataclass
class PPResult:
    rmse: float
    U_agg: RowGaussians              # aggregated posterior (permuted space)
    V_agg: RowGaussians
    per_block_rmse: np.ndarray       # (I, J)
    wall_time_s: float
    phase_times_s: Dict[str, float]
    n_test: int
    block_times_s: Dict[Tuple[int, int], float] = field(default_factory=dict)
    executor: str = "serial"         # engine executor that produced this run
    # dispatch→resolve spans per block, seconds relative to run start.
    # Recorded by overlapped executors (async); empty for barrier executors,
    # whose block_times_s are true per-block seconds (serial) or even bucket
    # splits (stacked/sharded — prefer phase_times_s there).
    block_spans_s: Dict[Tuple[int, int], Tuple[float, float]] = \
        field(default_factory=dict)
    # fault-tolerance ledger (engine.FaultRecord entries): every health-
    # guard trip, watchdog timeout, dispatch failure — and what the engine
    # did about it (retried / degraded / raised). Empty on a clean run;
    # "degrade" outcomes are ONLY trustworthy together with this record,
    # which is why it rides on the result instead of a log line.
    faults: list = field(default_factory=list)
    # blocks restored from a resume_from checkpoint (not re-run)
    resumed_blocks: int = 0
    # elastic group-fault-domain counters from the executor (engine
    # events: quarantine / steal / speculate / cancel). All-zero for
    # barrier executors and single-group async/streaming runs.
    group_stats: Dict[str, int] = field(default_factory=dict)
    # serving-export seam (repro.serving.PosteriorStore.from_pp_result):
    # U_agg/V_agg live in PERMUTED row/col space, so the result carries the
    # original->permuted maps plus the chain config the serve-time fold-in
    # conditional needs (rating precision tau, latent dim K). A PPResult is
    # thereby a self-contained servable artifact — no Partition or
    # BMFConfig needed at store-build time.
    row_perm: Optional[np.ndarray] = None
    col_perm: Optional[np.ndarray] = None
    tau: Optional[float] = None
    K: Optional[int] = None

    @property
    def n_retries(self) -> int:
        return sum(1 for f in self.faults if f.action == "retried")

    def _dep_graph(self):
        """Canonical PP dependency structure for this run's grid."""
        I, J = self.per_block_rmse.shape
        deps = {(0, 0): ()}
        deps.update({(i, 0): ((0, 0),) for i in range(1, I)})
        deps.update({(0, j): ((0, 0),) for j in range(1, J)})
        deps.update({(i, j): ((i, 0), (0, j))
                     for i in range(1, I) for j in range(1, J)})
        return deps

    def modeled_parallel_s(self, workers: int) -> float:
        """Wall-clock under the paper's deployment: a dependency-aware list
        schedule of the measured per-block times over ``workers`` — a block
        starts when its row/col prior sources are done AND a worker frees
        up, NOT at a phase barrier (overlapped execution is the point of
        the async executor, and the model matches it).

        block_times_s are true per-block seconds under serial, measured
        dispatch→resolve spans under async; under stacked/sharded they're
        even bucket splits, so prefer the MEASURED phase wall-clock in
        ``phase_times_s`` there."""
        import heapq
        deps = self._dep_graph()
        succ: Dict[Tuple[int, int], list] = {c: [] for c in deps}
        for c, ds in deps.items():
            for d in ds:
                succ[d].append(c)
        dur = {c: self.block_times_s.get(c, 0.0) for c in deps}
        free = [0.0] * max(int(workers), 1)
        heapq.heapify(free)
        ready = [(0.0, (0, 0))]
        finish: Dict[Tuple[int, int], float] = {}
        while ready:
            ready_t, c = heapq.heappop(ready)
            start = max(heapq.heappop(free), ready_t)
            finish[c] = start + dur[c]
            heapq.heappush(free, finish[c])
            for s in succ[c]:
                if all(d in finish for d in deps[s]):
                    heapq.heappush(ready, (max(finish[d] for d in deps[s]), s))
        return max(finish.values(), default=0.0)

    def critical_path_s(self) -> float:
        """Length of the longest dependency chain through the measured
        per-block times — the wall-clock floor under unbounded workers
        (what the async executor approaches as barrier stalls vanish)."""
        deps = self._dep_graph()
        memo: Dict[Tuple[int, int], float] = {}

        def cp(c):
            if c not in memo:
                memo[c] = (self.block_times_s.get(c, 0.0)
                           + max((cp(d) for d in deps[c]), default=0.0))
            return memo[c]

        return max((cp(c) for c in deps), default=0.0)


def _slice_prior(prior: RowGaussians, ids: np.ndarray) -> RowGaussians:
    return RowGaussians(eta=prior.eta[ids], Lambda=prior.Lambda[ids])


def _block_test(test: COO, block: Block) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Test entries falling inside a block, in local coordinates."""
    sub = test.submatrix(block.row_ids, block.col_ids)
    return sub.row, sub.col, sub.val


@dataclass
class BlockShapes:
    """Common bucketed shapes so one jitted executable serves every block
    of a bucket (per-block shapes would trigger a recompile each).

    Buckets are PER PHASE (``per_phase``): phase-a / b_row / b_col / c
    blocks have systematically different occupancy (phase a sees one dense
    corner block; phase-c blocks are the sparse interior), so one global
    max-shape bucket pads every interior block to the corner block's
    worst-case nnz/row.  Per-phase buckets trade ≤4 compilations for much
    tighter padding — and tighter padding is compute, not just memory,
    since the Gibbs einsum/kernel work scales with the padded M."""
    n_rows: int
    n_cols: int
    m_rows: int       # max nnz per user row
    m_cols: int       # max nnz per item row
    n_test: int

    def astuple(self) -> Tuple[int, int, int, int, int]:
        return (self.n_rows, self.n_cols, self.m_rows, self.m_cols,
                self.n_test)

    def block_bytes(self, K: int) -> int:
        """Device bytes ONE block occupies at this bucket's padding: CSR
        planes in both orientations (idx/val/mask), the four test vectors
        (row/col indices, values, mask), both propagated priors (eta +
        Lambda), and the U0/V0
        factor initializations — i.e. what a stacked executor multiplies
        by its batch size, and what the streaming executor multiplies by
        its window."""
        csr = 3 * 4 * (self.n_rows * self.m_rows + self.n_cols * self.m_cols)
        tst = 4 * 4 * self.n_test        # tr, tc, tv, tmask
        priors = 4 * (self.n_rows + self.n_cols) * (K + K * K)
        factors = 4 * (self.n_rows + self.n_cols) * K
        return csr + tst + priors + factors

    @staticmethod
    def coalesce(per_phase: Dict[str, "BlockShapes"], K: int,
                 max_waste: float = 1.5) -> Dict[str, "BlockShapes"]:
        """Bucket-coalescing for the streaming window: merge per-phase
        buckets whose padded footprints are within ``max_waste`` of each
        other (``partition.coalesce_shapes``), so one window shape — and
        therefore ONE window executable and one recycled buffer pool —
        serves blocks of several phase tags. Tags that coalesce share one
        ``BlockShapes`` instance (identity marks the group)."""
        from repro.core.partition import coalesce_shapes
        merged = coalesce_shapes(
            {tag: s.astuple() for tag, s in per_phase.items()},
            footprint=lambda t: BlockShapes(*t).block_bytes(K),
            max_waste=max_waste)
        uniq: Dict[Tuple[int, ...], BlockShapes] = {}
        return {tag: uniq.setdefault(t, BlockShapes(*t))
                for tag, t in merged.items()}

    @staticmethod
    def of(part: Partition, test: Optional[COO],
           phases: Optional[Tuple[str, ...]] = None) -> "BlockShapes":
        """Max shapes over the partition's blocks (optionally restricted to
        the given ``Block.phase`` tags)."""
        def row_m(c: COO, n):
            return int(np.bincount(c.row, minlength=n).max()) if c.nnz else 1
        n_rows = m_r = m_c = n_cols = n_test = 1
        for b in part.all_blocks():
            if phases is not None and b.phase not in phases:
                continue
            n_rows = max(n_rows, len(b.row_ids))
            n_cols = max(n_cols, len(b.col_ids))
            m_r = max(m_r, row_m(b.coo, len(b.row_ids)))
            m_c = max(m_c, row_m(b.coo.transpose(), len(b.col_ids)))
            if test is not None:
                sub = test.submatrix(b.row_ids, b.col_ids)
                n_test = max(n_test, sub.nnz)
        return BlockShapes(n_rows=n_rows, n_cols=n_cols, m_rows=m_r,
                           m_cols=m_c, n_test=n_test)

    @staticmethod
    def per_phase(part: Partition, test: Optional[COO]
                  ) -> Dict[str, "BlockShapes"]:
        """One occupancy bucket per phase tag present in the partition."""
        tags = {b.phase for b in part.all_blocks()}
        return {ph: BlockShapes.of(part, test, phases=(ph,)) for ph in tags}


def _pad_prior(prior: Optional[RowGaussians], n: int, K: int):
    if prior is None:
        return None
    pad = n - prior.eta.shape[0]
    if pad <= 0:
        return prior
    eta = jnp.concatenate([prior.eta, jnp.zeros((pad, K))])
    eye = jnp.broadcast_to(jnp.eye(K), (pad, K, K))
    Lam = jnp.concatenate([prior.Lambda, eye])
    return RowGaussians(eta=eta, Lambda=Lam)


def pad_block_inputs_host(block: Block, shapes: BlockShapes,
                          test: Optional[COO], poison_nan: bool = False):
    """Host-side (numpy) padding of one block's CSR planes and test
    entries to a shape bucket — the transferable part of
    ``pad_block_inputs``, kept in numpy so the streaming executor can
    assemble a whole window chunk on the host and ship it with ONE async
    ``device_put`` (the double-buffered prefetch H2D transfer) while the
    previous chunk is still computing. Priors are NOT built here: they are
    device-resident outputs of earlier blocks.

    Returns ``(csr_rows, csr_cols, tr, tc, tv, tmask)`` with numpy leaves.
    """
    csr_rows = coo_to_padded_csr(block.coo, max_nnz=shapes.m_rows,
                                 n_rows_pad=shapes.n_rows,
                                 n_cols_pad=shapes.n_cols, as_numpy=True)
    csr_cols = coo_to_padded_csr(block.coo.transpose(),
                                 max_nnz=shapes.m_cols,
                                 n_rows_pad=shapes.n_cols,
                                 n_cols_pad=shapes.n_rows, as_numpy=True)
    if poison_nan:
        # deterministic fault-injection seam (engine.FaultPlan): NaN-fill
        # the rating planes so the chain's very first sufficient-stats
        # einsum goes non-finite and the in-chain health guard trips — the
        # same failure surface as a real diverged/NaN'd chain, via the one
        # padding path every executor shares.
        csr_rows = PaddedCSR(idx=csr_rows.idx,
                             val=np.full_like(csr_rows.val, np.nan),
                             mask=csr_rows.mask, n_cols=csr_rows.n_cols)
        csr_cols = PaddedCSR(idx=csr_cols.idx,
                             val=np.full_like(csr_cols.val, np.nan),
                             mask=csr_cols.mask, n_cols=csr_cols.n_cols)
    if test is not None:
        tr, tc, tv_raw = _block_test(test, block)
    else:
        tr = np.zeros((0,), np.int32)
        tc = np.zeros((0,), np.int32)
        tv_raw = np.zeros((0,), np.float32)
    n = min(len(tr), shapes.n_test)

    def padded(arr, dtype):
        out = np.zeros((shapes.n_test,), dtype)
        out[:n] = arr[:n]
        return out

    tv = padded(tv_raw.astype(np.float32), np.float32)
    tmask = np.zeros((shapes.n_test,), np.float32)
    tmask[:n] = 1.0
    return (csr_rows, csr_cols, padded(tr, np.int32), padded(tc, np.int32),
            tv, tmask)


def pad_block_inputs(block: Block, shapes: BlockShapes, K: int,
                     test: Optional[COO],
                     U_prior: Optional[RowGaussians],
                     V_prior: Optional[RowGaussians],
                     poison_nan: bool = False):
    """Pad one block's CSR planes, priors, and test entries to its phase
    shape bucket — the single source of truth for bucketed padding.
    ``run_block`` (serial executor), ``engine._task_leaves`` (stacked/
    sharded executors), ``engine.AsyncExecutor._dispatch``, and the
    streaming executor's chunk assembly (via ``pad_block_inputs_host``)
    all go through the same numpy fill; the executors' chain-identical
    parity depends on them never diverging.

    Returns ``(csr_rows, csr_cols, tr, tc, tv, tmask, U_prior, V_prior)``:
    padded test indices, VALUES, and a validity mask over the bucket's
    n_test slots (one submatrix scan serves all three) — tv/tmask let the
    engine compute each block's squared error as a tiny on-device scalar
    instead of pulling the (n_test,) prediction vector to the host."""
    csr_rows_h, csr_cols_h, tr, tc, tv, tmask = pad_block_inputs_host(
        block, shapes, test, poison_nan=poison_nan)
    csr_rows = PaddedCSR(idx=jnp.asarray(csr_rows_h.idx),
                         val=jnp.asarray(csr_rows_h.val),
                         mask=jnp.asarray(csr_rows_h.mask),
                         n_cols=csr_rows_h.n_cols)
    csr_cols = PaddedCSR(idx=jnp.asarray(csr_cols_h.idx),
                         val=jnp.asarray(csr_cols_h.val),
                         mask=jnp.asarray(csr_cols_h.mask),
                         n_cols=csr_cols_h.n_cols)
    U_prior = _pad_prior(U_prior, shapes.n_rows, K)
    V_prior = _pad_prior(V_prior, shapes.n_cols, K)
    return (csr_rows, csr_cols, tr, tc, tv, tmask, U_prior, V_prior)


def run_block(key, block: Block, cfg: BMF.BMFConfig,
              test: Optional[COO],
              U_prior: Optional[RowGaussians],
              V_prior: Optional[RowGaussians],
              distributed_mesh=None,
              shapes: Optional[BlockShapes] = None,
              poison_nan: bool = False) -> GIBBS.GibbsResult:
    """Gibbs on one block (optionally internally distributed)."""
    if shapes is None:
        csr_rows = coo_to_padded_csr(block.coo)
        csr_cols = coo_to_padded_csr(block.coo.transpose())
        if poison_nan:
            csr_rows = PaddedCSR(idx=csr_rows.idx,
                                 val=jnp.full_like(csr_rows.val, jnp.nan),
                                 mask=csr_rows.mask, n_cols=csr_rows.n_cols)
            csr_cols = PaddedCSR(idx=csr_cols.idx,
                                 val=jnp.full_like(csr_cols.val, jnp.nan),
                                 mask=csr_cols.mask, n_cols=csr_cols.n_cols)
        if test is not None:
            tr, tc, _ = _block_test(test, block)
        else:
            tr = np.zeros((1,), np.int32)
            tc = np.zeros((1,), np.int32)
    else:
        csr_rows, csr_cols, tr, tc, _, _, U_prior, V_prior = \
            pad_block_inputs(block, shapes, cfg.K, test, U_prior, V_prior,
                             poison_nan=poison_nan)
    if distributed_mesh is not None:
        from repro.core import distributed as DIST
        return DIST.run_gibbs_distributed(
            key, csr_rows, csr_cols, jnp.asarray(tr), jnp.asarray(tc), cfg,
            distributed_mesh, U_prior=U_prior, V_prior=V_prior)
    return GIBBS.run_gibbs(key, csr_rows, csr_cols,
                           jnp.asarray(tr), jnp.asarray(tc), cfg,
                           U_prior=U_prior, V_prior=V_prior)


def run_pp(key, part: Partition, cfg: BMF.BMFConfig, test: COO,
           distributed_mesh=None, verbose: bool = False,
           executor="serial", block_mesh=None,
           window: Optional[int] = None, topology=None,
           on_fault: str = "raise", max_retries: int = 2,
           fault_policy=None, fault_plan=None,
           checkpoint_dir=None, ckpt_every: int = 1,
           resume_from=None) -> PPResult:
    """Full three-phase Posterior Propagation over the partition.

    Thin wrapper over the phase-graph engine (core.engine): the run is an
    explicit three-phase DAG of BlockTasks executed by a pluggable Executor.

    executor: "serial" (reference: per-block jitted calls, today's exact
      semantics), "stacked" (one vmapped Gibbs call per phase shape bucket),
      "sharded" (the stacked batch shard_map'd over a 'block' device mesh so
      same-phase blocks run concurrently on separate devices), "async"
      (dependency-driven overlap: readiness counters dispatch each block the
      moment its propagated priors resolve — phase b and c overlap, buffers
      are donated, posteriors stay device-resident), "streaming" (bounded
      window of donated block buffers streamed through the same ready
      queue — for grids whose stacked buckets don't fit device memory), or
      an ``engine.Executor`` instance.
    topology: unified 2-D device placement (core.topology.Topology or an
      ``(block, data)`` pair): 'block' counts device groups running blocks
      concurrently, 'data' counts devices INSIDE each block's Gibbs chain
      (the intra-block distributed sweep of core.distributed). E.g.
      ``run_pp(..., executor="sharded", topology=Topology(block=2, data=2))``
      on 4 devices runs 2 blocks at a time, each chain sharded 2-way —
      the paper's combined system. Consumed by serial (block must be 1),
      sharded, async (group streams), and streaming (per-group windows).
    distributed_mesh: legacy spelling of ``topology=Topology(1, S)`` —
      intra-block sharding only, forces the serial executor; ``block_mesh``
      is the legacy 1-D inter-block mesh for executor="sharded".
    window: streaming executor's window size W (blocks per chunk); ignored
      by the other executors.
    verbose: per-phase progress lines (block count, shape buckets, wall time).

    Fault tolerance (core/README.md "Fault tolerance"):
    on_fault: what to do with a block whose chain stays unhealthy after
      ``max_retries`` re-runs — "raise" (engine.BlockFaultError) or
      "degrade" (the block's posterior := its propagated prior, so it
      cancels exactly in the divide-away aggregation; its test entries are
      dropped from the RMSE; recorded in ``PPResult.faults``).
    max_retries: bounded re-runs of an unhealthy block, each with a
      ``fold_in``-resplit PRNG key and a jitter-inflated prior.
    fault_policy: a full ``engine.FaultPolicy`` (watchdog deadlines, RMSE
      divergence threshold, retry jitter); overrides on_fault/max_retries.
    fault_plan: deterministic test-only fault injection
      (``engine.FaultPlan``): NaN'd chains / hung dispatches / failed
      dispatches by coord and attempt.
    checkpoint_dir: persist each resolved block's posteriors through
      ``checkpoint.ckpt.PPCheckpoint`` (every ``ckpt_every`` resolves).
    resume_from: a checkpoint directory from an earlier (interrupted) run
      with the same key/grid/K/topology: resolved blocks are restored, the
      readiness counters rebuilt, and the finished run is bitwise
      identical to an uninterrupted one.
    """
    from repro.core import engine as ENG
    if int(max_retries) < 0:
        raise ValueError(f"max_retries must be >= 0, got {max_retries}")
    if on_fault not in ("raise", "degrade"):
        raise ValueError(f"on_fault must be 'raise' or 'degrade', "
                         f"got {on_fault!r}")
    if int(ckpt_every) < 1:
        raise ValueError(f"ckpt_every must be >= 1, got {ckpt_every}")
    if fault_policy is None:
        fault_policy = ENG.FaultPolicy(on_fault=on_fault,
                                       max_retries=int(max_retries))
    ex = ENG.make_executor(executor, distributed_mesh=distributed_mesh,
                           block_mesh=block_mesh, window=window,
                           topology=topology)
    return ENG.run_phase_graph(key, part, cfg, test, ex, verbose=verbose,
                               policy=fault_policy, fault_plan=fault_plan,
                               checkpoint_dir=checkpoint_dir,
                               ckpt_every=int(ckpt_every),
                               resume_from=resume_from)


@partial(jax.jit, static_argnames=("axis",))
def _aggregate_axis_jit(posts, axis: str) -> RowGaussians:
    """Jitted divide-away reduction over a (I, J) nested tuple of
    device-resident RowGaussians — ONE executable, no host round-trip:
    the engine keeps posterior summaries on device between phases and this
    is the only consumer, so natural-parameter sums, prior subtraction, and
    the final concatenation all stay on device."""
    I, J = len(posts), len(posts[0])
    out_eta, out_lam = [], []
    if axis == "row":
        for i in range(I):
            eta_stack = jnp.stack([posts[i][j].eta for j in range(J)])
            lam_stack = jnp.stack([posts[i][j].Lambda for j in range(J)])
            prior = posts[i][0]          # the propagated one for this row grp
            out_eta.append(eta_stack.sum(0) - (J - 1) * prior.eta)
            out_lam.append(lam_stack.sum(0) - (J - 1) * prior.Lambda)
    else:
        for j in range(J):
            eta_stack = jnp.stack([posts[i][j].eta for i in range(I)])
            lam_stack = jnp.stack([posts[i][j].Lambda for i in range(I)])
            prior = posts[0][j]
            out_eta.append(eta_stack.sum(0) - (I - 1) * prior.eta)
            out_lam.append(lam_stack.sum(0) - (I - 1) * prior.Lambda)
    return RowGaussians(eta=jnp.concatenate(out_eta),
                        Lambda=jnp.concatenate(out_lam))


def _aggregate_axis(part: Partition, posts, axis: str) -> RowGaussians:
    """Combine per-block posteriors for one factor.

    For U row-group i: posterior from blocks (i, 0..J-1); blocks 1..J-1 in
    that row all received the same propagated prior (the phase-b posterior
    of U^(i) — or phase-a for i=0), counted J times in the product, so J-1
    copies are divided away (Qin et al. 2019, eq. 5).

    Operates on stacked leaves: blocks of a row (col) group share their row
    (col) ids, so the J (I) per-block posteriors stack along a leading axis
    and the natural-parameter sum is one reduction instead of a Python
    chain of adds — and the whole reduction is one jitted executable
    (``_aggregate_axis_jit``) so posteriors never visit the host.
    """
    assert len(posts) == part.I and len(posts[0]) == part.J
    return _aggregate_axis_jit(tuple(tuple(row) for row in posts), axis)


def run_full_bmf(key, train: COO, test: COO, cfg: BMF.BMFConfig):
    """1×1 'partition' — the vanilla BMF baseline (paper Table 3 column BMF)."""
    csr_rows = coo_to_padded_csr(train)
    csr_cols = coo_to_padded_csr(train.transpose())
    t0 = time.time()
    res = GIBBS.run_gibbs(key, csr_rows, csr_cols,
                          jnp.asarray(test.row), jnp.asarray(test.col), cfg)
    rmse = float(GIBBS.rmse_from_acc(res.acc, jnp.asarray(test.val)))
    return rmse, time.time() - t0, res
