"""Unified 2-D device topology for Posterior Propagation.

The paper's headline system composes TWO levels of parallelism:

  * block level — same-phase PP blocks run concurrently (phase-graph
    executors, zero collectives inside a phase);
  * intra-block level — each block's Gibbs chain is itself distributed
    over several workers (ref [16]/[17]: rows of U sharded, item stats
    reduced or factors exchanged each sweep).

Historically each executor owned its own ad-hoc device logic — a 1-D
'block' mesh (sharded), a flat round-robin device list (async), the
default device (streaming) — and only the serial executor could compose
with an intra-block 'data' mesh.  ``Topology`` replaces all of that with
ONE placement object: a single 2-D ``('block', 'data')`` mesh whose
major axis counts *device groups* (block-level parallelism) and whose
minor axis counts *devices per group* (intra-block parallelism).

    Topology(block=2, data=2)      # 4 devices: 2 groups of 2
      group 0: devices[0:2]  — runs blocks, each chain sharded 2-way
      group 1: devices[2:4]

Every executor consumes the same object:

  * ``ShardedExecutor``   shard_maps the stacked bucket batch over the
    'block' axis while each block's chain runs the intra-block
    distributed sweep over the 'data' axis
    (``distributed.run_gibbs_stacked_2d``);
  * ``AsyncExecutor``     round-robins ready blocks over ``groups()``
    instead of single devices — a dispatch lands on a whole group and
    the chain is 'data'-sharded inside it;
  * ``StreamingExecutor`` keeps one W-bounded donated window per group
    (per-stream prefetch), dispatching each chunk onto its group;
  * ``SerialExecutor``    uses ``data_mesh()`` as its intra-block mesh
    (the historical ``distributed_mesh``), requiring ``block == 1``.

Multi-host block placement then becomes a config change — a Topology
over ``jax.devices()`` spanning hosts — rather than a new executor.

Mesh axis names are the repo-wide contract: 'block' collectives are
forbidden (phase boundaries go through the posterior store), 'data'
collectives are the intra-block sweep's limited communication
(``launch.bmf_dryrun --pp-engine`` lowers the composed executable and
asserts exactly that split from the HLO replica groups).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

import numpy as np

import jax
from jax.sharding import Mesh

BLOCK_AXIS = "block"
DATA_AXIS = "data"


@dataclass(frozen=True)
class Topology:
    """Placement of the PP run on ``block × data`` devices.

    block:   device groups — how many blocks run concurrently.
    data:    devices per group — how many shards inside one block's chain.
    devices: explicit device sequence (length block*data, grouped
             row-major: group g = devices[g*data:(g+1)*data]); defaults
             to the first ``block * data`` local devices.
    """
    block: int = 1
    data: int = 1
    devices: Optional[Tuple] = None

    def __post_init__(self):
        if self.block < 1 or self.data < 1:
            raise ValueError(f"topology axes must be >= 1, got "
                             f"block={self.block} data={self.data}")
        devs = (tuple(self.devices) if self.devices is not None
                else tuple(jax.devices()[: self.block * self.data]))
        if len(devs) != self.block * self.data:
            raise ValueError(
                f"topology {self.block}x{self.data} needs "
                f"{self.block * self.data} devices, got {len(devs)}")
        object.__setattr__(self, "devices", devs)

    # -- constructors -------------------------------------------------------

    @staticmethod
    def default(data: int = 1) -> "Topology":
        """All local devices, ``data`` per group (block = n_devices/data)."""
        n = len(jax.devices())
        if n % data:
            raise ValueError(f"{n} devices not divisible by data={data}")
        return Topology(block=n // data, data=data)

    @staticmethod
    def from_spec(spec) -> "Topology":
        """Coerce run_pp-style specs: a Topology, None (all devices,
        data=1), an ``(block, data)`` pair, an explicit device sequence
        (one single-device group per device — the legacy per-device
        stream spelling), or a 1-D 'block' Mesh (legacy
        ``block_mesh=``)."""
        if spec is None:
            return Topology.default()
        if isinstance(spec, Topology):
            return spec
        if (isinstance(spec, (list, tuple)) and spec
                and not all(isinstance(x, (int, np.integer)) for x in spec)):
            devs = tuple(spec)
            return Topology(block=len(devs), data=1, devices=devs)
        if isinstance(spec, Mesh):
            names = tuple(spec.axis_names)
            devs = tuple(spec.devices.flat)
            if names == (BLOCK_AXIS,):
                return Topology(block=len(devs), data=1, devices=devs)
            if names == (DATA_AXIS,):
                return Topology(block=1, data=len(devs), devices=devs)
            if names == (BLOCK_AXIS, DATA_AXIS):
                b, d = spec.devices.shape
                return Topology(block=b, data=d, devices=devs)
            raise ValueError(f"mesh axes {names} are not a PP topology "
                             f"(expected ('block',), ('data',) or "
                             f"('block','data'))")
        b, d = spec
        return Topology(block=int(b), data=int(d))

    # -- derived meshes -----------------------------------------------------

    @property
    def n_devices(self) -> int:
        return self.block * self.data

    @property
    def mesh(self) -> Mesh:
        """The full 2-D ('block', 'data') mesh."""
        grid = np.asarray(self.devices, dtype=object).reshape(
            self.block, self.data)
        return Mesh(grid, (BLOCK_AXIS, DATA_AXIS))

    def block_mesh(self) -> Mesh:
        """1-D 'block' mesh over group leads — the legacy inter-block mesh
        (``distributed.make_block_mesh``); only meaningful at data == 1."""
        if self.data != 1:
            raise ValueError(
                f"block_mesh() is the data==1 degenerate form; this "
                f"topology has data={self.data} (use .mesh)")
        return Mesh(np.asarray(self.devices, dtype=object), (BLOCK_AXIS,))

    def group(self, g: int) -> Tuple:
        """Devices of group ``g`` (one intra-block 'data' stream)."""
        return self.devices[g * self.data:(g + 1) * self.data]

    def groups(self) -> Tuple[Tuple, ...]:
        """All device groups, in block-axis order."""
        return tuple(self.group(g) for g in range(self.block))

    def data_mesh(self, g: int = 0) -> Mesh:
        """1-D 'data' mesh over group ``g`` — the intra-block mesh one
        block's distributed Gibbs chain shard_maps over (what
        ``run_pp(distributed_mesh=...)`` historically took)."""
        return Mesh(np.asarray(self.group(g), dtype=object), (DATA_AXIS,))

    def group_mesh_2d(self, g: int = 0) -> Mesh:
        """(1, data) submesh of group ``g`` with BOTH axis names — lets the
        stacked 2-D chain executable serve single-group dispatches (async
        groups, streaming windows) unchanged."""
        grid = np.asarray(self.group(g), dtype=object).reshape(1, self.data)
        return Mesh(grid, (BLOCK_AXIS, DATA_AXIS))

    def without_groups(self, dead) -> "Topology":
        """The surviving sub-topology after dropping device groups
        ``dead`` (e.g. ``TopologyDegradedError.dead_groups``) — same
        ``data`` width, the remaining groups in canonical order. Block
        posteriors are placement-independent, so a run checkpointed
        before the degradation resumes bitwise-identically on the
        survivor topology."""
        dead = {int(g) for g in dead}
        bad = dead - set(range(self.block))
        if bad:
            raise ValueError(f"unknown group(s) {sorted(bad)} "
                             f"(topology has {self.block} group(s))")
        alive = [g for g in range(self.block) if g not in dead]
        if not alive:
            raise ValueError("cannot drop every device group")
        devs = tuple(d for g in alive for d in self.group(g))
        return Topology(block=len(alive), data=self.data, devices=devs)

    def describe(self) -> str:
        return (f"topology {self.block}x{self.data} "
                f"({self.block} group(s) x {self.data} device(s))")
