"""Phase-graph execution engine for Posterior Propagation.

The paper's §2.2 structure is a three-phase DAG over the I×J block grid:
phase (a) is block (0,0); phase (b) is the first block-row and block-column,
depending only on (a); phase (c) is the interior, depending only on (b).
Within a phase, blocks are embarrassingly parallel — O((N/I + D/J)·K²)
posterior summaries cross phase boundaries, nothing else does.

This module makes the graph explicit (``BlockTask`` / ``build_phase_graph``)
and executes it through a pluggable ``Executor``:

  SerialExecutor   reference semantics: one jitted Gibbs call per block with
                   a host sync after each — what ``run_pp`` always did.
                   Composes with an intra-block ``distributed_mesh`` /
                   ``Topology(block=1, data=S)``.
  StackedExecutor  stacks all blocks of a phase shape bucket along a leading
                   axis and runs ONE jitted vmapped chain per bucket
                   (``gibbs.run_gibbs_stacked``) — the per-block Python
                   dispatch and per-block host syncs disappear.
                   ``BlockShapes.per_phase`` is what makes stacking legal:
                   every block of a bucket is padded to identical shapes.
  ShardedExecutor  the stacked batch additionally shard_map'd over a 1-D
                   'block' device mesh: same-phase blocks genuinely run
                   concurrently on separate devices with NO collectives
                   inside a phase — the paper's deployment model, on-device.
  AsyncExecutor    dependency-driven overlap: readiness counters over
                   ``BlockTask.deps`` dispatch each block's jitted chain the
                   moment its row/col prior posteriors resolve, riding JAX
                   async dispatch — phase-c blocks whose phase-b sources
                   finished early start while the rest of phase b is still
                   running. No ``block_until_ready`` until the final
                   aggregation; completion is detected by non-blocking
                   ``is_ready()`` polls on tiny per-block squared-error
                   scalars. Posterior summaries stay device-resident
                   between phases, padded input buffers are donated to XLA
                   (``gibbs.run_gibbs(donate=True)``), and with >1 local
                   device each dispatch lands on the next topology GROUP
                   round-robin: per-group streams instead of one sharded
                   bucket (groups of 1 device = the legacy per-device
                   streams; groups of >1 run each chain 'data'-sharded).
  StreamingExecutor the same ready queue, but blocks stream through a
                   bounded window of W donated block buffers: host-side
                   chunk assembly + double-buffered ``device_put``
                   prefetch, ``run_gibbs_stacked(donate=True)`` recycling,
                   live peak ≤ W×(depth+1)×block_bytes per stream — flat
                   in the grid size, for grids whose stacked buckets
                   exceed HBM. With a multi-group ``Topology`` it keeps
                   ONE such window per device group (per-stream prefetch).

Device placement is unified behind ``core.topology.Topology`` — a single
2-D ('block', 'data') mesh whose groups run blocks concurrently while the
'data' axis shards each block's Gibbs sweep (the intra-block distributed
chain of core.distributed). Executors consume the same object instead of
ad-hoc device lists; ``topology=Topology(block=2, data=2)`` turns any of
sharded/async/streaming into the paper's combined two-level system.

The async and streaming ready queues dispatch CRITICAL-PATH-FIRST: ready
blocks pop in descending bottom-level order (``critical_path_priority`` —
estimated block cost plus the longest estimated successor chain, the same
dependency-aware list-schedule depth ``PPResult.modeled_parallel_s``
schedules measured times with), FIFO among ties.

Executor contract
-----------------
``run_graph(ctx, graph, verbose) -> (outcomes, phase_times_s, spans)`` owns
ordering: it must write each block's posterior summaries into ``ctx.U_posts``
/ ``ctx.V_posts`` before any dependent reads them via ``ctx.priors(task)``.
Barrier executors get that for free from the default implementation, which
runs ``run_phase(ctx, phase, tasks) -> {(i, j): BlockOutcome}`` once per
phase after asserting every dep resolved; the async executor replaces the
whole loop with its dependency-counting scheduler. Executors never
aggregate: ``run_phase_graph`` owns RMSE accumulation and the Qin-et-al.
divide-away aggregation (``pp._aggregate_axis``, one jitted device-resident
reduction).

Fault tolerance (see core/README.md): every block's chain computes a
device-resident health scalar (``gibbs.GibbsResult.health``) checked at
resolve time by ``_commit_guard`` — unhealthy blocks retry through one
shared single-block runner (re-split key, jittered prior), then degrade to
their propagated prior or raise per ``FaultPolicy``. The async/streaming
poll loops are watchdog-policed (cost-model deadlines; timed-out dispatches
re-dispatch on the next device group), ``checkpoint_dir``/``resume_from``
persist and restore per-block posteriors bitwise, and ``FaultPlan`` is the
deterministic injection seam the chaos tests drive every executor with.

Note on timings: SerialExecutor measures true per-block seconds;
Stacked/Sharded report bucket wall time split evenly across the bucket's
blocks (one executable runs them all) — the interesting number there is the
*measured* phase wall time in ``PPResult.phase_times_s``. AsyncExecutor
records true dispatch→resolve spans per block (``PPResult.block_spans_s``);
because phases overlap, its per-phase times are first-dispatch→last-resolve
envelopes and may sum to more than the wall time —
``PPResult.critical_path_s()`` is the honest aggregate.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bmf as BMF
from repro.core import gibbs as GIBBS
from repro.core import pp as PP
from repro.core.partition import Partition
from repro.core.posterior import RowGaussians
from repro.core.topology import Topology
from repro.data.sparse import COO, PaddedCSR, apply_permutation

Coord = Tuple[int, int]

# stable intra-phase bucket order (phase b runs its two buckets back to back)
_TAG_ORDER = ("a", "b_row", "b_col", "c")


# ---------------------------------------------------------------------------
# Fault tolerance: policy, deterministic injection plan, fault ledger
# ---------------------------------------------------------------------------


class BlockFaultError(RuntimeError):
    """A block exhausted its retry budget (unhealthy chain, repeated
    dispatch failure, or repeated watchdog timeout) under
    ``FaultPolicy.on_fault == 'raise'``."""


class TopologyDegradedError(RuntimeError):
    """Quarantines shrank the usable device-group set below
    ``FaultPolicy.min_groups`` (or to zero healthy groups). Raised AFTER
    flushing any active checkpoint, so the run is immediately resumable
    on a different topology. ``dead_groups`` names the quarantined
    groups in canonical order."""

    def __init__(self, msg: str, dead_groups: Sequence[int] = ()):
        super().__init__(msg)
        self.dead_groups: Tuple[int, ...] = tuple(dead_groups)


class _InjectedDispatchFailure(RuntimeError):
    """Raised by the FaultPlan seam to simulate a dispatch-time failure
    (device OOM, dead runtime) — handled exactly like the real thing."""


# dispatch-time failures the engine treats as block faults rather than
# bugs: the injected seam plus JAX's runtime-side errors (OOM, dead
# device). Anything else propagates — a TypeError is a bug, not a fault.
try:
    _DISPATCH_ERRORS: tuple = (_InjectedDispatchFailure,
                               jax.errors.JaxRuntimeError)
except AttributeError:  # pragma: no cover - older jax without JaxRuntimeError
    _DISPATCH_ERRORS = (_InjectedDispatchFailure,)


@dataclass(frozen=True)
class FaultPolicy:
    """What the engine does when a block goes bad.

    on_fault: after ``max_retries`` failed re-runs — "raise"
      (``BlockFaultError``) or "degrade" (posterior := the block's
      propagated prior, which cancels EXACTLY in the divide-away
      aggregation; the block's test entries drop out of the RMSE and the
      fault is recorded in ``PPResult.faults``).
    max_retries: bounded re-runs of a faulty block. Retry ``a`` uses
      ``fold_in(key, a)`` (a fresh independent chain) and a prior whose
      precision is inflated by ``retry_jitter·a·I`` — the two standard
      fixes for a NaN'd Cholesky / diverged chain. Retries run through ONE
      shared single-block runner, so a retried block's chain is identical
      under every executor (deterministic by (coord, attempt)).
    rmse_max: optional divergence threshold — a resolved block whose own
      test RMSE exceeds it is treated as faulty even if finite.
    watchdog: deadline-police the async/streaming poll loops. A block's
      deadline is ``timeout_floor_s + timeout_slack · rate · est(block)``
      where ``est`` is the nnz cost proxy (``_block_cost_estimates``, the
      same model priority dispatch uses) and ``rate`` is the max observed
      seconds-per-cost-unit over already-resolved blocks (0 until the
      first resolve, so early blocks get the generous floor). A timed-out
      dispatch is dropped, its block re-dispatched on the next device
      group (same PRNG key — a slow-but-alive block re-resolves to
      bitwise-identical numbers); budget exhaustion degrades/raises.
      watchdog=False restores the legacy block-on-oldest fallback, which
      deadlocks if the oldest in-flight block died — keep it on.

    Group fault domain (active when the executor's topology has >1 device
    group; with one group there is nowhere to rebalance to):

    quarantine_after: a group whose dispatches expire this many
      CONSECUTIVE times is quarantined — drained, never dispatched to
      again this run; its staged share and in-flight blocks rebalance
      onto healthy groups under the same keys (a group fault consumes no
      block retry budget — the blocks did nothing wrong).
    speculate_at: straggler hedge — when a dispatch has been in flight
      longer than ``speculate_at × rate(group) × est`` (the group's OWN
      calibrated rate), the block is redundantly dispatched to an idle
      healthy group with the same attempt-0 key. Twins are bitwise
      identical by construction; resolution commits a deterministic
      winner (canonical group order, not wall-clock first) and cancels
      the other. 0 disables speculation (the default).
    min_groups: quarantines that leave fewer healthy groups than this
      trigger graceful degradation: the checkpoint (if any) is flushed,
      then the run either continues on the survivors or raises
      ``TopologyDegradedError`` naming the dead groups, per
      ``on_group_fault`` ("continue" | "raise"). Zero healthy groups
      always raises.
    """
    on_fault: str = "raise"
    max_retries: int = 2
    rmse_max: Optional[float] = None
    retry_jitter: float = 1e-3
    watchdog: bool = True
    timeout_floor_s: float = 60.0
    timeout_slack: float = 10.0
    quarantine_after: int = 3
    speculate_at: float = 0.0
    min_groups: int = 1
    on_group_fault: str = "raise"

    def __post_init__(self):
        if self.on_fault not in ("raise", "degrade"):
            raise ValueError(f"on_fault must be 'raise' or 'degrade', "
                             f"got {self.on_fault!r}")
        if int(self.max_retries) < 0:
            raise ValueError(f"max_retries must be >= 0, "
                             f"got {self.max_retries}")
        if self.on_group_fault not in ("raise", "continue"):
            raise ValueError(f"on_group_fault must be 'raise' or "
                             f"'continue', got {self.on_group_fault!r}")
        if int(self.quarantine_after) < 1:
            raise ValueError(f"quarantine_after must be >= 1, "
                             f"got {self.quarantine_after}")
        if int(self.min_groups) < 1:
            raise ValueError(f"min_groups must be >= 1, "
                             f"got {self.min_groups}")
        if float(self.speculate_at) < 0:
            raise ValueError(f"speculate_at must be >= 0 (0 disables), "
                             f"got {self.speculate_at}")


@dataclass(frozen=True)
class FaultPlan:
    """Deterministic fault injection by coordinate — the test-only seam
    the conformance fault battery drives every executor with.

    Each map is ``{coord: n}``: the block's first ``n`` attempts are
    affected (attempt 0 is the normal dispatch, attempt ``a`` the a-th
    retry), so a plan is a pure function of (coord, attempt) and every
    run under it is deterministic.

    nan_at: NaN-poison the block's rating planes at padding time — the
      chain itself goes non-finite and the in-chain health guard trips,
      exercising the REAL failure surface rather than a mocked flag.
    hang_at: suppress completion detection for the block's dispatch
      (async/streaming ``_is_resolved`` never fires) until the watchdog
      deadline recovers it. Ignored by barrier executors, which have no
      poll loop to hang.
    fail_dispatch_at: dispatching the block raises — exercised at every
      executor's dispatch site (serial call, stacked bucket assembly,
      async dispatch, streaming chunk formation).

    Group-level injections key on the GROUP and its per-group dispatch
    ordinal (``PhaseContext.next_group_ordinal``) instead of (coord,
    attempt) — they model a device row going bad partway through a run,
    independent of which blocks happen to land on it. Both act at the
    completion-observation seam (the device work is untouched), the real
    surface the watchdog / quarantine / speculation layers react to:

    group_dead_at: ``{group: n}`` — the group's n-th and later dispatches
      are never observed complete (a dead group: every dispatch expires
      until the group is quarantined).
    group_slow_at: ``{group: (n, slow_s)}`` — from the group's n-th
      dispatch on, completion is withheld for ``slow_s`` seconds after
      dispatch (a straggler group: alive, just late — the speculation
      target).
    """
    nan_at: Dict[Coord, int] = field(default_factory=dict)
    hang_at: Dict[Coord, int] = field(default_factory=dict)
    fail_dispatch_at: Dict[Coord, int] = field(default_factory=dict)
    group_dead_at: Dict[int, int] = field(default_factory=dict)
    group_slow_at: Dict[int, Tuple[int, float]] = field(default_factory=dict)

    def nan(self, c: Coord, attempt: int) -> bool:
        return attempt < self.nan_at.get(tuple(c), 0)

    def hang(self, c: Coord, attempt: int) -> bool:
        return attempt < self.hang_at.get(tuple(c), 0)

    def fail(self, c: Coord, attempt: int) -> bool:
        return attempt < self.fail_dispatch_at.get(tuple(c), 0)

    def group_dead(self, g: int, ordinal: int) -> bool:
        n = self.group_dead_at.get(int(g))
        return n is not None and ordinal >= int(n)

    def group_slow_s(self, g: int, ordinal: int) -> float:
        ent = self.group_slow_at.get(int(g))
        if ent is None:
            return 0.0
        n, slow = ent
        return float(slow) if ordinal >= int(n) else 0.0


@dataclass(frozen=True)
class FaultRecord:
    """One ledger entry in ``PPResult.faults``: what went wrong with which
    block at which attempt, and what the engine did about it. kind
    "group" entries record the group fault domain: action "quarantined"
    marks the block whose expiry tripped a group's quarantine, and
    "rebalanced" each in-flight block moved off the quarantined group
    (no retry budget consumed — the block did nothing wrong)."""
    coord: Coord
    kind: str        # "nonfinite" | "rmse" | "dispatch" | "timeout" | "group"
    attempt: int
    action: str      # "retried" | "redispatched" | "degraded" | "raised"
    #                  | "quarantined" | "rebalanced"


@dataclass(frozen=True)
class BlockTask:
    """One node of the PP phase graph.

    ``phase`` is the partition's shape-bucket tag ('a'|'b_row'|'b_col'|'c');
    ``u_prior_from`` / ``v_prior_from`` name the block whose U / V posterior
    is propagated into this block as its prior (None = NW hyperprior)."""
    i: int
    j: int
    phase: str
    u_prior_from: Optional[Coord]
    v_prior_from: Optional[Coord]

    @property
    def coord(self) -> Coord:
        return (self.i, self.j)

    @property
    def deps(self) -> Tuple[Coord, ...]:
        return tuple(c for c in (self.u_prior_from, self.v_prior_from)
                     if c is not None)


def build_phase_graph(part: Partition) -> List[Tuple[str, List[BlockTask]]]:
    """The paper's three-phase DAG: [(phase_name, tasks)] in execution
    order. Every task's deps live in strictly earlier phases."""
    I, J = part.I, part.J
    phase_a = [BlockTask(0, 0, "a", None, None)]
    phase_b = ([BlockTask(i, 0, "b_row", None, (0, 0)) for i in range(1, I)]
               + [BlockTask(0, j, "b_col", (0, 0), None) for j in range(1, J)])
    phase_c = [BlockTask(i, j, "c", (i, 0), (0, j))
               for i in range(1, I) for j in range(1, J)]
    return [(name, tasks) for name, tasks in
            (("a", phase_a), ("b", phase_b), ("c", phase_c)) if tasks]


@dataclass
class PhaseContext:
    """Run state shared with executors: inputs (partition, config, permuted
    test set, per-block keys, shape buckets) plus the posterior store that
    carries summaries across phase boundaries. The store holds DEVICE
    arrays end to end — executors write device-resident summaries, the
    engine aggregates them in one jitted reduction, and nothing round-trips
    through the host between phases."""
    part: Partition
    cfg: BMF.BMFConfig
    test_p: COO
    keys: jax.Array                      # (I, J) typed PRNG keys
    shapes: Dict[str, "PP.BlockShapes"]  # per phase tag
    U_posts: Dict[Coord, RowGaussians] = field(default_factory=dict)
    V_posts: Dict[Coord, RowGaussians] = field(default_factory=dict)
    # fault tolerance: policy, optional deterministic injection plan,
    # per-block attempt counters (0 = the normal dispatch), the run's
    # fault ledger, optional block-level checkpoint writer, and outcomes
    # restored from a resume_from directory (their tasks are pruned from
    # the graph the executor sees).
    policy: FaultPolicy = field(default_factory=FaultPolicy)
    fault_plan: Optional[FaultPlan] = None
    attempts: Dict[Coord, int] = field(default_factory=dict)
    faults: List[FaultRecord] = field(default_factory=list)
    ckpt: Optional[object] = None        # checkpoint.ckpt.PPCheckpoint
    resumed: Dict[Coord, "BlockOutcome"] = field(default_factory=dict)
    # per-group dispatch counters — the ordinals the group-level fault
    # injections (FaultPlan.group_dead_at / group_slow_at) key on
    group_dispatches: Dict[int, int] = field(default_factory=dict)

    def block_cfg(self, task: BlockTask) -> BMF.BMFConfig:
        """Reduced chains for phases b/c when cfg.phase_bc_samples is set
        (the propagated priors are informative — paper future-work)."""
        cfg = self.cfg
        if cfg.phase_bc_samples and task.phase != "a":
            return cfg._replace(n_samples=cfg.phase_bc_samples,
                                burnin=max(2, cfg.phase_bc_samples // 4))
        return cfg

    def priors(self, task: BlockTask):
        up = self.U_posts[task.u_prior_from] if task.u_prior_from else None
        vp = self.V_posts[task.v_prior_from] if task.v_prior_from else None
        return up, vp

    # -- fault-tolerance plumbing ----------------------------------------

    def cur_attempt(self, c: Coord) -> int:
        return self.attempts.get(c, 0)

    def attempt_key(self, c: Coord, attempt: int):
        """Retry ``a`` re-splits the block's key with ``fold_in(key, a)``
        — a fresh chain, still a pure function of (run key, coord, a), so
        retried runs are deterministic and executor-independent."""
        k = self.keys[c[0], c[1]]
        return k if attempt == 0 else jax.random.fold_in(k, attempt)

    def should_poison(self, c: Coord) -> bool:
        return (self.fault_plan is not None
                and self.fault_plan.nan(c, self.cur_attempt(c)))

    def is_hung(self, c: Coord) -> bool:
        return (self.fault_plan is not None
                and self.fault_plan.hang(c, self.cur_attempt(c)))

    def check_dispatch(self, c: Coord):
        if (self.fault_plan is not None
                and self.fault_plan.fail(c, self.cur_attempt(c))):
            raise _InjectedDispatchFailure(
                f"injected dispatch failure for block {c} "
                f"(attempt {self.cur_attempt(c)})")

    def next_group_ordinal(self, g: int) -> int:
        """Bump-and-return group ``g``'s dispatch ordinal (0-based) — one
        per chunk/block dispatch landing on the group."""
        n = self.group_dispatches.get(int(g), 0)
        self.group_dispatches[int(g)] = n + 1
        return n

    def group_suppressed_until(self, g: int, ordinal: int,
                               td: float) -> float:
        """Group-level injection verdict for one dispatch: 0.0 = healthy,
        ``inf`` = the group is dead (completion never observed), else the
        wall-clock time before which completion is withheld
        (``group_slow_at``). Applied at the completion-observation seam,
        like ``is_hung``."""
        if self.fault_plan is None:
            return 0.0
        if self.fault_plan.group_dead(g, ordinal):
            return float("inf")
        slow = self.fault_plan.group_slow_s(g, ordinal)
        return td + slow if slow else 0.0

    def record_fault(self, c: Coord, kind: str, action: str):
        self.faults.append(FaultRecord(coord=c, kind=kind,
                                       attempt=self.cur_attempt(c),
                                       action=action))

    def note_resolved(self, task: BlockTask, out: "BlockOutcome"):
        """Checkpoint hook: persist one resolved block's posteriors + RMSE
        contribution. No-cost when checkpointing is off."""
        if self.ckpt is None:
            return
        n, sq = _host_sq(self, task, out)
        self.ckpt.note(task.coord, out.U_post, out.V_post, sq, n)


@dataclass
class BlockOutcome:
    U_post: RowGaussians       # trimmed to the block's true row count
    V_post: RowGaussians       # trimmed to the block's true col count
    # (bucket n_test,) posterior-mean predictions — None on the async path,
    # which reports squared error through the sq_err scalar instead
    pred_mean: Optional[np.ndarray]
    seconds: float
    # device-resident RMSE channel (async path): a tiny on-device scalar of
    # Σ(pred-val)² over the block's true test entries + their count. When
    # set, the engine never touches pred_mean.
    sq_err: Optional[jax.Array] = None
    n_obs: int = 0
    # the chain's device-resident health flag (gibbs.GibbsResult.health);
    # None on paths that predate the guard — treated as healthy.
    health: Optional[jax.Array] = None


def _outcome(res: GIBBS.GibbsResult, blk, seconds: float) -> BlockOutcome:
    nr, nc = len(blk.row_ids), len(blk.col_ids)
    pred = np.asarray(res.acc.pred_sum
                      / np.maximum(float(res.acc.pred_cnt), 1.0))
    return BlockOutcome(
        U_post=RowGaussians(eta=res.U_post.eta[:nr],
                            Lambda=res.U_post.Lambda[:nr]),
        V_post=RowGaussians(eta=res.V_post.eta[:nc],
                            Lambda=res.V_post.Lambda[:nc]),
        pred_mean=pred, seconds=seconds, health=res.health)


@jax.jit
def _block_sq_err(pred_sum, pred_cnt, vals, mask):
    """Masked Σ(pred-val)² — the per-block completion/RMSE scalar."""
    err = (pred_sum / jnp.maximum(pred_cnt, 1.0) - vals) * mask
    return jnp.vdot(err, err)


def _host_sq(ctx: PhaseContext, task: BlockTask,
             o: BlockOutcome) -> Tuple[int, float]:
    """One block's (n_test, Σ(pred-val)²) as host scalars — from the
    device-resident sq_err channel when present, else from pred_mean."""
    if o.sq_err is not None:
        return o.n_obs, float(o.sq_err)
    blk = ctx.part.block(task.i, task.j)
    _, _, tv = PP._block_test(ctx.test_p, blk)
    n = len(tv)
    sq = float(np.sum((np.asarray(o.pred_mean[:n]) - tv) ** 2)) if n else 0.0
    return n, sq


def _fault_kind(ctx: PhaseContext, task: BlockTask,
                o: BlockOutcome) -> Optional[str]:
    """Health verdict on a resolved outcome: None = healthy, else the
    fault kind. Checked BEFORE the posterior feeds any successor or the
    final aggregation — a NaN caught here never poisons anything
    downstream."""
    if o.health is not None and not bool(np.asarray(o.health)):
        return "nonfinite"
    if ctx.policy.rmse_max is not None:
        n, sq = _host_sq(ctx, task, o)
        # `not <=` (rather than `>`) also trips on a NaN sq that slipped
        # past a health-less outcome
        if n and not (sq <= (ctx.policy.rmse_max ** 2) * n):
            return "rmse"
    return None


def _jitter_prior(p: Optional[RowGaussians],
                  eps: float) -> Optional[RowGaussians]:
    """Precision-inflate a retry's prior: Λ + eps·I. Tightens the
    conditional toward the prior mean — the standard stabilization for a
    chain whose Cholesky went non-PD."""
    if p is None or not eps:
        return p
    K = p.eta.shape[-1]
    return RowGaussians(eta=p.eta, Lambda=p.Lambda + eps * jnp.eye(K))


def _run_block_attempt(ctx: PhaseContext, task: BlockTask,
                       attempt: int) -> BlockOutcome:
    """The shared retry runner: one synchronous single-block chain with
    the attempt's re-split key and jittered prior. EVERY executor heals
    through this path, so a retried block's chain — and therefore the
    whole faulted run's numbers — is identical whichever executor hit the
    fault. Uses the block's per-phase bucket shapes (the serial
    executable), so no new compilation is introduced."""
    c = task.coord
    ctx.check_dispatch(c)
    blk = ctx.part.block(task.i, task.j)
    s = ctx.shapes[task.phase]
    up, vp = ctx.priors(task)
    # the parent posteriors committed wherever their dispatches resolved,
    # which on a multi-group topology can be two different devices; the
    # retry chain is one single-device executable, so colocate them on
    # the default device (a pure transfer — bitwise-neutral, and the
    # same placement the serial executor uses)
    d0 = jax.devices()[0]
    up = jax.device_put(up, d0) if up is not None else None
    vp = jax.device_put(vp, d0) if vp is not None else None
    csr_r, csr_c, tr, tc, tv, tmask, up_p, vp_p = PP.pad_block_inputs(
        blk, s, ctx.cfg.K, ctx.test_p, up, vp,
        poison_nan=(ctx.fault_plan is not None
                    and ctx.fault_plan.nan(c, attempt)))
    eps = ctx.policy.retry_jitter * attempt
    res = GIBBS.run_gibbs(ctx.attempt_key(c, attempt), csr_r, csr_c,
                          jnp.asarray(tr), jnp.asarray(tc),
                          ctx.block_cfg(task),
                          U_prior=_jitter_prior(up_p, eps),
                          V_prior=_jitter_prior(vp_p, eps))
    nr, nc = len(blk.row_ids), len(blk.col_ids)
    sq = _block_sq_err(res.acc.pred_sum, res.acc.pred_cnt,
                       jnp.asarray(tv), jnp.asarray(tmask))
    return BlockOutcome(
        U_post=RowGaussians(eta=res.U_post.eta[:nr],
                            Lambda=res.U_post.Lambda[:nr]),
        V_post=RowGaussians(eta=res.V_post.eta[:nc],
                            Lambda=res.V_post.Lambda[:nc]),
        pred_mean=None, seconds=0.0, sq_err=sq, n_obs=int(tmask.sum()),
        health=res.health)


def _degrade_outcome(ctx: PhaseContext, task: BlockTask) -> BlockOutcome:
    """on_fault='degrade': the block's posterior becomes its propagated
    prior (neutral N(0, I) where it had none). In the divide-away
    aggregation ``Σ_j posts − (J−1)·prior`` a prior-valued posterior
    cancels EXACTLY, so a degraded block contributes nothing instead of
    something wrong; its test entries are dropped from the RMSE
    (sq_err=0, n_obs=0) — the reported error stays honest over the blocks
    that actually ran."""
    blk = ctx.part.block(task.i, task.j)
    up, vp = ctx.priors(task)
    K = ctx.cfg.K
    return BlockOutcome(
        U_post=up if up is not None else _dummy_prior(len(blk.row_ids), K),
        V_post=vp if vp is not None else _dummy_prior(len(blk.col_ids), K),
        pred_mean=None, seconds=0.0, sq_err=jnp.zeros(()), n_obs=0,
        health=jnp.asarray(True))


def _commit_guard(ctx: PhaseContext, task: BlockTask,
                  out: Optional[BlockOutcome],
                  kind: Optional[str] = None) -> BlockOutcome:
    """The chain-health guard, applied to every block at resolve time.

    Healthy outcome → returned untouched (the common case costs one tiny
    device→host bool read of an already-computed scalar). Faulty outcome
    (or ``kind`` pre-set by a dispatch failure / watchdog timeout) →
    bounded retries through ``_run_block_attempt``, then degrade or raise
    per ``ctx.policy``. Whenever the outcome changes, the posterior store
    is rewritten BEFORE returning, so successors and the final aggregation
    only ever see the healed values."""
    c = task.coord
    if kind is None:
        if out is None:
            raise AssertionError(f"block {c}: no outcome and no fault kind")
        kind = _fault_kind(ctx, task, out)
        if kind is None:
            return out
    pol = ctx.policy
    t0 = time.time()
    while ctx.cur_attempt(c) < pol.max_retries:
        attempt = ctx.cur_attempt(c) + 1
        ctx.record_fault(c, kind, "retried")
        ctx.attempts[c] = attempt
        try:
            out = _run_block_attempt(ctx, task, attempt)
            kind = _fault_kind(ctx, task, out)
        except _DISPATCH_ERRORS:
            kind = "dispatch"
            continue
        if kind is None:
            out.seconds = time.time() - t0
            ctx.U_posts[c], ctx.V_posts[c] = out.U_post, out.V_post
            return out
    if pol.on_fault == "degrade":
        ctx.record_fault(c, kind, "degraded")
        out = _degrade_outcome(ctx, task)
        ctx.U_posts[c], ctx.V_posts[c] = out.U_post, out.V_post
        return out
    ctx.record_fault(c, kind, "raised")
    raise BlockFaultError(
        f"block {c}: {kind} fault after {ctx.cur_attempt(c)} of "
        f"{pol.max_retries} retries (on_fault='raise'; pass "
        f"on_fault='degrade' to fall back to the propagated prior)")


class Executor:
    """Runs the PP phase graph; subclasses choose the schedule.

    Every executor records an optional event trace (``record_trace=True``):
    (event, coord) or (event, coord, group) entries appended in real
    order — the overlapped executors (async/streaming) attribute every
    event to the device group it happened on; barrier executors have no
    group concept and emit 2-tuples. "dispatch" means the block's chain
    was handed to the runtime (its priors were read), "resolve" means its
    results were observed complete. Watchdog paths add "expire" (the
    in-flight attempt hit its deadline and its handles were dropped) and
    "redispatch" (the expired attempt was re-dispatched under the same
    keys) — so a fault-free run is always dispatch/resolve pairs and a
    timeout is totally ordered as dispatch < expire < redispatch <
    resolve (an expire followed directly by a terminal resolve is the
    degraded/exhausted-budget path). The group fault domain adds four
    more (all group-attributed):

      "quarantine"  the group crossed ``quarantine_after`` consecutive
                    expiries and was drained (coord = the trigger block);
                    no dispatch may target it afterwards;
      "steal"       an idle healthy group took this staged (not yet
                    dispatched) block from the most-loaded group — the
                    next dispatch of the coord runs on the thief;
      "speculate"   a straggling in-flight block was redundantly
                    dispatched to this idle group under the same
                    attempt-0 key (its twin);
      "cancel"      one side of a twin pair was dropped — every
                    speculative pair ends in exactly one resolve and one
                    cancel (the deterministic canonical-group winner
                    commits; wall-clock order does not).

    The conformance suite (tests/test_executor_conformance.py) asserts on
    this trace that no executor ever dispatches a block before its
    dependencies resolved, and the analyzer's happens-before pass
    (repro.analysis.trace_passes) checks the full protocol — new
    executors get both for free by reporting honestly.

    ``n_quarantined`` / ``n_steals`` / ``n_speculations`` / ``n_cancels``
    count the group-fault events of the last run (surfaced as
    ``PPResult.group_stats``); always 0 for barrier executors.
    """
    name = "base"
    devices: Tuple = ()    # AsyncExecutor's per-device streams

    def __init__(self, record_trace: bool = False):
        self.record_trace = record_trace
        self.trace: List[Tuple] = []
        self.n_quarantined = 0
        self.n_steals = 0
        self.n_speculations = 0
        self.n_cancels = 0

    def _reset_run_state(self):
        """Clear per-run mutable state. Every ``run_graph`` implementation
        calls this first, so one executor instance is safely reusable
        across ``run_pp`` calls (warmup + timed runs, repeated benches)
        without traces or peak counters leaking between runs."""
        self.trace = []
        self.n_quarantined = 0
        self.n_steals = 0
        self.n_speculations = 0
        self.n_cancels = 0

    def _record(self, event: str, coord: Coord, group: Optional[int] = None):
        if self.record_trace:
            self.trace.append((event, coord) if group is None
                              else (event, coord, int(group)))

    def run_phase(self, ctx: PhaseContext, phase: str,
                  tasks: Sequence[BlockTask]) -> Dict[Coord, BlockOutcome]:
        raise NotImplementedError

    def run_graph(self, ctx: PhaseContext, graph, verbose: bool = False):
        """Default barrier schedule: phases strictly in order, one
        ``run_phase`` call each, posterior store updated at the phase
        boundary. Returns ``(outcomes, phase_times_s, spans)``; spans is
        empty — per-block dispatch→resolve timing only exists under an
        overlapped schedule."""
        self._reset_run_state()
        outcomes: Dict[Coord, BlockOutcome] = {}
        phase_times: Dict[str, float] = {}
        for phase, tasks in graph:
            missing = {d for t in tasks for d in t.deps} - set(ctx.U_posts)
            assert not missing, f"phase {phase} scheduled before {missing}"
            t0 = time.time()
            outs = self.run_phase(ctx, phase, tasks)
            dropped = {t.coord for t in tasks} - set(outs)
            assert not dropped, f"executor {self.name} dropped blocks {dropped}"
            for t in tasks:
                # chain-health guard at block resolution: retry / degrade /
                # raise BEFORE the posterior reaches the store (and with it
                # every successor and the final aggregation)
                o = _commit_guard(ctx, t, outs[t.coord])
                outs[t.coord] = o
                ctx.U_posts[t.coord] = o.U_post
                ctx.V_posts[t.coord] = o.V_post
                ctx.note_resolved(t, o)
            dt = time.time() - t0
            phase_times[phase] = dt
            outcomes.update(outs)
            if verbose:
                print(f"[pp:{self.name}] phase {phase}: {len(tasks)} "
                      f"block(s) {_phase_desc(ctx, tasks)} {dt:.2f}s",
                      flush=True)
        return outcomes, phase_times, {}


def _phase_desc(ctx: PhaseContext, tasks: Sequence[BlockTask]) -> str:
    tags = [g for g in _TAG_ORDER if any(t.phase == g for t in tasks)]
    return " ".join(
        f"{g}[{sum(1 for t in tasks if t.phase == g)}blk "
        f"{ctx.shapes[g].n_rows}x{ctx.shapes[g].n_cols} "
        f"m={ctx.shapes[g].m_rows}/{ctx.shapes[g].m_cols}]" for g in tags)


class SerialExecutor(Executor):
    """One jitted Gibbs call + host sync per block (reference semantics,
    bit-for-bit today's ``run_pp`` loop). Composes with an intra-block
    ``distributed_mesh``: each block's chain is itself shard_map'd.
    A ``topology`` (block must be 1 — serial runs one block at a time)
    is the unified way to say the same thing: its single group's 'data'
    mesh becomes the intra-block mesh."""
    name = "serial"

    def __init__(self, distributed_mesh=None, record_trace: bool = False,
                 topology: Optional[Topology] = None):
        super().__init__(record_trace=record_trace)
        if topology is not None:
            if distributed_mesh is not None:
                raise ValueError("pass distributed_mesh OR topology, not both")
            if topology.block != 1:
                raise ValueError(
                    f"serial executor runs one block at a time — a topology "
                    f"with block={topology.block} device groups needs the "
                    f"sharded/async/streaming executor")
            if topology.data > 1:
                distributed_mesh = topology.data_mesh(0)
        self.distributed_mesh = distributed_mesh

    def run_phase(self, ctx, phase, tasks):
        out: Dict[Coord, BlockOutcome] = {}
        for t in tasks:
            blk = ctx.part.block(t.i, t.j)
            up, vp = ctx.priors(t)
            self._record("dispatch", t.coord)
            t0 = time.time()
            try:
                ctx.check_dispatch(t.coord)
                res = PP.run_block(ctx.keys[t.i, t.j], blk, ctx.block_cfg(t),
                                   ctx.test_p, up, vp, self.distributed_mesh,
                                   shapes=ctx.shapes[t.phase],
                                   poison_nan=ctx.should_poison(t.coord))
                jax.block_until_ready(res.U)
                self._record("resolve", t.coord)
                out[t.coord] = _outcome(res, blk, time.time() - t0)
            except _DISPATCH_ERRORS:
                self._record("resolve", t.coord)
                out[t.coord] = _commit_guard(ctx, t, None, kind="dispatch")
        return out


def _task_leaves(ctx: PhaseContext, task: BlockTask):
    """Device-ready leaves for one block — pp.pad_block_inputs is the
    single source of truth for bucket padding, shared with run_block, so
    stacked chains are identical to serial ones by construction."""
    blk = ctx.part.block(task.i, task.j)
    up, vp = ctx.priors(task)
    csr_r, csr_c, tr, tc, _, _, up, vp = PP.pad_block_inputs(
        blk, ctx.shapes[task.phase], ctx.cfg.K, ctx.test_p, up, vp,
        poison_nan=ctx.should_poison(task.coord))
    return ((csr_r.idx, csr_r.val, csr_r.mask),
            (csr_c.idx, csr_c.val, csr_c.mask),
            jnp.asarray(tr), jnp.asarray(tc), up, vp)


def _stack_trees(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


class StackedExecutor(Executor):
    """One jitted vmapped Gibbs call per phase shape bucket: all blocks of
    the bucket run as a leading batch axis inside a single executable.
    The stacked input leaves are donated to XLA by default (they are
    per-bucket copies nothing else holds)."""
    name = "stacked"
    block_mesh = None      # ShardedExecutor sets this

    def __init__(self, donate: bool = True, record_trace: bool = False):
        super().__init__(record_trace=record_trace)
        self.donate = donate

    def run_phase(self, ctx, phase, tasks):
        out: Dict[Coord, BlockOutcome] = {}
        for tag in _TAG_ORDER:
            group = [t for t in tasks if t.phase == tag]
            if group:
                out.update(self._run_bucket(ctx, tag, group))
        return out

    def _batch_pad(self, n_tasks: int) -> int:
        if self.block_mesh is None:
            return 0
        n_dev = self.block_mesh.devices.size
        return (-n_tasks) % n_dev

    def _run_bucket(self, ctx, tag, group):
        s = ctx.shapes[tag]
        t0 = time.time()
        for t in group:
            self._record("dispatch", t.coord)
        # dispatch-failure injection/handling: flagged blocks are excluded
        # from the bucket (per-block vmapped chains are independent, so the
        # rest of the bucket is unaffected) and healed individually through
        # the shared retry runner
        failed = []
        ok = []
        for t in group:
            try:
                ctx.check_dispatch(t.coord)
                ok.append(t)
            except _DISPATCH_ERRORS:
                failed.append(t)
        out: Dict[Coord, BlockOutcome] = {}
        for t in failed:
            self._record("resolve", t.coord)
            out[t.coord] = _commit_guard(ctx, t, None, kind="dispatch")
        if not ok:
            return out
        group = ok
        leaves = _stack_trees([_task_leaves(ctx, t) for t in group])
        rows_arrs, cols_arrs, test_rows, test_cols, up, vp = leaves
        ii = np.array([t.i for t in group])
        jj = np.array([t.j for t in group])
        keys = ctx.keys[ii, jj]
        pad = self._batch_pad(len(group))
        sel = np.arange(len(group))
        if pad:
            # round the batch up to the block mesh size by repeating the
            # last block (its duplicate results are dropped below)
            sel = np.concatenate([sel, np.full(pad, len(group) - 1)])
            rows_arrs, cols_arrs, test_rows, test_cols, up, vp = jax.tree.map(
                lambda x: x[sel],
                (rows_arrs, cols_arrs, test_rows, test_cols, up, vp))
            keys = keys[sel]
        res = self._dispatch_stacked(
            ctx, s, keys, [group[i] for i in sel],
            PaddedCSR(*rows_arrs, n_cols=s.n_cols),
            PaddedCSR(*cols_arrs, n_cols=s.n_rows),
            test_rows, test_cols, ctx.block_cfg(group[0]), up, vp)
        jax.block_until_ready(res.U)
        for t in group:
            self._record("resolve", t.coord)
        per = (time.time() - t0) / len(group)
        for b, t in enumerate(group):
            blk = ctx.part.block(t.i, t.j)
            res_b = jax.tree.map(lambda x: x[b], res)
            out[t.coord] = _outcome(res_b, blk, per)
        return out

    def _dispatch_stacked(self, ctx, s, keys, tasks, csr_r, csr_c,
                          test_rows, test_cols, cfg, up, vp):
        """Bucket-dispatch seam: the stacked executor runs one vmapped
        executable; the sharded executor overrides placement (1-D 'block'
        mesh, or the composed 2-D chain when its topology has a 'data'
        axis). ``tasks`` lists the batch's tasks AFTER padding (duplicates
        included) so overrides can assemble per-block host planes."""
        return GIBBS.run_gibbs_stacked(
            keys, csr_r, csr_c, test_rows, test_cols, cfg,
            U_prior=up, V_prior=vp, block_mesh=self.block_mesh,
            donate=self.donate)


def _stacked_csrt(ctx, tasks, s, n_shards: int, scatter: bool):
    """Host-assembled per-shard transposed planes for a stacked batch —
    (B, S, D_pad, m_cols) numpy leaves feeding the composed chain's
    'psum'/'scatter' V-step (``distributed.shard_transposed_planes``).
    ``tasks`` may contain batch-padding duplicates; the O(nnz) host
    assembly runs once per distinct block and duplicates are stacked by
    reference."""
    from repro.core import distributed as DIST
    N_pad = ((s.n_rows + n_shards - 1) // n_shards) * n_shards
    D_pad = (((s.n_cols + n_shards - 1) // n_shards) * n_shards
             if scatter else s.n_cols)
    cache: Dict[Coord, tuple] = {}
    for t in tasks:
        if t.coord not in cache:
            blk = ctx.part.block(t.i, t.j)
            cache[t.coord] = DIST.shard_transposed_planes(
                blk.coo.row, blk.coo.col, blk.coo.val, n_shards, N_pad,
                D_pad, s.m_cols)
    planes = [cache[t.coord] for t in tasks]
    return tuple(np.stack([p[k] for p in planes]) for k in range(3))


class ShardedExecutor(StackedExecutor):
    """StackedExecutor with the bucket batch placed by a ``Topology``.

    data == 1 (default): the historical 1-D 'block' mesh — the stacked
    batch shard_map'd so blocks of a phase run concurrently on separate
    devices with NO collective inside a phase.

    data > 1: the paper's combined system — the batch splits over the
    'block' axis (device groups) while each block's Gibbs sweep runs the
    intra-block distributed chain over the 'data' axis
    (``distributed.run_gibbs_stacked_2d``). ``comm`` picks the intra-block
    exchange: 'gather' (factor exchange, chain-parity with serial),
    'psum' (ref [16] item-stat reduction), 'scatter' (§Perf H6
    reduce-scatter). Either way no collective EVER runs on the 'block'
    axis — posterior summaries return to the host at the phase boundary,
    which is the paper's entire communication budget."""
    name = "sharded"

    def __init__(self, topology=None, donate: bool = True,
                 record_trace: bool = False, comm: str = "gather"):
        super().__init__(donate=donate, record_trace=record_trace)
        self.topology = Topology.from_spec(topology)
        self.comm = comm
        # data==1 keeps the legacy single-level executable; the base class
        # dispatch seam reads block_mesh
        self.block_mesh = (self.topology.block_mesh()
                           if self.topology.data == 1 else None)
        if self.topology.data > 1 and self.topology.n_devices > 1:
            self.devices = self.topology.devices

    def _batch_pad(self, n_tasks: int) -> int:
        return (-n_tasks) % self.topology.block

    def _dispatch_stacked(self, ctx, s, keys, tasks, csr_r, csr_c,
                          test_rows, test_cols, cfg, up, vp):
        if self.topology.data == 1:
            return super()._dispatch_stacked(ctx, s, keys, tasks, csr_r,
                                             csr_c, test_rows, test_cols,
                                             cfg, up, vp)
        from repro.core import distributed as DIST
        csrt = (None if self.comm == "gather" else
                _stacked_csrt(ctx, tasks, s, self.topology.data,
                              scatter=(self.comm == "scatter")))
        return DIST.run_gibbs_stacked_2d(
            keys, csr_r, csr_c, test_rows, test_cols, cfg, self.topology,
            U_prior=up, V_prior=vp, donate=self.donate, comm=self.comm,
            csrt=csrt)


def critical_path_priority(tasks: Dict[Coord, BlockTask],
                           est: Dict[Coord, float],
                           succ: Optional[Dict[Coord, List[Coord]]] = None
                           ) -> Dict[Coord, float]:
    """Bottom-level of every task: its estimated cost plus the longest
    estimated chain through its successors — the same dependency-aware
    list-schedule depth ``PPResult.modeled_parallel_s`` schedules measured
    times with, computed a priori from cost estimates. Dispatching ready
    blocks in DESCENDING bottom-level order (critical-path-first) closes
    the longest chain earliest, which is where skewed grids lose time under
    FIFO dispatch: a near-empty phase-b block can otherwise delay the dense
    column of phase-c blocks behind it. ``succ`` may be passed pre-built
    (``_dep_state`` shares its copy)."""
    if succ is None:
        succ = {c: [] for c in tasks}
        for t in tasks.values():
            for d in t.deps:
                succ[d].append(t.coord)
    memo: Dict[Coord, float] = {}

    def bottom(c: Coord) -> float:
        if c not in memo:
            memo[c] = (est.get(c, 0.0)
                       + max((bottom(s) for s in succ[c]), default=0.0))
        return memo[c]

    return {c: bottom(c) for c in tasks}


def _block_cost_estimates(ctx: PhaseContext,
                          tasks: Dict[Coord, BlockTask]) -> Dict[Coord, float]:
    """A-priori per-block cost proxy for priority dispatch: the block's nnz
    (+1 so empty blocks still order deterministically). Within a shape
    bucket the padded compute is nominally shape-bound, but the fused
    kernel's nnz-aware tile skip and the test-entry count both track nnz,
    and on skewed grids nnz spans orders of magnitude."""
    return {c: float(ctx.part.block(t.i, t.j).coo.nnz + 1)
            for c, t in tasks.items()}


def _dep_state(ctx: PhaseContext, graph, priority: bool, make_queue=None):
    """Shared ready-queue scaffolding for the overlapped schedulers
    (async + streaming): task/phase maps, readiness counters, successor
    lists, and the priority ready queue seeded with the dep-free blocks.
    ``make_queue(prio, tasks)`` lets callers substitute a queue type (the
    streaming executor uses a per-group view). Returns
    ``(tasks, phase_of, waiting, succ, ready)``."""
    tasks = {t.coord: t for _, ts in graph for t in ts}
    phase_of = {t.coord: ph for ph, ts in graph for t in ts}
    # a resumed graph is pruned: deps satisfied by restored blocks don't
    # count toward readiness, and restored blocks appear in no succ list
    waiting = {c: sum(1 for d in t.deps if d in tasks)
               for c, t in tasks.items()}
    succ: Dict[Coord, List[Coord]] = {c: [] for c in tasks}
    for t in tasks.values():
        for d in t.deps:
            if d in succ:
                succ[d].append(t.coord)
    prio = (critical_path_priority(tasks, _block_cost_estimates(ctx, tasks),
                                   succ=succ)
            if priority else None)
    ready = make_queue(prio, tasks) if make_queue else _ReadyQueue(prio)
    for c, w in waiting.items():
        if w == 0:
            ready.push(c)
    return tasks, phase_of, waiting, succ, ready


class _ReadyQueue:
    """Priority ready queue shared by the async and streaming schedulers:
    pops in descending critical-path (bottom-level) order, FIFO among ties
    — with priorities disabled it degenerates to the PR-3 FIFO exactly."""

    def __init__(self, prio: Optional[Dict[Coord, float]] = None):
        import heapq
        self._heapq = heapq
        self._prio = prio or {}
        self._seq = 0
        self._heap: List[Tuple[float, int, Coord]] = []

    def push(self, c: Coord):
        self._heapq.heappush(self._heap,
                             (-self._prio.get(c, 0.0), self._seq, c))
        self._seq += 1

    def pop(self) -> Coord:
        return self._heapq.heappop(self._heap)[2]

    def __len__(self):
        return len(self._heap)

    def __bool__(self):
        return bool(self._heap)


class _GroupedReadyQueue:
    """Streaming ready queue: a global priority heap for lead selection
    plus one heap per chunk-group key, so forming a chunk is O(W log n)
    instead of draining and re-pushing the whole queue whenever many
    groups interleave (hundreds of phase-c blocks behind a lone phase-b
    lead on the oversized grids streaming targets). Entries popped
    through one view are lazily skipped in the other."""

    def __init__(self, prio, group_of):
        self._prio = prio
        self._group_of = group_of
        self._global = _ReadyQueue(prio)
        self._groups: Dict = {}
        self._taken: set = set()
        self._n = 0

    def push(self, c: Coord):
        self._global.push(c)
        self._groups.setdefault(self._group_of(c),
                                _ReadyQueue(self._prio)).push(c)
        self._n += 1

    def __len__(self):
        return self._n

    def __bool__(self):
        return self._n > 0

    def pop_chunk(self, max_n: int) -> List[Coord]:
        """Highest-priority ready block plus up to ``max_n - 1`` more from
        its group, in priority order."""
        while True:
            lead = self._global.pop()
            if lead not in self._taken:
                break
        self._taken.add(lead)
        self._n -= 1
        take = [lead]
        grp = self._groups[self._group_of(lead)]
        while grp and len(take) < max_n:
            c = grp.pop()
            if c in self._taken:
                continue
            self._taken.add(c)
            self._n -= 1
            take.append(c)
        return take


class _GroupHealth:
    """Per-device-group health ledger shared by the overlapped schedulers
    (async + streaming): per-group EWMA rates, consecutive-expiry
    counters, and the quarantined set.

    Rate model (the watchdog/speculation cost calibration): ``rate(g)``
    is an EWMA (alpha=0.4) of group ``g``'s observed seconds per
    estimated cost unit — per-group, replacing the single global
    fastest-rate, which mis-sizes deadlines ~Nx too tight on any group
    slower than the fastest. Each group's FIRST observed resolve spans
    that group's executable compile and is excluded entirely (the
    per-group twin of the old global first-resolve skip). A group that
    has not yet calibrated inherits the fastest calibrated rate
    (``global_rate``); before ANY group calibrates every rate is 0.0 and
    deadlines fall back to the generous floor — the same cold-start
    behavior as before.

    Quarantine: ``note_expiry`` counts CONSECUTIVE expiries per group
    (any resolve resets the count) and returns True when the count
    crosses ``quarantine_after`` — the caller then drains the group.
    """

    ALPHA = 0.4

    def __init__(self, n_groups: int, quarantine_after: int):
        self.n = max(1, int(n_groups))
        self.quarantine_after = max(1, int(quarantine_after))
        self._rate = [0.0] * self.n     # EWMA s/cost; 0 = uncalibrated
        self._seen = [False] * self.n   # first resolve = compile span
        self.consec = [0] * self.n      # consecutive expiries
        self.quarantined: set = set()

    def healthy(self) -> List[int]:
        return [g for g in range(self.n) if g not in self.quarantined]

    @property
    def global_rate(self) -> float:
        cal = [r for r in self._rate if r > 0.0]
        return min(cal) if cal else 0.0

    def rate(self, g: int) -> float:
        return self._rate[g] if self._rate[g] > 0.0 else self.global_rate

    def observe(self, g: int, obs: float):
        if not self._seen[g]:
            self._seen[g] = True
            return
        if obs <= 0.0:
            return
        r = self._rate[g]
        self._rate[g] = (obs if r == 0.0
                         else (1 - self.ALPHA) * r + self.ALPHA * obs)

    def note_resolve(self, g: int):
        self.consec[g] = 0

    def note_expiry(self, g: int) -> bool:
        """True when this expiry crosses the quarantine threshold — the
        caller quarantines the group. Already-quarantined groups never
        re-trip."""
        if g in self.quarantined:
            return False
        self.consec[g] += 1
        return self.consec[g] >= self.quarantine_after

    def quarantine(self, g: int):
        self.quarantined.add(g)


@dataclass
class _Flight:
    """One in-flight dispatch attempt on a device group — a single block
    (async) or a window chunk (streaming). Multiple flights for the same
    work = a speculative twin pair. ``sup`` is the group-level injection
    verdict for this dispatch (0 healthy / wall-clock gate / inf dead),
    applied at the completion-observation seam like ``is_hung``."""
    sig: object                            # completion scalar/vector
    out: object                            # BlockOutcome | {coord: outcome}
    td: float                              # dispatch wall time
    group: int
    sup: float = 0.0
    tasks: Optional[List[BlockTask]] = None  # streaming chunk members


def _maybe_degrade_topology(ctx: PhaseContext, health: _GroupHealth):
    """Graceful topology degradation, checked after every quarantine:
    fewer healthy groups than ``FaultPolicy.min_groups`` (or none at all)
    flushes the checkpoint, then continues on the survivors or raises
    ``TopologyDegradedError`` per ``FaultPolicy.on_group_fault``."""
    pol = ctx.policy
    survivors = health.healthy()
    if len(survivors) >= pol.min_groups:
        return
    if ctx.ckpt is not None:
        ctx.ckpt.flush()
    if pol.on_group_fault == "continue" and survivors:
        return
    dead = sorted(health.quarantined)
    raise TopologyDegradedError(
        f"{len(survivors)} healthy device group(s) left (quarantined: "
        f"{dead}), below min_groups={pol.min_groups} "
        f"(on_group_fault={pol.on_group_fault!r}; checkpoint flushed)",
        dead_groups=dead)


class AsyncExecutor(Executor):
    """Dependency-driven overlapped schedule riding JAX async dispatch.

    Readiness counters over ``BlockTask.deps`` replace the phase barrier:
    each block is dispatched (one jitted per-block chain, the SAME bucketed
    executable the serial executor compiles — ≤4 compilations per run) the
    moment both of its prior sources have resolved, so phase-c blocks whose
    phase-b dependencies finished early start while the slowest phase-b
    bucket is still running. The host never blocks on bulk results:

      * completion detection polls ``is_ready()`` on a per-block scalar
        (masked Σ(pred-val)², doubling as the block's RMSE numerator) and
        only falls back to blocking on the OLDEST in-flight scalar when
        nothing has resolved — the device queue keeps draining either way;
      * posterior summaries (trimmed device slices) go straight into the
        context store and feed successors without touching the host;
      * padded per-block input buffers are donated to XLA
        (``run_gibbs(donate=True)``): U0/V0 are rewritten in place as the
        U/V outputs, the rest is released at dispatch where the runtime
        supports it — and holding ONE block's planes at a time instead of a
        whole stacked bucket is itself the larger live-footprint cut
        (``bench_roofline --gibbs-peak`` measures both);
      * with >1 device, ready blocks are assigned to the LEAST-LOADED
        healthy device group (per-group streams, zero inter-group
        collectives; priors device_put to the target group are the
        phase-boundary O(K²) summaries — the paper's whole budget); a
        group of >1 devices runs the block's chain 'data'-sharded
        (``distributed.run_gibbs_group``, intra-group collectives only).
        Each group holds at most ``depth`` blocks in flight; the rest of
        its share stays STAGED (assigned but undispatched), which is what
        makes the elastic layer possible: an idle group STEALS the
        highest-priority staged block from the most-loaded group, a group
        whose dispatches expire ``quarantine_after`` consecutive times is
        QUARANTINED (staged share re-queued, in-flight blocks rebalanced
        onto healthy groups under the same keys), and a straggling
        dispatch past ``speculate_at ×`` the group's own rate estimate is
        SPECULATIVELY twinned on an idle group — resolution commits the
        deterministic canonical-group winner and cancels the twin, so
        results stay bitwise identical to the fault-free run. With ONE
        group all of this is inert and dispatch is unbounded (legacy
        behavior).

    ``record_trace=True`` appends (event, coord, group) events to
    ``self.trace`` in real order (see ``Executor`` for the schema); the
    stress tests use it to assert no block ever dispatches before its
    dependencies resolved. ``_is_resolved`` is the completion-detection
    seam tests override to fake arbitrary completion orders.

    ``priority=True`` (default) pops the ready queue critical-path-first:
    ready blocks are ordered by their bottom-level (estimated cost + the
    longest estimated chain through their successors,
    ``critical_path_priority``), so on skewed grids the dense phase-b
    blocks that gate whole phase-c rows/columns dispatch before the
    near-empty stragglers. ``priority=False`` restores plain FIFO.
    """
    name = "async"

    def __init__(self, donate: bool = True, block_mesh=None,
                 record_trace: bool = False, priority: bool = True,
                 topology: Optional[Topology] = None, comm: str = "gather",
                 depth: int = 2):
        super().__init__(record_trace=record_trace)
        if topology is None:
            # legacy spellings: a 1-D 'block' mesh (or None = all local
            # devices) means single-device streams
            topology = Topology.from_spec(block_mesh)
        elif block_mesh is not None:
            raise ValueError("pass block_mesh OR topology, not both")
        else:
            topology = Topology.from_spec(topology)
        if int(depth) < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        self.topology = topology
        self.comm = comm
        self.donate = donate
        self.devices = topology.devices
        self.priority = priority
        self.depth = int(depth)    # per-group in-flight cap (multi-group)
        self._n_dispatched = 0

    def run_phase(self, ctx, phase, tasks):
        raise NotImplementedError(
            "AsyncExecutor overlaps phases — it schedules whole graphs "
            "(run_graph), not single phases")

    # -- completion-detection seam (tests fake completion order here) -----
    def _is_resolved(self, coord: Coord, signal) -> bool:
        return signal.is_ready()

    def _reset_run_state(self):
        super()._reset_run_state()
        self._n_dispatched = 0

    def run_graph(self, ctx, graph, verbose: bool = False):
        self._reset_run_state()
        tasks, phase_of, waiting, succ, ready = _dep_state(
            ctx, graph, self.priority)
        est = _block_cost_estimates(ctx, tasks)
        pol = ctx.policy
        G = max(1, self.topology.block)
        health = _GroupHealth(G, pol.quarantine_after)
        elastic = G > 1    # one group: nowhere to rebalance/steal/twin
        cap = self.depth if elastic else None   # per-group in-flight cap
        # per-group staged share (assigned, undispatched — the steal pool)
        staged = [_ReadyQueue(ready._prio) for _ in range(G)]
        flights: Dict[Coord, List[_Flight]] = {}  # >1 = speculative twins
        outcomes: Dict[Coord, BlockOutcome] = {}
        spans: Dict[Coord, Tuple[float, float]] = {}
        first_d: Dict[str, float] = {}
        last_r: Dict[str, float] = {}
        remaining = {ph: len(ts) for ph, ts in graph}
        t0 = time.time()

        def n_inflight(g):
            return sum(1 for fl in flights.values()
                       for f in fl if f.group == g)

        def n_assigned(g):
            return len(staged[g]) + n_inflight(g)

        def pick_group():
            return min(health.healthy(), key=lambda g: (n_assigned(g), g))

        def deadline(c, f):
            # per-group watchdog deadline: generous floor + slack × the
            # group's OWN calibrated rate (EWMA seconds/cost; cold groups
            # inherit the fastest calibrated rate, 0 until any group
            # calibrates — early blocks get the floor alone). A false
            # expiry is benign: re-dispatch reuses attempt-0 keys, so a
            # slow-but-alive block still resolves bitwise-identically.
            return (pol.timeout_floor_s
                    + pol.timeout_slack * health.rate(f.group) * est[c])

        def flight_ready(c, f):
            if ctx.is_hung(c):
                return False
            if f.sup and time.time() < f.sup:
                return False
            return self._is_resolved(c, f.sig)

        def retire(c, out, td, kind=None, group=None):
            self._record("resolve", c, group)
            out = _commit_guard(ctx, tasks[c], out, kind=kind)
            tr = time.time()
            if not out.seconds:
                out.seconds = tr - td
            if kind is None and group is not None:
                # per-group EWMA rate; the group's first resolve (compile
                # span) is dropped inside observe()
                health.observe(group, out.seconds / est[c])
            spans[c] = (td - t0, tr - t0)
            outcomes[c] = out
            ctx.note_resolved(tasks[c], out)
            ph = phase_of[c]
            remaining[ph] -= 1
            last_r[ph] = tr - t0
            if verbose and remaining[ph] == 0:
                ts = [t for t in tasks.values() if phase_of[t.coord] == ph]
                print(f"[pp:{self.name}] phase {ph}: {len(ts)} block(s) "
                      f"{_phase_desc(ctx, ts)} "
                      f"{last_r[ph] - first_d[ph]:.2f}s "
                      f"(dispatch→resolve envelope; phases overlap)",
                      flush=True)
            for s in succ[c]:
                waiting[s] -= 1
                if waiting[s] == 0:
                    ready.push(s)

        def dispatch_on(c, g, event):
            """Dispatch block ``c`` on group ``g``. Returns False when the
            dispatch failed (already healed through the retire path)."""
            self._record(event, c, g)
            td = time.time()
            first_d.setdefault(phase_of[c], td - t0)
            ordinal = ctx.next_group_ordinal(g)
            sup = ctx.group_suppressed_until(g, ordinal, td)
            try:
                sig, out = self._dispatch(ctx, tasks[c], group=g)
            except _DISPATCH_ERRORS:
                retire(c, None, td, kind="dispatch", group=g)
                return False
            flights.setdefault(c, []).append(
                _Flight(sig=sig, out=out, td=td, group=g, sup=sup))
            return True

        def quarantine_group(g, trigger):
            """Drain group ``g``: no future dispatch targets it, its
            staged share returns to the global ready queue, and its
            in-flight blocks rebalance onto healthy groups under the SAME
            keys (kind="group" — no block retry budget is consumed)."""
            health.quarantine(g)
            self._record("quarantine", trigger, g)
            self.n_quarantined += 1
            ctx.record_fault(trigger, "group", "quarantined")
            _maybe_degrade_topology(ctx, health)      # may raise (ckpt
            while staged[g]:                          # already flushed)
                ready.push(staged[g].pop())
            for c2 in list(flights):
                fl = flights.get(c2, [])
                mine = [f for f in fl if f.group == g]
                if not mine:
                    continue
                keep = [f for f in fl if f.group != g]
                if keep:
                    # its healthy twin flies on: this side just cancels
                    for f in mine:
                        self._record("cancel", c2, g)
                        self.n_cancels += 1
                    flights[c2] = keep
                    continue
                flights.pop(c2)
                self._record("expire", c2, g)
                ctx.record_fault(c2, "group", "rebalanced")
                dispatch_on(c2, pick_group(), "redispatch")

        def handle_expiries(now):
            """Watchdog sweep: expire overdue flights, count consecutive
            expiries toward quarantine, re-dispatch or terminally retire.
            Returns True when any state changed."""
            changed = False
            for c in list(flights):
                fl = flights.get(c)
                if fl is None:
                    continue
                dead = [f for f in fl if now - f.td > deadline(c, f)]
                if not dead:
                    continue
                changed = True
                live = [f for f in fl if f not in dead]
                if live:
                    # the twin flies on — the expired side only cancels
                    flights[c] = live
                    for f in dead:
                        self._record("cancel", c, f.group)
                        self.n_cancels += 1
                        if elastic and health.note_expiry(f.group):
                            quarantine_group(f.group, c)
                    continue
                flights.pop(c)
                self._record("expire", c, dead[0].group)
                for f in dead[1:]:
                    self._record("cancel", c, f.group)
                    self.n_cancels += 1
                for f in dead:
                    if elastic and health.note_expiry(f.group):
                        quarantine_group(f.group, c)
                if ctx.cur_attempt(c) < pol.max_retries:
                    ctx.record_fault(c, "timeout", "redispatched")
                    ctx.attempts[c] = ctx.cur_attempt(c) + 1
                    dispatch_on(c, pick_group(), "redispatch")
                else:
                    retire(c, None, dead[0].td, kind="timeout",
                           group=dead[0].group)
            return changed

        def maybe_speculate(now):
            """Straggler hedge: a sole flight past ``speculate_at ×`` its
            group's calibrated deadline model is twinned on an idle
            healthy group with the SAME attempt-0 key."""
            if not elastic or pol.speculate_at <= 0.0:
                return
            for c in list(flights):
                fl = flights.get(c)
                if fl is None or len(fl) != 1:
                    continue
                f = fl[0]
                r = health.rate(f.group)
                if r <= 0.0 or now - f.td <= pol.speculate_at * r * est[c]:
                    continue
                idle = [g for g in health.healthy()
                        if g != f.group and not staged[g]
                        and (cap is None or n_inflight(g) < cap)]
                if not idle:
                    continue
                g2 = min(idle, key=lambda g: (n_assigned(g), g))
                td = time.time()
                ordinal = ctx.next_group_ordinal(g2)
                sup = ctx.group_suppressed_until(g2, ordinal, td)
                try:
                    sig, out = self._dispatch(ctx, tasks[c], group=g2)
                except _DISPATCH_ERRORS:
                    continue    # the primary still flies; skip the twin
                self._record("speculate", c, g2)
                self.n_speculations += 1
                fl.append(_Flight(sig=sig, out=out, td=td, group=g2,
                                  sup=sup))

        def await_progress():
            """Adaptive-sleep poll until a flight resolves or the watchdog
            changes state (expiry/quarantine). ``watchdog=False`` restores
            the legacy block-on-oldest fallback, which deadlocks if the
            oldest in-flight block died — keep it on."""
            if not pol.watchdog:
                c0 = min(flights, key=lambda c: flights[c][0].td)
                jax.block_until_ready(flights[c0][0].sig)
                return
            sleep = 5e-5
            while flights:
                if any(flight_ready(c, f) for c, fl in flights.items()
                       for f in fl):
                    return
                now = time.time()
                if handle_expiries(now):
                    return
                maybe_speculate(now)
                time.sleep(sleep)
                sleep = min(sleep * 2, 2e-3)

        while ready or any(staged) or flights:
            # assign fresh ready blocks to the least-loaded healthy group
            while ready:
                staged[pick_group()].push(ready.pop())
            progress = False
            for g in health.healthy():
                while staged[g] and (cap is None or n_inflight(g) < cap):
                    dispatch_on(staged[g].pop(), g, "dispatch")
                    progress = True
            if elastic and not progress:
                # work stealing: an idle healthy group takes the highest-
                # priority STAGED block from the most-loaded group
                for g in health.healthy():
                    if staged[g] or (cap is not None
                                     and n_inflight(g) >= cap):
                        continue
                    victims = [h for h in health.healthy()
                               if h != g and staged[h]]
                    if not victims:
                        continue
                    v = max(victims, key=lambda h: (n_assigned(h), -h))
                    c = staged[v].pop()
                    self._record("steal", c, g)
                    self.n_steals += 1
                    dispatch_on(c, g, "dispatch")
                    progress = True
            if progress or not flights:
                continue
            await_progress()
            resolved = [c for c, fl in flights.items()
                        if any(flight_ready(c, f) for f in fl)]
            for c in resolved:
                fl = flights.pop(c, None)
                if fl is None:
                    continue
                rd = [f for f in fl if flight_ready(c, f)]
                if not rd:
                    flights[c] = fl
                    continue
                # deterministic winner: canonical group order among the
                # READY flights — twins share the attempt-0 key so either
                # outcome is bitwise the fault-free numbers, and the
                # canonical rule keeps the committed handles/trace
                # independent of wall-clock completion order
                win = min(rd, key=lambda f: f.group)
                for f in fl:
                    if f is not win:
                        self._record("cancel", c, f.group)
                        self.n_cancels += 1
                # the store may hold a losing twin's handles (written at
                # its dispatch) — successors must consume the winner's
                ctx.U_posts[c] = win.out.U_post
                ctx.V_posts[c] = win.out.V_post
                health.note_resolve(win.group)
                retire(c, win.out, win.td, group=win.group)
        # per-phase envelopes: first dispatch → last resolve. Phases
        # overlap, so these may sum to MORE than the wall time.
        phase_times = {ph: last_r[ph] - first_d[ph] for ph in first_d}
        return outcomes, phase_times, spans

    def _dispatch(self, ctx: PhaseContext, task: BlockTask,
                  group: Optional[int] = None):
        """Dispatch one block's jitted chain without waiting for anything:
        inputs may still be computing (JAX chains the dataflow) and no
        output is synced. ``group`` is the scheduler-chosen target device
        group (None = legacy round-robin). Returns (completion scalar,
        device outcome)."""
        ctx.check_dispatch(task.coord)
        blk = ctx.part.block(task.i, task.j)
        s = ctx.shapes[task.phase]
        up, vp = ctx.priors(task)
        csr_r, csr_c, tr, tc, tv, tmask, up, vp = PP.pad_block_inputs(
            blk, s, ctx.cfg.K, ctx.test_p, up, vp,
            poison_nan=ctx.should_poison(task.coord))
        n_obs = int(tmask.sum())
        key = ctx.keys[task.i, task.j]
        topo = self.topology
        g = (self._n_dispatched % topo.block) if group is None \
            else int(group)
        if topo.n_devices > 1:
            # per-GROUP streams: the block's padded planes plus the O(K²)
            # prior summaries move to the target group — the latter IS the
            # paper's phase-boundary communication, made explicit. With
            # data == 1 a group is the single device of the legacy
            # round-robin; with data > 1 the planes are replicated across
            # the group and the chain shards its sweep over them.
            if topo.data == 1:
                target = topo.group(g)[0]
            else:
                from jax.sharding import NamedSharding, PartitionSpec
                target = NamedSharding(topo.group_mesh_2d(g),
                                       PartitionSpec())
            (ra, ca, tr, tc, up, vp, tv, tmask, key) = jax.device_put(
                ((csr_r.idx, csr_r.val, csr_r.mask),
                 (csr_c.idx, csr_c.val, csr_c.mask),
                 tr, tc, up, vp, tv, tmask, key), target)
            csr_r = PaddedCSR(*ra, n_cols=csr_r.n_cols)
            csr_c = PaddedCSR(*ca, n_cols=csr_c.n_cols)
        self._n_dispatched += 1
        if topo.data > 1:
            from repro.core import distributed as DIST
            csrt = (None if self.comm == "gather" else
                    tuple(x[0] for x in _stacked_csrt(
                        ctx, [task], s, topo.data,
                        scatter=(self.comm == "scatter"))))
            res = DIST.run_gibbs_group(
                key, csr_r, csr_c, jnp.asarray(tr), jnp.asarray(tc),
                ctx.block_cfg(task), topo, group=g, U_prior=up, V_prior=vp,
                donate=self.donate, comm=self.comm, csrt=csrt)
        else:
            res = GIBBS.run_gibbs(key, csr_r, csr_c,
                                  jnp.asarray(tr), jnp.asarray(tc),
                                  ctx.block_cfg(task), U_prior=up,
                                  V_prior=vp, donate=self.donate)
        nr, nc = len(blk.row_ids), len(blk.col_ids)
        U_post = RowGaussians(eta=res.U_post.eta[:nr],
                              Lambda=res.U_post.Lambda[:nr])
        V_post = RowGaussians(eta=res.V_post.eta[:nc],
                              Lambda=res.V_post.Lambda[:nc])
        sq = _block_sq_err(res.acc.pred_sum, res.acc.pred_cnt,
                           jnp.asarray(tv), jnp.asarray(tmask))
        # device-resident store write happens AT DISPATCH: successors (and
        # the final jitted aggregation) consume these handles as dataflow
        ctx.U_posts[task.coord] = U_post
        ctx.V_posts[task.coord] = V_post
        out = BlockOutcome(U_post=U_post, V_post=V_post,
                           pred_mean=None, seconds=0.0,
                           sq_err=sq, n_obs=n_obs, health=res.health)
        return sq, out


# Per-block masked Σ(pred-val)² over a (W, n_test) window chunk — the SAME
# scalar as _block_sq_err, batched: one tiny (W,) vector is the chunk's
# completion signal AND its RMSE numerators.
_chunk_sq_err = jax.jit(jax.vmap(_block_sq_err))


def _dummy_prior(n: int, K: int) -> RowGaussians:
    """Placeholder prior rows for flag=0 slots of a window chunk. Never
    selected (the per-block flag routes those blocks to the resampled NW
    hyperprior); only has to be finite so the unused ``where`` branch is
    well-defined."""
    return RowGaussians(eta=jnp.zeros((n, K)),
                        Lambda=jnp.broadcast_to(jnp.eye(K), (n, K, K)))


@dataclass
class _StagedChunk:
    """A window chunk whose host→device transfer has been issued (the
    prefetch): device leaves + per-block metadata, waiting to dispatch."""
    tasks: List[BlockTask]        # true tasks, ≤ W (repeat-padded to W)
    shape: "PP.BlockShapes"
    cfg: BMF.BMFConfig
    dev: Tuple                    # (ri, rv, rm, ci, cv, cm, tr, tc, tv, tm)
    keys: jax.Array               # (W,) typed PRNG keys
    U_prior: RowGaussians         # (W, n_rows, ...) padded (dummies where off)
    V_prior: RowGaussians
    u_use: jax.Array              # (W,) {0,1} prior flags
    v_use: jax.Array
    n_obs: List[int]
    group: int = 0                # topology device group this chunk targets


class StreamingExecutor(Executor):
    """Bounded-window streaming schedule for out-of-memory block grids.

    The stacked executor materializes a whole phase bucket on device at
    once — ``num_blocks_in_bucket × block_bytes`` — which web-scale grids
    (thousands of blocks) cannot co-resident in HBM. This executor runs the
    SAME dependency-driven ready queue as the async scheduler but moves
    blocks through a bounded window of ``W`` donated block buffers:

      * ready blocks are popped critical-path-first (``_ReadyQueue`` over
        ``critical_path_priority``) and grouped into chunks of up to W
        blocks sharing one window shape and chain config (short chunks are
        repeat-padded to exactly W so ONE executable serves every chunk);
      * each chunk's CSR planes/test entries are assembled on the HOST
        (``pp.pad_block_inputs_host``) and shipped with one async
        ``device_put`` — the double-buffered prefetch: the next chunk's
        H2D transfer runs while the current chunk computes;
      * chunks dispatch through ``gibbs.run_gibbs_stacked(donate=True)``:
        XLA recycles the window buffers (U0/V0 alias the U/V outputs, the
        planes return to the allocator), so the live input footprint is
        ``≤ W × (depth + 1) × block_bytes`` — flat in the grid size
        (``peak_window_blocks`` records the realized bound;
        ``bench_roofline --gibbs-peak`` measures it);
      * completion is detected by non-blocking ``is_ready()`` polls on each
        chunk's (W,) squared-error vector, falling back to blocking on the
        OLDEST in-flight chunk only — same contract as the async executor,
        and the same ``_is_resolved`` seam for the conformance fake-delay
        stress;
      * per-phase shape buckets are COALESCED first
        (``pp.BlockShapes.coalesce`` / ``partition.coalesce_shapes``):
        buckets within the waste budget share one window shape, and the
        per-block prior flags (``run_gibbs_stacked(prior_use=...)``) let
        that single executable serve phase-a/b/c blocks despite their
        different prior structures.

    Per-block chains are the stacked executor's vmapped semantics (same
    keys, same padding), so RMSE matches serial to batched-fp tolerance
    and results are bit-identical across runs regardless of how completion
    timing regroups the chunks.

    ``max_waste`` defaults to 1.0 — only bit-identical shapes merge, which
    preserves exact chain parity with the serial/stacked reference (the
    padded row count feeds the NW hyper-resample and the RNG shapes, so
    ANY padding change perturbs the chains). Raising it trades that strict
    parity for fewer window executables and a single recycled buffer pool:
    results remain valid Gibbs chains, just not the reference's draws.
    """
    name = "streaming"

    def __init__(self, window: int = 4, donate: bool = True,
                 max_waste: float = 1.0, priority: bool = True,
                 depth: int = 2, record_trace: bool = False,
                 topology: Optional[Topology] = None, comm: str = "gather"):
        super().__init__(record_trace=record_trace)
        if int(window) < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        if int(depth) < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        self.window = int(window)
        self.donate = donate
        self.max_waste = max_waste
        self.priority = priority
        self.depth = int(depth)               # in-flight chunks before block
        self.topology = Topology.from_spec(topology) if topology is not None \
            else Topology(block=1, data=1)
        if comm != "gather":
            # window chunks use prior_use-flagged executables; only the
            # 'gather' intra-group exchange composes with them (and at
            # data == 1 no other mode means anything)
            raise ValueError(
                f"streaming executor supports comm='gather' only, "
                f"got {comm!r}")
        self.comm = comm
        if self.topology.n_devices > 1:
            self.devices = self.topology.devices
        self.peak_window_blocks = 0           # realized live-buffer bound
        self.window_shapes: Optional[Dict[str, "PP.BlockShapes"]] = None

    def run_phase(self, ctx, phase, tasks):
        raise NotImplementedError(
            "StreamingExecutor streams whole graphs through its window "
            "(run_graph), not single phases")

    # -- completion-detection seam (tests fake completion order here) -----
    def _is_resolved(self, coord: Coord, signal) -> bool:
        return signal.is_ready()

    def _group_key(self, ctx, task, shapes):
        cfg = ctx.block_cfg(task)
        return (id(shapes[task.phase]), cfg.n_samples, cfg.burnin)

    def _pop_chunk(self, ctx, ready: _GroupedReadyQueue,
                   tasks) -> List[BlockTask]:
        """Up to W ready blocks sharing the top-priority block's window
        shape and chain config — priority order within the group."""
        return [tasks[c] for c in ready.pop_chunk(self.window)]

    def _group_target(self, g: int):
        """device_put destination for group ``g``'s window buffers: the
        group's device (data == 1) or a replicated sharding over its
        (1, data) submesh — the per-STREAM prefetch lands the H2D transfer
        on the group that will compute the chunk."""
        if self.topology.n_devices == 1:
            return None
        if self.topology.data == 1:
            return self.topology.group(g)[0]
        from jax.sharding import NamedSharding, PartitionSpec
        return NamedSharding(self.topology.group_mesh_2d(g),
                             PartitionSpec())

    def _stage(self, ctx: PhaseContext, chunk: List[BlockTask],
               shapes, group: int = 0) -> _StagedChunk:
        """Assemble one chunk on the host and issue its (async) H2D
        transfer to the target group. Deps are resolved (the chunk came
        off the ready queue), so the device-resident priors are read here
        too — moving them to the group is the phase-boundary O(K²)
        communication, made explicit."""
        s = shapes[chunk[0].phase]
        K = ctx.cfg.K
        W = self.window
        sel = list(range(len(chunk))) + [len(chunk) - 1] * (W - len(chunk))
        host = [PP.pad_block_inputs_host(ctx.part.block(t.i, t.j), s,
                                         ctx.test_p,
                                         poison_nan=ctx.should_poison(t.coord))
                for t in chunk]

        def stack(get):
            return np.stack([get(host[i]) for i in sel])

        host_leaves = (stack(lambda h: h[0].idx), stack(lambda h: h[0].val),
                       stack(lambda h: h[0].mask),
                       stack(lambda h: h[1].idx), stack(lambda h: h[1].val),
                       stack(lambda h: h[1].mask),
                       stack(lambda h: h[2]), stack(lambda h: h[3]),
                       stack(lambda h: h[4]), stack(lambda h: h[5]))
        target = self._group_target(group)
        # ONE async transfer per chunk, onto the chunk's group
        dev = (jax.device_put(host_leaves) if target is None
               else jax.device_put(host_leaves, target))

        ups, vps, uf, vf = [], [], [], []
        for t in chunk:
            up, vp = ctx.priors(t)
            uf.append(float(up is not None))
            vf.append(float(vp is not None))
            ups.append(PP._pad_prior(up, s.n_rows, K) if up is not None
                       else _dummy_prior(s.n_rows, K))
            vps.append(PP._pad_prior(vp, s.n_cols, K) if vp is not None
                       else _dummy_prior(s.n_cols, K))
        sel_tasks = [chunk[i] for i in sel]
        ii = np.array([t.i for t in sel_tasks])
        jj = np.array([t.j for t in sel_tasks])
        U_pri = _stack_trees([ups[i] for i in sel])
        V_pri = _stack_trees([vps[i] for i in sel])
        keys = ctx.keys[ii, jj]
        if target is not None:
            # posteriors may live on another group: colocate prior
            # summaries and keys with the chunk's window buffers
            U_pri, V_pri, keys = jax.device_put((U_pri, V_pri, keys), target)
        return _StagedChunk(
            tasks=chunk, shape=s, cfg=ctx.block_cfg(chunk[0]), dev=dev,
            keys=keys, U_prior=U_pri, V_prior=V_pri,
            u_use=jnp.asarray([uf[i] for i in sel], jnp.float32),
            v_use=jnp.asarray([vf[i] for i in sel], jnp.float32),
            n_obs=[int(h[5].sum()) for h in host], group=group)

    def _dispatch(self, ctx: PhaseContext, st: _StagedChunk):
        """Dispatch one staged chunk; returns (signal, outcomes). The
        window buffers are donated — after this call nothing holds them
        and XLA recycles their storage for the next chunk."""
        ri, rv, rm, ci, cv, cm, tr, tc, tv, tmask = st.dev
        csr_r = PaddedCSR(ri, rv, rm, n_cols=st.shape.n_cols)
        csr_c = PaddedCSR(ci, cv, cm, n_cols=st.shape.n_rows)
        if self.topology.data > 1:
            from repro.core import distributed as DIST
            res = DIST.run_gibbs_stacked_2d(
                st.keys, csr_r, csr_c, tr, tc, st.cfg, self.topology,
                U_prior=st.U_prior, V_prior=st.V_prior,
                prior_use=(st.u_use, st.v_use), donate=self.donate,
                comm=self.comm,
                mesh=self.topology.group_mesh_2d(st.group))
        else:
            res = GIBBS.run_gibbs_stacked(
                st.keys, csr_r, csr_c, tr, tc, st.cfg,
                U_prior=st.U_prior, V_prior=st.V_prior,
                prior_use=(st.u_use, st.v_use), donate=self.donate)
        sq = _chunk_sq_err(res.acc.pred_sum, res.acc.pred_cnt, tv, tmask)
        outs: Dict[Coord, BlockOutcome] = {}
        for b, t in enumerate(st.tasks):      # padded duplicates dropped
            blk = ctx.part.block(t.i, t.j)
            nr, nc = len(blk.row_ids), len(blk.col_ids)
            U_post = RowGaussians(eta=res.U_post.eta[b, :nr],
                                  Lambda=res.U_post.Lambda[b, :nr])
            V_post = RowGaussians(eta=res.V_post.eta[b, :nc],
                                  Lambda=res.V_post.Lambda[b, :nc])
            ctx.U_posts[t.coord] = U_post
            ctx.V_posts[t.coord] = V_post
            outs[t.coord] = BlockOutcome(
                U_post=U_post, V_post=V_post, pred_mean=None, seconds=0.0,
                sq_err=sq[b], n_obs=st.n_obs[b],
                health=(res.health[b] if res.health is not None else None))
        return sq, outs

    def _reset_run_state(self):
        super()._reset_run_state()
        self.peak_window_blocks = 0
        self.window_shapes = None

    def run_graph(self, ctx, graph, verbose: bool = False):
        self._reset_run_state()
        shapes = PP.BlockShapes.coalesce(ctx.shapes, ctx.cfg.K,
                                         self.max_waste)
        tasks, phase_of, waiting, succ, ready = _dep_state(
            ctx, graph, self.priority,
            make_queue=lambda prio, ts: _GroupedReadyQueue(
                prio, lambda c: self._group_key(ctx, ts[c], shapes)))
        self.window_shapes = shapes
        G = self.topology.block
        pol = ctx.policy
        health = _GroupHealth(G, pol.quarantine_after)
        elastic = G > 1    # one group: nowhere to rebalance/steal/twin
        if verbose:
            n_buckets = len({id(s) for s in shapes.values()})
            print(f"[pp:{self.name}] window={self.window} depth={self.depth} "
                  f"{n_buckets} coalesced bucket(s) over {len(shapes)} phase "
                  f"tag(s), {G} stream group(s) x {self.topology.data} "
                  f"device(s)", flush=True)

        # one W-bounded donated window PER DEVICE GROUP: each group runs
        # its own stream of chunks (own prefetch slot + its share of the
        # in-flight chunk flights, capped at ``depth``)
        staged: List[Optional[_StagedChunk]] = [None] * G
        flights: Dict[int, _Flight] = {}    # flight id -> chunk flight
        twin: Dict[int, int] = {}           # speculative twin links (both ways)
        fid_next = [0]
        outcomes: Dict[Coord, BlockOutcome] = {}
        spans: Dict[Coord, Tuple[float, float]] = {}
        first_d: Dict[str, float] = {}
        last_r: Dict[str, float] = {}
        remaining = {ph: len(ts) for ph, ts in graph}
        t0 = time.time()

        def n_inflight(g):
            return sum(1 for f in flights.values() if f.group == g)

        def note_peak():
            live = self.window * (len(flights)
                                  + sum(st is not None for st in staged))
            self.peak_window_blocks = max(self.peak_window_blocks, live)

        est = _block_cost_estimates(ctx, tasks)

        def chunk_cost(ts_):
            return sum(est[t.coord] for t in ts_)

        def deadline(f):
            # per-group watchdog deadline over the chunk's total estimated
            # cost (one executable runs all its members); the group's OWN
            # EWMA rate, cold groups inherit the fastest calibrated one
            return (pol.timeout_floor_s + pol.timeout_slack
                    * health.rate(f.group) * chunk_cost(f.tasks))

        def flight_ready(f):
            if any(ctx.is_hung(t.coord) for t in f.tasks):
                return False
            if f.sup and time.time() < f.sup:
                return False
            return self._is_resolved(f.tasks[0].coord, f.sig)

        def retire(t, out, td, tr_, per, kind=None, group=None):
            c = t.coord
            self._record("resolve", c, group)
            out = _commit_guard(ctx, tasks[c], out, kind=kind)
            if not out.seconds:
                out.seconds = per
            spans[c] = (td - t0, tr_ - t0)
            outcomes[c] = out
            ctx.note_resolved(tasks[c], out)
            ph = phase_of[c]
            remaining[ph] -= 1
            last_r[ph] = tr_ - t0
            if verbose and remaining[ph] == 0:
                ts2 = [t2 for t2 in tasks.values()
                       if phase_of[t2.coord] == ph]
                print(f"[pp:{self.name}] phase {ph}: {len(ts2)} "
                      f"block(s) {_phase_desc(ctx, ts2)} "
                      f"{last_r[ph] - first_d[ph]:.2f}s "
                      f"(dispatch→resolve envelope; phases overlap)",
                      flush=True)
            for s2 in succ[c]:
                waiting[s2] -= 1
                if waiting[s2] == 0:
                    ready.push(s2)

        def launch(ch: _StagedChunk, event: str) -> int:
            """Dispatch a staged chunk on its group; returns the flight
            id. The chunk's dispatch consumes one group ordinal (the
            group-level injection unit)."""
            g = ch.group
            td = time.time()
            for t in ch.tasks:
                self._record(event, t.coord, g)
                first_d.setdefault(phase_of[t.coord], td - t0)
            ordinal = ctx.next_group_ordinal(g)
            sup = ctx.group_suppressed_until(g, ordinal, td)
            sig, outs = self._dispatch(ctx, ch)
            fid = fid_next[0]
            fid_next[0] += 1
            flights[fid] = _Flight(sig=sig, out=outs, td=td, group=g,
                                   sup=sup, tasks=ch.tasks)
            note_peak()
            return fid

        def least_loaded():
            return min(health.healthy(), key=lambda g: (n_inflight(g), g))

        def stage_next(g) -> Optional[_StagedChunk]:
            """Pop + stage the group's next chunk, healing dispatch-failure
            injections at chunk formation (the flagged block never joins
            the window; the rest of the chunk is unaffected)."""
            while ready:
                chunk = self._pop_chunk(ctx, ready, tasks)
                good = []
                for t in chunk:
                    try:
                        ctx.check_dispatch(t.coord)
                        good.append(t)
                    except _DISPATCH_ERRORS:
                        self._record("dispatch", t.coord, g)
                        now = time.time()
                        first_d.setdefault(phase_of[t.coord], now - t0)
                        retire(t, None, now, time.time(), 0.0,
                               kind="dispatch", group=g)
                if good:
                    return self._stage(ctx, good, shapes, group=g)
            return None

        def quarantine_group(g, trigger):
            """Drain group ``g``: its staged window buffers are RELEASED
            (the chunk's blocks return to the ready queue, dropping the
            device leaves), and its in-flight chunks re-stage on healthy
            groups under the same keys (kind="group" — no block retry
            budget consumed)."""
            health.quarantine(g)
            self._record("quarantine", trigger, g)
            self.n_quarantined += 1
            ctx.record_fault(trigger, "group", "quarantined")
            _maybe_degrade_topology(ctx, health)      # may raise (ckpt
            if staged[g] is not None:                 # already flushed)
                for t in staged[g].tasks:
                    ready.push(t.coord)
                staged[g] = None
            for fid in [i for i, f in flights.items() if f.group == g]:
                f = flights.pop(fid)
                tw = twin.pop(fid, None)
                if tw is not None:
                    # its healthy twin flies on: this side just cancels
                    twin.pop(tw, None)
                    for t in f.tasks:
                        self._record("cancel", t.coord, g)
                    self.n_cancels += len(f.tasks)
                    continue
                for t in f.tasks:
                    self._record("expire", t.coord, g)
                    ctx.record_fault(t.coord, "group", "rebalanced")
                st2 = self._stage(ctx, f.tasks, shapes,
                                  group=least_loaded())
                launch(st2, "redispatch")

        def handle_expiries(now):
            """Watchdog sweep over the chunk flights; True on any state
            change (expiry, quarantine, redispatch, terminal retire)."""
            changed = False
            for fid in list(flights):
                f = flights.get(fid)
                if f is None or now - f.td <= deadline(f):
                    continue
                changed = True
                flights.pop(fid)
                tw = twin.pop(fid, None)
                if tw is not None and tw in flights:
                    # the twin flies on — the expired side only cancels
                    twin.pop(tw, None)
                    for t in f.tasks:
                        self._record("cancel", t.coord, f.group)
                    self.n_cancels += len(f.tasks)
                    if elastic and health.note_expiry(f.group):
                        quarantine_group(f.group, f.tasks[0].coord)
                    continue
                for t in f.tasks:
                    self._record("expire", t.coord, f.group)
                if elastic and health.note_expiry(f.group):
                    quarantine_group(f.group, f.tasks[0].coord)
                if all(ctx.cur_attempt(t.coord) < pol.max_retries
                       for t in f.tasks):
                    # re-stage on the least-loaded healthy group with the
                    # same keys — a slow-but-alive chunk re-resolves to
                    # bitwise-identical numbers
                    for t in f.tasks:
                        ctx.record_fault(t.coord, "timeout", "redispatched")
                        ctx.attempts[t.coord] = ctx.cur_attempt(t.coord) + 1
                    st2 = self._stage(ctx, f.tasks, shapes,
                                      group=least_loaded())
                    launch(st2, "redispatch")
                else:
                    for t in f.tasks:
                        retire(t, None, f.td, now, 0.0, kind="timeout",
                               group=f.group)
            return changed

        def maybe_speculate(now):
            """Straggler hedge: an untwinned chunk past ``speculate_at ×``
            its group's calibrated deadline model re-stages on an idle
            healthy group with the SAME keys."""
            if not elastic or pol.speculate_at <= 0.0:
                return
            for fid in list(flights):
                f = flights.get(fid)
                if f is None or fid in twin:
                    continue
                r = health.rate(f.group)
                if (r <= 0.0 or now - f.td
                        <= pol.speculate_at * r * chunk_cost(f.tasks)):
                    continue
                idle = [g for g in health.healthy()
                        if g != f.group and staged[g] is None
                        and n_inflight(g) < self.depth]
                if not idle:
                    continue
                g2 = min(idle, key=lambda g: (n_inflight(g), g))
                for t in f.tasks:
                    self._record("speculate", t.coord, g2)
                self.n_speculations += len(f.tasks)
                try:
                    st2 = self._stage(ctx, f.tasks, shapes, group=g2)
                    td = time.time()
                    ordinal = ctx.next_group_ordinal(g2)
                    sup = ctx.group_suppressed_until(g2, ordinal, td)
                    sig, outs = self._dispatch(ctx, st2)
                except _DISPATCH_ERRORS:
                    for t in f.tasks:
                        self._record("cancel", t.coord, g2)
                    self.n_cancels += len(f.tasks)
                    continue    # the primary still flies; skip the twin
                fid2 = fid_next[0]
                fid_next[0] += 1
                flights[fid2] = _Flight(sig=sig, out=outs, td=td, group=g2,
                                        sup=sup, tasks=f.tasks)
                twin[fid] = fid2
                twin[fid2] = fid
                note_peak()

        def await_flights():
            """Adaptive poll until a chunk resolves or the watchdog
            changes state; ``watchdog=False`` restores the legacy
            block-on-oldest-chunk fallback."""
            if not pol.watchdog:
                f0 = min(flights.values(), key=lambda f: f.td)
                jax.block_until_ready(f0.sig)
                return
            sleep = 5e-5
            while flights:
                if any(flight_ready(f) for f in flights.values()):
                    return
                now = time.time()
                if handle_expiries(now):
                    return
                maybe_speculate(now)
                time.sleep(sleep)
                sleep = min(sleep * 2, 2e-3)

        while (ready or any(st is not None for st in staged) or flights):
            progress = False
            for g in health.healthy():
                # fair staging: every idle group stages ONE chunk before
                # any group prefetches a second — a greedy first group
                # would starve the rest of the mesh whenever the DAG
                # releases blocks a few at a time
                if staged[g] is None and ready:
                    staged[g] = stage_next(g)
                    note_peak()
            for g in health.healthy():
                if staged[g] is not None and n_inflight(g) < self.depth:
                    ch, staged[g] = staged[g], None
                    launch(ch, "dispatch")
                    # per-stream double-buffered prefetch: the group's NEXT
                    # chunk's H2D transfer overlaps this chunk's compute
                    if ready:
                        staged[g] = stage_next(g)
                        note_peak()
                    progress = True
            if elastic and not progress:
                # work stealing: an idle healthy group re-stages the
                # staged chunk of the most-loaded group onto itself
                for g in health.healthy():
                    if (staged[g] is not None or ready
                            or n_inflight(g) >= self.depth):
                        continue
                    victims = [h for h in health.healthy()
                               if h != g and staged[h] is not None]
                    if not victims:
                        continue
                    v = max(victims, key=lambda h: (n_inflight(h), -h))
                    ch, staged[v] = staged[v], None
                    for t in ch.tasks:
                        self._record("steal", t.coord, g)
                    self.n_steals += len(ch.tasks)
                    st2 = self._stage(ctx, ch.tasks, shapes, group=g)
                    launch(st2, "dispatch")
                    progress = True
            if progress or not flights:
                continue
            await_flights()
            for fid in [i for i, f in flights.items() if flight_ready(f)]:
                f = flights.get(fid)
                if f is None:       # its twin already committed this work
                    continue
                tw = twin.pop(fid, None)
                if tw is not None and tw in flights:
                    twin.pop(tw, None)
                    # deterministic winner: canonical group order among
                    # the READY sides (twins share keys, so either is the
                    # fault-free bitwise result)
                    cand = [x for x in (fid, tw)
                            if flights.get(x) is not None
                            and flight_ready(flights[x])]
                    win_id = min(cand, key=lambda x: flights[x].group)
                    lose_id = tw if win_id == fid else fid
                    loser = flights.pop(lose_id)
                    for t in loser.tasks:
                        self._record("cancel", t.coord, loser.group)
                    self.n_cancels += len(loser.tasks)
                    f = flights.pop(win_id)
                    # successors must consume the winner's dataflow, not
                    # whichever twin wrote the store last
                    for t in f.tasks:
                        ctx.U_posts[t.coord] = f.out[t.coord].U_post
                        ctx.V_posts[t.coord] = f.out[t.coord].V_post
                else:
                    flights.pop(fid)
                tr_ = time.time()
                # one executable ran the whole chunk: split its wall evenly
                # across members (mirrors StackedExecutor's bucket split)
                per = (tr_ - f.td) / len(f.tasks)
                health.observe(f.group, (tr_ - f.td) / chunk_cost(f.tasks))
                health.note_resolve(f.group)
                for t in f.tasks:
                    retire(t, f.out[t.coord], f.td, tr_, per,
                           group=f.group)
        phase_times = {ph: last_r[ph] - first_d[ph] for ph in first_d}
        return outcomes, phase_times, spans


EXECUTORS: Dict[str, type] = {
    "serial": SerialExecutor,
    "stacked": StackedExecutor,
    "sharded": ShardedExecutor,
    "async": AsyncExecutor,
    "streaming": StreamingExecutor,
}
"""Executor registry. ``run_pp(executor=<name>)`` resolves here, and the
conformance suite (tests/test_executor_conformance.py) parametrizes over
exactly these names — registering a new executor auto-enrolls it in the
battery (fixed-key RMSE parity, bitwise determinism, dependency-safe
dispatch trace, transfer-guard-clean aggregation). Every executor class
must accept ``record_trace=`` and report dispatch/resolve events honestly.
"""


def make_executor(spec, distributed_mesh=None, block_mesh=None,
                  window=None, topology=None) -> Executor:
    """Resolve run_pp's ``executor=`` argument: a registry name or an
    instance. ``topology`` is the unified 2-D ('block', 'data') placement
    (core.topology.Topology, an ``(block, data)`` pair, or a legacy 1-D
    mesh) consumed by the serial (block must be 1), sharded, async, and
    streaming executors. An intra-block ``distributed_mesh`` is the legacy
    spelling of ``topology=Topology(block=1, data=S)`` and forces the
    serial executor. ``window`` is the streaming executor's window size
    (ignored by the others)."""
    if isinstance(spec, Executor):
        for arg, name in ((distributed_mesh, "distributed_mesh"),
                          (window, "window"), (topology, "topology")):
            if arg is not None:
                raise ValueError(
                    f"{name} with an Executor instance is ambiguous — "
                    f"construct the executor with it yourself or pass the "
                    f"executor by name")
        return spec
    if window is not None and int(window) < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    if distributed_mesh is not None:
        if topology is not None:
            raise ValueError("pass distributed_mesh OR topology, not both")
        spec = "serial"
    if spec not in EXECUTORS:
        raise ValueError(f"unknown executor {spec!r} "
                         f"(expected {' | '.join(EXECUTORS)})")
    topo = None if topology is None else Topology.from_spec(topology)
    if spec == "stacked" and topo is not None:
        raise ValueError(
            "the stacked executor is single-executable (no device "
            "placement) — use executor='sharded' with a topology")
    factories = {
        "serial": lambda: SerialExecutor(distributed_mesh, topology=topo),
        "stacked": lambda: StackedExecutor(),
        "sharded": lambda: ShardedExecutor(
            topo if topo is not None else block_mesh),
        "async": lambda: AsyncExecutor(block_mesh=block_mesh,
                                       topology=topo),
        "streaming": lambda: StreamingExecutor(
            topology=topo,
            **({} if window is None else {"window": int(window)})),
    }
    # a registered executor without a dedicated factory gets default
    # construction — never a silent fallthrough to a different class
    factory = factories.get(spec, EXECUTORS[spec])
    return factory()


def _run_meta(key, part: Partition, cfg: BMF.BMFConfig) -> Dict:
    """The fields that determine a PP run's numbers — written to the
    checkpoint's meta.json and validated on resume. Deliberately excludes
    the executor/topology: block chains are executor-independent, so a run
    checkpointed on 8 devices legitimately resumes on 1 (the
    fault-tolerance story) and still finishes bitwise-identical."""
    return {
        "format": 1,
        "I": part.I, "J": part.J, "K": cfg.K,
        "n_samples": cfg.n_samples, "burnin": cfg.burnin,
        "phase_bc_samples": cfg.phase_bc_samples,
        "key": np.asarray(jax.random.key_data(key)).tolist(),
    }


def _restore_resume(ctx: PhaseContext, resume_from, meta: Dict):
    """Load a checkpoint directory's resolved blocks into the context:
    posteriors into the device store (successors read them as priors) and
    finished BlockOutcomes into ``ctx.resumed`` (their tasks are pruned
    from the executed graph). Validates the directory's meta against this
    run first — a mismatch is a usage error, named after resume_from."""
    from repro.checkpoint.ckpt import PPCheckpoint
    saved = PPCheckpoint.read_meta(resume_from)
    for k, v in meta.items():
        if saved.get(k) != v:
            raise ValueError(
                f"resume_from={str(resume_from)!r} was written by a "
                f"different run: {k} is {saved.get(k)!r} there but {v!r} "
                f"here — resume requires identical grid, K, chain config "
                f"and PRNG key")
    for (i, j), d in PPCheckpoint.load_blocks(resume_from).items():
        if not (0 <= i < ctx.part.I and 0 <= j < ctx.part.J):
            raise ValueError(
                f"resume_from={str(resume_from)!r} holds block ({i}, {j}) "
                f"outside this run's {ctx.part.I}x{ctx.part.J} grid")
        U_post = RowGaussians(eta=jnp.asarray(d["U_eta"]),
                              Lambda=jnp.asarray(d["U_Lambda"]))
        V_post = RowGaussians(eta=jnp.asarray(d["V_eta"]),
                              Lambda=jnp.asarray(d["V_Lambda"]))
        ctx.U_posts[(i, j)] = U_post
        ctx.V_posts[(i, j)] = V_post
        ctx.resumed[(i, j)] = BlockOutcome(
            U_post=U_post, V_post=V_post, pred_mean=None, seconds=0.0,
            sq_err=jnp.asarray(float(d["sq"])), n_obs=int(d["n_obs"]),
            health=jnp.asarray(True))


def run_phase_graph(key, part: Partition, cfg: BMF.BMFConfig, test: COO,
                    executor: Executor, verbose: bool = False,
                    policy: Optional[FaultPolicy] = None,
                    fault_plan: Optional[FaultPlan] = None,
                    checkpoint_dir=None, ckpt_every: int = 1,
                    resume_from=None) -> "PP.PPResult":
    """Execute the PP phase graph with ``executor`` and aggregate — the
    engine behind ``pp.run_pp``.

    Fault tolerance: every resolved block passes the chain-health guard
    (``_commit_guard``) under ``policy`` before its posterior reaches any
    successor; ``fault_plan`` is the deterministic injection seam the
    chaos tests drive. ``checkpoint_dir`` persists each resolved block's
    posterior through ``checkpoint.ckpt.PPCheckpoint`` (flushed even when
    a block fault raises), and ``resume_from`` restores such a directory:
    restored blocks are pruned from the graph and the finished run is
    bitwise-identical to an uninterrupted one (float32 posteriors
    round-trip exactly; pending blocks re-run under their original keys).
    """
    I, J = part.I, part.J
    t_start = time.time()
    test_p = apply_permutation(test, part.row_perm, part.col_perm)
    keys = jax.random.split(key, I * J).reshape(I, J)
    shapes = PP.BlockShapes.per_phase(part, test_p)
    ctx = PhaseContext(part=part, cfg=cfg, test_p=test_p, keys=keys,
                       shapes=shapes,
                       policy=policy if policy is not None else FaultPolicy(),
                       fault_plan=fault_plan)
    meta = _run_meta(key, part, cfg)
    if resume_from is not None:
        _restore_resume(ctx, resume_from, meta)
        if verbose and ctx.resumed:
            print(f"[pp] resumed {len(ctx.resumed)} block(s) from "
                  f"{resume_from}", flush=True)
    if checkpoint_dir is not None:
        from repro.checkpoint.ckpt import PPCheckpoint
        ctx.ckpt = PPCheckpoint(checkpoint_dir, every=ckpt_every)
        ctx.ckpt.write_meta(meta)

    full_graph = build_phase_graph(part)
    # a resumed block's task is pruned: the executor never re-runs it, and
    # _dep_state counts only intra-graph deps toward readiness
    graph = [(ph, pending) for ph, tasks in full_graph
             if (pending := [t for t in tasks if t.coord not in ctx.resumed])]
    # static pre-dispatch validation: the graph the executor is about to
    # drain must be acyclic with every dep in-graph or pre-resolved — a
    # rewired prior_from or an over-pruned resume fails HERE, not as a
    # hang inside an executor's ready loop
    from repro.analysis import trace_passes as _TRACE_LINT
    _bad = _TRACE_LINT.check_graph(
        {t.coord: list(t.deps) for _, ts in graph for t in ts},
        resolved=set(ctx.resumed))
    if _bad:
        raise ValueError("invalid phase graph: "
                         + "; ".join(v.message for v in _bad))
    if graph:
        try:
            outcomes, phase_times, spans = executor.run_graph(
                ctx, graph, verbose=verbose)
        finally:
            # a BlockFaultError (or any crash) still lands the buffered
            # blocks on disk — that is what makes the directory resumable
            if ctx.ckpt is not None:
                ctx.ckpt.flush()
    else:
        outcomes, phase_times, spans = {}, {}, {}
    if ctx.ckpt is not None:
        ctx.ckpt.flush()
    outcomes.update(ctx.resumed)

    sq_err, n_test = 0.0, 0
    per_block_rmse = np.zeros((I, J))
    block_times: Dict[Coord, float] = {}
    for _, tasks in full_graph:
        for t in tasks:
            o = outcomes[t.coord]
            block_times[t.coord] = o.seconds
            n, sq = _host_sq(ctx, t, o)
            if n:
                sq_err += sq
                n_test += n
                per_block_rmse[t.i, t.j] = float(np.sqrt(sq / n))

    U_posts = [[ctx.U_posts[(i, j)] for j in range(J)] for i in range(I)]
    V_posts = [[ctx.V_posts[(i, j)] for j in range(J)] for i in range(I)]
    if len(executor.devices) > 1:
        # per-device streams leave posteriors scattered; colocate for the
        # single jitted aggregation executable
        U_posts, V_posts = jax.device_put((U_posts, V_posts),
                                          executor.devices[0])
    U_agg = PP._aggregate_axis(part, U_posts, axis="row")
    V_agg = PP._aggregate_axis(part, V_posts, axis="col")

    rmse = float(np.sqrt(sq_err / max(n_test, 1)))
    return PP.PPResult(rmse=rmse, U_agg=U_agg, V_agg=V_agg,
                       per_block_rmse=per_block_rmse,
                       wall_time_s=time.time() - t_start,
                       phase_times_s=phase_times, n_test=n_test,
                       block_times_s=block_times, executor=executor.name,
                       block_spans_s=spans, faults=list(ctx.faults),
                       resumed_blocks=len(ctx.resumed),
                       group_stats=dict(
                           n_quarantined=executor.n_quarantined,
                           n_steals=executor.n_steals,
                           n_speculations=executor.n_speculations,
                           n_cancels=executor.n_cancels),
                       row_perm=part.row_perm, col_perm=part.col_perm,
                       tau=cfg.tau, K=cfg.K)
