"""Phase-graph execution engine for Posterior Propagation.

The paper's §2.2 structure is a three-phase DAG over the I×J block grid:
phase (a) is block (0,0); phase (b) is the first block-row and block-column,
depending only on (a); phase (c) is the interior, depending only on (b).
Within a phase, blocks are embarrassingly parallel — O((N/I + D/J)·K²)
posterior summaries cross phase boundaries, nothing else does.

This module makes the graph explicit (``BlockTask`` / ``build_phase_graph``)
and executes it through a pluggable ``Executor``:

  SerialExecutor   reference semantics: one jitted Gibbs call per block with
                   a host sync after each — what ``run_pp`` always did. The
                   only executor that composes with an intra-block
                   ``distributed_mesh`` (core.distributed's shard_map).
  StackedExecutor  stacks all blocks of a phase shape bucket along a leading
                   axis and runs ONE jitted vmapped chain per bucket
                   (``gibbs.run_gibbs_stacked``) — the per-block Python
                   dispatch and per-block host syncs disappear.
                   ``BlockShapes.per_phase`` is what makes stacking legal:
                   every block of a bucket is padded to identical shapes.
  ShardedExecutor  the stacked batch additionally shard_map'd over a 1-D
                   'block' device mesh: same-phase blocks genuinely run
                   concurrently on separate devices with NO collectives
                   inside a phase — the paper's deployment model, on-device.

Executor contract
-----------------
``run_phase(ctx, phase, tasks) -> {(i, j): BlockOutcome}`` must return one
outcome per task. The engine only calls ``run_phase`` once every task's
dependencies (``BlockTask.deps``) are resolved in ``ctx.U_posts`` /
``ctx.V_posts``, so executors read priors via ``ctx.priors(task)`` and never
reason about ordering. Executors never aggregate: ``run_phase_graph`` owns
phase sequencing, RMSE accumulation, and the Qin-et-al. divide-away
aggregation (``pp._aggregate_axis``).

Note on timings: SerialExecutor measures true per-block seconds;
Stacked/Sharded report bucket wall time split evenly across the bucket's
blocks (one executable runs them all), so ``PPResult.modeled_parallel_s``
stays defined but the interesting number there is the *measured* phase
wall time in ``PPResult.phase_times_s``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bmf as BMF
from repro.core import gibbs as GIBBS
from repro.core import pp as PP
from repro.core.partition import Partition
from repro.core.posterior import RowGaussians
from repro.data.sparse import COO, PaddedCSR, apply_permutation

Coord = Tuple[int, int]

# stable intra-phase bucket order (phase b runs its two buckets back to back)
_TAG_ORDER = ("a", "b_row", "b_col", "c")


@dataclass(frozen=True)
class BlockTask:
    """One node of the PP phase graph.

    ``phase`` is the partition's shape-bucket tag ('a'|'b_row'|'b_col'|'c');
    ``u_prior_from`` / ``v_prior_from`` name the block whose U / V posterior
    is propagated into this block as its prior (None = NW hyperprior)."""
    i: int
    j: int
    phase: str
    u_prior_from: Optional[Coord]
    v_prior_from: Optional[Coord]

    @property
    def coord(self) -> Coord:
        return (self.i, self.j)

    @property
    def deps(self) -> Tuple[Coord, ...]:
        return tuple(c for c in (self.u_prior_from, self.v_prior_from)
                     if c is not None)


def build_phase_graph(part: Partition) -> List[Tuple[str, List[BlockTask]]]:
    """The paper's three-phase DAG: [(phase_name, tasks)] in execution
    order. Every task's deps live in strictly earlier phases."""
    I, J = part.I, part.J
    phase_a = [BlockTask(0, 0, "a", None, None)]
    phase_b = ([BlockTask(i, 0, "b_row", None, (0, 0)) for i in range(1, I)]
               + [BlockTask(0, j, "b_col", (0, 0), None) for j in range(1, J)])
    phase_c = [BlockTask(i, j, "c", (i, 0), (0, j))
               for i in range(1, I) for j in range(1, J)]
    return [(name, tasks) for name, tasks in
            (("a", phase_a), ("b", phase_b), ("c", phase_c)) if tasks]


@dataclass
class PhaseContext:
    """Run state shared with executors: inputs (partition, config, permuted
    test set, per-block keys, shape buckets) plus the posterior store that
    carries summaries across phase boundaries."""
    part: Partition
    cfg: BMF.BMFConfig
    test_p: COO
    keys: jax.Array                      # (I, J) typed PRNG keys
    shapes: Dict[str, "PP.BlockShapes"]  # per phase tag
    U_posts: Dict[Coord, RowGaussians] = field(default_factory=dict)
    V_posts: Dict[Coord, RowGaussians] = field(default_factory=dict)

    def block_cfg(self, task: BlockTask) -> BMF.BMFConfig:
        """Reduced chains for phases b/c when cfg.phase_bc_samples is set
        (the propagated priors are informative — paper future-work)."""
        cfg = self.cfg
        if cfg.phase_bc_samples and task.phase != "a":
            return cfg._replace(n_samples=cfg.phase_bc_samples,
                                burnin=max(2, cfg.phase_bc_samples // 4))
        return cfg

    def priors(self, task: BlockTask):
        up = self.U_posts[task.u_prior_from] if task.u_prior_from else None
        vp = self.V_posts[task.v_prior_from] if task.v_prior_from else None
        return up, vp


@dataclass
class BlockOutcome:
    U_post: RowGaussians       # trimmed to the block's true row count
    V_post: RowGaussians       # trimmed to the block's true col count
    pred_mean: np.ndarray      # (bucket n_test,) posterior-mean predictions
    seconds: float


def _outcome(res: GIBBS.GibbsResult, blk, seconds: float) -> BlockOutcome:
    nr, nc = len(blk.row_ids), len(blk.col_ids)
    pred = np.asarray(res.acc.pred_sum
                      / np.maximum(float(res.acc.pred_cnt), 1.0))
    return BlockOutcome(
        U_post=RowGaussians(eta=res.U_post.eta[:nr],
                            Lambda=res.U_post.Lambda[:nr]),
        V_post=RowGaussians(eta=res.V_post.eta[:nc],
                            Lambda=res.V_post.Lambda[:nc]),
        pred_mean=pred, seconds=seconds)


class Executor:
    """Runs all blocks of ONE phase; never crosses a phase boundary."""
    name = "base"

    def run_phase(self, ctx: PhaseContext, phase: str,
                  tasks: Sequence[BlockTask]) -> Dict[Coord, BlockOutcome]:
        raise NotImplementedError


class SerialExecutor(Executor):
    """One jitted Gibbs call + host sync per block (reference semantics,
    bit-for-bit today's ``run_pp`` loop). Composes with an intra-block
    ``distributed_mesh``: each block's chain is itself shard_map'd."""
    name = "serial"

    def __init__(self, distributed_mesh=None):
        self.distributed_mesh = distributed_mesh

    def run_phase(self, ctx, phase, tasks):
        out: Dict[Coord, BlockOutcome] = {}
        for t in tasks:
            blk = ctx.part.block(t.i, t.j)
            up, vp = ctx.priors(t)
            t0 = time.time()
            res = PP.run_block(ctx.keys[t.i, t.j], blk, ctx.block_cfg(t),
                               ctx.test_p, up, vp, self.distributed_mesh,
                               shapes=ctx.shapes[t.phase])
            jax.block_until_ready(res.U)
            out[t.coord] = _outcome(res, blk, time.time() - t0)
        return out


def _task_leaves(ctx: PhaseContext, task: BlockTask):
    """Device-ready leaves for one block — pp.pad_block_inputs is the
    single source of truth for bucket padding, shared with run_block, so
    stacked chains are identical to serial ones by construction."""
    blk = ctx.part.block(task.i, task.j)
    up, vp = ctx.priors(task)
    csr_r, csr_c, tr, tc, up, vp = PP.pad_block_inputs(
        blk, ctx.shapes[task.phase], ctx.cfg.K, ctx.test_p, up, vp)
    return ((csr_r.idx, csr_r.val, csr_r.mask),
            (csr_c.idx, csr_c.val, csr_c.mask),
            jnp.asarray(tr), jnp.asarray(tc), up, vp)


def _stack_trees(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


class StackedExecutor(Executor):
    """One jitted vmapped Gibbs call per phase shape bucket: all blocks of
    the bucket run as a leading batch axis inside a single executable."""
    name = "stacked"
    block_mesh = None      # ShardedExecutor sets this

    def run_phase(self, ctx, phase, tasks):
        out: Dict[Coord, BlockOutcome] = {}
        for tag in _TAG_ORDER:
            group = [t for t in tasks if t.phase == tag]
            if group:
                out.update(self._run_bucket(ctx, tag, group))
        return out

    def _batch_pad(self, n_tasks: int) -> int:
        if self.block_mesh is None:
            return 0
        n_dev = self.block_mesh.devices.size
        return (-n_tasks) % n_dev

    def _run_bucket(self, ctx, tag, group):
        s = ctx.shapes[tag]
        t0 = time.time()
        leaves = _stack_trees([_task_leaves(ctx, t) for t in group])
        rows_arrs, cols_arrs, test_rows, test_cols, up, vp = leaves
        ii = np.array([t.i for t in group])
        jj = np.array([t.j for t in group])
        keys = ctx.keys[ii, jj]
        pad = self._batch_pad(len(group))
        if pad:
            # round the batch up to the block mesh size by repeating the
            # last block (its duplicate results are dropped below)
            sel = np.concatenate([np.arange(len(group)),
                                  np.full(pad, len(group) - 1)])
            rows_arrs, cols_arrs, test_rows, test_cols, up, vp = jax.tree.map(
                lambda x: x[sel],
                (rows_arrs, cols_arrs, test_rows, test_cols, up, vp))
            keys = keys[sel]
        res = GIBBS.run_gibbs_stacked(
            keys,
            PaddedCSR(*rows_arrs, n_cols=s.n_cols),
            PaddedCSR(*cols_arrs, n_cols=s.n_rows),
            test_rows, test_cols, ctx.block_cfg(group[0]),
            U_prior=up, V_prior=vp, block_mesh=self.block_mesh)
        jax.block_until_ready(res.U)
        per = (time.time() - t0) / len(group)
        out = {}
        for b, t in enumerate(group):
            blk = ctx.part.block(t.i, t.j)
            res_b = jax.tree.map(lambda x: x[b], res)
            out[t.coord] = _outcome(res_b, blk, per)
        return out


class ShardedExecutor(StackedExecutor):
    """StackedExecutor with the bucket batch shard_map'd over a 1-D 'block'
    device mesh: blocks of a phase run concurrently on separate devices.
    No collective ever runs inside a phase — posterior summaries return to
    the host at the phase boundary, which is the paper's entire
    communication budget."""
    name = "sharded"

    def __init__(self, block_mesh=None):
        if block_mesh is None:
            from repro.core.distributed import make_block_mesh
            block_mesh = make_block_mesh()
        self.block_mesh = block_mesh


def make_executor(spec, distributed_mesh=None, block_mesh=None) -> Executor:
    """Resolve run_pp's ``executor=`` argument: a name or an instance.
    An intra-block ``distributed_mesh`` forces the serial executor — the
    two shard_map levels don't compose (yet)."""
    if isinstance(spec, Executor):
        if distributed_mesh is not None:
            raise ValueError(
                "distributed_mesh with an Executor instance is ambiguous — "
                "construct SerialExecutor(distributed_mesh) yourself or pass "
                "executor='serial'")
        return spec
    if distributed_mesh is not None:
        spec = "serial"
    if spec == "serial":
        return SerialExecutor(distributed_mesh)
    if spec == "stacked":
        return StackedExecutor()
    if spec == "sharded":
        return ShardedExecutor(block_mesh)
    raise ValueError(f"unknown executor {spec!r} "
                     "(expected serial | stacked | sharded)")


def run_phase_graph(key, part: Partition, cfg: BMF.BMFConfig, test: COO,
                    executor: Executor, verbose: bool = False) -> "PP.PPResult":
    """Execute the PP phase graph with ``executor`` and aggregate — the
    engine behind ``pp.run_pp``."""
    I, J = part.I, part.J
    t_start = time.time()
    test_p = apply_permutation(test, part.row_perm, part.col_perm)
    keys = jax.random.split(key, I * J).reshape(I, J)
    shapes = PP.BlockShapes.per_phase(part, test_p)
    ctx = PhaseContext(part=part, cfg=cfg, test_p=test_p, keys=keys,
                       shapes=shapes)

    sq_err, n_test = 0.0, 0
    per_block_rmse = np.zeros((I, J))
    phase_times: Dict[str, float] = {}
    block_times: Dict[Coord, float] = {}

    for phase, tasks in build_phase_graph(part):
        missing_deps = {d for t in tasks for d in t.deps} - set(ctx.U_posts)
        assert not missing_deps, f"phase {phase} scheduled before {missing_deps}"
        t0 = time.time()
        outcomes = executor.run_phase(ctx, phase, tasks)
        dt = time.time() - t0
        phase_times[phase] = dt
        dropped = {t.coord for t in tasks} - set(outcomes)
        assert not dropped, f"executor {executor.name} dropped blocks {dropped}"
        for t in tasks:
            o = outcomes[t.coord]
            ctx.U_posts[t.coord] = o.U_post
            ctx.V_posts[t.coord] = o.V_post
            block_times[t.coord] = o.seconds
            blk = part.block(t.i, t.j)
            _, _, tv = PP._block_test(test_p, blk)
            if len(tv):
                err = o.pred_mean[:len(tv)] - tv
                sq_err += float(np.sum(err ** 2))
                n_test += len(tv)
                per_block_rmse[t.i, t.j] = float(np.sqrt(np.mean(err ** 2)))
        if verbose:
            tags = [g for g in _TAG_ORDER if any(t.phase == g for t in tasks)]
            desc = " ".join(
                f"{g}[{sum(1 for t in tasks if t.phase == g)}blk "
                f"{shapes[g].n_rows}x{shapes[g].n_cols} "
                f"m={shapes[g].m_rows}/{shapes[g].m_cols}]" for g in tags)
            print(f"[pp:{executor.name}] phase {phase}: {len(tasks)} block(s) "
                  f"{desc} {dt:.2f}s", flush=True)

    U_posts = [[ctx.U_posts[(i, j)] for j in range(J)] for i in range(I)]
    V_posts = [[ctx.V_posts[(i, j)] for j in range(J)] for i in range(I)]
    U_agg = PP._aggregate_axis(part, U_posts, axis="row")
    V_agg = PP._aggregate_axis(part, V_posts, axis="col")

    rmse = float(np.sqrt(sq_err / max(n_test, 1)))
    return PP.PPResult(rmse=rmse, U_agg=U_agg, V_agg=V_agg,
                       per_block_rmse=per_block_rmse,
                       wall_time_s=time.time() - t_start,
                       phase_times_s=phase_times, n_test=n_test,
                       block_times_s=block_times, executor=executor.name)
