"""I×J block partitioning of the rating matrix for Posterior Propagation.

The paper (§3.3) finds approximately-square blocks give the best
wall-clock/RMSE trade-off, with the block grid following the matrix aspect
ratio. ``suggest_grid`` implements that heuristic; ``partition`` builds the
per-block local COO with load-balancing row/col permutations (the
fixed-shape-padding analogue of ref [16]'s sparsity-aware distribution).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Dict, Hashable, List, Optional, Tuple

import numpy as np

from repro.data.sparse import (COO, apply_permutation, balance_permutation,
                               occupancy_rank)


@dataclass
class Block:
    i: int
    j: int
    row_ids: np.ndarray       # global (permuted-space) row ids, sorted
    col_ids: np.ndarray
    coo: COO                  # local coordinates
    phase: str                # 'a' | 'b_row' | 'b_col' | 'c'


@dataclass
class Partition:
    I: int
    J: int
    row_perm: np.ndarray      # original -> permuted position
    col_perm: np.ndarray
    row_splits: np.ndarray    # (I+1,) boundaries in permuted space
    col_splits: np.ndarray
    blocks: List[List[Block]] # [i][j]

    def block(self, i: int, j: int) -> Block:
        return self.blocks[i][j]

    def all_blocks(self):
        for row in self.blocks:
            yield from row


def _phase(i: int, j: int) -> str:
    if i == 0 and j == 0:
        return "a"
    if j == 0:
        return "b_row"
    if i == 0:
        return "b_col"
    return "c"


def suggest_grid(n_rows: int, n_cols: int, n_blocks: int) -> Tuple[int, int]:
    """Paper §3.3: blocks should be ~square => I/J ≈ n_rows/n_cols with
    I·J ≈ n_blocks."""
    best = (1, n_blocks)
    best_err = float("inf")
    for I in range(1, n_blocks + 1):
        if n_blocks % I:
            continue
        J = n_blocks // I
        # squareness: rows-per-block vs cols-per-block
        err = abs(math.log((n_rows / I) / (n_cols / J)))
        if err < best_err:
            best_err, best = err, (I, J)
    return best


def _occupancy_refine(pc: COO, perm: np.ndarray, splits: np.ndarray,
                      axis: str) -> np.ndarray:
    """Compose a within-stripe occupancy sort onto the global permutation.

    ``balance_permutation`` spreads heavy rows ACROSS stripes (equal nnz per
    block); ``occupancy_rank`` (the core of data.sparse's
    ``occupancy_permutation``) then sorts each stripe's rows by descending
    rating count WITHIN it, so the padded-CSR slot planes of every block in
    the stripe are occupancy-coherent: the fused kernel's nnz-aware M-tile
    skip (data.sparse.tile_occupancy) sees long runs of equally-full rows,
    and stacked same-phase buckets waste fewer padded tiles. Stripe
    membership is untouched, so block nnz balance and the per-phase
    BlockShapes buckets are identical either way."""
    ids = pc.row if axis == "row" else pc.col
    n = pc.n_rows if axis == "row" else pc.n_cols
    counts = np.bincount(ids, minlength=n)    # one pass over nnz, all stripes
    refine = np.arange(n, dtype=np.int64)
    for lo, hi in zip(splits[:-1], splits[1:]):
        refine[lo:hi] = lo + occupancy_rank(counts[lo:hi])
    return refine[perm]


def partition(coo: COO, I: int, J: int, balance=True,
              seed: int = 0, occupancy_sort: bool = True) -> Partition:
    """balance: True = nnz-balance permutation (default), False = random
    permutation, "none" = identity — keeps deliberately skewed grids intact
    (the occupancy-skewed engine benchmarks depend on it; occupancy_sort
    still composes, it only reorders WITHIN stripes)."""
    if balance == "none":
        row_perm = np.arange(coo.n_rows, dtype=np.int64)
        col_perm = np.arange(coo.n_cols, dtype=np.int64)
    elif balance:
        row_perm = balance_permutation(coo, "row")
        col_perm = balance_permutation(coo, "col")
    else:
        rng = np.random.default_rng(seed)
        row_perm = rng.permutation(coo.n_rows)
        col_perm = rng.permutation(coo.n_cols)
    pc = apply_permutation(coo, row_perm, col_perm)

    row_splits = np.linspace(0, coo.n_rows, I + 1).astype(np.int64)
    col_splits = np.linspace(0, coo.n_cols, J + 1).astype(np.int64)

    if occupancy_sort:
        row_perm = _occupancy_refine(pc, row_perm, row_splits, "row")
        col_perm = _occupancy_refine(pc, col_perm, col_splits, "col")
        pc = apply_permutation(coo, row_perm, col_perm)

    blocks: List[List[Block]] = []
    for i in range(I):
        row = []
        r_ids = np.arange(row_splits[i], row_splits[i + 1])
        for j in range(J):
            c_ids = np.arange(col_splits[j], col_splits[j + 1])
            sub = pc.submatrix(r_ids, c_ids)
            row.append(Block(i=i, j=j, row_ids=r_ids, col_ids=c_ids,
                             coo=sub, phase=_phase(i, j)))
        blocks.append(row)
    return Partition(I=I, J=J, row_perm=row_perm, col_perm=col_perm,
                     row_splits=row_splits, col_splits=col_splits,
                     blocks=blocks)


def coalesce_shapes(shapes: Dict[Hashable, Tuple[int, ...]],
                    footprint: Callable[[Tuple[int, ...]], float],
                    max_waste: float = 1.5) -> Dict[Hashable, Tuple[int, ...]]:
    """Bucket-coalescing: merge shape buckets so ONE padded shape (the
    elementwise max of its members) serves many blocks, as long as no
    member's ``footprint`` is inflated by more than ``max_waste``.

    The streaming executor's window buffers have one shape per bucket, so
    fewer buckets = fewer window executables and better buffer reuse across
    phase tags — but merging a sparse bucket into a dense one would pad the
    sparse blocks to the dense worst case, which is compute as well as
    memory (the Gibbs einsum work scales with padded M). The waste budget is
    the compatibility rule: a merge happens only if, for EVERY member of the
    resulting group, footprint(merged) <= max_waste * footprint(member).

    ``shapes`` maps bucket keys to same-length int tuples; returns the same
    keys mapped to their group's merged tuple (coalesced keys share one
    tuple object). ``footprint`` must be monotone in each dimension.
    """
    assert max_waste >= 1.0, max_waste
    order = sorted(shapes, key=lambda k: (-footprint(shapes[k]), str(k)))
    groups: List[Tuple[Tuple[int, ...], List[Hashable]]] = []
    for k in order:
        s = shapes[k]
        placed = False
        for gi, (gshape, members) in enumerate(groups):
            merged = tuple(max(a, b) for a, b in zip(gshape, s))
            fm = footprint(merged)
            if all(fm <= max_waste * footprint(shapes[m])
                   for m in members + [k]):
                groups[gi] = (merged, members + [k])
                placed = True
                break
        if not placed:
            groups.append((s, [k]))
    out: Dict[Hashable, Tuple[int, ...]] = {}
    for gshape, members in groups:
        for m in members:
            out[m] = gshape
    return out


def nnz_balance_stats(part: Partition) -> dict:
    nnz = np.array([[b.coo.nnz for b in row] for row in part.blocks])
    return {
        "min": int(nnz.min()), "max": int(nnz.max()),
        "mean": float(nnz.mean()),
        "imbalance": float(nnz.max() / max(nnz.mean(), 1.0)),
    }
