"""Bayesian Probabilistic Matrix Factorization (Salakhutdinov & Mnih 2008).

Model:
    r_nd ~ N(u_nᵀ v_d, τ⁻¹)                   observed entries only
    u_n  ~ N(μ_U, Λ_U⁻¹),  (μ_U, Λ_U) ~ NW    (likewise for v_d)

Gibbs conditionals per row (the compute hot-spot, see kernels/bmf_precision):
    Λ_n = Λ_prior_n + τ Σ_{d∈Ω_n} v_d v_dᵀ
    η_n = η_prior_n + τ Σ_{d∈Ω_n} r_nd v_d
    u_n ~ N(Λ_n⁻¹ η_n, Λ_n⁻¹)

Priors are per-row ``RowGaussians`` so the same code serves both the vanilla
NW-hyperprior case (broadcast) and Posterior-Propagation propagated
posteriors.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import posterior as POST
from repro.core.posterior import NormalWishart, RowGaussians
from repro.data.sparse import PaddedCSR


class BMFConfig(NamedTuple):
    K: int = 16
    tau: float = 2.0              # residual precision (paper: fixed alpha=2)
    n_samples: int = 60
    burnin: int = 20
    use_kernel: bool = False      # Pallas bmf_precision kernel vs jnp ref
    # beyond-paper (listed as future work in §4): shorter chains for PP
    # phases b/c, justified by the informative propagated priors.
    # None = paper-faithful (same n_samples everywhere).
    phase_bc_samples: Optional[int] = None
    # one-kernel Gibbs sweep (kernels/bmf_sweep): the whole factor step —
    # gather, Λ/η accumulate, Cholesky, triangular solves, noise add — as a
    # single pass (Pallas on TPU, bitwise-identical striped XLA elsewhere).
    # sweep_dtype: 'fp32', or 'bf16' for the mixed-precision mode (bf16
    # gather/accumulate, f32 factorization) gated by the conformance
    # suite's RMSE-parity check.
    sweep_fused: bool = False
    sweep_dtype: str = "fp32"


def sufficient_stats(csr: PaddedCSR, other: jnp.ndarray, tau: float,
                     use_kernel: bool = False):
    """Per-row likelihood contributions (Λ_contrib, η_contrib).

    csr: rows of R (N, M) padded; other: the *other* factor matrix (D, K).
    Returns (N, K, K), (N, K). This gather + masked rank-1 accumulation is
    O(nnz · K²).  use_kernel=True routes through the zero-materialization
    hot path (repro/kernels/bmf_precision): the fused-gather Pallas kernel
    on TPU, an N-striped symmetric matmul elsewhere — neither builds the
    (N, M, K) gathered tensor the jnp path below materializes.
    """
    if use_kernel:
        from repro.kernels.bmf_precision import ops as KOPS
        return KOPS.precision_accum(csr.idx, csr.val, csr.mask, other, tau)
    V = other[csr.idx]                                  # (N, M, K)
    Vm = V * csr.mask[..., None]
    Lam = tau * jnp.einsum("nmk,nml->nkl", Vm, V)
    eta = tau * jnp.einsum("nm,nmk->nk", csr.val * csr.mask, V)
    return Lam, eta


def sample_factor(key, csr: PaddedCSR, other: jnp.ndarray, tau: float,
                  prior: RowGaussians, use_kernel: bool = False) -> jnp.ndarray:
    """Draw all rows of one factor from their Gibbs conditional."""
    Lam_c, eta_c = sufficient_stats(csr, other, tau, use_kernel)
    cond = RowGaussians(eta=prior.eta + eta_c, Lambda=prior.Lambda + Lam_c)
    return POST.sample_rows(key, cond)


def sample_hyper(key, X: jnp.ndarray, nw_prior: NormalWishart):
    """(μ, Λ) ~ NW posterior given current factor rows X."""
    post = POST.nw_posterior(nw_prior, X)
    return POST.sample_nw(key, post)


def predict(U: jnp.ndarray, V: jnp.ndarray, rows: jnp.ndarray,
            cols: jnp.ndarray) -> jnp.ndarray:
    """Pointwise predictions for test entries."""
    return jnp.einsum("ek,ek->e", U[rows], V[cols])


def init_factors(key, N: int, D: int, K: int, scale: float = 0.1):
    ku, kv = jax.random.split(key)
    U = scale * jax.random.normal(ku, (N, K), jnp.float32)
    V = scale * jax.random.normal(kv, (D, K), jnp.float32)
    return U, V
