"""Fixed-shape sparse rating-matrix containers for XLA.

``PaddedCSR`` stores, for each row, up to ``max_nnz`` (column, value) pairs
plus a mask — the TPU-friendly analogue of CSR (static shapes; the Gibbs
per-row conditionals become masked gathers + batched einsums). ``COO`` keeps
flat triplets for scatter-style updates (item-side statistics, test-set
evaluation).

Host-side construction uses numpy (data prep happens once, outside jit).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np
import jax.numpy as jnp


@dataclass
class COO:
    row: np.ndarray      # (nnz,) int32
    col: np.ndarray      # (nnz,) int32
    val: np.ndarray      # (nnz,) float32
    n_rows: int
    n_cols: int

    @property
    def nnz(self) -> int:
        return int(self.row.shape[0])

    def transpose(self) -> "COO":
        return COO(row=self.col, col=self.row, val=self.val,
                   n_rows=self.n_cols, n_cols=self.n_rows)

    def submatrix(self, row_ids: np.ndarray, col_ids: np.ndarray) -> "COO":
        """Extract block given *sorted* global id arrays; ids are relabeled
        to local [0, len) coordinates."""
        row_pos = -np.ones(self.n_rows, np.int64)
        row_pos[row_ids] = np.arange(len(row_ids))
        col_pos = -np.ones(self.n_cols, np.int64)
        col_pos[col_ids] = np.arange(len(col_ids))
        r = row_pos[self.row]
        c = col_pos[self.col]
        keep = (r >= 0) & (c >= 0)
        return COO(row=r[keep].astype(np.int32), col=c[keep].astype(np.int32),
                   val=self.val[keep], n_rows=len(row_ids), n_cols=len(col_ids))


@dataclass
class PaddedCSR:
    """Row-major padded sparse matrix (device arrays)."""
    idx: jnp.ndarray     # (N, M) int32 column ids (0 where padded)
    val: jnp.ndarray     # (N, M) f32
    mask: jnp.ndarray    # (N, M) f32 {0,1}
    n_cols: int

    @property
    def n_rows(self) -> int:
        return int(self.idx.shape[0])

    @property
    def max_nnz(self) -> int:
        return int(self.idx.shape[1])


def coo_to_padded_csr(coo: COO, max_nnz: Optional[int] = None,
                      pad_to_multiple: int = 8,
                      n_rows_pad: Optional[int] = None,
                      n_cols_pad: Optional[int] = None,
                      as_numpy: bool = False) -> PaddedCSR:
    """``n_rows_pad`` / ``n_cols_pad`` / ``max_nnz`` let callers bucket many
    matrices to ONE shape so a single jitted executable serves all blocks
    (the PP scheduler pads every block of a phase to common shapes).

    ``as_numpy=True`` keeps the planes on the host: the streaming executor
    assembles whole window chunks in numpy and ships each chunk with ONE
    async ``device_put`` instead of one transfer per plane."""
    order = np.argsort(coo.row, kind="stable")
    rows, cols, vals = coo.row[order], coo.col[order], coo.val[order]
    counts = np.bincount(rows, minlength=coo.n_rows)
    M = int(counts.max()) if counts.size and counts.max() > 0 else 1
    if max_nnz is not None:
        M = max_nnz   # bucket target: pad up to it, truncate rows beyond it
    M = max(1, ((M + pad_to_multiple - 1) // pad_to_multiple) * pad_to_multiple)
    NR = n_rows_pad if n_rows_pad is not None else coo.n_rows
    assert NR >= coo.n_rows

    idx = np.zeros((NR, M), np.int32)
    val = np.zeros((NR, M), np.float32)
    mask = np.zeros((NR, M), np.float32)
    # vectorized scatter fill: entry e (row-sorted) lands in slot
    # e - starts[row[e]]; slots >= M are truncated (rows beyond max_nnz)
    starts = np.concatenate([[0], np.cumsum(counts)])
    slot = np.arange(len(rows), dtype=np.int64) - starts[rows]
    keep = slot < M
    r_k, s_k = rows[keep], slot[keep]
    idx[r_k, s_k] = cols[keep]
    val[r_k, s_k] = vals[keep]
    mask[r_k, s_k] = 1.0
    n_cols = n_cols_pad if n_cols_pad is not None else coo.n_cols
    if as_numpy:
        return PaddedCSR(idx=idx, val=val, mask=mask, n_cols=n_cols)
    return PaddedCSR(idx=jnp.asarray(idx), val=jnp.asarray(val),
                     mask=jnp.asarray(mask), n_cols=n_cols)


def tile_occupancy(mask, tn: int, tm: int):
    """Per-row-tile count of live M-tiles for the fused-gather kernel's
    nnz-aware grid: ``ntiles[t]`` = number of tm-wide slot tiles that
    contain any unmasked entry among rows [t·tn, (t+1)·tn).  CSR padding
    fills slots from the left, so a tile's occupancy is determined by its
    last live slot; the kernel skips M-tiles >= ntiles (no DMA, no matmul).

    mask: (N, M) with N % tn == 0 and M % tm == 0 (np or jnp; traceable)."""
    N, M = mask.shape
    assert N % tn == 0 and M % tm == 0, (N, M, tn, tm)
    arange = jnp.arange(M, dtype=jnp.float32) + 1.0
    last_live = jnp.max(mask.astype(jnp.float32) * arange, axis=1)   # (N,)
    last_live = last_live.reshape(N // tn, tn).max(axis=1)
    return jnp.ceil(last_live / tm).astype(jnp.int32)


def occupancy_rank(counts: np.ndarray) -> np.ndarray:
    """rank[i] = position of row i when sorted by DESCENDING count (stable)
    — the core of ``occupancy_permutation``, exposed so core.partition can
    refine stripes from one global bincount instead of building a
    submatrix per stripe."""
    order = np.argsort(-counts, kind="stable")
    rank = np.empty(len(counts), np.int64)
    rank[order] = np.arange(len(counts))
    return rank


def occupancy_permutation(coo: COO, axis: str = "row") -> np.ndarray:
    """Permutation sorting rows (or cols) by DESCENDING rating count, so the
    fused kernel's tn-row tiles are occupancy-coherent and its M-tile skip
    is effective (the complement of ``balance_permutation``, which spreads
    heavy rows — use this WITHIN a block after blocks are balanced)."""
    ids = coo.row if axis == "row" else coo.col
    n = coo.n_rows if axis == "row" else coo.n_cols
    return occupancy_rank(np.bincount(ids, minlength=n))


def train_test_split(coo: COO, test_frac: float = 0.1,
                     seed: int = 0) -> Tuple[COO, COO]:
    rng = np.random.default_rng(seed)
    m = rng.random(coo.nnz) < test_frac
    tr = COO(coo.row[~m], coo.col[~m], coo.val[~m], coo.n_rows, coo.n_cols)
    te = COO(coo.row[m], coo.col[m], coo.val[m], coo.n_rows, coo.n_cols)
    return tr, te


def balance_permutation(coo: COO, axis: str = "row") -> np.ndarray:
    """Permutation that round-robins rows (or cols) by descending rating
    count — the blocking then gets near-equal nnz per block stripe (the
    TPU-padded analogue of ref [16]'s sparsity-aware load balancing)."""
    ids = coo.row if axis == "row" else coo.col
    n = coo.n_rows if axis == "row" else coo.n_cols
    counts = np.bincount(ids, minlength=n)
    order = np.argsort(-counts, kind="stable")
    # round-robin assignment: order[i] -> position pattern spreading heavy rows
    perm = np.empty(n, np.int64)
    perm[order] = _round_robin_positions(n)
    return perm


def _round_robin_positions(n: int, stride: int = 64) -> np.ndarray:
    """i-th entry = target position of the i-th heaviest row: strided so the
    heavy rows spread uniformly over the index space (any contiguous blocking
    into <= stride blocks then receives a balanced mix)."""
    pos = []
    for s in range(stride):
        pos.extend(range(s, n, stride))
    return np.asarray(pos[:n], np.int64)


def apply_permutation(coo: COO, row_perm: Optional[np.ndarray] = None,
                      col_perm: Optional[np.ndarray] = None) -> COO:
    row = coo.row if row_perm is None else row_perm[coo.row].astype(np.int32)
    col = coo.col if col_perm is None else col_perm[coo.col].astype(np.int32)
    return COO(row=row, col=col, val=coo.val, n_rows=coo.n_rows,
               n_cols=coo.n_cols)
