"""Synthetic token pipeline for the LLM drivers (offline container).

Generates a deterministic mixture of structured sequences so the loss has
learnable signal (repeats, arithmetic-progression tokens, local n-gram
patterns) rather than pure noise — a ~100M model shows a clearly
decreasing loss within tens of steps.
"""
from __future__ import annotations

from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


def synthetic_token_batches(cfg: ArchConfig, batch: int, seq: int,
                            seed: int = 0) -> Iterator[dict]:
    rng = np.random.default_rng(seed)
    V = cfg.vocab_size

    def make_seq():
        kind = rng.integers(0, 3)
        if kind == 0:        # periodic repeats
            period = int(rng.integers(2, 8))
            base = rng.integers(0, V, period)
            return np.tile(base, seq // period + 1)[:seq]
        if kind == 1:        # arithmetic progression mod V
            start = int(rng.integers(0, V))
            stride = int(rng.integers(1, 7))
            return (start + stride * np.arange(seq)) % V
        # Markov-ish bigram walk over a small alphabet slice
        lo = int(rng.integers(0, max(V - 64, 1)))
        out = [int(rng.integers(lo, lo + 64))]
        for _ in range(seq - 1):
            out.append(lo + (out[-1] - lo + int(rng.integers(0, 3))) % 64)
        return np.asarray(out)

    while True:
        toks = np.stack([make_seq() for _ in range(batch)]).astype(np.int32)
        b = {"tokens": jnp.asarray(toks)}
        if cfg.family == "vlm":
            b["image_embeds"] = jnp.asarray(
                rng.normal(size=(batch, cfg.n_image_tokens, cfg.d_model)),
                jnp.bfloat16)
        if cfg.family == "audio":
            b["audio_embeds"] = jnp.asarray(
                rng.normal(size=(batch, cfg.n_audio_frames, cfg.d_model)),
                jnp.bfloat16)
        yield b
