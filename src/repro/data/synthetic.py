"""Synthetic rating-matrix generators matched to the paper's Table 1.

The container is offline, so the four web-scale benchmark datasets are
replaced by low-rank + noise synthetic analogues that preserve the
*structural* properties Table 1 reports — #rows/#cols aspect ratio,
ratings/row density, rating scale, and K — at a configurable reduction
factor. Generators are seeded and deterministic.

| preset        | paper rows | cols  | nnz    | scale | K   | ratings/row |
|---------------|-----------|-------|--------|-------|-----|-------------|
| movielens     | 138.5K    | 27.3K | 20.0M  | 1-5   | 10  | 144         |
| netflix       | 480.2K    | 17.8K | 100.5M | 1-5   | 100 | 209         |
| yahoo         | 1.0M      | 625K  | 262.8M | 0-100 | 100 | 263         |
| amazon        | 21.2M     | 9.7M  | 82.5M  | 1-5   | 10  | 4           |
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np

from repro.data.sparse import COO


@dataclass(frozen=True)
class DatasetPreset:
    name: str
    n_rows: int
    n_cols: int
    ratings_per_row: float
    scale_lo: float
    scale_hi: float
    K: int                 # latent dim used by ALL methods (paper Table 1)
    true_rank: int         # rank of the generating factors


# reduction ~1/100 on rows/cols (nnz scales with rows × ratings/row)
PRESETS: Dict[str, DatasetPreset] = {
    "movielens": DatasetPreset("movielens", 1385, 273, 144, 1, 5, 10, 8),
    "netflix": DatasetPreset("netflix", 4802, 178, 209, 1, 5, 100, 12),
    "yahoo": DatasetPreset("yahoo", 10_000, 6250, 263, 0, 100, 100, 12),
    "amazon": DatasetPreset("amazon", 21_200, 9700, 4, 1, 5, 10, 6),
    # small preset for unit tests / examples
    "mini": DatasetPreset("mini", 400, 120, 30, 1, 5, 8, 5),
}


def generate(preset: str | DatasetPreset, seed: int = 0,
             noise_std: float = 0.35) -> Tuple[COO, DatasetPreset]:
    """Low-rank + Gaussian noise ratings, clipped to the preset scale."""
    p = PRESETS[preset] if isinstance(preset, str) else preset
    rng = np.random.default_rng(seed)
    nnz = int(p.n_rows * p.ratings_per_row)

    # bounded power-law popularity (realistic skew without the extreme
    # concentration of a raw zipf draw, which would collapse under dedup)
    row_w = (np.arange(p.n_rows) + 1.0) ** -0.7
    col_w = (np.arange(p.n_cols) + 1.0) ** -0.6
    rng.shuffle(row_w)
    rng.shuffle(col_w)
    row_p = row_w / row_w.sum()
    col_p = col_w / col_w.sum()
    # oversample then dedupe to hit the target nnz
    rows = rng.choice(p.n_rows, size=int(nnz * 1.6), p=row_p).astype(np.int32)
    cols = rng.choice(p.n_cols, size=int(nnz * 1.6), p=col_p).astype(np.int32)
    key = rows.astype(np.int64) * p.n_cols + cols
    _, uniq = np.unique(key, return_index=True)
    # shuffle BEFORE truncating: np.unique returns indices sorted by
    # row-major key, so uniq[:nnz] alone would keep only the smallest row
    # ids and CUT the tail rows off entirely instead of thinning the drawn
    # popularity profile uniformly (same bug fixed in
    # bench_pp_engine.make_skewed; committed BENCH_* artifacts were
    # regenerated together with this fix)
    uniq = rng.permutation(uniq)[:nnz]
    rows, cols = rows[uniq], cols[uniq]

    r = p.true_rank
    scale_mid = 0.5 * (p.scale_lo + p.scale_hi)
    spread = 0.5 * (p.scale_hi - p.scale_lo)
    U = rng.normal(0, 1, (p.n_rows, r))
    V = rng.normal(0, 1, (p.n_cols, r))
    raw = np.einsum("ek,ek->e", U[rows], V[cols]) / np.sqrt(r)
    vals = scale_mid + spread * 0.5 * raw + noise_std * spread * rng.normal(size=len(rows))
    vals = np.clip(vals, p.scale_lo, p.scale_hi).astype(np.float32)

    return COO(row=rows, col=cols, val=vals, n_rows=p.n_rows,
               n_cols=p.n_cols), p
