import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh).

For each combination this:
  1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
  2. jits the right step function (train / prefill / decode) with explicit
     in/out shardings from repro.sharding.partitioning,
  3. ``.lower(**ShapeDtypeStruct specs).compile()`` — NO allocation,
  4. records memory_analysis / cost_analysis / per-kind collective bytes
     into a JSON results file (incrementally, one entry per run).

Usage:
  python -m repro.launch.dryrun --arch llama3_8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--out out.json]
"""
import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (ARCH_IDS, INPUT_SHAPES, TrainConfig,
                                get_config, shape_supported)
from repro.launch.mesh import make_production_mesh
from repro.models import steps as STEPS
from repro.optim import adamw
from repro.roofline import analysis as ROOF
from repro.roofline import jaxpr_cost as JCOST
from repro.sharding import partitioning as PART

DEFAULT_OUT = Path(__file__).resolve().parents[3] / "benchmarks" / "dryrun_results.json"


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _mesh_ctx(mesh):
    """jax.set_mesh appeared after 0.4.x; shardings here are explicit
    NamedShardings, so on older jax no ambient mesh is needed."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    import contextlib
    return contextlib.nullcontext()


def lower_one(arch_id: str, shape_name: str, multi_pod: bool,
              tcfg=None, verbose=True, extra_tags=None):
    cfg = get_config(arch_id)
    shape = INPUT_SHAPES[shape_name]
    ok, note = shape_supported(cfg, shape)
    if not ok:
        return {"arch": arch_id, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "note": note}

    # production default: 4 microbatches of 64 sequences (grad accumulation)
    tcfg = tcfg or TrainConfig(microbatches=4)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()

    params_s = STEPS.params_specs(cfg)
    p_sh = _named(mesh, PART.param_specs(params_s, cfg, mesh))
    win = STEPS.long_context_window(cfg, shape)

    with _mesh_ctx(mesh):
        if shape.kind == "train":
            batch_s = STEPS.batch_specs(cfg, shape)
            opt_s = STEPS.opt_specs(cfg)
            b_sh = _named(mesh, PART.batch_specs(batch_s, cfg, shape, mesh))
            o_sh = _named(mesh, PART.opt_specs(opt_s, params_s, cfg, mesh))
            step = STEPS.make_train_step(cfg, tcfg)
            jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                             out_shardings=(p_sh, o_sh, None),
                             donate_argnums=(0, 1))
            traced = jitted.trace(params_s, opt_s, batch_s)
            tokens = shape.global_batch * shape.seq_len
            kind = "train"
        elif shape.kind == "prefill":
            batch_s = STEPS.batch_specs(cfg, shape)
            b_sh = _named(mesh, PART.batch_specs(batch_s, cfg, shape, mesh))
            step = STEPS.make_prefill_step(cfg, shape, window_override=win)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
            traced = jitted.trace(params_s, batch_s)
            tokens = shape.global_batch * shape.seq_len
            kind = "prefill"
        else:  # decode
            kv_quant = bool(extra_tags and extra_tags.get("kv_quant"))
            cache_fn = STEPS.cache_specs_quant if kv_quant else STEPS.cache_specs
            cache_s = cache_fn(cfg, shape, window_override=win)
            c_sh = _named(mesh, PART.cache_specs(cache_s, cfg, shape, mesh))
            tok_s = STEPS.decode_token_specs(shape)
            t_sh = _named(mesh, PART.batch_specs(tok_s, cfg, shape, mesh))
            step = STEPS.make_serve_step(cfg, window_override=win)
            jitted = jax.jit(step, in_shardings=(p_sh, c_sh, t_sh),
                             out_shardings=(None, c_sh),
                             donate_argnums=(1,))  # cache updated in place
            traced = jitted.trace(params_s, cache_s, tok_s)
            tokens = shape.global_batch  # one new token per sequence
            kind = "decode"

        jcost = JCOST.jaxpr_cost(traced.jaxpr)
        t_lower = time.time() - t0
        lowered = traced.lower()
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            if hasattr(ma, attr):
                mem[attr] = int(getattr(ma, attr))
    except Exception as e:  # platform-dependent
        mem["error"] = str(e)

    hlo = compiled.as_text()
    n_chips = 512 if multi_pod else 256
    terms = ROOF.terms_from(jcost, hlo, n_chips)
    coll = ROOF.collective_bytes(hlo)

    n_active = cfg.active_param_count()
    model_flops_global = ROOF.model_flops_per_step(n_active, tokens, kind)
    model_flops_per_chip = model_flops_global / n_chips
    useful_ratio = (model_flops_per_chip / terms.flops) if terms.flops else 0.0

    rec = {
        "arch": arch_id, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok", "kind": kind,
        "swa_variant": bool(win),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": mem,
        "roofline": terms.as_dict(),
        "bytes_unfused_upper": jcost["bytes"] / n_chips,
        "dot_flops_frac": (jcost["dot_flops"] / jcost["flops"]) if jcost["flops"] else 0,
        "collectives": coll,
        "params": cfg.param_count(), "active_params": n_active,
        "model_flops_per_chip": model_flops_per_chip,
        "useful_flops_ratio": useful_ratio,
        "tokens_per_step": tokens,
    }
    if extra_tags:
        rec.update(extra_tags)
    if verbose:
        print(json.dumps({k: rec[k] for k in
                          ("arch", "shape", "mesh", "status", "compile_s")}))
        print("  memory:", mem)
        print("  roofline:", {k: (f"{v:.3e}" if isinstance(v, float) else v)
                              for k, v in rec["roofline"].items()})
    return rec


def append_result(rec, out_path: Path):
    out_path = Path(out_path)
    results = []
    if out_path.exists():
        results = json.loads(out_path.read_text())
    # replace same-key entry if present
    key = (rec["arch"], rec["shape"], rec["mesh"], rec.get("tag", ""))
    results = [r for r in results
               if (r["arch"], r["shape"], r["mesh"], r.get("tag", "")) != key]
    results.append(rec)
    out_path.write_text(json.dumps(results, indent=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS))
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    ap.add_argument("--tag", default="")
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV cache for decode shapes (§Perf H2)")
    args = ap.parse_args()

    combos = []
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    if args.all:
        for a in ARCH_IDS:
            for s in INPUT_SHAPES:
                for m in meshes:
                    combos.append((a, s, m))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        combos = [(args.arch, args.shape, m) for m in meshes]

    failures = 0
    extra = {"kv_quant": True} if args.kv_quant else None
    for a, s, m in combos:
        try:
            rec = lower_one(a, s, m, extra_tags=extra)
            if args.tag:
                rec["tag"] = args.tag
        except Exception:
            failures += 1
            rec = {"arch": a, "shape": s, "mesh": "multi" if m else "single",
                   "status": "error", "error": traceback.format_exc()[-2000:]}
            if args.tag:
                rec["tag"] = args.tag
            print(f"FAILED {a} {s} mesh={'multi' if m else 'single'}",
                  file=sys.stderr)
            print(rec["error"], file=sys.stderr)
        append_result(rec, Path(args.out))
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
