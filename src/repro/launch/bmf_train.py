"""BMF-PP training driver — the paper's end-to-end pipeline.

Usage:
  PYTHONPATH=src python -m repro.launch.bmf_train \
      --dataset movielens --blocks 4 --samples 60 \
      [--executor serial|stacked|sharded] [--distributed]

--executor picks the phase-graph engine executor (core.engine): 'stacked'
(default) runs each PP phase's shape bucket as ONE vmapped Gibbs call;
'sharded' additionally spreads that batch over all local devices on a
'block' mesh (set XLA_FLAGS=--xla_force_host_platform_device_count=N to
fake a mesh on CPU); 'async' overlaps phases b/c with a dependency-driven
scheduler (per-device streams when >1 device, donated buffers,
device-resident posteriors); 'streaming' bounds the live device footprint
to a window of --window donated block buffers (prefetched host planes,
critical-path-first dispatch) for grids whose stacked buckets don't fit
device memory; 'serial' is the reference per-block loop.

--distributed shards each block's Gibbs loop INTERNALLY over all local
devices (core.distributed shard_map) — this forces the serial executor.

--topology B D places the run on the unified 2-D ('block','data') mesh
(core.topology.Topology): B device groups run blocks concurrently while
each block's Gibbs sweep is sharded over the D devices of its group —
the paper's combined system (block-parallel PP x intra-block distributed
BMF). Composes with --executor sharded (2-D shard_map), async (group
streams), streaming (one donated window per group), and serial (B=1).

Fault tolerance: --on-fault/--max-retries set the engine's chain-health
policy (core/README.md "Fault tolerance"); --ckpt-dir persists each
resolved block's posteriors so a killed run restarts with --resume and
finishes bitwise-identical to an uninterrupted one.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.core import bmf as BMF
from repro.core import pp as PP
from repro.core.partition import nnz_balance_stats, partition, suggest_grid
from repro.data import synthetic as SYN
from repro.data.sparse import train_test_split


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="movielens",
                    choices=list(SYN.PRESETS))
    ap.add_argument("--blocks", type=int, default=4)
    ap.add_argument("--samples", type=int, default=60)
    ap.add_argument("--k", type=int, default=0, help="0 = preset K (capped 16)")
    ap.add_argument("--executor", default="stacked",
                    choices=["serial", "stacked", "sharded", "async",
                             "streaming"],
                    help="phase-graph engine executor (core.engine)")
    ap.add_argument("--window", type=int, default=0,
                    help="streaming executor window size W (0 = default)")
    ap.add_argument("--distributed", action="store_true",
                    help="intra-block shard_map (forces --executor serial)")
    ap.add_argument("--topology", type=int, nargs=2, default=None,
                    metavar=("BLOCK", "DATA"),
                    help="2-D ('block','data') placement: BLOCK device "
                         "groups x DATA devices per group (unified "
                         "core.topology mesh)")
    ap.add_argument("--phase-bc-samples", type=int, default=0)
    ap.add_argument("--fused-sweep", action="store_true",
                    help="one-kernel Gibbs sweep (kernels/bmf_sweep): the "
                         "whole factor step in one pass — Pallas on TPU, "
                         "bitwise-identical striped XLA elsewhere")
    ap.add_argument("--sweep-dtype", default="fp32",
                    choices=["fp32", "bf16"],
                    help="fused-sweep precision: bf16 runs the gather + "
                         "precision accumulate in bf16 (f32 factorization "
                         "always); only meaningful with --fused-sweep")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-dir", default="",
                    help="block-level phase-graph checkpoint directory: "
                         "each resolved block's posteriors persist there "
                         "(atomic per-block files), making the run "
                         "resumable with --resume")
    ap.add_argument("--ckpt-every", type=int, default=1,
                    help="flush block checkpoints every N resolves "
                         "(a kill loses at most N-1 blocks)")
    ap.add_argument("--resume", action="store_true",
                    help="resume from --ckpt-dir: restored blocks are "
                         "skipped and the finished run is bitwise-identical "
                         "to an uninterrupted one")
    ap.add_argument("--on-fault", default="raise",
                    choices=["raise", "degrade"],
                    help="after --max-retries failed re-runs of a faulty "
                         "block: raise, or degrade it to its propagated "
                         "prior (recorded in the fault ledger)")
    ap.add_argument("--max-retries", type=int, default=2,
                    help="bounded re-runs of an unhealthy block "
                         "(re-split key + jittered prior)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.resume and not args.ckpt_dir:
        raise SystemExit("--resume needs --ckpt-dir (the directory the "
                         "interrupted run checkpointed into)")

    coo, p = SYN.generate(args.dataset, seed=args.seed)
    train, test = train_test_split(coo, 0.1, seed=args.seed + 1)
    K = args.k or min(p.K, 16)
    cfg = BMF.BMFConfig(K=K, n_samples=args.samples,
                        burnin=args.samples // 3,
                        phase_bc_samples=args.phase_bc_samples or None,
                        sweep_fused=args.fused_sweep,
                        sweep_dtype=args.sweep_dtype)

    I, J = suggest_grid(train.n_rows, train.n_cols, args.blocks)
    part = partition(train, I, J)
    print(f"dataset={args.dataset} N={train.n_rows} D={train.n_cols} "
          f"nnz={train.nnz} grid={I}x{J} K={K}")
    print("block nnz balance:", nnz_balance_stats(part))

    mesh = None
    topology = None
    if args.topology:
        from repro.core.topology import Topology
        if args.distributed:
            raise SystemExit("--topology and --distributed are exclusive "
                             "(--distributed is Topology(1, n_devices))")
        topology = Topology(block=args.topology[0], data=args.topology[1])
        print(topology.describe())
    if args.distributed:
        n = len(jax.devices())
        mesh = jax.make_mesh((n,), ("data",))
        print(f"distributed: {n}-way shard_map per block (serial executor)")
    elif args.executor == "sharded":
        print(f"sharded executor: {len(jax.devices())}-way block mesh")
    elif args.executor == "async":
        print(f"async executor: dependency-driven overlap, "
              f"{len(jax.devices())} device stream(s)")
    elif args.executor == "streaming":
        print(f"streaming executor: bounded window of "
              f"{args.window or 4} donated block buffers, "
              f"critical-path-first dispatch")

    res = PP.run_pp(jax.random.key(args.seed), part, cfg, test,
                    distributed_mesh=mesh, verbose=True,
                    executor=args.executor, window=args.window or None,
                    topology=topology, on_fault=args.on_fault,
                    max_retries=args.max_retries,
                    checkpoint_dir=args.ckpt_dir or None,
                    ckpt_every=args.ckpt_every,
                    resume_from=(args.ckpt_dir if args.resume else None))
    print(f"executor={res.executor}  RMSE={res.rmse:.4f}  "
          f"wall={res.wall_time_s:.1f}s  "
          f"phases={ {k: round(v, 2) for k, v in res.phase_times_s.items()} }")
    if res.resumed_blocks:
        print(f"resumed {res.resumed_blocks} block(s) from {args.ckpt_dir}")
    if res.faults:
        print(f"faults: {len(res.faults)} event(s), "
              f"{res.n_retries} retr{'y' if res.n_retries == 1 else 'ies'} — "
              + "; ".join(f"{f.kind}@{f.coord}:{f.action}"
                          for f in res.faults))
    print(f"modeled 16-worker wall: {res.modeled_parallel_s(16):.1f}s")
    if res.block_spans_s:
        print(f"measured critical path: {res.critical_path_s():.1f}s "
              f"(dispatch→resolve spans, dependency chain)")

    if args.ckpt:
        ckpt.save(args.ckpt, {"U_eta": res.U_agg.eta, "U_Lam": res.U_agg.Lambda,
                              "V_eta": res.V_agg.eta, "V_Lam": res.V_agg.Lambda},
                  extra={"rmse": res.rmse, "grid": [I, J]})
        print("checkpoint ->", args.ckpt)


if __name__ == "__main__":
    main()
