"""Serving driver: prefill a batch of prompts, then batched greedy decode.

Usage (smoke scale, CPU):
  PYTHONPATH=src python -m repro.launch.serve --arch mixtral_8x7b --smoke \
      --batch 2 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, get_config
from repro.models import model as MODEL
from repro.models import steps as STEPS
from repro.models.kvcache import serve_cache_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke_variant()

    key = jax.random.key(args.seed)
    params = MODEL.init_params(key, cfg)
    max_len = args.prompt_len + args.gen + 8
    cache = serve_cache_init(cfg, args.batch, max_len)

    prompts = jax.random.randint(jax.random.key(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    batch = {"tokens": prompts}
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.zeros(
            (args.batch, cfg.n_image_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        batch["audio_embeds"] = jnp.zeros(
            (args.batch, cfg.n_audio_frames, cfg.d_model), jnp.bfloat16)

    t0 = time.time()
    logits, cache = MODEL.prefill(params, cfg, batch, cache)
    t_prefill = time.time() - t0
    print(f"prefill {args.batch}x{args.prompt_len}: {t_prefill:.2f}s")

    decode = jax.jit(STEPS.make_serve_step(cfg))
    tok = jnp.argmax(logits[:, -1, :], -1, keepdims=True).astype(jnp.int32)
    generated = [tok]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits[:, -1, :], -1, keepdims=True).astype(jnp.int32)
        generated.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    toks = np.concatenate([np.asarray(t) for t in generated], 1)
    print(f"decoded {args.gen} tokens/seq in {dt:.2f}s "
          f"({args.gen * args.batch / max(dt, 1e-9):.1f} tok/s)")
    print("sample:", toks[0][:16].tolist())
    assert np.isfinite(np.asarray(logits)).all()


if __name__ == "__main__":
    main()
