"""Production mesh builders.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init and only then calls these.

Topology (TPU v5e target):
  single pod : 16 × 16 = 256 chips, axes ('data', 'model')
  multi-pod  : 2 × 16 × 16 = 512 chips, axes ('pod', 'data', 'model')

BMF-PP placement goes through ONE builder, ``make_pp_mesh`` — the 2-D
('block', 'data') mesh of ``core.topology.Topology``. The transformer-side
('data', 'model') meshes above are unrelated to PP placement.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 4, n_model: int = 2):
    """Small mesh for CI-scale integration tests (8 host devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def make_pp_mesh(block: int, data: int = 1, devices=None):
    """The unified BMF-PP placement mesh: 2-D ('block', 'data') with
    ``block`` device groups of ``data`` devices each. Thin wrapper over
    ``core.topology.Topology`` so launch scripts, the dry-run, and the
    engine all build device placement from the same object —
    ``distributed.make_block_mesh`` is the data==1 degenerate form."""
    from repro.core.topology import Topology
    return Topology(block=block, data=data, devices=devices).mesh


def make_pp_topology(block: int, data: int = 1, devices=None):
    """Topology counterpart of ``make_pp_mesh`` (what ``run_pp`` takes)."""
    from repro.core.topology import Topology
    return Topology(block=block, data=data, devices=devices)
