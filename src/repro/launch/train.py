"""LLM training driver for the architecture zoo.

Usage (smoke scale, CPU):
  PYTHONPATH=src python -m repro.launch.train --arch llama3_8b --smoke \
      --steps 20 --batch 2 --seq 128

Production scale uses the same code path under the dry-run mesh; the
container has one device, so full configs are exercised via
repro.launch.dryrun (lower+compile only).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs.base import ARCH_IDS, TrainConfig, get_config
from repro.data.tokens import synthetic_token_batches
from repro.models import model as MODEL
from repro.models import steps as STEPS
from repro.optim import adamw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (2 layers, d<=256)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke_variant()
    tcfg = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                       warmup_steps=max(2, args.steps // 10), remat=True)

    key = jax.random.key(args.seed)
    params = MODEL.init_params(key, cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"(analytic {cfg.param_count()/1e6:.1f}M full)")
    opt = adamw.init(params)
    step_fn = jax.jit(STEPS.make_train_step(cfg, tcfg), donate_argnums=(0, 1))

    batches = synthetic_token_batches(cfg, args.batch, args.seq,
                                      seed=args.seed)
    losses = []
    t0 = time.time()
    for i, batch in zip(range(args.steps), batches):
        params, opt, metrics = step_fn(params, opt, batch)
        losses.append(float(metrics["loss"]))
        if i % args.log_every == 0 or i == args.steps - 1:
            dt = time.time() - t0
            print(f"step {i:4d}  loss {losses[-1]:.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"lr {float(metrics['lr']):.2e}  {dt:.1f}s")
    first = np.mean(losses[:5]) if len(losses) >= 10 else losses[0]
    last = np.mean(losses[-5:])
    print(f"loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")

    if args.ckpt:
        ckpt.save(args.ckpt, params, step=args.steps)
        print("checkpoint ->", args.ckpt)


if __name__ == "__main__":
    main()
