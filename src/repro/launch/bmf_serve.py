"""BMF-PP serving driver — train, build the posterior store, serve top-K.

Usage (smoke scale, CPU):
  PYTHONPATH=src python -m repro.launch.bmf_serve \
      --dataset movielens --blocks 4 --samples 20 \
      --mode thompson --requests 256 --check

Pipeline: ``run_pp`` with the chosen executor, then
``PosteriorStore.from_pp_result`` (one jitted device gather — posteriors
never visit the host), then a ``MicroBatchRouter`` pumping ``--requests``
recommendation requests built from real users (each masks its own
training items as seen). Reports per-request p50/p99 latency and QPS.

``--check`` (mean mode) verifies every served top-K against a dense numpy
brute-force ranking over the store means: each returned item's score must
be within 1e-5 of the k-th best brute-force score — the CLI twin of the
``tests/test_serving.py`` parity battery.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core import bmf as BMF
from repro.core import pp as PP
from repro.core.partition import partition, suggest_grid
from repro.data import synthetic as SYN
from repro.data.sparse import train_test_split
from repro.serving import MicroBatchRouter, PosteriorStore, Request
from repro.serving.scoring import MODES


def build_requests(train, n_requests: int, max_seen: int, seed: int):
    """One request per (cycled) user: mask the user's training items
    (truncated to the router's seen cap)."""
    by_user = {}
    for r, c in zip(train.row, train.col):
        by_user.setdefault(int(r), []).append(int(c))
    users = sorted(by_user)
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n_requests):
        u = users[int(rng.integers(len(users)))]
        out.append(Request(user_id=u, seen=by_user[u][:max_seen]))
    return out


def check_parity(router, tickets, reqs, store, tol: float = 1e-5):
    """Brute-force dense reference over store means: every served item's
    score must reach the k-th best masked score (tolerance absorbs
    jax-vs-numpy matmul reduction-order noise)."""
    U = np.asarray(store.U_mean)
    V = np.asarray(store.V_mean)
    k = router.k
    for t, r in zip(tickets, reqs):
        scores = U[r.user_id] @ V.T
        scores[np.asarray(r.seen, int)] = -np.inf
        kth = np.sort(scores)[::-1][min(k, len(scores)) - 1]
        served = scores[t.ids[t.valid]]
        assert served.size == min(k, int(np.isfinite(scores).sum()))
        assert (served >= kth - tol).all(), (r.user_id, served, kth)
    print(f"parity check OK: {len(tickets)} request(s) match the dense "
          f"brute-force top-{k} within {tol}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="movielens",
                    choices=list(SYN.PRESETS))
    ap.add_argument("--blocks", type=int, default=4)
    ap.add_argument("--samples", type=int, default=20)
    ap.add_argument("--k", type=int, default=0, help="0 = preset K (cap 16)")
    ap.add_argument("--executor", default="stacked",
                    choices=["serial", "stacked", "sharded", "async",
                             "streaming"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--slots", type=int, default=8,
                    help="item posterior sample slots S in the store")
    ap.add_argument("--mode", default="mean", choices=list(MODES))
    ap.add_argument("--topk", type=int, default=10)
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--max-seen", type=int, default=64)
    ap.add_argument("--latency-budget-ms", type=float, default=2.0)
    ap.add_argument("--check", action="store_true",
                    help="verify served top-K against a dense numpy "
                         "brute-force ranking (mean mode)")
    args = ap.parse_args()

    coo, p = SYN.generate(args.dataset, seed=args.seed)
    train, test = train_test_split(coo, 0.1, seed=args.seed + 1)
    K = args.k or min(p.K, 16)
    cfg = BMF.BMFConfig(K=K, n_samples=args.samples,
                        burnin=args.samples // 3)
    I, J = suggest_grid(train.n_rows, train.n_cols, args.blocks)
    part = partition(train, I, J)
    print(f"dataset={args.dataset} N={train.n_rows} M={train.n_cols} "
          f"grid={I}x{J} K={K} executor={args.executor}")

    t0 = time.time()
    res = PP.run_pp(jax.random.key(args.seed), part, cfg, test,
                    executor=args.executor)
    print(f"trained: RMSE={res.rmse:.4f} wall={time.time() - t0:.1f}s")

    t0 = time.time()
    store = PosteriorStore.from_pp_result(
        res, jax.random.key(args.seed + 2), n_slots=args.slots)
    jax.block_until_ready(store)
    print(f"store: {store.n_users} users x {store.n_items} items, "
          f"K={store.K}, {store.n_slots} sample slot(s), "
          f"built in {time.time() - t0:.2f}s")

    router = MicroBatchRouter(store, k=args.topk, mode=args.mode,
                              latency_budget_s=args.latency_budget_ms / 1e3,
                              max_batch=args.max_batch,
                              max_seen=args.max_seen,
                              seed=args.seed + 3)
    print(f"router: {len(router.plan_signatures)} executable bucket(s): "
          f"{router.plan_signatures}")

    reqs = build_requests(train, args.requests, args.max_seen,
                          args.seed + 4)
    # warm the full-batch executable so measured latency is serving, not
    # compilation
    for r in reqs[:args.max_batch]:
        router.submit(r)
    router.flush()
    router.latencies_s.clear()
    router.dispatches.clear()

    t0 = time.time()
    for r in reqs:
        router.submit(r)
        router.poll()
    router.flush()
    wall = time.time() - t0
    lat = np.asarray(router.latencies_s)
    print(f"served {len(lat)} request(s) in {wall:.2f}s  "
          f"QPS={len(lat) / max(wall, 1e-9):.0f}  "
          f"p50={np.percentile(lat, 50) * 1e3:.2f}ms  "
          f"p99={np.percentile(lat, 99) * 1e3:.2f}ms  "
          f"dispatches={len(router.dispatches)}")

    if args.check:
        router2 = MicroBatchRouter(store, k=args.topk, mode="mean",
                                   latency_budget_s=0.0,
                                   max_batch=args.max_batch,
                                   max_seen=args.max_seen,
                                   seed=args.seed + 5)
        check_reqs = reqs[:min(64, len(reqs))]
        tickets = [router2.submit(r) for r in check_reqs]
        router2.flush()
        check_parity(router2, tickets, check_reqs, store)


if __name__ == "__main__":
    main()
