import os
import sys


def _forced_device_count(argv) -> int:
    """4-device default (covers the 2x2 composed topology); --topo B D
    raises it. Must run before jax import, like bmf_dryrun."""
    need = 4
    if "--topo" in argv:
        i = argv.index("--topo")
        try:
            need = max(need, int(argv[i + 1]) * int(argv[i + 2]))
        except (IndexError, ValueError):
            pass
    return need


if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               f"{_forced_device_count(sys.argv)}")

"""bmf_lint — run the static invariant analyzer over the executor registry.

For every executor in ``engine.EXECUTORS`` x a topology matrix (1x1 plus
the composed --topo pair on a faked multi-device host), this lints:

  * the executor's ACTUAL chain executables, traced at abstract shapes
    through the ``gibbs.trace_chain`` / ``distributed.trace_chain_2d``
    lowering hooks: materialization budget, dtype promotion, host
    callbacks (jaxpr passes); collective confinement + per-comm-mode
    budgets and donation effectiveness (HLO passes);
  * a real mini PP run's dispatch/resolve trace (``record_trace=True``):
    happens-before protocol and streaming window occupancy;
  * the phase graph itself (cycles/unreachable/dangling deps) and the
    partition+coalesce executable-shape plan (recompilation budget).

It also lints the SERVING path (executor-independent, once per run): the
``serving.scoring.score_topk`` jaxpr for both modes, traced through
``trace_scoring`` at serving dims against ``scoring_budget`` — a dense
all-users x all-items (N, M) score matrix or a host callback inside the
scoring executable is a violation — plus the ``MicroBatchRouter`` bucket
plan (recompilation budget).

And the one-kernel SWEEP path (executor-independent, once per run): the
``kernels.bmf_sweep`` factor-step jaxpr and the ``sweep_fused`` chain
executable, fp32 and bf16, against the block materialization budget; the
dtype pass proves bf16 never reaches a cholesky/triangular_solve/sqrt
operand in the mixed-precision lowering.

Emits a machine-readable JSON report (one violation object per breach,
with fix-hint text) and exits non-zero on any violation — the CI
lint-invariants job gates on that.

  python -m repro.launch.bmf_lint --all-executors [--topo 2 2]
                                  [--json-out PATH]
"""
import argparse
import json
from pathlib import Path

import jax

from repro import analysis as LINT
from repro.core import bmf as BMF
from repro.core import distributed as DIST
from repro.core import engine as ENG
from repro.core import gibbs as GIBBS
from repro.core import pp as PP
from repro.core.partition import partition
from repro.core.topology import Topology
from repro.data import synthetic as SYN
from repro.data.sparse import train_test_split

OUT = Path(__file__).resolve().parents[3] / "benchmarks" / "bmf_lint_report.json"

# abstract dims for the static lowerings: small enough to trace fast,
# large enough that a materialized gather tensor (n*m*K) clears the
# block-dim budget by >2x
LINT_DIMS = dict(n_rows=64, n_cols=48, m_rows=16, m_cols=24, n_test=64)
K = 8

# serving-path lint dims: a dense (n_users, n_items) f32 score matrix
# (1 MiB here) clears scoring_budget (512 KiB) while every legitimate
# buffer — store precisions, resident sample slots, per-batch gathered
# slots — fits
SERVE_DIMS = dict(n_users=1024, n_items=256, K=8, batch=32, n_seen=16,
                  n_fold=4, n_slots=8, k=10)


def _chain_artifacts(label, tchain, *, comm, allowed_groups, budget):
    """Both artifact views of one lowered chain: the traced jaxpr and the
    compiled HLO (plus the donation contract when the lowering donated)."""
    with GIBBS._quiet_donation():
        hlo = tchain.traced.lower().compile().as_text()
    donated = tuple(tchain.donated_labels)
    must = set(tchain.must_alias)
    return [
        LINT.JaxprArtifact(label=f"{label}/jaxpr", jaxpr=tchain.traced.jaxpr,
                           bytes_budget=budget),
        LINT.HLOArtifact(label=f"{label}/hlo", hlo_text=hlo, comm=comm,
                         allowed_groups=allowed_groups,
                         param_labels=tchain.param_labels,
                         donated=donated, must_alias=tchain.must_alias,
                         release_only=tuple(lb for lb in donated
                                            if lb not in must)),
    ]


def static_artifacts(name, topo, cfg):
    """The chain executables executor ``name`` dispatches on ``topo``,
    traced through the core lowering hooks."""
    d = LINT_DIMS
    n, c, mr, mc, nt = (d["n_rows"], d["n_cols"], d["m_rows"], d["m_cols"],
                        d["n_test"])
    b1 = LINT.jaxpr_passes.materialization_budget(n, c, mr, mc, cfg.K)
    arts = []

    def single(lbl, **kw):
        tc = GIBBS.trace_chain(cfg, n, c, mr, mc, nt, **kw)
        return _chain_artifacts(lbl, tc, comm=None, allowed_groups=None,
                                budget=b1)

    def stacked(lbl, batch, **kw):
        bb = LINT.jaxpr_passes.materialization_budget(n, c, mr, mc, cfg.K,
                                                      batch=batch)
        tc = GIBBS.trace_chain(cfg, n, c, mr, mc, nt, batch=batch, **kw)
        return _chain_artifacts(lbl, tc, comm=None, allowed_groups=None,
                                budget=bb)

    def composed(lbl, topology, batch, comm, **kw):
        S = topology.data
        n_pad = ((n + S - 1) // S) * S
        c_pad = ((c + S - 1) // S) * S
        bb = LINT.jaxpr_passes.materialization_budget(
            n_pad, c_pad * S, mr, mc, cfg.K, batch=batch)
        groups = [list(range(g * S, (g + 1) * S))
                  for g in range(topology.block)]
        tc = DIST.trace_chain_2d(cfg, topology, n, c, mr, mc, nt,
                                 batch=batch, comm=comm, **kw)
        return _chain_artifacts(lbl, tc, comm=comm, allowed_groups=groups,
                                budget=bb)

    if name == "serial":
        arts += single("serial/block_c")
        arts += single("serial/block_a", u_prior=False, v_prior=False)
    elif name == "stacked":
        arts += stacked("stacked/bucket_c", batch=4, donate=True)
    elif name == "sharded":
        if topo.data == 1:
            arts += stacked(f"sharded/bucket_c@{topo.block}x1",
                            batch=max(topo.block, 1), donate=True,
                            mesh=topo.block_mesh())
        else:
            for comm in DIST.COMM_MODES:
                arts += composed(
                    f"sharded/composed[{comm}]@{topo.block}x{topo.data}",
                    topo, batch=topo.block, comm=comm,
                    donate=(comm == "gather"))
    elif name == "async":
        arts += single("async/block_c_donated", donate=True)
        if topo.data > 1:
            gt = Topology(block=1, data=topo.data)
            arts += composed(f"async/group_chain@1x{topo.data}", gt,
                             batch=1, comm="gather", donate=True)
    elif name == "streaming":
        arts += stacked("streaming/window_chunk", batch=2, donate=True,
                        prior_use=True)
    return arts


def behavioral_artifacts(name, topo, part, cfg, test, key):
    """One real mini PP run with ``record_trace=True``: the executor's
    trace + the phase graph + the executable-shape plan."""
    kw = {}
    if topo.n_devices > 1 and name in ("sharded", "async", "streaming"):
        kw["topology"] = topo
    if name == "streaming":
        kw["window"] = 2
    if name == "sharded" and topo.n_devices == 1:
        kw["topology"] = Topology(block=1, data=1)
    ex = ENG.make_executor(name, **kw)
    ex.record_trace = True
    PP.run_pp(key, part, cfg, test, executor=ex)

    graph = ENG.build_phase_graph(part)
    deps = {t.coord: list(t.deps) for _, ts in graph for t in ts}
    bound = peak = None
    if name == "streaming":
        G = max(1, ex.topology.block if ex.topology is not None else 1)
        bound = G * ex.window * (ex.depth + 1)
        peak = ex.peak_window_blocks
    label = f"{name}@{topo.block}x{topo.data}"
    return [
        LINT.TraceArtifact(label=f"{label}/trace", trace=list(ex.trace),
                           deps=deps, window_bound=bound,
                           reported_peak=peak),
        LINT.GraphArtifact(label=f"{label}/phase-graph", deps=deps),
        LINT.PlanArtifact(label=f"{label}/plan",
                          signatures=plan_signatures(name, part, test, cfg)),
    ]


def plan_signatures(name, part, test, cfg):
    """Distinct executable shapes the partition implies for this executor:
    per phase-tag buckets (serial/stacked/sharded/async compile one chain
    per tag), or the coalesced window buckets (streaming's prior-use
    flags make its executable tag-agnostic)."""
    from repro.core.engine import apply_permutation
    test_p = apply_permutation(test, part.row_perm, part.col_perm)
    shapes = PP.BlockShapes.per_phase(part, test_p)
    if name == "streaming":
        merged = PP.BlockShapes.coalesce(shapes, cfg.K, max_waste=1.0)
        return sorted({s.astuple() for s in merged.values()})
    return sorted((tag, s.astuple()) for tag, s in shapes.items())


def serving_artifacts():
    """The serving path's lintable surface: one scoring jaxpr per mode at
    SERVE_DIMS (materialization budget = ``scoring_budget``, plus the
    dtype-promotion and host-callback passes for free) and the router's
    coalesced executable-shape plan."""
    from repro.serving import router as ROUTE
    from repro.serving import scoring as SCORE
    d = SERVE_DIMS
    budget = SCORE.scoring_budget(d["n_users"], d["n_items"], d["K"],
                                  d["batch"], d["n_slots"])
    arts = []
    for mode in SCORE.MODES:
        ts = SCORE.trace_scoring(d["n_users"], d["n_items"], d["K"],
                                 d["batch"], d["n_seen"], d["n_fold"],
                                 d["n_slots"], k=d["k"], mode=mode)
        arts.append(LINT.JaxprArtifact(
            label=f"serving/score_topk[{mode}]/jaxpr",
            jaxpr=ts.traced.jaxpr, bytes_budget=budget))
    store = SCORE.abstract_store(d["n_users"], d["n_items"], d["K"],
                                 d["n_slots"])
    router = ROUTE.MicroBatchRouter(store, k=d["k"],
                                    max_batch=d["batch"])
    arts.append(LINT.PlanArtifact(label="serving/router/plan",
                                  signatures=router.plan_signatures))
    return arts


def lint_serving():
    arts = serving_artifacts()
    violations = []
    for a in arts:
        violations += LINT.analyze(a)
    return {
        "executor": "serving",
        "topology": [1, 1],
        "artifacts": [a.label for a in arts],
        "violations": [v.as_dict() for v in violations],
    }, violations


def sweep_artifacts(cfg):
    """The one-kernel Gibbs sweep's lintable surface (executor-independent,
    both precision modes): the op-level factor-step jaxpr through
    ``bmf_sweep.ops.trace_sweep`` (materialization budget = the SAME block
    budget the chains get — the fused path's striped gather tiles and
    padded planes must fit where the legacy path's did), plus the full
    chain executable with ``sweep_fused`` on through ``gibbs.trace_chain``.
    The dtype pass over the bf16 lowerings proves the mixed-precision
    contract: bf16 never reaches a cholesky/triangular_solve/sqrt operand
    (the sqrt IS the in-register Cholesky diagonal — the kernel hand-rolls
    the factorization, so no cholesky primitive appears)."""
    from repro.kernels.bmf_sweep import ops as SWEEP
    d = LINT_DIMS
    n, c, mr, mc, nt = (d["n_rows"], d["n_cols"], d["m_rows"], d["m_cols"],
                        d["n_test"])
    b1 = LINT.jaxpr_passes.materialization_budget(n, c, mr, mc, cfg.K)
    arts = []
    for dt in SWEEP.SWEEP_DTYPES:
        ts = SWEEP.trace_sweep(cfg.K, n, mr, c, dtype=dt)
        arts.append(LINT.JaxprArtifact(
            label=f"sweep/factor_step[{dt}]/jaxpr",
            jaxpr=ts.traced.jaxpr, bytes_budget=b1))
        cfg_f = cfg._replace(sweep_fused=True, sweep_dtype=dt)
        tc = GIBBS.trace_chain(cfg_f, n, c, mr, mc, nt)
        arts += _chain_artifacts(f"sweep/chain[{dt}]", tc, comm=None,
                                 allowed_groups=None, budget=b1)
    return arts


def lint_sweep(cfg):
    arts = sweep_artifacts(cfg)
    violations = []
    for a in arts:
        violations += LINT.analyze(a)
    return {
        "executor": "sweep",
        "topology": [1, 1],
        "artifacts": [a.label for a in arts],
        "violations": [v.as_dict() for v in violations],
    }, violations


def lint_executor(name, topo, part, cfg, test, key):
    arts = static_artifacts(name, topo, cfg)
    arts += behavioral_artifacts(name, topo, part, cfg, test, key)
    violations = []
    for a in arts:
        violations += LINT.analyze(a)
    return {
        "executor": name,
        "topology": [topo.block, topo.data],
        "artifacts": [a.label for a in arts],
        "violations": [v.as_dict() for v in violations],
    }, violations


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="static invariant lint over the executor registry")
    ap.add_argument("--all-executors", action="store_true",
                    help="lint every executor in engine.EXECUTORS")
    ap.add_argument("--executors", nargs="*", default=None,
                    help="subset of executor names to lint")
    ap.add_argument("--topo", type=int, nargs=2, default=(2, 2),
                    metavar=("BLOCK", "DATA"),
                    help="composed topology linted in addition to 1x1 "
                         "(needs BLOCK*DATA faked devices)")
    ap.add_argument("--json-out", type=Path, default=OUT)
    args = ap.parse_args(argv)

    names = sorted(ENG.EXECUTORS) if (args.all_executors
                                      or not args.executors) \
        else list(args.executors)
    for nm in names:
        if nm not in ENG.EXECUTORS:
            ap.error(f"unknown executor {nm!r}")

    topos = [Topology(block=1, data=1)]
    tb, td = args.topo
    if (tb, td) != (1, 1):
        if tb * td > jax.device_count():
            print(f"[bmf_lint] skipping {tb}x{td}: needs {tb * td} devices, "
                  f"have {jax.device_count()}")
        else:
            topos.append(Topology(block=tb, data=td))

    coo, p = SYN.generate("mini", seed=13)
    train, test = train_test_split(coo, 0.15, seed=14)
    cfg = BMF.BMFConfig(K=p.K, n_samples=5, burnin=1)
    part = partition(train, 3, 3)          # covers all four phase tags
    key = jax.random.key(5)

    runs, all_violations = [], []
    for topo in topos:
        for name in names:
            rec, vs = lint_executor(name, topo, part, cfg, test, key)
            runs.append(rec)
            all_violations += vs
            print(f"[bmf_lint] {name}@{topo.block}x{topo.data}: "
                  f"{len(rec['artifacts'])} artifact(s), "
                  f"{len(vs)} violation(s)")
    rec, vs = lint_serving()
    runs.append(rec)
    all_violations += vs
    print(f"[bmf_lint] serving: {len(rec['artifacts'])} artifact(s), "
          f"{len(vs)} violation(s)")
    rec, vs = lint_sweep(cfg)
    runs.append(rec)
    all_violations += vs
    print(f"[bmf_lint] sweep: {len(rec['artifacts'])} artifact(s), "
          f"{len(vs)} violation(s)")

    report = {
        "executors": names,
        "topologies": [[t.block, t.data] for t in topos],
        "passes": [{"name": pz.name, "kind": pz.kind, "doc": pz.doc}
                   for pz in LINT.passes()],
        "runs": runs,
        "n_violations": len(all_violations),
    }
    args.json_out.parent.mkdir(parents=True, exist_ok=True)
    args.json_out.write_text(json.dumps(report, indent=1))
    print(f"-> {args.json_out}")
    if all_violations:
        print(f"[bmf_lint] {len(all_violations)} violation(s):")
        for v in all_violations:
            print(str(LINT.Violation(**{
                "pass_name": v.pass_name, "artifact": v.artifact,
                "message": v.message, "fix_hint": v.fix_hint})))
        return 1
    print(f"[bmf_lint] OK: {len(runs)} executor/topology runs, "
          f"zero violations")
    return 0


if __name__ == "__main__":
    sys.exit(main())
