import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Production-mesh dry-run for the paper's OWN workload: the distributed
BMF Gibbs sweep at real-Netflix scale, lowered on the 256-chip 'data' ring
(one PP block spanning a pod's worth of chips).

Records roofline terms for the paper-faithful (psum) and beyond-paper
(scatter-V, §Perf H6) variants — the artifact behind the EXPERIMENTS
§Scaling saturation analysis.

  python -m repro.launch.bmf_dryrun [--shards 256] [--k 100]
"""
import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.core import bmf as BMF
from repro.core import distributed as DIST
from repro.roofline import analysis as ROOF
from repro.roofline import jaxpr_cost as JCOST

OUT = Path(__file__).resolve().parents[3] / "benchmarks" / "bmf_dryrun_results.json"


def lower_sweep(n_shards: int, N: int, D: int, M: int, K: int,
                scatter_v: bool):
    mesh = jax.make_mesh((n_shards,), ("data",))
    cfg = BMF.BMFConfig(K=K)
    D_pad = ((D + n_shards - 1) // n_shards) * n_shards
    N_pad = ((N + n_shards - 1) // n_shards) * n_shards
    M_c = max(8, (M * N // D // 8) * 8)  # transposed-side padded nnz

    sweep = DIST.make_distributed_sweep(mesh, cfg, N_pad, D_pad, n_shards,
                                        has_u_prior=False, has_v_prior=False,
                                        scatter_v=scatter_v)
    S = jax.ShapeDtypeStruct
    args = (
        jax.eval_shape(lambda: jax.random.key(0)),
        S((N_pad, K), jnp.float32), S((D_pad, K), jnp.float32),
        S((N_pad, M), jnp.int32), S((N_pad, M), jnp.float32),
        S((N_pad, M), jnp.float32),
        S((n_shards, D_pad, M_c), jnp.int32),
        S((n_shards, D_pad, M_c), jnp.float32),
        S((n_shards, D_pad, M_c), jnp.float32),
        S((1,), jnp.float32), S((1,), jnp.float32),
        S((1,), jnp.float32), S((1,), jnp.float32),
    )
    jitted = jax.jit(sweep)
    traced = jitted.trace(*args)
    jcost = JCOST.jaxpr_cost(traced.jaxpr)
    compiled = traced.lower().compile()
    terms = ROOF.terms_from(jcost, compiled.as_text(), n_shards)
    analytic = (DIST.sweep_comm_bytes_scatter if scatter_v
                else DIST.sweep_comm_bytes)(D_pad, K)
    return {
        "variant": "scatter_v" if scatter_v else "paper_psum",
        "n_shards": n_shards, "N": N, "D": D, "M": M, "K": K,
        "roofline": terms.as_dict(),
        "analytic_comm_bytes": analytic,
        "collectives": ROOF.collective_bytes(compiled.as_text()),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", type=int, default=256)
    ap.add_argument("--k", type=int, default=100)
    # real-Netflix dims; M = padded nnz/row budget after balance permutation
    ap.add_argument("--n", type=int, default=480_256)
    ap.add_argument("--d", type=int, default=17_792)
    ap.add_argument("--m", type=int, default=512)
    args = ap.parse_args()

    results = []
    for sv in (False, True):
        rec = lower_sweep(args.shards, args.n, args.d, args.m, args.k, sv)
        results.append(rec)
        rf = rec["roofline"]
        print(f"{rec['variant']:12s} compute={rf['compute_s']:.3e}s "
              f"memory={rf['memory_s']:.3e}s collective={rf['collective_s']:.3e}s "
              f"dominant={rf['dominant']} "
              f"(analytic comm {rec['analytic_comm_bytes']/1e6:.0f} MB)")
    OUT.write_text(json.dumps(results, indent=1))
    print("->", OUT)


if __name__ == "__main__":
    main()
