import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Production-mesh dry-run for the paper's OWN workload: the distributed
BMF Gibbs sweep at real-Netflix scale, lowered on the 256-chip 'data' ring
(one PP block spanning a pod's worth of chips).

Records roofline terms for the paper-faithful (psum) and beyond-paper
(scatter-V, §Perf H6) variants — the artifact behind the EXPERIMENTS
§Scaling saturation analysis.

--pp-engine additionally lowers the phase-graph engine's sharded phase-c
bucket (core.engine.ShardedExecutor: one batched Gibbs chain shard_map'd
over a 'block' mesh) and records that NO collective appears inside the
phase — the engine moves posterior summaries only at phase boundaries,
which is the paper's entire communication budget. It also lowers the
COMPOSED 2-D topology executable (core.topology: blocks over the 'block'
axis, each block's sweep distributed over the 'data' axis) and asserts
from the HLO replica groups that every collective is confined to a
'data' row — the scatter-V / factor-gather exchange inside one block —
with zero collectives crossing the 'block' axis. It also lowers the
ASYNC executor's unit of work — one interior block's DONATED per-block
chain executable (core.engine.AsyncExecutor dispatches these
dependency-driven onto per-device streams) — and records the
input_output_alias map XLA builds from the donation: aliased bytes are
buffers the chain reuses in place, donated-but-unaliased bytes are
released back to the allocator at dispatch.

  python -m repro.launch.bmf_dryrun [--shards 256] [--k 100] [--pp-engine]
"""
import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro import analysis as LINT
from repro.core import bmf as BMF
from repro.core import distributed as DIST
from repro.roofline import analysis as ROOF
from repro.roofline import jaxpr_cost as JCOST

OUT = Path(__file__).resolve().parents[3] / "benchmarks" / "bmf_dryrun_results.json"


def lower_sweep(n_shards: int, N: int, D: int, M: int, K: int,
                scatter_v: bool):
    mesh = jax.make_mesh((n_shards,), ("data",))
    cfg = BMF.BMFConfig(K=K)
    D_pad = ((D + n_shards - 1) // n_shards) * n_shards
    N_pad = ((N + n_shards - 1) // n_shards) * n_shards
    M_c = max(8, (M * N // D // 8) * 8)  # transposed-side padded nnz

    sweep = DIST.make_distributed_sweep(mesh, cfg, N_pad, D_pad, n_shards,
                                        has_u_prior=False, has_v_prior=False,
                                        scatter_v=scatter_v)
    S = jax.ShapeDtypeStruct
    args = (
        jax.eval_shape(lambda: jax.random.key(0)),
        S((N_pad, K), jnp.float32), S((D_pad, K), jnp.float32),
        S((N_pad, M), jnp.int32), S((N_pad, M), jnp.float32),
        S((N_pad, M), jnp.float32),
        S((n_shards, D_pad, M_c), jnp.int32),
        S((n_shards, D_pad, M_c), jnp.float32),
        S((n_shards, D_pad, M_c), jnp.float32),
        S((1,), jnp.float32), S((1,), jnp.float32),
        S((1,), jnp.float32), S((1,), jnp.float32),
    )
    jitted = jax.jit(sweep)
    traced = jitted.trace(*args)
    jcost = JCOST.jaxpr_cost(traced.jaxpr)
    compiled = traced.lower().compile()
    terms = ROOF.terms_from(jcost, compiled.as_text(), n_shards)
    analytic = (DIST.sweep_comm_bytes_scatter if scatter_v
                else DIST.sweep_comm_bytes)(D_pad, K)
    return {
        "variant": "scatter_v" if scatter_v else "paper_psum",
        "n_shards": n_shards, "N": N, "D": D, "M": M, "K": K,
        "roofline": terms.as_dict(),
        "analytic_comm_bytes": analytic,
        "collectives": ROOF.collective_bytes(compiled.as_text()),
    }


def lower_pp_phase(n_blocks: int, N: int, D: int, M: int, K: int,
                   chain_len: int):
    """Lower the engine's sharded phase-c bucket: B=n_blocks interior
    blocks, each (N/block-rows × D/block-cols), ONE chain executable
    shard_map'd over the 'block' mesh. Expect zero collective bytes —
    same-phase blocks never talk to each other."""
    from repro.core import gibbs as GIBBS
    from repro.core.distributed import make_block_mesh
    from repro.core.posterior import RowGaussians

    mesh = make_block_mesh(n_blocks)
    cfg = BMF.BMFConfig(K=K)._replace(n_samples=0, burnin=0,
                                      phase_bc_samples=None)
    B = n_blocks
    m_c = max(8, (M * N // D // 8) * 8)
    n_test = 1024
    S = jax.ShapeDtypeStruct
    key_data = S((B, 2), jnp.uint32)
    prior_u = (S((B, N, K), jnp.float32), S((B, N, K, K), jnp.float32))
    prior_v = (S((B, D, K), jnp.float32), S((B, D, K, K), jnp.float32))
    args = (
        key_data,
        (S((B, N, M), jnp.int32), S((B, N, M), jnp.float32),
         S((B, N, M), jnp.float32)),
        (S((B, D, m_c), jnp.int32), S((B, D, m_c), jnp.float32),
         S((B, D, m_c), jnp.float32)),
        S((B, n_test), jnp.int32), S((B, n_test), jnp.int32),
        S((), jnp.int32), S((), jnp.int32),
        RowGaussians(eta=prior_u[0], Lambda=prior_u[1]),
        RowGaussians(eta=prior_v[0], Lambda=prior_v[1]),
        S((B, N, K), jnp.float32), S((B, D, K), jnp.float32),
    )
    traced = GIBBS._run_gibbs_stacked_jit.trace(
        args[0], args[1], args[2], args[3], args[4], cfg, D, N,
        args[5], args[6], args[7], args[8], args[9], args[10], mesh=mesh)
    jcost = JCOST.jaxpr_cost(traced.jaxpr, mult=chain_len)
    compiled = traced.lower().compile()
    coll = ROOF.collective_bytes(compiled.as_text())
    terms = ROOF.terms_from(jcost, compiled.as_text(), n_blocks)
    return {
        "variant": "pp_phase_c_sharded",
        "n_blocks": n_blocks, "N": N, "D": D, "M": M, "K": K,
        "chain_len": chain_len,
        "roofline": terms.as_dict(),
        "collectives": coll,
        "intra_phase_collective_bytes": float(sum(coll.values())),
    }


def lower_pp_phase_2d(n_block: int, n_data: int, N: int, D: int, M: int,
                      K: int, chain_len: int, comm: str = "scatter"):
    """Lower the COMPOSED executable — the unified 2-D topology's unit of
    work: B=n_block interior blocks shard_map'd over the 'block' axis while
    each block's Gibbs sweep runs the intra-block distributed chain over
    the 'data' axis (distributed.run_gibbs_stacked_2d). Asserts, from the
    compiled HLO's replica groups, the paper's communication structure:
    every intra-phase collective is CONFINED to a 'data' row (the
    scatter-V / psum / factor-gather exchanges inside one block's chain)
    and ZERO collectives run on the 'block' axis — blocks never talk."""
    from repro.core import gibbs as GIBBS
    from repro.core import distributed as DIST
    from repro.core.posterior import RowGaussians
    from repro.core.topology import Topology

    topo = Topology(block=n_block, data=n_data)
    cfg = BMF.BMFConfig(K=K)._replace(n_samples=0, burnin=0,
                                      phase_bc_samples=None)
    B, S = n_block, n_data
    N_pad = ((N + S - 1) // S) * S
    D_pad = ((D + S - 1) // S) * S if comm == "scatter" else D
    m_c = max(8, (M * N // D // 8) * 8)
    n_test = 1024
    Sd = jax.ShapeDtypeStruct
    rows = (Sd((B, N_pad, M), jnp.int32), Sd((B, N_pad, M), jnp.float32),
            Sd((B, N_pad, M), jnp.float32))
    if comm == "gather":
        cols = (Sd((B, D, m_c), jnp.int32), Sd((B, D, m_c), jnp.float32),
                Sd((B, D, m_c), jnp.float32))
        csrt = None
    else:
        cols = None
        csrt = (Sd((B, S, D_pad, m_c), jnp.int32),
                Sd((B, S, D_pad, m_c), jnp.float32),
                Sd((B, S, D_pad, m_c), jnp.float32))
    args = (
        Sd((B, 2), jnp.uint32), rows, cols, csrt,
        Sd((B, n_test), jnp.int32), Sd((B, n_test), jnp.int32),
        Sd((), jnp.int32), Sd((), jnp.int32),
        RowGaussians(eta=Sd((B, N, K), jnp.float32),
                     Lambda=Sd((B, N, K, K), jnp.float32)),
        RowGaussians(eta=Sd((B, D, K), jnp.float32),
                     Lambda=Sd((B, D, K, K), jnp.float32)),
        Sd((B, N, K), jnp.float32), Sd((B, D, K), jnp.float32),
    )
    traced = DIST._run_gibbs_2d_jit.trace(
        args[0], args[1], args[2], args[3], args[4], args[5], cfg, D, N,
        args[6], args[7], args[8], args[9], args[10], args[11], None, None,
        mesh=topo.mesh, comm=comm, n_rows=N, n_cols=D)
    jcost = JCOST.jaxpr_cost(traced.jaxpr, mult=chain_len)
    compiled = traced.lower().compile()
    hlo = compiled.as_text()
    coll = ROOF.collective_bytes(hlo)
    terms = ROOF.terms_from(jcost, hlo, n_block * n_data)
    # 'data'-axis rows in flattened mesh order: group g = [g*S, (g+1)*S)
    data_rows = [list(range(g * S, (g + 1) * S)) for g in range(B)]
    # the confinement + per-comm-budget invariant now lives in the pass
    # registry (analysis.hlo_passes); dryrun enrolls its lowering like
    # any other artifact instead of hand-rolling the check
    violations = LINT.analyze(LINT.HLOArtifact(
        label=f"pp_phase_c_composed_2d[{comm}]", hlo_text=hlo, comm=comm,
        allowed_groups=data_rows))
    assert not violations, (
        "composed executable fails the collective lint:\n"
        + "\n".join(str(v) for v in violations))
    confinement = ROOF.collectives_confined_to_groups(hlo, data_rows)
    return {
        "variant": "pp_phase_c_composed_2d",
        "comm": comm,
        "topology": [n_block, n_data],
        "N": N, "D": D, "M": M, "K": K, "chain_len": chain_len,
        "roofline": terms.as_dict(),
        "collectives": coll,
        "collective_axis_check": {
            "n_collectives": confinement["n_collectives"],
            "n_confined_to_data_axis": confinement["n_confined"],
            "n_crossing_block_axis": confinement["n_crossing"],
        },
    }


def lower_pp_window(window: int, n_blocks: int, N: int, D: int, M: int,
                    K: int, chain_len: int):
    """Lower the STREAMING executor's unit of work — one window chunk:
    the stacked chain at batch W with per-block prior-use flags and
    donated buffers (gibbs._run_gibbs_stacked_jit_donated, exactly what
    StreamingExecutor dispatches per chunk) — and the full-bucket stacked
    executable at batch B=n_blocks for comparison. XLA's buffer assignment
    (arg + temp + out − alias) shows the streaming point: the per-dispatch
    peak scales with W, flat in the grid size, while the stacked bucket
    scales with B."""
    import warnings

    from repro.core import gibbs as GIBBS
    from repro.core.posterior import RowGaussians

    cfg = BMF.BMFConfig(K=K)._replace(n_samples=0, burnin=0,
                                      phase_bc_samples=None)
    m_c = max(8, (M * N // D // 8) * 8)
    n_test = 1024
    S = jax.ShapeDtypeStruct

    def effective_peak(B, flags):
        args = (
            S((B, 2), jnp.uint32),
            (S((B, N, M), jnp.int32), S((B, N, M), jnp.float32),
             S((B, N, M), jnp.float32)),
            (S((B, D, m_c), jnp.int32), S((B, D, m_c), jnp.float32),
             S((B, D, m_c), jnp.float32)),
            S((B, n_test), jnp.int32), S((B, n_test), jnp.int32),
            S((), jnp.int32), S((), jnp.int32),
            RowGaussians(eta=S((B, N, K), jnp.float32),
                         Lambda=S((B, N, K, K), jnp.float32)),
            RowGaussians(eta=S((B, D, K), jnp.float32),
                         Lambda=S((B, D, K, K), jnp.float32)),
            S((B, N, K), jnp.float32), S((B, D, K), jnp.float32),
        )
        uu = S((B,), jnp.float32) if flags else None
        with warnings.catch_warnings():
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable")
            traced = GIBBS._run_gibbs_stacked_jit_donated.trace(
                args[0], args[1], args[2], args[3], args[4], cfg, D, N,
                args[5], args[6], args[7], args[8], args[9], args[10],
                uu, uu, mesh=None)
            ma = traced.lower().compile().memory_analysis()
        return (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                + ma.output_size_in_bytes - ma.alias_size_in_bytes)

    win = effective_peak(window, flags=True)
    bucket = effective_peak(n_blocks, flags=False)
    return {
        "variant": "pp_window_streaming_donated",
        "window": window, "n_blocks": n_blocks,
        "N": N, "D": D, "M": M, "K": K, "chain_len": chain_len,
        "window_effective_peak_bytes": int(win),
        "stacked_bucket_effective_peak_bytes": int(bucket),
        "peak_ratio": float(win / max(bucket, 1)),
    }


def lower_pp_block_async(N: int, D: int, M: int, K: int, chain_len: int):
    """Lower the async executor's per-block unit: ONE interior (phase-c)
    block's chain with donated input buffers (gibbs._run_gibbs_jit_donated
    — the exact executable AsyncExecutor dispatches per readiness event).
    Records the donation outcome from the compiled module: alias bytes
    (inputs XLA rewrites in place — U0/V0 onto the U/V outputs) and the
    donated-but-unaliased remainder (padded CSR planes/test indices, whose
    buffers return to the allocator at dispatch instead of run end). A
    single-block executable trivially has zero intra-phase collectives —
    async streams only communicate O(K²) summaries at readiness edges."""
    from repro.core import gibbs as GIBBS
    from repro.core.posterior import RowGaussians

    cfg = BMF.BMFConfig(K=K)._replace(n_samples=0, burnin=0,
                                      phase_bc_samples=None)
    m_c = max(8, (M * N // D // 8) * 8)
    n_test = 1024
    S = jax.ShapeDtypeStruct
    csr_r = (S((N, M), jnp.int32), S((N, M), jnp.float32),
             S((N, M), jnp.float32))
    csr_c = (S((D, m_c), jnp.int32), S((D, m_c), jnp.float32),
             S((D, m_c), jnp.float32))
    args = (
        jax.eval_shape(lambda: jax.random.key(0)),
        csr_r, csr_c,
        S((n_test,), jnp.int32), S((n_test,), jnp.int32),
        S((), jnp.int32), S((), jnp.int32),
        RowGaussians(eta=S((N, K), jnp.float32),
                     Lambda=S((N, K, K), jnp.float32)),
        RowGaussians(eta=S((D, K), jnp.float32),
                     Lambda=S((D, K, K), jnp.float32)),
        S((N, K), jnp.float32), S((D, K), jnp.float32),
    )
    import warnings
    with warnings.catch_warnings():
        # the un-aliasable donations (CSR planes, test indices) are noted
        # by XLA; expected — see gibbs._quiet_donation
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        traced = GIBBS._run_gibbs_jit_donated.trace(
            args[0], args[1], args[2], args[3], args[4], cfg, D, N,
            args[5], args[6], args[7], args[8], args[9], args[10])
        jcost = JCOST.jaxpr_cost(traced.jaxpr, mult=chain_len)
        compiled = traced.lower().compile()
    hlo = compiled.as_text()
    ma = compiled.memory_analysis()
    alias_bytes = int(getattr(ma, "alias_size_in_bytes", 0) or 0)
    def nbytes(s):
        return int(np.dtype(s.dtype).itemsize) * int(np.prod(s.shape))

    donated_bytes = (sum(nbytes(s) for s in csr_r + csr_c)
                     + nbytes(args[3]) + nbytes(args[4])
                     + nbytes(args[9]) + nbytes(args[10]))
    coll = ROOF.collective_bytes(hlo)
    terms = ROOF.terms_from(jcost, hlo, 1)
    return {
        "variant": "pp_block_async_donated",
        "N": N, "D": D, "M": M, "K": K, "chain_len": chain_len,
        "roofline": terms.as_dict(),
        "collectives": coll,
        "intra_phase_collective_bytes": float(sum(coll.values())),
        "has_input_output_alias": "input_output_alias=" in hlo,
        "alias_bytes": alias_bytes,
        "donated_input_bytes": donated_bytes,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", type=int, default=256)
    ap.add_argument("--k", type=int, default=100)
    # real-Netflix dims; M = padded nnz/row budget after balance permutation
    ap.add_argument("--n", type=int, default=480_256)
    ap.add_argument("--d", type=int, default=17_792)
    ap.add_argument("--m", type=int, default=512)
    ap.add_argument("--pp-engine", action="store_true",
                    help="also lower the sharded phase-c bucket "
                         "(16 interior blocks of a 5x5 grid)")
    ap.add_argument("--samples", type=int, default=60,
                    help="chain length used to scale --pp-engine flop terms")
    ap.add_argument("--window", type=int, default=4,
                    help="streaming window W lowered by --pp-engine")
    ap.add_argument("--topo", type=int, nargs=2, default=(16, 16),
                    metavar=("BLOCK", "DATA"),
                    help="('block','data') shape of the composed 2-D "
                         "executable lowered by --pp-engine")
    args = ap.parse_args()

    results = []
    for sv in (False, True):
        rec = lower_sweep(args.shards, args.n, args.d, args.m, args.k, sv)
        results.append(rec)
        rf = rec["roofline"]
        print(f"{rec['variant']:12s} compute={rf['compute_s']:.3e}s "
              f"memory={rf['memory_s']:.3e}s collective={rf['collective_s']:.3e}s "
              f"dominant={rf['dominant']} "
              f"(analytic comm {rec['analytic_comm_bytes']/1e6:.0f} MB)")
    if args.pp_engine:
        # 5x5 grid of the same matrix -> 16 interior (phase-c) blocks
        rec = lower_pp_phase(16, args.n // 5 + 1, args.d // 5 + 1,
                             max(8, args.m // 4), args.k, args.samples)
        results.append(rec)
        print(f"{rec['variant']} blocks={rec['n_blocks']} "
              f"intra-phase collective bytes="
              f"{rec['intra_phase_collective_bytes']:.0f} "
              f"(phase boundary is the only communication)")
        # the composed 2-D topology executable: BLOCK groups x DATA-way
        # intra-block sharding (default 16x16 = 256 of the 512 faked
        # chips), scatter-V / factor-gather inside each block, ZERO
        # 'block'-axis collectives (asserted from the HLO replica groups)
        tb, td = args.topo
        for comm in ("scatter", "gather"):
            rec = lower_pp_phase_2d(tb, td, args.n // 5 + 1,
                                    args.d // 5 + 1, max(8, args.m // 4),
                                    args.k, args.samples, comm=comm)
            results.append(rec)
            chk = rec["collective_axis_check"]
            print(f"{rec['variant']}[{comm}] topology={tb}x{td} "
                  f"collectives={chk['n_collectives']} "
                  f"confined-to-'data'={chk['n_confined_to_data_axis']} "
                  f"crossing-'block'={chk['n_crossing_block_axis']}")
        rec = lower_pp_block_async(args.n // 5 + 1, args.d // 5 + 1,
                                   max(8, args.m // 4), args.k, args.samples)
        results.append(rec)
        print(f"{rec['variant']} alias_bytes={rec['alias_bytes']} "
              f"donated={rec['donated_input_bytes']/1e6:.0f}MB "
              f"intra-phase collective bytes="
              f"{rec['intra_phase_collective_bytes']:.0f}")
        rec = lower_pp_window(args.window, 16, args.n // 5 + 1,
                              args.d // 5 + 1, max(8, args.m // 4), args.k,
                              args.samples)
        results.append(rec)
        print(f"{rec['variant']} W={rec['window']} "
              f"window peak={rec['window_effective_peak_bytes']/1e6:.0f}MB "
              f"vs stacked bucket="
              f"{rec['stacked_bucket_effective_peak_bytes']/1e6:.0f}MB "
              f"(x{rec['peak_ratio']:.2f})")
    OUT.write_text(json.dumps(results, indent=1))
    print("->", OUT)


if __name__ == "__main__":
    main()
