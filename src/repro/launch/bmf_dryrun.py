import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Production-mesh dry-run for the paper's OWN workload: the distributed
BMF Gibbs sweep at real-Netflix scale, lowered on the 256-chip 'data' ring
(one PP block spanning a pod's worth of chips).

Records roofline terms for the paper-faithful (psum) and beyond-paper
(scatter-V, §Perf H6) variants — the artifact behind the EXPERIMENTS
§Scaling saturation analysis.

--pp-engine additionally lowers the phase-graph engine's sharded phase-c
bucket (core.engine.ShardedExecutor: one batched Gibbs chain shard_map'd
over a 'block' mesh) and records that NO collective appears inside the
phase — the engine moves posterior summaries only at phase boundaries,
which is the paper's entire communication budget.

  python -m repro.launch.bmf_dryrun [--shards 256] [--k 100] [--pp-engine]
"""
import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.core import bmf as BMF
from repro.core import distributed as DIST
from repro.roofline import analysis as ROOF
from repro.roofline import jaxpr_cost as JCOST

OUT = Path(__file__).resolve().parents[3] / "benchmarks" / "bmf_dryrun_results.json"


def lower_sweep(n_shards: int, N: int, D: int, M: int, K: int,
                scatter_v: bool):
    mesh = jax.make_mesh((n_shards,), ("data",))
    cfg = BMF.BMFConfig(K=K)
    D_pad = ((D + n_shards - 1) // n_shards) * n_shards
    N_pad = ((N + n_shards - 1) // n_shards) * n_shards
    M_c = max(8, (M * N // D // 8) * 8)  # transposed-side padded nnz

    sweep = DIST.make_distributed_sweep(mesh, cfg, N_pad, D_pad, n_shards,
                                        has_u_prior=False, has_v_prior=False,
                                        scatter_v=scatter_v)
    S = jax.ShapeDtypeStruct
    args = (
        jax.eval_shape(lambda: jax.random.key(0)),
        S((N_pad, K), jnp.float32), S((D_pad, K), jnp.float32),
        S((N_pad, M), jnp.int32), S((N_pad, M), jnp.float32),
        S((N_pad, M), jnp.float32),
        S((n_shards, D_pad, M_c), jnp.int32),
        S((n_shards, D_pad, M_c), jnp.float32),
        S((n_shards, D_pad, M_c), jnp.float32),
        S((1,), jnp.float32), S((1,), jnp.float32),
        S((1,), jnp.float32), S((1,), jnp.float32),
    )
    jitted = jax.jit(sweep)
    traced = jitted.trace(*args)
    jcost = JCOST.jaxpr_cost(traced.jaxpr)
    compiled = traced.lower().compile()
    terms = ROOF.terms_from(jcost, compiled.as_text(), n_shards)
    analytic = (DIST.sweep_comm_bytes_scatter if scatter_v
                else DIST.sweep_comm_bytes)(D_pad, K)
    return {
        "variant": "scatter_v" if scatter_v else "paper_psum",
        "n_shards": n_shards, "N": N, "D": D, "M": M, "K": K,
        "roofline": terms.as_dict(),
        "analytic_comm_bytes": analytic,
        "collectives": ROOF.collective_bytes(compiled.as_text()),
    }


def lower_pp_phase(n_blocks: int, N: int, D: int, M: int, K: int,
                   chain_len: int):
    """Lower the engine's sharded phase-c bucket: B=n_blocks interior
    blocks, each (N/block-rows × D/block-cols), ONE chain executable
    shard_map'd over the 'block' mesh. Expect zero collective bytes —
    same-phase blocks never talk to each other."""
    from repro.core import gibbs as GIBBS
    from repro.core.distributed import make_block_mesh
    from repro.core.posterior import RowGaussians

    mesh = make_block_mesh(n_blocks)
    cfg = BMF.BMFConfig(K=K)._replace(n_samples=0, burnin=0,
                                      phase_bc_samples=None)
    B = n_blocks
    m_c = max(8, (M * N // D // 8) * 8)
    n_test = 1024
    S = jax.ShapeDtypeStruct
    key_data = S((B, 2), jnp.uint32)
    prior_u = (S((B, N, K), jnp.float32), S((B, N, K, K), jnp.float32))
    prior_v = (S((B, D, K), jnp.float32), S((B, D, K, K), jnp.float32))
    args = (
        key_data,
        (S((B, N, M), jnp.int32), S((B, N, M), jnp.float32),
         S((B, N, M), jnp.float32)),
        (S((B, D, m_c), jnp.int32), S((B, D, m_c), jnp.float32),
         S((B, D, m_c), jnp.float32)),
        S((B, n_test), jnp.int32), S((B, n_test), jnp.int32),
        S((), jnp.int32), S((), jnp.int32),
        RowGaussians(eta=prior_u[0], Lambda=prior_u[1]),
        RowGaussians(eta=prior_v[0], Lambda=prior_v[1]),
        S((B, N, K), jnp.float32), S((B, D, K), jnp.float32),
    )
    traced = GIBBS._run_gibbs_stacked_jit.trace(
        args[0], args[1], args[2], args[3], args[4], cfg, D, N,
        args[5], args[6], args[7], args[8], args[9], args[10], mesh=mesh)
    jcost = JCOST.jaxpr_cost(traced.jaxpr, mult=chain_len)
    compiled = traced.lower().compile()
    coll = ROOF.collective_bytes(compiled.as_text())
    terms = ROOF.terms_from(jcost, compiled.as_text(), n_blocks)
    return {
        "variant": "pp_phase_c_sharded",
        "n_blocks": n_blocks, "N": N, "D": D, "M": M, "K": K,
        "chain_len": chain_len,
        "roofline": terms.as_dict(),
        "collectives": coll,
        "intra_phase_collective_bytes": float(sum(coll.values())),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", type=int, default=256)
    ap.add_argument("--k", type=int, default=100)
    # real-Netflix dims; M = padded nnz/row budget after balance permutation
    ap.add_argument("--n", type=int, default=480_256)
    ap.add_argument("--d", type=int, default=17_792)
    ap.add_argument("--m", type=int, default=512)
    ap.add_argument("--pp-engine", action="store_true",
                    help="also lower the sharded phase-c bucket "
                         "(16 interior blocks of a 5x5 grid)")
    ap.add_argument("--samples", type=int, default=60,
                    help="chain length used to scale --pp-engine flop terms")
    args = ap.parse_args()

    results = []
    for sv in (False, True):
        rec = lower_sweep(args.shards, args.n, args.d, args.m, args.k, sv)
        results.append(rec)
        rf = rec["roofline"]
        print(f"{rec['variant']:12s} compute={rf['compute_s']:.3e}s "
              f"memory={rf['memory_s']:.3e}s collective={rf['collective_s']:.3e}s "
              f"dominant={rf['dominant']} "
              f"(analytic comm {rec['analytic_comm_bytes']/1e6:.0f} MB)")
    if args.pp_engine:
        # 5x5 grid of the same matrix -> 16 interior (phase-c) blocks
        rec = lower_pp_phase(16, args.n // 5 + 1, args.d // 5 + 1,
                             max(8, args.m // 4), args.k, args.samples)
        results.append(rec)
        print(f"{rec['variant']} blocks={rec['n_blocks']} "
              f"intra-phase collective bytes="
              f"{rec['intra_phase_collective_bytes']:.0f} "
              f"(phase boundary is the only communication)")
    OUT.write_text(json.dumps(results, indent=1))
    print("->", OUT)


if __name__ == "__main__":
    main()
