"""AdamW with decoupled weight decay + global-norm clipping.

Built from scratch (optax is not available in the container); the state is a
plain pytree so it shards with the same NamedSharding rules as the params.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def global_norm(tree) -> jnp.ndarray:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def apply(params, grads, state: AdamWState, tcfg: TrainConfig, lr):
    """One AdamW update. ``lr`` may be a scalar (schedule already applied)."""
    step = state.step + 1
    b1, b2, eps, wd = tcfg.beta1, tcfg.beta2, tcfg.eps, tcfg.weight_decay
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m2 / bc1
        vhat = v2 / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + wd * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v)
