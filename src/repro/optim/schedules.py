"""Learning-rate schedules (warmup + cosine decay, constant, rsqrt)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import TrainConfig


def warmup_cosine(tcfg: TrainConfig):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = tcfg.learning_rate * step / max(tcfg.warmup_steps, 1)
        prog = jnp.clip((step - tcfg.warmup_steps) /
                        max(tcfg.total_steps - tcfg.warmup_steps, 1), 0.0, 1.0)
        cos = 0.5 * tcfg.learning_rate * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < tcfg.warmup_steps, warm, cos)
    return lr


def constant(tcfg: TrainConfig):
    return lambda step: jnp.asarray(tcfg.learning_rate, jnp.float32)


def rsqrt(tcfg: TrainConfig):
    def lr(step):
        step = jnp.maximum(step.astype(jnp.float32), 1.0)
        scale = jnp.minimum(step / max(tcfg.warmup_steps, 1),
                            jnp.sqrt(tcfg.warmup_steps / step))
        return tcfg.learning_rate * scale
    return lr
