"""Host-side checkpointing: pytree <-> npz with a JSON manifest.

Works for params, optimizer state, BMF posteriors — any pytree of arrays.
Arrays are gathered to host (fine for the CPU container and for the
single-host driver; a multi-host deployment would swap in a
per-shard writer behind the same interface).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Dict

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":   # npz has no bf16; manifest keeps dtype
            arr = arr.astype(np.float32)
        out[key] = arr
    return out, treedef


def save(path: str | Path, tree: Any, step: int = 0, extra: Dict = None):
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    flat, _ = _flatten(tree)
    np.savez(path.with_suffix(".npz"), **flat)
    manifest = {
        "step": step,
        "keys": sorted(flat.keys()),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        **(extra or {}),
    }
    path.with_suffix(".json").write_text(json.dumps(manifest, indent=1))


def restore(path: str | Path, like: Any) -> Any:
    """Restore into the structure of ``like`` (shape-checked; cast to the
    like-leaf dtype, which round-trips bf16 through the f32 npz storage)."""
    import jax.numpy as jnp
    path = Path(path)
    data = np.load(path.with_suffix(".npz"))
    flat, _ = jax.tree_util.tree_flatten_with_path(like)
    rebuilt = []
    for p, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in p)
        arr = data[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        rebuilt.append(jnp.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), rebuilt)


def manifest(path: str | Path) -> Dict:
    return json.loads(Path(path).with_suffix(".json").read_text())
