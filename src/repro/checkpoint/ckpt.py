"""Host-side checkpointing: pytree <-> npz with a JSON manifest.

Works for params, optimizer state, BMF posteriors — any pytree of arrays.
Arrays are gathered to host (fine for the CPU container and for the
single-host driver; a multi-host deployment would swap in a
per-shard writer behind the same interface).
"""
from __future__ import annotations

import json
import os
import tempfile
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":   # npz has no bf16; manifest keeps dtype
            arr = arr.astype(np.float32)
        out[key] = arr
    return out, treedef


def save(path: str | Path, tree: Any, step: int = 0, extra: Dict = None):
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    flat, _ = _flatten(tree)
    np.savez(path.with_suffix(".npz"), **flat)
    manifest = {
        "step": step,
        "keys": sorted(flat.keys()),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        **(extra or {}),
    }
    path.with_suffix(".json").write_text(json.dumps(manifest, indent=1))


def restore(path: str | Path, like: Any) -> Any:
    """Restore into the structure of ``like`` (shape-checked; cast to the
    like-leaf dtype, which round-trips bf16 through the f32 npz storage)."""
    import jax.numpy as jnp
    path = Path(path)
    data = np.load(path.with_suffix(".npz"))
    flat, _ = jax.tree_util.tree_flatten_with_path(like)
    rebuilt = []
    for p, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in p)
        arr = data[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        rebuilt.append(jnp.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), rebuilt)


def manifest(path: str | Path) -> Dict:
    return json.loads(Path(path).with_suffix(".json").read_text())


# ---------------------------------------------------------------------------
# Phase-graph (PP) block-level checkpoint store
# ---------------------------------------------------------------------------


def _atomic_savez(path: Path, **arrays):
    """npz write that is atomic under kill -9: write to a temp file in the
    same directory, fsync, then os.replace — a resume never observes a
    torn block file (it either exists complete or not at all)."""
    fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".npz.tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **arrays)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


class PPCheckpoint:
    """Per-block posterior store behind the phase-graph engine's
    checkpoint/resume (core.engine ``run_phase_graph(resume_from=...)``).

    Layout: one ``block_{i}_{j}.npz`` per resolved block holding the
    trimmed ``RowGaussians`` natural parameters (U_eta/U_Lambda/V_eta/
    V_Lambda), the block's test squared error and observation count, plus
    a ``meta.json`` describing the run IDENTITY only (grid, K, chain
    config, PRNG key — deliberately NOT the executor or topology: block
    posteriors are placement-independent, so a run checkpointed on a
    4x1 topology legitimately resumes on 2x2 and stays bitwise
    identical). The resolved-set IS the set of complete block files — no
    separate index to keep consistent, and each file is written atomically
    (``_atomic_savez``), so a run killed at ANY instant leaves a valid
    resumable directory.

    ``every`` batches writes: blocks are buffered and flushed to disk every
    ``every``-th resolve (a kill loses at most ``every - 1`` resolved
    blocks — they are simply recomputed on resume). Posterior arrays are
    float32 end to end, so a save/load round trip is bitwise exact — the
    engine's resume-bitwise-identity guarantee rests on that.
    """

    META = "meta.json"

    def __init__(self, directory: str | Path, every: int = 1):
        if int(every) < 1:
            raise ValueError(f"ckpt_every must be >= 1, got {every}")
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.every = int(every)
        self._pending: List[Tuple[Tuple[int, int], Dict[str, np.ndarray]]] = []

    # -- writing ---------------------------------------------------------

    def write_meta(self, meta: Dict):
        fd, tmp = tempfile.mkstemp(dir=self.dir, suffix=".json.tmp")
        with os.fdopen(fd, "w") as f:
            json.dump(meta, f, indent=1)
        os.replace(tmp, self.dir / self.META)

    def note(self, coord: Tuple[int, int], U_post, V_post,
             sq: float, n_obs: int):
        """Buffer one resolved block; flush every ``self.every`` notes."""
        self._pending.append((coord, {
            "U_eta": np.asarray(U_post.eta),
            "U_Lambda": np.asarray(U_post.Lambda),
            "V_eta": np.asarray(V_post.eta),
            "V_Lambda": np.asarray(V_post.Lambda),
            "sq": np.float64(sq),
            "n_obs": np.int64(n_obs),
        }))
        if len(self._pending) >= self.every:
            self.flush()

    def flush(self):
        for (i, j), arrays in self._pending:
            _atomic_savez(self.dir / f"block_{i}_{j}.npz", **arrays)
        self._pending = []

    # -- reading ---------------------------------------------------------

    @staticmethod
    def read_meta(directory: str | Path) -> Dict:
        return json.loads((Path(directory) / PPCheckpoint.META).read_text())

    @staticmethod
    def load_blocks(directory: str | Path
                    ) -> Dict[Tuple[int, int], Dict[str, np.ndarray]]:
        """All complete block files: {(i, j): {U_eta, U_Lambda, V_eta,
        V_Lambda, sq, n_obs}} with numpy leaves."""
        out: Dict[Tuple[int, int], Dict[str, np.ndarray]] = {}
        for p in sorted(Path(directory).glob("block_*_*.npz")):
            _, i, j = p.stem.split("_")
            with np.load(p) as data:
                out[(int(i), int(j))] = {k: data[k] for k in data.files}
        return out
