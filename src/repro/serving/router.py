"""Micro-batching request plane for the scoring path.

Requests arrive one at a time with ragged seen/fold lists; executables
want fixed shapes. The router closes the gap with the same trick the
streaming executor uses for window buffers: a ladder of power-of-two
candidate shapes, coalesced through ``partition.coalesce_shapes`` under a
padded-footprint waste budget, so the WHOLE ladder compiles to a handful
of executables (ONE per coalesced bucket — the recompilation-budget lint
pass checks the realized plan).

Batching rule: a request waits at most ``latency_budget_s`` — a batch
dispatches as soon as it is full (``max_batch``) OR its oldest request's
wait exceeds the budget. ``poll(now)`` drives the clock (callers pass
``now`` explicitly in tests; wall-clock by default); ``flush`` force-
dispatches the tail.

The router is deliberately host-side and synchronous: its job is shape
management and latency accounting, not concurrency — scoring itself is
one jitted call per dispatch on a ``ScoringWorker`` (workers round-robin,
sharing the jit cache, a seam for pinning stores to devices later).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.partition import coalesce_shapes
from repro.serving.scoring import MODES, RequestBatch, score_topk
from repro.serving.store import PosteriorStore


@dataclass
class Request:
    """One recommendation request. ``seen`` items are excluded from the
    top-K; ``fold_items``/``fold_ratings`` are in-request feedback folded
    into the user's conditional posterior (cold-start: user_id = -1)."""
    user_id: int
    seen: Sequence[int] = ()
    fold_items: Sequence[int] = ()
    fold_ratings: Sequence[float] = ()


@dataclass
class Ticket:
    """Handle returned by ``submit``; filled in when its batch dispatches."""
    t_submit: float
    done: bool = False
    ids: Optional[np.ndarray] = None       # (k,)
    scores: Optional[np.ndarray] = None    # (k,)
    valid: Optional[np.ndarray] = None     # (k,)
    latency_s: float = 0.0


def _ladder(lo: int, hi: int) -> List[int]:
    """Power-of-two rungs lo..>=hi (plus hi itself)."""
    out, v = [], max(1, lo)
    while v < hi:
        out.append(v)
        v *= 2
    out.append(hi)
    return sorted(set(out))


@dataclass
class ScoringWorker:
    """One scoring endpoint: a store plus the (k, mode) the executable is
    specialized on. ``score`` is a thin jitted-dispatch wrapper — a
    placement seam (per-device stores) more than a compute unit."""
    store: PosteriorStore
    k: int
    mode: str

    def score(self, batch: RequestBatch):
        return score_topk(self.store, batch, k=self.k, mode=self.mode)


class MicroBatchRouter:
    """Coalesce requests into shape-bucketed fixed batches under a latency
    budget and dispatch them to scoring workers."""

    def __init__(self, store: PosteriorStore, k: int = 10,
                 mode: str = "mean", latency_budget_s: float = 0.005,
                 max_batch: int = 32, max_seen: int = 64, max_fold: int = 8,
                 max_waste: float = 1.5, n_workers: int = 1, seed: int = 0):
        if mode not in MODES:
            raise ValueError(f"unknown scoring mode {mode!r} "
                             f"(expected {MODES})")
        self.k, self.mode = int(k), mode
        self.latency_budget_s = float(latency_budget_s)
        self.max_batch, self.max_seen = int(max_batch), int(max_seen)
        self.max_fold = int(max_fold)
        self.workers = [ScoringWorker(store, self.k, mode)
                        for _ in range(max(1, n_workers))]
        self._next_worker = 0
        self._rng = np.random.default_rng(seed)
        self._queue: List[Tuple[Request, Ticket]] = []
        # per-request padded cost of one executable: the (M, K) score row /
        # gathered sample slot DOMINATES the seen/fold request-plane
        # arrays, so the waste budget measures real compute+bytes — all
        # (L, F) variants of a batch rung coalesce into one executable,
        # while batch rungs stay distinct (doubling B is 2x real work,
        # over a max_waste < 2 budget)
        self._req_cost = store.n_items * store.K
        cand = {(b, l, f): (b, l, f)
                for b in _ladder(1, self.max_batch)
                for l in _ladder(1, self.max_seen)
                for f in _ladder(1, self.max_fold)}
        self.bucket_table: Dict[Tuple[int, int, int], Tuple[int, int, int]] \
            = coalesce_shapes(cand, self._footprint, max_waste=max_waste)
        self.dispatches: List[Tuple[Tuple[int, int, int], int]] = []
        self.latencies_s: List[float] = []

    def _footprint(self, shape: Tuple[int, int, int]) -> float:
        b, l, f = shape
        return float(b * (l + f + self._req_cost))

    @property
    def plan_signatures(self) -> List[Tuple[int, int, int]]:
        """Distinct executables the ladder compiles to (plan lint input)."""
        return sorted(set(self.bucket_table.values()))

    def bucket_for(self, n_reqs: int, n_seen: int, n_fold: int):
        """Smallest ladder rung >= each dim, then its coalesced shape."""
        def rung(v, hi):
            for r in _ladder(1, hi):
                if r >= v:
                    return r
            raise ValueError(f"request dim {v} exceeds router cap {hi}")
        return self.bucket_table[(rung(n_reqs, self.max_batch),
                                  rung(max(1, n_seen), self.max_seen),
                                  rung(max(1, n_fold), self.max_fold))]

    # -- request plane ------------------------------------------------------

    def submit(self, req: Request, now: Optional[float] = None) -> Ticket:
        if len(req.seen) > self.max_seen:
            raise ValueError(f"seen list ({len(req.seen)}) exceeds "
                             f"max_seen={self.max_seen}")
        if len(req.fold_items) > self.max_fold:
            raise ValueError(f"fold list ({len(req.fold_items)}) exceeds "
                             f"max_fold={self.max_fold}")
        if len(req.fold_items) != len(req.fold_ratings):
            raise ValueError("fold_items and fold_ratings length mismatch")
        t = Ticket(t_submit=time.monotonic() if now is None else now)
        self._queue.append((req, t))
        if len(self._queue) >= self.max_batch:
            self._dispatch(self._queue[:self.max_batch], now)
        return t

    def poll(self, now: Optional[float] = None) -> int:
        """Dispatch the pending batch iff its oldest request has waited
        past the latency budget. Returns requests dispatched."""
        now_eff = time.monotonic() if now is None else now
        if self._queue and \
                now_eff - self._queue[0][1].t_submit >= self.latency_budget_s:
            return self._dispatch(self._queue, now)
        return 0

    def flush(self, now: Optional[float] = None) -> int:
        """Force-dispatch everything pending (shutdown / bench tail)."""
        n = 0
        while self._queue:
            n += self._dispatch(self._queue[:self.max_batch], now)
        return n

    # -- dispatch -----------------------------------------------------------

    def _dispatch(self, pairs, now: Optional[float]) -> int:
        pairs = list(pairs)
        del self._queue[:len(pairs)]
        reqs = [r for r, _ in pairs]
        shape = self.bucket_for(
            len(reqs),
            max((len(r.seen) for r in reqs), default=0),
            max((len(r.fold_items) for r in reqs), default=0))
        out = self._worker().score(self._pad_batch(reqs, shape))
        ids = np.asarray(out.ids)
        scores = np.asarray(out.scores)
        valid = np.asarray(out.valid)
        # wall-clock callers get latency INCLUSIVE of the scoring call
        # (np.asarray above blocks on the device result); explicit-now
        # callers keep a deterministic clock for tests
        t_done = time.monotonic() if now is None else now
        for i, (_, t) in enumerate(pairs):
            t.ids, t.scores, t.valid = ids[i], scores[i], valid[i]
            t.done = True
            t.latency_s = max(0.0, t_done - t.t_submit)
            self.latencies_s.append(t.latency_s)
        self.dispatches.append((shape, len(pairs)))
        return len(pairs)

    def _worker(self) -> ScoringWorker:
        w = self.workers[self._next_worker]
        self._next_worker = (self._next_worker + 1) % len(self.workers)
        return w

    def _pad_batch(self, reqs: List[Request], shape) -> RequestBatch:
        B, L, F = shape
        uid = np.full((B,), -1, np.int32)
        s_idx = np.zeros((B, L), np.int32)
        s_msk = np.zeros((B, L), np.float32)
        f_idx = np.zeros((B, F), np.int32)
        f_val = np.zeros((B, F), np.float32)
        f_msk = np.zeros((B, F), np.float32)
        for i, r in enumerate(reqs):
            uid[i] = r.user_id
            ns, nf = len(r.seen), len(r.fold_items)
            s_idx[i, :ns] = np.asarray(r.seen, np.int32)
            s_msk[i, :ns] = 1.0
            f_idx[i, :nf] = np.asarray(r.fold_items, np.int32)
            f_val[i, :nf] = np.asarray(r.fold_ratings, np.float32)
            f_msk[i, :nf] = 1.0
        key_data = self._rng.integers(0, 2 ** 32, size=(B, 2),
                                      dtype=np.uint32)
        return RequestBatch(user_ids=uid, seen_idx=s_idx, seen_mask=s_msk,
                            fold_idx=f_idx, fold_val=f_val, fold_mask=f_msk,
                            key_data=key_data)
