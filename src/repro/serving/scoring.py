"""Jitted batched top-K scoring over a ``PosteriorStore``.

One executable per (batch shape, k, mode): gather user posterior rows →
fold-in conditional over in-request feedback → score against the item
factors → mask seen items → ``lax.top_k``. Two modes share everything up
to the score matrix:

  mean      μ_u = (Λ_u + jitter·I)⁻¹ η_u, scores = μ_u @ V_meanᵀ — exact
            posterior-mean ranking, bitwise-deterministic (no RNG input).
  thompson  u ~ N(μ_u, Λ_u⁻¹) per request (fresh draw from the per-request
            PRNG key), scored against ONE stored item-posterior sample
            slot picked by the same key — Thompson sampling over the joint
            posterior, the uncertainty-exploiting policy the paper's
            Bayesian treatment buys.

Fold-in conditional (why serving can personalize without retraining): for
feedback (j, r) supplied with the request, the user row's conditional
posterior given the trained item factors V is the conjugate update

    Λ ← Λ + τ Σ_f m_f v_f v_fᵀ        η ← η + τ Σ_f m_f r_f v_f

against the fixed V_mean — the same likelihood form the Gibbs sweep uses
(``bmf.sufficient_stats``), so a cold-start request (user_id < 0, identity
prior) folded over its history approximates the trained row. Requests are
FIXED-shape: seen/fold lists are padded and masked, so the router's shape
buckets map 1:1 to executables.

Seen-item masking uses an out-of-bounds scatter-drop: padded seen slots
redirect to column index M, which ``mode="drop"`` discards — no (B, M)
one-hot mask materialization. The whole path never forms anything larger
than the (B, M, K) gathered sample slots (``scoring_budget`` is the lint
budget; ``trace_scoring`` the lowering hook ``bmf_lint`` feeds the jaxpr
passes).

Invariants (lint-enforced): no dense (N, M) score matrix — scoring is per
REQUEST batch, never all-users; no host callback inside the jitted body.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import posterior as POST
from repro.core.posterior import RowGaussians
from repro.serving.store import PosteriorStore, _posterior_mean

MODES = ("mean", "thompson")


class RequestBatch(NamedTuple):
    """One fixed-shape scoring batch. Pad rows with user_id = -1 and
    all-zero masks; pad slots in seen/fold lists with mask 0."""
    user_ids: jnp.ndarray   # (B,)   i32, -1 = cold-start (identity prior)
    seen_idx: jnp.ndarray   # (B, L) i32 item ids to exclude from top-K
    seen_mask: jnp.ndarray  # (B, L) f32 1 = real, 0 = padding
    fold_idx: jnp.ndarray   # (B, F) i32 fold-in feedback item ids
    fold_val: jnp.ndarray   # (B, F) f32 fold-in ratings
    fold_mask: jnp.ndarray  # (B, F) f32
    key_data: jnp.ndarray   # (B, 2) u32 raw per-request PRNG key data


class TopK(NamedTuple):
    ids: jnp.ndarray        # (B, k) i32 item ids, best first
    scores: jnp.ndarray     # (B, k) f32, -inf on invalid slots
    valid: jnp.ndarray      # (B, k) bool — False when < k scorable items


def _fold_in(g: RowGaussians, batch: RequestBatch, V_mean, tau):
    """Conjugate per-request conditional update against fixed item means."""
    v = V_mean[batch.fold_idx]                               # (B, F, K)
    m = batch.fold_mask
    Lam = g.Lambda + tau * jnp.einsum("bf,bfk,bfl->bkl", m, v, v)
    eta = g.eta + tau * jnp.einsum("bf,bf,bfk->bk", m, batch.fold_val, v)
    return RowGaussians(eta=eta, Lambda=Lam)


@partial(jax.jit, static_argnames=("k", "mode", "jitter"))
def score_topk(store: PosteriorStore, batch: RequestBatch, k: int,
               mode: str = "mean", jitter: float = 1e-6) -> TopK:
    if mode not in MODES:
        raise ValueError(f"unknown scoring mode {mode!r} (expected {MODES})")
    B = batch.user_ids.shape[0]
    M, K = store.V_mean.shape

    cold = batch.user_ids < 0
    uid = jnp.where(cold, 0, batch.user_ids)
    eye = jnp.eye(K, dtype=store.U.Lambda.dtype)
    g = RowGaussians(
        eta=jnp.where(cold[:, None], 0.0, store.U.eta[uid]),
        Lambda=jnp.where(cold[:, None, None], eye, store.U.Lambda[uid]))
    g = _fold_in(g, batch, store.V_mean, store.tau)

    if mode == "mean":
        mu = _posterior_mean(g, jitter)                      # (B, K)
        scores = mu @ store.V_mean.T                         # (B, M)
    else:
        keys = jax.random.wrap_key_data(batch.key_data)      # (B,) keys
        kz = jax.vmap(jax.random.fold_in, (0, None))(keys, 0)
        ks = jax.vmap(jax.random.fold_in, (0, None))(keys, 1)
        z = jax.vmap(lambda kk: jax.random.normal(kk, (K,)))(kz)
        u = POST.sample_rows_noise(g, z, jitter=jitter)      # (B, K)
        slot = jax.vmap(lambda kk: jax.random.randint(
            kk, (), 0, store.n_slots))(ks)                   # (B,)
        scores = jnp.einsum("bk,bmk->bm", u, store.V_samples[slot])

    # seen masking: padded slots redirect to out-of-bounds column M, which
    # scatter mode="drop" discards — no (B, M) one-hot intermediate
    seen_col = jnp.where(batch.seen_mask > 0, batch.seen_idx, M)
    scores = scores.at[jnp.arange(B)[:, None], seen_col].set(
        -jnp.inf, mode="drop")

    vals, idx = jax.lax.top_k(scores, k)   # stable: lowest index wins ties
    return TopK(ids=idx.astype(jnp.int32), scores=vals,
                valid=vals > -jnp.inf)


# ---------------------------------------------------------------------------
# static-analyzer hooks (launch.bmf_lint)
# ---------------------------------------------------------------------------


class TracedScoring(NamedTuple):
    """What the analyzer needs from one scoring lowering: the jax Traced
    object (``.jaxpr`` feeds the jaxpr passes) plus flat parameter labels
    for report readability."""
    traced: object
    param_labels: Tuple[str, ...]


def scoring_budget(n_users: int, n_items: int, K: int, batch: int,
                   n_slots: int, slack: float = 2.0) -> int:
    """Largest buffer the scoring executable legitimately holds: the store
    precision tensors (N·K² f32), the resident sample slots (S·M·K), or
    the per-batch gathered slots (B·M·K) — whichever is bigger, times
    ``slack`` for layout headroom. The banned formulation scores ALL users
    against all items at once (the dense N×M matrix): at lint dims that is
    > slack× over every legitimate buffer, so it trips the
    materialization pass."""
    store_side = max(n_users, n_items) * K * K
    slots = n_slots * n_items * K
    gathered = batch * n_items * K
    return int(slack * 4 * max(store_side, slots, gathered))


def abstract_store(n_users: int, n_items: int, K: int,
                   n_slots: int) -> PosteriorStore:
    """A shape-only store (ShapeDtypeStructs): feeds ``trace_scoring`` and
    lets the lint driver build a ``MicroBatchRouter`` bucket plan without
    training anything (the router only reads n_items/K from the store)."""
    S_ = jax.ShapeDtypeStruct
    f32 = jnp.float32
    return PosteriorStore(
        U=RowGaussians(eta=S_((n_users, K), f32),
                       Lambda=S_((n_users, K, K), f32)),
        V=RowGaussians(eta=S_((n_items, K), f32),
                       Lambda=S_((n_items, K, K), f32)),
        U_mean=S_((n_users, K), f32), V_mean=S_((n_items, K), f32),
        V_samples=S_((n_slots, n_items, K), f32), tau=S_((), f32))


def trace_scoring(n_users: int, n_items: int, K: int, batch: int,
                  n_seen: int, n_fold: int, n_slots: int, k: int,
                  mode: str) -> TracedScoring:
    """Trace the EXACT executable ``score_topk`` dispatches for one shape
    bucket, at abstract shapes — the serving analogue of
    ``gibbs.trace_chain``."""
    S_ = jax.ShapeDtypeStruct
    f32, i32, u32 = jnp.float32, jnp.int32, jnp.uint32
    store = abstract_store(n_users, n_items, K, n_slots)
    reqs = RequestBatch(
        user_ids=S_((batch,), i32),
        seen_idx=S_((batch, n_seen), i32), seen_mask=S_((batch, n_seen), f32),
        fold_idx=S_((batch, n_fold), i32), fold_val=S_((batch, n_fold), f32),
        fold_mask=S_((batch, n_fold), f32),
        key_data=S_((batch, 2), u32))
    traced = score_topk.trace(store, reqs, k=k, mode=mode)
    labels = tuple(f"store.{f}" for f in ("U.eta", "U.Lambda", "V.eta",
                                          "V.Lambda", "U_mean", "V_mean",
                                          "V_samples", "tau"))
    labels += tuple(f"batch.{f}" for f in RequestBatch._fields)
    return TracedScoring(traced=traced, param_labels=labels)
