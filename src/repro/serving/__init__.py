"""Posterior serving layer — the trained PPResult as a live artifact.

Training (core.pp / core.engine) ends with aggregated per-row Gaussian
posteriors in natural parameters; this package is the other half of the
ROADMAP's "millions of users" story: keep those posteriors DEVICE-resident
and answer batched top-K recommendation requests from them, exploiting the
uncertainty the paper trains for (Thompson sampling over posterior draws)
alongside exact posterior-mean ranking.

  store    — ``PosteriorStore``: U/V moment summaries + S item-factor
             posterior sample slots, built from any executor's
             ``PPResult`` in one jitted gather (no host round-trip).
  scoring  — the jitted batched scoring path: gather → fold-in
             conditional → ``U_u @ V_meanᵀ`` (or per-request posterior
             draw) → seen-item masking → ``lax.top_k``; plus the
             ``trace_scoring`` lowering hook and ``scoring_budget`` the
             static analyzer lints against.
  router   — ``MicroBatchRouter``: coalesces requests under a latency
             budget into fixed shape-bucketed batches
             (``partition.coalesce_shapes`` over padded request shapes,
             ONE executable per bucket) and dispatches to scoring
             workers.
"""
from repro.serving.store import PosteriorStore               # noqa: F401
from repro.serving.scoring import (                          # noqa: F401
    RequestBatch, score_topk, scoring_budget, trace_scoring)
from repro.serving.router import (                           # noqa: F401
    MicroBatchRouter, Request, ScoringWorker)
