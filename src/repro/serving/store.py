"""Device-resident posterior store — a trained ``PPResult`` made servable.

``run_pp``'s aggregated posteriors live in PERMUTED row/col space (the
load-balancing permutation the partition applied); a store is those same
natural parameters gathered back to ORIGINAL user/item ids, plus the
derived moment summaries scoring needs (posterior means) and ``n_slots``
item-factor posterior samples for Thompson scoring. The whole build is ONE
jitted executable over the result's device arrays — the posteriors never
round-trip through the host (only the permutation index vectors are
shipped up, they are host numpy to begin with).

Layout (all jax arrays, original id space):

  U         RowGaussians (N, K) / (N, K, K)   user posterior, natural params
  V         RowGaussians (M, K) / (M, K, K)   item posterior
  U_mean    (N, K)      Λ⁻¹η via jittered Cholesky (matches the scoring path)
  V_mean    (M, K)
  V_samples (S, M, K)   slot s = one joint posterior draw of ALL item rows
  tau       ()          rating precision the fold-in conditional reuses

A Thompson request pairs a fresh user-factor draw with ONE slot (a
coherent item-matrix sample), so item-side uncertainty enters scoring
without per-request (M, K, K) sampling work.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import posterior as POST
from repro.core.posterior import RowGaussians


def _project_pd(Lambda: jnp.ndarray, rel_floor: float = 1e-4) -> jnp.ndarray:
    """Project per-row precisions onto the PD cone.

    The divide-away aggregation subtracts multiply-counted priors from
    SAMPLE-ESTIMATED per-block precisions; for weakly observed rows the
    estimation noise makes the difference indefinite (short chains: up to
    ~40% of rows), which would NaN every Cholesky in the serving path.
    Serving's sanitization: symmetrize, then clamp each eigenvalue to its
    MAGNITUDE (floored at rel_floor x the row's largest magnitude) — the
    information scale of a flipped direction is preserved and the per-row
    condition number is bounded by 1/rel_floor, so posterior draws stay
    sane instead of exploding along noise directions."""
    sym = (Lambda + jnp.swapaxes(Lambda, -1, -2)) / 2
    ev, Q = jnp.linalg.eigh(sym)
    mag = jnp.abs(ev)
    floor = jnp.maximum(rel_floor * jnp.max(mag, axis=-1, keepdims=True),
                        1e-6)
    return jnp.einsum("...ik,...k,...jk->...ij", Q, jnp.maximum(mag, floor),
                      Q)


def _posterior_mean(g: RowGaussians, jitter: float) -> jnp.ndarray:
    """μ = (Λ + jitter·I)⁻¹ η via Cholesky — the SAME factor+solve the
    scoring path and ``sample_rows_noise`` use, so store means and scores
    computed from raw natural params agree bitwise."""
    K = g.eta.shape[-1]
    chol = jnp.linalg.cholesky(g.Lambda + jitter * jnp.eye(K))
    return jax.scipy.linalg.cho_solve((chol, True), g.eta[..., None])[..., 0]


class PosteriorStore(NamedTuple):
    U: RowGaussians            # (N, K) / (N, K, K), original user ids
    V: RowGaussians            # (M, K) / (M, K, K), original item ids
    U_mean: jnp.ndarray        # (N, K)
    V_mean: jnp.ndarray        # (M, K)
    V_samples: jnp.ndarray     # (S, M, K)
    tau: jnp.ndarray           # () f32

    @property
    def n_users(self) -> int:
        return self.U_mean.shape[0]

    @property
    def n_items(self) -> int:
        return self.V_mean.shape[0]

    @property
    def K(self) -> int:
        return self.V_mean.shape[-1]

    @property
    def n_slots(self) -> int:
        return self.V_samples.shape[0]

    @classmethod
    def from_pp_result(cls, res, key=None, n_slots: int = 8,
                       jitter: float = 1e-6) -> "PosteriorStore":
        """Build a store from any executor's ``PPResult``.

        The result must carry the serving seam (``row_perm``/``col_perm``/
        ``tau`` — populated by ``engine.run_phase_graph`` since the store
        existed); ``key`` seeds the item-slot posterior draws."""
        if res.row_perm is None or res.col_perm is None or res.tau is None:
            raise ValueError(
                "PPResult lacks the serving export seam (row_perm/col_perm/"
                "tau are None) — re-run training with the current engine; "
                "pre-seam checkpointed results cannot be served")
        if key is None:
            key = jax.random.key(0)
        return _build_store(res.U_agg, res.V_agg,
                            jnp.asarray(res.row_perm, jnp.int32),
                            jnp.asarray(res.col_perm, jnp.int32),
                            jnp.asarray(res.tau, jnp.float32), key,
                            n_slots=int(n_slots), jitter=float(jitter))


@partial(jax.jit, static_argnames=("n_slots", "jitter"))
def _build_store(U_agg: RowGaussians, V_agg: RowGaussians, row_perm,
                 col_perm, tau, key, n_slots: int,
                 jitter: float) -> PosteriorStore:
    # perm maps original id -> permuted position, so the ORIGINAL-space
    # posteriors are one device gather per factor side; precisions are
    # PD-projected so every downstream Cholesky is well-defined
    U = RowGaussians(eta=U_agg.eta[row_perm],
                     Lambda=_project_pd(U_agg.Lambda[row_perm]))
    V = RowGaussians(eta=V_agg.eta[col_perm],
                     Lambda=_project_pd(V_agg.Lambda[col_perm]))
    slot_keys = jax.random.split(key, n_slots)
    V_samples = jax.vmap(
        lambda kk: POST.sample_rows(kk, V, jitter=jitter))(slot_keys)
    return PosteriorStore(U=U, V=V,
                          U_mean=_posterior_mean(U, jitter),
                          V_mean=_posterior_mean(V, jitter),
                          V_samples=V_samples, tau=tau)
