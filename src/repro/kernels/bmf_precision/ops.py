"""Jit'd public wrappers for the bmf_precision kernel.

``precision_accum`` is the hot-path entry point used by
``core.bmf.sufficient_stats(use_kernel=True)``.  Neither implementation it
dispatches to ever materializes the gathered (N, M, K) factor tensor:

  - on TPU: the fused-gather Pallas kernel (kernel.py) — column indices are
    scalar-prefetched, factor rows are DMA'd from HBM into VMEM per tile.
  - off TPU: an N-striped XLA fallback gathering only (n_stripe, M, K) per
    stripe, in the symmetric one-operand form (interpret-mode Pallas is
    orders of magnitude slower than XLA on CPU, so it is reserved for
    parity tests).

``precision_accum_fused`` exposes the Pallas path directly (interpret mode
off-TPU) for parity testing; ``precision_accum_reference`` is the dense
full-gather oracle — it is the ONLY path that builds (N, M, K).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.bmf_precision.kernel import (
    LANES, TM, TN, precision_accum_fused_padded)
from repro.kernels.bmf_precision.ref import precision_accum_ref
from repro.data.sparse import tile_occupancy


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# peak-gather budget (elements) of the chunked XLA fallback: the N axis is
# striped so each stripe's (n_stripe, M, K) gather stays near this budget
# (~8 MB f32).  Stripes are independent row blocks — full-M matmuls, no
# accumulator chain — which measured faster than M-tiling at every shape
# tried (thin M-tiles serialize; fat ones just re-create the blowup)
CHUNK_BUDGET_ELEMS = 2 << 20

# scalar-prefetch operands live in SMEM, which is KB-scale: cap the (N, M)
# int32 index plane per pallas_call and stripe the N axis above it (each
# stripe is an independent call; outputs concatenate along N)
SMEM_IDX_BUDGET = 256 * 1024


def _pad_to(x, n, axis):
    pad = n - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("tau",))
def precision_accum(idx, val, mask, other, tau: float):
    """idx/val/mask: padded CSR (N, M); other: (D, K) factor matrix.
    Returns (Lam (N, K, K), eta (N, K)) likelihood contributions."""
    if _on_tpu():
        return precision_accum_fused(idx, val, mask, other, tau,
                                     interpret=False)
    return precision_accum_chunked(idx, val, mask, other, tau)


def precision_accum_fused(idx, val, mask, other, tau: float, *,
                          tm: int = TM, interpret=None,
                          smem_idx_budget: int = SMEM_IDX_BUDGET):
    """Fused-gather Pallas path: pads (N, M) to tile multiples and K to the
    LANES width, computes per-row-tile occupancy, and dispatches.  The
    gather happens INSIDE the kernel — peak live memory here is O(N·M) CSR
    planes + O(D·K) factors + O(N·K²) outputs.

    The scalar-prefetched index plane sits in SMEM, so the N axis is
    striped such that each pallas_call's (n_stripe, M) int32 plane stays
    under ``smem_idx_budget`` bytes.  Stripes run under ``lax.map`` — ONE
    pallas_call in the program regardless of N (a Python loop would emit
    one call per stripe and blow up compile time at web-scale N), with
    ``other`` resident across all stripes."""
    if interpret is None:
        interpret = not _on_tpu()
    N, M = idx.shape
    D, K = other.shape
    Kp = ((K + LANES - 1) // LANES) * LANES
    Mp = ((M + tm - 1) // tm) * tm
    ns = max(TN, (smem_idx_budget // (Mp * 4)) // TN * TN)
    Np = ((N + ns - 1) // ns) * ns                 # rows pad to whole stripes

    idxp = _pad_to(idx, Mp, 1)
    idxp = _pad_to(idxp, Np, 0)                    # padded slots gather row 0
    valp = _pad_to(_pad_to(val, Mp, 1), Np, 0)
    maskp = _pad_to(_pad_to(mask, Mp, 1), Np, 0)   # ... but are masked out
    otherp = _pad_to(other, Kp, 1)

    def stripe(args):
        ix, vl, mk = args
        return precision_accum_fused_padded(
            ix, tile_occupancy(mk, TN, tm), vl, mk, otherp, tau,
            tm=tm, interpret=interpret)

    if Np == ns:
        Lam, eta = stripe((idxp, valp, maskp))
    else:
        nsp = Np // ns
        Lam, eta = jax.lax.map(stripe, (idxp.reshape(nsp, ns, Mp),
                                        valp.reshape(nsp, ns, Mp),
                                        maskp.reshape(nsp, ns, Mp)))
        Lam = Lam.reshape(Np, Kp, Kp)
        eta = eta.reshape(Np, Kp)
    return Lam[:N, :K, :K], eta[:N, :K]


def precision_accum_chunked(idx, val, mask, other, tau: float, *,
                            budget_elems: int = CHUNK_BUDGET_ELEMS):
    """XLA fallback with the same zero-materialization property: the N axis
    is striped so only an (n_stripe, M, K) gather is ever live.  Stripes
    are independent (outputs concatenate along N), so each keeps the fat
    full-M batched matmul, and the loop is statically unrolled — a lax
    loop would wall off the per-stripe gather+matmul from XLA fusion."""
    N, M = idx.shape
    K = other.shape[-1]
    n_stripe = max(8, budget_elems // max(M * K, 1) // 8 * 8)
    if N <= n_stripe:
        return _sym_tile(idx, val, mask, other, tau)
    lams, etas = [], []
    for lo in range(0, N, n_stripe):
        hi = min(lo + n_stripe, N)
        l, e = _sym_tile(idx[lo:hi], val[lo:hi], mask[lo:hi], other, tau)
        lams.append(l)
        etas.append(e)
    return jnp.concatenate(lams), jnp.concatenate(etas)


def _sym_tile(ix, vl, mk, other, tau):
    """Sufficient stats of one row stripe in the symmetric form: for 0/1
    masks, Σ w vvᵀ = (w⊙V)ᵀ(w⊙V), so ONE masked gather feeds both matmul
    operands (the two-operand ``einsum(Vm, V)`` form makes XLA keep a
    second gathered buffer live and is measurably slower)."""
    Vm = other[ix] * mk[..., None]
    lam = tau * jax.lax.dot_general(Vm, Vm, (((1,), (1,)), ((0,), (0,))),
                                    preferred_element_type=jnp.float32)
    eta = tau * jnp.einsum("nm,nmk->nk", vl, Vm,
                           preferred_element_type=jnp.float32)
    return lam, eta


def precision_accum_reference(idx, val, mask, other, tau: float):
    """Dense full-gather oracle — materializes (N, M, K); test/bench only."""
    Vg = other[idx]
    return precision_accum_ref(Vg, val, mask, tau)
