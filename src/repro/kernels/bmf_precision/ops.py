"""Jit'd public wrapper for the bmf_precision kernel.

Handles the gather (stays in XLA — it's HBM-bandwidth work), pads
(N, M, K) to kernel tile multiples (K to the 128 MXU lanes), dispatches to
the Pallas kernel (interpret=True off-TPU), and slices the padding away.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.bmf_precision.kernel import TM, TN, precision_accum_padded
from repro.kernels.bmf_precision.ref import precision_accum_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("tau",))
def precision_accum(idx, val, mask, other, tau: float):
    """idx/val/mask: padded CSR (N, M); other: (D, K) factor matrix.
    Returns (Lam (N,K,K), eta (N,K)) likelihood contributions."""
    N, M = idx.shape
    K = other.shape[-1]
    Vg = other[idx]                                   # (N, M, K) gather in XLA

    Kp = ((K + 127) // 128) * 128
    Np = ((N + TN - 1) // TN) * TN
    Mp = ((M + TM - 1) // TM) * TM
    Vp = jnp.zeros((Np, Mp, Kp), Vg.dtype).at[:N, :M, :K].set(Vg)
    valp = jnp.zeros((Np, Mp), val.dtype).at[:N, :M].set(val)
    maskp = jnp.zeros((Np, Mp), mask.dtype).at[:N, :M].set(mask)

    Lam, eta = precision_accum_padded(Vp, valp, maskp, tau,
                                      interpret=not _on_tpu())
    return Lam[:N, :K, :K], eta[:N, :K]


def precision_accum_reference(idx, val, mask, other, tau: float):
    Vg = other[idx]
    return precision_accum_ref(Vg, val, mask, tau)
