"""Pure-jnp oracle for the bmf_precision kernel.

Given gathered factor rows Vg = V[idx] (N, M, K), mask (N, M) and ratings
val (N, M), computes the per-row Gibbs conditional contributions

    Lam[n] = tau * sum_m mask[n,m] * Vg[n,m] Vg[n,m]^T     (N, K, K)
    eta[n] = tau * sum_m mask[n,m] * val[n,m] * Vg[n,m]    (N, K)
"""
from __future__ import annotations

import jax.numpy as jnp


def precision_accum_ref(Vg, val, mask, tau: float):
    Vm = Vg * mask[..., None]
    Lam = tau * jnp.einsum("nmk,nml->nkl", Vm, Vg,
                           preferred_element_type=jnp.float32)
    eta = tau * jnp.einsum("nm,nmk->nk", val * mask, Vg,
                           preferred_element_type=jnp.float32)
    return Lam, eta
