"""Pallas TPU kernel: fused-gather per-row precision/linear-term accumulation
for the BMF Gibbs conditional — the paper's compute hot-spot (O(nnz·K²),
§3.4 "compute intensity is O(K³) per row").

Zero-materialization design (vs the old wrapper that gathered
``Vg = other[idx]`` into a dense (N, M, K) HBM array *before* the kernel):

  - the factor matrix ``other`` (D, K) stays resident in HBM
    (``memory_space=ANY``); nothing of shape (N, M, K) ever exists.
  - the padded-CSR column indices are **scalar-prefetched**
    (``pltpu.PrefetchScalarGridSpec``) so they are available in SMEM before
    the kernel body runs; each grid step DMAs exactly the TN·TM factor rows
    it needs into a VMEM scratch (row-granular ``make_async_copy`` with a
    fixed lookahead window so copies overlap the index reads).
  - the per-row rank-1 accumulation Σ_m v vᵀ then runs as a batched
    (K, TM) × (TM, K) matmul on the MXU exactly as before, with the η
    accumulation fused into the same pass.
  - nnz-aware grid: the second scalar-prefetch operand gives, per TN-row
    tile, the number of M-tiles that contain any live slot
    (``data.sparse.tile_occupancy``).  All-padding M-tiles are skipped —
    no DMA, no matmul — and their input-block index maps clamp to the last
    live tile so the pipeline re-uses the already-resident block instead of
    fetching a dead one.

Grid: (N/TN, M/TM) with M innermost, so the (TN, K, K) output block stays
resident in VMEM and accumulates across M tiles (revisited-output pattern).

VMEM budget per step: TN·TM·K·4 (gather scratch) + TN·TM·4·2 (val/mask) +
TN·K·K·4 + TN·K·4 (outputs) ≈ 8·256·128·4 + 16 KB + 0.5 MB ≈ 1.6 MB for
K=128 — comfortably inside the ~16 MB VMEM.  SMEM holds this call's
(N_stripe, M) int32 index plane; the ops.py wrapper stripes the N axis so
that plane stays under its SMEM_IDX_BUDGET per pallas_call.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TN = 8       # rows per tile
TM = 256     # nnz slots per tile
LANES = 128  # MXU/VPU lane width; K is padded to a multiple of this
DMA_LOOKAHEAD = 16   # outstanding row copies kept in flight


def _fused_kernel(idx_ref, ntiles_ref, val_ref, mask_ref, other_ref,
                  lam_ref, eta_ref, vg_ref, sem, *, tau: float, tm: int):
    n = pl.program_id(0)
    m = pl.program_id(1)

    @pl.when(m == 0)
    def _init():
        lam_ref[...] = jnp.zeros_like(lam_ref)
        eta_ref[...] = jnp.zeros_like(eta_ref)

    @pl.when(m < ntiles_ref[n])
    def _accumulate():
        G = TN * tm

        def row_copy(s):
            # slot s of this tile gathers factor row idx[r, c]
            r = n * TN + s // tm
            c = m * tm + s % tm
            row = idx_ref[r, c]
            return pltpu.make_async_copy(other_ref.at[pl.ds(row, 1)],
                                         vg_ref.at[pl.ds(s, 1)], sem)

        def warmup(s, carry):
            row_copy(s).start()
            return carry

        jax.lax.fori_loop(0, DMA_LOOKAHEAD, warmup, None)

        def pump(s, carry):
            @pl.when(s + DMA_LOOKAHEAD < G)
            def _():
                row_copy(s + DMA_LOOKAHEAD).start()
            row_copy(s).wait()
            return carry

        jax.lax.fori_loop(0, G, pump, None)

        v = vg_ref[...].astype(jnp.float32).reshape(TN, tm, -1)
        w = mask_ref[...].astype(jnp.float32)       # (TN, TM)
        r = val_ref[...].astype(jnp.float32)        # (TN, TM)

        vm = v * w[..., None]
        # batched (K, TM) x (TM, K) matmuls on the MXU
        lam_ref[...] += tau * jax.lax.dot_general(
            vm, v, (((1,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        # fused η accumulation — same pass, same gathered rows
        eta_ref[...] += tau * jnp.einsum(
            "nm,nmk->nk", r * w, v, preferred_element_type=jnp.float32)


def precision_accum_fused_padded(idx, ntiles, val, mask, other, tau: float, *,
                                 tm: int = TM, interpret: bool = False):
    """idx/val/mask: (N, M) with N % TN == 0, M % tm == 0; ntiles: (N/TN,)
    live-M-tile counts; other: (D, K) with K % LANES == 0, resident in HBM.
    Returns (Lam (N, K, K), eta (N, K)) — no (N, M, K) intermediate."""
    N, M = idx.shape
    D, K = other.shape
    assert N % TN == 0 and M % tm == 0, (N, M, tm)
    assert K % LANES == 0, K
    grid = (N // TN, M // tm)

    def live_block(n, m, idx_ref, ntiles_ref):
        # skipped steps re-point at the tile's last live block: the pipeline
        # sees the same block index and elides the copy entirely
        return (n, jnp.minimum(m, jnp.maximum(ntiles_ref[n], 1) - 1))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TN, tm), live_block),     # val
            pl.BlockSpec((TN, tm), live_block),     # mask
            pl.BlockSpec(memory_space=pltpu.ANY),   # other: stays in HBM
        ],
        out_specs=[
            pl.BlockSpec((TN, K, K), lambda n, m, *_: (n, 0, 0)),
            pl.BlockSpec((TN, K), lambda n, m, *_: (n, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((TN * tm, K), other.dtype),  # gathered rows
            pltpu.SemaphoreType.DMA,
        ],
    )
    kernel = functools.partial(_fused_kernel, tau=tau, tm=tm)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((N, K, K), jnp.float32),
            jax.ShapeDtypeStruct((N, K), jnp.float32),
        ],
        interpret=interpret,
    )(idx, ntiles, val, mask, other)
