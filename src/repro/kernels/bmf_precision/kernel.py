"""Pallas TPU kernel: per-row precision/linear-term accumulation for the BMF
Gibbs conditional — the paper's compute hot-spot (O(nnz·K²), §3.4 "compute
intensity is O(K³) per row").

TPU adaptation (vs the paper's CPU/MPI inner loop):
  - K is padded to the 128-lane MXU width by the wrapper (ops.py); the
    per-row rank-1 accumulation Σ_m v v^T becomes a (K, M_tile) × (M_tile, K)
    matmul on the MXU, batched over a tile of TN rows held in VMEM.
  - the grid is (N/TN, M/TM); the M axis is innermost so the (TN, K, K)
    output block stays resident in VMEM and accumulates across M tiles
    (revisited-output accumulation pattern).

VMEM budget per step: TN·TM·K·4 (Vg tile) + TN·K·K·4 (acc) ≈
8·256·128·4 + 8·128·128·4 = 1.6 MB — comfortably inside the ~16 MB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TN = 8      # rows per tile
TM = 256    # nnz slots per tile


def _kernel(v_ref, val_ref, mask_ref, lam_ref, eta_ref, *, tau: float,
            n_m_tiles: int):
    m_idx = pl.program_id(1)

    @pl.when(m_idx == 0)
    def _init():
        lam_ref[...] = jnp.zeros_like(lam_ref)
        eta_ref[...] = jnp.zeros_like(eta_ref)

    v = v_ref[...].astype(jnp.float32)          # (TN, TM, K)
    w = mask_ref[...].astype(jnp.float32)       # (TN, TM)
    r = val_ref[...].astype(jnp.float32)        # (TN, TM)

    vm = v * w[..., None]
    # batched (K, TM) x (TM, K) matmuls on the MXU
    lam_ref[...] += tau * jax.lax.dot_general(
        vm, v, (((1,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)
    eta_ref[...] += tau * jnp.einsum(
        "nm,nmk->nk", r * w, v, preferred_element_type=jnp.float32)


def precision_accum_padded(Vg, val, mask, tau: float, *, interpret=False):
    """Vg: (N, M, K) with N % TN == 0, M % TM == 0, K % 128 == 0."""
    N, M, K = Vg.shape
    assert N % TN == 0 and M % TM == 0, (N, M)
    grid = (N // TN, M // TM)
    kernel = functools.partial(_kernel, tau=tau, n_m_tiles=grid[1])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TN, TM, K), lambda n, m: (n, m, 0)),
            pl.BlockSpec((TN, TM), lambda n, m: (n, m)),
            pl.BlockSpec((TN, TM), lambda n, m: (n, m)),
        ],
        out_specs=[
            pl.BlockSpec((TN, K, K), lambda n, m: (n, 0, 0)),
            pl.BlockSpec((TN, K), lambda n, m: (n, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, K, K), jnp.float32),
            jax.ShapeDtypeStruct((N, K), jnp.float32),
        ],
        interpret=interpret,
    )(Vg, val, mask)
