"""Dispatch layer for the one-kernel Gibbs sweep.

``fused_sweep`` is the factor-step entry point used by
``core.gibbs`` when ``BMFConfig.sweep_fused`` is set: it pads the CSR
planes / priors / noise to tile shapes and routes to

  - the Pallas kernel (kernel.py) on TPU for K ≤ ``SWEEP_K_MAX`` — the
    in-register Cholesky is a column loop, so beyond small K its O(K²)
    masked-lane overhead stops paying for the saved HBM round-trips;
  - the striped-XLA fallback (ref.py) everywhere else — same tile math,
    same padded operands, same M-tile order (bitwise-identical in the
    single-stripe regime; a few ulps once XLA fuses the striped body —
    see ref.py on the parity contract).

Lane padding follows the backend: K pads to the 128-lane MXU width on
TPU, to 8 sublanes on hosts (interpret mode has no lane constraint, and
padding the CPU fallback 16× wide would be pure waste).  Pad lanes carry
an identity diagonal in the prior Λ, so the padded Cholesky is block
diagonal and pad-lane samples are exactly zero — trimming is lossless.

``sample_factor_fused`` is the drop-in for ``bmf.sample_factor``: it
draws the SAME z = normal(key, (N, K)) that ``posterior.sample_rows``
would, so switching ``sweep_fused`` on or off never perturbs the chain's
random stream.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.data.sparse import tile_occupancy
from repro.kernels.bmf_precision.ops import SMEM_IDX_BUDGET, _on_tpu, _pad_to
from repro.kernels.bmf_sweep.kernel import (
    LANES, TM, TN, fused_sweep_padded)
from repro.kernels.bmf_sweep.ref import sweep_ref_padded

SWEEP_DTYPES = ("fp32", "bf16")

# Pallas cutoff: the masked-lane Cholesky/solve epilogue is O(K²) vector
# ops per column on top of the O(K³) MXU work — fine for the paper's
# K ≤ 32 regime, wasteful beyond it (and (TN, K, K) solver temporaries
# start crowding VMEM once K pads to multiple LANES widths)
SWEEP_K_MAX = 32

# host-side lane padding granularity (f32 sublane count); TPU uses LANES
HOST_LANES = 8

# fallback gather-tile budget (elements): the N axis is striped so each
# stripe's (ns, tm, K) gather stays near ~1 MB f32 — big enough to keep
# the batched matmuls fat, small enough that XLA's per-dispatch peak is
# a stripe, not the plane
SWEEP_TILE_ELEMS = 1 << 18


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def fused_sweep(z, idx, val, mask, prior_eta, prior_lam, other, tau: float, *,
                dtype: str = "fp32", jitter: float = 1e-6, tm=None,
                interpret=None, force=None, n_stripe=None,
                tile_elems: int = SWEEP_TILE_ELEMS,
                smem_idx_budget: int = SMEM_IDX_BUDGET):
    """One-pass factor step: returns U (N, K) sampled from the Gibbs
    conditional, given the padded CSR planes (N, M), per-row prior natural
    params (N, K)/(N, K, K), the caller's noise draw z (N, K), and the
    other factor (D, K).

    dtype: 'fp32', or 'bf16' for the mixed-precision mode (bf16 gather +
    Λ accumulate with f32 MXU accumulation; priors, Cholesky, and solves
    stay f32).  force: 'pallas' / 'ref' pins the path, and n_stripe pins
    the N-stripe width, for the parity tests (a stripe covering all of N
    keeps both paths in the single-dispatch regime where agreement is
    bitwise, not just ulp-level — see ref.py)."""
    if dtype not in SWEEP_DTYPES:
        raise ValueError(
            f"sweep dtype must be one of {SWEEP_DTYPES}, got {dtype!r}")
    N, M = idx.shape
    K = other.shape[-1]
    use_pallas = force == "pallas" or (
        force is None and _on_tpu() and K <= SWEEP_K_MAX)
    if interpret is None:
        interpret = not _on_tpu()
    tm_eff = tm or min(TM, _ceil_to(max(M, 1), LANES))
    lanes = LANES if _on_tpu() else HOST_LANES
    Kp = _ceil_to(K, lanes)
    Mp = _ceil_to(M, tm_eff)
    if n_stripe is not None:
        ns = _ceil_to(n_stripe, TN)
    elif use_pallas:
        # the scalar-prefetched index plane lives in SMEM: stripe N under it
        ns = max(TN, (smem_idx_budget // (Mp * 4)) // TN * TN)
    else:
        raw = min(max(N * M // tm_eff, 1),
                  max(tile_elems // (tm_eff * Kp), 1))
        ns = max(TN, raw // TN * TN)
    Np = _ceil_to(N, ns)

    idxp = _pad_to(_pad_to(idx, Mp, 1), Np, 0)      # pad slots gather row 0
    valp = _pad_to(_pad_to(val, Mp, 1), Np, 0)      # ... but are masked out
    maskp = _pad_to(_pad_to(mask, Mp, 1), Np, 0)
    pe = _pad_to(_pad_to(prior_eta.astype(jnp.float32), Kp, 1), Np, 0)
    pL = prior_lam.astype(jnp.float32)
    pL = _pad_to(_pad_to(_pad_to(pL, Kp, 1), Kp, 2), Np, 0)
    if Kp > K:
        # identity on the pad diagonal -> block-diagonal factor; pad-lane
        # η/z are zero, so pad-lane samples are exactly zero
        pad_diag = (jnp.arange(Kp) >= K).astype(jnp.float32)
        pL = pL + jnp.diag(pad_diag)[None]
    zp = _pad_to(_pad_to(z.astype(jnp.float32), Kp, 1), Np, 0)
    otherp = _pad_to(other, Kp, 1)
    if dtype == "bf16":
        otherp = otherp.astype(jnp.bfloat16)

    if not use_pallas:
        U = sweep_ref_padded(idxp, valp, maskp, pe, pL, zp, otherp, tau,
                             tm=tm_eff, jitter=jitter, n_stripe=ns)
        return U[:N, :K]

    def stripe(args):
        ix, vl, mk, pe1, pL1, zz = args
        return fused_sweep_padded(
            ix, tile_occupancy(mk, TN, tm_eff), vl, mk, pe1, pL1, zz,
            otherp, tau, tm=tm_eff, jitter=jitter, interpret=interpret)

    if Np == ns:
        U = stripe((idxp, valp, maskp, pe, pL, zp))
    else:
        nsp = Np // ns
        U = jax.lax.map(stripe, (idxp.reshape(nsp, ns, Mp),
                                 valp.reshape(nsp, ns, Mp),
                                 maskp.reshape(nsp, ns, Mp),
                                 pe.reshape(nsp, ns, Kp),
                                 pL.reshape(nsp, ns, Kp, Kp),
                                 zp.reshape(nsp, ns, Kp)))
        U = U.reshape(Np, Kp)
    return U[:N, :K]


def sample_factor_fused(key, csr, other, tau: float, prior, *,
                        dtype: str = "fp32", jitter: float = 1e-6):
    """Drop-in for ``bmf.sample_factor``: same signature shape, same noise
    stream (z is exactly ``posterior.sample_rows``'s draw), one fused pass
    instead of sufficient-stats → Cholesky → sample round-trips."""
    N = csr.idx.shape[0]
    K = other.shape[-1]
    z = jax.random.normal(key, (N, K), dtype=prior.eta.dtype)
    return fused_sweep(z, csr.idx, csr.val, csr.mask,
                       prior.eta, prior.Lambda, other, tau,
                       dtype=dtype, jitter=jitter)


@partial(jax.jit, static_argnames=("tau", "dtype"))
def _fused_sweep_jit(z, idx, val, mask, prior_eta, prior_lam, other,
                     tau: float, dtype: str):
    return fused_sweep(z, idx, val, mask, prior_eta, prior_lam, other, tau,
                       dtype=dtype)


def trace_sweep(K: int, n_rows: int, m_rows: int, n_other: int, *,
                dtype: str = "fp32"):
    """Lowering hook for the static analyzer (launch.bmf_lint), shaped like
    ``gibbs.trace_chain``: trace the jitted fused factor step at abstract
    shapes so the materialization-budget and dtype-promotion passes run
    over the EXACT op-level jaxpr (both precision modes)."""
    from repro.core.gibbs import TracedChain, _flat_param_labels
    S = jax.ShapeDtypeStruct
    f32, i32 = jnp.float32, jnp.int32
    named = [("z", S((n_rows, K), f32)),
             ("csr_idx", S((n_rows, m_rows), i32)),
             ("csr_val", S((n_rows, m_rows), f32)),
             ("csr_mask", S((n_rows, m_rows), f32)),
             ("prior_eta", S((n_rows, K), f32)),
             ("prior_Lambda", S((n_rows, K, K), f32)),
             ("other", S((n_other, K), f32))]
    traced = _fused_sweep_jit.trace(*(t for _, t in named),
                                    tau=2.0, dtype=dtype)
    return TracedChain(traced=traced, param_labels=_flat_param_labels(named),
                       donated_labels=(), must_alias=())
