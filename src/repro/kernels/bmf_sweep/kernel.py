"""Pallas TPU kernel: ONE-pass fused Gibbs sweep for a BMF factor step.

kernels/bmf_precision fused the gather + Λ/η accumulation but still returned
the (N, K, K)/(N, K) sufficient stats to HBM, where XLA ran the Cholesky
solve and the noise draw as separate kernels — three HBM round-trips per
factor step.  This kernel chains the whole per-row conditional

    gather v_d rows → Λ/η accumulate → small-K Cholesky → two triangular
    solves + noise add   (u = Λ⁻¹η + L⁻ᵀ z, the ``sample_rows_noise`` split)

inside one pallas_call: the (TN, K, K) precision block lives ONLY in VMEM
scratch, and the single HBM-resident output is the sampled factor block
(TN, K).  The grid, scalar-prefetched CSR planes, DMA row pump, and
nnz-aware tile skip are bmf_precision's exactly (imported constants);
what is new is the ``m == last`` epilogue that factors and samples in
registers instead of writing Λ/η out.

Small-K linear algebra without dynamic lane indexing: TPU vector layouts
forbid addressing individual lanes, so the Cholesky and the triangular
solves are written as fori_loops over columns where every "element access"
is a masked broadcasted-iota reduction and every "element write" is a
masked add into a zero lane.  That costs O(K) vector ops per column —
O(K²) total per row on top of the O(K³) multiply work — which is cheap
for the K ≤ 32 regime this kernel targets (ops.py falls back above it).

Noise contract: the caller supplies z = normal(key, (N, K)) — the SAME
draw ``posterior.sample_rows`` makes — so the chain's random stream is
bitwise-preserved no matter which path (kernel / fallback / legacy
unfused) executes the sweep.

Mixed precision: the gather scratch and the Λ accumulate run in the
factor's dtype (bf16 in mixed mode) with f32 MXU accumulation
(``preferred_element_type``); the Λ/η scratches, priors, Cholesky, and
solves are f32 ALWAYS — bf16 never reaches the factorization (the
bmf_lint dtype pass proves this over the lowered jaxpr).

Bitwise parity with the off-TPU fallback is BY CONSTRUCTION: ref.py runs
``accum_tile``/``sample_tile`` — the same helpers below — over the same
padded planes in the same M-tile order, so interpret-mode Pallas and the
striped-XLA fallback agree bit-for-bit (tests/test_sweep_kernel.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.bmf_precision.kernel import DMA_LOOKAHEAD, LANES, TM, TN

__all__ = ["accum_tile", "sample_tile", "chol_tile", "solve_lower_tile",
           "solve_upper_tile", "fused_sweep_padded",
           "TN", "TM", "LANES", "DMA_LOOKAHEAD"]


# ---------------------------------------------------------------------------
# Shared tile math — called by BOTH the Pallas kernel body and the striped
# XLA fallback (ref.py).  Everything here is per-row batched (leading axis B)
# with no cross-row reductions, so results are independent of how rows are
# batched into tiles — the property the bitwise parity tests rely on.
# ---------------------------------------------------------------------------


def accum_tile(lam, eta, v, w, r, tau):
    """Fold one M-tile of gathered factor rows into the (Λ, η) accumulators.

    lam (B, K, K) f32, eta (B, K) f32; v (B, tm, K) in the gather dtype
    (f32 or bf16); w/r (B, tm) f32 mask/value planes.  The Λ matmul runs on
    the gather dtype with f32 accumulation — the mixed-precision contract."""
    vm = v * w.astype(v.dtype)[..., None]
    lam = lam + tau * jax.lax.dot_general(
        vm, v, (((1,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)
    eta = eta + tau * jnp.einsum(
        "nm,nmk->nk", r * w, v, preferred_element_type=jnp.float32)
    return lam, eta


def _kk_iota(K, dtype=jnp.float32):
    rows = jax.lax.broadcasted_iota(jnp.int32, (K, K), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (K, K), 1)
    return rows, cols


def chol_tile(A):
    """Batched left-looking Cholesky of (B, K, K) SPD tiles.

    Column j of L needs only columns < j — which are the only nonzeros of
    the running factor — so the cross-term Σ_{p<j} L[i,p]·L[j,p] is the
    FULL-K contraction against row j (zeros beyond p<j contribute exactly
    nothing).  Element reads/writes are masked-iota reductions/adds: no
    dynamic lane indexing anywhere."""
    B, K, _ = A.shape
    rows, cols = _kk_iota(K)
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, K), 1)

    def col(j, L):
        colsel = (cols == j).astype(A.dtype)            # one-hot column j
        rowsel = (rows == j).astype(A.dtype)            # one-hot row j
        a_col = jnp.sum(A * colsel[None], axis=2)       # (B, K) = A[:, :, j]
        l_row = jnp.sum(L * rowsel[None], axis=1)       # (B, K) = L[:, j, :]
        # s_i = Σ_p L[i, p] · L[j, p]; at i = j this is Σ L[j, p]²
        s = jax.lax.dot_general(L, l_row,
                                (((2,), (1,)), ((0,), (0,))))
        a_jj = jnp.sum(a_col * (lane == j).astype(A.dtype), axis=1)
        sq = jnp.sum(l_row * l_row, axis=1)
        ljj = jnp.sqrt(a_jj - sq)                       # (B,)
        below = (lane > j).astype(A.dtype)              # strictly-lower mask
        at_j = (lane == j).astype(A.dtype)
        newcol = (a_col - s) / ljj[:, None] * below + ljj[:, None] * at_j
        return L + newcol[:, :, None] * colsel[None]    # write column j

    return jax.lax.fori_loop(0, K, col, jnp.zeros_like(A))


def solve_lower_tile(L, b):
    """Forward substitution y = L⁻¹ b for (B, K, K) lower tiles."""
    B, K = b.shape
    rows, _ = _kk_iota(K)
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, K), 1)

    def step(j, y):
        rowsel = (rows == j).astype(L.dtype)
        l_row = jnp.sum(L * rowsel[None], axis=1)       # (B, K) = L[:, j, :]
        s = jnp.sum(l_row * y, axis=1)                  # y zeroed for p ≥ j
        at_j = (lane == j).astype(L.dtype)
        bj = jnp.sum(b * at_j, axis=1)
        ljj = jnp.sum(l_row * at_j, axis=1)
        return y + ((bj - s) / ljj)[:, None] * at_j

    return jax.lax.fori_loop(0, K, step, jnp.zeros_like(b))


def solve_upper_tile(L, b):
    """Backward substitution x = L⁻ᵀ b (solve against the TRANSPOSE of the
    lower factor — the covariance half of the ``sample_rows_noise`` split)."""
    B, K = b.shape
    _, cols = _kk_iota(K)
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, K), 1)

    def step(t, x):
        j = K - 1 - t
        colsel = (cols == j).astype(L.dtype)
        l_col = jnp.sum(L * colsel[None], axis=2)       # (B, K) = L[:, :, j]
        s = jnp.sum(l_col * x, axis=1)                  # x zeroed for p ≤ j
        at_j = (lane == j).astype(L.dtype)
        bj = jnp.sum(b * at_j, axis=1)
        ljj = jnp.sum(l_col * at_j, axis=1)
        return x + ((bj - s) / ljj)[:, None] * at_j

    return jax.lax.fori_loop(0, K, step, jnp.zeros_like(b))


def sample_tile(lam, eta, prior_lam, prior_eta, z, jitter):
    """Finish one row tile: add the prior, factor, and draw the sample.

    Mirrors ``posterior.sample_rows_noise`` exactly — Λ += jitter·I,
    μ = Λ⁻¹η via forward+backward solve, δ = L⁻ᵀ z — with the in-register
    solvers above.  All f32: bf16 stops at the accumulate."""
    K = eta.shape[-1]
    rows, cols = _kk_iota(K)
    eye = (rows == cols).astype(jnp.float32)
    A = lam + prior_lam + jitter * eye[None]
    b = eta + prior_eta
    L = chol_tile(A)
    mu = solve_upper_tile(L, solve_lower_tile(L, b))
    delta = solve_upper_tile(L, z)
    return mu + delta


# ---------------------------------------------------------------------------
# The Pallas kernel
# ---------------------------------------------------------------------------


def _sweep_kernel(idx_ref, ntiles_ref, val_ref, mask_ref, peta_ref, plam_ref,
                  z_ref, other_ref, u_ref, lam_ref, eta_ref, vg_ref, sem, *,
                  tau: float, tm: int, jitter: float):
    n = pl.program_id(0)
    m = pl.program_id(1)

    @pl.when(m == 0)
    def _init():
        lam_ref[...] = jnp.zeros_like(lam_ref)
        eta_ref[...] = jnp.zeros_like(eta_ref)
        u_ref[...] = jnp.zeros_like(u_ref)

    @pl.when(m < ntiles_ref[n])
    def _accumulate():
        G = TN * tm

        def row_copy(s):
            # slot s of this tile gathers factor row idx[r, c]
            r = n * TN + s // tm
            c = m * tm + s % tm
            row = idx_ref[r, c]
            return pltpu.make_async_copy(other_ref.at[pl.ds(row, 1)],
                                         vg_ref.at[pl.ds(s, 1)], sem)

        def warmup(s, carry):
            row_copy(s).start()
            return carry

        jax.lax.fori_loop(0, DMA_LOOKAHEAD, warmup, None)

        def pump(s, carry):
            @pl.when(s + DMA_LOOKAHEAD < G)
            def _():
                row_copy(s + DMA_LOOKAHEAD).start()
            row_copy(s).wait()
            return carry

        jax.lax.fori_loop(0, G, pump, None)

        v = vg_ref[...].reshape(TN, tm, -1)             # gather dtype
        lam, eta = accum_tile(lam_ref[...], eta_ref[...], v,
                              mask_ref[...], val_ref[...], tau)
        lam_ref[...] = lam
        eta_ref[...] = eta

    @pl.when(m == pl.num_programs(1) - 1)
    def _solve_and_sample():
        # epilogue: Λ/η never leave VMEM — prior add, in-register Cholesky,
        # triangular solves, and the noise add all happen here, and the only
        # HBM write of the whole factor step is this (TN, K) sample block
        u_ref[...] = sample_tile(lam_ref[...], eta_ref[...], plam_ref[...],
                                 peta_ref[...], z_ref[...], jitter)


def fused_sweep_padded(idx, ntiles, val, mask, prior_eta, prior_lam, z,
                       other, tau: float, *, tm: int = TM,
                       jitter: float = 1e-6, interpret: bool = False):
    """idx/val/mask: (N, M) with N % TN == 0, M % tm == 0; ntiles: (N/TN,)
    live-M-tile counts; prior_eta/z: (N, K), prior_lam: (N, K, K) f32 with
    pad lanes carrying an identity diagonal; other: (D, K), HBM-resident.
    Returns the sampled factor U (N, K) — no (N, K, K) HBM intermediate."""
    N, M = idx.shape
    D, K = other.shape
    assert N % TN == 0 and M % tm == 0, (N, M, tm)
    grid = (N // TN, M // tm)

    def live_block(n, m, idx_ref, ntiles_ref):
        # skipped steps re-point at the tile's last live block: the pipeline
        # sees the same block index and elides the copy entirely
        return (n, jnp.minimum(m, jnp.maximum(ntiles_ref[n], 1) - 1))

    def row_block(n, m, *_):
        return (n, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TN, tm), live_block),             # val
            pl.BlockSpec((TN, tm), live_block),             # mask
            pl.BlockSpec((TN, K), row_block),               # prior eta
            pl.BlockSpec((TN, K, K), lambda n, m, *_: (n, 0, 0)),
            pl.BlockSpec((TN, K), row_block),               # noise z
            pl.BlockSpec(memory_space=pltpu.ANY),           # other: HBM
        ],
        out_specs=pl.BlockSpec((TN, K), row_block),
        scratch_shapes=[
            pltpu.VMEM((TN, K, K), jnp.float32),            # Λ accumulator
            pltpu.VMEM((TN, K), jnp.float32),               # η accumulator
            pltpu.VMEM((TN * tm, K), other.dtype),          # gathered rows
            pltpu.SemaphoreType.DMA,
        ],
    )
    kernel = functools.partial(_sweep_kernel, tau=tau, tm=tm, jitter=jitter)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N, K), jnp.float32),
        interpret=interpret,
    )(idx, ntiles, val, mask, prior_eta, prior_lam, z, other)
