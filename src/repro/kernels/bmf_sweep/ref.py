"""Striped-XLA fallback for the fused Gibbs sweep — the off-TPU PRODUCTION
path, not just a test oracle.

It consumes the SAME padded planes as the Pallas kernel and runs the SAME
tile math (``kernel.accum_tile`` / ``kernel.sample_tile``) in the SAME
M-tile order, so parity with interpret-mode Pallas is by construction:
in the single-stripe regime (one eager dispatch per helper on both sides)
the two paths agree bit-for-bit, and the parity suite asserts exact
equality there.  Once the N axis stripes under ``lax.map``, XLA compiles
the stripe body as one fused computation and CPU fast-math contraction
(FMA / add reassociation across fusion boundaries) can shift results by
a few ulps relative to the op-by-op interpreter — same math, tighter
rounding, asserted at 1e-5.  (Dead M-tiles the kernel's occupancy counts
skip are processed here — their masked contribution is exactly zero,
which the parity suite pins down.)

Zero-materialization shape discipline matches bmf_precision's fallback:
the N axis is striped under ``lax.map`` (one program regardless of N) and
each stripe gathers one (ns, tm, K) tile at a time, so peak live memory is
O(stripe) — no (N, M, K) tensor and, unlike the legacy sufficient-stats
path, no (N, K, K) precision round-trip either: Λ exists only as the
per-stripe accumulator inside the map body.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.bmf_sweep.kernel import accum_tile, sample_tile


def sweep_ref_padded(idx, val, mask, prior_eta, prior_lam, z, other,
                     tau: float, *, tm: int, jitter: float = 1e-6,
                     n_stripe: int):
    """Same contract as ``kernel.fused_sweep_padded`` (minus the occupancy
    counts — all tiles are processed; dead ones add exact zeros).  N must
    be a multiple of ``n_stripe``; M a multiple of ``tm``."""
    N, M = idx.shape
    K = other.shape[-1]
    assert N % n_stripe == 0 and M % tm == 0, (N, M, n_stripe, tm)

    def stripe(args):
        ix, vl, mk, pe, pL, zz = args
        lam = jnp.zeros((n_stripe, K, K), jnp.float32)
        eta = jnp.zeros((n_stripe, K), jnp.float32)
        # static unrolled M-tile loop, SAME order as the kernel grid's
        # innermost axis — the rounding-order half of the parity contract
        for lo in range(0, M, tm):
            v = other[ix[:, lo:lo + tm]]                # (ns, tm, K) gather
            lam, eta = accum_tile(lam, eta, v, mk[:, lo:lo + tm],
                                  vl[:, lo:lo + tm], tau)
        # (no optimization_barrier between the phases even though the
        # kernel has a hard VMEM-scratch boundary there: the stacked
        # executors vmap this whole chain and the barrier primitive has
        # no batching rule — the ulp-level fusion drift it would prevent
        # is already inside the parity contract above)
        return sample_tile(lam, eta, pL, pe, zz, jitter)

    if N == n_stripe:
        return stripe((idx, val, mask, prior_eta, prior_lam, z))
    nsp = N // n_stripe
    U = jax.lax.map(stripe, (idx.reshape(nsp, n_stripe, M),
                             val.reshape(nsp, n_stripe, M),
                             mask.reshape(nsp, n_stripe, M),
                             prior_eta.reshape(nsp, n_stripe, K),
                             prior_lam.reshape(nsp, n_stripe, K, K),
                             z.reshape(nsp, n_stripe, K)))
    return U.reshape(N, K)
