"""Pallas TPU kernel: one Mamba2 SSD chunk per (batch, head) grid cell.

Why a kernel: the chunk-parallel identity materializes pairwise (C×C)
decay/score tensors per head. In the jnp path those roundtrip HBM —
for zamba2 prefill_32k that is ~300 GB of traffic per step (the dominant
roofline term, see EXPERIMENTS §Perf H3). Here they live in VMEM: HBM sees
only the (C,P)/(C,N) streams and the (P,N) state.

Math (scalar per-head decay a_t = log-decay < 0, L = cumsum(a)):
    y_inter = exp(L_t) · (C_t · state)
    y_intra = Σ_{j<=t} (C_t·B_j) exp(L_t - L_j) xdt_j
    state'  = exp(L_C) state + Σ_j exp(L_C - L_j) xdt_j ⊗ B_j

VMEM per cell: C·P + 2·C·N + 2·C·C + P·N floats ≈ 0.2 MB (C=128, N=P=64).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, a_ref, b_ref, c_ref, s_ref, y_ref, sout_ref):
    x = x_ref[0, :, 0].astype(jnp.float32)        # (C, P)  xdt
    a = a_ref[0, :, 0].astype(jnp.float32)        # (C,)
    B_ = b_ref[0].astype(jnp.float32)             # (C, N)
    C_ = c_ref[0].astype(jnp.float32)             # (C, N)
    S = s_ref[0, 0].astype(jnp.float32)           # (P, N)

    Cn = x.shape[0]
    L = jnp.cumsum(a)                             # (C,)
    # inter-chunk: y_t += exp(L_t) * state @ C_t   -> (C, P)
    y_inter = jnp.exp(L)[:, None] * jax.lax.dot_general(
        C_, S, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    # intra-chunk
    G = jax.lax.dot_general(C_, B_, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (C, C)
    ii = jax.lax.broadcasted_iota(jnp.int32, (Cn, Cn), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Cn, Cn), 1)
    mask = jj <= ii
    D = L[:, None] - L[None, :]
    Dexp = jnp.exp(jnp.where(mask, D, 0.0)) * mask  # stays in VMEM
    A = G * Dexp
    y_intra = jax.lax.dot_general(A, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_ref[0, :, 0] = y_inter + y_intra

    LC = L[-1]
    w_tail = jnp.exp(LC - L)                      # (C,)
    xw = x * w_tail[:, None]                      # (C, P)
    S_new = jnp.exp(LC) * S + jax.lax.dot_general(
        xw, B_, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    sout_ref[0, 0] = S_new


def ssd_chunk_padded(xdt, a, B_, C_, state0, *, interpret=False):
    """xdt: (Bb, C, H, P); a: (Bb, C, H); B_/C_: (Bb, C, N);
    state0: (Bb, H, P, N). Returns (y (Bb,C,H,P) f32, state)."""
    Bb, C, H, P = xdt.shape
    N = B_.shape[-1]
    return pl.pallas_call(
        _kernel,
        grid=(Bb, H),
        in_specs=[
            pl.BlockSpec((1, C, 1, P), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, C, 1), lambda b, h: (b, 0, h)),
            pl.BlockSpec((1, C, N), lambda b, h: (b, 0, 0)),
            pl.BlockSpec((1, C, N), lambda b, h: (b, 0, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, C, 1, P), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, C, H, P), jnp.float32),
            jax.ShapeDtypeStruct((Bb, H, P, N), jnp.float32),
        ],
        interpret=interpret,
    )(xdt, a, B_, C_, state0)
