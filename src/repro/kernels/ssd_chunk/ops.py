"""Jit'd wrapper: full-sequence SSD via lax.scan over Pallas chunk calls."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd_chunk.kernel import ssd_chunk_padded
from repro.kernels.ssd_chunk.ref import ssd_chunk_ref_batched

CHUNK = 128


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def ssd_scan(xdt, a, B_, C_, state0):
    """xdt: (Bb, S, H, P) (dt already folded); a: (Bb, S, H) log decay;
    B_/C_: (Bb, S, N); state0: (Bb, H, P, N). S % CHUNK == 0.
    Returns y (Bb,S,H,P) f32, state."""
    Bb, S, H, P = xdt.shape
    N = B_.shape[-1]
    nc = S // CHUNK
    interp = not _on_tpu()

    def body(state, xs):
        xc, ac, bc, cc = xs
        y, state = ssd_chunk_padded(xc, ac, bc, cc, state, interpret=interp)
        return state, y

    xs = (jnp.moveaxis(xdt.reshape(Bb, nc, CHUNK, H, P), 1, 0),
          jnp.moveaxis(a.reshape(Bb, nc, CHUNK, H), 1, 0),
          jnp.moveaxis(B_.reshape(Bb, nc, CHUNK, N), 1, 0),
          jnp.moveaxis(C_.reshape(Bb, nc, CHUNK, N), 1, 0))
    state, ys = jax.lax.scan(body, state0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bb, S, H, P)
    return y, state


def ssd_scan_reference(xdt, a, B_, C_, state0):
    return ssd_chunk_ref_batched(xdt, a, B_, C_, state0)
