"""Pure-jnp oracle for the Mamba2 SSD chunk kernel: sequential recurrence.

    h_t = exp(a_t) h_{t-1} + xdt_t ⊗ B_t ;   y_t = h_t C_t
(xdt = dt·x already folded in by the caller; D-residual applied outside.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_chunk_ref(xdt, a, B_, C_, state0):
    """xdt: (C, P); a: (C,) log decay; B_/C_: (C, N); state0: (P, N).
    Returns y (C, P), state (P, N)."""
    def step(h, inp):
        x_t, a_t, b_t, c_t = inp
        h = jnp.exp(a_t) * h + jnp.outer(x_t, b_t)
        y = h @ c_t
        return h, y

    h, ys = jax.lax.scan(step, state0, (xdt, a, B_, C_))
    return ys, h


def ssd_chunk_ref_batched(xdt, a, B_, C_, state0):
    """xdt: (Bb, C, H, P); a: (Bb, C, H); B_/C_: (Bb, C, N);
    state0: (Bb, H, P, N)."""
    # inner vmap over heads: per-batch shapes xdt (C,H,P), a (C,H),
    # B_/C_ (C,N) shared, state (H,P,N)
    f = jax.vmap(jax.vmap(ssd_chunk_ref, in_axes=(1, 1, None, None, 0),
                          out_axes=(1, 0)),
                 in_axes=(0, 0, 0, 0, 0), out_axes=(0, 0))
    return f(xdt, a, B_, C_, state0)
