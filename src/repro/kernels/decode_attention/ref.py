"""Pure-jnp oracle for the single-token GQA decode-attention kernel."""
from __future__ import annotations

import jax.numpy as jnp


def decode_attention_ref(q, k, v, kv_pos, q_pos, window: int = 0):
    """q: (B, H, hd); k/v: (B, S, Hkv, hd); kv_pos: (S,) absolute positions
    (-1 = empty slot); q_pos: scalar int. Causal + optional sliding window.
    Returns (B, H, hd) in f32."""
    B, H, hd = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    group = H // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, group, hd)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    s = jnp.einsum("bhgd,bshd->bhgs", qf * scale, k.astype(jnp.float32))
    valid = (kv_pos >= 0) & (kv_pos <= q_pos)
    if window > 0:
        valid = valid & (kv_pos > q_pos - window)
    s = jnp.where(valid[None, None, None, :], s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(valid[None, None, None, :], p, 0.0)
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))
    return out.reshape(B, H, hd)
