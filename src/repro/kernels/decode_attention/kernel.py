"""Pallas TPU kernel: single-token GQA decode attention over a (ring) KV
cache — the serving hot-spot for decode_32k / long_500k.

Design (flash-decode, TPU-adapted):
  - grid = (B, Hkv, S/TS); the S axis is the innermost (sequential) grid
    dim, so the f32 online-softmax state (m, l, acc) lives in VMEM scratch
    and persists across S tiles; out is written on the last tile.
  - each step loads a (TS, hd) K tile and V tile plus the (group, hd) query
    slice for this KV head; scores are a (group, TS) matmul — group = H/Hkv
    query heads share this KV head (GQA).
  - masking uses per-slot absolute positions (ring caches are not
    contiguous in time), so causal+sliding-window masks stay exact after
    wrap-around.

VMEM per step: TS·hd·2·2 (K,V bf16) + group·hd·4 + group·TS·4 ≈
512·128·4 + small ≈ 0.3 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TS = 512   # kv slots per tile


def _kernel(q_ref, k_ref, v_ref, pos_ref, qpos_ref, o_ref,
            m_scr, l_scr, acc_scr, *, window: int, n_s: int, scale: float):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale       # (group, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)            # (TS, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)            # (TS, hd)
    kv_pos = pos_ref[...]                              # (TS,)
    q_pos = qpos_ref[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (group, TS)
    valid = (kv_pos >= 0) & (kv_pos <= q_pos)
    if window > 0:
        valid = valid & (kv_pos > q_pos - window)
    s = jnp.where(valid[None, :], s, -jnp.inf)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
    p = jnp.exp(s - m_safe[:, None])
    p = jnp.where(valid[None, :], p, 0.0)
    corr = jnp.where(jnp.isinf(m_prev), 0.0, jnp.exp(m_prev - m_safe))
    l_scr[...] = l_scr[...] * corr + p.sum(-1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(s_idx == n_s - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)[:, None])


def decode_attention_padded(q, k, v, kv_pos, q_pos, *, window: int = 0,
                            interpret: bool = False):
    """q: (B, Hkv, group, hd); k/v: (B, S, Hkv, hd); kv_pos: (S,) int32;
    q_pos: (1,) int32. S % TS == 0. Returns (B, Hkv, group, hd) f32."""
    B, Hkv, group, hd = q.shape
    S = k.shape[1]
    assert S % TS == 0, S
    n_s = S // TS
    scale = 1.0 / (hd ** 0.5)
    kernel = functools.partial(_kernel, window=window, n_s=n_s, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(B, Hkv, n_s),
        in_specs=[
            pl.BlockSpec((1, 1, group, hd), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, TS, 1, hd), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, TS, 1, hd), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((TS,), lambda b, h, s: (s,)),
            pl.BlockSpec((1,), lambda b, h, s: (0,)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, hd), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, group, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((group,), jnp.float32),       # m (running max)
            pltpu.VMEM((group,), jnp.float32),       # l (denominator)
            pltpu.VMEM((group, hd), jnp.float32),    # acc (numerator)
        ],
        interpret=interpret,
    )(q, k, v, kv_pos, q_pos)
