"""Jit'd wrapper for the decode-attention kernel: pads S to tile multiples,
reshapes GQA heads, dispatches (interpret off-TPU), restores shapes."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import TS, decode_attention_padded
from repro.kernels.decode_attention.ref import decode_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("window",))
def decode_attention(q, k, v, kv_pos, q_pos, window: int = 0):
    """q: (B, H, hd); k/v: (B, S, Hkv, hd); kv_pos: (S,) int32 absolute
    positions (-1 empty); q_pos: scalar int32. Returns (B, H, hd) f32."""
    B, H, hd = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    group = H // Hkv
    qr = q.reshape(B, Hkv, group, hd)

    Sp = ((S + TS - 1) // TS) * TS
    if Sp != S:
        pad = Sp - S
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, (0, pad), constant_values=-1)

    out = decode_attention_padded(
        qr, k, v, kv_pos.astype(jnp.int32),
        jnp.asarray(q_pos, jnp.int32).reshape(1), window=window,
        interpret=not _on_tpu())
    return out.reshape(B, H, hd)


def decode_attention_reference(q, k, v, kv_pos, q_pos, window: int = 0):
    return decode_attention_ref(q, k, v, kv_pos, q_pos, window)
