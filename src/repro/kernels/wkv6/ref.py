"""Pure-jnp oracle for the wkv6 chunk kernel: the sequential recurrence.

    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t^T S_{t-1} + (r_t ⊙ u ⊙ k_t)·v_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv_chunk_ref(r, k, v, logw, u, state0):
    """r,k,v,logw: (C, N) one head, one chunk; u: (N,); state0: (N, N).
    Returns y (C, N), state (N, N). Sequential scan — ground truth."""
    def step(S, inp):
        rt, kt, vt, wt = inp
        y = rt @ S + (rt * u * kt).sum() * vt
        S = jnp.exp(wt)[:, None] * S + jnp.outer(kt, vt)
        return S, y

    S, ys = jax.lax.scan(step, state0, (r, k, v, logw))
    return ys, S


def wkv_chunk_ref_batched(r, k, v, logw, u, state0):
    """r,k,v,logw: (B, C, H, N); u: (H, N); state0: (B, H, N, N)."""
    f = jax.vmap(jax.vmap(wkv_chunk_ref, in_axes=(1, 1, 1, 1, 0, 0),
                          out_axes=(1, 0)),
                 in_axes=(0, 0, 0, 0, None, 0), out_axes=(0, 0))
    return f(r, k, v, logw, u, state0)
