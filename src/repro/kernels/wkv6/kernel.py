"""Pallas TPU kernel: one WKV6 chunk (intra-chunk parallel form).

Implements the chunked linear-attention identity used by
repro.models.rwkv6.wkv_chunked, per (batch, head) grid cell:

    L_t   = Σ_{s<=t} log w_s                 (cumsum over the chunk)
    y_t   = (r_t e^{L_{t-1}}) · S_in
          + Σ_{j<t} [(r_t e^{L_{t-1}-c}) · (k_j e^{c-L_j})] v_j
          + (r_t ⊙ u ⊙ k_t)·v_t
    S_out = diag(e^{L_C}) S_in + Σ_j diag(e^{L_C - L_j}) k_j v_j^T

with the mid-chunk stabilizer c = L_C/2 (both factorized exponents stay
≤ |L_C|/2). All operands for one (b, h) cell — (C, N) tiles with C = 128,
N = 64 — fit in VMEM; the matmuls (C×N · N×C and C×C · C×N) run on the MXU.
The cross-chunk sequential dependency stays a lax.scan at the JAX level
(ops.py), carrying the (N, N) state.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s_ref, y_ref, sout_ref):
    r = r_ref[0, :, 0].astype(jnp.float32)       # (C, N)
    k = k_ref[0, :, 0].astype(jnp.float32)
    v = v_ref[0, :, 0].astype(jnp.float32)
    w = w_ref[0, :, 0].astype(jnp.float32)       # log-decay, < 0
    u = u_ref[0].astype(jnp.float32)             # (N,)
    S = s_ref[0, 0].astype(jnp.float32)          # (N, N)

    C = r.shape[0]
    L = jnp.cumsum(w, axis=0)                    # (C, N)
    Lm1 = L - w
    c = L[-1] * 0.5

    r_dec = r * jnp.exp(Lm1)                     # inter-chunk factor
    y_inter = jax.lax.dot_general(r_dec, S, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    r_dec2 = r * jnp.exp(Lm1 - c)
    k_dec = k * jnp.exp(c - L)
    A = jax.lax.dot_general(r_dec2, k_dec, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (C, C)
    ii = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    A = jnp.where(jj < ii, A, 0.0)               # strict lower triangle
    y_intra = jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    bonus = jnp.sum(r * u[None, :] * k, axis=1, keepdims=True) * v
    y_ref[0, :, 0] = y_inter + y_intra + bonus

    LC = L[-1]
    k_tail = k * jnp.exp(LC[None, :] - L)
    S_new = jnp.exp(LC)[:, None] * S + jax.lax.dot_general(
        k_tail, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    sout_ref[0, 0] = S_new


def wkv_chunk_padded(r, k, v, logw, u, state0, *, interpret=False):
    """One chunk for all (B, H): r,k,v,logw (B, C, H, N); u (H, N);
    state0 (B, H, N, N). Returns y (B, C, H, N) f32, state (B, H, N, N)."""
    B, C, H, N = r.shape
    return pl.pallas_call(
        _kernel,
        grid=(B, H),
        in_specs=[
            pl.BlockSpec((1, C, 1, N), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, C, 1, N), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, C, 1, N), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, C, 1, N), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, N), lambda b, h: (h, 0)),
            pl.BlockSpec((1, 1, N, N), lambda b, h: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, C, 1, N), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, 1, N, N), lambda b, h: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, C, H, N), jnp.float32),
            jax.ShapeDtypeStruct((B, H, N, N), jnp.float32),
        ],
        interpret=interpret,
    )(r, k, v, logw, u, state0)
