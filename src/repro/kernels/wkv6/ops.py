"""Jit'd wrapper: full-sequence WKV6 via lax.scan over Pallas chunk calls."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.wkv6.kernel import wkv_chunk_padded
from repro.kernels.wkv6.ref import wkv_chunk_ref_batched

CHUNK = 128


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@jax.jit
def wkv6(r, k, v, logw, u, state0):
    """r,k,v,logw: (B, S, H, N) with S % CHUNK == 0; u: (H, N);
    state0: (B, H, N, N). Returns (y (B,S,H,N) f32, state)."""
    B, S, H, N = r.shape
    nc = S // CHUNK
    interp = not _on_tpu()

    def body(state, xs):
        rc, kc, vc, wc = xs
        y, state = wkv_chunk_padded(rc, kc, vc, wc, u, state,
                                    interpret=interp)
        return state, y

    rs = jnp.moveaxis(r.reshape(B, nc, CHUNK, H, N), 1, 0)
    ks = jnp.moveaxis(k.reshape(B, nc, CHUNK, H, N), 1, 0)
    vs = jnp.moveaxis(v.reshape(B, nc, CHUNK, H, N), 1, 0)
    ws = jnp.moveaxis(logw.reshape(B, nc, CHUNK, H, N), 1, 0)
    state, ys = jax.lax.scan(body, state0, (rs, ks, vs, ws))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, N)
    return y, state


def wkv6_reference(r, k, v, logw, u, state0):
    return wkv_chunk_ref_batched(r, k, v, logw, u, state0)
