"""Pallas TPU kernel: q-tiled flash attention (prefill / training forward).

Why (EXPERIMENTS §Perf H3b/H7): the pure-jnp chunked attention streams its
(B, Sq, Hkv, group, TK) score tensors through HBM — at prefill_32k that is
the dominant memory-roofline term for every attention arch (e.g. ~17 GB per
shared-attn call for zamba2). Here each (TQ, TK) score tile lives in VMEM
between the two MXU matmuls; HBM sees only the q/k/v/o streams.

Grid = (B, H, Sq/TQ, Skv/TK); the KV axis is innermost/sequential so the
online-softmax state (m, l, acc) persists in VMEM scratch across KV tiles;
the output tile is finalized on the last KV step. GQA via index_map: the
q-head h reads KV head h // group. Causal/SWA masks are computed from
absolute tile offsets; fully-masked tiles short-circuit via pl.when.

VMEM per step: TQ·hd (q) + 2·TK·hd (k,v) + TQ·TK (scores) + TQ·hd (acc)
≈ (256+512)·128·4 + 256·512·4 ≈ 0.9 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TQ = 256
TK = 512


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            causal: bool, window: int, n_k: int, scale: float,
            lse_ref=None):
    kt = pl.program_id(3)
    qt = pl.program_id(2)

    @pl.when(kt == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_lo = qt * TQ
    k_lo = kt * TK
    # tile-level skip: causal => no kv beyond the last q of this tile;
    # window => no kv before the first q's window start
    live = jnp.bool_(True)
    if causal:
        live = jnp.logical_and(live, k_lo <= q_lo + TQ - 1)
    if window > 0:
        live = jnp.logical_and(live, k_lo + TK - 1 > q_lo - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, :, 0].astype(jnp.float32) * scale    # (TQ, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)            # (TK, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (TQ,TK)
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (TQ, TK), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (TQ, TK), 1)
        mask = jnp.ones((TQ, TK), bool)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, -jnp.inf)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.where(jnp.isinf(m_prev), 0.0, jnp.exp(m_prev - m_safe))
        l_scr[...] = l_scr[...] * corr + p.sum(-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(kt == n_k - 1)
    def _finalize():
        o_ref[0, :, 0] = (acc_scr[...] /
                          jnp.maximum(l_scr[...], 1e-30)[:, None])
        if lse_ref is not None:
            m_fin = jnp.where(jnp.isinf(m_scr[...]), 0.0, m_scr[...])
            lse_ref[0, :, 0] = m_fin + jnp.log(
                jnp.maximum(l_scr[...], 1e-30))


def flash_attention_padded(q, k, v, *, causal=True, window=0,
                           interpret=False, return_lse=False):
    """q: (B, Sq, H, hd); k/v: (B, Skv, Hkv, hd); Sq % TQ == 0,
    Skv % TK == 0. Returns (B, Sq, H, hd) f32 (and, with return_lse, the
    per-row logsumexp (B, Sq, H) f32 the backward pass needs)."""
    B, Sq, H, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    group = H // Hkv
    n_q, n_k = Sq // TQ, Skv // TK
    scale = 1.0 / (hd ** 0.5)

    in_specs = [
        pl.BlockSpec((1, TQ, 1, hd), lambda b, h, qt, kt: (b, qt, h, 0)),
        pl.BlockSpec((1, TK, 1, hd),
                     lambda b, h, qt, kt, grp=group: (b, kt, h // grp, 0)),
        pl.BlockSpec((1, TK, 1, hd),
                     lambda b, h, qt, kt, grp=group: (b, kt, h // grp, 0)),
    ]
    scratch = [
        pltpu.VMEM((TQ,), jnp.float32),
        pltpu.VMEM((TQ,), jnp.float32),
        pltpu.VMEM((TQ, hd), jnp.float32),
    ]
    o_spec = pl.BlockSpec((1, TQ, 1, hd), lambda b, h, qt, kt: (b, qt, h, 0))
    o_shape = jax.ShapeDtypeStruct((B, Sq, H, hd), jnp.float32)

    if not return_lse:
        kernel = functools.partial(_kernel, causal=causal, window=window,
                                   n_k=n_k, scale=scale)
        return pl.pallas_call(
            kernel, grid=(B, H, n_q, n_k), in_specs=in_specs,
            out_specs=o_spec, out_shape=o_shape, scratch_shapes=scratch,
            interpret=interpret)(q, k, v)

    def kernel_lse(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr):
        _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                causal=causal, window=window, n_k=n_k, scale=scale,
                lse_ref=lse_ref)

    return pl.pallas_call(
        kernel_lse, grid=(B, H, n_q, n_k), in_specs=in_specs,
        out_specs=[o_spec,
                   pl.BlockSpec((1, TQ, 1), lambda b, h, qt, kt: (b, qt, h))],
        out_shape=[o_shape, jax.ShapeDtypeStruct((B, Sq, H), jnp.float32)],
        scratch_shapes=scratch, interpret=interpret)(q, k, v)
