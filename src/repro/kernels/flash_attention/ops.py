"""Jit'd wrapper for the prefill flash-attention kernel: pads Sq/Skv to tile
multiples (mask handles the padding), dispatches interpret off-TPU."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import (TK, TQ,
                                                  flash_attention_padded)
from repro.kernels.flash_attention.ref import flash_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention(q, k, v, causal: bool = True, window: int = 0):
    """q: (B, Sq, H, hd); k/v: (B, Skv, Hkv, hd). Returns (B, Sq, H, hd) f32.

    Padding note: padded q rows produce garbage rows that are sliced away;
    padded kv columns are masked out by the causal test (their positions
    exceed every real q position) — for non-causal use the caller must pad
    kv to the tile size itself.
    """
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    Sq_p = ((Sq + TQ - 1) // TQ) * TQ
    Skv_p = ((Skv + TK - 1) // TK) * TK
    if Sq_p != Sq:
        q = jnp.pad(q, ((0, 0), (0, Sq_p - Sq), (0, 0), (0, 0)))
    if Skv_p != Skv:
        k = jnp.pad(k, ((0, 0), (0, Skv_p - Skv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Skv_p - Skv), (0, 0), (0, 0)))
    out = flash_attention_padded(q, k, v, causal=causal, window=window,
                                 interpret=not _on_tpu())
    return out[:, :Sq]


def flash_attention_reference(q, k, v, causal: bool = True, window: int = 0):
    return flash_attention_ref(q, k, v, causal=causal, window=window)


# ---------------------------------------------------------------------------
# Differentiable variant (custom VJP; backward = two Pallas kernels)
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention_trainable(q, k, v, causal: bool = True, window: int = 0):
    """Like flash_attention but with a Pallas backward pass (kernel_bwd.py),
    so REPRO_PALLAS_ATTN can serve training too. Requires Sq % TQ == 0 and
    Skv % TK == 0 (the train/prefill shapes satisfy this)."""
    out, _ = _fa_fwd(q, k, v, causal, window)
    return out


def _fa_fwd(q, k, v, causal, window):
    from repro.kernels.flash_attention.kernel import flash_attention_padded
    o, lse = flash_attention_padded(q, k, v, causal=causal, window=window,
                                    interpret=not _on_tpu(), return_lse=True)
    return o, (q, k, v, o, lse)


def _fa_bwd(causal, window, res, do):
    from repro.kernels.flash_attention.kernel_bwd import flash_bwd_padded
    q, k, v, o, lse = res
    B, Sq, H, hd = q.shape
    Hkv = k.shape[2]
    group = H // Hkv
    # expand kv to Q heads; fold group gradients back afterwards
    k_r = jnp.repeat(k, group, axis=2)
    v_r = jnp.repeat(v, group, axis=2)
    Dl = jnp.sum(do.astype(jnp.float32) * o, axis=-1)        # (B, Sq, H)
    dq, dk, dv = flash_bwd_padded(q, k_r, v_r, do.astype(jnp.float32),
                                  lse, Dl, causal=causal, window=window,
                                  interpret=not _on_tpu())
    Skv = k.shape[1]
    dk = dk.reshape(B, Skv, Hkv, group, hd).sum(3)
    dv = dv.reshape(B, Skv, Hkv, group, hd).sum(3)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention_trainable.defvjp(_fa_fwd, _fa_bwd)
