"""Pure-jnp oracle for the prefill flash-attention kernel: full masked
softmax attention with GQA, causal and sliding-window options."""
from __future__ import annotations

import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """q: (B, Sq, H, hd); k/v: (B, Skv, Hkv, hd). q position i attends to kv
    position j iff (not causal or j <= i) and (window == 0 or j > i - window),
    with q offset so the last q aligns with the last kv (Sq == Skv here).
    Returns (B, Sq, H, hd) f32."""
    B, Sq, H, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    group = H // Hkv
    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, group, hd)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qf * scale, k.astype(jnp.float32))
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)
    m = jnp.where(jnp.isinf(m), 0.0, m)
    p = jnp.exp(s - m)
    p = jnp.where(mask[None, :, None, None, :], p, 0.0)
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    o = jnp.einsum("bqhgk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, hd)
