"""Pallas TPU kernels: flash-attention backward (dq and dk/dv passes).

Standard flash-bwd formulation. The forward saves per-row logsumexp
L = m + log(l); the backward recomputes each (TQ, TK) score tile in VMEM:

    p  = exp(q·kᵀ·scale - L)                (exact softmax tile)
    dv += pᵀ · do
    dp = do · vᵀ
    ds = p ⊙ (dp - D)        with D = rowsum(do ⊙ o)
    dq += ds · k · scale
    dk += dsᵀ · q · scale

Two kernels because the reductions run along different axes:
  - dq pass: grid (B, H, n_q, n_k), kv innermost, dq accumulates in scratch.
  - dkv pass: grid (B, H, n_k, n_q), q innermost, dk/dv accumulate in scratch.
GQA: dk/dv are produced per Q-head and summed over the group by the wrapper
(ops.py) — keeps both kernels free of cross-head reductions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_attention.kernel import TK, TQ


def _mask(q_lo, k_lo, causal, window):
    qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (TQ, TK), 0)
    kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (TQ, TK), 1)
    m = jnp.ones((TQ, TK), bool)
    if causal:
        m &= kpos <= qpos
    if window > 0:
        m &= kpos > qpos - window
    return m


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, L_ref, Dl_ref, dq_ref, acc,
               *, causal, window, n_k, scale):
    kt = pl.program_id(3)
    qt = pl.program_id(2)

    @pl.when(kt == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    q = q_ref[0, :, 0].astype(jnp.float32)
    k = k_ref[0, :, 0].astype(jnp.float32)
    v = v_ref[0, :, 0].astype(jnp.float32)
    do = do_ref[0, :, 0].astype(jnp.float32)
    L = L_ref[0, :, 0]
    Dl = Dl_ref[0, :, 0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    m = _mask(qt * TQ, kt * TK, causal, window)
    p = jnp.where(m, jnp.exp(s - L[:, None]), 0.0)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - Dl[:, None])
    acc[...] += scale * jax.lax.dot_general(
        ds, k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(kt == n_k - 1)
    def _fin():
        dq_ref[0, :, 0] = acc[...]


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, L_ref, Dl_ref,
                dk_ref, dv_ref, acck, accv, *, causal, window, n_q, scale):
    qt = pl.program_id(3)
    kt = pl.program_id(2)

    @pl.when(qt == 0)
    def _init():
        acck[...] = jnp.zeros_like(acck)
        accv[...] = jnp.zeros_like(accv)

    q = q_ref[0, :, 0].astype(jnp.float32)
    k = k_ref[0, :, 0].astype(jnp.float32)
    v = v_ref[0, :, 0].astype(jnp.float32)
    do = do_ref[0, :, 0].astype(jnp.float32)
    L = L_ref[0, :, 0]
    Dl = Dl_ref[0, :, 0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    m = _mask(qt * TQ, kt * TK, causal, window)
    p = jnp.where(m, jnp.exp(s - L[:, None]), 0.0)          # (TQ, TK)
    accv[...] += jax.lax.dot_general(
        p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - Dl[:, None])
    acck[...] += scale * jax.lax.dot_general(
        ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(qt == n_q - 1)
    def _fin():
        dk_ref[0, :, 0] = acck[...]
        dv_ref[0, :, 0] = accv[...]


def flash_bwd_padded(q, k, v, do, L, Dl, *, causal, window, interpret=False):
    """All per-Q-head: q/do (B, Sq, H, hd); k/v (B, Skv, H, hd) (kv already
    repeated to Q heads by the wrapper); L/Dl (B, Sq, H) f32.
    Returns dq, dk, dv (f32, same shapes as q/k/v)."""
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    n_q, n_k = Sq // TQ, Skv // TK
    scale = 1.0 / (hd ** 0.5)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, causal=causal, window=window,
                          n_k=n_k, scale=scale),
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, TQ, 1, hd), lambda b, h, qt, kt: (b, qt, h, 0)),
            pl.BlockSpec((1, TK, 1, hd), lambda b, h, qt, kt: (b, kt, h, 0)),
            pl.BlockSpec((1, TK, 1, hd), lambda b, h, qt, kt: (b, kt, h, 0)),
            pl.BlockSpec((1, TQ, 1, hd), lambda b, h, qt, kt: (b, qt, h, 0)),
            pl.BlockSpec((1, TQ, 1), lambda b, h, qt, kt: (b, qt, h)),
            pl.BlockSpec((1, TQ, 1), lambda b, h, qt, kt: (b, qt, h)),
        ],
        out_specs=pl.BlockSpec((1, TQ, 1, hd),
                               lambda b, h, qt, kt: (b, qt, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((TQ, hd), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, L, Dl)

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, causal=causal, window=window,
                          n_q=n_q, scale=scale),
        grid=(B, H, n_k, n_q),
        in_specs=[
            pl.BlockSpec((1, TQ, 1, hd), lambda b, h, kt, qt: (b, qt, h, 0)),
            pl.BlockSpec((1, TK, 1, hd), lambda b, h, kt, qt: (b, kt, h, 0)),
            pl.BlockSpec((1, TK, 1, hd), lambda b, h, kt, qt: (b, kt, h, 0)),
            pl.BlockSpec((1, TQ, 1, hd), lambda b, h, kt, qt: (b, qt, h, 0)),
            pl.BlockSpec((1, TQ, 1), lambda b, h, kt, qt: (b, qt, h)),
            pl.BlockSpec((1, TQ, 1), lambda b, h, kt, qt: (b, qt, h)),
        ],
        out_specs=[
            pl.BlockSpec((1, TK, 1, hd), lambda b, h, kt, qt: (b, kt, h, 0)),
            pl.BlockSpec((1, TK, 1, hd), lambda b, h, kt, qt: (b, kt, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Skv, H, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, Skv, H, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((TK, hd), jnp.float32),
                        pltpu.VMEM((TK, hd), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, L, Dl)
    return dq, dk, dv
