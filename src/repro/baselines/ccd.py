"""CCD++ coordinate descent MF (Yu et al. 2012, ref [18]).

Updates one latent dimension at a time across all rows, using the padded-CSR
residual formulation: for dimension k,

    u_nk <- ( Σ_d m_nd (r*_nd) v_dk ) / (reg + Σ_d m_nd v_dk²)

where r* is the residual excluding dimension k's current contribution.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import bmf as BMF
from repro.data.sparse import PaddedCSR


class CCDConfig(NamedTuple):
    K: int = 16
    reg: float = 2.0
    n_iters: int = 10            # outer passes over all K dims


def _update_dim(csr: PaddedCSR, X, other, k, reg):
    """One coordinate update of X[:, k] given the other factor."""
    Vg = other[csr.idx]                               # (N, M, K)
    pred = jnp.einsum("nmk,nk->nm", Vg, X)            # full prediction
    resid_k = csr.val - pred + X[:, k][:, None] * Vg[..., k]
    num = jnp.sum(csr.mask * resid_k * Vg[..., k], axis=1)
    den = reg + jnp.sum(csr.mask * Vg[..., k] ** 2, axis=1)
    return X.at[:, k].set(num / den)


def run_ccd(key, csr_rows: PaddedCSR, csr_cols: PaddedCSR,
            test_rows, test_cols, cfg: CCDConfig):
    N, D = csr_rows.n_rows, csr_cols.n_rows
    U, V = BMF.init_factors(key, N, D, cfg.K, scale=0.3)
    mean = (csr_rows.val * csr_rows.mask).sum() / jnp.maximum(
        csr_rows.mask.sum(), 1.0)
    csr_rows = PaddedCSR(idx=csr_rows.idx,
                         val=(csr_rows.val - mean) * csr_rows.mask,
                         mask=csr_rows.mask, n_cols=csr_rows.n_cols)
    csr_cols = PaddedCSR(idx=csr_cols.idx,
                         val=(csr_cols.val - mean) * csr_cols.mask,
                         mask=csr_cols.mask, n_cols=csr_cols.n_cols)

    @jax.jit
    def outer(carry, _):
        U, V = carry

        def per_dim(carry, k):
            U, V = carry
            U = _update_dim(csr_rows, U, V, k, cfg.reg)
            V = _update_dim(csr_cols, V, U, k, cfg.reg)
            return (U, V), None

        (U, V), _ = jax.lax.scan(per_dim, (U, V), jnp.arange(cfg.K))
        return (U, V), None

    (U, V), _ = jax.lax.scan(outer, (U, V), jnp.arange(cfg.n_iters))
    pred = BMF.predict(U, V, test_rows, test_cols) + mean
    return U, V, pred
