"""FPSGD-style blocked stochastic gradient descent MF (Teflioudi et al.,
ref [15]).

The defining feature of FPSGD/NOMAD vs plain SGD is *block scheduling*:
the rating matrix is partitioned into a grid and independent (row-block,
col-block) pairs are updated in parallel without factor conflicts. On TPU we
realize a round of the scheduler as a vmap over B conflict-free diagonal
blocks (a Latin-square schedule), each performing minibatch SGD on its local
COO triplets — the XLA-native analogue of FPSGD's worker threads.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bmf as BMF
from repro.data.sparse import COO


class SGDConfig(NamedTuple):
    K: int = 16
    lr: float = 0.05
    reg: float = 0.05
    n_epochs: int = 30
    n_blocks: int = 4            # grid size (B x B, B parallel per round)
    batch: int = 256


def _block_schedule(coo: COO, B: int, seed: int = 0):
    """Assign ratings to (bi, bj) blocks; return per-round padded triplets.

    Round r updates blocks {(i, (i + r) % B)}: conflict-free (Latin square).
    """
    rng = np.random.default_rng(seed)
    bi = coo.row % B
    bj = coo.col % B
    rounds = []
    for r in range(B):
        sel = np.where((bj - bi) % B == r)[0]
        rng.shuffle(sel)
        rounds.append(sel)
    m = max(len(s) for s in rounds)
    idx = np.zeros((B, m), np.int64)
    msk = np.zeros((B, m), np.float32)
    for r, sel in enumerate(rounds):
        idx[r, :len(sel)] = sel
        msk[r, :len(sel)] = 1.0
    return idx, msk


def run_sgd(key, train: COO, test_rows, test_cols, cfg: SGDConfig):
    N, D = train.n_rows, train.n_cols
    U, V = BMF.init_factors(key, N, D, cfg.K, scale=0.3)
    rows = jnp.asarray(train.row)
    cols = jnp.asarray(train.col)
    vals = jnp.asarray(train.val)
    r_idx, r_msk = _block_schedule(train, cfg.n_blocks)
    r_idx = jnp.asarray(r_idx)
    r_msk = jnp.asarray(r_msk)
    mean = vals.mean()

    @jax.jit
    def epoch(carry, _):
        U, V = carry

        def round_step(carry, r):
            U, V = carry
            sel = r_idx[r]
            w = r_msk[r]

            def mini(carry, i):
                U, V = carry
                lo = i * cfg.batch
                s = jax.lax.dynamic_slice_in_dim(sel, lo, cfg.batch)
                wr = jax.lax.dynamic_slice_in_dim(w, lo, cfg.batch)
                r_ = rows[s]
                c_ = cols[s]
                v_ = vals[s] - mean
                u = U[r_]
                vt = V[c_]
                err = (jnp.einsum("bk,bk->b", u, vt) - v_) * wr
                gu = err[:, None] * vt + cfg.reg * u * wr[:, None]
                gv = err[:, None] * u + cfg.reg * vt * wr[:, None]
                U = U.at[r_].add(-cfg.lr * gu)
                V = V.at[c_].add(-cfg.lr * gv)
                return (U, V), None

            n_mini = max(1, r_idx.shape[1] // cfg.batch)
            (U, V), _ = jax.lax.scan(mini, (U, V), jnp.arange(n_mini))
            return (U, V), None

        (U, V), _ = jax.lax.scan(round_step, (U, V),
                                 jnp.arange(cfg.n_blocks))
        return (U, V), None

    (U, V), _ = jax.lax.scan(epoch, (U, V), jnp.arange(cfg.n_epochs))
    pred = BMF.predict(U, V, test_rows, test_cols) + mean
    return U, V, pred
