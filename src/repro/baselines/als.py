"""Alternating Least Squares MF baseline (Koren et al. 2009, ref [14]).

Same padded-CSR data path as the Gibbs sampler; each half-iteration solves
the ridge-regularized normal equations per row — i.e. exactly the BMF
conditional mode instead of a posterior draw, so it shares
``bmf.sufficient_stats`` (and the Pallas kernel when enabled).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import bmf as BMF
from repro.data.sparse import PaddedCSR


class ALSConfig(NamedTuple):
    K: int = 16
    reg: float = 2.0
    n_iters: int = 20
    use_kernel: bool = False


def solve_factor(csr: PaddedCSR, other: jnp.ndarray, reg: float,
                 use_kernel: bool = False) -> jnp.ndarray:
    Lam, eta = BMF.sufficient_stats(csr, other, tau=1.0, use_kernel=use_kernel)
    K = other.shape[-1]
    Lam = Lam + reg * jnp.eye(K)
    return jnp.linalg.solve(Lam, eta[..., None])[..., 0]


def run_als(key, csr_rows: PaddedCSR, csr_cols: PaddedCSR,
            test_rows, test_cols, cfg: ALSConfig):
    N, D = csr_rows.n_rows, csr_cols.n_rows
    U, V = BMF.init_factors(key, N, D, cfg.K)
    # global-mean centering (standard ALS practice; BMF handles the mean
    # through the adaptive NW hyperprior instead)
    mean = (csr_rows.val * csr_rows.mask).sum() / jnp.maximum(
        csr_rows.mask.sum(), 1.0)
    rows_c = PaddedCSR(idx=csr_rows.idx, val=(csr_rows.val - mean) * csr_rows.mask,
                       mask=csr_rows.mask, n_cols=csr_rows.n_cols)
    cols_c = PaddedCSR(idx=csr_cols.idx, val=(csr_cols.val - mean) * csr_cols.mask,
                       mask=csr_cols.mask, n_cols=csr_cols.n_cols)

    def body(i, carry):
        U, V = carry
        U = solve_factor(rows_c, V, cfg.reg, cfg.use_kernel)
        V = solve_factor(cols_c, U, cfg.reg, cfg.use_kernel)
        return U, V

    U, V = jax.lax.fori_loop(0, cfg.n_iters, body, (U, V))
    pred = BMF.predict(U, V, test_rows, test_cols) + mean
    return U, V, pred
