"""Roofline-term derivation from compiled XLA artifacts + jaxpr costs.

Three terms (seconds), per device, for a TPU v5e:

    compute    = FLOPs/device    / peak_FLOPs       (197e12 bf16 FLOP/s/chip)
    memory     = bytes/device    / HBM_bw           (819e9  B/s/chip)
    collective = coll_B/device   / ICI_bw           (~50e9  B/s/link × links)

Methodology (see EXPERIMENTS §Dry-run):
  - FLOPs/bytes come from the *jaxpr* cost model (repro.roofline.jaxpr_cost):
    XLA's cost_analysis counts while-loop bodies once, undercounting scanned
    layer stacks by ~n_layers. Jaxpr costs are global → divide by chips.
  - Collective bytes are parsed from the compiled (post-SPMD, per-device)
    HLO text with a computation call-graph walk that multiplies while-loop
    bodies by their trip count (extracted from the loop-condition constant).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

PEAK_FLOPS = 197e12         # bf16 FLOP/s per v5e chip
HBM_BW = 819e9              # B/s per chip
ICI_BW = 50e9               # B/s per link
ICI_LINKS = 2               # usable links per collective on a 2D torus axis

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                   "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'f32[16,128]' -> 8192; tuple shapes sum their element shapes."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


# ---------------------------------------------------------------------------
# HLO computation graph
# ---------------------------------------------------------------------------

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->", re.M)
_CALLSITE = re.compile(
    r"(?:condition|body|to_apply|calls|true_computation|false_computation)="
    r"%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")


def _split_computations(hlo_text: str):
    """Return {name: [lines]} per HLO computation, plus the ENTRY name."""
    comps = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        m = _COMP_HDR.match(line.strip())
        if m and line.rstrip().endswith("{"):
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
                continue
            comps[cur].append(line)
    return comps, entry


def _line_op_and_shape(line: str):
    m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)", line.strip())
    if not m:
        return None, None
    return m.group(2), m.group(1)


def _while_trip_count(cond_lines) -> int:
    """Largest integer constant in the loop-condition computation — for
    lax.scan-lowered loops this is the trip count."""
    best = 1
    for line in cond_lines:
        for m in re.finditer(r"constant\((\d+)\)", line):
            best = max(best, int(m.group(1)))
    return best


def collective_bytes_graph(hlo_text: str) -> Dict[str, float]:
    """Collective result-bytes summed over the computation call graph, with
    while-loop bodies multiplied by their trip counts."""
    comps, entry = _split_computations(hlo_text)
    if entry is None:
        return {k: 0.0 for k in _COLLECTIVE_OPS}

    memo = {}

    def cost(name):
        if name in memo:
            return memo[name]
        memo[name] = {k: 0.0 for k in _COLLECTIVE_OPS}  # cycle guard
        out = {k: 0.0 for k in _COLLECTIVE_OPS}
        for line in comps.get(name, ()):
            op, shape_str = _line_op_and_shape(line)
            if op is None:
                continue
            base = op[:-len("-start")] if op.endswith("-start") else op
            if base in _COLLECTIVE_OPS:
                out[base] += _shape_bytes(shape_str)
            if base == "while":
                mb = _CALLSITE.findall(line)
                body = cond = None
                for m2 in re.finditer(r"(condition|body)=%?([\w.\-]+)", line):
                    if m2.group(1) == "body":
                        body = m2.group(2)
                    else:
                        cond = m2.group(2)
                if body:
                    trips = _while_trip_count(comps.get(cond, ())) if cond else 1
                    sub = cost(body)
                    for k in out:
                        out[k] += trips * sub[k]
            elif base in ("call", "fusion", "conditional", "async-start"):
                for callee in _CALLSITE.findall(line):
                    sub = cost(callee)
                    for k in out:
                        out[k] += sub[k]
                mbr = _BRANCHES.search(line)
                if mbr:
                    subs = [cost(c.strip().lstrip("%"))
                            for c in mbr.group(1).split(",")]
                    if subs:
                        worst = max(subs, key=lambda s: sum(s.values()))
                        for k in out:
                            out[k] += worst[k]
        memo[name] = out
        return out

    totals = cost(entry)
    return totals


_REPLICA_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^=]*?\})\}")
# iota list format: replica_groups=[G,S]<=[d0,d1,...] with an optional
# transpose suffix T(p0,p1,...) — groups are rows of
# reshape(transpose(iota(d), p), (G, S))
_IOTA_GROUPS_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_PAIRS_RE = re.compile(r"source_target_pairs=\{((?:\{\d+,\d+\},?)+)\}")


def collective_replica_groups(hlo_text: str):
    """Parse every collective instruction's participant groups.

    Returns ``[(op, [[device ids], ...]), ...]`` — one entry per collective
    HLO line, each with its replica groups as lists of device ids. Handles
    the braces format (``replica_groups={{0,1},{2,3}}``), the iota format
    (``replica_groups=[2,2]<=[4]``, including a transposed assignment
    ``<=[2,2]T(1,0)``), and collective-permute's ``source_target_pairs``
    (each (src, dst) pair is a 2-device group). Used by the dry-run to
    assert that a composed ('block','data') executable's collectives are
    CONFINED to the 'data' axis: with the mesh's default device order, a
    data-axis group is a contiguous run inside one block row, while any
    'block'-axis collective would span rows. Unparsable participant
    formats yield ``[]`` — classified conservatively as spanning
    everything, so the confinement check fails LOUDLY rather than
    passing on a format this parser does not know."""
    import numpy as np
    out = []
    for line in hlo_text.splitlines():
        op, _ = _line_op_and_shape(line)
        if op is None:
            continue
        base = op[:-len("-start")] if op.endswith("-start") else op
        if base not in _COLLECTIVE_OPS:
            continue
        m = _REPLICA_GROUPS_RE.search(line)
        if m:
            groups = [[int(x) for x in grp.split(",") if x.strip()]
                      for grp in re.findall(r"\{([^{}]*)\}", m.group(1))]
            out.append((base, groups))
            continue
        m = _IOTA_GROUPS_RE.search(line)
        if m:
            n_groups, size = int(m.group(1)), int(m.group(2))
            dims = [int(x) for x in m.group(3).split(",")]
            ids = np.arange(int(np.prod(dims))).reshape(dims)
            if m.group(4):
                perm = [int(x) for x in m.group(4).split(",")]
                ids = ids.transpose(perm)
            groups = ids.reshape(n_groups, size).tolist()
            out.append((base, groups))
            continue
        m = _PAIRS_RE.search(line)
        if m:
            pairs = [[int(x) for x in grp.split(",")]
                     for grp in re.findall(r"\{(\d+,\d+)\}", m.group(1))]
            out.append((base, pairs))
            continue
        out.append((base, []))            # unparsed: treat as all devices
    return out


def collectives_confined_to_groups(hlo_text: str, allowed_groups) -> Dict:
    """Check every collective's replica groups lie WITHIN the allowed
    device groups (e.g. a topology's 'data'-axis rows). Returns
    ``{"n_collectives", "n_confined", "n_crossing", "crossing"}`` where
    ``crossing`` lists (op, group) pairs that span allowed-group
    boundaries — for the composed PP executable this list must be empty
    (nothing ever reduces over the 'block' axis)."""
    allowed = [frozenset(g) for g in allowed_groups]
    crossing = []
    n = 0
    for op, groups in collective_replica_groups(hlo_text):
        n += 1
        if not groups:                    # un-grouped = spans everything
            crossing.append((op, "all"))
            continue
        bad = [grp for grp in groups
               if not any(set(grp) <= a for a in allowed)]
        if bad:                           # one crossing entry per OP
            crossing.append((op, bad[0]))
    return {"n_collectives": n, "n_crossing": len(crossing),
            "n_confined": n - len(crossing), "crossing": crossing}


def collective_counts(hlo_text: str) -> Dict[str, int]:
    """Flat per-kind collective instruction counts ({op: n}, zero-count
    kinds omitted). The sweep body appears once in HLO text, so for the
    chain executables a flat count IS the per-sweep count — this is what
    the analysis layer's per-comm-mode collective budgets check against."""
    counts: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        op, _ = _line_op_and_shape(line)
        if op is None:
            continue
        base = op[:-len("-start")] if op.endswith("-start") else op
        if base in _COLLECTIVE_OPS:
            counts[base] = counts.get(base, 0) + 1
    return counts


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Graph-walked collective bytes + op counts (flat, for reporting)."""
    g = collective_bytes_graph(hlo_text)
    flat = collective_counts(hlo_text)
    flat_counts = {f"n_{k}": flat.get(k, 0) for k in _COLLECTIVE_OPS}
    return {**g, **flat_counts}


# ---------------------------------------------------------------------------
# Roofline terms
# ---------------------------------------------------------------------------


@dataclass
class RooflineTerms:
    flops: float          # per device
    hbm_bytes: float      # per device
    coll_bytes: float     # per device
    compute_s: float = field(init=False)
    memory_s: float = field(init=False)
    collective_s: float = field(init=False)

    def __post_init__(self):
        self.compute_s = self.flops / PEAK_FLOPS
        self.memory_s = self.hbm_bytes / HBM_BW
        self.collective_s = self.coll_bytes / (ICI_BW * ICI_LINKS)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        return self.compute_s + self.memory_s + self.collective_s

    def as_dict(self):
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes, "compute_s": self.compute_s,
            "memory_s": self.memory_s, "collective_s": self.collective_s,
            "dominant": self.dominant,
        }


def model_flops_per_step(n_params_active: int, tokens: int, kind: str) -> float:
    """6ND for train (fwd+bwd), 2ND for inference forward."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_params_active * tokens


def terms_from(jaxpr_costs: Dict[str, float], hlo_text: str,
               n_chips: int) -> RooflineTerms:
    """Combine global jaxpr costs (÷ chips) with per-device HLO collectives.

    The memory term uses ``bytes_min`` (dot/conv/gather operand+result
    traffic = the fused-ideal HBM traffic; XLA fuses elementwise chains into
    dot epilogues on TPU). ``bytes`` (un-fused upper bound) is recorded
    alongside by the dry-run for the band.
    """
    coll = collective_bytes_graph(hlo_text)
    coll_total = sum(coll.values())
    return RooflineTerms(flops=jaxpr_costs["flops"] / n_chips,
                         hbm_bytes=jaxpr_costs["bytes_min"] / n_chips,
                         coll_bytes=coll_total)
