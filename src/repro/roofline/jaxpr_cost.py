"""Jaxpr-level cost model: exact FLOP counting with scan trip-count
multiplication (XLA's ``cost_analysis`` counts while-loop bodies ONCE, which
undercounts a scanned-layer transformer by ~n_layers — see EXPERIMENTS
§Dry-run methodology).

``jaxpr_cost(jitted.trace(...).jaxpr)`` walks the closed jaxpr:
  - dot_general: 2 · prod(batch) · M · N · K
  - scan: recurse × length
  - while: recurse × 1 (trip unknown; we don't emit unbounded whiles)
  - pjit / remat / custom_*: recurse (remat'd recompute appears explicitly
    in the grad jaxpr, so backward recompute is counted faithfully)
  - everything else: 1 flop per output element (elementwise estimate)

Byte counting sums operand+result sizes of dots, gathers/scatters/
dynamic-slices and scan-carried streams — an un-fused upper bound for HBM
traffic (fusion reduces elementwise traffic; dots dominate the shapes we
care about). FLOPs/bytes here are GLOBAL (the jaxpr is the pre-SPMD
program); divide by chip count for per-device terms.
"""
from __future__ import annotations

from typing import Dict

import jax
import numpy as np
from jax import core as jcore


def _nbytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0


def _dot_flops(eqn) -> int:
    dims = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dims
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    out = eqn.outvars[0].aval
    k = 1
    for d in lc:
        k *= lhs.shape[d]
    return 2 * int(np.prod(out.shape)) * k


def _conv_flops(eqn) -> int:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    # flops = 2 * out_elems * (kernel spatial * in_features)
    k = int(np.prod(rhs.shape[:-1]))
    return 2 * int(np.prod(out.shape)) * k


_RECURSE_CALL = {"pjit", "closed_call", "custom_jvp_call", "custom_vjp_call",
                 "custom_vjp_call_jaxpr", "remat2", "checkpoint", "core_call",
                 "xla_call", "named_call", "custom_transpose_call"}


def jaxpr_cost(jaxpr, mult: int = 1) -> Dict[str, float]:
    """Returns {'flops', 'bytes', 'dot_flops', 'elem_flops'} for one jaxpr
    (pass ClosedJaxpr.jaxpr or Jaxpr)."""
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    total = {"flops": 0.0, "bytes": 0.0, "bytes_min": 0.0,
             "dot_flops": 0.0, "elem_flops": 0.0}

    def add(key, v):
        total[key] += mult * v

    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "dot_general":
            f = _dot_flops(eqn)
            b = (sum(_nbytes(v.aval) for v in eqn.invars) +
                 sum(_nbytes(v.aval) for v in eqn.outvars))
            add("flops", f)
            add("dot_flops", f)
            add("bytes", b)
            add("bytes_min", b)
        elif prim in ("conv_general_dilated",):
            f = _conv_flops(eqn)
            b = (sum(_nbytes(v.aval) for v in eqn.invars) +
                 sum(_nbytes(v.aval) for v in eqn.outvars))
            add("flops", f)
            add("dot_flops", f)
            add("bytes", b)
            add("bytes_min", b)
        elif prim == "pallas_call":
            # cost the kernel body per grid step × grid product. FLOPs are
            # exact. Bytes: each ref's BLOCK (the inner aval) is fetched per
            # grid step — an upper bound on HBM traffic (Pallas skips
            # refetching blocks whose index is unchanged between consecutive
            # steps, e.g. the q tile across the kv axis of flash attention);
            # VMEM scratch (online-softmax state, pairwise score tiles)
            # correctly contributes nothing.
            inner_jaxpr = eqn.params.get("jaxpr")
            gm = eqn.params.get("grid_mapping")
            grid = tuple(getattr(gm, "grid", ())) if gm is not None else ()
            steps = 1
            for g in grid:
                steps *= int(g)
            if inner_jaxpr is not None:
                inner = jaxpr_cost(inner_jaxpr, mult=1)
                total["flops"] += mult * steps * inner["flops"]
                total["dot_flops"] += mult * steps * inner["dot_flops"]
                total["elem_flops"] += mult * steps * inner["elem_flops"]
                ij = (inner_jaxpr.jaxpr if hasattr(inner_jaxpr, "jaxpr")
                      else inner_jaxpr)
                block_bytes = sum(_nbytes(v.aval) for v in ij.invars
                                  if hasattr(v.aval, "shape"))
                add("bytes", steps * block_bytes)
                add("bytes_min", steps * block_bytes)
            else:
                b = (sum(_nbytes(v.aval) for v in eqn.invars) +
                     sum(_nbytes(v.aval) for v in eqn.outvars))
                add("bytes", b)
                add("bytes_min", b)
        elif prim == "scan":
            inner = jaxpr_cost(eqn.params["jaxpr"], mult=1)
            length = eqn.params["length"]
            n_unroll = eqn.params.get("unroll", 1) or 1
            trips = length
            for k in total:
                total[k] += mult * trips * inner[k]
        elif prim == "while":
            inner = jaxpr_cost(eqn.params["body_jaxpr"], mult=1)
            for k in total:
                total[k] += mult * inner[k]  # trip count unknown
        elif prim == "cond":
            branches = [jaxpr_cost(b, mult=1) for b in eqn.params["branches"]]
            worst = max(branches, key=lambda c: c["flops"])
            for k in total:
                total[k] += mult * worst[k]
        elif prim in _RECURSE_CALL or "jaxpr" in eqn.params or "call_jaxpr" in eqn.params:
            sub = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
            if sub is not None:
                inner = jaxpr_cost(sub, mult=1)
                for k in total:
                    total[k] += mult * inner[k]
        elif prim in ("gather", "scatter", "scatter-add", "scatter_add",
                      "dynamic_slice", "dynamic_update_slice", "take"):
            b = sum(_nbytes(v.aval) for v in eqn.outvars) * 2
            add("bytes", b)
            add("bytes_min", b)
        else:
            out_elems = sum(int(np.prod(v.aval.shape)) for v in eqn.outvars
                            if hasattr(v.aval, "shape"))
            add("flops", out_elems)
            add("elem_flops", out_elems)
            add("bytes", sum(_nbytes(v.aval) for v in eqn.invars) +
                sum(_nbytes(v.aval) for v in eqn.outvars))
    return total


def traced_cost(jitted, *args) -> Dict[str, float]:
    """Cost of a jitted function at given (abstract) args."""
    tr = jitted.trace(*args)
    return jaxpr_cost(tr.jaxpr)


def iter_eqns(jaxpr):
    """Yield every eqn in a (closed) jaxpr, recursing into sub-jaxprs
    hiding in eqn params (scan/while bodies, cond branches, pjit
    sub-jaxprs, pallas kernel jaxprs) — the traversal the dtype and
    host-callback lint passes run on."""
    from jax.core import ClosedJaxpr, Jaxpr

    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield eqn
        for p in eqn.params.values():
            for q in (p if isinstance(p, (list, tuple)) else [p]):
                if isinstance(q, (ClosedJaxpr, Jaxpr)):
                    yield from iter_eqns(q)


def iter_avals(jaxpr):
    """Yield every aval appearing anywhere in a (closed) jaxpr — eqn
    in/outvars plus all sub-jaxprs hiding in eqn params (scan bodies,
    pallas kernel jaxprs, cond branches, ...)."""
    from jax.core import ClosedJaxpr, Jaxpr

    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    for v in list(jaxpr.invars) + list(jaxpr.outvars) + list(jaxpr.constvars):
        aval = getattr(v, "aval", None)
        if hasattr(aval, "shape"):
            yield aval
    for eqn in jaxpr.eqns:
        for v in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(v, "aval", None)
            if hasattr(aval, "shape"):
                yield aval
        for p in eqn.params.values():
            for q in (p if isinstance(p, (list, tuple)) else [p]):
                if isinstance(q, (ClosedJaxpr, Jaxpr)):
                    yield from iter_avals(q)


def peak_buffer_bytes(jaxpr) -> int:
    """Largest single buffer (aval) anywhere in the jaxpr, sub-jaxprs
    included — a cheap proxy for the materialization high-water mark (e.g.
    the (N, M, K) gathered-factor tensor of a naive BMF sufficient-stats
    formulation shows up here; the fused/chunked paths don't have it)."""
    return max((_nbytes(a) for a in iter_avals(jaxpr)), default=0)
